//! Quickstart: optimize the block size with the paper's bound, run the
//! pipelined protocol, and compare against transmit-everything-first.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use edgepipe::bound::corollary1::BoundParams;
use edgepipe::bound::{estimate_constants, optimize_block_size};
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::{ridge_solution, RidgeModel};

fn main() -> Result<()> {
    // 1. the paper's dataset (synthetic CalHousing-like; see DESIGN.md §3)
    let raw = synth_calhousing(&SynthSpec::default());
    let (train, _) = train_split(&raw, 0.9, 42);
    println!("dataset: N = {} samples, d = {}", train.n, train.d);

    // 2. protocol setup: T = 1.5 N, overhead n_o = 100, τ_p = 1
    let t_budget = 1.5 * train.n as f64;
    let n_o = 100.0;

    // 3. estimate the bound constants (L, c from the Gramian; D from a
    //    pilot run) and pick the block size that minimizes Corollary 1
    let k = estimate_constants(&train, 0.05, 1e-4, 2000, 42);
    let params = BoundParams {
        alpha: 1e-4,
        big_l: k.big_l,
        c: k.c,
        m: 1.0,
        m_g: 1.0,
        d_diam: k.d_diam,
    };
    let opt = optimize_block_size(&params, train.n, t_budget, n_o, 1.0);
    println!(
        "bound-optimal block size ñ_c = {} (case {:?}, bound {:.4})",
        opt.n_c, opt.case, opt.value
    );

    // 4. run the pipelined protocol at ñ_c, and the transmit-all baseline
    let run_at = |n_c: usize| -> Result<f64> {
        let cfg = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(n_c, n_o, t_budget, 42)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(train.d, cfg.lambda, train.n),
            cfg.alpha,
        );
        Ok(run_des(&train, &cfg, &mut IdealChannel, &mut exec)?.final_loss)
    };
    let pipelined = run_at(opt.n_c)?;
    let all_first = run_at(train.n)?;

    let w_star = ridge_solution(&train, 0.05)?;
    let loss_star = train.ridge_loss(&w_star, 0.05 / train.n as f64);
    println!("final training loss:");
    println!("  pipelined @ ñ_c        = {pipelined:.6}");
    println!("  transmit-all-first     = {all_first:.6}");
    println!("  optimal L(w*)          = {loss_star:.6}");
    println!(
        "pipelining recovers {:.1}% of the achievable improvement",
        100.0 * (all_first - pipelined) / (all_first - loss_star)
    );
    Ok(())
}
