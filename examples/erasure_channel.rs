//! Future-work extension (paper Sec. 6): channel errors and rate
//! selection. Runs the protocol over an erasure channel with ARQ, shows
//! how packet loss shifts the effective optimum, and scans the
//! transmission rate on the outage model.
//!
//! ```bash
//! cargo run --release --example erasure_channel
//! ```

use anyhow::Result;
use edgepipe::channel::{Channel, ErasureChannel, IdealChannel};
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::extensions::rate_select::{
    best_rate, expected_slowdown, rate_sweep,
};
use edgepipe::model::RidgeModel;

fn main() -> Result<()> {
    let raw = synth_calhousing(&SynthSpec { n: 4000, ..Default::default() });
    let (train, _) = train_split(&raw, 0.9, 42);
    let t_budget = 1.5 * train.n as f64;
    let cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(120, 30.0, t_budget, 7)
    };
    let mk = || {
        NativeExecutor::new(
            RidgeModel::new(train.d, cfg.lambda, train.n),
            cfg.alpha,
        )
    };

    println!("— erasure channel with ARQ (n_c={}, n_o={}) —", cfg.n_c, cfg.n_o);
    let mut ideal = IdealChannel;
    let base = run_des(&train, &cfg, &mut ideal, &mut mk())?;
    println!(
        "  p_loss=0.00: loss {:.6}, delivered {:>5}, retrans {:>4}",
        base.final_loss, base.samples_delivered, base.retransmissions
    );
    for p_loss in [0.1, 0.3, 0.5] {
        let mut ch = ErasureChannel::new(p_loss);
        let r = run_des(&train, &cfg, &mut ch, &mut mk())?;
        println!(
            "  p_loss={p_loss:.2}: loss {:.6}, delivered {:>5}, retrans \
             {:>4}  ({})",
            r.final_loss,
            r.samples_delivered,
            r.retransmissions,
            ch.describe()
        );
    }

    println!("\n— rate selection on the outage model p(r)=1-exp(-κ(r-1)) —");
    for kappa in [0.2, 0.8] {
        let r_star = best_rate(kappa, 6.0);
        println!(
            "  κ={kappa}: analytic best rate r*={r_star:.2} (slowdown \
             {:.3})",
            expected_slowdown(r_star, kappa)
        );
        let rows =
            rate_sweep(&train, &cfg, &[1.0, r_star, 4.0], kappa, 3);
        for (rate, loss) in rows {
            println!("    rate {rate:>4.2}: mean final loss {loss:.6}");
        }
    }
    Ok(())
}
