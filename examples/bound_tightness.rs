//! Bound-tightness study: Theorem 1 (with measured per-block gaps) vs
//! Corollary 1 (the LD²/2 relaxation) vs the actual measured optimality
//! gap — the hierarchy actual ≤ Theorem 1 ≤ Corollary 1 made concrete.
//!
//! ```bash
//! cargo run --release --example bound_tightness
//! ```

use anyhow::Result;
use edgepipe::bound::corollary1::{corollary1_bound, BoundParams};
use edgepipe::bound::estimate_constants;
use edgepipe::bound::theorem1::{theorem1_case_b, BlockGaps};
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::{ridge_solution, RidgeModel};
use edgepipe::protocol::TimelineCase;

fn main() -> Result<()> {
    let raw = synth_calhousing(&SynthSpec { n: 4000, ..Default::default() });
    let (train, _) = train_split(&raw, 0.9, 42);
    let t_budget = 1.5 * train.n as f64;
    let (alpha, lambda, n_o) = (1e-4, 0.05, 50.0);

    let k = estimate_constants(&train, lambda, alpha, 2000, 42);
    let params = BoundParams {
        alpha,
        big_l: k.big_l,
        c: k.c,
        m: 1.0,
        m_g: 1.0,
        d_diam: k.d_diam,
    };
    let w_star = ridge_solution(&train, lambda)?;
    let loss_star = train.ridge_loss(&w_star, lambda / train.n as f64);

    println!(
        "bound hierarchy at N={}, T={t_budget}, n_o={n_o} (L={:.3}, \
         c={:.3}, D={:.2}):",
        train.n, k.big_l, k.c, k.d_diam
    );
    println!(
        "{:>7} | {:>12} | {:>12} | {:>12}",
        "n_c", "actual gap", "theorem 1", "corollary 1"
    );
    for n_c in [150usize, 400, 1200] {
        let cfg = DesConfig {
            collect_snapshots: true,
            record_blocks: false,
            ..DesConfig::paper(n_c, n_o, t_budget, 3)
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(train.d, lambda, train.n),
            alpha,
        );
        let run = run_des(&train, &cfg, &mut IdealChannel, &mut exec)?;
        anyhow::ensure!(
            run.case == TimelineCase::Full,
            "pick n_c values in case (b) for this example"
        );

        // measured per-block gaps: L_b(w_b^{n_p}) − L_b(w*) over each
        // block's own samples (paper eq. (7))
        let gaps: Vec<f64> = run
            .snapshots
            .iter()
            .map(|s| {
                let block_ds = edgepipe::data::Dataset::new(
                    s.x.clone(),
                    s.y.clone(),
                    s.y.len(),
                    train.d,
                );
                block_ds.ridge_loss(&s.w_end, lambda / train.n as f64)
                    - block_ds.ridge_loss(&w_star, lambda / train.n as f64)
            })
            .collect();
        let b_d = run.snapshots.len();
        let block_len = n_c as f64 + n_o;
        let n_l = (t_budget - b_d as f64 * block_len).max(0.0);
        let th1 = theorem1_case_b(
            &params,
            &BlockGaps { gaps, remainder_gap: 0.0 },
            b_d,
            block_len,
            n_l,
        );
        let co1 = corollary1_bound(
            &params, train.n, t_budget, n_c as f64, n_o, 1.0, false,
        );
        let actual = run.final_loss - loss_star;
        println!(
            "{n_c:>7} | {actual:>12.6} | {th1:>12.6} | {co1:>12.6}"
        );
        anyhow::ensure!(actual <= th1 * 1.05, "Theorem 1 violated!");
        anyhow::ensure!(th1 <= co1 * 1.05, "Corollary 1 tighter than Thm 1?");
    }
    println!("hierarchy holds: actual ≤ Theorem 1 ≤ Corollary 1.");
    Ok(())
}
