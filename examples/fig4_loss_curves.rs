//! Regenerate paper Fig. 4: average training loss vs normalized time for
//! the bound optimum ñ_c, the experimentally optimal n_c*, and reference
//! block sizes — and report the bound-vs-experiment penalty the paper
//! quotes as ≈ 3.8 %. Writes CSVs to out/.
//!
//! Set `FIG4_FAST=1` to shrink the Monte-Carlo sweep.
//!
//! ```bash
//! cargo run --release --example fig4_loss_curves
//! ```

use anyhow::Result;
use edgepipe::bound::corollary1::BoundParams;
use edgepipe::bound::estimate_constants;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::metrics::writer::write_csv;
use edgepipe::sweep::fig4::{fig4_data, Fig4Config};

fn main() -> Result<()> {
    let fast = std::env::var("FIG4_FAST").is_ok();
    let raw = synth_calhousing(&SynthSpec::default());
    let (train, _) = train_split(&raw, 0.9, 42);
    let t_budget = 1.5 * train.n as f64;
    let n_o = 100.0;

    let k = estimate_constants(&train, 0.05, 1e-4, 2000, 42);
    let params = BoundParams {
        alpha: 1e-4,
        big_l: k.big_l,
        c: k.c,
        m: 1.0,
        m_g: 1.0,
        d_diam: k.d_diam,
    };

    let cfg = Fig4Config {
        seeds: if fast { 3 } else { 10 },
        search_points: if fast { 10 } else { 24 },
        ..Fig4Config::paper(n_o, t_budget)
    };
    let out = fig4_data(&train, &params, &cfg)?;
    print!("{}", out.render());

    let dir = std::path::Path::new("out");
    write_csv(&out.curve_table(), &dir.join("fig4_curves.csv"))?;
    write_csv(&out.search_table(), &dir.join("fig4_search.csv"))?;
    println!("wrote out/fig4_curves.csv and out/fig4_search.csv");
    Ok(())
}
