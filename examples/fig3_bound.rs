//! Regenerate paper Fig. 3: the Corollary-1 bound versus block size n_c
//! for several overheads, with the optimum ñ_c (cross) and the
//! full-delivery boundary (dot) per curve. Writes CSVs to out/.
//!
//! ```bash
//! cargo run --release --example fig3_bound
//! ```

use anyhow::Result;
use edgepipe::bound::corollary1::BoundParams;
use edgepipe::bound::estimate_constants;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::metrics::writer::write_csv;
use edgepipe::sweep::fig3::fig3_data;

fn main() -> Result<()> {
    // the paper's Fig. 3 parameters: N = 18 576, T = 1.5 N, τ_p = 1,
    // α = 1e-4, L = 1.908, c = 0.061, M = M_G = 1
    let raw = synth_calhousing(&SynthSpec::default());
    let (train, _) = train_split(&raw, 0.9, 42);
    let t_budget = 1.5 * train.n as f64;

    // constants estimated from the data (matching the paper's), D from a
    // pilot run
    let k = estimate_constants(&train, 0.05, 1e-4, 2000, 42);
    let params = BoundParams {
        alpha: 1e-4,
        big_l: k.big_l,
        c: k.c,
        m: 1.0,
        m_g: 1.0,
        d_diam: k.d_diam,
    };

    let out = fig3_data(
        &params,
        train.n,
        t_budget,
        1.0,
        &[1.0, 10.0, 100.0, 1000.0],
        160,
    )?;
    print!("{}", out.render());

    let dir = std::path::Path::new("out");
    write_csv(&out.curve_table(), &dir.join("fig3_curves.csv"))?;
    write_csv(&out.marker_table(), &dir.join("fig3_markers.csv"))?;
    println!("wrote out/fig3_curves.csv and out/fig3_markers.csv");
    Ok(())
}
