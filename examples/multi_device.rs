//! Future-work extension (paper Sec. 6): multiple devices sharing the
//! uplink round-robin, each holding a disjoint shard of the dataset.
//! Compares device counts at fixed total data and shows the overhead
//! multiplication effect on the optimal block size.
//!
//! ```bash
//! cargo run --release --example multi_device
//! ```

use anyhow::Result;
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::DesConfig;
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::extensions::multi_device::{run_multi_device, shard_dataset};
use edgepipe::model::RidgeModel;

fn main() -> Result<()> {
    let raw = synth_calhousing(&SynthSpec { n: 6000, ..Default::default() });
    let (train, _) = train_split(&raw, 0.9, 42);
    let t_budget = 1.2 * train.n as f64;
    let n_o = 50.0;

    println!(
        "multi-device edge learning: N={} total, T={t_budget}, n_o={n_o}",
        train.n
    );
    for devices in [1usize, 2, 4, 8] {
        let shards = shard_dataset(&train, devices);
        // per-turn payload chosen so the union cycle payload stays fixed
        for n_c in [64usize, 256, 1024] {
            let cfg = DesConfig {
                record_blocks: false,
                ..DesConfig::paper(n_c, n_o, t_budget, 11)
            };
            let mut exec = NativeExecutor::new(
                RidgeModel::new(train.d, cfg.lambda, train.n),
                cfg.alpha,
            );
            let r = run_multi_device(
                &train,
                &shards,
                &cfg,
                &mut IdealChannel,
                &mut exec,
            )?;
            println!(
                "  devices={devices} n_c={n_c:>5}: loss {:.6} delivered \
                 {:>5}/{} blocks {:>4}",
                r.final_loss,
                r.samples_delivered,
                train.n,
                r.blocks_sent
            );
        }
    }
    println!(
        "note: more devices -> more packets for the same data -> overhead \
         paid more often; larger n_c amortizes it (same trade-off as Fig. 3)."
    );
    Ok(())
}
