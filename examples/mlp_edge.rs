//! Model-generality extension: train an MLP (~68k parameters) through the
//! SAME pipelined protocol, with every forward/backward pass running in
//! the AOT JAX/Pallas `mlp_step` artifact (fused tiled matmul kernels).
//!
//! The device streams a synthetic nonlinear regression dataset in blocks;
//! the edge node accumulates a store and runs mini-batch SGD steps during
//! each block's transmission window, for a few hundred steps total. Shows
//! the coordinator is model-agnostic (paper's protocol, nonlinear model).
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example mlp_edge
//! ```

use anyhow::{Context, Result};
use edgepipe::runtime::mlp::{MlpParams, PjrtMlp};
use edgepipe::runtime::RuntimeSession;
use edgepipe::util::rng::Pcg32;
use edgepipe::util::timefmt::fmt_count;

/// Synthetic nonlinear target: y = tanh(x·a) + 0.3 sin(x·b).
fn gen_data(n: usize, d: usize, rng: &mut Pcg32) -> (Vec<f32>, Vec<f32>) {
    let a: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let b: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let (mut da, mut db) = (0.0, 0.0);
        for j in 0..d {
            let v = rng.next_gaussian();
            x[i * d + j] = v as f32;
            da += v * a[j];
            db += v * b[j];
        }
        y[i] = (da.tanh() + 0.3 * db.sin()) as f32;
    }
    (x, y)
}

fn main() -> Result<()> {
    let session = RuntimeSession::open_default()
        .context("run `make artifacts` first")?;
    let mut mlp = PjrtMlp::new(session)?;
    let mut rng = Pcg32::seeded(2024);
    let mut params = MlpParams::init(mlp.d_in, mlp.hidden, &mut rng);
    println!(
        "MLP: {} -> {} -> {} -> 1 ({} parameters), batch {}",
        mlp.d_in,
        mlp.hidden,
        mlp.hidden,
        fmt_count(params.count() as u64),
        mlp.batch
    );

    // protocol: blocks of n_c samples arrive; during each block's window
    // the edge runs `steps_per_block` mini-batch steps on its store
    let (n, d) = (8192, mlp.d_in);
    let (data_x, data_y) = gen_data(n, d, &mut rng);
    let n_c = 1024;
    let steps_per_block = 40;
    let alpha = 0.03f32;

    let mut store_x: Vec<f32> = Vec::new();
    let mut store_y: Vec<f32> = Vec::new();
    let mut total_steps = 0usize;
    let mut first_loss = None;
    let mut last_loss = 0.0;

    for block in 0..(n / n_c) {
        // ---- "transmission": the next block arrives
        let lo = block * n_c;
        let hi = lo + n_c;
        store_x.extend_from_slice(&data_x[lo * d..hi * d]);
        store_y.extend_from_slice(&data_y[lo..hi]);

        // ---- "computation during next block's transmission window"
        if store_y.len() >= mlp.batch {
            for _ in 0..steps_per_block {
                // sample a batch from the store
                let mut bx = vec![0.0f32; mlp.batch * d];
                let mut by = vec![0.0f32; mlp.batch];
                let m = store_y.len() as u64;
                for s in 0..mlp.batch {
                    let i = rng.gen_range(m) as usize;
                    bx[s * d..(s + 1) * d]
                        .copy_from_slice(&store_x[i * d..(i + 1) * d]);
                    by[s] = store_y[i];
                }
                let loss = mlp.step(&mut params, &bx, &by, alpha)?;
                if first_loss.is_none() {
                    first_loss = Some(loss);
                }
                last_loss = loss;
                total_steps += 1;
            }
            println!(
                "block {:>2}: store {:>5} samples, {:>4} steps, batch loss \
                 {:.5}",
                block + 1,
                store_y.len(),
                total_steps,
                last_loss
            );
        }
    }
    let first = first_loss.expect("ran steps");
    println!(
        "MLP e2e: {total_steps} PJRT steps, loss {first:.5} -> {last_loss:.5}"
    );
    anyhow::ensure!(
        last_loss < 0.5 * first,
        "MLP failed to learn: {first} -> {last_loss}"
    );
    println!("MLP OK: nonlinear model trains through the same protocol.");
    Ok(())
}
