//! END-TO-END DRIVER: the full stack on the paper's real workload.
//!
//! Runs the pipelined coordinator on the 18 576-sample ridge workload
//! with the bound-optimized block size through the native engine, then
//! re-estimates the final loss by Monte-Carlo twice — once on the
//! scalar per-seed path and once on the batched-seed engine
//! (`sweep/batch.rs`, 8 lanes) — and checks the two estimates are
//! bit-identical while reporting the wall-clock ratio.
//!
//! Set `E2E_FAST=1` for a shortened run.
//!
//! ```bash
//! cargo run --release --example e2e_edge_training
//! ```

use std::time::Instant;

use anyhow::Result;
use edgepipe::bound::corollary1::BoundParams;
use edgepipe::bound::{estimate_constants, optimize_block_size};
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::metrics::writer::{write_csv, CsvTable};
use edgepipe::model::{ridge_solution, RidgeModel};
use edgepipe::sweep::mc_final_loss_lanes;
use edgepipe::util::timefmt::{fmt_count, fmt_duration};

fn main() -> Result<()> {
    let fast = std::env::var("E2E_FAST").is_ok();

    // ---------------- dataset (paper Sec. 5) ----------------
    let raw = synth_calhousing(&SynthSpec::default());
    let (train, _) = train_split(&raw, 0.9, 42);
    let (alpha, lambda) = (1e-4, 0.05);
    let t_budget = if fast { 3000.0 } else { 1.5 * train.n as f64 };
    let n_o = 100.0;
    println!(
        "e2e: N={} d={} T={} n_o={} α={alpha} λ={lambda}",
        fmt_count(train.n as u64),
        train.d,
        t_budget,
        n_o
    );

    // ---------------- block size from the bound ----------------
    let k = estimate_constants(&train, lambda, alpha, 2000, 42);
    let params = BoundParams {
        alpha,
        big_l: k.big_l,
        c: k.c,
        m: 1.0,
        m_g: 1.0,
        d_diam: k.d_diam,
    };
    let n_c = optimize_block_size(&params, train.n, t_budget, n_o, 1.0).n_c;
    println!(
        "bound constants L={:.4} c={:.4} D={:.2} -> ñ_c = {n_c}",
        k.big_l, k.c, k.d_diam
    );

    // ---------------- pipelined reference run ----------------
    let cfg = DesConfig {
        n_c,
        loss_every: 2000,
        record_blocks: false,
        ..DesConfig::paper(n_c, n_o, t_budget, 42)
    };
    let mut exec = NativeExecutor::new(
        RidgeModel::new(train.d, lambda, train.n),
        alpha,
    );
    let t0 = Instant::now();
    let run = run_des(&train, &cfg, &mut IdealChannel, &mut exec)?;
    println!(
        "native run: {} SGD updates in {} blocks, wall {}",
        fmt_count(run.updates as u64),
        run.blocks_sent,
        fmt_duration(t0.elapsed())
    );

    // ---------------- scalar vs batched Monte-Carlo ----------------
    let seeds = if fast { 8 } else { 24 };
    let sweep_cfg = DesConfig {
        loss_every: 0,
        record_blocks: false,
        ..cfg.clone()
    };
    let t1 = Instant::now();
    let scalar = mc_final_loss_lanes(&train, &sweep_cfg, seeds, 0, 1)?;
    let scalar_time = t1.elapsed();
    let t2 = Instant::now();
    let batched = mc_final_loss_lanes(&train, &sweep_cfg, seeds, 0, 8)?;
    let batched_time = t2.elapsed();
    println!(
        "MC over {seeds} seeds: scalar {} vs 8-lane batched {} \
         (mean loss {:.6})",
        fmt_duration(scalar_time),
        fmt_duration(batched_time),
        batched.mean
    );
    anyhow::ensure!(
        scalar.mean.to_bits() == batched.mean.to_bits()
            && scalar.std.to_bits() == batched.std.to_bits(),
        "batched engine diverged from scalar: {} vs {}",
        scalar.mean,
        batched.mean
    );
    println!("batched-seed engine bit-identical to scalar ✓");

    // ---------------- report vs optimum ----------------
    let w_star = ridge_solution(&train, lambda)?;
    let loss_star = train.ridge_loss(&w_star, lambda / train.n as f64);
    println!(
        "optimality gap at deadline: {:.3e} (L(w*) = {loss_star:.6})",
        run.final_loss - loss_star
    );

    // loss curve out
    let mut table = CsvTable::new(&["time", "loss"]);
    for &(t, l) in &run.curve {
        table.push_nums(&[t, l]);
    }
    let out = std::path::Path::new("out").join("e2e_loss_curve.csv");
    write_csv(&table, &out)?;
    println!(
        "loss curve ({} points) -> {}",
        run.curve.len(),
        out.display()
    );
    println!("E2E OK: coordinator, bound, and batched sweeps compose.");
    Ok(())
}
