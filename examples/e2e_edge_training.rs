//! END-TO-END DRIVER: the full three-layer stack on the paper's real
//! workload.
//!
//! Layer 3 (this binary, Rust) runs the pipelined coordinator on the
//! 18 576-sample ridge workload with the bound-optimized block size;
//! every SGD update executes through Layer 2/1 — the AOT-compiled
//! JAX+Pallas `sgd_block` artifact — on the PJRT CPU client. Loss checks
//! run through the `dataset_loss` artifact AND the native f64 oracle, and
//! the whole trajectory is cross-validated against the native engine.
//!
//! Requires `make artifacts`. Set `E2E_FAST=1` for a shortened run.
//!
//! ```bash
//! cargo run --release --example e2e_edge_training
//! ```

use std::time::Instant;

use anyhow::{Context, Result};
use edgepipe::bound::corollary1::BoundParams;
use edgepipe::bound::{estimate_constants, optimize_block_size};
use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::metrics::writer::{write_csv, CsvTable};
use edgepipe::model::{ridge_solution, RidgeModel};
use edgepipe::runtime::{PjrtExecutor, PjrtLossEvaluator, RuntimeSession};
use edgepipe::util::timefmt::{fmt_count, fmt_duration};

fn main() -> Result<()> {
    let fast = std::env::var("E2E_FAST").is_ok();

    // ---------------- dataset (paper Sec. 5) ----------------
    let raw = synth_calhousing(&SynthSpec::default());
    let (train, _) = train_split(&raw, 0.9, 42);
    let (alpha, lambda) = (1e-4, 0.05);
    let t_budget = if fast { 3000.0 } else { 1.5 * train.n as f64 };
    let n_o = 100.0;
    println!(
        "e2e: N={} d={} T={} n_o={} α={alpha} λ={lambda}",
        fmt_count(train.n as u64),
        train.d,
        t_budget,
        n_o
    );

    // ---------------- block size from the bound ----------------
    let k = estimate_constants(&train, lambda, alpha, 2000, 42);
    let params = BoundParams {
        alpha,
        big_l: k.big_l,
        c: k.c,
        m: 1.0,
        m_g: 1.0,
        d_diam: k.d_diam,
    };
    let n_c = optimize_block_size(&params, train.n, t_budget, n_o, 1.0).n_c;
    println!(
        "bound constants L={:.4} c={:.4} D={:.2} -> ñ_c = {n_c}",
        k.big_l, k.c, k.d_diam
    );

    // ---------------- PJRT-backed pipelined run ----------------
    let cfg = DesConfig {
        n_c,
        loss_every: 2000,
        record_blocks: false,
        ..DesConfig::paper(n_c, n_o, t_budget, 42)
    };
    let session = RuntimeSession::open_default()
        .context("run `make artifacts` first")?;
    let mut pjrt_exec = PjrtExecutor::new(session, alpha, lambda, train.n)?;
    let t0 = Instant::now();
    let pjrt_run = run_des(&train, &cfg, &mut IdealChannel, &mut pjrt_exec)?;
    let pjrt_time = t0.elapsed();
    println!(
        "PJRT run: {} SGD updates in {} artifact calls, wall {}",
        fmt_count(pjrt_run.updates as u64),
        fmt_count(pjrt_exec.calls()),
        fmt_duration(pjrt_time)
    );

    // ---------------- native cross-validation ----------------
    let mut native_exec = NativeExecutor::new(
        RidgeModel::new(train.d, lambda, train.n),
        alpha,
    );
    let t1 = Instant::now();
    let native_run =
        run_des(&train, &cfg, &mut IdealChannel, &mut native_exec)?;
    let native_time = t1.elapsed();
    let max_dw = pjrt_run
        .final_w
        .iter()
        .zip(&native_run.final_w)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "native run: wall {} — trajectory divergence max|Δw| = {max_dw:.2e} \
         (f32 artifact vs f64 native)",
        fmt_duration(native_time)
    );
    anyhow::ensure!(max_dw < 1e-2, "backends diverged: {max_dw}");

    // ---------------- loss agreement through the artifact ----------------
    let session2 = RuntimeSession::open_default()?;
    let mut loss_eval = PjrtLossEvaluator::new(session2, lambda, train.n)?;
    loss_eval.append_rows(&train.x, &train.y)?;
    let pjrt_loss = loss_eval.loss(&pjrt_run.final_w)?;
    let native_loss = pjrt_run.final_loss;
    println!(
        "final training loss: pjrt artifact {pjrt_loss:.6} vs native \
         {native_loss:.6}"
    );
    anyhow::ensure!(
        (pjrt_loss - native_loss).abs() / native_loss < 1e-3,
        "loss paths disagree"
    );

    // ---------------- report vs optimum ----------------
    let w_star = ridge_solution(&train, lambda)?;
    let loss_star = train.ridge_loss(&w_star, lambda / train.n as f64);
    println!(
        "optimality gap at deadline: {:.3e} (L(w*) = {loss_star:.6})",
        pjrt_run.final_loss - loss_star
    );

    // loss curve out
    let mut table = CsvTable::new(&["time", "loss"]);
    for &(t, l) in &pjrt_run.curve {
        table.push_nums(&[t, l]);
    }
    let out = std::path::Path::new("out").join("e2e_loss_curve.csv");
    write_csv(&table, &out)?;
    println!(
        "loss curve ({} points) -> {}",
        pjrt_run.curve.len(),
        out.display()
    );
    println!("E2E OK: all three layers compose.");
    Ok(())
}
