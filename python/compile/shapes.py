"""Fixed AOT shapes shared by the JAX model, aot.py, and the Rust runtime.

One artifact per entry point, NOT per runtime configuration: buffers are
fixed-capacity with validity masks so a single compiled executable serves
every block size n_c, overhead n_o, and store size the coordinator can
produce (DESIGN.md §4, Layer 2).

These constants are exported into artifacts/manifest.json; the Rust side
reads them from there (rust/src/runtime/manifest.rs) — keep the names in
sync.
"""

# Feature dimension of the paper's ridge workload (California-Housing-like).
D = 8

# Step capacity of one sgd_block call. The coordinator loops calls when a
# block's n_p = (n_c + n_o) / tau_p exceeds this.
K_MAX = 512

# Raw dataset size (paper Sec. 5: California Housing, 20640 rows).
N_RAW = 20640

# Row-buffer capacity: N_RAW padded up to a multiple of the loss tile.
from .kernels.masked_loss import TILE  # noqa: E402

N_CAP = ((N_RAW + TILE - 1) // TILE) * TILE  # = 21504 for TILE=1024

# MLP extension example dimensions.
MLP_IN = D
MLP_HIDDEN = 256
MLP_BATCH = 256
