"""AOT lowering: JAX entry points -> HLO text artifacts + manifest.json.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every entry point is lowered with fixed shapes (shapes.py) and
return_tuple=True; the Rust runtime unwraps the tuple. The manifest
records, for each artifact, its file plus the exact input/output
shapes & dtypes so the Rust executor can validate buffers at load time.

Usage:  cd python && python -m compile.aot --out ../artifacts
        (or --out ../artifacts/model.hlo.txt; the directory is used)
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# name -> (fn, [(input name, ShapeDtypeStruct)...])
ENTRY_POINTS = {
    "sgd_block": (
        model.sgd_block,
        [
            ("w", _f32(1, shapes.D)),
            ("xs", _f32(shapes.K_MAX, shapes.D)),
            ("ys", _f32(shapes.K_MAX)),
            ("mask", _f32(shapes.K_MAX)),
            ("scalars", _f32(1, 2)),  # [[alpha, 2*lam/N]]
        ],
    ),
    "dataset_loss": (
        model.dataset_loss,
        [
            ("w", _f32(1, shapes.D)),
            ("xx", _f32(shapes.N_CAP, shapes.D)),
            ("yy", _f32(shapes.N_CAP)),
            ("mask", _f32(shapes.N_CAP)),
            ("scalars", _f32(1, 2)),  # [[count, lam/N]]
        ],
    ),
    "dataset_grad": (
        model.dataset_grad,
        [
            ("w", _f32(1, shapes.D)),
            ("xx", _f32(shapes.N_CAP, shapes.D)),
            ("yy", _f32(shapes.N_CAP)),
            ("mask", _f32(shapes.N_CAP)),
            ("scalars", _f32(1, 2)),  # [[count, 2*lam/N]]
        ],
    ),
    "batch_step": (
        model.batch_step,
        [
            ("w", _f32(1, shapes.D)),
            ("xx", _f32(shapes.N_CAP, shapes.D)),
            ("yy", _f32(shapes.N_CAP)),
            ("mask", _f32(shapes.N_CAP)),
            ("scalars", _f32(1, 3)),  # [[count, 2*lam/N, alpha]]
        ],
    ),
    "mlp_step": (
        model.mlp_step,
        [
            ("x", _f32(shapes.MLP_BATCH, shapes.MLP_IN)),
            ("y", _f32(shapes.MLP_BATCH)),
            ("w1", _f32(shapes.MLP_IN, shapes.MLP_HIDDEN)),
            ("b1", _f32(1, shapes.MLP_HIDDEN)),
            ("w2", _f32(shapes.MLP_HIDDEN, shapes.MLP_HIDDEN)),
            ("b2", _f32(1, shapes.MLP_HIDDEN)),
            ("w3", _f32(shapes.MLP_HIDDEN, 1)),
            ("b3", _f32(1, 1)),
            ("scalars", _f32(1, 1)),  # [[alpha]]
        ],
    ),
    "mlp_loss": (
        model.mlp_loss,
        [
            ("x", _f32(shapes.MLP_BATCH, shapes.MLP_IN)),
            ("y", _f32(shapes.MLP_BATCH)),
            ("w1", _f32(shapes.MLP_IN, shapes.MLP_HIDDEN)),
            ("b1", _f32(1, shapes.MLP_HIDDEN)),
            ("w2", _f32(shapes.MLP_HIDDEN, shapes.MLP_HIDDEN)),
            ("b2", _f32(1, shapes.MLP_HIDDEN)),
            ("w3", _f32(shapes.MLP_HIDDEN, 1)),
            ("b3", _f32(1, 1)),
        ],
    ),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name):
    """Lower one entry point; returns (hlo_text, manifest record)."""
    fn, sig = ENTRY_POINTS[name]
    specs = [s for (_, s) in sig]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    outs = jax.eval_shape(fn, *specs)
    record = {
        "file": f"{name}.hlo.txt",
        "inputs": [
            {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
            for (n, s) in sig
        ],
        "outputs": [
            {"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs
        ],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, record


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output dir")
    parser.add_argument(
        "--only", default=None, help="comma-separated subset of entry points"
    )
    args = parser.parse_args()
    out_dir = args.out
    if out_dir.endswith(".txt"):  # Makefile passes a file path; use its dir
        out_dir = os.path.dirname(out_dir) or "."
    os.makedirs(out_dir, exist_ok=True)

    names = list(ENTRY_POINTS) if args.only is None else args.only.split(",")
    manifest = {
        "format": 1,
        "constants": {
            "d": shapes.D,
            "k_max": shapes.K_MAX,
            "n_raw": shapes.N_RAW,
            "n_cap": shapes.N_CAP,
            "loss_tile": shapes.TILE,
            "mlp_hidden": shapes.MLP_HIDDEN,
            "mlp_batch": shapes.MLP_BATCH,
        },
        "artifacts": {},
    }
    for name in names:
        text, record = lower_entry(name)
        path = os.path.join(out_dir, record["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = record
        print(f"  wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {os.path.join(out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
