"""Layer-2 JAX model: the paper's ridge-regression training computation.

Every public function here is an AOT entry point lowered by aot.py to HLO
text and executed from the Rust coordinator via PJRT — Python never runs on
the request path. All heavy compute routes through the Layer-1 Pallas
kernels in ``kernels/``.

Paper objects implemented (Skatchkovsky & Simeone 2019):
  eq. (1)  L(w)        -> dataset_loss (masked over the growing store)
  eq. (2)  SGD update  -> sgd_block (one pipelined block of n_p updates)
  eq. (6-8) store/remainder losses -> dataset_loss with the right mask
plus batch-gradient entry points for the baseline policies and a small MLP
for the model-generality extension example.
"""

import jax
import jax.numpy as jnp

from .kernels import grad_batch as _grad_batch_kernel
from .kernels import linear_fused
from .kernels import masked_loss as _masked_loss_kernel
from .kernels import sgd_block as _sgd_block_kernel


# --------------------------------------------------------------------------
# Ridge regression entry points (the paper's workload)
# --------------------------------------------------------------------------

def sgd_block(w, xs, ys, mask, scalars):
    """One pipelined block of masked single-sample SGD updates (eq. (2)).

    scalars = [[alpha, 2*lam/N]]. Returns the (1, d) updated parameters.
    """
    return (_sgd_block_kernel(w, xs, ys, mask, scalars),)


def dataset_loss(w, xx, yy, mask, scalars):
    """Masked empirical ridge loss over the row buffer (eqs. (1), (6)-(8)).

    scalars = [[count, lam/N]] where count = sum(mask) is the number of
    valid rows. Returns a (1,) loss.
    """
    count = scalars[0, 0]
    reg = scalars[0, 1]
    partials = _masked_loss_kernel(w, xx, yy, mask)
    data = jnp.sum(partials) / count
    return (jnp.reshape(data + reg * jnp.dot(w[0], w[0]), (1,)),)


def dataset_grad(w, xx, yy, mask, scalars):
    """Masked full-store ridge gradient. scalars = [[count, 2*lam/N]]."""
    count = scalars[0, 0]
    reg2 = scalars[0, 1]
    partials = _grad_batch_kernel(w, xx, yy, mask)       # (tiles, d)
    g = jnp.sum(partials, axis=0) / count + reg2 * w[0]
    return (jnp.reshape(g, (1, -1)),)


def batch_step(w, xx, yy, mask, scalars):
    """One full-store batch gradient-descent step (baseline policies).

    scalars = [[count, 2*lam/N, alpha]]. Returns the (1, d) updated params.
    """
    count = scalars[0, 0]
    reg2 = scalars[0, 1]
    alpha = scalars[0, 2]
    partials = _grad_batch_kernel(w, xx, yy, mask)
    g = jnp.sum(partials, axis=0) / count + reg2 * w[0]
    return (jnp.reshape(w[0] - alpha * g, (1, -1)),)


# --------------------------------------------------------------------------
# MLP extension (model-generality example; trained through the same protocol)
# --------------------------------------------------------------------------

def _mlp_forward_parts(x, w1, b1, w2, b2, w3, b3):
    """Forward pass through the fused Pallas dense layers, keeping
    intermediate activations for the hand-derived backward pass."""
    h1 = linear_fused(x, w1, b1, relu=True)     # (n, H)
    h2 = linear_fused(h1, w2, b2, relu=True)    # (n, H)
    out = linear_fused(h2, w3, b3, relu=False)  # (n, 1)
    return h1, h2, out[:, 0]


def mlp_loss(x, y, w1, b1, w2, b2, w3, b3):
    """MSE loss of the MLP on batch (x, y). Returns (1,)."""
    _, _, pred = _mlp_forward_parts(x, w1, b1, w2, b2, w3, b3)
    diff = pred - y
    return (jnp.reshape(jnp.mean(diff * diff), (1,)),)


def mlp_step(x, y, w1, b1, w2, b2, w3, b3, scalars):
    """One SGD step of the MLP with hand-derived backprop.

    Forward activations and the two activation-gradient matmuls route
    through the Layer-1 ``linear_fused`` kernel; the (in, n) @ (n, out)
    weight-gradient contractions stay in L2 where XLA fuses them (their
    layout does not fit the row-tiled kernel). scalars = [[alpha]].
    Returns (w1', b1', w2', b2', w3', b3', loss(1,)).
    """
    alpha = scalars[0, 0]
    n = x.shape[0]
    h1, h2, pred = _mlp_forward_parts(x, w1, b1, w2, b2, w3, b3)
    diff = pred - y
    loss = jnp.mean(diff * diff)

    zeros_h = jnp.zeros((1, w2.shape[1]), jnp.float32)
    dpred = (2.0 / n) * diff                               # (n,)
    dw3 = jnp.dot(h2.T, dpred[:, None])                    # (H, 1)
    db3 = jnp.reshape(jnp.sum(dpred), (1, 1))
    dh2 = linear_fused(dpred[:, None], w3.T, zeros_h, relu=False)  # (n, H)
    da2 = dh2 * (h2 > 0)                                   # ReLU mask
    dw2 = jnp.dot(h1.T, da2)
    db2 = jnp.sum(da2, axis=0, keepdims=True)
    dh1 = linear_fused(da2, w2.T, zeros_h, relu=False)     # (n, H)
    da1 = dh1 * (h1 > 0)
    dw1 = jnp.dot(x.T, da1)
    db1 = jnp.sum(da1, axis=0, keepdims=True)

    return (
        w1 - alpha * dw1,
        b1 - alpha * db1,
        w2 - alpha * dw2,
        b2 - alpha * db2,
        w3 - alpha * dw3,
        b3 - alpha * db3,
        jnp.reshape(loss, (1,)),
    )
