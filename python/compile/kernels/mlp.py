"""Pallas kernel: fused tiled dense layer act(x @ w + b) for the MLP example.

The MLP extension example (examples/mlp_edge.rs) trains a small multi-layer
perceptron through the same pipelined protocol as the paper's ridge model,
demonstrating that the coordinator is model-agnostic. Forward and backward
matmuls all route through this one fused kernel.

TPU mapping: grid over row tiles of the batch; weights for one layer fit in
VMEM (<= 256x256 f32 = 256 KiB), so each grid step performs a
(TB, in) @ (in, out) MXU matmul, adds the bias, and applies the optional
ReLU in-register before writing the tile back. This is the MXU showcase
path of the artifact set (DESIGN.md §9).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch-row tile. Batches are padded to a multiple of this.
ROW_TILE = 128


def _linear_kernel(x_ref, w_ref, b_ref, out_ref, *, relu):
    """One grid step: out_tile = act(x_tile @ w + b)."""
    acc = jnp.dot(x_ref[...], w_ref[...]) + b_ref[0, :][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    out_ref[...] = acc


def linear_fused(x, w, b, relu):
    """Fused dense layer over row tiles.

    x    : (n, in)  float32, n % ROW_TILE == 0
    w    : (in, out) float32
    b    : (1, out)  float32
    relu : static bool
    returns (n, out) float32
    """
    n, d_in = x.shape
    d_out = w.shape[1]
    assert n % ROW_TILE == 0, f"batch {n} must be a multiple of {ROW_TILE}"
    grid = n // ROW_TILE
    kernel = functools.partial(_linear_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((ROW_TILE, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((1, d_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), jnp.float32),
        interpret=True,
    )(x, w, b)
