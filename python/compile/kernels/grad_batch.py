"""Pallas kernel: tiled masked mini-batch ridge gradient.

Used by the baselines (transmit-all-then-batch-train) and extensions; the
paper's main path is single-sample SGD (sgd_block.py), but batch gradients
are needed for the "sequential" comparison policy and for computing w* /
full-dataset gradients on device-scale buffers.

TPU mapping: same row tiling as masked_loss; each grid step computes its
tile's contribution  2 * X_tile^T (mask * (X_tile w - y))  with MXU-shaped
products, writing one (d,) partial per tile. Layer 2 reduces partials,
divides by count and adds the regularizer gradient.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .masked_loss import TILE


def _grad_batch_kernel(w_ref, xs_ref, ys_ref, mask_ref, out_ref):
    """One grid step: partial gradient over a (TILE, d) row tile."""
    xs = xs_ref[...]                                  # (TILE, d)
    w_col = w_ref[0, :].reshape(-1, 1)                # (d, 1)
    err = jnp.dot(xs, w_col)[:, 0] - ys_ref[...]      # (TILE,)
    weighted = (mask_ref[...] * err).reshape(1, -1)   # (1, TILE)
    out_ref[0, :] = 2.0 * jnp.dot(weighted, xs)[0]    # (d,) via MXU


def grad_batch(w, xx, yy, mask):
    """Partial tile sums of the masked squared-error gradient.

    w    : (1, d)     float32
    xx   : (N_cap, d) float32, N_cap % TILE == 0
    yy   : (N_cap,)   float32
    mask : (N_cap,)   float32
    returns (N_cap // TILE, d) float32 partials; caller reduces, divides by
    count, and adds reg2 * w (see model.dataset_grad).
    """
    n_cap, d = xx.shape
    assert n_cap % TILE == 0, f"N_cap={n_cap} must be a multiple of TILE={TILE}"
    grid = n_cap // TILE
    return pl.pallas_call(
        _grad_batch_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((TILE, d), lambda i: (i, 0)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid, d), jnp.float32),
        interpret=True,
    )(w, xx, yy, mask)
