"""Pallas kernel: tiled masked empirical ridge loss over a fixed row buffer.

Evaluates the paper's empirical loss (eq. (1), and the growing-store
variants (6)-(8)) over a fixed-capacity (N_cap, d) buffer in which only the
first ``count`` rows (mask == 1) are real samples. A fixed capacity plus a
validity mask lets one AOT artifact serve every store size as the edge
node's sample set grows block by block.

TPU mapping: the buffer is tiled over rows; each grid step streams one
(TILE, d) tile HBM->VMEM, computes the tile's residual via an MXU-shaped
(TILE, d) @ (d, 1) product, and writes one partial sum. Layer 2 reduces
the partials and adds the (lam/N)*||w||^2 regularizer.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile size. N_cap buffers are padded to a multiple of this.
TILE = 1024


def _masked_loss_kernel(w_ref, xs_ref, ys_ref, mask_ref, out_ref):
    """One grid step: partial sum of mask * (x_i^T w - y_i)^2 over a tile."""
    w_col = w_ref[0, :].reshape(-1, 1)          # (d, 1)
    err = jnp.dot(xs_ref[...], w_col)[:, 0] - ys_ref[...]  # (TILE,) via MXU
    out_ref[0] = jnp.sum(mask_ref[...] * err * err)


def masked_loss(w, xx, yy, mask):
    """Partial tile sums of the masked squared error.

    w    : (1, d)     float32
    xx   : (N_cap, d) float32, N_cap % TILE == 0
    yy   : (N_cap,)   float32
    mask : (N_cap,)   float32
    returns (N_cap // TILE,) float32 partial sums; caller divides by count
    and adds the regularizer (see model.dataset_loss).
    """
    n_cap, d = xx.shape
    assert n_cap % TILE == 0, f"N_cap={n_cap} must be a multiple of TILE={TILE}"
    grid = n_cap // TILE
    return pl.pallas_call(
        _masked_loss_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),      # w broadcast
            pl.BlockSpec((TILE, d), lambda i: (i, 0)),   # row tile
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.float32),
        interpret=True,
    )(w, xx, yy, mask)
