"""Pallas kernel: one pipelined block of K sequential SGD updates.

This is the paper's compute hot-spot (Sec. 2, eq. (2)): while block b+1 is
on the wire, the edge node performs n_p = (n_c + n_o)/tau_p single-sample
SGD updates on samples drawn from its current store. The Rust coordinator
gathers the sampled rows into a contiguous (K, d) tile and invokes this
kernel once per block (looping calls when n_p > K).

TPU mapping (DESIGN.md §Hardware-Adaptation): the updates are sequentially
dependent, so the kernel streams the block's samples HBM->VMEM once
(single-tile BlockSpec) and carries ``w`` in registers/VMEM across all K
steps. The per-step work (two d-length dots + axpy, d = 8) is VPU work by
nature; the MXU path lives in masked_loss / grad_batch / mlp.

A fixed step capacity K plus a step mask lets ONE artifact serve every
n_p: padded slots have mask 0.0 and leave ``w`` unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgd_block_kernel(w_ref, xs_ref, ys_ref, mask_ref, sc_ref, out_ref):
    """Kernel body. sc_ref packs scalars [[alpha, reg2]] (reg2 = 2*lam/N)."""
    alpha = sc_ref[0, 0]
    reg2 = sc_ref[0, 1]
    k = xs_ref.shape[0]

    def step(j, w):
        x = xs_ref[pl.dslice(j, 1), :][0]       # (d,) dynamic row load
        y = ys_ref[pl.dslice(j, 1)][0]
        m = mask_ref[pl.dslice(j, 1)][0]
        err = jnp.sum(x * w) - y                # w^T x - y
        g = 2.0 * err * x + reg2 * w            # per-sample ridge gradient
        return w - m * alpha * g                # masked update (eq. (2))

    out_ref[0, :] = jax.lax.fori_loop(0, k, step, w_ref[0, :])


@functools.partial(jax.jit, static_argnames=())
def sgd_block(w, xs, ys, mask, scalars):
    """Apply one block of masked SGD updates.

    w       : (1, d) float32   current parameters (row vector)
    xs      : (K, d) float32   gathered covariates for the block's steps
    ys      : (K,)   float32   labels
    mask    : (K,)   float32   1.0 = active step, 0.0 = padded slot
    scalars : (1, 2) float32   [[alpha, 2*lam/N]]
    returns : (1, d) float32   parameters after the block
    """
    d = w.shape[1]
    return pl.pallas_call(
        _sgd_block_kernel,
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=True,
    )(w, xs, ys, mask, scalars)
