"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must
match its oracle to float32 tolerance across randomized shapes and values
(see python/tests/). The oracles implement the paper's math directly:

  loss      l(w, x) = (w^T x - y)^2 + (lam/N) ||w||^2          (paper Sec. 5)
  gradient  grad l  = 2 x (w^T x - y) + (2 lam/N) w
  SGD step  w <- w - alpha * grad l(w, xi)                      (paper eq. (2))
"""

import jax
import jax.numpy as jnp


def ridge_loss_point(w, x, y, reg):
    """Per-sample ridge loss l(w,x) with regularizer coefficient reg = lam/N."""
    err = jnp.dot(w, x) - y
    return err * err + reg * jnp.dot(w, w)


def ridge_grad_point(w, x, y, reg2):
    """Per-sample ridge gradient; reg2 = 2*lam/N (derivative of the reg term)."""
    err = jnp.dot(w, x) - y
    return 2.0 * err * x + reg2 * w


def sgd_block_ref(w, xs, ys, mask, alpha, reg2):
    """Run K sequential masked single-sample SGD updates (paper eq. (2)).

    w     : (d,)    parameter vector
    xs    : (K, d)  gathered covariates for this block's updates
    ys    : (K,)    labels
    mask  : (K,)    1.0 for active steps, 0.0 for padded slots
    alpha : scalar  learning rate
    reg2  : scalar  2*lam/N
    Returns the (d,) parameter vector after the block.
    """

    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    mask = jnp.asarray(mask)

    def step(j, w):
        g = ridge_grad_point(w, xs[j], ys[j], reg2)
        return w - mask[j] * alpha * g

    return jax.lax.fori_loop(0, xs.shape[0], step, jnp.asarray(w))


def masked_loss_ref(w, xx, yy, mask, count, reg):
    """Masked empirical ridge loss over a fixed row buffer (paper eq. (1)/(6)).

    xx    : (N_cap, d) row buffer; only rows with mask==1 are real samples
    count : scalar     number of valid rows (sum of mask)
    reg   : scalar     lam/N  (N = FULL dataset size per paper Sec. 5)
    """
    err = xx @ w - yy
    data = jnp.sum(mask * err * err) / count
    return data + reg * jnp.dot(w, w)


def grad_batch_ref(w, xx, yy, mask, count, reg2):
    """Masked mini-batch ridge gradient: mean over valid rows.

    grad = (1/count) sum_i mask_i * 2 x_i (w^T x_i - y_i) + reg2 * w
    """
    err = xx @ w - yy
    g = 2.0 * (xx * (mask * err)[:, None]).sum(axis=0) / count
    return g + reg2 * w


def linear_fused_ref(x, w, b, relu):
    """Fused dense layer: act(x @ w + b), act = ReLU if relu else identity."""
    out = x @ w + b[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def mlp_forward_ref(params, x):
    """Two-hidden-layer MLP forward pass used by the extension example.

    params = (w1, b1, w2, b2, w3, b3); returns (n,) predictions.
    """
    w1, b1, w2, b2, w3, b3 = params
    h1 = jnp.maximum(x @ w1 + b1[None, :], 0.0)
    h2 = jnp.maximum(h1 @ w2 + b2[None, :], 0.0)
    return (h2 @ w3 + b3[None, :])[:, 0]


def mlp_loss_ref(params, x, y):
    """Mean-squared-error loss of the MLP on batch (x, y)."""
    pred = mlp_forward_ref(params, x)
    d = pred - y
    return jnp.mean(d * d)
