"""Layer-1 Pallas kernels for edgepipe.

Every kernel is authored with ``interpret=True`` so it lowers to plain HLO
ops executable by the CPU PJRT client the Rust runtime uses (real-TPU
Pallas lowering emits Mosaic custom-calls that only a TPU plugin can run).

Kernels:
  sgd_block    — one pipelined block of K sequential single-sample SGD
                 updates fused in a single kernel (the paper's hot path).
  masked_loss  — tiled masked empirical ridge loss over the full row buffer.
  grad_batch   — tiled mini-batch ridge gradient (baselines / extensions).
  mlp          — fused tiled linear(+ReLU) layers for the MLP example.

``ref.py`` holds the pure-jnp oracles each kernel is tested against.
"""

from . import ref  # noqa: F401
from .sgd_block import sgd_block  # noqa: F401
from .masked_loss import masked_loss  # noqa: F401
from .grad_batch import grad_batch  # noqa: F401
from .mlp import linear_fused  # noqa: F401
