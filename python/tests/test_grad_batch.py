"""grad_batch Pallas kernel vs oracle and finite differences."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.grad_batch import grad_batch
from compile.kernels.masked_loss import TILE


def _grad_from_partials(w, xx, yy, mask, reg2):
    partials = np.asarray(grad_batch(w[None, :], xx, yy, mask))
    count = float(mask.sum())
    return partials.sum(axis=0) / count + reg2 * w


def _numpy_grad(w, xx, yy, mask, reg2):
    xx64 = xx.astype(np.float64)
    err = xx64 @ w - yy
    g = 2.0 * (xx64 * (mask * err)[:, None]).sum(axis=0) / float(mask.sum())
    return g + reg2 * w


def _rand(rng, n, d):
    xx = rng.normal(size=(n, d)).astype(np.float32)
    yy = rng.normal(size=n).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32)
    return w, xx, yy


def test_matches_numpy_multi_tile():
    rng = np.random.default_rng(20)
    n = 2 * TILE
    w, xx, yy = _rand(rng, n, 8)
    mask = (np.arange(n) < 1800).astype(np.float32)
    got = _grad_from_partials(w, xx, yy, mask, 1e-3)
    want = _numpy_grad(w, xx, yy, mask, 1e-3)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)


def test_matches_jnp_ref():
    rng = np.random.default_rng(21)
    n = TILE
    w, xx, yy = _rand(rng, n, 8)
    mask = (rng.random(n) < 0.6).astype(np.float32)
    count = float(mask.sum())
    got = _grad_from_partials(w, xx, yy, mask, 5e-4)
    want = np.asarray(ref.grad_batch_ref(w, xx, yy, mask, count, 5e-4))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)


def test_finite_differences():
    """Kernel gradient must match central differences of the masked loss."""
    rng = np.random.default_rng(22)
    n = TILE
    w, xx, yy = _rand(rng, n, 8)
    mask = (np.arange(n) < 512).astype(np.float32)
    count = float(mask.sum())
    reg2 = 2e-3

    def loss(wv):
        err = xx.astype(np.float64) @ wv - yy
        return float((mask * err * err).sum()) / count + 0.5 * reg2 * float(
            wv @ wv
        )

    g = _grad_from_partials(w, xx, yy, mask, reg2)
    eps = 1e-4
    for i in range(8):
        e = np.zeros(8)
        e[i] = eps
        fd = (loss(w + e) - loss(w - e)) / (2 * eps)
        np.testing.assert_allclose(g[i], fd, rtol=2e-2, atol=1e-4)


def test_gradient_at_solution_is_reg_only():
    """If y = X w exactly, the data term of the gradient vanishes."""
    rng = np.random.default_rng(23)
    n = TILE
    xx = rng.normal(size=(n, 8)).astype(np.float32)
    w = rng.normal(size=8).astype(np.float32)
    yy = (xx @ w).astype(np.float32)
    mask = np.ones(n, dtype=np.float32)
    got = _grad_from_partials(w, xx, yy, mask, 1e-2)
    np.testing.assert_allclose(got, 1e-2 * w, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=2),
    d=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_matches_numpy(tiles, d, seed):
    rng = np.random.default_rng(seed)
    n = tiles * TILE
    w, xx, yy = _rand(rng, n, d)
    mask = (rng.random(n) < 0.8).astype(np.float32)
    if mask.sum() == 0:
        mask[0] = 1.0
    got = _grad_from_partials(w, xx, yy, mask, 1e-3)
    want = _numpy_grad(w, xx, yy, mask, 1e-3)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=5e-5)
