"""AOT lowering: HLO text well-formedness and manifest completeness."""

import json
import os

import pytest

from compile import aot, shapes

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_sgd_block_text():
    text, record = aot.lower_entry("sgd_block")
    assert "ENTRY" in text and "HloModule" in text
    assert record["inputs"][0]["shape"] == [1, shapes.D]
    assert record["inputs"][1]["shape"] == [shapes.K_MAX, shapes.D]
    assert record["outputs"][0]["shape"] == [1, shapes.D]


def test_lower_dataset_loss_text():
    text, record = aot.lower_entry("dataset_loss")
    assert "ENTRY" in text
    assert record["inputs"][1]["shape"] == [shapes.N_CAP, shapes.D]
    assert record["outputs"][0]["shape"] == [1]


def test_all_entry_points_lower():
    for name in aot.ENTRY_POINTS:
        text, record = aot.lower_entry(name)
        assert "ENTRY" in text, name
        assert all(i["dtype"] == "float32" for i in record["inputs"]), name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_on_disk_is_complete():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    consts = manifest["constants"]
    assert consts["d"] == shapes.D
    assert consts["k_max"] == shapes.K_MAX
    assert consts["n_cap"] == shapes.N_CAP
    for name in aot.ENTRY_POINTS:
        assert name in manifest["artifacts"], name
        rec = manifest["artifacts"][name]
        path = os.path.join(ART_DIR, rec["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, name
