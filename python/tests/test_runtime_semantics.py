"""Tests mirroring EXACTLY how the Rust runtime drives the artifacts:
zero-padded buffers, chunked sgd_block calls, f32 scalar packing."""

import numpy as np

from compile import model, shapes


def test_sgd_block_chunking_equals_one_shot():
    """The Rust PjrtExecutor splits a block of n_p > K_MAX updates into
    chunked sgd_block calls; chaining chunks must equal a single
    sequential numpy run over all updates."""
    rng = np.random.default_rng(50)
    d = shapes.D
    total = 700  # > K_MAX = 512 -> two chunks, exactly as the runtime
    xs = rng.normal(size=(total, d)).astype(np.float32)
    ys = rng.normal(size=total).astype(np.float32)
    alpha, reg2 = 1e-3, 1e-5
    sc = np.array([[alpha, reg2]], dtype=np.float32)

    w = rng.normal(size=d).astype(np.float32)
    w_chunked = w.copy()
    k = shapes.K_MAX
    for lo in range(0, total, k):
        hi = min(lo + k, total)
        m = hi - lo
        xs_buf = np.zeros((k, d), dtype=np.float32)
        ys_buf = np.zeros(k, dtype=np.float32)
        mask = np.zeros(k, dtype=np.float32)
        xs_buf[:m] = xs[lo:hi]
        ys_buf[:m] = ys[lo:hi]
        mask[:m] = 1.0
        (out,) = model.sgd_block(
            w_chunked[None, :], xs_buf, ys_buf, mask, sc
        )
        w_chunked = np.asarray(out)[0]

    # float64 reference over the whole sequence
    w_ref = w.astype(np.float64).copy()
    for j in range(total):
        err = w_ref @ xs[j] - ys[j]
        w_ref -= alpha * (2 * err * xs[j] + reg2 * w_ref)

    np.testing.assert_allclose(w_chunked, w_ref, rtol=2e-4, atol=2e-5)


def test_dataset_loss_with_zero_padded_buffer():
    """The Rust PjrtLossEvaluator zero-pads the (N_CAP, d) buffer beyond
    `count`; zeros in the masked region must be exactly neutral."""
    rng = np.random.default_rng(51)
    d = shapes.D
    n_valid = 777
    xx = np.zeros((shapes.N_CAP, d), dtype=np.float32)
    yy = np.zeros(shapes.N_CAP, dtype=np.float32)
    mask = np.zeros(shapes.N_CAP, dtype=np.float32)
    xx[:n_valid] = rng.normal(size=(n_valid, d))
    yy[:n_valid] = rng.normal(size=n_valid)
    mask[:n_valid] = 1.0
    w = rng.normal(size=d).astype(np.float32)
    lam_over_n = 0.05 / 18576.0
    sc = np.array([[float(n_valid), lam_over_n]], dtype=np.float32)

    (got,) = model.dataset_loss(w[None, :], xx, yy, mask, sc)
    err = xx[:n_valid].astype(np.float64) @ w - yy[:n_valid]
    want = (err**2).mean() + lam_over_n * float(w @ w)
    np.testing.assert_allclose(float(got[0]), want, rtol=1e-4)


def test_scalars_survive_f32_packing():
    """The paper's alpha = 1e-4 and lambda/N ~ 2.7e-6 are small; verify
    the (1,2) f32 scalar tensor carries them with enough precision for a
    512-step block."""
    rng = np.random.default_rng(52)
    d = shapes.D
    k = shapes.K_MAX
    xs = rng.normal(size=(k, d)).astype(np.float32)
    ys = rng.normal(size=k).astype(np.float32)
    mask = np.ones(k, dtype=np.float32)
    alpha = 1e-4
    reg2 = 2 * 0.05 / 18576.0
    sc = np.array([[alpha, reg2]], dtype=np.float32)
    w = rng.normal(size=d).astype(np.float32)
    (out,) = model.sgd_block(w[None, :], xs, ys, mask, sc)
    w_got = np.asarray(out)[0]

    w_ref = w.astype(np.float64).copy()
    a32, r32 = float(np.float32(alpha)), float(np.float32(reg2))
    for j in range(k):
        err = w_ref @ xs[j] - ys[j]
        w_ref -= a32 * (2 * err * xs[j] + r32 * w_ref)
    np.testing.assert_allclose(w_got, w_ref, rtol=1e-4, atol=1e-6)
    # and the step actually moved w
    assert np.abs(w_got - w).max() > 1e-5
