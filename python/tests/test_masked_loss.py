"""masked_loss Pallas kernel vs oracle (paper eqs. (1), (6)-(8))."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.masked_loss import TILE, masked_loss


def _loss_from_partials(w, xx, yy, mask, reg):
    partials = np.asarray(masked_loss(w[None, :], xx, yy, mask))
    count = float(mask.sum())
    return float(partials.sum()) / count + reg * float(w @ w)


def _numpy_loss(w, xx, yy, mask, reg):
    err = xx.astype(np.float64) @ w.astype(np.float64) - yy
    data = float((mask * err * err).sum()) / float(mask.sum())
    return data + reg * float(w @ w)


def _rand(rng, n, d):
    xx = rng.normal(size=(n, d)).astype(np.float32)
    yy = rng.normal(size=n).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32)
    return w, xx, yy


def test_full_mask_one_tile():
    rng = np.random.default_rng(10)
    w, xx, yy = _rand(rng, TILE, 8)
    mask = np.ones(TILE, dtype=np.float32)
    got = _loss_from_partials(w, xx, yy, mask, 0.05 / TILE)
    want = _numpy_loss(w, xx, yy, mask, 0.05 / TILE)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_multi_tile_partial_mask():
    rng = np.random.default_rng(11)
    n = 3 * TILE
    w, xx, yy = _rand(rng, n, 8)
    mask = (np.arange(n) < 1500).astype(np.float32)
    got = _loss_from_partials(w, xx, yy, mask, 1e-3)
    want = _numpy_loss(w, xx, yy, mask, 1e-3)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_matches_jnp_ref():
    rng = np.random.default_rng(12)
    n = 2 * TILE
    w, xx, yy = _rand(rng, n, 8)
    mask = (rng.random(n) < 0.5).astype(np.float32)
    count = float(mask.sum())
    got = _loss_from_partials(w, xx, yy, mask, 2e-3)
    want = float(ref.masked_loss_ref(w, xx, yy, mask, count, 2e-3))
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_masked_rows_do_not_contribute():
    """Garbage in masked rows must not change the loss."""
    rng = np.random.default_rng(13)
    n = TILE
    w, xx, yy = _rand(rng, n, 8)
    mask = (np.arange(n) < 700).astype(np.float32)
    base = _loss_from_partials(w, xx, yy, mask, 0.0)
    xx2 = xx.copy()
    xx2[700:] = 1e6  # poison the masked region
    yy2 = yy.copy()
    yy2[700:] = -1e6
    poisoned = _loss_from_partials(w, xx2, yy2, mask, 0.0)
    np.testing.assert_allclose(base, poisoned, rtol=1e-6)


def test_partials_shape():
    rng = np.random.default_rng(14)
    n = 5 * TILE
    w, xx, yy = _rand(rng, n, 8)
    mask = np.ones(n, dtype=np.float32)
    partials = np.asarray(masked_loss(w[None, :], xx, yy, mask))
    assert partials.shape == (5,)
    # each partial is that tile's sum
    for t in range(5):
        err = xx[t * TILE : (t + 1) * TILE] @ w - yy[t * TILE : (t + 1) * TILE]
        np.testing.assert_allclose(
            partials[t], (err * err).sum(), rtol=1e-4
        )


def test_zero_weights_gives_label_power():
    rng = np.random.default_rng(15)
    n = TILE
    _, xx, yy = _rand(rng, n, 8)
    w = np.zeros(8, dtype=np.float32)
    mask = np.ones(n, dtype=np.float32)
    got = _loss_from_partials(w, xx, yy, mask, 0.0)
    np.testing.assert_allclose(got, float((yy**2).mean()), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=3),
    d=st.integers(min_value=1, max_value=12),
    frac=st.floats(min_value=0.05, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_matches_numpy(tiles, d, frac, seed):
    rng = np.random.default_rng(seed)
    n = tiles * TILE
    w, xx, yy = _rand(rng, n, d)
    m = max(1, int(frac * n))
    mask = (np.arange(n) < m).astype(np.float32)
    got = _loss_from_partials(w, xx, yy, mask, 1e-3)
    want = _numpy_loss(w, xx, yy, mask, 1e-3)
    np.testing.assert_allclose(got, want, rtol=2e-4)
