"""MLP fused kernel + hand-derived backprop vs jax.grad oracle."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref
from compile.kernels.mlp import ROW_TILE, linear_fused


def _init_params(rng, d_in=8, h=32):
    def g(*shape, scale=0.3):
        return (rng.normal(size=shape) * scale).astype(np.float32)

    return (
        g(d_in, h),
        g(1, h),
        g(h, h),
        g(1, h),
        g(h, 1),
        g(1, 1),
    )


def test_linear_fused_matches_ref():
    rng = np.random.default_rng(30)
    x = rng.normal(size=(ROW_TILE, 8)).astype(np.float32)
    w = rng.normal(size=(8, 16)).astype(np.float32)
    b = rng.normal(size=(1, 16)).astype(np.float32)
    for relu in (False, True):
        got = np.asarray(linear_fused(x, w, b, relu=relu))
        want = np.asarray(ref.linear_fused_ref(x, w, b[0], relu))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_linear_fused_multi_tile():
    rng = np.random.default_rng(31)
    x = rng.normal(size=(3 * ROW_TILE, 4)).astype(np.float32)
    w = rng.normal(size=(4, 8)).astype(np.float32)
    b = rng.normal(size=(1, 8)).astype(np.float32)
    got = np.asarray(linear_fused(x, w, b, relu=True))
    want = np.maximum(x @ w + b, 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mlp_loss_matches_ref():
    rng = np.random.default_rng(32)
    params = _init_params(rng)
    x = rng.normal(size=(ROW_TILE, 8)).astype(np.float32)
    y = rng.normal(size=ROW_TILE).astype(np.float32)
    (got,) = model.mlp_loss(x, y, *params)
    ref_params = tuple(
        p[0] if p.shape[0] == 1 and p.ndim == 2 and i % 2 == 1 else p
        for i, p in enumerate(params)
    )
    want = ref.mlp_loss_ref(ref_params, x, y)
    np.testing.assert_allclose(float(got[0]), float(want), rtol=1e-4)


def test_mlp_step_grads_match_autodiff():
    """Hand-derived backprop must equal jax.grad of the pure-jnp MLP."""
    rng = np.random.default_rng(33)
    params = _init_params(rng)
    x = rng.normal(size=(ROW_TILE, 8)).astype(np.float32)
    y = rng.normal(size=ROW_TILE).astype(np.float32)
    alpha = 0.05
    sc = np.array([[alpha]], dtype=np.float32)

    out = model.mlp_step(x, y, *params, sc)
    new_params, loss = out[:6], out[6]

    def jnp_loss(ps):
        w1, b1, w2, b2, w3, b3 = ps
        h1 = jnp.maximum(x @ w1 + b1, 0.0)
        h2 = jnp.maximum(h1 @ w2 + b2, 0.0)
        pred = (h2 @ w3 + b3)[:, 0]
        d = pred - y
        return jnp.mean(d * d)

    grads = jax.grad(jnp_loss)(params)
    for got_new, p, g in zip(new_params, params, grads):
        want = p - alpha * np.asarray(g)
        np.testing.assert_allclose(
            np.asarray(got_new), want, rtol=1e-3, atol=1e-5
        )
    np.testing.assert_allclose(
        float(loss[0]), float(jnp_loss(params)), rtol=1e-5
    )


def test_mlp_training_reduces_loss():
    """A few steps on a fixed batch must drive the loss down."""
    rng = np.random.default_rng(34)
    params = _init_params(rng)
    x = rng.normal(size=(ROW_TILE, 8)).astype(np.float32)
    w_true = rng.normal(size=8).astype(np.float32)
    y = np.tanh(x @ w_true).astype(np.float32)
    sc = np.array([[0.05]], dtype=np.float32)

    losses = []
    for _ in range(20):
        out = model.mlp_step(x, y, *params, sc)
        params, loss = out[:6], float(out[6][0])
        losses.append(loss)
    assert losses[-1] < 0.5 * losses[0]
