"""Layer-2 entry points: shapes, formulas, multi-block trajectories."""

import numpy as np

from compile import model, shapes


def _rand_store(rng, n_valid, n_cap=None, d=8):
    n_cap = n_cap or shapes.N_CAP
    xx = np.zeros((n_cap, d), dtype=np.float32)
    yy = np.zeros(n_cap, dtype=np.float32)
    xx[:n_valid] = rng.normal(size=(n_valid, d))
    yy[:n_valid] = rng.normal(size=n_valid)
    mask = (np.arange(n_cap) < n_valid).astype(np.float32)
    return xx, yy, mask


def test_dataset_loss_formula():
    """dataset_loss == (1/count) sum (w.x - y)^2 + reg * |w|^2 exactly."""
    rng = np.random.default_rng(40)
    n_valid = 5000
    xx, yy, mask = _rand_store(rng, n_valid)
    w = rng.normal(size=8).astype(np.float32)
    lam_over_n = 0.05 / 18576.0
    sc = np.array([[float(n_valid), lam_over_n]], dtype=np.float32)
    (got,) = model.dataset_loss(w[None, :], xx, yy, mask, sc)
    err = xx[:n_valid].astype(np.float64) @ w - yy[:n_valid]
    want = (err**2).mean() + lam_over_n * float(w @ w)
    np.testing.assert_allclose(float(got[0]), want, rtol=1e-4)


def test_dataset_grad_formula():
    rng = np.random.default_rng(41)
    n_valid = 3000
    xx, yy, mask = _rand_store(rng, n_valid)
    w = rng.normal(size=8).astype(np.float32)
    reg2 = 2 * 0.05 / 18576.0
    sc = np.array([[float(n_valid), reg2]], dtype=np.float32)
    (got,) = model.dataset_grad(w[None, :], xx, yy, mask, sc)
    xx64 = xx[:n_valid].astype(np.float64)
    err = xx64 @ w - yy[:n_valid]
    want = 2.0 * (xx64 * err[:, None]).mean(axis=0) + reg2 * w
    np.testing.assert_allclose(np.asarray(got)[0], want, rtol=1e-3, atol=1e-6)


def test_batch_step_descends():
    rng = np.random.default_rng(42)
    n_valid = 4000
    xx, yy, mask = _rand_store(rng, n_valid)
    w = rng.normal(size=8).astype(np.float32)
    sc_step = np.array([[float(n_valid), 0.0, 0.05]], dtype=np.float32)
    sc_loss = np.array([[float(n_valid), 0.0]], dtype=np.float32)

    (l0,) = model.dataset_loss(w[None, :], xx, yy, mask, sc_loss)
    (w1,) = model.batch_step(w[None, :], xx, yy, mask, sc_step)
    (l1,) = model.dataset_loss(np.asarray(w1), xx, yy, mask, sc_loss)
    assert float(l1[0]) < float(l0[0])


def test_sgd_block_multiblock_trajectory():
    """Chain 4 blocks through the L2 entry point and check against a
    single numpy re-simulation (this is exactly what the Rust edge trainer
    does per transmission block)."""
    rng = np.random.default_rng(43)
    k = 64
    d = 8
    alpha, reg2 = 1e-2, 1e-4
    sc = np.array([[alpha, reg2]], dtype=np.float32)
    w = rng.normal(size=d).astype(np.float32)
    w_np = w.astype(np.float64).copy()
    for _ in range(4):
        xs = rng.normal(size=(k, d)).astype(np.float32)
        ys = rng.normal(size=k).astype(np.float32)
        mask = np.ones(k, dtype=np.float32)
        (w_out,) = model.sgd_block(w[None, :], xs, ys, mask, sc)
        w = np.asarray(w_out)[0]
        for j in range(k):
            err = w_np @ xs[j] - ys[j]
            w_np -= alpha * (2 * err * xs[j] + reg2 * w_np)
    np.testing.assert_allclose(w, w_np, rtol=1e-3, atol=1e-5)


def test_n_cap_is_tile_aligned():
    assert shapes.N_CAP % shapes.TILE == 0
    assert shapes.N_CAP >= shapes.N_RAW


def test_entry_points_shapes_match_manifest_sig():
    """Every aot.py signature must be consumable by its entry point."""
    import jax

    from compile import aot

    for name, (fn, sig) in aot.ENTRY_POINTS.items():
        specs = [s for (_, s) in sig]
        outs = jax.eval_shape(fn, *specs)
        assert len(outs) >= 1, name
