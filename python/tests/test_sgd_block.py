"""sgd_block Pallas kernel vs pure-jnp oracle (paper eq. (2))."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sgd_block import sgd_block


def _run_kernel(w, xs, ys, mask, alpha, reg2):
    sc = np.array([[alpha, reg2]], dtype=np.float32)
    out = sgd_block(w[None, :], xs, ys, mask, sc)
    return np.asarray(out)[0]


def _run_numpy(w, xs, ys, mask, alpha, reg2):
    """Float64 numpy re-derivation, independent of jax."""
    w = w.astype(np.float64).copy()
    for j in range(xs.shape[0]):
        err = float(w @ xs[j]) - float(ys[j])
        g = 2.0 * err * xs[j].astype(np.float64) + reg2 * w
        w = w - mask[j] * alpha * g
    return w


def _rand_case(rng, k, d, scale=1.0):
    w = (rng.normal(size=d) * scale).astype(np.float32)
    xs = (rng.normal(size=(k, d)) * scale).astype(np.float32)
    ys = (rng.normal(size=k) * scale).astype(np.float32)
    return w, xs, ys


def test_matches_ref_full_mask():
    rng = np.random.default_rng(1)
    w, xs, ys = _rand_case(rng, 64, 8)
    mask = np.ones(64, dtype=np.float32)
    got = _run_kernel(w, xs, ys, mask, 1e-2, 1e-3)
    want = _run_numpy(w, xs, ys, mask, 1e-2, 1e-3)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_matches_jnp_ref():
    rng = np.random.default_rng(2)
    w, xs, ys = _rand_case(rng, 32, 8)
    mask = (np.arange(32) < 17).astype(np.float32)
    got = _run_kernel(w, xs, ys, mask, 5e-3, 1e-4)
    want = np.asarray(ref.sgd_block_ref(w, xs, ys, mask, 5e-3, 1e-4))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_partial_mask_equals_truncated_run():
    """Steps with mask 0 beyond position m must not change the result."""
    rng = np.random.default_rng(3)
    w, xs, ys = _rand_case(rng, 48, 8)
    m = 19
    mask = (np.arange(48) < m).astype(np.float32)
    full = _run_kernel(w, xs, ys, mask, 1e-2, 1e-3)
    trunc = _run_numpy(w, xs[:m], ys[:m], np.ones(m, np.float32), 1e-2, 1e-3)
    np.testing.assert_allclose(full, trunc, rtol=1e-4, atol=1e-5)


def test_zero_mask_is_noop():
    rng = np.random.default_rng(4)
    w, xs, ys = _rand_case(rng, 16, 8)
    mask = np.zeros(16, dtype=np.float32)
    got = _run_kernel(w, xs, ys, mask, 1e-1, 1e-2)
    np.testing.assert_allclose(got, w, rtol=0, atol=0)


def test_zero_alpha_is_noop():
    rng = np.random.default_rng(5)
    w, xs, ys = _rand_case(rng, 16, 8)
    mask = np.ones(16, dtype=np.float32)
    got = _run_kernel(w, xs, ys, mask, 0.0, 1e-2)
    np.testing.assert_allclose(got, w, rtol=0, atol=0)


def test_single_step_matches_closed_form():
    """One unmasked step is exactly w - alpha*(2(w.x-y)x + reg2*w)."""
    rng = np.random.default_rng(6)
    w, xs, ys = _rand_case(rng, 1, 8)
    mask = np.ones(1, dtype=np.float32)
    alpha, reg2 = 7e-3, 2e-3
    got = _run_kernel(w, xs, ys, mask, alpha, reg2)
    err = w @ xs[0] - ys[0]
    want = w - alpha * (2 * err * xs[0] + reg2 * w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_descends_on_quadratic():
    """With a small step size the block must reduce the batch loss."""
    rng = np.random.default_rng(7)
    k, d = 128, 8
    xs = rng.normal(size=(k, d)).astype(np.float32)
    w_true = rng.normal(size=d).astype(np.float32)
    ys = (xs @ w_true).astype(np.float32)
    w0 = np.zeros(d, dtype=np.float32)
    mask = np.ones(k, dtype=np.float32)
    w1 = _run_kernel(w0, xs, ys, mask, 1e-2, 0.0)

    def loss(w):
        return float(np.mean((xs @ w - ys) ** 2))

    assert loss(w1) < loss(w0)


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=96),
    d=st.integers(min_value=1, max_value=16),
    alpha=st.floats(min_value=1e-5, max_value=5e-2),
    reg2=st.floats(min_value=0.0, max_value=1e-2),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_matches_numpy(k, d, alpha, reg2, seed):
    rng = np.random.default_rng(seed)
    w, xs, ys = _rand_case(rng, k, d)
    mask = (rng.random(k) < 0.7).astype(np.float32)
    got = _run_kernel(w, xs, ys, mask, alpha, reg2)
    want = _run_numpy(w, xs, ys, mask, alpha, reg2)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("k", [1, 2, 7, 33, 512])
def test_shapes(k):
    rng = np.random.default_rng(8)
    w, xs, ys = _rand_case(rng, k, 8)
    mask = np.ones(k, dtype=np.float32)
    out = _run_kernel(w, xs, ys, mask, 1e-3, 0.0)
    assert out.shape == (8,)
    assert out.dtype == np.float32
