//! Integration tests across the whole experiment stack: figure
//! producers emit paper-shaped outputs, the baselines order correctly,
//! and the bound's guidance is actually useful (the paper's core claim).

use edgepipe::bound::corollary1::BoundParams;
use edgepipe::bound::estimate_constants;
use edgepipe::config::ExperimentConfig;
use edgepipe::coordinator::run::run_experiment;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::sweep::fig3::fig3_data;
use edgepipe::sweep::fig4::{fig4_data, Fig4Config};

fn small_paper_setup() -> (edgepipe::data::Dataset, BoundParams, f64) {
    let raw = synth_calhousing(&SynthSpec { n: 3000, ..Default::default() });
    let (train, _) = train_split(&raw, 0.9, 42);
    let t = 1.5 * train.n as f64;
    let k = estimate_constants(&train, 0.05, 1e-3, 1000, 42);
    let params = BoundParams {
        alpha: 1e-3,
        big_l: k.big_l,
        c: k.c,
        m: 1.0,
        m_g: 1.0,
        d_diam: k.d_diam,
    };
    (train, params, t)
}

#[test]
fn fig3_shape_matches_paper_narrative() {
    let (train, params, t) = small_paper_setup();
    let out =
        fig3_data(&params, train.n, t, 1.0, &[1.0, 10.0, 100.0, 500.0], 80)
            .unwrap();
    // ñ_c strictly increasing in n_o; curve has an interior minimum
    let mut prev = 0usize;
    for c in &out.curves {
        assert!(c.opt_n_c > prev, "ñ_c not increasing: {:?}", c.opt_n_c);
        prev = c.opt_n_c;
        let first = c.points.first().unwrap().1;
        let last = c.points.last().unwrap().1;
        assert!(c.opt_value <= first && c.opt_value <= last);
        // boundary exists for these overheads at T = 1.5N
        assert!(c.boundary_n_c.is_some());
    }
}

#[test]
fn fig4_bound_guidance_close_to_experimental_optimum() {
    let (train, params, t) = small_paper_setup();
    let cfg = Fig4Config {
        alpha: 1e-3,
        seeds: 4,
        search_points: 10,
        curve_points: 40,
        reference_n_cs: vec![train.n],
        ..Fig4Config::paper(50.0, t)
    };
    let out = fig4_data(&train, &params, &cfg).unwrap();
    // the paper's quantitative headline: the bound's ñ_c costs only a
    // few percent vs the experimental optimum (paper: 3.8%)
    assert!(
        out.bound_penalty < 0.25,
        "bound guidance too weak: {:+.1}%",
        100.0 * out.bound_penalty
    );
    // and transmit-everything-first is far worse than both
    let all_first = out
        .curves
        .iter()
        .find(|c| c.n_c == train.n)
        .expect("reference curve");
    assert!(
        all_first.final_loss > 1.2 * out.exp_final,
        "n_c=N should lose clearly: {} vs {}",
        all_first.final_loss,
        out.exp_final
    );
}

#[test]
fn experiment_config_end_to_end() {
    let mut cfg = ExperimentConfig::default();
    cfg.data.n_raw = 1500;
    cfg.protocol.n_c = 0; // auto-optimize
    cfg.protocol.n_o = 30.0;
    cfg.train.alpha = 1e-3;
    cfg.train.loss_stride = 100.0;
    let out = run_experiment(&cfg).unwrap();
    // auto n_c chosen, training happened, gap nonnegative, curve dense
    assert!(out.n_c >= 1 && out.n_c <= out.train.n);
    assert!(out.result.updates > 0);
    assert!(out.result.final_gap(out.loss_star) >= -1e-9);
    assert!(out.result.curve.len() > 10);
    // curve is recorded in time order and ends at the deadline
    let t_budget = cfg.protocol.deadline(out.train.n);
    assert_eq!(out.result.curve.last().unwrap().0, t_budget);
}

#[test]
fn seeds_change_trajectory_but_not_protocol_accounting() {
    let mut cfg = ExperimentConfig::default();
    cfg.data.n_raw = 1000;
    cfg.protocol.n_c = 64;
    cfg.train.alpha = 1e-3;
    let a = run_experiment(&cfg).unwrap();
    cfg.train.seed = 999;
    let b = run_experiment(&cfg).unwrap();
    assert_ne!(a.result.final_w, b.result.final_w);
    assert_eq!(a.result.blocks_sent, b.result.blocks_sent);
    assert_eq!(a.result.samples_delivered, b.result.samples_delivered);
    assert_eq!(a.result.updates, b.result.updates);
}
