//! Failure injection and edge cases: hostile channels, degenerate
//! deadlines, capacity extremes, config validation.

use edgepipe::channel::{ErasureChannel, IdealChannel, RateLimitedChannel};
use edgepipe::config::ExperimentConfig;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::RidgeModel;
use edgepipe::protocol::TimelineCase;

fn ds(n: usize) -> edgepipe::data::Dataset {
    synth_calhousing(&SynthSpec { n, ..Default::default() })
}

fn exec(d: &edgepipe::data::Dataset, cfg: &DesConfig) -> NativeExecutor {
    NativeExecutor::new(RidgeModel::new(d.d, cfg.lambda, d.n), cfg.alpha)
}

#[test]
fn deadline_shorter_than_first_block_trains_nothing() {
    let data = ds(200);
    // block duration 60+10=70 > T=50: nothing arrives, no updates
    let cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(60, 10.0, 50.0, 1)
    };
    let res =
        run_des(&data, &cfg, &mut IdealChannel, &mut exec(&data, &cfg))
            .unwrap();
    assert_eq!(res.samples_delivered, 0);
    assert_eq!(res.updates, 0);
    assert_eq!(res.case, TimelineCase::Partial);
    // initial w is the final w
    assert_eq!(res.curve.first().unwrap().1, res.final_loss);
}

#[test]
fn nearly_dead_channel_still_terminates() {
    let data = ds(100);
    let cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(10, 5.0, 500.0, 2)
    };
    let mut ch = ErasureChannel::new(0.95);
    let res = run_des(&data, &cfg, &mut ch, &mut exec(&data, &cfg)).unwrap();
    // massive retransmission, little delivery — but bounded and sane
    assert!(res.retransmissions > 0);
    assert!(res.samples_delivered <= 100);
    assert!(res.final_loss.is_finite());
}

#[test]
fn very_slow_rate_channel_degrades_gracefully() {
    let data = ds(100);
    let cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(10, 5.0, 300.0, 3)
    };
    let mut ch = RateLimitedChannel::new(0.01, IdealChannel);
    let res = run_des(&data, &cfg, &mut ch, &mut exec(&data, &cfg)).unwrap();
    assert_eq!(res.samples_delivered, 0, "rate 0.01 delivers nothing in T");
    assert_eq!(res.updates, 0);
}

#[test]
fn single_sample_store_trains() {
    let data = ds(50);
    let cfg = DesConfig {
        store_capacity: Some(1),
        record_blocks: false,
        ..DesConfig::paper(5, 2.0, 200.0, 4)
    };
    let res =
        run_des(&data, &cfg, &mut IdealChannel, &mut exec(&data, &cfg))
            .unwrap();
    assert!(res.updates > 0);
    assert!(res.final_loss.is_finite());
}

#[test]
fn n_c_one_extreme_works() {
    let data = ds(80);
    let cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(1, 0.0, 200.0, 5)
    };
    let res =
        run_des(&data, &cfg, &mut IdealChannel, &mut exec(&data, &cfg))
            .unwrap();
    assert_eq!(res.blocks_sent, 80.min(200));
    assert!(res.updates > 0);
}

#[test]
fn n_c_equals_n_single_shot() {
    let data = ds(80);
    let cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(80, 10.0, 300.0, 6)
    };
    let res =
        run_des(&data, &cfg, &mut IdealChannel, &mut exec(&data, &cfg))
            .unwrap();
    assert_eq!(res.blocks_sent, 1);
    assert_eq!(res.samples_delivered, 80);
    // updates only in the tail: T - (80 + 10)
    assert_eq!(res.updates, 300 - 90);
}

#[test]
fn zero_overhead_is_allowed() {
    let data = ds(60);
    let cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(10, 0.0, 120.0, 7)
    };
    let res =
        run_des(&data, &cfg, &mut IdealChannel, &mut exec(&data, &cfg))
            .unwrap();
    assert_eq!(res.samples_delivered, 60);
}

#[test]
fn config_validation_rejects_nonsense() {
    for (key, val) in [
        ("train.alpha", "-1.0"),
        ("protocol.tau_p", "0"),
        ("data.train_frac", "1.5"),
        ("data.hess_min", "0"),
        ("data.n_raw", "0"),
    ] {
        let r = ExperimentConfig::load(
            None,
            &[(key.to_string(), val.to_string())],
        );
        assert!(r.is_err(), "{key}={val} should be rejected");
    }
}

#[test]
fn malformed_manifest_is_rejected() {
    use edgepipe::runtime::Manifest;
    let dir = std::env::temp_dir().join("edgepipe_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    // missing constants
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": 1, "artifacts": {}}"#,
    )
    .unwrap();
    assert!(Manifest::load(&dir).is_err());
    // wrong format version
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": 99, "constants": {}, "artifacts": {}}"#,
    )
    .unwrap();
    assert!(Manifest::load(&dir).is_err());
    // referenced file missing
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": 1,
            "constants": {"d":8,"k_max":512,"n_raw":10,"n_cap":1024,
                          "loss_tile":1024,"mlp_hidden":16,"mlp_batch":16},
            "artifacts": {"sgd_block": {"file": "missing.hlo.txt",
              "inputs": [], "outputs": [], "sha256": ""}}}"#,
    )
    .unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
fn csv_loader_rejects_garbage() {
    use edgepipe::data::csv::load_csv;
    let dir = std::env::temp_dir().join("edgepipe_bad_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("bad.csv");
    std::fs::write(&p, "1,2,3\nnot,a,number\n").unwrap();
    assert!(load_csv(&p).is_err());
    let p2 = dir.join("empty.csv");
    std::fs::write(&p2, "").unwrap();
    assert!(load_csv(&p2).is_err());
}
