//! The threaded pipeline must be bit-identical to the DES across random
//! configurations, channels, and store capacities.

use edgepipe::channel::{Channel, ErasureChannel, IdealChannel};
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::coordinator::pipeline::run_pipelined;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::RidgeModel;
use edgepipe::testkit::forall;

fn check_parity(cfg: &DesConfig, n: usize, make_channel: impl Fn() -> Box<dyn Channel>) {
    let ds = synth_calhousing(&SynthSpec { n, ..Default::default() });
    let mk = || {
        NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        )
    };
    let mut ch1 = make_channel();
    let mut ch2 = make_channel();
    let des = run_des(&ds, cfg, ch1.as_mut(), &mut mk()).unwrap();
    let pipe = run_pipelined(&ds, cfg, ch2.as_mut(), &mut mk()).unwrap();
    assert_eq!(des.final_w, pipe.final_w, "trajectories diverged");
    assert_eq!(des.curve, pipe.curve, "loss curves diverged");
    assert_eq!(des.updates, pipe.updates);
    assert_eq!(des.samples_delivered, pipe.samples_delivered);
    assert_eq!(des.blocks_sent, pipe.blocks_sent);
    assert_eq!(des.blocks_delivered, pipe.blocks_delivered);
    assert_eq!(des.retransmissions, pipe.retransmissions);
    assert_eq!(des.case, pipe.case);
    assert_eq!(des.snapshots.len(), pipe.snapshots.len());
}

#[test]
fn parity_on_ideal_channel() {
    forall("parity ideal", 10, |g| {
        let n = g.usize_in(50..=500);
        let cfg = DesConfig {
            loss_every: *g.choose(&[0usize, 37, 200]),
            record_blocks: g.bool_with(0.5),
            collect_snapshots: g.bool_with(0.3),
            ..DesConfig::paper(
                g.usize_in(1..=n),
                g.f64_in(0.0, 40.0).round(),
                g.f64_in(20.0, 3.0 * n as f64).round(),
                g.u64_in(0..=1 << 40),
            )
        };
        check_parity(&cfg, n, || Box::new(IdealChannel));
    });
}

#[test]
fn parity_on_erasure_channel() {
    forall("parity erasure", 8, |g| {
        let n = g.usize_in(50..=400);
        let p = g.f64_in(0.05, 0.5);
        let cfg = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(
                g.usize_in(5..=n),
                g.f64_in(0.0, 20.0).round(),
                g.f64_in(50.0, 2.0 * n as f64).round(),
                g.u64_in(0..=1 << 40),
            )
        };
        check_parity(&cfg, n, move || Box::new(ErasureChannel::new(p)));
    });
}

#[test]
fn parity_with_bounded_store() {
    forall("parity reservoir", 6, |g| {
        let n = g.usize_in(100..=400);
        let cfg = DesConfig {
            store_capacity: Some(g.usize_in(10..=n / 2)),
            record_blocks: false,
            ..DesConfig::paper(
                g.usize_in(5..=n / 2),
                5.0,
                2.0 * n as f64,
                g.u64_in(0..=1 << 40),
            )
        };
        check_parity(&cfg, n, || Box::new(IdealChannel));
    });
}
