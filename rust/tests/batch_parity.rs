//! Batched-seed engine ≡ scalar engine, bit-for-bit.
//!
//! The batched-seed sweep engine (`sweep/batch.rs`) traces each seed's
//! DES once and replays the SGD tape lane-batched through SoA kernels.
//! Its contract is exact equality — every lane's final loss must carry
//! the SAME bits as the scalar per-seed run — across every scenario
//! axis: channels (ideal, erasure, Gilbert–Elliott fading), policies
//! (fixed, warmup, closed-loop control), traffic (single device,
//! multi-device, online arrivals), and both workloads. Configs the
//! engine cannot replay (bounded stores, curve recording) must fall
//! back to the scalar path, transparently.

use edgepipe::coordinator::des::DesConfig;
use edgepipe::coordinator::scheduler::RunWorkspace;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::Workload;
use edgepipe::sweep::scenario::{
    ChannelSpec, EstimatorSpec, PolicySpec, ScenarioRunner, ScenarioSpec,
    TrafficSpec,
};
use edgepipe::sweep::{
    batchable, mc_scenario_loss_lanes, run_group, scenario_grid_lanes,
    BatchWorkspace,
};

fn small_ds() -> edgepipe::data::Dataset {
    synth_calhousing(&SynthSpec { n: 320, ..Default::default() })
}

fn sweep_base(seed: u64) -> DesConfig {
    DesConfig {
        loss_every: 0,
        record_blocks: false,
        collect_snapshots: false,
        event_capacity: 0,
        ..DesConfig::paper(32, 5.0, 640.0, seed)
    }
}

/// Every scenario axis the engine claims to support, one spec each.
fn axis_specs() -> Vec<ScenarioSpec> {
    let paper = ScenarioSpec::paper();
    vec![
        paper.clone(),
        ScenarioSpec {
            channel: ChannelSpec::Erasure { p: 0.2 },
            ..paper.clone()
        },
        ScenarioSpec {
            channel: ChannelSpec::Fading {
                p_gb: 0.05,
                p_bg: 0.25,
                p_good: 0.0,
                p_bad: 0.6,
                rate_good: 1.0,
                rate_bad: 1.0,
            },
            policy: PolicySpec::Control {
                est: EstimatorSpec::Ge,
                replan_every: 2,
            },
            ..paper.clone()
        },
        ScenarioSpec {
            policy: PolicySpec::Warmup { start: 4, growth: 2.0, cap: 64 },
            ..paper.clone()
        },
        ScenarioSpec { workload: Workload::Logistic, ..paper.clone() },
        ScenarioSpec { traffic: TrafficSpec::Devices(3), ..paper.clone() },
        ScenarioSpec {
            traffic: TrafficSpec::Online { rate: 0.8 },
            ..paper
        },
    ]
}

#[test]
fn every_axis_matches_scalar_bitwise() {
    let ds = small_ds();
    let base = sweep_base(19);
    for (k, spec) in axis_specs().into_iter().enumerate() {
        // 5 seeds exercises a ragged 8-wide group with 3 dead lanes
        let scalar =
            mc_scenario_loss_lanes(&ds, &base, &spec, 5, 2, 1).unwrap();
        for lanes in [4usize, 8, 16] {
            let batched =
                mc_scenario_loss_lanes(&ds, &base, &spec, 5, 2, lanes)
                    .unwrap();
            assert_eq!(
                scalar.mean.to_bits(),
                batched.mean.to_bits(),
                "spec #{k} {} lanes={lanes}: mean diverged",
                spec.label()
            );
            assert_eq!(
                scalar.std.to_bits(),
                batched.std.to_bits(),
                "spec #{k} {} lanes={lanes}: std diverged",
                spec.label()
            );
        }
    }
}

#[test]
fn grid_crossing_matches_scalar_bitwise() {
    let ds = small_ds();
    let base = sweep_base(7);
    let specs = axis_specs();
    let scalar = scenario_grid_lanes(&ds, &base, &specs, 4, 3, 1).unwrap();
    let batched = scenario_grid_lanes(&ds, &base, &specs, 4, 3, 8).unwrap();
    assert_eq!(scalar.len(), batched.len());
    for (a, b) in scalar.iter().zip(&batched) {
        assert_eq!(a.0, b.0);
        assert_eq!(a.1.mean.to_bits(), b.1.mean.to_bits(), "{}", a.0);
        assert_eq!(a.1.std.to_bits(), b.1.std.to_bits(), "{}", a.0);
    }
}

#[test]
fn run_group_reports_scalar_update_counts() {
    let ds = small_ds();
    let base = sweep_base(31);
    let runner = ScenarioRunner::new(ScenarioSpec::paper(), &ds);
    let cfg_for = |s: usize| DesConfig {
        seed: base.seed.wrapping_add(s as u64),
        ..base.clone()
    };
    let mut bw = BatchWorkspace::new();
    let outs = run_group(&runner, &mut bw, 5, cfg_for).unwrap();
    for l in 0..5 {
        let mut ws = RunWorkspace::new();
        let stats = runner.run_with(&mut ws, &cfg_for(l)).unwrap();
        assert_eq!(outs[l].updates, stats.updates, "lane {l} updates");
        assert_eq!(
            outs[l].final_loss.to_bits(),
            stats.final_loss.to_bits(),
            "lane {l} final loss"
        );
    }
}

#[test]
fn bounded_store_falls_back_to_scalar() {
    let ds = small_ds();
    let base = sweep_base(11);
    let spec = ScenarioSpec {
        store_capacity: Some(48),
        ..ScenarioSpec::paper()
    };
    // the reservoir store overwrites rows, so the traced-replay gate
    // must reject it...
    let runner = ScenarioRunner::new(spec.clone(), &ds);
    assert!(!batchable(&runner.effective_cfg(&base)));
    // ...and the batched entry points still return scalar results
    let scalar = mc_scenario_loss_lanes(&ds, &base, &spec, 4, 2, 1).unwrap();
    let batched = mc_scenario_loss_lanes(&ds, &base, &spec, 4, 2, 8).unwrap();
    assert_eq!(scalar.mean.to_bits(), batched.mean.to_bits());
}

#[test]
fn curve_recording_configs_are_not_batchable() {
    // run_group must take the scalar path whenever the config records
    // anything mid-run — semantics the tape replay cannot reproduce
    let sweep = sweep_base(3);
    assert!(batchable(&sweep));
    assert!(!batchable(&DesConfig { loss_every: 100, ..sweep.clone() }));
    assert!(!batchable(&DesConfig { record_blocks: true, ..sweep.clone() }));
    assert!(!batchable(&DesConfig {
        collect_snapshots: true,
        ..sweep
    }));
}
