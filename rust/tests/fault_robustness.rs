//! Seeded property tests for the protocol-hardening layer (ARQ
//! timeout/retry/backoff bounds, deterministic eviction, fault-grammar
//! round-trips), plus the PR's acceptance Monte-Carlo: under permanent
//! device dropout and periodic link outages, the hardened
//! graceful-degradation protocol with the closed-loop `control` policy
//! must complete within the deadline and beat the fault-blind fixed
//! recommendation on both mean final loss and deadline-outage rate.

use edgepipe::channel::{FaultSpec, FaultWindow, RetrySpec};
use edgepipe::coordinator::des::DesConfig;
use edgepipe::coordinator::run::deadline_outage;
use edgepipe::coordinator::scheduler::RunWorkspace;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::sweep::scenario::{
    ChannelSpec, EstimatorSpec, HeteroSpec, PolicySpec, ScenarioRunner,
    ScenarioSpec, SchedulerSpec, TrafficSpec,
};
use edgepipe::testkit::{forall, Gen};

fn gen_window(g: &mut Gen) -> FaultWindow {
    let start = g.f64_in(0.0, 1000.0);
    let dur = g.f64_in(0.5, 200.0);
    let period = if g.bool_with(0.5) {
        f64::INFINITY
    } else {
        dur + g.f64_in(0.5, 500.0)
    };
    FaultWindow::new(start, dur, period).expect("generated window valid")
}

fn gen_fault(g: &mut Gen) -> FaultSpec {
    let mut spec = FaultSpec::default();
    for _ in 0..g.usize_in(0..=2) {
        spec.outages.push(gen_window(g));
    }
    if g.bool_with(0.4) {
        spec.ack_loss = g.f64_in(0.01, 0.9);
    }
    for _ in 0..g.usize_in(0..=2) {
        spec.drops.push((g.usize_in(0..=7), g.f64_in(0.0, 1000.0)));
    }
    for _ in 0..g.usize_in(0..=1) {
        spec.preempts.push(gen_window(g));
    }
    if g.bool_with(0.5) {
        spec.retry = Some(RetrySpec {
            // exercise the suffix-defaulted label forms too
            timeout: 1.0 + g.f64_log(0.1, 20.0),
            budget: if g.bool_with(0.3) {
                3 // DEFAULT_RETRY_BUDGET: label drops the suffix
            } else {
                g.u64_in(0..=6) as u32
            },
            evict: if g.bool_with(0.4) { 0 } else { g.u64_in(1..=4) as u32 },
        });
    }
    spec
}

#[test]
fn fault_spec_labels_round_trip() {
    forall("fault parse∘label == id", 300, |g| {
        let spec = gen_fault(g);
        let label = spec.label();
        let re = FaultSpec::parse(&label)
            .unwrap_or_else(|e| panic!("label '{label}' unparseable: {e}"));
        assert_eq!(spec, re, "label '{label}' round-tripped differently");
        // idempotent canonical form
        assert_eq!(re.label(), label, "label not canonical");
    });
}

#[test]
fn faulty_channel_labels_round_trip() {
    forall("channel:fault parse∘label == id", 200, |g| {
        let fault = gen_fault(g);
        let base = match g.usize_in(0..=2) {
            0 => ChannelSpec::Ideal,
            1 => ChannelSpec::Erasure { p: g.f64_in(0.0, 0.99) },
            _ => ChannelSpec::Rate {
                rate: g.f64_log(0.05, 20.0),
                p: g.f64_in(0.0, 0.99),
            },
        };
        let spec = base.with_fault(&fault);
        let label = spec.label();
        let re = ChannelSpec::parse(&label)
            .unwrap_or_else(|e| panic!("label '{label}' unparseable: {e}"));
        assert_eq!(spec, re, "label '{label}' round-tripped differently");
    });
}

/// ARQ invariants, over randomized outage scripts, retry knobs, seeds
/// and traffic shapes: the timeout count is bounded by the retry
/// budget, eviction only happens when armed, the sample ledger never
/// over-counts, and a re-run with identical inputs is bit-identical.
#[test]
fn retry_and_backoff_respect_their_bounds() {
    let ds = synth_calhousing(&SynthSpec { n: 192, ..Default::default() });
    forall("ARQ bounds", 24, |g| {
        let budget = g.u64_in(0..=4) as u32;
        let evict = if g.bool_with(0.5) { 0 } else { g.u64_in(1..=3) as u32 };
        let timeout = 2.0 + g.f64_in(0.0, 6.0);
        let start = g.f64_in(0.0, 300.0);
        let dur = g.f64_in(10.0, 1500.0);
        let fault = format!("outage:{start}:{dur}+retry:{timeout}:{budget}:{evict}");
        let base = *g.choose(&["ideal", "erasure:0.15"]);
        let channel =
            ChannelSpec::parse(&format!("{base}:fault={fault}")).unwrap();
        let devices = *g.choose(&[1usize, 3]);
        let spec = ScenarioSpec {
            channel,
            traffic: TrafficSpec::Devices(devices),
            ..ScenarioSpec::paper()
        };
        let cfg = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(24, 6.0, 420.0, g.u64_in(0..=1u64 << 32))
        };
        let runner = ScenarioRunner::new(spec, &ds);
        let mut ws = RunWorkspace::new();
        let stats = runner.run_with(&mut ws, &cfg).unwrap();
        // each block times out at most once per send: 1 initial send +
        // `budget` re-sends
        assert!(
            stats.timeouts <= (u64::from(budget) + 1) * stats.blocks_sent as u64,
            "timeouts {} exceed (budget {budget} + 1) x sent {}",
            stats.timeouts,
            stats.blocks_sent
        );
        assert!(stats.blocks_abandoned <= stats.blocks_sent);
        if evict == 0 {
            assert_eq!(stats.evictions, 0, "eviction fired while disarmed");
        }
        assert!(stats.evictions <= devices, "more evictions than devices");
        assert!(
            stats.samples_delivered + stats.samples_lost <= ds.n,
            "sample ledger over-counts: {} delivered + {} lost > {}",
            stats.samples_delivered,
            stats.samples_lost,
            ds.n
        );
        if stats.degraded_completion {
            assert_eq!(stats.blocks_missed, 0, "degraded yet late");
            assert!(stats.samples_lost > 0, "degraded yet nothing shed");
            assert!(
                stats.samples_delivered + stats.samples_lost >= ds.n,
                "degraded yet samples unaccounted for"
            );
            assert!(
                !deadline_outage(
                    stats.blocks_missed,
                    stats.case,
                    stats.degraded_completion
                ),
                "degraded completion must not be an outage"
            );
        }
        // determinism: an identical re-run reproduces every bit/counter
        let mut ws2 = RunWorkspace::new();
        let again = runner.run_with(&mut ws2, &cfg).unwrap();
        assert_eq!(stats.final_loss.to_bits(), again.final_loss.to_bits());
        assert_eq!(stats.timeouts, again.timeouts);
        assert_eq!(stats.retransmissions, again.retransmissions);
        assert_eq!(stats.blocks_abandoned, again.blocks_abandoned);
        assert_eq!(stats.evictions, again.evictions);
        assert_eq!(stats.samples_lost, again.samples_lost);
    });
}

/// Dropout → eviction is scripted, so it must replay exactly: same
/// seed, same event log, same ledger — and it must actually evict.
#[test]
fn eviction_is_deterministic_across_reruns() {
    let ds = synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
    let spec = ScenarioSpec {
        channel: ChannelSpec::parse("erasure:0.15:fault=drop:1:80+retry:4:2:2")
            .unwrap(),
        traffic: TrafficSpec::Devices(3),
        ..ScenarioSpec::paper()
    };
    let cfg = DesConfig {
        record_blocks: false,
        event_capacity: 1 << 14,
        ..DesConfig::paper(24, 6.0, 420.0, 13)
    };
    let a = ScenarioRunner::new(spec.clone(), &ds).run(&cfg).unwrap();
    let b = ScenarioRunner::new(spec.clone(), &ds).run(&cfg).unwrap();
    assert!(a.evictions >= 1, "the dropped device was never evicted");
    assert!(a.samples_lost > 0, "eviction must shed the dead shard");
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    assert_eq!(a.timeouts, b.timeouts);
    assert_eq!(a.evictions, b.evictions);
    assert_eq!(a.samples_lost, b.samples_lost);
    assert_eq!(
        format!("{:?}", a.events),
        format!("{:?}", b.events),
        "eviction event log diverged between identical runs"
    );
    // a different seed keeps the scripted eviction (only the channel
    // noise around it moves)
    let c = ScenarioRunner::new(spec, &ds)
        .run(&DesConfig { seed: 14, ..cfg })
        .unwrap();
    assert!(c.evictions >= 1, "eviction must not depend on the seed");
}

/// The PR's acceptance criterion (>= 32 Monte-Carlo seeds): a 3-device
/// fleet where one lane suffers periodic outages and another dies
/// permanently at t = 0. The fault-blind paper protocol head-of-line
/// blocks on the dead lane and busts the deadline on every seed; the
/// hardened protocol (ARQ timeout 2x, retry budget 1, evict after 1)
/// with the closed-loop `control` policy evicts the dead device, sheds
/// its shard (bias, not blocking) and finishes inside the deadline —
/// with strictly better mean loss and outage rate.
#[test]
fn graceful_degradation_beats_the_fault_blind_protocol() {
    let ds = synth_calhousing(&SynthSpec { n: 480, ..Default::default() });
    let lanes = |dead: &str| -> Vec<ChannelSpec> {
        vec![
            ChannelSpec::Ideal,
            ChannelSpec::parse("erasure:0.1:fault=outage:60:25:240").unwrap(),
            ChannelSpec::parse(dead).unwrap(),
        ]
    };
    let scenario = |dead: &str, policy: PolicySpec| ScenarioSpec {
        traffic: TrafficSpec::Hetero(
            HeteroSpec::new(3, SchedulerSpec::Greedy, 0.0, lanes(dead))
                .expect("valid hetero spec"),
        ),
        policy,
        ..ScenarioSpec::paper()
    };
    let blind =
        scenario("ideal:fault=drop:2:0", ScenarioSpec::paper().policy);
    let hardened = scenario(
        "ideal:fault=drop:2:0+retry:2:1:1",
        PolicySpec::Control { est: EstimatorSpec::Ema, replan_every: 1 },
    );
    let base = DesConfig {
        loss_every: 0,
        record_blocks: false,
        event_capacity: 0,
        // 2x the natural transmission time: generous slack, so any
        // outage below is the protocol's fault, not the deadline's
        ..DesConfig::paper(24, 6.0, 2.0 * 480.0, 7000)
    };
    let seeds = 32u64;
    let run_all = |spec: &ScenarioSpec| -> (f64, f64, usize) {
        let runner = ScenarioRunner::new(spec.clone(), &ds);
        let mut ws = RunWorkspace::new();
        let (mut loss_sum, mut outages, mut degraded) = (0.0, 0usize, 0usize);
        for s in 0..seeds {
            let cfg = DesConfig { seed: base.seed + s, ..base.clone() };
            let stats = runner.run_with(&mut ws, &cfg).unwrap();
            loss_sum += stats.final_loss;
            if deadline_outage(
                stats.blocks_missed,
                stats.case,
                stats.degraded_completion,
            ) {
                outages += 1;
            }
            if stats.degraded_completion {
                assert!(stats.evictions >= 1, "degraded without eviction");
                degraded += 1;
            }
        }
        (loss_sum / seeds as f64, outages as f64 / seeds as f64, degraded)
    };
    let (blind_loss, blind_outage, _) = run_all(&blind);
    let (hard_loss, hard_outage, hard_degraded) = run_all(&hardened);
    // the dead lane guarantees a missed block for the blind protocol
    assert_eq!(
        blind_outage, 1.0,
        "fault-blind protocol somehow met the deadline"
    );
    // graceful degradation: inside the deadline on every seed...
    assert_eq!(
        hard_outage, 0.0,
        "hardened protocol busted the deadline (mean loss {hard_loss})"
    );
    // ...by shedding the dead shard, not by luck
    assert_eq!(
        hard_degraded, seeds as usize,
        "hardened runs should all be degraded completions"
    );
    // and the surviving 2/3 of the data trains far further than the
    // head-of-line-blocked baseline
    assert!(
        hard_loss < blind_loss,
        "hardened mean loss {hard_loss} not below fault-blind {blind_loss}"
    );
}
