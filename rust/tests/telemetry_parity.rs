//! Telemetry is write-only observation: attaching a sink must not
//! change a single computed bit. Three contracts pin that:
//!
//! 1. Scenario runs across every axis (channel, policy, traffic,
//!    workload, bounded store, faults, heterogeneous uplink) produce a
//!    bit-identical `RunResult` — event stream, loss curve, snapshots
//!    and fault counters included — with the process-global sink
//!    attached vs detached.
//! 2. The threaded shard layer at shard counts 1 (inline) and 4
//!    (pooled) stays bit-identical with the sink attached, while the
//!    pool/shard counters actually accumulate.
//! 3. A streamed sweep writes a byte-identical journal and bit-
//!    identical `(label, McStats)` rows attached vs detached, at lane
//!    widths 4 and 8 — and the attached run's backpressure gauges
//!    drain to zero (`journal_lag == 0`, empty stage queues).
//!
//! Tests here install the process-global sink, so they serialize on a
//! file-local mutex; the shared CI matrix additionally runs this binary
//! under `EDGEPIPE_SHARDS`/`EDGEPIPE_LANES` variations.

use std::path::PathBuf;
use std::sync::Mutex;

use edgepipe::channel::{ErasureChannel, FaultSpec};
use edgepipe::coordinator::des::DesConfig;
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::coordinator::run::RunResult;
use edgepipe::coordinator::{
    run_schedule, FixedPolicy, GreedyScheduler, OverlapMode, ShardedSource,
};
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::data::Dataset;
use edgepipe::extensions::multi_device::shard_dataset;
use edgepipe::model::RidgeModel;
use edgepipe::sweep::scenario::{
    ChannelSpec, HeteroSpec, PolicySpec, ScenarioRunner, ScenarioSpec,
    SchedulerSpec, TrafficSpec,
};
use edgepipe::sweep::stream::{stream_scenario_grid, StreamOptions};
use edgepipe::sweep::McStats;
use edgepipe::util::telemetry::{self, Telemetry};

/// Every test below installs (and clears) the process-global sink;
/// serialize them so counter assertions stay exact.
static GLOBAL_SINK: Mutex<()> = Mutex::new(());

fn sink_lock() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_SINK.lock().unwrap_or_else(|e| e.into_inner())
}

fn mk_exec(ds: &Dataset, cfg: &DesConfig) -> NativeExecutor {
    NativeExecutor::new(RidgeModel::new(ds.d, cfg.lambda, ds.n), cfg.alpha)
}

/// Full bit-exact RunResult comparison, fault counters included.
fn assert_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.final_w, b.final_w, "{what}: final_w diverged");
    assert_eq!(a.final_loss, b.final_loss, "{what}: final_loss diverged");
    assert_eq!(a.curve, b.curve, "{what}: loss curve diverged");
    assert_eq!(a.updates, b.updates, "{what}: update count diverged");
    assert_eq!(a.blocks_sent, b.blocks_sent, "{what}: blocks_sent");
    assert_eq!(
        a.blocks_delivered, b.blocks_delivered,
        "{what}: blocks_delivered"
    );
    assert_eq!(
        a.samples_delivered, b.samples_delivered,
        "{what}: samples_delivered"
    );
    assert_eq!(
        a.retransmissions, b.retransmissions,
        "{what}: retransmissions"
    );
    assert_eq!(a.timeouts, b.timeouts, "{what}: timeouts diverged");
    assert_eq!(a.evictions, b.evictions, "{what}: evictions diverged");
    assert_eq!(a.case, b.case, "{what}: timeline case");
    assert_eq!(a.events, b.events, "{what}: event stream diverged");
    assert_eq!(a.snapshots.len(), b.snapshots.len(), "{what}: snapshots");
    for (sa, sb) in a.snapshots.iter().zip(&b.snapshots) {
        assert_eq!(sa.w_end, sb.w_end, "{what}: snapshot w_end");
        assert_eq!(sa.arrived_at, sb.arrived_at, "{what}: snapshot time");
    }
}

/// One spec per scenario axis the sweep surface exposes.
fn axis_specs() -> Vec<ScenarioSpec> {
    let paper = ScenarioSpec::paper();
    vec![
        // baseline
        paper.clone(),
        // channel axis
        ScenarioSpec {
            channel: ChannelSpec::Erasure { p: 0.2 },
            ..paper.clone()
        },
        // policy axis
        ScenarioSpec {
            policy: PolicySpec::Warmup { start: 8, growth: 2.0, cap: 64 },
            ..paper.clone()
        },
        // traffic axis: multi-device and online arrivals
        ScenarioSpec { traffic: TrafficSpec::Devices(3), ..paper.clone() },
        ScenarioSpec {
            traffic: TrafficSpec::Online { rate: 1.5 },
            ..paper.clone()
        },
        // workload axis (on a fading channel)
        ScenarioSpec {
            channel: ChannelSpec::Fading {
                p_gb: 0.05,
                p_bg: 0.25,
                p_good: 0.0,
                p_bad: 0.6,
                rate_good: 1.0,
                rate_bad: 0.5,
            },
            workload: edgepipe::model::Workload::Logistic,
            ..paper.clone()
        },
        // bounded-store axis
        ScenarioSpec { store_capacity: Some(120), ..paper.clone() },
        // fault axis: device 0's link dies at t=100 with the retry /
        // eviction machinery armed — timeouts are guaranteed to fire
        ScenarioSpec {
            channel: ChannelSpec::Ideal.with_fault(
                &FaultSpec::parse("drop:0:100.0+retry:4:2:3").unwrap(),
            ),
            traffic: TrafficSpec::Devices(3),
            ..paper.clone()
        },
        // heterogeneous-uplink axis: greedy over mixed lanes with skew
        ScenarioSpec {
            traffic: TrafficSpec::Hetero(
                HeteroSpec::new(
                    3,
                    SchedulerSpec::Greedy,
                    0.5,
                    vec![
                        ChannelSpec::Ideal,
                        ChannelSpec::Erasure { p: 0.2 },
                        ChannelSpec::Rate { rate: 0.5, p: 0.1 },
                    ],
                )
                .unwrap(),
            ),
            ..paper
        },
    ]
}

#[test]
fn scenario_axes_are_bit_identical_with_telemetry_attached() {
    let _g = sink_lock();
    let ds = synth_calhousing(&SynthSpec { n: 360, ..Default::default() });
    let cfg = DesConfig {
        alpha: 1e-3,
        collect_snapshots: true,
        event_capacity: 4096,
        ..DesConfig::paper(30, 8.0, 700.0, 17)
    };
    let specs = axis_specs();
    let run_all = || -> Vec<RunResult> {
        specs
            .iter()
            .map(|s| ScenarioRunner::new(s.clone(), &ds).run(&cfg).unwrap())
            .collect()
    };

    telemetry::install(Telemetry::off());
    let detached = run_all();

    let sink = Telemetry::attached();
    telemetry::install(sink.clone());
    let attached = run_all();
    telemetry::install(Telemetry::off());

    for ((spec, d), a) in specs.iter().zip(&detached).zip(&attached) {
        assert_identical(d, a, &spec.label());
    }
    // the sink really was live for the second pass
    sink.with(|m| {
        assert_eq!(m.sched.runs.get() as usize, specs.len());
        assert!(m.sched.events.get() > 0, "events folded in");
        assert!(m.sched.packets_sent.get() > 0, "packets folded in");
        assert!(
            m.sched.packets_resent.get() > 0,
            "lossy axes must retransmit"
        );
        assert!(m.sched.timeouts.get() > 0, "the fault axis times out");
    });
}

/// One k-device greedy run through the threaded shard layer.
fn run_sharded(
    ds: &Dataset,
    shards: &[Dataset],
    slowdowns: &[f64],
    cfg: &DesConfig,
    n_shards: usize,
) -> RunResult {
    let mut policy = FixedPolicy(cfg.n_c.max(1));
    let mut exec = mk_exec(ds, cfg);
    // constructed AFTER any install: the source clones the global
    // handle once here
    let mut src = ShardedSource::new(
        shards,
        cfg.seed,
        GreedyScheduler::new(),
        slowdowns,
        n_shards,
    );
    run_schedule(
        ds,
        cfg,
        &mut src,
        &mut policy,
        OverlapMode::Pipelined,
        &mut ErasureChannel::new(0.2),
        &mut exec,
    )
    .unwrap()
}

#[test]
fn sharded_runs_are_bit_identical_with_telemetry_attached() {
    let _g = sink_lock();
    let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
    let k = 4;
    let shards = shard_dataset(&ds, k);
    let slowdowns = [1.0, 2.0, 1.5, 1.0];
    let cfg = DesConfig {
        event_capacity: 8192,
        ..DesConfig::paper(25, 5.0, 1500.0, 99)
    };
    for s in [1usize, 4] {
        telemetry::install(Telemetry::off());
        let detached = run_sharded(&ds, &shards, &slowdowns, &cfg, s);

        let sink = Telemetry::attached();
        telemetry::install(sink.clone());
        let attached = run_sharded(&ds, &shards, &slowdowns, &cfg, s);
        telemetry::install(Telemetry::off());

        assert_identical(&detached, &attached, &format!("shards={s}"));
        sink.with(|m| {
            assert!(
                m.pool.shard_draws.get() > 0,
                "shards={s}: draws must count (inline and pooled alike)"
            );
            if s > 1 {
                assert!(
                    m.pool.shard_jobs.get() > 0,
                    "shards={s}: pooled workers must count jobs"
                );
                assert!(m.pool.barrier_waits.get() > 0);
                assert_eq!(
                    m.pool.shard_queue.get(),
                    0,
                    "shards={s}: queue gauge must drain to zero"
                );
            }
        });
    }
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("edgepipe_telemetry_parity");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}_{}.jsonl", std::process::id()))
}

fn assert_rows_bitwise(
    expected: &[(String, McStats)],
    got: &[(String, McStats)],
    ctx: &str,
) {
    assert_eq!(expected.len(), got.len(), "{ctx}: row count");
    for (e, g) in expected.iter().zip(got) {
        assert_eq!(e.0, g.0, "{ctx}: label");
        assert_eq!(e.1.n, g.1.n, "{ctx}: {} n", e.0);
        assert_eq!(
            e.1.mean.to_bits(),
            g.1.mean.to_bits(),
            "{ctx}: {} mean diverged",
            e.0
        );
        assert_eq!(
            e.1.std.to_bits(),
            g.1.std.to_bits(),
            "{ctx}: {} std diverged",
            e.0
        );
        assert_eq!(
            e.1.sem.to_bits(),
            g.1.sem.to_bits(),
            "{ctx}: {} sem diverged",
            e.0
        );
    }
}

#[test]
fn streamed_journal_bytes_are_identical_with_telemetry_attached() {
    let _g = sink_lock();
    telemetry::install(Telemetry::off());
    let ds = synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
    let base = DesConfig {
        loss_every: 0,
        record_blocks: false,
        collect_snapshots: false,
        event_capacity: 0,
        ..DesConfig::paper(24, 6.0, 420.0, 19)
    };
    let paper = ScenarioSpec::paper();
    let specs = vec![
        paper.clone(),
        ScenarioSpec {
            channel: ChannelSpec::Erasure { p: 0.2 },
            ..paper.clone()
        },
        ScenarioSpec {
            policy: PolicySpec::Warmup { start: 4, growth: 2.0, cap: 64 },
            ..paper
        },
    ];
    for lanes in [4usize, 8] {
        let j_off = tmp(&format!("off_l{lanes}"));
        let j_on = tmp(&format!("on_l{lanes}"));
        let _ = std::fs::remove_file(&j_off);
        let _ = std::fs::remove_file(&j_on);
        // one run worker: the journal's row order is deterministic, so
        // the two files must match byte for byte, not just row for row
        let detached_opts = StreamOptions {
            seeds: 5,
            threads: 1,
            lanes,
            journal: Some(j_off.clone()),
            ..StreamOptions::default()
        };
        let detached =
            stream_scenario_grid(&ds, &base, &specs, &detached_opts).unwrap();

        let sink = Telemetry::attached();
        let attached_opts = StreamOptions {
            seeds: 5,
            threads: 1,
            lanes,
            journal: Some(j_on.clone()),
            telemetry: sink.clone(),
            ..StreamOptions::default()
        };
        let attached =
            stream_scenario_grid(&ds, &base, &specs, &attached_opts).unwrap();

        assert!(detached.errors.is_empty() && attached.errors.is_empty());
        assert_rows_bitwise(
            &detached.rows,
            &attached.rows,
            &format!("lanes={lanes}"),
        );
        let bytes_off = std::fs::read(&j_off).unwrap();
        let bytes_on = std::fs::read(&j_on).unwrap();
        assert_eq!(
            bytes_off, bytes_on,
            "lanes={lanes}: journal bytes diverged with telemetry attached"
        );

        // the attached run's backpressure accounting drained completely
        sink.with(|m| {
            assert_eq!(
                m.stream.groups_run.get() as usize,
                attached.groups_run,
                "lanes={lanes}: groups_run"
            );
            assert_eq!(m.stream.groups_reused.get(), 0);
            assert_eq!(m.stream.error_rows.get(), 0);
            assert_eq!(
                m.stream.journal_lag(),
                0,
                "lanes={lanes}: every journaled row must be aggregated"
            );
            assert_eq!(
                m.stream.rows_journaled.get(),
                attached.groups_run as u64
            );
            assert_eq!(m.stream.job_queue.get(), 0, "gen→run drained");
            assert_eq!(m.stream.row_queue.get(), 0, "run→metrics drained");
            assert_eq!(m.stream.agg_queue.get(), 0, "metrics→agg drained");
            assert!(
                m.stream.group_time.count() > 0,
                "executed groups must be timed"
            );
        });

        let _ = std::fs::remove_file(&j_off);
        let _ = std::fs::remove_file(&j_on);
    }
}
