//! Seeded property tests for the open-loop block-size schedules in
//! `extensions::adaptive` — the policies the closed-loop controller is
//! compared against:
//!
//! * warmup is monotone non-decreasing and caps at its configured cap
//!   (the fixed-`ñ_c` optimum in the standard wiring);
//! * no schedule ever requests more than the remaining dataset, and a
//!   drained schedule grants exactly `n` samples in total;
//! * deadline-aware sizing shrinks monotonically as the deadline nears
//!   and stays legal (≥ 1) past the budget.

use edgepipe::coordinator::scheduler::BlockPolicy;
use edgepipe::extensions::adaptive::{DeadlineAwareSchedule, WarmupSchedule};
use edgepipe::testkit::forall;

#[test]
fn warmup_is_monotone_non_decreasing_and_caps() {
    forall("warmup monotone + cap", 80, |g| {
        let start = g.usize_in(1..=128);
        let growth = 1.0 + g.f64_in(0.0, 4.0);
        let cap = start + g.usize_in(0..=4000);
        let mut s = WarmupSchedule::new(start, growth, cap);
        // plenty of data: the remaining clamp never binds here
        let plenty = usize::MAX / 2;
        let mut prev = 0usize;
        let mut reached_cap = false;
        for b in 1..=64usize {
            let nc = s.next_n_c(b, plenty, 0.0);
            assert!(nc >= 1 && nc <= cap, "block {b}: {nc} vs cap {cap}");
            assert!(
                nc >= prev,
                "block {b}: warmup shrank {prev} -> {nc} (start={start}, \
                 growth={growth}, cap={cap})"
            );
            if reached_cap {
                assert_eq!(nc, cap, "block {b}: left the cap after reaching it");
            }
            reached_cap |= nc == cap;
            prev = nc;
        }
        // real growth must actually reach the cap within 64 blocks
        // (1.2^63 > 4128 >= cap - start)
        if growth >= 1.2 {
            assert!(reached_cap, "growth {growth} never reached cap {cap}");
        }
    });
}

#[test]
fn warmup_never_over_requests_and_drains_exactly_n() {
    forall("warmup drains n", 80, |g| {
        let n = g.usize_in(1..=5000);
        let start = g.usize_in(1..=64);
        let growth = 1.0 + g.f64_in(0.0, 3.0);
        let cap = start + g.usize_in(0..=1000);
        let mut s = WarmupSchedule::new(start, growth, cap);
        let mut remaining = n;
        let mut total = 0usize;
        let mut block = 1usize;
        while remaining > 0 {
            let nc = s.next_n_c(block, remaining, block as f64);
            assert!(nc >= 1, "block {block}: empty grant");
            assert!(
                nc <= remaining,
                "block {block}: requested {nc} of {remaining} remaining"
            );
            assert!(nc <= cap, "block {block}: {nc} above cap {cap}");
            total += nc;
            remaining -= nc;
            block += 1;
            assert!(block <= n + 2, "schedule failed to make progress");
        }
        assert_eq!(total, n, "total scheduled samples must equal n");
    });
}

#[test]
fn deadline_aware_shrinks_toward_the_deadline_and_stays_legal() {
    forall("deadline-aware monotone", 80, |g| {
        let t_budget = g.f64_in(100.0, 5000.0);
        let n_o = g.f64_in(0.0, 50.0);
        let frac = g.f64_in(0.01, 1.0);
        let remaining = g.usize_in(1..=100_000);
        let mut s = DeadlineAwareSchedule {
            t_budget,
            n_o,
            aggressiveness: frac,
        };
        let mut prev = usize::MAX;
        for i in 0..=10usize {
            let t = t_budget * i as f64 / 10.0;
            let nc = s.next_n_c(i + 1, remaining, t);
            assert!(nc >= 1 && nc <= remaining, "t={t}: {nc}");
            assert!(
                nc <= prev,
                "t={t}: grew {prev} -> {nc} approaching the deadline"
            );
            prev = nc;
        }
        // past the budget it still emits a minimal legal block
        assert_eq!(s.next_n_c(99, remaining, t_budget + 10.0), 1);
    });
}

#[test]
fn deadline_aware_drains_exactly_n() {
    forall("deadline-aware drains n", 80, |g| {
        let n = g.usize_in(1..=5000);
        let t_budget = g.f64_in(10.0, 4000.0);
        let mut s = DeadlineAwareSchedule {
            t_budget,
            n_o: g.f64_in(0.0, 30.0),
            aggressiveness: g.f64_in(0.05, 1.0),
        };
        let mut remaining = n;
        let mut total = 0usize;
        let mut t = 0.0f64;
        let mut block = 1usize;
        while remaining > 0 {
            let nc = s.next_n_c(block, remaining, t);
            assert!(nc >= 1 && nc <= remaining, "block {block}: {nc}");
            total += nc;
            remaining -= nc;
            // advance past the deadline too: the schedule must stay
            // legal even when the budget has run out
            t += nc as f64 + 1.0;
            block += 1;
            assert!(block <= n + 2, "schedule failed to make progress");
        }
        assert_eq!(total, n, "total scheduled samples must equal n");
    });
}
