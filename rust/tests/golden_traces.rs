//! The scenario test harness for the new channel/workload axes:
//!
//! 1. **Golden traces** — deterministic event-stream snapshots of one
//!    representative run per axis combination, compared bit-exactly
//!    against committed fixtures in `rust/tests/golden/` (regen with
//!    `EDGEPIPE_REGEN_GOLDEN=1`; a missing fixture is bootstrapped and
//!    CI fails on the resulting dirty tree).
//! 2. **Metamorphic properties** — the fading channel pinned to its
//!    good state (`p_gb = 0`, i.e. p(bad) = 0) must be bit-identical to
//!    the erasure channel, across seeds AND against the erasure
//!    fixture; logistic on near-separable linear data must track the
//!    sign decisions of ridge on ±1 labels.
//! 3. **Statistical bound check** — the Corollary-1/Theorem-1 bound,
//!    made channel-aware via the expected slowdown, covers the measured
//!    Monte-Carlo optimality gap at 99% bootstrap confidence over the
//!    new scenario grid. All seeds fixed; no wall-clock anywhere, so
//!    the test cannot flake.

use edgepipe::bound::{
    check_recommendation, estimate_constants, estimate_logistic_constants,
    logistic_reference_loss, BoundParams, CheckConfig,
};
use edgepipe::coordinator::des::DesConfig;
use edgepipe::coordinator::run::RunResult;
use edgepipe::data::classify::{synth_logistic, LogitSpec};
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::data::Dataset;
use edgepipe::model::{ridge_solution, LogisticModel, Workload};
use edgepipe::sgd::{SgdEngine, StoreView};
use edgepipe::sweep::scenario::{
    ChannelSpec, PolicySpec, ScenarioRunner, ScenarioSpec,
};
use edgepipe::testkit::{assert_golden_trace, forall, render_trace};
use edgepipe::util::rng::Pcg32;

// ---------------------------------------------------------------- setup

fn trace_ds() -> Dataset {
    synth_calhousing(&SynthSpec { n: 240, ..Default::default() })
}

/// The fixed configuration every golden snapshot uses: 10 blocks of 24
/// samples + tail compute inside T = 420 — long enough to exercise
/// retransmissions and fades, short enough for a readable fixture.
fn trace_cfg() -> DesConfig {
    DesConfig {
        record_blocks: false,
        event_capacity: 1 << 14,
        ..DesConfig::paper(24, 6.0, 420.0, 13)
    }
}

/// The registry's bursty fading parameters.
fn bursty_fading() -> ChannelSpec {
    ChannelSpec::Fading {
        p_gb: 0.05,
        p_bg: 0.25,
        p_good: 0.0,
        p_bad: 0.6,
        rate_good: 1.0,
        rate_bad: 0.5,
    }
}

fn run_scenario(spec: &ScenarioSpec, ds: &Dataset, cfg: &DesConfig) -> RunResult {
    ScenarioRunner::new(spec.clone(), ds).run(cfg).unwrap()
}

fn snapshot(name: &str, spec: &ScenarioSpec) {
    let ds = trace_ds();
    let cfg = trace_cfg();
    let run = run_scenario(spec, &ds, &cfg);
    assert!(
        run.events.len() > 4,
        "{name}: trace too small to pin anything ({} events)",
        run.events.len()
    );
    assert_golden_trace(name, &render_trace(&spec.label(), &run.events));
}

// ---------------------------------------------------- 1. golden traces

#[test]
fn golden_paper_scenario() {
    snapshot("paper", &ScenarioSpec::paper());
}

#[test]
fn golden_erasure_scenario() {
    snapshot(
        "erasure_p010",
        &ScenarioSpec {
            channel: ChannelSpec::Erasure { p: 0.1 },
            ..ScenarioSpec::paper()
        },
    );
}

#[test]
fn golden_fading_scenario() {
    snapshot(
        "fading_bursty",
        &ScenarioSpec { channel: bursty_fading(), ..ScenarioSpec::paper() },
    );
}

#[test]
fn golden_logistic_scenario() {
    snapshot(
        "logistic_ideal",
        &ScenarioSpec {
            workload: Workload::Logistic,
            ..ScenarioSpec::paper()
        },
    );
}

#[test]
fn golden_fading_logistic_scenario() {
    snapshot(
        "fading_logistic",
        &ScenarioSpec {
            channel: bursty_fading(),
            workload: Workload::Logistic,
            ..ScenarioSpec::paper()
        },
    );
}

/// Acceptance criterion: the heterogeneous 3-device registry preset
/// (greedy scheduling, label-skewed shards, ideal/erasure/fading lanes)
/// has a committed golden fixture. The trace pins device selection
/// (`BlockSent { device }`), per-lane channel timing and the RNG stream
/// discipline of the multi-lane uplink in one diff-able artifact.
#[test]
fn golden_hetero3_scenario() {
    let spec = edgepipe::sweep::scenario::from_name("hetero3")
        .expect("hetero3 preset registered");
    snapshot("hetero3_greedy", &spec);
}

/// Acceptance criterion: the fault-injection layer's protocol semantics
/// are pinned bit-exactly on the `hetero3_dropout_control` preset — the
/// hetero3 fleet whose bursty lane dies permanently at t = 150 under
/// the hardened ARQ (timeout 4x, budget 2, evict after 2 consecutive
/// timeouts). The fixture freezes the timeout ladder
/// (`BlockTimedOut { resend }`), the eviction decision
/// (`DeviceEvicted { lost_samples }`), the re-scheduling of the two
/// surviving lanes and the controller's re-planned payloads in one
/// diff-able artifact.
#[test]
fn golden_hetero3_dropout_control_scenario() {
    let spec = edgepipe::sweep::scenario::from_name("hetero3_dropout_control")
        .expect("hetero3_dropout_control preset registered");
    let ds = trace_ds();
    let cfg = trace_cfg();
    let run = run_scenario(&spec, &ds, &cfg);
    // the scripted dropout must actually bite in this window
    assert!(run.timeouts > 0, "no ARQ timeouts fired");
    assert!(run.evictions >= 1, "the dropped lane was never evicted");
    assert!(run.samples_lost > 0, "eviction must shed the dead shard");
    assert_golden_trace(
        "hetero3_dropout_control",
        &render_trace(&spec.label(), &run.events),
    );
}

/// Acceptance criterion: the closed-loop controller's decision trace on
/// the `adaptive_fading` preset is pinned bit-exactly. The fixture
/// freezes the whole control loop — the GE belief trajectory (through
/// the payload sizes it produces), every re-planned `ñ_c`
/// (`BlockSent { payload }`), the channel timing and the RNG stream
/// discipline — so any change to the estimator update, the re-planner's
/// no-op rule or the plan constants shows up as a one-line diff.
#[test]
fn golden_adaptive_fading_control_scenario() {
    let spec = edgepipe::sweep::scenario::from_name("adaptive_fading")
        .expect("adaptive_fading preset registered");
    snapshot("adaptive_fading_control", &spec);
}

// ------------------------------------------- 2. metamorphic properties

/// Acceptance criterion: p(bad) = 0 fading at unit good rate + ridge ≡
/// the erasure scenario, bit for bit — asserted against the erasure
/// scenario's OWN committed fixture, so the two channels can never
/// drift apart without a visible fixture diff. (Named `golden_…` so
/// CI's fixture-scoped filter re-runs every fixture-touching test.)
#[test]
fn golden_fading_pinned_good_reproduces_the_erasure_fixture() {
    let ds = trace_ds();
    let cfg = trace_cfg();
    let spec = ScenarioSpec {
        channel: ChannelSpec::Fading {
            p_gb: 0.0, // p(bad) = 0: the chain never leaves good
            p_bg: 0.25,
            p_good: 0.1,
            p_bad: 0.6,
            rate_good: 1.0,
            rate_bad: 0.5,
        },
        ..ScenarioSpec::paper()
    };
    let run = run_scenario(&spec, &ds, &cfg);
    // the label differs (it names the fading spec), so render under the
    // erasure label the fixture was written with
    let erasure_label = ScenarioSpec {
        channel: ChannelSpec::Erasure { p: 0.1 },
        ..ScenarioSpec::paper()
    }
    .label();
    assert_golden_trace(
        "erasure_p010",
        &render_trace(&erasure_label, &run.events),
    );
}

#[test]
fn fading_with_p_bad_zero_is_bit_identical_to_erasure() {
    forall("fading p(bad)=0 == erasure", 6, |g| {
        let n = g.usize_in(80..=300);
        let p = g.f64_in(0.02, 0.35);
        let cfg = DesConfig {
            record_blocks: g.bool_with(0.5),
            event_capacity: 1 << 14,
            ..DesConfig::paper(
                g.usize_in(5..=n),
                g.f64_in(0.0, 20.0).round(),
                g.f64_in(50.0, 2.0 * n as f64).round(),
                g.u64_in(0..=1 << 40),
            )
        };
        let ds = synth_calhousing(&SynthSpec { n, ..Default::default() });
        let fading = ScenarioSpec {
            channel: ChannelSpec::Fading {
                p_gb: 0.0,
                p_bg: g.f64_in(0.0, 1.0),
                p_good: p,
                p_bad: g.f64_in(0.0, 0.9),
                rate_good: 1.0,
                rate_bad: g.f64_in(0.1, 2.0),
            },
            ..ScenarioSpec::paper()
        };
        let erasure = ScenarioSpec {
            channel: ChannelSpec::Erasure { p },
            ..ScenarioSpec::paper()
        };
        let a = run_scenario(&fading, &ds, &cfg);
        let b = run_scenario(&erasure, &ds, &cfg);
        assert_eq!(a.events, b.events, "event streams diverged");
        assert_eq!(a.final_w, b.final_w, "final iterates diverged");
        assert_eq!(a.curve, b.curve, "loss curves diverged");
        assert_eq!(a.retransmissions, b.retransmissions);
        assert_eq!(a.blocks_sent, b.blocks_sent);
        assert_eq!(a.samples_delivered, b.samples_delivered);
        assert_eq!(a.case, b.case);
    });
}

/// On near-separable linear data, logistic SGD and the exact ridge
/// solution on ±1 labels must make (almost) the same sign decisions —
/// the classification analogue of "both workloads learn the same
/// separator".
#[test]
fn logistic_tracks_ridge_sign_decisions_on_near_separable_data() {
    let ds = synth_logistic(&LogitSpec {
        n: 1500,
        margin_noise: 0.05,
        flip_prob: 0.01,
        ..Default::default()
    });
    // logistic: plain seeded SGD on the {0,1} labels
    let model = LogisticModel::new(ds.d, 1e-3, ds.n);
    let engine = SgdEngine::new(0.1);
    let store = StoreView::new(&ds.x, &ds.y, ds.d);
    let mut w_log = vec![0.0f64; ds.d];
    let mut rng = Pcg32::new(4242, 3);
    engine.run_updates(&model, &mut w_log, store, 30_000, &mut rng);

    // ridge: exact solution on the ±1-mapped labels
    let pm1 = Dataset::new(
        ds.x.clone(),
        ds.y.iter().map(|&y| 2.0 * y - 1.0).collect(),
        ds.n,
        ds.d,
    );
    let w_ridge = ridge_solution(&pm1, 1e-3).unwrap();

    let mut agree = 0usize;
    let mut log_correct = 0usize;
    for i in 0..ds.n {
        let row = ds.row(i);
        let z_log: f64 = (0..ds.d).map(|j| row[j] as f64 * w_log[j]).sum();
        let z_rdg: f64 =
            (0..ds.d).map(|j| row[j] as f64 * w_ridge[j]).sum();
        if (z_log > 0.0) == (z_rdg > 0.0) {
            agree += 1;
        }
        if (z_log > 0.0) == (ds.y[i] == 1.0) {
            log_correct += 1;
        }
    }
    let agreement = agree as f64 / ds.n as f64;
    let accuracy = log_correct as f64 / ds.n as f64;
    assert!(
        agreement >= 0.93,
        "logistic/ridge sign agreement {agreement} < 0.93"
    );
    assert!(accuracy >= 0.90, "logistic accuracy {accuracy} < 0.90");
    // sanity: the logistic loss at the trained iterate beats w = 0
    let reg = model.reg;
    let trained = Workload::Logistic.full_loss(&ds, &w_log, reg);
    let at_zero =
        Workload::Logistic.full_loss(&ds, &vec![0.0; ds.d], reg);
    assert!(trained < 0.5 * at_zero, "{trained} vs ln2 {at_zero}");
}

// ------------------------------------- 3. statistical bound validation

fn bound_base(ds: &Dataset, seed: u64) -> DesConfig {
    DesConfig {
        loss_every: 0,
        record_blocks: false,
        event_capacity: 0,
        ..DesConfig::paper(1, 10.0, 1.5 * ds.n as f64, seed)
    }
}

fn check_cfg() -> CheckConfig {
    CheckConfig {
        seeds: 16,
        threads: 0,
        resamples: 600,
        confidence: 0.99,
        boot_seed: 1906,
    }
}

/// Acceptance criterion: the Theorem-1/Corollary-1 bound holds over the
/// new scenario grid at 99% bootstrap confidence, fully seeded.
#[test]
fn bound_holds_at_99_bootstrap_confidence_over_the_new_axes() {
    let ds = synth_calhousing(&SynthSpec { n: 1500, ..Default::default() });
    let base = bound_base(&ds, 1906);
    let k = estimate_constants(&ds, base.lambda, base.alpha, 2000, base.seed);
    let params = BoundParams::from_constants(base.alpha, &k);
    let w_star = ridge_solution(&ds, base.lambda).unwrap();
    let loss_star = ds.ridge_loss(&w_star, base.lambda / ds.n as f64);

    let paper = ScenarioSpec::paper();
    let grid = vec![
        paper.clone(),
        ScenarioSpec {
            channel: ChannelSpec::Erasure { p: 0.1 },
            ..paper.clone()
        },
        ScenarioSpec { channel: bursty_fading(), ..paper.clone() },
        ScenarioSpec {
            channel: ChannelSpec::Fading {
                p_gb: 0.2,
                p_bg: 0.2,
                p_good: 0.05,
                p_bad: 0.4,
                rate_good: 1.0,
                rate_bad: 1.0,
            },
            ..paper
        },
    ];
    for spec in &grid {
        let out = check_recommendation(
            &ds,
            &base,
            spec,
            &params,
            loss_star,
            &check_cfg(),
        );
        assert!(out.n_c >= 1 && out.n_c <= ds.n, "{}", out.label);
        assert!(out.slowdown >= 1.0, "{}: slowdown {}", out.label, out.slowdown);
        assert!(
            out.gaps.iter().all(|g| *g >= -1e-9),
            "{}: negative gap against the exact ridge optimum",
            out.label
        );
        assert!(
            out.holds,
            "{}: measured gap (99% upper {:.6}) exceeds the bound {:.6}",
            out.label, out.gap_upper, out.bound
        );
    }
}

#[test]
fn bound_holds_for_the_logistic_workload_at_99_confidence() {
    let ds = synth_calhousing(&SynthSpec { n: 1200, ..Default::default() });
    let base = bound_base(&ds, 777);
    for channel in [ChannelSpec::Ideal, bursty_fading()] {
        let spec = ScenarioSpec {
            channel,
            workload: Workload::Logistic,
            ..ScenarioSpec::paper()
        };
        // constants + reference loss on the exact label view the
        // scenario trains (median-binarized)
        let runner = ScenarioRunner::new(spec.clone(), &ds);
        let view = runner.data();
        let k = estimate_logistic_constants(
            view, base.lambda, base.alpha, 2000, base.seed,
        );
        let params = BoundParams::from_constants(base.alpha, &k);
        // shared long-run SGD reference for L(w*); the reference
        // upper-bounds the optimum, so this validates the bound
        // against the measurable part of the gap (the ridge test uses
        // the exact optimum)
        let loss_star =
            logistic_reference_loss(view, base.lambda, base.alpha, base.seed);

        let out = check_recommendation(
            &ds,
            &base,
            &spec,
            &params,
            loss_star,
            &check_cfg(),
        );
        assert!(
            out.holds,
            "{}: logistic gap (99% upper {:.6}) exceeds the bound {:.6}",
            out.label, out.gap_upper, out.bound
        );
        assert!(out.bound.is_finite() && out.bound > 0.0);
    }
}

/// Acceptance criterion: on the `adaptive_fading` preset, the
/// closed-loop controller beats the best fixed `ñ_c` — the channel-aware
/// Corollary-1 recommendation, i.e. the strongest schedule the paper's
/// static optimizer can produce for this channel — in expected final
/// loss over seeded Monte-Carlo, and does not worsen the deadline-outage
/// rate. The margin is conservative (strict improvement of the mean, no
/// effect-size requirement): the controller's edge is structural
/// (re-planning with the true remaining budget and the estimated
/// channel state), not tuned. Fully seeded; if the first real toolchain
/// run ever finds the margin too tight, widen per the ROADMAP note
/// before loosening anything else.
#[test]
fn closed_loop_control_beats_the_fixed_recommendation_under_fading() {
    use edgepipe::bound::replan::ControlPlan;
    use edgepipe::sweep::control::control_comparison;
    use edgepipe::sweep::scenario::EstimatorSpec;

    let ds = synth_calhousing(&SynthSpec { n: 1500, ..Default::default() });
    let base = DesConfig {
        loss_every: 0,
        record_blocks: false,
        event_capacity: 0,
        ..DesConfig::paper(1, 20.0, 1.5 * 1500.0, 2024)
    };
    let preset = edgepipe::sweep::scenario::from_name("adaptive_fading")
        .expect("adaptive_fading preset registered");
    let rows = control_comparison(
        &ds,
        &base,
        std::slice::from_ref(&preset.channel),
        &[
            PolicySpec::Fixed { n_c: 0 },
            PolicySpec::Control { est: EstimatorSpec::Ge, replan_every: 1 },
        ],
        48,
        0,
    );
    assert_eq!(rows.len(), 2);
    let (fixed, control) = (&rows[0], &rows[1]);
    assert_eq!(fixed.policy, "fixed");
    assert_eq!(control.policy, "control");
    // both competed from the same channel-aware recommendation
    let plan = ControlPlan::compute(&ds, &base, preset.expected_slowdown());
    assert_eq!(fixed.n_c, plan.n_c0);
    assert_eq!(control.n_c, plan.n_c0);
    assert!(
        control.loss.mean < fixed.loss.mean,
        "closed-loop control ({:.6} ± {:.6}) must beat the fixed \
         recommendation ñ_c={} ({:.6} ± {:.6}) on {}",
        control.loss.mean,
        control.loss.sem,
        plan.n_c0,
        fixed.loss.mean,
        fixed.loss.sem,
        preset.channel.label()
    );
    assert!(
        control.outage_rate <= fixed.outage_rate,
        "control must not worsen the deadline-outage rate: {} vs {}",
        control.outage_rate,
        fixed.outage_rate
    );
}

// --------------------------------------------- axis sanity cross-checks

/// The fading channel must actually hurt: at equal configuration, the
/// bursty link delivers no more samples and trains no better (in
/// expectation over seeds) than the ideal link.
#[test]
fn fades_never_help_delivery() {
    let ds = trace_ds();
    let cfg = trace_cfg();
    let mut ideal_delivered = 0usize;
    let mut fading_delivered = 0usize;
    for s in 0..6u64 {
        let per_seed = DesConfig { seed: 100 + s, ..cfg.clone() };
        let a = run_scenario(&ScenarioSpec::paper(), &ds, &per_seed);
        let b = run_scenario(
            &ScenarioSpec { channel: bursty_fading(), ..ScenarioSpec::paper() },
            &ds,
            &per_seed,
        );
        ideal_delivered += a.samples_delivered;
        fading_delivered += b.samples_delivered;
        assert!(
            b.retransmissions >= a.retransmissions,
            "seed {s}: fading produced fewer retransmissions than ideal"
        );
    }
    assert!(
        fading_delivered <= ideal_delivered,
        "fading delivered more ({fading_delivered}) than ideal \
         ({ideal_delivered})"
    );
}

/// The workspace-reuse purity contract extends to the new axes: one
/// workspace threaded across fading/logistic runs stays bit-identical
/// to fresh runs (mirrors `scenario_parity.rs` for the new specs).
#[test]
fn new_axes_keep_workspace_reuse_pure() {
    use edgepipe::coordinator::RunWorkspace;
    let ds = trace_ds();
    let cfg = trace_cfg();
    let paper = ScenarioSpec::paper();
    let specs = vec![
        ScenarioSpec { channel: bursty_fading(), ..paper.clone() },
        ScenarioSpec { workload: Workload::Logistic, ..paper.clone() },
        ScenarioSpec {
            channel: bursty_fading(),
            workload: Workload::Logistic,
            ..paper
        },
    ];
    let mut ws = RunWorkspace::new();
    for spec in specs {
        let runner = ScenarioRunner::new(spec.clone(), &ds);
        for s in 0..2u64 {
            let per_seed =
                DesConfig { seed: cfg.seed.wrapping_add(s), ..cfg.clone() };
            let fresh = runner.run(&per_seed).unwrap();
            let stats = runner.run_with(&mut ws, &per_seed).unwrap();
            let what = format!("{} seed {s}", spec.label());
            assert_eq!(stats.final_loss, fresh.final_loss, "{what}: loss");
            assert_eq!(ws.final_w(), &fresh.final_w[..], "{what}: w");
            assert_eq!(ws.events(), &fresh.events[..], "{what}: events");
            assert_eq!(stats.updates, fresh.updates, "{what}: updates");
        }
    }
}
