//! Property tests on the Corollary-1 bound and the block-size optimizer.

use edgepipe::bound::corollary1::{corollary1_bound, BoundParams};
use edgepipe::bound::optimizer::{optimize_block_size, scan_bound};
use edgepipe::testkit::forall;

fn rand_params(g: &mut edgepipe::testkit::Gen) -> BoundParams {
    let big_l = g.f64_log(0.1, 10.0);
    let c = g.f64_log(0.001, big_l.min(1.0));
    let m_g = 1.0;
    // stepsize condition: alpha <= 2 / (L * M_G)
    let alpha = g.f64_log(1e-6, (2.0 / (big_l * m_g)).min(0.1));
    BoundParams {
        alpha,
        big_l,
        c,
        m: g.f64_in(0.0, 4.0),
        m_g,
        d_diam: g.f64_log(0.1, 20.0),
    }
}

#[test]
fn bound_is_finite_positive_and_above_the_bias_floor_limit() {
    forall("bound sane", 120, |g| {
        let p = rand_params(g);
        let n = g.usize_in(100..=30000);
        let t = g.f64_in(10.0, 4.0 * n as f64);
        let n_c = g.usize_in(1..=n) as f64;
        let n_o = g.f64_in(0.0, 2000.0);
        let v = corollary1_bound(&p, n, t, n_c, n_o, 1.0, false);
        assert!(v.is_finite(), "bound not finite");
        assert!(v > 0.0, "bound not positive: {v}");
        // the bound can never beat the asymptotic bias floor scaled by
        // the delivered fraction heuristic — weak but universal check:
        // it must be at least min(A, cap) * small constant
        let floor = p.bias_floor().min(p.initial_error_cap());
        assert!(v >= 0.01 * floor, "v={v} below plausibility floor");
    });
}

#[test]
fn closed_form_equals_naive_everywhere() {
    forall("closed vs naive", 150, |g| {
        let p = rand_params(g);
        let n = g.usize_in(100..=30000);
        let t = g.f64_in(10.0, 4.0 * n as f64);
        let n_c = g.usize_in(1..=n) as f64;
        let n_o = g.f64_in(0.0, 500.0);
        let fast = corollary1_bound(&p, n, t, n_c, n_o, 1.0, false);
        let slow = corollary1_bound(&p, n, t, n_c, n_o, 1.0, true);
        let rel = (fast - slow).abs() / slow.abs().max(1e-300);
        assert!(rel < 1e-8, "fast {fast} vs naive {slow}");
    });
}

#[test]
fn optimizer_is_a_true_argmin() {
    forall("optimizer argmin", 10, |g| {
        let p = rand_params(g);
        let n = g.usize_in(500..=5000);
        let t = g.f64_in(0.5 * n as f64, 3.0 * n as f64);
        let n_o = g.f64_in(0.0, 300.0);
        let opt = optimize_block_size(&p, n, t, n_o, 1.0);
        // beat every point of a random probe grid
        for _ in 0..50 {
            let nc = g.usize_in(1..=n);
            let v = corollary1_bound(&p, n, t, nc as f64, n_o, 1.0, false);
            assert!(
                opt.value <= v + 1e-12,
                "optimizer {} beaten at n_c={nc}: {v}",
                opt.value
            );
        }
        assert!(opt.n_c >= 1 && opt.n_c <= n);
    });
}

#[test]
fn scan_is_consistent_with_direct_eval() {
    forall("scan consistency", 20, |g| {
        let p = rand_params(g);
        let n = 2000;
        let t = 3000.0;
        let n_o = g.f64_in(0.0, 100.0);
        let n_cs: Vec<usize> =
            (0..10).map(|_| g.usize_in(1..=n)).collect();
        let rows = scan_bound(&p, n, t, n_o, 1.0, &n_cs);
        for (nc, v) in rows {
            let direct =
                corollary1_bound(&p, n, t, nc as f64, n_o, 1.0, false);
            assert_eq!(v, direct);
        }
    });
}

#[test]
fn gamma_positive_under_stepsize_condition() {
    forall("gamma positive", 200, |g| {
        let p = rand_params(g);
        assert!(p.stepsize_ok());
        assert!(p.gamma() > 0.0, "gamma {} <= 0", p.gamma());
        let q = p.contraction();
        assert!(q < 1.0, "no contraction: q={q}");
        assert!(q > -1.0);
        assert!(p.bias_floor() >= 0.0);
    });
}

#[test]
fn more_time_with_same_blocks_never_hurts_case_b() {
    // Within case (b), increasing T only increases n_l, so the bound
    // decreases — PROVIDED the initial-error cap LD²/2 exceeds the
    // asymptotic floor A (the practically relevant regime; when cap < A
    // the series term is negative and the bound legitimately climbs
    // toward A from below as T grows).
    forall("case b monotone in T", 60, |g| {
        let p = rand_params(g);
        if p.initial_error_cap() < p.bias_floor() {
            return; // degenerate regime, monotonicity not implied
        }
        let n = 2000usize;
        let n_c = g.usize_in(100..=n) as f64;
        let n_o = g.f64_in(0.0, 50.0);
        let b_d = n as f64 / n_c;
        let full = b_d * (n_c + n_o);
        let t1 = full + g.f64_in(1.0, 500.0);
        let t2 = t1 + g.f64_in(1.0, 5000.0);
        let v1 = corollary1_bound(&p, n, t1, n_c, n_o, 1.0, false);
        let v2 = corollary1_bound(&p, n, t2, n_c, n_o, 1.0, false);
        assert!(v2 <= v1 + 1e-12, "t {t1}->{t2}: bound {v1}->{v2}");
    });
}
