//! Native-f64 vs PJRT-f32 backend parity over full protocol runs, and
//! the bound/experiment integration checks that need both backends.
//!
//! These tests skip (with a note) when `make artifacts` has not run.

use edgepipe::channel::IdealChannel;
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::data::split::train_split;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::RidgeModel;
use edgepipe::runtime::{find_artifact_dir, PjrtExecutor, RuntimeSession};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = find_artifact_dir();
    if dir.is_none() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    dir
}

#[test]
fn full_protocol_run_matches_native_trajectory() {
    let Some(dir) = artifacts() else { return };
    let raw = synth_calhousing(&SynthSpec { n: 2000, ..Default::default() });
    let (train, _) = train_split(&raw, 0.9, 42);
    let cfg = DesConfig {
        record_blocks: false,
        loss_every: 500,
        ..DesConfig::paper(150, 20.0, 2500.0, 11)
    };

    let mut native = NativeExecutor::new(
        RidgeModel::new(train.d, cfg.lambda, train.n),
        cfg.alpha,
    );
    let res_native =
        run_des(&train, &cfg, &mut IdealChannel, &mut native).unwrap();

    let session = RuntimeSession::open(&dir).unwrap();
    let mut pjrt =
        PjrtExecutor::new(session, cfg.alpha, cfg.lambda, train.n).unwrap();
    let res_pjrt =
        run_des(&train, &cfg, &mut IdealChannel, &mut pjrt).unwrap();

    // identical protocol accounting
    assert_eq!(res_native.updates, res_pjrt.updates);
    assert_eq!(res_native.samples_delivered, res_pjrt.samples_delivered);
    assert_eq!(res_native.blocks_sent, res_pjrt.blocks_sent);
    // trajectory agreement to f32 tolerance
    for (a, b) in res_native.final_w.iter().zip(&res_pjrt.final_w) {
        assert!((a - b).abs() < 1e-3, "w diverged: {a} vs {b}");
    }
    let rel =
        (res_native.final_loss - res_pjrt.final_loss).abs() / res_native.final_loss;
    assert!(rel < 1e-3, "final loss diverged: rel {rel}");
    // loss curves sampled at the same instants
    assert_eq!(res_native.curve.len(), res_pjrt.curve.len());
    for ((t1, l1), (t2, l2)) in res_native.curve.iter().zip(&res_pjrt.curve)
    {
        assert_eq!(t1, t2);
        assert!((l1 - l2).abs() / l1 < 1e-3, "curve diverged at t={t1}");
    }
}

#[test]
fn threaded_pipeline_works_with_pjrt_backend() {
    // The real two-thread pipeline driving the PJRT executor (the
    // executor stays on the edge thread; packets stream from the device
    // thread): must equal the DES with the same backend exactly, since
    // both consume identical RNG streams and the same artifact.
    let Some(dir) = artifacts() else { return };
    use edgepipe::coordinator::pipeline::run_pipelined;
    let raw = synth_calhousing(&SynthSpec { n: 1200, ..Default::default() });
    let (train, _) = train_split(&raw, 0.9, 42);
    let cfg = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(120, 15.0, 1600.0, 5)
    };
    let mk = || {
        let session = RuntimeSession::open(&dir).unwrap();
        PjrtExecutor::new(session, cfg.alpha, cfg.lambda, train.n).unwrap()
    };
    let des =
        run_des(&train, &cfg, &mut IdealChannel, &mut mk()).unwrap();
    let pipe =
        run_pipelined(&train, &cfg, &mut IdealChannel, &mut mk()).unwrap();
    assert_eq!(des.final_w, pipe.final_w, "PJRT pipeline != PJRT DES");
    assert_eq!(des.updates, pipe.updates);
    assert_eq!(des.backend, "pjrt");
    assert_eq!(pipe.backend, "pjrt");
}

#[test]
fn pjrt_loss_evaluator_tracks_growing_store() {
    let Some(dir) = artifacts() else { return };
    use edgepipe::runtime::PjrtLossEvaluator;
    let ds = synth_calhousing(&SynthSpec { n: 900, ..Default::default() });
    let session = RuntimeSession::open(&dir).unwrap();
    let mut eval = PjrtLossEvaluator::new(session, 0.05, ds.n).unwrap();
    let w = vec![0.2f64; ds.d];
    // grow in 3 chunks, cross-check against native subset loss each time
    for chunk in 0..3usize {
        let lo = chunk * 300;
        let hi = lo + 300;
        eval.append_rows(&ds.x[lo * ds.d..hi * ds.d], &ds.y[lo..hi])
            .unwrap();
        let got = eval.loss(&w).unwrap();
        let subset = ds.subset(&(0..hi).collect::<Vec<_>>());
        let want = subset.ridge_loss(&w, 0.05 / ds.n as f64);
        assert!(
            (got - want).abs() / want < 1e-3,
            "chunk {chunk}: {got} vs {want}"
        );
    }
}

#[test]
fn pjrt_grad_descends_the_real_loss() {
    let Some(dir) = artifacts() else { return };
    use edgepipe::runtime::PjrtLossEvaluator;
    let ds = synth_calhousing(&SynthSpec { n: 1200, ..Default::default() });
    let session = RuntimeSession::open(&dir).unwrap();
    let mut eval = PjrtLossEvaluator::new(session, 0.05, ds.n).unwrap();
    eval.append_rows(&ds.x, &ds.y).unwrap();
    let mut w = vec![0.5f64; ds.d];
    let mut prev = eval.loss(&w).unwrap();
    for _ in 0..20 {
        let g = eval.grad(&w).unwrap();
        for j in 0..ds.d {
            w[j] -= 0.05 * g[j];
        }
        let cur = eval.loss(&w).unwrap();
        assert!(cur <= prev * 1.001, "batch GD must descend: {prev}->{cur}");
        prev = cur;
    }
}
