//! Every protocol variant, run through the unified Scheduler, must be
//! bit-identical to the seed DES semantics:
//!
//! * the paper scenario (single device, fixed `n_c`, ideal channel)
//!   equals `run_des` exactly — including the event stream;
//! * multi-device with `k = 1` equals `run_des` exactly (same seeds,
//!   same `final_loss`);
//! * the baseline policies (`sequential`, `allfirst`) and adaptive
//!   schedules run through `ScenarioSpec` equal their dedicated entry
//!   points exactly;
//! * the heterogeneous multi-lane uplink collapses correctly: `k = 1`
//!   equals `run_des` under EVERY device scheduler, identical lanes
//!   make greedy ≡ round-robin, and a homogeneous hetero uplink on a
//!   stateless channel equals the legacy shared-channel `Devices(k)`;
//! * `shard_dataset` shards are disjoint and cover the dataset;
//! * the threaded shard layer is an execution strategy, not a
//!   semantics: `ShardedSource` at EVERY shard count (1, 2 and 4 are
//!   pinned, inline and pooled alike) produces the identical
//!   `RunResult` — event stream, weights and the fault counters
//!   `timeouts`/`evictions` included — as the pre-PR single-threaded
//!   `ScheduledSource`, with the fault machinery dormant, armed-but-
//!   dormant, and actively evicting.

use edgepipe::baselines::{sequential, transmit_all_first};
use edgepipe::bound::replan::ControlPlan;
use edgepipe::channel::{
    Channel, ErasureChannel, FaultPlan, FaultSpec, FaultTolerance,
    IdealChannel,
};
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::coordinator::run::RunResult;
use edgepipe::coordinator::{
    run_schedule, FixedPolicy, GreedyScheduler, OverlapMode, RunWorkspace,
    ScheduledSource, ShardedSource,
};
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::data::Dataset;
use edgepipe::extensions::adaptive::{run_scheduled, WarmupSchedule};
use edgepipe::extensions::multi_device::{run_multi_device, shard_dataset};
use edgepipe::model::RidgeModel;
use edgepipe::sweep::scenario::{
    ChannelSpec, EstimatorSpec, HeteroSpec, PolicySpec, ScenarioRunner,
    ScenarioSpec, SchedulerSpec, TrafficSpec,
};
use edgepipe::testkit::forall;

fn mk_exec(ds: &Dataset, cfg: &DesConfig) -> NativeExecutor {
    NativeExecutor::new(RidgeModel::new(ds.d, cfg.lambda, ds.n), cfg.alpha)
}

/// Full bit-exact RunResult comparison.
fn assert_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.final_w, b.final_w, "{what}: final_w diverged");
    assert_eq!(a.final_loss, b.final_loss, "{what}: final_loss diverged");
    assert_eq!(a.curve, b.curve, "{what}: loss curve diverged");
    assert_eq!(a.updates, b.updates, "{what}: update count diverged");
    assert_eq!(a.blocks_sent, b.blocks_sent, "{what}: blocks_sent");
    assert_eq!(
        a.blocks_delivered, b.blocks_delivered,
        "{what}: blocks_delivered"
    );
    assert_eq!(
        a.samples_delivered, b.samples_delivered,
        "{what}: samples_delivered"
    );
    assert_eq!(
        a.retransmissions, b.retransmissions,
        "{what}: retransmissions"
    );
    assert_eq!(a.case, b.case, "{what}: timeline case");
    assert_eq!(a.events, b.events, "{what}: event stream diverged");
    assert_eq!(a.snapshots.len(), b.snapshots.len(), "{what}: snapshots");
    for (sa, sb) in a.snapshots.iter().zip(&b.snapshots) {
        assert_eq!(sa.w_end, sb.w_end, "{what}: snapshot w_end");
        assert_eq!(sa.arrived_at, sb.arrived_at, "{what}: snapshot time");
    }
}

#[test]
fn paper_scenario_is_bit_identical_to_run_des() {
    forall("scenario paper == des", 8, |g| {
        let n = g.usize_in(50..=500);
        let cfg = DesConfig {
            record_blocks: g.bool_with(0.5),
            collect_snapshots: g.bool_with(0.3),
            event_capacity: 4096,
            ..DesConfig::paper(
                g.usize_in(1..=n),
                g.f64_in(0.0, 40.0).round(),
                g.f64_in(20.0, 3.0 * n as f64).round(),
                g.u64_in(0..=1 << 40),
            )
        };
        let ds = synth_calhousing(&SynthSpec { n, ..Default::default() });
        let des = run_des(&ds, &cfg, &mut IdealChannel, &mut mk_exec(&ds, &cfg))
            .unwrap();
        let runner = ScenarioRunner::new(ScenarioSpec::paper(), &ds);
        let uni = runner.run(&cfg).unwrap();
        assert_identical(&des, &uni, "paper scenario");
    });
}

#[test]
fn multi_device_k1_is_bit_identical_to_run_des() {
    forall("multi k=1 == des", 8, |g| {
        let n = g.usize_in(60..=400);
        let cfg = DesConfig {
            event_capacity: 4096,
            ..DesConfig::paper(
                g.usize_in(1..=n / 2),
                g.f64_in(0.0, 20.0).round(),
                g.f64_in(50.0, 2.5 * n as f64).round(),
                g.u64_in(0..=1 << 40),
            )
        };
        let ds = synth_calhousing(&SynthSpec { n, ..Default::default() });
        let des = run_des(&ds, &cfg, &mut IdealChannel, &mut mk_exec(&ds, &cfg))
            .unwrap();
        let shards = shard_dataset(&ds, 1);
        let multi = run_multi_device(
            &ds,
            &shards,
            &cfg,
            &mut IdealChannel,
            &mut mk_exec(&ds, &cfg),
        )
        .unwrap();
        assert_identical(&des, &multi, "multi-device k=1");
    });
}

#[test]
fn multi_device_scenario_matches_run_multi_device() {
    let ds = synth_calhousing(&SynthSpec { n: 480, ..Default::default() });
    let cfg = DesConfig {
        alpha: 1e-3,
        event_capacity: 4096,
        ..DesConfig::paper(40, 10.0, 1200.0, 23)
    };
    let shards = shard_dataset(&ds, 4);
    let direct = run_multi_device(
        &ds,
        &shards,
        &cfg,
        &mut IdealChannel,
        &mut mk_exec(&ds, &cfg),
    )
    .unwrap();
    let spec = ScenarioSpec {
        traffic: TrafficSpec::Devices(4),
        ..ScenarioSpec::paper()
    };
    let via_spec = ScenarioRunner::new(spec, &ds).run(&cfg).unwrap();
    assert_identical(&direct, &via_spec, "multi-device k=4 via spec");
}

/// Acceptance criterion: `k = 1` heterogeneous traffic is bit-identical
/// to `run_des` for EVERY device scheduler — a single lane leaves no
/// scheduling freedom, and the lane's sample stream / channel stream
/// must match the single-device discipline draw for draw.
#[test]
fn hetero_k1_is_bit_identical_to_run_des_for_every_scheduler() {
    forall("hetero k=1 == des", 6, |g| {
        let n = g.usize_in(60..=300);
        let p = g.f64_in(0.05, 0.3);
        let cfg = DesConfig {
            record_blocks: g.bool_with(0.5),
            event_capacity: 4096,
            ..DesConfig::paper(
                g.usize_in(1..=n / 2),
                g.f64_in(0.0, 20.0).round(),
                g.f64_in(50.0, 2.5 * n as f64).round(),
                g.u64_in(0..=1 << 40),
            )
        };
        let ds = synth_calhousing(&SynthSpec { n, ..Default::default() });
        let mut channel: Box<dyn Channel> = Box::new(ErasureChannel::new(p));
        let des =
            run_des(&ds, &cfg, channel.as_mut(), &mut mk_exec(&ds, &cfg))
                .unwrap();
        for sched in [
            SchedulerSpec::RoundRobin,
            SchedulerSpec::Greedy,
            SchedulerSpec::PropFair,
        ] {
            let spec = ScenarioSpec {
                channel: ChannelSpec::Erasure { p },
                traffic: TrafficSpec::Hetero(
                    HeteroSpec::new(1, sched, 0.0, Vec::new()).unwrap(),
                ),
                ..ScenarioSpec::paper()
            };
            let hetero = ScenarioRunner::new(spec, &ds).run(&cfg).unwrap();
            assert_identical(
                &des,
                &hetero,
                &format!("hetero k=1, sched={}", sched.label()),
            );
        }
    });
}

/// Acceptance criterion: identical lanes leave greedy no signal, so its
/// rotating tie-break must reproduce round-robin exactly — across
/// channels, including a stateful per-lane fading link.
#[test]
fn homogeneous_greedy_is_bit_identical_to_round_robin() {
    let ds = synth_calhousing(&SynthSpec { n: 420, ..Default::default() });
    let cfg = DesConfig {
        alpha: 1e-3,
        event_capacity: 4096,
        ..DesConfig::paper(30, 8.0, 1400.0, 29)
    };
    for channel in [
        ChannelSpec::Ideal,
        ChannelSpec::Erasure { p: 0.2 },
        ChannelSpec::Fading {
            p_gb: 0.05,
            p_bg: 0.25,
            p_good: 0.0,
            p_bad: 0.6,
            rate_good: 1.0,
            rate_bad: 0.5,
        },
    ] {
        let mk = |sched: SchedulerSpec| ScenarioSpec {
            channel: channel.clone(),
            traffic: TrafficSpec::Hetero(
                HeteroSpec::new(4, sched, 0.3, Vec::new()).unwrap(),
            ),
            ..ScenarioSpec::paper()
        };
        let rr = ScenarioRunner::new(mk(SchedulerSpec::RoundRobin), &ds)
            .run(&cfg)
            .unwrap();
        let greedy = ScenarioRunner::new(mk(SchedulerSpec::Greedy), &ds)
            .run(&cfg)
            .unwrap();
        assert_identical(
            &rr,
            &greedy,
            &format!("homogeneous greedy vs rr on {}", channel.label()),
        );
    }
}

/// A homogeneous heterogeneous-uplink (all lanes the same STATELESS
/// channel, round-robin, zero skew) equals the legacy shared-channel
/// `Devices(k)` bit for bit: same shard layout, same per-lane sample
/// streams, same single channel-noise stream.
#[test]
fn homogeneous_hetero_round_robin_matches_legacy_devices() {
    let ds = synth_calhousing(&SynthSpec { n: 360, ..Default::default() });
    let cfg = DesConfig {
        alpha: 1e-3,
        event_capacity: 4096,
        ..DesConfig::paper(24, 6.0, 1200.0, 41)
    };
    for channel in
        [ChannelSpec::Ideal, ChannelSpec::Erasure { p: 0.15 }]
    {
        let legacy = ScenarioRunner::new(
            ScenarioSpec {
                channel: channel.clone(),
                traffic: TrafficSpec::Devices(3),
                ..ScenarioSpec::paper()
            },
            &ds,
        )
        .run(&cfg)
        .unwrap();
        let hetero = ScenarioRunner::new(
            ScenarioSpec {
                channel: channel.clone(),
                traffic: TrafficSpec::Hetero(
                    HeteroSpec::new(
                        3,
                        SchedulerSpec::RoundRobin,
                        0.0,
                        Vec::new(),
                    )
                    .unwrap(),
                ),
                ..ScenarioSpec::paper()
            },
            &ds,
        )
        .run(&cfg)
        .unwrap();
        assert_identical(
            &legacy,
            &hetero,
            &format!("hetero rr vs Devices(3) on {}", channel.label()),
        );
    }
}

/// Heterogeneous lanes actually route: with one lane rate-limited far
/// below the others, the greedy scheduler drains the fast lanes first
/// and the slow device transmits last.
#[test]
fn greedy_prefers_fast_lanes_end_to_end() {
    use edgepipe::coordinator::EventKind;
    let ds = synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
    let cfg = DesConfig {
        record_blocks: false,
        event_capacity: 4096,
        ..DesConfig::paper(24, 6.0, 5000.0, 3)
    };
    let spec = ScenarioSpec {
        traffic: TrafficSpec::Hetero(
            HeteroSpec::new(
                3,
                SchedulerSpec::Greedy,
                0.0,
                vec![
                    ChannelSpec::Rate { rate: 0.25, p: 0.0 },
                    ChannelSpec::Ideal,
                    ChannelSpec::Ideal,
                ],
            )
            .unwrap(),
        ),
        ..ScenarioSpec::paper()
    };
    let run = ScenarioRunner::new(spec, &ds).run(&cfg).unwrap();
    let devices: Vec<usize> = run
        .events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::BlockSent { device, .. } => Some(device),
            _ => None,
        })
        .collect();
    assert_eq!(run.samples_delivered, ds.n, "budget covers everything");
    // lanes 1 and 2 (fast) drain completely before lane 0 starts
    let first_slow =
        devices.iter().position(|&d| d == 0).expect("lane 0 transmits");
    let last_fast = devices
        .iter()
        .rposition(|&d| d != 0)
        .expect("fast lanes transmit");
    assert!(
        last_fast < first_slow,
        "greedy interleaved the slow lane: {devices:?}"
    );
}

/// Acceptance criterion: on a static channel with exact estimator
/// constants, the closed-loop `ControlPolicy` is bit-identical to
/// `FixedPolicy(ñ_c)` at the channel-aware recommendation — the
/// Gilbert–Elliott belief of a pinned-good chain never moves, so
/// re-planning with unchanged inputs is a no-op and the controller
/// degenerates to the paper's fixed schedule, event stream and all.
#[test]
fn control_policy_is_bit_identical_to_fixed_on_static_channels() {
    let ds = synth_calhousing(&SynthSpec { n: 420, ..Default::default() });
    let cfg = DesConfig {
        alpha: 1e-3,
        collect_snapshots: true,
        event_capacity: 4096,
        ..DesConfig::paper(40, 10.0, 900.0, 37)
    };
    for channel in [
        ChannelSpec::Ideal,
        ChannelSpec::Erasure { p: 0.2 },
        ChannelSpec::Rate { rate: 0.5, p: 0.1 },
    ] {
        let control_spec = ScenarioSpec {
            channel: channel.clone(),
            policy: PolicySpec::Control {
                est: EstimatorSpec::Ge,
                replan_every: 1,
            },
            ..ScenarioSpec::paper()
        };
        // the exact plan the controller starts from (shared code path:
        // ScenarioRunner::control_plan calls the same constructor)
        let plan =
            ControlPlan::compute(&ds, &cfg, control_spec.expected_slowdown());
        let fixed_spec = ScenarioSpec {
            channel: channel.clone(),
            policy: PolicySpec::Fixed { n_c: plan.n_c0 },
            ..ScenarioSpec::paper()
        };
        let control =
            ScenarioRunner::new(control_spec, &ds).run(&cfg).unwrap();
        let fixed = ScenarioRunner::new(fixed_spec, &ds).run(&cfg).unwrap();
        assert_identical(
            &fixed,
            &control,
            &format!("control vs fixed({}) on {}", plan.n_c0, channel.label()),
        );
    }
}

/// On heterogeneous traffic the GE filter has no single chain to
/// condition on, so `est=ge` must fall back to the EMA tracker —
/// bit-identically to asking for `est=ema` outright.
#[test]
fn hetero_control_ge_falls_back_to_ema() {
    let ds = synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
    let cfg = DesConfig {
        alpha: 1e-3,
        event_capacity: 4096,
        ..DesConfig::paper(24, 6.0, 600.0, 5)
    };
    let mk = |est: EstimatorSpec| ScenarioSpec {
        policy: PolicySpec::Control { est, replan_every: 1 },
        traffic: TrafficSpec::Hetero(
            HeteroSpec::new(
                2,
                SchedulerSpec::Greedy,
                0.0,
                vec![ChannelSpec::Ideal, ChannelSpec::Erasure { p: 0.2 }],
            )
            .unwrap(),
        ),
        ..ScenarioSpec::paper()
    };
    let ge = ScenarioRunner::new(mk(EstimatorSpec::Ge), &ds)
        .run(&cfg)
        .unwrap();
    let ema = ScenarioRunner::new(mk(EstimatorSpec::Ema), &ds)
        .run(&cfg)
        .unwrap();
    assert_identical(&ema, &ge, "hetero control est=ge vs est=ema");
}

#[test]
fn sequential_scenario_matches_baseline_entry_point() {
    let ds = synth_calhousing(&SynthSpec { n: 600, ..Default::default() });
    let cfg = DesConfig {
        alpha: 1e-3,
        event_capacity: 4096,
        ..DesConfig::paper(60, 15.0, 1000.0, 31)
    };
    let direct =
        sequential(&ds, &cfg, &mut IdealChannel, &mut mk_exec(&ds, &cfg))
            .unwrap();
    let spec = ScenarioSpec {
        policy: PolicySpec::Sequential { n_c: 0 },
        ..ScenarioSpec::paper()
    };
    let via_spec = ScenarioRunner::new(spec, &ds).run(&cfg).unwrap();
    assert_identical(&direct, &via_spec, "sequential baseline via spec");
    // sequential can never out-train the pipelined run
    let pipe = run_des(&ds, &cfg, &mut IdealChannel, &mut mk_exec(&ds, &cfg))
        .unwrap();
    assert!(pipe.updates > direct.updates);
}

#[test]
fn allfirst_scenario_matches_baseline_entry_point() {
    let ds = synth_calhousing(&SynthSpec { n: 500, ..Default::default() });
    let cfg = DesConfig {
        alpha: 1e-3,
        event_capacity: 64,
        ..DesConfig::paper(50, 10.0, 1100.0, 7)
    };
    let direct = transmit_all_first(
        &ds,
        &cfg,
        &mut IdealChannel,
        &mut mk_exec(&ds, &cfg),
    )
    .unwrap();
    let spec =
        ScenarioSpec { policy: PolicySpec::AllFirst, ..ScenarioSpec::paper() };
    let via_spec = ScenarioRunner::new(spec, &ds).run(&cfg).unwrap();
    assert_identical(&direct, &via_spec, "transmit-all-first via spec");
    assert_eq!(via_spec.blocks_sent, 1);
}

#[test]
fn warmup_scenario_matches_run_scheduled() {
    let ds = synth_calhousing(&SynthSpec { n: 450, ..Default::default() });
    let cfg = DesConfig {
        alpha: 1e-3,
        event_capacity: 4096,
        ..DesConfig::paper(64, 10.0, 1600.0, 19)
    };
    let mut sched = WarmupSchedule::new(16, 2.0, 64);
    let direct = run_scheduled(
        &ds,
        &cfg,
        &mut sched,
        &mut IdealChannel,
        &mut mk_exec(&ds, &cfg),
    )
    .unwrap();
    let spec = ScenarioSpec {
        policy: PolicySpec::Warmup { start: 16, growth: 2.0, cap: 0 },
        ..ScenarioSpec::paper()
    };
    let via_spec = ScenarioRunner::new(spec, &ds).run(&cfg).unwrap();
    assert_identical(&direct, &via_spec, "warmup schedule via spec");
}

#[test]
fn erasure_scenario_matches_run_des_on_erasure_channel() {
    forall("erasure via spec == des", 6, |g| {
        let n = g.usize_in(80..=300);
        let p = g.f64_in(0.05, 0.4);
        let cfg = DesConfig {
            record_blocks: false,
            event_capacity: 4096,
            ..DesConfig::paper(
                g.usize_in(5..=n),
                g.f64_in(0.0, 20.0).round(),
                g.f64_in(50.0, 2.0 * n as f64).round(),
                g.u64_in(0..=1 << 40),
            )
        };
        let ds = synth_calhousing(&SynthSpec { n, ..Default::default() });
        let mut channel: Box<dyn Channel> = Box::new(ErasureChannel::new(p));
        let des = run_des(&ds, &cfg, channel.as_mut(), &mut mk_exec(&ds, &cfg))
            .unwrap();
        let spec = ScenarioSpec {
            channel: ChannelSpec::Erasure { p },
            ..ScenarioSpec::paper()
        };
        let via_spec = ScenarioRunner::new(spec, &ds).run(&cfg).unwrap();
        assert_identical(&des, &via_spec, "erasure channel via spec");
    });
}

#[test]
fn workspace_reuse_is_bit_identical_to_fresh_runs() {
    // ONE workspace threaded through successive seeds AND scenario
    // kinds (single-device, sequential, erasure, warmup, multi-device,
    // online arrivals, bounded store, closed-loop control) must
    // reproduce a fresh `run()` bit-for-bit every time — the purity
    // contract of `run_with`.
    let ds = synth_calhousing(&SynthSpec { n: 360, ..Default::default() });
    let base = DesConfig {
        alpha: 1e-3,
        collect_snapshots: true,
        event_capacity: 4096,
        ..DesConfig::paper(40, 8.0, 700.0, 11)
    };
    let paper = ScenarioSpec::paper();
    let specs = vec![
        paper.clone(),
        ScenarioSpec {
            policy: PolicySpec::Sequential { n_c: 0 },
            ..paper.clone()
        },
        ScenarioSpec {
            channel: ChannelSpec::Erasure { p: 0.15 },
            ..paper.clone()
        },
        ScenarioSpec {
            policy: PolicySpec::Warmup { start: 8, growth: 2.0, cap: 0 },
            ..paper.clone()
        },
        ScenarioSpec { traffic: TrafficSpec::Devices(3), ..paper.clone() },
        ScenarioSpec {
            traffic: TrafficSpec::Online { rate: 1.5 },
            ..paper.clone()
        },
        ScenarioSpec { store_capacity: Some(120), ..paper.clone() },
        ScenarioSpec {
            channel: ChannelSpec::Fading {
                p_gb: 0.05,
                p_bg: 0.25,
                p_good: 0.0,
                p_bad: 0.6,
                rate_good: 1.0,
                rate_bad: 0.5,
            },
            ..paper.clone()
        },
        ScenarioSpec {
            workload: edgepipe::model::Workload::Logistic,
            ..paper.clone()
        },
        ScenarioSpec {
            channel: ChannelSpec::Fading {
                p_gb: 0.1,
                p_bg: 0.3,
                p_good: 0.02,
                p_bad: 0.5,
                rate_good: 1.0,
                rate_bad: 1.0,
            },
            workload: edgepipe::model::Workload::Logistic,
            ..paper.clone()
        },
        // heterogeneous uplink: ScheduledSource + MultiLaneChannel join
        // the purity contract (per-lane index buffers recycle through
        // the same ws.lane_bufs as RoundRobinSource)
        ScenarioSpec {
            traffic: TrafficSpec::Hetero(
                HeteroSpec::new(
                    3,
                    SchedulerSpec::Greedy,
                    0.5,
                    vec![
                        ChannelSpec::Ideal,
                        ChannelSpec::Erasure { p: 0.2 },
                        ChannelSpec::Fading {
                            p_gb: 0.05,
                            p_bg: 0.25,
                            p_good: 0.0,
                            p_bad: 0.6,
                            rate_good: 1.0,
                            rate_bad: 0.5,
                        },
                    ],
                )
                .unwrap(),
            ),
            ..paper.clone()
        },
        ScenarioSpec {
            traffic: TrafficSpec::Hetero(
                HeteroSpec::new(
                    4,
                    SchedulerSpec::PropFair,
                    0.8,
                    vec![ChannelSpec::Rate { rate: 0.5, p: 0.1 }],
                )
                .unwrap(),
            ),
            workload: edgepipe::model::Workload::Logistic,
            ..paper.clone()
        },
        ScenarioSpec {
            traffic: TrafficSpec::Hetero(
                HeteroSpec::new(
                    1,
                    SchedulerSpec::RoundRobin,
                    0.0,
                    Vec::new(),
                )
                .unwrap(),
            ),
            ..paper.clone()
        },
        // closed-loop control joins the purity contract: the policy is
        // rebuilt per run (fresh estimator belief + re-planner state),
        // so a reused workspace must stay bit-identical — under both
        // estimators, on the channel the controller actually adapts to
        ScenarioSpec {
            channel: ChannelSpec::Fading {
                p_gb: 0.1,
                p_bg: 0.15,
                p_good: 0.0,
                p_bad: 0.5,
                rate_good: 1.0,
                rate_bad: 0.3,
            },
            policy: PolicySpec::Control {
                est: EstimatorSpec::Ge,
                replan_every: 1,
            },
            ..paper.clone()
        },
        ScenarioSpec {
            channel: ChannelSpec::Fading {
                p_gb: 0.05,
                p_bg: 0.25,
                p_good: 0.0,
                p_bad: 0.6,
                rate_good: 1.0,
                rate_bad: 0.5,
            },
            policy: PolicySpec::Control {
                est: EstimatorSpec::Ema,
                replan_every: 4,
            },
            ..paper
        },
    ];
    let mut ws = RunWorkspace::new();
    for spec in specs {
        let runner = ScenarioRunner::new(spec.clone(), &ds);
        for s in 0..3u64 {
            let cfg =
                DesConfig { seed: base.seed.wrapping_add(s), ..base.clone() };
            let fresh = runner.run(&cfg).unwrap();
            let stats = runner.run_with(&mut ws, &cfg).unwrap();
            let what = format!("{} seed {s}", spec.label());
            assert_eq!(
                stats.final_loss, fresh.final_loss,
                "{what}: final_loss"
            );
            assert_eq!(ws.final_w(), &fresh.final_w[..], "{what}: final_w");
            assert_eq!(ws.curve(), &fresh.curve[..], "{what}: curve");
            assert_eq!(ws.events(), &fresh.events[..], "{what}: events");
            assert_eq!(stats.updates, fresh.updates, "{what}: updates");
            assert_eq!(
                stats.blocks_sent, fresh.blocks_sent,
                "{what}: blocks_sent"
            );
            assert_eq!(
                stats.blocks_delivered, fresh.blocks_delivered,
                "{what}: blocks_delivered"
            );
            assert_eq!(
                stats.samples_delivered, fresh.samples_delivered,
                "{what}: samples_delivered"
            );
            assert_eq!(
                stats.retransmissions, fresh.retransmissions,
                "{what}: retransmissions"
            );
            assert_eq!(stats.case, fresh.case, "{what}: case");
            assert_eq!(
                ws.snapshots().len(),
                fresh.snapshots.len(),
                "{what}: snapshot count"
            );
            for (a, b) in ws.snapshots().iter().zip(&fresh.snapshots) {
                assert_eq!(a.w_end, b.w_end, "{what}: snapshot w_end");
                assert_eq!(
                    a.arrived_at, b.arrived_at,
                    "{what}: snapshot time"
                );
                assert_eq!(a.x, b.x, "{what}: snapshot x");
                assert_eq!(a.y, b.y, "{what}: snapshot y");
            }
        }
    }
}

#[test]
fn workspace_into_result_equals_fresh_run() {
    // a workspace that already served other runs still assembles the
    // exact RunResult for its final run
    let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
    let cfg = DesConfig {
        event_capacity: 4096,
        ..DesConfig::paper(30, 5.0, 600.0, 5)
    };
    let runner = ScenarioRunner::new(ScenarioSpec::paper(), &ds);
    let mut ws = RunWorkspace::new();
    for s in 0..2u64 {
        let warm = DesConfig { seed: cfg.seed.wrapping_add(s), ..cfg.clone() };
        runner.run_with(&mut ws, &warm).unwrap();
    }
    let stats = runner.run_with(&mut ws, &cfg).unwrap();
    let rebuilt = ws.into_result(stats);
    let fresh = runner.run(&cfg).unwrap();
    assert_identical(&fresh, &rebuilt, "into_result after reuse");
}

#[test]
fn shards_are_disjoint_and_cover_the_dataset() {
    forall("shards partition", 20, |g| {
        let n = g.usize_in(20..=600);
        let k = g.usize_in(1..=n.min(12));
        let ds = synth_calhousing(&SynthSpec { n, ..Default::default() });
        let shards = shard_dataset(&ds, k);
        assert_eq!(shards.len(), k);
        // total size matches and shards are near-equal
        let total: usize = shards.iter().map(|s| s.n).sum();
        assert_eq!(total, ds.n, "shards must cover every sample");
        for s in &shards {
            assert!(
                s.n >= n / k && s.n <= n / k + 1,
                "shard size {} vs n/k {}",
                s.n,
                n / k
            );
        }
        // disjointness + coverage via the deterministic layout: shard s
        // holds exactly dataset rows s, s+k, s+2k, ... in order
        let mut covered = vec![false; n];
        for (s, shard) in shards.iter().enumerate() {
            for j in 0..shard.n {
                let src = s + j * k;
                assert!(src < n, "shard row maps outside the dataset");
                assert!(!covered[src], "row {src} appears in two shards");
                covered[src] = true;
                assert_eq!(
                    shard.row(j),
                    ds.row(src),
                    "shard {s} row {j} != dataset row {src}"
                );
                assert_eq!(shard.label(j), ds.label(src));
            }
        }
        assert!(covered.iter().all(|&c| c), "some rows never sharded");
    });
}

// ---------------------------------------------------------------------
// Threaded shard layer: sharding is an execution strategy, not a
// semantics. The pre-PR `ScheduledSource` stays in the tree as the
// reference; `ShardedSource` must match it bit-for-bit at every shard
// count, fault counters included.
// ---------------------------------------------------------------------

/// Shard counts every parity test below pins: the inline path (1) and
/// two pooled layouts (2, 4) with uneven device/shard splits.
const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

/// One k-device greedy run through `run_schedule`. `n_shards = None`
/// is the pre-PR reference `ScheduledSource`; `Some(s)` runs the
/// threaded `ShardedSource` with `s` shard workers.
fn run_k_devices(
    ds: &Dataset,
    shards: &[Dataset],
    slowdowns: &[f64],
    cfg: &DesConfig,
    channel: &mut dyn Channel,
    n_shards: Option<usize>,
) -> RunResult {
    let mut policy = FixedPolicy(cfg.n_c.max(1));
    let mut exec = mk_exec(ds, cfg);
    match n_shards {
        None => {
            let mut src = ScheduledSource::new(
                shards,
                cfg.seed,
                GreedyScheduler::new(),
                slowdowns,
            );
            run_schedule(
                ds,
                cfg,
                &mut src,
                &mut policy,
                OverlapMode::Pipelined,
                channel,
                &mut exec,
            )
            .unwrap()
        }
        Some(s) => {
            let mut src = ShardedSource::new(
                shards,
                cfg.seed,
                GreedyScheduler::new(),
                slowdowns,
                s,
            );
            assert_eq!(src.shard_workers(), s.min(shards.len()));
            run_schedule(
                ds,
                cfg,
                &mut src,
                &mut policy,
                OverlapMode::Pipelined,
                channel,
                &mut exec,
            )
            .unwrap()
        }
    }
}

/// `assert_identical` plus the fault counters it deliberately omits —
/// the shard layer must reproduce those too.
fn assert_identical_with_faults(a: &RunResult, b: &RunResult, what: &str) {
    assert_identical(a, b, what);
    assert_eq!(a.timeouts, b.timeouts, "{what}: timeouts diverged");
    assert_eq!(a.evictions, b.evictions, "{what}: evictions diverged");
}

#[test]
fn sharded_source_is_bit_identical_to_scheduled_for_every_shard_count() {
    forall("sharded == scheduled", 6, |g| {
        let n = g.usize_in(80..=400);
        let k = g.usize_in(2..=8);
        let cfg = DesConfig {
            event_capacity: 8192,
            ..DesConfig::paper(
                g.usize_in(1..=n / k),
                g.f64_in(0.0, 15.0).round(),
                g.f64_in(100.0, 3.0 * n as f64).round(),
                g.u64_in(0..=1 << 40),
            )
        };
        let ds = synth_calhousing(&SynthSpec { n, ..Default::default() });
        let shards = shard_dataset(&ds, k);
        let slowdowns: Vec<f64> =
            (0..k).map(|_| g.f64_in(0.5, 3.0)).collect();
        let p_loss = g.f64_in(0.0, 0.3);
        let reference = run_k_devices(
            &ds,
            &shards,
            &slowdowns,
            &cfg,
            &mut ErasureChannel::new(p_loss),
            None,
        );
        for s in SHARD_COUNTS {
            let sharded = run_k_devices(
                &ds,
                &shards,
                &slowdowns,
                &cfg,
                &mut ErasureChannel::new(p_loss),
                Some(s),
            );
            assert_identical_with_faults(
                &reference,
                &sharded,
                &format!("sharded k={k} shards={s}"),
            );
        }
    });
}

#[test]
fn sharding_with_faults_armed_but_dormant_is_bit_identical() {
    // Arm the full timeout/retry/eviction machinery on a clean channel:
    // the armed code path runs on every delivery, but nothing fires.
    // The shard layer must be 0-ULP identical through that path too.
    let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
    let k = 3;
    let shards = shard_dataset(&ds, k);
    let slowdowns = [1.0, 2.0, 1.5];
    let cfg = DesConfig {
        event_capacity: 8192,
        faults: FaultTolerance {
            timeout_mult: 8.0,
            retry_budget: 2,
            evict_after: 3,
            preempt: vec![],
        },
        ..DesConfig::paper(25, 5.0, 1500.0, 1234)
    };
    assert!(cfg.faults.enabled(), "machinery must be armed");
    let reference = run_k_devices(
        &ds,
        &shards,
        &slowdowns,
        &cfg,
        &mut IdealChannel,
        None,
    );
    assert_eq!(reference.timeouts, 0, "ideal channel must stay dormant");
    assert_eq!(reference.evictions, 0, "ideal channel must stay dormant");
    for s in SHARD_COUNTS {
        let sharded = run_k_devices(
            &ds,
            &shards,
            &slowdowns,
            &cfg,
            &mut IdealChannel,
            Some(s),
        );
        assert_identical_with_faults(
            &reference,
            &sharded,
            &format!("armed-but-dormant shards={s}"),
        );
    }
}

#[test]
fn sharded_eviction_path_matches_scheduled_under_faults() {
    // Kill device 0's link at t=0 with a tight retry budget: its blocks
    // time out and the device is evicted, driving the scheduler through
    // `ShardedSource::evict` (the clear runs on the owning shard's
    // worker thread). Losses, the event stream and the fault counters
    // must all match the single-threaded reference exactly.
    let ds = synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
    let k = 3;
    let shards = shard_dataset(&ds, k);
    let slowdowns = [1.0, 1.0, 1.0];
    let spec = FaultSpec::parse("drop:0:0.0+retry:2:1:2").unwrap();
    let cfg = DesConfig {
        event_capacity: 8192,
        faults: spec.tolerance(),
        ..DesConfig::paper(30, 5.0, 4000.0, 77)
    };
    let reference = run_k_devices(
        &ds,
        &shards,
        &slowdowns,
        &cfg,
        &mut FaultPlan::new(spec.clone(), IdealChannel),
        None,
    );
    assert!(reference.timeouts > 0, "dead link must time out");
    assert!(reference.evictions > 0, "dead device must be evicted");
    assert!(reference.samples_lost > 0, "evicted lane sheds its samples");
    for s in SHARD_COUNTS {
        let sharded = run_k_devices(
            &ds,
            &shards,
            &slowdowns,
            &cfg,
            &mut FaultPlan::new(spec.clone(), IdealChannel),
            Some(s),
        );
        assert_identical_with_faults(
            &reference,
            &sharded,
            &format!("eviction shards={s}"),
        );
        assert_eq!(
            reference.samples_lost, sharded.samples_lost,
            "eviction shards={s}: samples_lost diverged"
        );
        assert_eq!(
            reference.blocks_abandoned, sharded.blocks_abandoned,
            "eviction shards={s}: blocks_abandoned diverged"
        );
    }
}
