//! Spec-string round-trip properties, driven by the seeded `testkit`
//! property harness: for every scenario axis — channel, policy, traffic
//! (including the heterogeneous `devices:` grammar), workload — the
//! canonical label must re-parse to the identical spec
//! (`parse ∘ label ≡ id`), across randomly generated specs.
//!
//! Rust's `{}` float formatting emits the shortest representation that
//! round-trips through `str::parse::<f64>`, so exact `PartialEq` (not
//! approximate comparison) is the right assertion here: any label that
//! drops, reorders or re-defaults a field is a real grammar bug.

use edgepipe::channel::FaultSpec;
use edgepipe::model::Workload;
use edgepipe::sweep::scenario::{
    ChannelSpec, EstimatorSpec, HeteroSpec, PolicySpec, ScenarioSpec,
    SchedulerSpec, TrafficSpec,
};
use edgepipe::testkit::{forall, Gen};

fn gen_channel(g: &mut Gen) -> ChannelSpec {
    let base = match g.usize_in(0..=3) {
        0 => ChannelSpec::Ideal,
        1 => ChannelSpec::Erasure { p: g.f64_in(0.0, 0.99) },
        2 => ChannelSpec::Rate {
            rate: g.f64_log(0.05, 20.0),
            p: g.f64_in(0.0, 0.99),
        },
        _ => ChannelSpec::Fading {
            p_gb: g.f64_in(0.0, 1.0),
            p_bg: g.f64_in(0.0, 1.0),
            // exercise the suffix-defaulted label forms too
            p_good: if g.bool_with(0.3) { 0.0 } else { g.f64_in(0.0, 0.99) },
            p_bad: g.f64_in(0.0, 0.99),
            rate_good: if g.bool_with(0.3) {
                1.0
            } else {
                g.f64_log(0.1, 10.0)
            },
            rate_bad: if g.bool_with(0.3) {
                1.0
            } else {
                g.f64_log(0.1, 10.0)
            },
        },
    };
    // occasionally wrap in a fault plan: the `:fault=` suffix must
    // round-trip on every base channel, including inside the hetero
    // `ch=` lane lists below (randomized *fault-spec* round-trips live
    // in rust/tests/fault_robustness.rs)
    if g.bool_with(0.2) {
        let fault = FaultSpec::parse(*g.choose(&[
            "outage:50:10:200",
            "ackloss:0.25",
            "drop:1:300+retry:4:2:2",
            "preempt:10:5+retry:2",
        ]))
        .expect("fault spec literal valid");
        base.with_fault(&fault)
    } else {
        base
    }
}

fn gen_policy(g: &mut Gen) -> PolicySpec {
    match g.usize_in(0..=5) {
        0 => PolicySpec::Fixed { n_c: g.usize_in(0..=5000) },
        1 => PolicySpec::Warmup {
            start: g.usize_in(1..=256),
            growth: 1.0 + g.f64_in(0.0, 7.0),
            cap: if g.bool_with(0.4) { 0 } else { g.usize_in(1..=5000) },
        },
        2 => PolicySpec::Deadline { frac: g.f64_in(0.001, 1.0) },
        3 => PolicySpec::Sequential { n_c: g.usize_in(0..=5000) },
        4 => PolicySpec::Control {
            est: *g.choose(&[EstimatorSpec::Ge, EstimatorSpec::Ema]),
            // exercise the suffix-defaulted label form too
            replan_every: if g.bool_with(0.4) {
                1
            } else {
                g.usize_in(1..=64)
            },
        },
        _ => PolicySpec::AllFirst,
    }
}

fn gen_sched(g: &mut Gen) -> SchedulerSpec {
    *g.choose(&[
        SchedulerSpec::RoundRobin,
        SchedulerSpec::Greedy,
        SchedulerSpec::PropFair,
    ])
}

fn gen_traffic(g: &mut Gen) -> TrafficSpec {
    match g.usize_in(0..=2) {
        0 => TrafficSpec::Devices(g.usize_in(1..=64)),
        1 => TrafficSpec::Online { rate: g.f64_log(0.01, 100.0) },
        _ => {
            let k = g.usize_in(1..=8);
            let channels = match g.usize_in(0..=2) {
                0 => Vec::new(),
                1 => vec![gen_channel(g)],
                _ => (0..k).map(|_| gen_channel(g)).collect(),
            };
            let skew = match g.usize_in(0..=2) {
                0 => 0.0,
                1 => 1.0,
                _ => g.f64_in(0.0, 1.0),
            };
            TrafficSpec::Hetero(
                HeteroSpec::new(k, gen_sched(g), skew, channels)
                    .expect("generator produced an invalid HeteroSpec"),
            )
        }
    }
}

#[test]
fn channel_labels_round_trip() {
    forall("channel parse∘label == id", 300, |g| {
        let spec = gen_channel(g);
        let label = spec.label();
        let re = ChannelSpec::parse(&label)
            .unwrap_or_else(|e| panic!("label '{label}' unparseable: {e}"));
        assert_eq!(spec, re, "label '{label}' round-tripped differently");
    });
}

#[test]
fn policy_labels_round_trip() {
    forall("policy parse∘label == id", 300, |g| {
        let spec = gen_policy(g);
        let label = spec.label();
        let re = PolicySpec::parse(&label)
            .unwrap_or_else(|e| panic!("label '{label}' unparseable: {e}"));
        assert_eq!(spec, re, "label '{label}' round-tripped differently");
    });
}

#[test]
fn traffic_labels_round_trip_including_device_strings() {
    forall("traffic parse∘label == id", 300, |g| {
        let spec = gen_traffic(g);
        let label = spec.label();
        // `k<k>` is a display label, not an input form: Devices round
        // trips through its input string instead
        let input = match &spec {
            TrafficSpec::Devices(k) => k.to_string(),
            _ => label.clone(),
        };
        let re = TrafficSpec::parse(&input)
            .unwrap_or_else(|e| panic!("spec '{input}' unparseable: {e}"));
        assert_eq!(spec, re, "'{input}' round-tripped differently");
        // and the canonical label is idempotent
        assert_eq!(re.label(), label, "label not canonical for '{input}'");
    });
}

#[test]
fn workload_labels_round_trip() {
    for w in [Workload::Ridge, Workload::Logistic] {
        assert_eq!(Workload::parse(w.label()).unwrap(), w);
    }
}

#[test]
fn whole_scenarios_round_trip_axis_by_axis() {
    forall("scenario axes parse∘label == id", 150, |g| {
        let spec = ScenarioSpec {
            channel: gen_channel(g),
            policy: gen_policy(g),
            traffic: gen_traffic(g),
            workload: *g.choose(&[Workload::Ridge, Workload::Logistic]),
            store_capacity: if g.bool_with(0.5) {
                None
            } else {
                Some(g.usize_in(1..=100_000))
            },
        };
        let traffic_input = match &spec.traffic {
            TrafficSpec::Devices(k) => k.to_string(),
            t => t.label(),
        };
        let re = ScenarioSpec::parse(
            &spec.channel.label(),
            &spec.policy.label(),
            &traffic_input,
            spec.workload.label(),
            spec.store_capacity.unwrap_or(0),
        )
        .unwrap_or_else(|e| {
            panic!("scenario '{}' unparseable: {e}", spec.label())
        });
        assert_eq!(spec, re, "scenario '{}' diverged", spec.label());
        assert_eq!(spec.label(), re.label());
    });
}
