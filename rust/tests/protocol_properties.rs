//! Property tests over the protocol and coordinator invariants
//! (DESIGN.md §7), using the in-repo testkit.

use edgepipe::channel::{ErasureChannel, IdealChannel};
use edgepipe::coordinator::des::{run_des, DesConfig};
use edgepipe::coordinator::executor::NativeExecutor;
use edgepipe::coordinator::DeviceTransmitter;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::data::Dataset;
use edgepipe::model::RidgeModel;
use edgepipe::protocol::{Timeline, TimelineCase};
use edgepipe::testkit::forall;

fn small_ds(seed: u64, n: usize) -> Dataset {
    synth_calhousing(&SynthSpec { n, seed, ..Default::default() })
}

#[test]
fn device_never_retransmits_and_covers_everything() {
    forall("device no-dup cover", 25, |g| {
        let n = g.usize_in(10..=400);
        let n_c = g.usize_in(1..=n);
        let ds = small_ds(g.u64_in(0..=u64::MAX / 2), n);
        let mut device = DeviceTransmitter::new(&ds, n_c, g.u64_in(0..=1 << 40));
        let mut seen = vec![false; n];
        let mut blocks = 0;
        while let Some((idx, x, y)) = device.next_block() {
            blocks += 1;
            assert_eq!(x.len(), y.len() * ds.d, "payload shape");
            for &i in &idx {
                assert!(!seen[i as usize], "sample {i} transmitted twice");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "all samples transmitted");
        assert_eq!(blocks, n.div_ceil(n_c), "block count = ceil(N/n_c)");
    });
}

#[test]
fn timeline_case_dichotomy_is_exact() {
    forall("timeline dichotomy", 200, |g| {
        let n = g.usize_in(10..=20000);
        let n_c = g.usize_in(1..=n);
        let n_o = g.f64_in(0.0, 2000.0);
        let tau_p = g.f64_log(0.1, 10.0);
        let t = g.f64_in(1.0, 3.0 * n as f64);
        let tl = Timeline::resolve(n, t, n_c, n_o, tau_p);
        let full_time = tl.b_d as f64 * tl.block_len;
        match tl.case {
            TimelineCase::Full => assert!(t > full_time),
            TimelineCase::Partial => assert!(t <= full_time),
        }
        // delivered fraction in [0, 1]; store sizes monotone
        let f = tl.delivered_fraction();
        assert!((0.0..=1.0).contains(&f), "fraction {f}");
        let mut prev = 0;
        for b in 1..=tl.b_d + 1 {
            let s = tl.store_size_at_block(b);
            assert!(s >= prev && s <= n);
            prev = s;
        }
    });
}

#[test]
fn des_accounting_matches_timeline_closed_form() {
    forall("des vs timeline", 15, |g| {
        let n = g.usize_in(50..=500);
        let n_c = g.usize_in(1..=n);
        let n_o = g.f64_in(0.0, 50.0).round();
        let t = g.f64_in(10.0, 2.5 * n as f64).round();
        let ds = small_ds(g.u64_in(0..=1 << 40), n);
        let cfg = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(n_c, n_o, t, g.u64_in(0..=1 << 40))
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res = run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        let tl = Timeline::resolve(n, t, n_c, n_o, 1.0);
        // delivered samples: block b (1-indexed, b <= B_d) arrives at
        // sum of the durations of blocks 1..=b; it counts iff that
        // arrival is strictly before T (the final block may be ragged,
        // shortening its duration)
        let mut delivered = 0usize;
        let mut arrival = 0.0;
        for b in 1..=tl.b_d {
            let payload = tl.payload_of_block(b);
            arrival += payload as f64 + n_o;
            if arrival < t {
                delivered += payload;
            } else {
                break;
            }
        }
        assert_eq!(res.samples_delivered, delivered);
        // update count: at most total budget over tau_p, and they all
        // happened while data was available
        assert!(res.updates <= t as usize);
        if res.samples_delivered == n {
            assert_eq!(res.case, TimelineCase::Full);
        } else {
            assert_eq!(res.case, TimelineCase::Partial);
        }
    });
}

#[test]
fn erasure_channel_never_speeds_up_delivery() {
    forall("erasure slows", 12, |g| {
        let n = 300;
        let ds = small_ds(7, n);
        let n_c = g.usize_in(10..=150);
        let t = 800.0;
        let seed = g.u64_in(0..=1 << 40);
        let p = g.f64_in(0.05, 0.6);
        let cfg = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(n_c, 10.0, t, seed)
        };
        let mk = || {
            NativeExecutor::new(
                RidgeModel::new(ds.d, cfg.lambda, ds.n),
                cfg.alpha,
            )
        };
        let ideal =
            run_des(&ds, &cfg, &mut IdealChannel, &mut mk()).unwrap();
        let mut ch = ErasureChannel::new(p);
        let lossy = run_des(&ds, &cfg, &mut ch, &mut mk()).unwrap();
        assert!(
            lossy.samples_delivered <= ideal.samples_delivered,
            "erasures cannot deliver more: {} vs {}",
            lossy.samples_delivered,
            ideal.samples_delivered
        );
        assert!(lossy.blocks_delivered <= ideal.blocks_delivered);
    });
}

#[test]
fn store_contents_are_always_a_subset_of_the_dataset() {
    forall("store subset", 8, |g| {
        let n = g.usize_in(50..=300);
        let ds = small_ds(g.u64_in(0..=1 << 40), n);
        let n_c = g.usize_in(1..=n);
        let cfg = DesConfig {
            collect_snapshots: true,
            record_blocks: false,
            ..DesConfig::paper(n_c, 5.0, 2.0 * n as f64, g.u64_in(0..=1 << 40))
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res = run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        // every snapshot row must be an actual dataset row
        for snap in &res.snapshots {
            for (i, _) in snap.y.iter().enumerate() {
                let row = &snap.x[i * ds.d..(i + 1) * ds.d];
                let found = (0..ds.n).any(|j| ds.row(j) == row);
                assert!(found, "snapshot row not in dataset");
            }
        }
    });
}

#[test]
fn updates_never_exceed_time_budget() {
    forall("update budget", 20, |g| {
        let n = g.usize_in(20..=300);
        let ds = small_ds(3, n);
        let n_c = g.usize_in(1..=n);
        let tau_p = *g.choose(&[0.5, 1.0, 2.0]);
        let t = g.f64_in(5.0, 3.0 * n as f64).round();
        let cfg = DesConfig {
            tau_p,
            record_blocks: false,
            ..DesConfig::paper(n_c, 3.0, t, g.u64_in(0..=1 << 40))
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        let res = run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        assert!(
            res.updates as f64 * tau_p <= t + 1e-6,
            "{} updates x {tau_p} > {t}",
            res.updates
        );
    });
}
