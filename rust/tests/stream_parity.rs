//! Streaming pipeline ≡ in-memory sweep, and the panic-free error path.
//!
//! Four contracts, each an acceptance criterion for the streaming
//! sweep + serve surface:
//!
//! 1. A streamed scenario sweep produces `(label, McStats)` rows
//!    **bit-identical** to the in-memory `scenario_grid_lanes` over the
//!    same specs — and so does a streamed sweep that was interrupted
//!    (journal truncated mid-line, as a `kill -9` leaves it) and then
//!    resumed.
//! 2. An injected per-group failure surfaces as an error row in the
//!    outcome and the journal — the journal stays line-parseable, the
//!    sibling groups complete, and resuming re-runs exactly the failed
//!    group.
//! 3. A *panicking* group run costs one error row, never the pipeline:
//!    no panic reaches the worker pool.
//! 4. The serve loop answers identical requests from cache with
//!    identical bits, which also match the standalone Monte-Carlo
//!    estimator; malformed requests get error replies on their line.
//! 5. Two TCP clients racing the same request through `serve_listener`
//!    get bit-identical answers, populate ONE shared cache (a third
//!    client hits it), and shutdown drains the accept loop cleanly.
//! 6. Resume compacts the journal (one header + the latest row per
//!    group, stale error rows squashed, idempotent) and the aggregates
//!    after healing + compaction are bit-identical to a never-failed,
//!    never-journaled run.

use std::path::PathBuf;

use edgepipe::coordinator::des::DesConfig;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::linalg::batch::MAX_LANES;
use edgepipe::sweep::runner::{mc_scenario_loss_lanes, scenario_grid_lanes};
use edgepipe::sweep::scenario::{ChannelSpec, PolicySpec, ScenarioSpec};
use edgepipe::sweep::serve::{serve_connection, serve_listener, ServeState};
use edgepipe::sweep::stream::{
    stream_grid_with, stream_scenario_grid, StreamOptions,
};
use edgepipe::sweep::McStats;
use edgepipe::util::json::{self, Value};

const SEEDS: usize = 5;
const LANES: usize = 4;

fn small_ds() -> edgepipe::data::Dataset {
    synth_calhousing(&SynthSpec { n: 240, ..Default::default() })
}

fn sweep_base(seed: u64) -> DesConfig {
    DesConfig {
        loss_every: 0,
        record_blocks: false,
        collect_snapshots: false,
        event_capacity: 0,
        ..DesConfig::paper(24, 6.0, 420.0, seed)
    }
}

fn specs() -> Vec<ScenarioSpec> {
    let paper = ScenarioSpec::paper();
    vec![
        paper.clone(),
        ScenarioSpec {
            channel: ChannelSpec::Erasure { p: 0.2 },
            ..paper.clone()
        },
        ScenarioSpec {
            policy: PolicySpec::Warmup { start: 4, growth: 2.0, cap: 64 },
            ..paper
        },
    ]
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("edgepipe_stream_parity");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}_{}.jsonl", std::process::id()))
}

fn assert_rows_bitwise(
    expected: &[(String, McStats)],
    got: &[(String, McStats)],
    ctx: &str,
) {
    assert_eq!(expected.len(), got.len(), "{ctx}: row count");
    for (e, g) in expected.iter().zip(got) {
        assert_eq!(e.0, g.0, "{ctx}: label");
        assert_eq!(e.1.n, g.1.n, "{ctx}: {} n", e.0);
        assert_eq!(
            e.1.mean.to_bits(),
            g.1.mean.to_bits(),
            "{ctx}: {} mean diverged",
            e.0
        );
        assert_eq!(
            e.1.std.to_bits(),
            g.1.std.to_bits(),
            "{ctx}: {} std diverged",
            e.0
        );
        assert_eq!(
            e.1.sem.to_bits(),
            g.1.sem.to_bits(),
            "{ctx}: {} sem diverged",
            e.0
        );
    }
}

#[test]
fn streamed_and_interrupted_resumed_sweeps_match_in_memory_bitwise() {
    let ds = small_ds();
    let base = sweep_base(19);
    let specs = specs();
    let expected =
        scenario_grid_lanes(&ds, &base, &specs, SEEDS, 2, LANES).unwrap();

    let journal = tmp("full");
    let _ = std::fs::remove_file(&journal);
    let opts = StreamOptions {
        seeds: SEEDS,
        threads: 2,
        lanes: LANES,
        journal: Some(journal.clone()),
        ..StreamOptions::default()
    };
    let streamed = stream_scenario_grid(&ds, &base, &specs, &opts).unwrap();
    assert!(streamed.errors.is_empty());
    // 3 points × ceil(5/4) groups, none reused on a fresh run
    assert_eq!(streamed.groups_run, 6);
    assert_eq!(streamed.groups_reused, 0);
    assert_rows_bitwise(&expected, &streamed.rows, "fresh stream");

    // the journal is valid JSONL: header first, every line parses
    let text = std::fs::read_to_string(&journal).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 7, "header + one row per group");
    for (i, line) in lines.iter().enumerate() {
        let v = json::parse(line).unwrap_or_else(|e| {
            panic!("journal line {i} is not JSON ({e}): {line}")
        });
        assert!(v.opt("error").is_none(), "no error rows on a clean run");
    }

    // interrupt: keep the header and two completed rows, then the
    // truncated tail a kill mid-write leaves behind
    let partial = tmp("part");
    let mut kept = lines[..3].join("\n");
    kept.push_str("\n{\"i\":9,\"poin");
    std::fs::write(&partial, kept).unwrap();

    let resumed_opts = StreamOptions {
        seeds: SEEDS,
        threads: 2,
        lanes: LANES,
        resume: Some(partial.clone()),
        ..StreamOptions::default()
    };
    let resumed =
        stream_scenario_grid(&ds, &base, &specs, &resumed_opts).unwrap();
    assert_eq!(resumed.groups_reused, 2, "both surviving rows reused");
    assert_eq!(resumed.groups_run, 4);
    assert!(resumed.errors.is_empty());
    assert_rows_bitwise(&expected, &resumed.rows, "interrupted + resumed");

    // the resume appended its re-runs to the same journal; a second
    // resume now reuses everything and still matches bitwise
    let replayed =
        stream_scenario_grid(&ds, &base, &specs, &resumed_opts).unwrap();
    assert_eq!(replayed.groups_reused, 6);
    assert_eq!(replayed.groups_run, 0);
    assert_rows_bitwise(&expected, &replayed.rows, "full journal replay");

    // a journal from different sweep parameters must be rejected
    let wrong_seeds = StreamOptions {
        seeds: SEEDS + 1,
        resume: Some(partial.clone()),
        ..resumed_opts
    };
    assert!(stream_scenario_grid(&ds, &base, &specs, &wrong_seeds).is_err());

    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&partial);
}

/// Deterministic per-lane losses so parity is checkable without a DES.
fn synthetic_losses(point: usize, seed0: u64, len: usize) -> [f64; MAX_LANES] {
    let mut out = [f64::NAN; MAX_LANES];
    for (lane, slot) in out.iter_mut().take(len).enumerate() {
        *slot = (point * 100) as f64 + seed0 as f64 + lane as f64 * 0.5;
    }
    out
}

#[test]
fn injected_failures_become_error_rows_and_resume_reruns_them() {
    let labels = vec!["alpha".to_string(), "beta".to_string()];
    let journal = tmp("inject");
    let _ = std::fs::remove_file(&journal);
    let opts = StreamOptions {
        seeds: 6,
        threads: 2,
        lanes: 4,
        journal: Some(journal.clone()),
        fingerprint: "inject-fp".to_string(),
        ..StreamOptions::default()
    };
    let out = stream_grid_with(&labels, &opts, |_bw, job| {
        if job.point == 1 && job.seed0 == 4 {
            anyhow::bail!("injected failure");
        }
        Ok(synthetic_losses(job.point, job.seed0, job.len))
    })
    .unwrap();

    // the failure is an error row, not a panic and not a lost sweep
    assert_eq!(out.errors.len(), 1);
    assert_eq!(out.errors[0].point, 1);
    assert_eq!(out.errors[0].label, "beta");
    assert_eq!(out.errors[0].seed0, 4);
    assert!(out.errors[0].message.contains("injected failure"));
    assert_eq!(out.groups_run, 4); // 2 points × 2 groups, all executed
    assert_eq!(out.rows[0].1.n, 6, "sibling point unaffected");
    assert_eq!(out.rows[1].1.n, 4, "failed group's seeds dropped");

    // the journal survived the failure: all lines parse, one error row
    let text = std::fs::read_to_string(&journal).unwrap();
    let error_rows = text
        .lines()
        .map(|l| json::parse(l).expect("valid line"))
        .filter(|v| v.opt("error").is_some())
        .count();
    assert_eq!(error_rows, 1);

    // resuming with the failure gone re-runs ONLY the failed group
    let resume_opts = StreamOptions {
        resume: Some(journal.clone()),
        journal: None,
        ..opts
    };
    let healed = stream_grid_with(&labels, &resume_opts, |_bw, job| {
        Ok(synthetic_losses(job.point, job.seed0, job.len))
    })
    .unwrap();
    assert!(healed.errors.is_empty());
    assert_eq!(healed.groups_reused, 3);
    assert_eq!(healed.groups_run, 1);

    // ...and the healed result is bit-identical to a never-failed run
    let fresh_opts = StreamOptions {
        seeds: 6,
        threads: 2,
        lanes: 4,
        fingerprint: "inject-fp".to_string(),
        ..StreamOptions::default()
    };
    let fresh = stream_grid_with(&labels, &fresh_opts, |_bw, job| {
        Ok(synthetic_losses(job.point, job.seed0, job.len))
    })
    .unwrap();
    assert_rows_bitwise(&fresh.rows, &healed.rows, "healed vs fresh");

    let _ = std::fs::remove_file(&journal);
}

#[test]
fn a_panicking_group_costs_one_error_row_not_the_pipeline() {
    let labels = vec!["panicky".to_string()];
    let opts = StreamOptions {
        seeds: 8,
        threads: 2,
        lanes: 4,
        fingerprint: "panic-fp".to_string(),
        ..StreamOptions::default()
    };
    let out = stream_grid_with(&labels, &opts, |_bw, job| {
        if job.seed0 == 0 {
            panic!("kaboom in group {}", job.seed0);
        }
        Ok(synthetic_losses(0, job.seed0, job.len))
    })
    .expect("a panicking group must not sink the pipeline");
    assert_eq!(out.errors.len(), 1);
    assert!(
        out.errors[0].message.contains("kaboom"),
        "panic payload preserved: {}",
        out.errors[0].message
    );
    assert_eq!(out.groups_run, 2);
    assert_eq!(out.rows[0].1.n, 4, "the sibling group still aggregated");
}

#[test]
fn serve_loop_caches_and_matches_the_standalone_estimator() {
    let ds = small_ds();
    let base = sweep_base(19);
    let mut state = ServeState::new(&ds, base.clone(), 64, LANES);

    let req = r#"{"id":1,"channel":"erasure:0.2","seeds":5}"#;
    let input = format!(
        "{req}\n{}\n{}\n{}\n",
        req.replace("\"id\":1", "\"id\":2"),
        r#"{"id":3,"policy":"warp-drive"}"#,
        r#"{"id":4,"cmd":"shutdown"}"#,
    );
    let mut out = Vec::new();
    let stopped = serve_connection(
        &mut state,
        std::io::Cursor::new(input),
        &mut out,
    )
    .unwrap();
    assert!(stopped, "shutdown must stop the loop");

    let replies: Vec<Value> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| json::parse(l).expect("every reply is JSON"))
        .collect();
    assert_eq!(replies.len(), 4);

    let loss = |v: &Value, key: &str| -> f64 {
        match v.get(key).unwrap() {
            Value::Num(n) => *n,
            Value::Str(text) => text.parse().unwrap(),
            other => panic!("{key}: unexpected {other:?}"),
        }
    };
    // first request computes, second is a pure cache hit — same bits
    assert_eq!(replies[0].get("ok").unwrap(), &Value::Bool(true));
    assert_eq!(replies[0].get("cache").unwrap().as_str().unwrap(), "miss");
    assert_eq!(replies[1].get("cache").unwrap().as_str().unwrap(), "hit");
    for key in ["mean", "std", "sem"] {
        assert_eq!(
            loss(&replies[0], key).to_bits(),
            loss(&replies[1], key).to_bits(),
            "{key}: cache hit must carry identical bits"
        );
    }

    // ...and both match the standalone Monte-Carlo estimator bitwise
    let spec = ScenarioSpec {
        channel: ChannelSpec::Erasure { p: 0.2 },
        ..ScenarioSpec::paper()
    };
    let mc =
        mc_scenario_loss_lanes(&ds, &base, &spec, 5, 2, LANES).unwrap();
    assert_eq!(loss(&replies[0], "mean").to_bits(), mc.mean.to_bits());
    assert_eq!(loss(&replies[0], "std").to_bits(), mc.std.to_bits());
    assert_eq!(
        replies[0].get("n").unwrap().as_usize().unwrap(),
        mc.n
    );

    // the bad request got an error reply in place, id echoed
    assert_eq!(replies[2].get("ok").unwrap(), &Value::Bool(false));
    assert_eq!(replies[2].get("id").unwrap().as_usize().unwrap(), 3);
    assert!(replies[2]
        .get("error")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("warp-drive"));

    // shutdown acknowledged on its line
    assert_eq!(replies[3].get("id").unwrap().as_usize().unwrap(), 4);
    assert_eq!(replies[3].get("ok").unwrap(), &Value::Bool(true));
}

/// Pull one reply's loss field whether it was encoded as a JSON number
/// or as a full-precision string.
fn reply_loss(v: &Value, key: &str) -> f64 {
    match v.get(key).unwrap() {
        Value::Num(n) => *n,
        Value::Str(text) => text.parse().unwrap(),
        other => panic!("{key}: unexpected {other:?}"),
    }
}

#[test]
fn concurrent_tcp_clients_share_the_cache_and_match_bitwise() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{SocketAddr, TcpListener, TcpStream};
    use std::sync::Barrier;

    fn ask(addr: SocketAddr, line: &str) -> Value {
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "{line}").unwrap();
        let mut reply = String::new();
        BufReader::new(conn).read_line(&mut reply).unwrap();
        json::parse(reply.trim_end()).expect("reply must be JSON")
    }

    let ds = small_ds();
    let base = sweep_base(19);
    let state = ServeState::new(&ds, base, 64, LANES);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let req = r#"{"id":7,"channel":"erasure:0.2","seeds":5}"#;

    std::thread::scope(|scope| {
        let server = scope.spawn(|| serve_listener(&state, listener));

        // two clients race the same request through separate
        // connections; whichever order the cache fills in, determinism
        // makes the answers carry identical bits
        let barrier = &Barrier::new(2);
        let c1 = scope.spawn(move || {
            barrier.wait();
            ask(addr, req)
        });
        let c2 = scope.spawn(move || {
            barrier.wait();
            ask(addr, req)
        });
        let r1 = c1.join().unwrap();
        let r2 = c2.join().unwrap();
        for r in [&r1, &r2] {
            assert_eq!(r.get("ok").unwrap(), &Value::Bool(true));
            assert_eq!(r.get("id").unwrap().as_usize().unwrap(), 7);
            let cache = r.get("cache").unwrap().as_str().unwrap().to_string();
            assert!(
                cache == "hit" || cache == "miss",
                "cache field must be hit|miss, got {cache}"
            );
        }
        for key in ["mean", "std", "sem"] {
            assert_eq!(
                reply_loss(&r1, key).to_bits(),
                reply_loss(&r2, key).to_bits(),
                "{key}: concurrent clients must agree bitwise"
            );
        }

        // a third client after the race MUST hit the shared cache, with
        // the same bits again
        let warm = ask(addr, req);
        assert_eq!(warm.get("cache").unwrap().as_str().unwrap(), "hit");
        for key in ["mean", "std", "sem"] {
            assert_eq!(
                reply_loss(&warm, key).to_bits(),
                reply_loss(&r1, key).to_bits(),
                "{key}: warm hit must carry identical bits"
            );
        }

        // shutdown stops the accept loop; the server thread drains
        let bye = ask(addr, r#"{"id":9,"cmd":"shutdown"}"#);
        assert_eq!(bye.get("ok").unwrap(), &Value::Bool(true));
        server
            .join()
            .expect("server thread must not panic")
            .expect("serve_listener must exit cleanly");
    });
}

#[test]
fn resume_compacts_the_journal_and_keeps_aggregates_bitwise() {
    let labels = vec!["gamma".to_string(), "delta".to_string()];
    let journal = tmp("compact");
    let _ = std::fs::remove_file(&journal);
    let opts = StreamOptions {
        seeds: 6,
        threads: 2,
        lanes: 4,
        journal: Some(journal.clone()),
        fingerprint: "compact-fp".to_string(),
        ..StreamOptions::default()
    };

    // run 1: one injected failure leaves an error row in the journal
    let first = stream_grid_with(&labels, &opts, |_bw, job| {
        if job.point == 0 && job.seed0 == 4 {
            anyhow::bail!("flaky the first time");
        }
        Ok(synthetic_losses(job.point, job.seed0, job.len))
    })
    .unwrap();
    assert_eq!(first.errors.len(), 1);
    // header + 2 points × 2 groups (one of them the error row)
    let text = std::fs::read_to_string(&journal).unwrap();
    assert_eq!(text.lines().count(), 5, "run-1 journal: header + 4 rows");

    // resume 1: compaction runs on entry (nothing to squash yet — all
    // keys unique), then the append-mode writer adds its own header and
    // the failed group's re-run success row lands AFTER the stale error
    let resume_opts = StreamOptions {
        resume: Some(journal.clone()),
        journal: None,
        ..opts.clone()
    };
    let healed = stream_grid_with(&labels, &resume_opts, |_bw, job| {
        Ok(synthetic_losses(job.point, job.seed0, job.len))
    })
    .unwrap();
    assert!(healed.errors.is_empty());
    assert_eq!(healed.groups_reused, 3);
    assert_eq!(healed.groups_run, 1);
    let text = std::fs::read_to_string(&journal).unwrap();
    assert_eq!(
        text.lines().count(),
        7,
        "compacted 5 + resume header + healed re-run"
    );

    // resume 2: compaction squashes the superseded error row and the
    // duplicate header; every group is reused, nothing runs, and only
    // the writer's fresh header is appended to the compacted file
    let replayed = stream_grid_with(&labels, &resume_opts, |_bw, _job| {
        panic!("fully-journaled resume must not run anything")
    })
    .unwrap();
    assert!(replayed.errors.is_empty());
    assert_eq!(replayed.groups_reused, 4);
    assert_eq!(replayed.groups_run, 0);
    let compacted = std::fs::read_to_string(&journal).unwrap();
    assert_eq!(
        compacted.lines().count(),
        6,
        "header + 4 latest rows + resume header"
    );
    for line in compacted.lines() {
        let v = json::parse(line).expect("compacted line parses");
        assert!(v.opt("error").is_none(), "error row must be squashed");
    }

    // the aggregates survive journaling, healing and compaction with
    // identical bits to a never-failed, never-journaled run
    let fresh_opts = StreamOptions {
        journal: None,
        ..opts.clone()
    };
    let fresh = stream_grid_with(&labels, &fresh_opts, |_bw, job| {
        Ok(synthetic_losses(job.point, job.seed0, job.len))
    })
    .unwrap();
    assert_rows_bitwise(&fresh.rows, &healed.rows, "healed vs fresh");
    assert_rows_bitwise(&fresh.rows, &replayed.rows, "compacted vs fresh");

    // resume 3: compacting an already-compact journal is a byte no-op
    let again = stream_grid_with(&labels, &resume_opts, |_bw, _job| {
        panic!("still nothing to run")
    })
    .unwrap();
    assert_eq!(again.groups_reused, 4);
    let recompacted = std::fs::read_to_string(&journal).unwrap();
    assert_eq!(compacted, recompacted, "compaction must be idempotent");

    let _ = std::fs::remove_file(&journal);
}
