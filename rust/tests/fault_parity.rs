//! Fault-layer parity: the fault-injection machinery must be invisible
//! unless a fault actually fires.
//!
//! Three contracts, each load-bearing for the robustness layer:
//!
//! 1. `fault=off` (and an absent suffix) parse to the *structurally
//!    identical* spec — a disabled plan never constructs a `FaultPlan`,
//!    so fault-free bit-identity holds by construction.
//! 2. An *armed but dormant* plan — clauses that cannot fire within the
//!    run horizon, including an armed `retry:` tolerance that switches
//!    the scheduler onto its ARQ-aware path — is bit-for-bit identical
//!    to the bare scenario across every axis: channels, policies,
//!    traffic shapes, workloads. This is the strong form of the
//!    "a clause that cannot fire draws nothing" contract: wrapping the
//!    channel and arming the timeout machinery must not perturb a
//!    single RNG draw, event, or loss bit.
//! 3. Scenarios whose faults DO fire stay batchable, and the
//!    batched-seed SoA engine replays them bit-identically to the
//!    scalar engine at every lane width.

use edgepipe::channel::FaultSpec;
use edgepipe::coordinator::des::DesConfig;
use edgepipe::coordinator::run::RunResult;
use edgepipe::coordinator::scheduler::RunWorkspace;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::model::Workload;
use edgepipe::sweep::scenario::{
    ChannelSpec, EstimatorSpec, HeteroSpec, PolicySpec, ScenarioRunner,
    ScenarioSpec, SchedulerSpec, TrafficSpec,
};
use edgepipe::sweep::{batchable, from_name, mc_scenario_loss_lanes};

/// Every clause armed, none able to fire before `t = 100000` — far past
/// any run horizon used here. The `retry:` clause matters most: it
/// flips `DesConfig::faults` non-trivial, so the scheduler runs its
/// timeout/eviction bookkeeping on every delivery.
const DORMANT_ARMED: &str =
    "outage:100000:10+drop:0:100000+preempt:100000:5+retry:100000:3:2";

/// Channel-side clauses only (trivial tolerance): exercises the
/// `FaultPlan` wrapper transparency without touching the scheduler.
const DORMANT_WRAPPER: &str = "outage:100000:10+drop:0:100000";

fn parity_ds() -> edgepipe::data::Dataset {
    synth_calhousing(&SynthSpec { n: 240, ..Default::default() })
}

fn trace_cfg(seed: u64) -> DesConfig {
    DesConfig {
        record_blocks: false,
        event_capacity: 1 << 14,
        ..DesConfig::paper(24, 6.0, 420.0, seed)
    }
}

fn fading() -> ChannelSpec {
    ChannelSpec::Fading {
        p_gb: 0.05,
        p_bg: 0.25,
        p_good: 0.0,
        p_bad: 0.6,
        rate_good: 1.0,
        rate_bad: 1.0,
    }
}

/// One spec per scenario axis the fault layer must stay invisible on.
fn axis_specs() -> Vec<ScenarioSpec> {
    let paper = ScenarioSpec::paper();
    vec![
        paper.clone(),
        ScenarioSpec {
            channel: ChannelSpec::Erasure { p: 0.2 },
            ..paper.clone()
        },
        ScenarioSpec {
            channel: fading(),
            policy: PolicySpec::Control {
                est: EstimatorSpec::Ge,
                replan_every: 2,
            },
            ..paper.clone()
        },
        ScenarioSpec {
            policy: PolicySpec::Warmup { start: 4, growth: 2.0, cap: 64 },
            ..paper.clone()
        },
        ScenarioSpec { workload: Workload::Logistic, ..paper.clone() },
        ScenarioSpec { traffic: TrafficSpec::Devices(3), ..paper.clone() },
        ScenarioSpec {
            traffic: TrafficSpec::Online { rate: 0.8 },
            ..paper
        },
    ]
}

fn hetero(lanes: Vec<ChannelSpec>) -> ScenarioSpec {
    ScenarioSpec {
        traffic: TrafficSpec::Hetero(
            HeteroSpec::new(3, SchedulerSpec::Greedy, 0.5, lanes)
                .expect("valid hetero spec"),
        ),
        ..ScenarioSpec::paper()
    }
}

fn assert_bit_identical(ctx: &str, bare: &RunResult, faulted: &RunResult) {
    assert_eq!(
        bare.final_loss.to_bits(),
        faulted.final_loss.to_bits(),
        "{ctx}: final loss diverged"
    );
    assert_eq!(bare.final_w.len(), faulted.final_w.len(), "{ctx}: dim");
    for (i, (a, b)) in bare.final_w.iter().zip(&faulted.final_w).enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: final_w[{i}] diverged");
    }
    assert_eq!(bare.curve.len(), faulted.curve.len(), "{ctx}: curve len");
    for ((ta, la), (tb, lb)) in bare.curve.iter().zip(&faulted.curve) {
        assert_eq!(ta.to_bits(), tb.to_bits(), "{ctx}: curve time diverged");
        assert_eq!(la.to_bits(), lb.to_bits(), "{ctx}: curve loss diverged");
    }
    assert_eq!(bare.updates, faulted.updates, "{ctx}: updates");
    assert_eq!(bare.blocks_sent, faulted.blocks_sent, "{ctx}: sent");
    assert_eq!(
        bare.blocks_delivered, faulted.blocks_delivered,
        "{ctx}: delivered"
    );
    assert_eq!(bare.blocks_missed, faulted.blocks_missed, "{ctx}: missed");
    assert_eq!(
        bare.retransmissions, faulted.retransmissions,
        "{ctx}: retransmissions"
    );
    assert_eq!(bare.case, faulted.case, "{ctx}: timeline case");
    // dormant plans must never trip the fault counters...
    assert_eq!(faulted.timeouts, 0, "{ctx}: phantom timeout");
    assert_eq!(faulted.blocks_abandoned, 0, "{ctx}: phantom abandonment");
    assert_eq!(faulted.evictions, 0, "{ctx}: phantom eviction");
    assert_eq!(faulted.samples_lost, 0, "{ctx}: phantom shed samples");
    assert!(!faulted.degraded_completion, "{ctx}: phantom degradation");
    // ...and the event log must match event-for-event
    assert_eq!(
        format!("{:?}", bare.events),
        format!("{:?}", faulted.events),
        "{ctx}: event log diverged"
    );
}

#[test]
fn fault_off_and_absent_are_the_same_channel_spec() {
    for s in [
        "ideal",
        "erasure:0.2",
        "rate:0.5:0.1",
        "fading:0.05:0.25:0.6",
        "fading:0.05:0.25:0.6:0:0.5",
    ] {
        let bare = ChannelSpec::parse(s).unwrap();
        for suffix in [":fault=off", ":fault="] {
            let wrapped =
                ChannelSpec::parse(&format!("{s}{suffix}")).unwrap();
            assert_eq!(bare, wrapped, "'{s}{suffix}' must be the bare spec");
            assert_eq!(bare.label(), wrapped.label());
        }
        // the programmatic route agrees with the grammar
        assert_eq!(bare, bare.with_fault(&FaultSpec::default()));
        assert_eq!(bare, bare.with_fault(&FaultSpec::parse("off").unwrap()));
    }
}

#[test]
fn dormant_fault_plans_are_bit_identical_on_every_axis() {
    let ds = parity_ds();
    for dormant in [DORMANT_WRAPPER, DORMANT_ARMED] {
        let fault = FaultSpec::parse(dormant).unwrap();
        assert!(!fault.is_disabled(), "'{dormant}' must construct a plan");
        for (k, spec) in axis_specs().into_iter().enumerate() {
            let faulted = ScenarioSpec {
                channel: spec.channel.with_fault(&fault),
                ..spec.clone()
            };
            assert_ne!(spec.label(), faulted.label(), "spec #{k}: no wrap?");
            for seed in [13u64, 77] {
                let cfg = trace_cfg(seed);
                let a = ScenarioRunner::new(spec.clone(), &ds)
                    .run(&cfg)
                    .unwrap();
                let b = ScenarioRunner::new(faulted.clone(), &ds)
                    .run(&cfg)
                    .unwrap();
                let ctx = format!(
                    "spec #{k} '{}' + '{dormant}' seed {seed}",
                    spec.label()
                );
                assert_bit_identical(&ctx, &a, &b);
            }
        }
    }
}

#[test]
fn dormant_fault_plans_are_bit_identical_on_hetero_lanes() {
    let ds = parity_ds();
    let lanes = vec![ChannelSpec::Ideal, ChannelSpec::Erasure { p: 0.2 }, fading()];
    let fault = FaultSpec::parse(DORMANT_ARMED).unwrap();
    let bare = hetero(lanes.clone());
    let faulted =
        hetero(lanes.iter().map(|c| c.with_fault(&fault)).collect());
    for seed in [13u64, 77] {
        let cfg = trace_cfg(seed);
        let a = ScenarioRunner::new(bare.clone(), &ds).run(&cfg).unwrap();
        let b = ScenarioRunner::new(faulted.clone(), &ds).run(&cfg).unwrap();
        let ctx = format!("hetero3 + '{DORMANT_ARMED}' seed {seed}");
        assert_bit_identical(&ctx, &a, &b);
    }
}

#[test]
fn live_fault_scenarios_stay_batchable_and_batch_bitwise() {
    let ds = synth_calhousing(&SynthSpec { n: 320, ..Default::default() });
    let base = DesConfig {
        loss_every: 0,
        record_blocks: false,
        collect_snapshots: false,
        event_capacity: 0,
        ..DesConfig::paper(32, 5.0, 640.0, 19)
    };
    let paper = ScenarioSpec::paper();
    let specs = vec![
        ScenarioSpec {
            channel: ChannelSpec::parse(
                "erasure:0.1:fault=outage:80:40:200+retry:4:2",
            )
            .unwrap(),
            ..paper.clone()
        },
        ScenarioSpec {
            channel: ChannelSpec::parse("ideal:fault=drop:0:200+retry:4:2:2")
                .unwrap(),
            ..paper
        },
        from_name("hetero3_dropout_control")
            .expect("hetero3_dropout_control preset registered"),
    ];
    for (k, spec) in specs.iter().enumerate() {
        let runner = ScenarioRunner::new(spec.clone(), &ds);
        // fault scenarios must not silently fall off the fast path
        assert!(
            batchable(&runner.effective_cfg(&base)),
            "spec #{k} {} must stay batchable",
            spec.label()
        );
        // ...and must actually fire, or the parity below is vacuous
        let mut ws = RunWorkspace::new();
        let stats = runner.run_with(&mut ws, &base).unwrap();
        assert!(
            stats.timeouts > 0,
            "spec #{k} {}: faults never fired",
            spec.label()
        );
        let scalar = mc_scenario_loss_lanes(&ds, &base, spec, 5, 2, 1).unwrap();
        for lanes in [4usize, 8] {
            let batched = mc_scenario_loss_lanes(&ds, &base, spec, 5, 2, lanes)
                .unwrap();
            assert_eq!(
                scalar.mean.to_bits(),
                batched.mean.to_bits(),
                "spec #{k} {} lanes={lanes}: mean diverged",
                spec.label()
            );
            assert_eq!(
                scalar.std.to_bits(),
                batched.std.to_bits(),
                "spec #{k} {} lanes={lanes}: std diverged",
                spec.label()
            );
        }
    }
}
