//! Seeded Monte-Carlo agreement of the channel-statistics closed forms
//! with simulation — the statistical layer behind the channel-aware
//! bound recommendation:
//!
//! 1. `GilbertElliott::stationary_p_bad` matches the long-run fraction
//!    of packets clocked in the bad state;
//! 2. `GilbertElliott::expected_slowdown` matches the mean measured
//!    channel occupancy per unit of nominal duration;
//! 3. `bound::validate::aggregate_slowdown` (the per-device aggregate
//!    used for the heterogeneous uplink) matches the measured occupancy
//!    of a `MultiLaneChannel` served round-robin — both directly and
//!    through a full heterogeneous scenario run's event stream.
//!
//! Everything is seeded (fixed Pcg32 streams, fixed trial counts), so
//! these are deterministic regression tests, not flaky statistics.

use edgepipe::bound::aggregate_slowdown;
use edgepipe::channel::{
    Channel, ErasureChannel, GeBeliefEstimator, GeParams,
    GilbertElliottChannel, IdealChannel, LinkState, MultiLaneChannel,
    PacketObs,
};
use edgepipe::coordinator::des::DesConfig;
use edgepipe::coordinator::EventKind;
use edgepipe::data::synth::{synth_calhousing, SynthSpec};
use edgepipe::sweep::scenario::{
    ChannelSpec, HeteroSpec, ScenarioRunner, ScenarioSpec, SchedulerSpec,
    TrafficSpec,
};
use edgepipe::util::rng::Pcg32;

fn bursty() -> GilbertElliottChannel {
    GilbertElliottChannel::new(
        0.2,
        0.5,
        LinkState::new(1.0, 0.05),
        LinkState::new(0.5, 0.6),
    )
}

#[test]
fn stationary_bad_state_probability_matches_monte_carlo() {
    let mut ge = bursty();
    let want = ge.stationary_p_bad(); // 0.2/(0.2+0.5) = 2/7
    assert!((want - 2.0 / 7.0).abs() < 1e-12);
    let mut rng = Pcg32::new(2024, 4);
    let trials = 40_000usize;
    let mut bad = 0usize;
    for _ in 0..trials {
        ge.transmit(0.0, 1.0, &mut rng);
        bad += usize::from(ge.is_bad());
    }
    let measured = bad as f64 / trials as f64;
    // the chain is autocorrelated (flip prob 0.3 per packet), which
    // inflates the MC variance over the i.i.d. binomial sem — 0.015 is
    // ~5σ with the inflation factored in
    assert!(
        (measured - want).abs() < 0.015,
        "measured p(bad) {measured} vs stationary {want}"
    );
}

#[test]
fn expected_slowdown_matches_measured_occupancy() {
    // across three parameterizations, including a degenerate chain
    let channels = [
        bursty(),
        GilbertElliottChannel::new(
            0.5,
            0.5,
            LinkState::new(2.0, 0.0),
            LinkState::new(0.25, 0.3),
        ),
        // p_gb = 0: pinned good, slowdown = good-state occupancy exactly
        GilbertElliottChannel::new(
            0.0,
            0.3,
            LinkState::new(1.0, 0.2),
            LinkState::new(0.5, 0.9),
        ),
    ];
    for (i, mut ge) in channels.into_iter().enumerate() {
        let want = ge.expected_slowdown();
        let mut rng = Pcg32::new(77 + i as u64, 4);
        let trials = 30_000usize;
        let mut occupancy = 0.0;
        for _ in 0..trials {
            occupancy += ge.transmit(0.0, 1.0, &mut rng).arrival;
        }
        let measured = occupancy / trials as f64;
        // per-packet (not per-attempt) state clocking: the mixture is
        // exact in the stationary regime, so 5% covers MC noise
        assert!(
            (measured - want).abs() < 0.05 * want,
            "channel {i}: measured slowdown {measured} vs closed form {want}"
        );
    }
}

#[test]
fn aggregate_slowdown_matches_multilane_occupancy() {
    // a heterogeneous 3-lane uplink served round-robin with equal data
    // shares: measured mean occupancy per unit nominal duration must
    // match the equal-share aggregate of the per-lane closed forms
    let specs = [
        ChannelSpec::Ideal,
        ChannelSpec::Erasure { p: 0.4 },
        ChannelSpec::Fading {
            p_gb: 0.2,
            p_bg: 0.5,
            p_good: 0.05,
            p_bad: 0.6,
            rate_good: 1.0,
            rate_bad: 0.5,
        },
    ];
    let lane_slowdowns: Vec<f64> =
        specs.iter().map(|s| s.expected_slowdown()).collect();
    let want = aggregate_slowdown(&lane_slowdowns, &[1.0, 1.0, 1.0]);

    let mut multi =
        MultiLaneChannel::new(specs.iter().map(|s| s.make()).collect());
    let mut rng = Pcg32::new(99, 4);
    let trials = 30_000usize;
    let mut occupancy = 0.0;
    for i in 0..trials {
        multi.select_lane(i % 3);
        occupancy += multi.transmit(0.0, 1.0, &mut rng).arrival;
    }
    let measured = occupancy / trials as f64;
    assert!(
        (measured - want).abs() < 0.05 * want,
        "measured aggregate {measured} vs closed form {want}"
    );
}

#[test]
fn scenario_event_stream_reproduces_the_aggregate_slowdown() {
    // end-to-end: a heterogeneous round-robin scenario's event stream
    // (send → delivery spans) must measure the same aggregate slowdown
    // the bound layer computes. Round-robin + equal shards keep the
    // channel-time shares equal, matching the closed form's weights.
    let ds = synth_calhousing(&SynthSpec { n: 900, ..Default::default() });
    let channels = vec![
        ChannelSpec::Ideal,
        ChannelSpec::Erasure { p: 0.3 },
        ChannelSpec::Rate { rate: 0.5, p: 0.0 },
    ];
    let spec = ScenarioSpec {
        traffic: TrafficSpec::Hetero(
            HeteroSpec::new(
                3,
                SchedulerSpec::RoundRobin,
                0.0,
                channels.clone(),
            )
            .unwrap(),
        ),
        ..ScenarioSpec::paper()
    };
    let want = spec.expected_slowdown();
    let runner = ScenarioRunner::new(spec, &ds);
    let mut nominal = 0.0f64;
    let mut occupied = 0.0f64;
    for seed in 0..8u64 {
        let cfg = DesConfig {
            record_blocks: false,
            event_capacity: 1 << 14,
            // generous budget so late lanes still transmit
            ..DesConfig::paper(30, 5.0, 20_000.0, 400 + seed)
        };
        let run = runner.run(&cfg).unwrap();
        let mut sent_at = 0.0f64;
        let mut payload_at_send = 0.0f64;
        for e in &run.events {
            match e.kind {
                EventKind::BlockSent { payload, .. } => {
                    sent_at = e.t;
                    payload_at_send = payload as f64 + cfg.n_o;
                }
                EventKind::BlockDelivered { .. } => {
                    nominal += payload_at_send;
                    occupied += e.t - sent_at;
                }
                _ => {}
            }
        }
    }
    let measured = occupied / nominal;
    assert!(
        (measured - want).abs() < 0.08 * want,
        "event-stream slowdown {measured} vs aggregate closed form {want}"
    );
}

#[test]
fn ge_belief_estimator_converges_to_the_stationary_distribution() {
    // drive the belief filter with a long observed trace of the true
    // channel: the mean posterior P(bad) must converge to the chain's
    // stationary distribution (tower property: E[posterior] = P(bad)),
    // and — since the two states have distinct rates, which identify
    // the state from timing — the posterior must track the realized
    // state almost perfectly packet by packet.
    let mut ge = bursty();
    let params = GeParams::new(
        0.2,
        0.5,
        LinkState::new(1.0, 0.05),
        LinkState::new(0.5, 0.6),
    );
    let mut est = GeBeliefEstimator::new(params);
    let want = ge.stationary_p_bad(); // 2/7
    let mut rng = Pcg32::new(512, 4);
    let trials = 30_000usize;
    let mut belief_sum = 0.0f64;
    let mut tracked = 0usize;
    let mut slowdown_sum = 0.0f64;
    for _ in 0..trials {
        let d = ge.transmit(0.0, 1.0, &mut rng);
        est.observe(&PacketObs {
            nominal: 1.0,
            occupancy: d.arrival,
            attempts: d.attempts,
        });
        belief_sum += est.belief();
        tracked += usize::from((est.belief() > 0.5) == ge.is_bad());
        slowdown_sum += est.horizon_slowdown(1e9);
    }
    let mean_belief = belief_sum / trials as f64;
    // autocorrelated chain: same tolerance rationale as the
    // stationary-p(bad) Monte-Carlo test above
    assert!(
        (mean_belief - want).abs() < 0.02,
        "mean posterior {mean_belief} vs stationary {want}"
    );
    let track_rate = tracked as f64 / trials as f64;
    assert!(
        track_rate > 0.95,
        "rate-identified states should be tracked: {track_rate}"
    );
    // the long-horizon slowdown forecast averages to the closed form
    let mean_slowdown = slowdown_sum / trials as f64;
    let want_slowdown = ge.expected_slowdown();
    assert!(
        (mean_slowdown - want_slowdown).abs() < 0.05 * want_slowdown,
        "mean forecast {mean_slowdown} vs closed form {want_slowdown}"
    );
}

#[test]
fn erasure_is_the_degenerate_fading_aggregate() {
    // sanity tie between the layers: a single-lane "aggregate" is the
    // lane's own closed form, and the fading p_gb=0 lane equals erasure
    let er = ChannelSpec::Erasure { p: 0.25 }.expected_slowdown();
    assert!((aggregate_slowdown(&[er], &[1.0]) - er).abs() < 1e-12);
    let pinned = ChannelSpec::Fading {
        p_gb: 0.0,
        p_bg: 0.7,
        p_good: 0.25,
        p_bad: 0.9,
        rate_good: 1.0,
        rate_bad: 0.25,
    }
    .expected_slowdown();
    assert!((pinned - er).abs() < 1e-12);
    // and the two channels consume the RNG stream identically
    let mut a = ErasureChannel::new(0.25);
    let mut b = GilbertElliottChannel::new(
        0.0,
        0.7,
        LinkState::new(1.0, 0.25),
        LinkState::new(0.25, 0.9),
    );
    let mut rng_a = Pcg32::new(5, 4);
    let mut rng_b = Pcg32::new(5, 4);
    for i in 0..500 {
        let t = i as f64;
        assert_eq!(
            a.transmit(t, 2.0, &mut rng_a),
            b.transmit(t, 2.0, &mut rng_b),
            "packet {i}"
        );
    }
    // ideal lanes cannot slow anything down
    let mut ideal = IdealChannel;
    let mut rng = Pcg32::new(6, 4);
    assert_eq!(ideal.transmit(1.0, 3.0, &mut rng).arrival, 4.0);
}
