//! Minimal data-parallel helpers over `std::thread` (no rayon offline).
//!
//! The sweep runner fans Monte-Carlo trials over cores with
//! [`parallel_map`]; work is distributed by an atomic cursor so uneven
//! trial costs (e.g. different `n_c` values) still balance.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default (respects
/// `EDGEPIPE_THREADS`, else available parallelism, capped at 16).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("EDGEPIPE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get().min(16))
        .unwrap_or(4)
}

/// Apply `f` to every item of `items` using `threads` workers, preserving
/// input order in the returned vector. `f` must be `Sync` (called from
/// many threads) and items are taken by reference.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<R>>> =
        Mutex::new((0..items.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                results.lock().unwrap()[i] = Some(r);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker missed an item"))
        .collect()
}

/// Run `n` independent jobs `f(0..n)` in parallel, collecting results in
/// index order. Convenience wrapper over [`parallel_map`].
pub fn parallel_tasks<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    parallel_map(&idx, threads, |&i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn tasks_by_index() {
        let out = parallel_tasks(10, 4, |i| i * i);
        assert_eq!(out, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |&x| x).is_empty());
    }
}
