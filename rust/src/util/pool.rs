//! Minimal data-parallel helpers over `std::thread` (no rayon offline).
//!
//! The sweep runner fans Monte-Carlo trials over cores with
//! [`parallel_map`]; work is distributed by an atomic cursor so uneven
//! trial costs (e.g. different `n_c` values) still balance. A panicking
//! task no longer poisons the shared results mutex and silently kills
//! the whole sweep: the first panic is captured, the pool drains, and
//! the panic is re-raised on the caller with the originating task index.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default (respects
/// `EDGEPIPE_THREADS`, else available parallelism, capped at 16).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("EDGEPIPE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get().min(16))
        .unwrap_or(4)
}

/// Apply `f` to every item of `items` using `threads` workers, preserving
/// input order in the returned vector. `f` must be `Sync` (called from
/// many threads) and items are taken by reference.
///
/// If `f` panics for some item, the remaining workers stop picking up
/// new work and the panic is re-raised here, prefixed with the failing
/// task's index (payloads that aren't strings are re-raised verbatim).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let results: Mutex<Vec<Option<R>>> =
        Mutex::new((0..items.len()).map(|_| None).collect());
    let first_panic: Mutex<Option<(usize, Box<dyn std::any::Any + Send>)>> =
        Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                // catch the panic HERE so the results mutex is never
                // poisoned and sibling tasks finish cleanly
                match catch_unwind(AssertUnwindSafe(|| f(&items[i]))) {
                    Ok(r) => results.lock().unwrap()[i] = Some(r),
                    Err(payload) => {
                        abort.store(true, Ordering::Relaxed);
                        let mut slot = first_panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some((i, payload));
                        }
                        break;
                    }
                }
            });
        }
    });
    if let Some((index, payload)) = first_panic.into_inner().unwrap() {
        let message = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()));
        match message {
            Some(msg) => {
                panic!("parallel_map: task {index} panicked: {msg}")
            }
            None => resume_unwind(payload),
        }
    }
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker missed an item"))
        .collect()
}

/// Run `n` independent jobs `f(0..n)` in parallel, collecting results in
/// index order. Convenience wrapper over [`parallel_map`].
pub fn parallel_tasks<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    parallel_map(&idx, threads, |&i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn tasks_by_index() {
        let out = parallel_tasks(10, 4, |i| i * i);
        assert_eq!(out, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |&x| x).is_empty());
    }

    #[test]
    fn panic_carries_task_index() {
        let items: Vec<usize> = (0..64).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_map(&items, 4, |&x| {
                if x == 33 {
                    panic!("boom at {x}");
                }
                x
            })
        }));
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .expect("panic message should be a String");
        assert!(
            msg.contains("task 33") && msg.contains("boom at 33"),
            "unexpected panic message: {msg}"
        );
    }

    #[test]
    fn panic_does_not_lose_sibling_results_mutex() {
        // after a panicking sweep, a fresh sweep on the same pool
        // machinery still works (no poisoned global state)
        let items: Vec<usize> = (0..16).collect();
        let _ = catch_unwind(AssertUnwindSafe(|| {
            parallel_map(&items, 4, |&x| {
                if x == 0 {
                    panic!("first task dies");
                }
                x
            })
        }));
        let ok = parallel_map(&items, 4, |&x| x + 1);
        assert_eq!(ok.len(), 16);
    }
}
