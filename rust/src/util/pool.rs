//! Minimal data-parallel helpers over `std::thread` (no rayon offline).
//!
//! The sweep runner fans Monte-Carlo trials over cores with
//! [`parallel_map`] / [`parallel_map_with`]; work is distributed by an
//! atomic cursor so uneven trial costs (e.g. different `n_c` values)
//! still balance.
//!
//! Two properties make this the sweep hot path's substrate:
//!
//! * **Per-worker workspaces** — [`parallel_map_with`] hands every
//!   worker thread one long-lived `&mut W` scratch workspace for its
//!   whole share of the items, so a sweep of thousands of runs performs
//!   each run's heap allocations once per *worker*, not once per *task*
//!   (see `coordinator::scheduler::RunWorkspace`).
//! * **Lock-free result slots** — results land in pre-sized per-index
//!   slots through disjoint writes instead of a global `Mutex<Vec>`
//!   locked per task, so short tasks don't serialize on a lock.
//!
//! A panicking task no longer poisons shared state and silently kills
//! the whole sweep: the first panic is captured, the pool drains, and
//! the panic is re-raised on the caller with the originating task index.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default.
///
/// Resolution order:
/// 1. `EDGEPIPE_THREADS=<n>` — use exactly `n` workers.
/// 2. `std::thread::available_parallelism()`, capped at
///    `EDGEPIPE_MAX_THREADS` (default cap: 16). Set
///    `EDGEPIPE_MAX_THREADS` on large machines so wide scenario grids
///    are not silently capped at 16 cores.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("EDGEPIPE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    let cap = std::env::var("EDGEPIPE_MAX_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(16);
    std::thread::available_parallelism()
        .map(|n| n.get().min(cap))
        .unwrap_or(4)
}

/// Write handle over the pre-sized result slots. Each task index is
/// claimed by exactly one worker (the atomic cursor hands out unique
/// indices), so writes are disjoint; the thread scope's join provides
/// the happens-before edge back to the reader.
struct Slots<R> {
    ptr: *mut Option<R>,
    len: usize,
}

// SAFETY: workers only write through `ptr` at indices they uniquely own
// (see `Slots` docs); `&Slots` therefore never aliases a write.
unsafe impl<R: Send> Sync for Slots<R> {}

impl<R> Slots<R> {
    /// Store `value` at `index`. Caller must own `index` exclusively.
    unsafe fn write(&self, index: usize, value: R) {
        debug_assert!(index < self.len);
        *self.ptr.add(index) = Some(value);
    }
}

/// Apply `f` to every item of `items` using `threads` workers, giving
/// each worker a long-lived scratch workspace built once by `make_ws`.
/// Input order is preserved in the returned vector.
///
/// The workspace is the zero-allocation lever: a worker reuses its `W`
/// across every item it processes, so per-task heap churn amortizes to
/// (near) zero after the first task. `f` MUST be pure with respect to
/// the workspace — the result for an item may not depend on which
/// worker ran it or what ran before (asserted for scenario runs by
/// `rust/tests/scenario_parity.rs`).
///
/// If `f` panics for some item, the remaining workers stop picking up
/// new work and the panic is re-raised here, prefixed with the failing
/// task's index (payloads that aren't strings are re-raised verbatim).
pub fn parallel_map_with<T, R, W, M, F>(
    items: &[T],
    threads: usize,
    make_ws: M,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    M: Fn() -> W + Sync,
    F: Fn(&mut W, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    // one global-handle clone per fan-out, shared by reference across
    // the workers (write-only observation; see util/telemetry.rs)
    let tel = crate::util::telemetry::global();
    if threads <= 1 || items.len() <= 1 {
        let mut ws = make_ws();
        return items
            .iter()
            .map(|item| {
                tel.with(|m| m.pool.jobs.inc());
                f(&mut ws, item)
            })
            .collect();
    }
    let mut results: Vec<Option<R>> =
        (0..items.len()).map(|_| None).collect();
    let slots = Slots { ptr: results.as_mut_ptr(), len: results.len() };
    let cursor = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let first_panic: Mutex<Option<(usize, Box<dyn std::any::Any + Send>)>> =
        Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            // non-move closure: every worker shares &cursor/&abort/
            // &slots/&first_panic and the caller's &f/&make_ws
            scope.spawn(|| {
                // one workspace per worker, alive for its whole share
                let mut ws = make_ws();
                loop {
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    // catch the panic HERE so sibling tasks finish
                    // cleanly and the caller gets the task index
                    match catch_unwind(AssertUnwindSafe(|| {
                        f(&mut ws, &items[i])
                    })) {
                        // SAFETY: `i` came from the cursor, so this
                        // worker exclusively owns slot `i`.
                        Ok(r) => {
                            tel.with(|m| m.pool.jobs.inc());
                            unsafe { slots.write(i, r) }
                        }
                        Err(payload) => {
                            abort.store(true, Ordering::Relaxed);
                            let mut slot = first_panic.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some((i, payload));
                            }
                            break;
                        }
                    }
                }
            });
        }
    });
    if let Some((index, payload)) = first_panic.into_inner().unwrap() {
        let message = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()));
        match message {
            Some(msg) => {
                panic!("parallel_map: task {index} panicked: {msg}")
            }
            None => resume_unwind(payload),
        }
    }
    results
        .into_iter()
        .map(|r| r.expect("worker missed an item"))
        .collect()
}

/// Fallible variant of [`parallel_map_with`]: `f` returns `Result`, and
/// each item's outcome lands in its own slot instead of aborting the
/// pool. One bad item fails *that row only* — sibling tasks keep
/// running, and the caller decides whether the first `Err` (in input
/// order) sinks the whole fan-out or just one row (the streaming sweep
/// journal records it as an error row; `serve` turns it into an error
/// response).
///
/// This is a thin, documented wrapper: `parallel_map_with` is already
/// generic over any `R: Send`, so per-slot `Result` composes for free.
/// Panics are NOT converted to `Err` — they are still caught, the pool
/// still drains, and the first panic is re-raised with its task index
/// exactly as in [`parallel_map_with`].
pub fn try_parallel_map_with<T, R, E, W, M, F>(
    items: &[T],
    threads: usize,
    make_ws: M,
    f: F,
) -> Vec<Result<R, E>>
where
    T: Sync,
    R: Send,
    E: Send,
    M: Fn() -> W + Sync,
    F: Fn(&mut W, &T) -> Result<R, E> + Sync,
{
    parallel_map_with(items, threads, make_ws, f)
}

/// Apply `f` to every item of `items` using `threads` workers, preserving
/// input order in the returned vector (workspace-free convenience over
/// [`parallel_map_with`]).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_with(items, threads, || (), |_, item| f(item))
}

/// Run `n` independent jobs `f(0..n)` in parallel, collecting results in
/// index order. Convenience wrapper over [`parallel_map`].
pub fn parallel_tasks<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    parallel_map(&idx, threads, |&i| f(i))
}

/// Run `n` indexed jobs with per-worker workspaces. Convenience wrapper
/// over [`parallel_map_with`].
pub fn parallel_tasks_with<R, W, M, F>(
    n: usize,
    threads: usize,
    make_ws: M,
    f: F,
) -> Vec<R>
where
    R: Send,
    M: Fn() -> W + Sync,
    F: Fn(&mut W, usize) -> R + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    parallel_map_with(&idx, threads, make_ws, |ws, &i| f(ws, i))
}

/// A boxed job handed to a shard worker. The `'static` bound is a
/// *runtime* lie maintained by [`ShardPool`]: jobs are transmuted from a
/// caller-chosen lifetime and the dispatching call blocks on the job's
/// ack before returning, so every borrow the job captures strictly
/// outlives its execution (the scoped-thread discipline, enforced by a
/// barrier instead of a scope).
type ShardJob = Box<dyn FnOnce() + Send + 'static>;

enum ShardAck {
    Done,
    Panicked(Box<dyn std::any::Any + Send>),
}

struct ShardWorker {
    tx: Option<
        std::sync::mpsc::Sender<(ShardJob, std::sync::mpsc::Sender<ShardAck>)>,
    >,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ShardWorker {
    fn sender(
        &self,
    ) -> &std::sync::mpsc::Sender<(ShardJob, std::sync::mpsc::Sender<ShardAck>)>
    {
        self.tx.as_ref().expect("shard worker already shut down")
    }
}

/// Long-lived shard worker threads for the sharded DES source
/// (`coordinator::shard`): worker `s` owns shard `s`'s node-local state
/// for the lifetime of the pool, and every piece of that state is only
/// ever touched from its owning thread.
///
/// Unlike [`parallel_map_with`] — one scoped fan-out per call — a
/// `ShardPool` keeps its threads alive across many dispatches, so the
/// per-event cost is one channel round-trip, not a thread spawn. Jobs
/// may borrow caller-local data: [`ShardPool::run_on`] and
/// [`ShardPool::run_all`] block until every dispatched job has finished
/// (and been dropped) before returning, which is exactly the guarantee
/// a `std::thread::scope` join provides — see [`ShardJob`].
///
/// Panic discipline matches `parallel_map_with`: a panicking job is
/// caught on the worker, the barrier still completes (sibling jobs
/// finish, no lock is poisoned, the worker thread survives for the next
/// dispatch), and the first panic in job order is re-raised on the
/// caller prefixed with the shard index (non-string payloads verbatim).
pub struct ShardPool {
    workers: Vec<ShardWorker>,
    /// Global telemetry handle cloned once at pool construction; every
    /// dispatch is then a single branch when telemetry is detached.
    tel: crate::util::telemetry::Telemetry,
}

impl ShardPool {
    /// Spawn `shards` long-lived worker threads (at least one).
    pub fn new(shards: usize) -> ShardPool {
        let workers = (0..shards.max(1))
            .map(|s| {
                let (tx, rx) = std::sync::mpsc::channel::<(
                    ShardJob,
                    std::sync::mpsc::Sender<ShardAck>,
                )>();
                let handle = std::thread::Builder::new()
                    .name(format!("edgepipe-shard-{s}"))
                    .spawn(move || {
                        // exits when the pool (the only sender) drops
                        while let Ok((job, ack)) = rx.recv() {
                            // `catch_unwind` consumes the job, so its
                            // captured borrows are dead before the ack
                            // releases the caller
                            let result =
                                catch_unwind(AssertUnwindSafe(job));
                            let msg = match result {
                                Ok(()) => ShardAck::Done,
                                Err(payload) => ShardAck::Panicked(payload),
                            };
                            // a dropped ack receiver means the caller
                            // itself is unwinding; nothing to do
                            let _ = ack.send(msg);
                        }
                    })
                    .expect("failed to spawn shard worker thread");
                ShardWorker { tx: Some(tx), handle: Some(handle) }
            })
            .collect();
        ShardPool { workers, tel: crate::util::telemetry::global() }
    }

    /// Worker threads in this pool.
    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Run `job` to completion on shard worker `shard`, blocking until
    /// it finishes. A panic inside the job is re-raised here.
    pub fn run_on<'scope>(
        &self,
        shard: usize,
        job: Box<dyn FnOnce() + Send + 'scope>,
    ) {
        // SAFETY: same-layout fat pointers differing only in lifetime;
        // the blocking ack below keeps every borrow in `job` alive past
        // its execution (see `ShardJob`).
        let job: ShardJob = unsafe { std::mem::transmute(job) };
        let (ack_tx, ack_rx) = std::sync::mpsc::channel();
        self.tel.with(|m| m.pool.shard_queue.add(1));
        // wall clock flows write-only into the histogram; never read it
        // unless telemetry is attached
        let t0 = self.tel.is_attached().then(std::time::Instant::now);
        self.workers[shard]
            .sender()
            .send((job, ack_tx))
            .expect("shard worker channel closed");
        let ack = ack_rx.recv().expect("shard worker died mid-job");
        self.tel.with(|m| {
            m.pool.shard_queue.sub(1);
            m.pool.shard_jobs.inc();
            m.pool.barrier_waits.inc();
            if let Some(t0) = t0 {
                m.pool.barrier_wait.record(t0.elapsed());
            }
        });
        match ack {
            ShardAck::Done => {}
            ShardAck::Panicked(payload) => raise_shard_panic(shard, payload),
        }
    }

    /// Run one job per shard worker (`jobs[s]` on worker `s`; pass
    /// `None` to skip a shard), blocking until ALL of them finish. The
    /// barrier always completes before any panic is re-raised, so
    /// sibling jobs never observe a half-torn-down caller frame.
    pub fn run_all<'scope>(
        &self,
        jobs: Vec<Option<Box<dyn FnOnce() + Send + 'scope>>>,
    ) {
        assert!(
            jobs.len() <= self.workers.len(),
            "more jobs than shard workers"
        );
        // one ack channel per dispatched job, received back in job
        // order, so the barrier is complete before any re-raise and the
        // FIRST panic in job order wins deterministically
        let mut acks: Vec<(usize, std::sync::mpsc::Receiver<ShardAck>)> =
            Vec::with_capacity(jobs.len());
        for (s, job) in jobs.into_iter().enumerate() {
            let Some(job) = job else { continue };
            // SAFETY: as in `run_on` — the loop below blocks on every
            // dispatched job's ack before this call returns.
            let job: ShardJob = unsafe { std::mem::transmute(job) };
            let (ack_tx, ack_rx) = std::sync::mpsc::channel();
            self.tel.with(|m| m.pool.shard_queue.add(1));
            self.workers[s]
                .sender()
                .send((job, ack_tx))
                .expect("shard worker channel closed");
            acks.push((s, ack_rx));
        }
        let dispatched = acks.len() as u64;
        let t0 = self.tel.is_attached().then(std::time::Instant::now);
        let mut first_panic: Option<(usize, Box<dyn std::any::Any + Send>)> =
            None;
        for (s, ack_rx) in acks {
            match ack_rx.recv().expect("shard worker died mid-job") {
                ShardAck::Done => {}
                ShardAck::Panicked(payload) => {
                    if first_panic.is_none() {
                        first_panic = Some((s, payload));
                    }
                }
            }
            self.tel.with(|m| {
                m.pool.shard_queue.sub(1);
                m.pool.shard_jobs.inc();
            });
        }
        self.tel.with(|m| {
            if dispatched > 0 {
                m.pool.barrier_waits.inc();
                if let Some(t0) = t0 {
                    m.pool.barrier_wait.record(t0.elapsed());
                }
            }
        });
        if let Some((s, payload)) = first_panic {
            raise_shard_panic(s, payload);
        }
    }
}

fn raise_shard_panic(shard: usize, payload: Box<dyn std::any::Any + Send>) -> ! {
    let message = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()));
    match message {
        Some(msg) => panic!("shard pool: shard {shard} panicked: {msg}"),
        None => resume_unwind(payload),
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // closing the job channel ends each worker's recv loop
        for w in &mut self.workers {
            w.tx.take();
            if let Some(handle) = w.handle.take() {
                // workers catch job panics, so join only fails if a
                // worker died outside a job; don't double-panic in Drop
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn tasks_by_index() {
        let out = parallel_tasks(10, 4, |i| i * i);
        assert_eq!(out, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |&x| x).is_empty());
    }

    #[test]
    fn workspaces_are_per_worker_not_per_task() {
        // the number of workspace constructions is bounded by the worker
        // count, NOT the item count — the whole point of the pool
        let built = AtomicUsize::new(0);
        let items: Vec<usize> = (0..300).collect();
        let threads = 4;
        let out = parallel_map_with(
            &items,
            threads,
            || {
                built.fetch_add(1, Ordering::Relaxed);
                Vec::<usize>::new()
            },
            |ws, &x| {
                ws.push(x); // workspace accumulates across tasks
                x + 1
            },
        );
        assert_eq!(out, (1..=300).collect::<Vec<_>>());
        let n_built = built.load(Ordering::Relaxed);
        assert!(
            n_built >= 1 && n_built <= threads,
            "built {n_built} workspaces for {threads} workers"
        );
    }

    #[test]
    fn workspace_mutation_does_not_leak_into_results() {
        // results must be a pure function of the item, independent of
        // scheduling (compare against the single-threaded run)
        let items: Vec<u64> = (0..64).collect();
        let run = |threads| {
            parallel_map_with(
                &items,
                threads,
                || 0u64,
                |acc, &x| {
                    *acc = acc.wrapping_add(x); // stateful scratch
                    x * 3 + 1 // ...but the result ignores it
                },
            )
        };
        assert_eq!(run(1), run(7));
    }

    #[test]
    fn panic_carries_task_index() {
        let items: Vec<usize> = (0..64).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_map(&items, 4, |&x| {
                if x == 33 {
                    panic!("boom at {x}");
                }
                x
            })
        }));
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .expect("panic message should be a String");
        assert!(
            msg.contains("task 33") && msg.contains("boom at 33"),
            "unexpected panic message: {msg}"
        );
    }

    #[test]
    fn panic_does_not_lose_sibling_results_mutex() {
        // after a panicking sweep, a fresh sweep on the same pool
        // machinery still works (no poisoned global state)
        let items: Vec<usize> = (0..16).collect();
        let _ = catch_unwind(AssertUnwindSafe(|| {
            parallel_map(&items, 4, |&x| {
                if x == 0 {
                    panic!("first task dies");
                }
                x
            })
        }));
        let ok = parallel_map(&items, 4, |&x| x + 1);
        assert_eq!(ok.len(), 16);
    }

    #[test]
    fn with_workspace_panic_carries_index_and_leaves_pool_reusable() {
        // regression for the PR 1 fix, exercised through the WORKSPACE
        // entry point the sweeps actually use: a panicking task must
        // re-raise with its index, and the same machinery must serve a
        // subsequent sweep with fresh workspaces as if nothing happened
        let items: Vec<usize> = (0..64).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_map_with(
                &items,
                4,
                || Vec::<usize>::with_capacity(8),
                |ws, &x| {
                    ws.push(x);
                    if x == 21 {
                        panic!("workspace task blew up at {x}");
                    }
                    x * 2
                },
            )
        }));
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .expect("panic message should be a String");
        assert!(
            msg.contains("task 21") && msg.contains("blew up at 21"),
            "unexpected panic message: {msg}"
        );
        // the pool machinery (and workspace construction) still works
        let built = AtomicUsize::new(0);
        let ok = parallel_map_with(
            &items,
            4,
            || {
                built.fetch_add(1, Ordering::Relaxed);
                0u64
            },
            |_, &x| x + 1,
        );
        assert_eq!(ok, (1..=64).collect::<Vec<_>>());
        assert!(built.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn non_string_panic_payload_is_resumed_verbatim() {
        // payloads that aren't strings can't be prefixed with the task
        // index — they must be re-raised unchanged, not swallowed
        let items: Vec<usize> = (0..8).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_map_with(
                &items,
                2,
                || (),
                |_, &x| {
                    if x == 3 {
                        std::panic::panic_any(1337usize);
                    }
                    x
                },
            )
        }));
        let payload = result.unwrap_err();
        let code = payload
            .downcast_ref::<usize>()
            .expect("typed payload must survive the re-raise");
        assert_eq!(*code, 1337);
    }

    #[test]
    fn try_map_one_error_does_not_abort_siblings() {
        // the whole point of the fallible variant: an Err row is data,
        // not a pool abort — every other slot still completes
        let items: Vec<usize> = (0..64).collect();
        let out = try_parallel_map_with(
            &items,
            4,
            || (),
            |_, &x| {
                if x == 17 {
                    Err(format!("bad item {x}"))
                } else {
                    Ok(x * 2)
                }
            },
        );
        assert_eq!(out.len(), 64);
        for (i, r) in out.iter().enumerate() {
            if i == 17 {
                assert_eq!(r.as_deref(), Err("bad item 17"));
            } else {
                assert_eq!(*r, Ok(i * 2), "sibling {i} must complete");
            }
        }
    }

    #[test]
    fn try_map_preserves_order_with_workspaces() {
        let items: Vec<usize> = (0..97).collect();
        let out: Vec<Result<usize, String>> = try_parallel_map_with(
            &items,
            3,
            || 0usize,
            |scratch, &x| {
                *scratch += 1; // stateful scratch must not leak
                Ok(x + 1)
            },
        );
        let want: Vec<Result<usize, String>> =
            (1..=97).map(Ok).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn shard_pool_runs_borrowed_jobs_to_completion() {
        // the whole soundness story: a job may borrow caller locals
        // because run_on blocks until the job (and its borrows) is done
        let pool = ShardPool::new(3);
        assert_eq!(pool.shards(), 3);
        let mut data = vec![0u64; 8];
        for round in 1..=4u64 {
            let slice = &mut data;
            pool.run_on(
                (round as usize) % 3,
                Box::new(move || {
                    for v in slice.iter_mut() {
                        *v += round;
                    }
                }),
            );
        }
        assert_eq!(data, vec![1 + 2 + 3 + 4; 8]);
    }

    #[test]
    fn shard_pool_run_all_mutates_disjoint_slices() {
        let pool = ShardPool::new(4);
        let mut data: Vec<usize> = vec![0; 12];
        {
            let mut rest = data.as_mut_slice();
            let mut jobs: Vec<Option<Box<dyn FnOnce() + Send + '_>>> =
                Vec::new();
            for s in 0..4 {
                let (mine, tail) = rest.split_at_mut(3);
                rest = tail;
                jobs.push(Some(Box::new(move || {
                    for (i, v) in mine.iter_mut().enumerate() {
                        *v = s * 100 + i;
                    }
                })));
            }
            pool.run_all(jobs);
        }
        let want: Vec<usize> = (0..4)
            .flat_map(|s| (0..3).map(move |i| s * 100 + i))
            .collect();
        assert_eq!(data, want);
    }

    #[test]
    fn shard_pool_panic_carries_shard_index_and_pool_survives() {
        let pool = ShardPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run_on(1, Box::new(|| panic!("shard job died")));
        }));
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .expect("panic message should be a String");
        assert!(
            msg.contains("shard 1") && msg.contains("shard job died"),
            "unexpected panic message: {msg}"
        );
        // the worker thread caught the panic and is still serving jobs
        let mut ran = false;
        pool.run_on(1, Box::new(|| ran = true));
        assert!(ran, "worker must survive a panicking job");
    }

    #[test]
    fn shard_pool_run_all_finishes_siblings_before_reraising() {
        let pool = ShardPool::new(3);
        let done = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Option<Box<dyn FnOnce() + Send + '_>>> = (0..3)
                .map(|s| {
                    let done = &done;
                    Some(Box::new(move || {
                        if s == 0 {
                            panic!("first shard dies");
                        }
                        done.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>)
                })
                .collect();
            pool.run_all(jobs);
        }));
        assert!(result.is_err(), "panic must propagate");
        // the barrier completed: both sibling jobs ran to completion
        assert_eq!(done.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn default_threads_is_positive() {
        // EDGEPIPE_MAX_THREADS itself can't be exercised here (setting
        // process-global env in parallel tests races); the parse/cap
        // logic is covered by CI runs with the vars exported
        assert!(default_threads() >= 1);
    }
}
