//! General-purpose substrates built in-repo (the offline image vendors only
//! `xla` + `anyhow`, so RNG, JSON, stats, threading and time formatting are
//! all implemented and tested here).

pub mod alloc;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod telemetry;
pub mod timefmt;
