//! Human-readable duration / rate formatting for bench and CLI output.

use std::time::Duration;

/// Format a duration adaptively: ns / µs / ms / s.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Format an operations-per-second rate adaptively.
pub fn fmt_rate(ops_per_sec: f64) -> String {
    if ops_per_sec >= 1e9 {
        format!("{:.2} Gop/s", ops_per_sec / 1e9)
    } else if ops_per_sec >= 1e6 {
        format!("{:.2} Mop/s", ops_per_sec / 1e6)
    } else if ops_per_sec >= 1e3 {
        format!("{:.2} Kop/s", ops_per_sec / 1e3)
    } else {
        format!("{ops_per_sec:.2} op/s")
    }
}

/// Format a count with thousands separators (1234567 -> "1,234,567").
pub fn fmt_count(n: u64) -> String {
    let digits = n.to_string();
    let mut out = String::with_capacity(digits.len() + digits.len() / 3);
    for (i, c) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00 s");
    }

    #[test]
    fn rates() {
        assert_eq!(fmt_rate(2.5e6), "2.50 Mop/s");
        assert_eq!(fmt_rate(999.0), "999.00 op/s");
    }

    #[test]
    fn counts() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1_234_567), "1,234,567");
    }
}
