//! Deterministic pseudo-random number generation (PCG-XSH-RR 64/32).
//!
//! Every stochastic component in the crate — dataset synthesis, the
//! device's without-replacement sample selection, the edge node's i.i.d.
//! draws for SGD (paper eq. (2)), Monte-Carlo sweeps — draws from this
//! generator, keyed by an explicit `u64` seed, so every run is exactly
//! reproducible and the threaded coordinator can be made bit-identical to
//! the discrete-event fast path.
//!
//! Reference: M. E. O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation" (2014).
//! Output function XSH-RR on a 64-bit LCG state; passes the reference test
//! vectors (see tests below).

const MULTIPLIER: u64 = 6364136223846793005;

/// PCG-XSH-RR 64/32 generator with an explicit stream id.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from `(seed, stream)`. Different streams with the
    /// same seed are independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        let _ = rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        let _ = rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (used to give each
    /// block/thread its own stream while keeping runs reproducible).
    pub fn split(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream)
    }

    /// Next 32 uniformly random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULTIPLIER).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` — Lemire's multiply-shift with
    /// rejection (unbiased, and ~2× faster than the modulo method: the
    /// common case costs one 64×64→128 multiply and no division).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            // rejection threshold: 2^64 mod bound (single division, only
            // on the rare low-fringe path)
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (no cached spare: keeps the stream
    /// position a pure function of the number of draws).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        let n = slice.len();
        for i in (1..n).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            slice.swap(i, j);
        }
    }

    /// Sample `k` distinct indices uniformly from `[0, n)` (partial
    /// Fisher–Yates; O(n) memory, O(k) swaps). Used by the device to pick
    /// which untransmitted samples go into the next block (paper Sec. 2).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vector from the PCG paper's minimal C implementation
    /// (pcg32_srandom(42, 54); six outputs).
    #[test]
    fn matches_reference_vectors() {
        let mut rng = Pcg32::new(42, 54);
        let expected: [u32; 6] = [
            0xa15c02b7, 0x7b47f409, 0xba1d3330, 0x83d2f293, 0xbfa4784b,
            0xcbed606e,
        ];
        for e in expected {
            assert_eq!(rng.next_u32(), e);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u32> = (0..16).map({
            let mut r = Pcg32::seeded(7);
            move |_| r.next_u32()
        }).collect();
        let b: Vec<u32> = (0..16).map({
            let mut r = Pcg32::seeded(7);
            move |_| r.next_u32()
        }).collect();
        assert_eq!(a, b);
        let c: Vec<u32> = (0..16).map({
            let mut r = Pcg32::seeded(8);
            move |_| r.next_u32()
        }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Pcg32::seeded(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.gen_range(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut rng = Pcg32::seeded(2);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.next_f64();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::seeded(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.next_gaussian();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 2e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 2e-2, "var={var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_uniformish() {
        let mut rng = Pcg32::seeded(4);
        let got = rng.sample_distinct(100, 40);
        assert_eq!(got.len(), 40);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40, "duplicates in sample");
        assert!(sorted.iter().all(|&i| i < 100));
        // frequency check: each index appears with prob 0.4
        let mut counts = [0u32; 100];
        let mut r = Pcg32::seeded(5);
        for _ in 0..2000 {
            for i in r.sample_distinct(100, 40) {
                counts[i] += 1;
            }
        }
        for &c in &counts {
            let p = c as f64 / 2000.0;
            assert!((p - 0.4).abs() < 0.06, "p={p}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(6);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Pcg32::seeded(9);
        let mut a = parent.split(1);
        let mut b = parent.split(2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }
}
