//! A counting global allocator for allocation-budget benchmarks.
//!
//! The sweep engine's contract is *zero heap allocations per run after
//! warm-up*; [`CountingAllocator`] lets `edgepipe bench` and
//! `rust/benches/bench_sweep.rs` measure that instead of asserting it.
//! Binaries opt in with:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: edgepipe::util::alloc::CountingAllocator =
//!     edgepipe::util::alloc::CountingAllocator;
//! ```
//!
//! and call [`mark_installed`] at startup so [`allocation_count`] can
//! distinguish "zero allocations" from "not counting". The counter is a
//! single relaxed atomic increment per `alloc`/`realloc` — noise next to
//! the allocation itself.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
static INSTALLED: AtomicBool = AtomicBool::new(false);

/// `System` allocator wrapper counting `alloc`/`realloc` calls.
pub struct CountingAllocator;

// SAFETY: defers entirely to `System`; the counter has no effect on the
// returned memory.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Declare that [`CountingAllocator`] is this process's global
/// allocator (call once from `main`).
pub fn mark_installed() {
    INSTALLED.store(true, Ordering::Relaxed);
}

/// Allocations counted so far, or `None` when the counting allocator is
/// not installed in this process (library consumers, tests).
pub fn allocation_count() -> Option<u64> {
    if INSTALLED.load(Ordering::Relaxed) {
        Some(ALLOCATIONS.load(Ordering::Relaxed))
    } else {
        None
    }
}

/// Normalize a phase's allocation count to a per-unit mean — e.g. per
/// Monte-Carlo run, so lane-batched phases (which amortize one gather
/// buffer and one SoA model across a whole seed-group) report on the
/// same per-run axis as the scalar engine. `None` in, or zero units,
/// yields `None`.
pub fn allocs_per_unit(allocs: Option<u64>, units: usize) -> Option<f64> {
    match (allocs, units) {
        (Some(a), u) if u > 0 => Some(a as f64 / u as f64),
        _ => None,
    }
}

/// Allocations performed while running `f`, when counting is available.
pub fn allocations_during<R>(f: impl FnOnce() -> R) -> (R, Option<u64>) {
    let before = allocation_count();
    let out = f();
    let delta = match (before, allocation_count()) {
        (Some(b), Some(a)) => Some(a - b),
        _ => None,
    };
    (out, delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_unit_normalization() {
        assert_eq!(allocs_per_unit(Some(120), 24), Some(5.0));
        assert_eq!(allocs_per_unit(Some(7), 0), None);
        assert_eq!(allocs_per_unit(None, 24), None);
    }
}
