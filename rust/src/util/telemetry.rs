//! Runtime telemetry: atomic counters, bucketed duration histograms and
//! queue-depth gauges behind a cheap [`Telemetry`] handle.
//!
//! ## Contract (load-bearing — the parity tests pin it)
//!
//! Telemetry is **write-only observation**. Instrumented code may bump
//! counters, move gauges and record wall-clock durations into histograms,
//! but telemetry must NEVER:
//!
//! - touch an RNG stream (no draws, no reseeds, no stream splits);
//! - steer control flow (no branch in simulation/sweep code may read a
//!   metric; wall-clock reads flow INTO histograms only, never back into
//!   scheduling decisions);
//! - change what bytes are written to journals, CSVs or serve replies
//!   (modulo the explicit `{"cmd":"stats"}` surface).
//!
//! Consequently per-seed losses, golden event traces and stream journal
//! rows are bit-identical with telemetry attached or detached at every
//! `EDGEPIPE_SHARDS`/`EDGEPIPE_LANES` setting — `telemetry_parity.rs`
//! asserts exactly that.
//!
//! ## Handles
//!
//! [`Telemetry`] wraps `Option<Arc<Metrics>>`: a detached handle
//! ([`Telemetry::off`]) makes every instrumentation site a single branch
//! on `None`; an attached one ([`Telemetry::attached`]) shares one
//! [`Metrics`] sink across threads via `Arc`. Layers that take options
//! structs (`StreamOptions`, `ServeState`) carry a handle explicitly;
//! parameter-less layers (the scheduler core, `util/pool.rs`,
//! `coordinator/shard.rs`) consult the process-global handle installed by
//! [`install`] — [`global`] is a relaxed-atomic fast path when nothing is
//! installed, so the default cost is one predictable load.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::json::{num, obj, Value};

/// Monotone event counter (relaxed atomics: totals, not ordering).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Signed level gauge (queue occupancy) with a high-water mark.
///
/// std's `mpsc` channels expose no length, so occupancy is tracked at the
/// endpoints: `+1` at every send, `-1` at every receive. Snapshots can
/// transiently disagree with the true depth by in-flight items; the
/// high-water mark is monotone and exact up to the same race.
#[derive(Default)]
pub struct Gauge {
    level: AtomicI64,
    max: AtomicI64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge { level: AtomicI64::new(0), max: AtomicI64::new(0) }
    }

    pub fn add(&self, n: i64) {
        let now = self.level.fetch_add(n, Ordering::Relaxed) + n;
        if n > 0 {
            self.max.fetch_max(now, Ordering::Relaxed);
        }
    }

    pub fn sub(&self, n: i64) {
        self.add(-n);
    }

    pub fn get(&self) -> i64 {
        self.level.load(Ordering::Relaxed)
    }

    pub fn high_water(&self) -> i64 {
        self.max.load(Ordering::Relaxed)
    }
}

/// Number of histogram buckets: bucket 0 holds exact zeros, bucket `i`
/// (1..) holds durations in `[2^(i-1), 2^i)` nanoseconds. 40 buckets
/// cover up to ~9.2 minutes; anything longer clamps into the last one.
pub const HIST_BUCKETS: usize = 40;

/// Bucket index for a duration of `nanos`: 0 for 0, else
/// `floor(log2(nanos)) + 1`, clamped to `HIST_BUCKETS - 1`.
pub fn bucket_index(nanos: u64) -> usize {
    if nanos == 0 {
        return 0;
    }
    let idx = 64 - nanos.leading_zeros() as usize;
    idx.min(HIST_BUCKETS - 1)
}

/// Lower bound (inclusive) of bucket `i` in nanoseconds.
pub fn bucket_floor(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// Power-of-two duration histogram. `record` is wait-free (three relaxed
/// atomic adds); the snapshot reports count, total and non-empty buckets.
#[derive(Default)]
pub struct Histogram {
    count: AtomicU64,
    total_ns: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        self.record_ns(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    pub fn record_ns(&self, nanos: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(nanos, Ordering::Relaxed);
        self.buckets[bucket_index(nanos)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn total_ns(&self) -> u64 {
        self.total_ns.load(Ordering::Relaxed)
    }

    /// Mean recorded duration in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.total_ns() as f64 / n as f64
        }
    }

    /// `{"count", "total_ns", "mean_ns", "buckets": [[floor_ns, n], ..]}`
    /// with only non-empty buckets listed (ascending).
    fn snapshot(&self) -> Value {
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then(|| {
                    Value::Arr(vec![
                        num(bucket_floor(i) as f64),
                        num(n as f64),
                    ])
                })
            })
            .collect();
        obj(vec![
            ("count", num(self.count() as f64)),
            ("total_ns", num(self.total_ns() as f64)),
            ("mean_ns", num(self.mean_ns())),
            ("buckets", Value::Arr(buckets)),
        ])
    }
}

/// Scheduler-core totals, folded in once per completed run from the
/// scheduler's own `RunStats` (no hot-loop instrumentation needed).
#[derive(Default)]
pub struct SchedMetrics {
    pub runs: Counter,
    pub events: Counter,
    pub packets_sent: Counter,
    pub packets_resent: Counter,
    pub timeouts: Counter,
    pub evictions: Counter,
}

impl SchedMetrics {
    fn snapshot(&self) -> Value {
        obj(vec![
            ("runs", num(self.runs.get() as f64)),
            ("events", num(self.events.get() as f64)),
            ("packets_sent", num(self.packets_sent.get() as f64)),
            ("packets_resent", num(self.packets_resent.get() as f64)),
            ("timeouts", num(self.timeouts.get() as f64)),
            ("evictions", num(self.evictions.get() as f64)),
        ])
    }
}

/// Thread-pool / shard-pool activity.
#[derive(Default)]
pub struct PoolMetrics {
    /// Closures executed by `parallel_map_with` workers.
    pub jobs: Counter,
    /// Ack barriers crossed by `ShardPool::run_on`/`run_all`.
    pub barrier_waits: Counter,
    /// Wall time the caller spent blocked on shard acks.
    pub barrier_wait: Histogram,
    /// Outstanding commands across shard queues (send +1 / ack -1).
    pub shard_queue: Gauge,
    /// Commands executed by shard workers.
    pub shard_jobs: Counter,
    /// Lane block draws through `ShardedSource` (inline or pooled).
    pub shard_draws: Counter,
    /// Lane evict-clears through `ShardedSource` (inline or pooled).
    pub shard_evicts: Counter,
}

impl PoolMetrics {
    fn snapshot(&self) -> Value {
        obj(vec![
            ("jobs", num(self.jobs.get() as f64)),
            ("barrier_waits", num(self.barrier_waits.get() as f64)),
            ("barrier_wait_ns", self.barrier_wait.snapshot()),
            ("shard_queue_depth", num(self.shard_queue.get() as f64)),
            (
                "shard_queue_high_water",
                num(self.shard_queue.high_water() as f64),
            ),
            ("shard_jobs", num(self.shard_jobs.get() as f64)),
            ("shard_draws", num(self.shard_draws.get() as f64)),
            ("shard_evicts", num(self.shard_evicts.get() as f64)),
        ])
    }
}

/// Streaming-sweep pipeline (gen → run → metrics → aggregate).
#[derive(Default)]
pub struct StreamMetrics {
    pub groups_run: Counter,
    pub groups_reused: Counter,
    /// Rows the metrics stage has journaled (or skipped as reused) and
    /// forwarded toward the aggregator.
    pub rows_journaled: Counter,
    /// Rows the aggregator has folded into Welford accumulators.
    pub rows_aggregated: Counter,
    pub error_rows: Counter,
    /// Stage-queue occupancy: gen→run, run→metrics, metrics→aggregate.
    pub job_queue: Gauge,
    pub row_queue: Gauge,
    pub agg_queue: Gauge,
    /// Wall time per executed (non-reused) group.
    pub group_time: Histogram,
}

impl StreamMetrics {
    /// Rows forwarded by the metrics stage but not yet aggregated. Ends
    /// at 0 for every completed stream run.
    pub fn journal_lag(&self) -> u64 {
        self.rows_journaled
            .get()
            .saturating_sub(self.rows_aggregated.get())
    }

    fn snapshot(&self) -> Value {
        obj(vec![
            ("groups_run", num(self.groups_run.get() as f64)),
            ("groups_reused", num(self.groups_reused.get() as f64)),
            ("rows_journaled", num(self.rows_journaled.get() as f64)),
            ("rows_aggregated", num(self.rows_aggregated.get() as f64)),
            ("journal_lag", num(self.journal_lag() as f64)),
            ("error_rows", num(self.error_rows.get() as f64)),
            (
                "queues",
                obj(vec![
                    ("jobs", num(self.job_queue.get() as f64)),
                    ("jobs_high_water", num(self.job_queue.high_water() as f64)),
                    ("rows", num(self.row_queue.get() as f64)),
                    ("rows_high_water", num(self.row_queue.high_water() as f64)),
                    ("agg", num(self.agg_queue.get() as f64)),
                    ("agg_high_water", num(self.agg_queue.high_water() as f64)),
                ]),
            ),
            ("group_time_ns", self.group_time.snapshot()),
        ])
    }
}

/// `edgepipe serve` connection/request/cache activity.
#[derive(Default)]
pub struct ServeMetrics {
    pub connections: Counter,
    pub requests: Counter,
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    pub errors: Counter,
    /// Wall time from request line received to reply line written.
    pub reply_time: Histogram,
}

impl ServeMetrics {
    fn snapshot(&self) -> Value {
        obj(vec![
            ("connections", num(self.connections.get() as f64)),
            ("requests", num(self.requests.get() as f64)),
            ("cache_hits", num(self.cache_hits.get() as f64)),
            ("cache_misses", num(self.cache_misses.get() as f64)),
            ("errors", num(self.errors.get() as f64)),
            ("reply_time_ns", self.reply_time.snapshot()),
        ])
    }
}

/// The full metric sink, grouped by layer.
#[derive(Default)]
pub struct Metrics {
    pub sched: SchedMetrics,
    pub pool: PoolMetrics,
    pub stream: StreamMetrics,
    pub serve: ServeMetrics,
}

impl Metrics {
    /// JSON snapshot: `{"sched": .., "pool": .., "stream": .., "serve": ..}`.
    pub fn snapshot(&self) -> Value {
        obj(vec![
            ("sched", self.sched.snapshot()),
            ("pool", self.pool.snapshot()),
            ("stream", self.stream.snapshot()),
            ("serve", self.serve.snapshot()),
        ])
    }
}

/// Cheap-to-clone telemetry handle: `None` = detached (every
/// instrumentation site is one branch), `Some` = shared sink.
#[derive(Clone, Default)]
pub struct Telemetry(Option<Arc<Metrics>>);

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.is_attached() {
            "Telemetry(attached)"
        } else {
            "Telemetry(off)"
        })
    }
}

impl Telemetry {
    /// A detached handle: all instrumentation is a no-op.
    pub fn off() -> Telemetry {
        Telemetry(None)
    }

    /// A fresh attached handle with zeroed metrics.
    pub fn attached() -> Telemetry {
        Telemetry(Some(Arc::new(Metrics::default())))
    }

    pub fn is_attached(&self) -> bool {
        self.0.is_some()
    }

    /// Run `f` against the sink iff attached. The only instrumentation
    /// entry point — keeps call sites one-line and guarantees detached
    /// cost is a single branch.
    #[inline]
    pub fn with<F: FnOnce(&Metrics)>(&self, f: F) {
        if let Some(m) = &self.0 {
            f(m);
        }
    }

    /// JSON snapshot of the sink (`None` when detached).
    pub fn snapshot(&self) -> Option<Value> {
        self.0.as_ref().map(|m| m.snapshot())
    }
}

// Process-global handle for layers that cannot take a parameter
// (scheduler core, pools, sharded source). `ATTACHED` is the fast path:
// when nothing is installed, `global()` is one relaxed load and no lock.
static ATTACHED: AtomicBool = AtomicBool::new(false);
static GLOBAL: Mutex<Option<Telemetry>> = Mutex::new(None);

/// Install (or, with a detached handle, clear) the process-global
/// telemetry sink. Long-lived workers should clone the handle once via
/// [`global`] rather than re-reading it per operation.
pub fn install(t: Telemetry) {
    let on = t.is_attached();
    // Order matters on clear: drop the flag first so racing `global()`
    // callers fall back to `off` rather than locking mid-swap.
    if !on {
        ATTACHED.store(false, Ordering::SeqCst);
    }
    *GLOBAL.lock().unwrap_or_else(|e| e.into_inner()) =
        if on { Some(t) } else { None };
    if on {
        ATTACHED.store(true, Ordering::SeqCst);
    }
}

/// Clone the process-global handle (detached when none is installed).
pub fn global() -> Telemetry {
    if !ATTACHED.load(Ordering::Relaxed) {
        return Telemetry::off();
    }
    GLOBAL
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .clone()
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);

        let g = Gauge::new();
        g.add(3);
        g.sub(2);
        g.add(4);
        g.sub(5);
        assert_eq!(g.get(), 0);
        assert_eq!(g.high_water(), 5);
    }

    #[test]
    fn bucket_index_edges() {
        // bucket 0 is exactly zero; bucket i holds [2^(i-1), 2^i).
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(7), 3);
        assert_eq!(bucket_index(8), 4);
        // 2^k lands in bucket k+1, 2^k - 1 in bucket k
        for k in 1..=38u32 {
            assert_eq!(bucket_index(1u64 << k), k as usize + 1);
            assert_eq!(bucket_index((1u64 << k) - 1), k as usize);
        }
        // everything past the last bucket floor clamps
        assert_eq!(bucket_index(1u64 << 39), HIST_BUCKETS - 1);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // floors invert the index at bucket boundaries
        for i in 0..HIST_BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_floor(i)), i);
        }
    }

    #[test]
    fn histogram_counts_and_mean() {
        let h = Histogram::default();
        h.record_ns(0);
        h.record_ns(1);
        h.record_ns(1000);
        assert_eq!(h.count(), 3);
        assert_eq!(h.total_ns(), 1001);
        assert!((h.mean_ns() - 1001.0 / 3.0).abs() < 1e-9);
        let snap = h.snapshot();
        assert_eq!(snap.get("count").unwrap().as_usize().unwrap(), 3);
        let buckets = snap.get("buckets").unwrap().as_arr().unwrap();
        // 0 → bucket 0, 1 → bucket 1, 1000 → bucket 10 ⇒ three entries
        assert_eq!(buckets.len(), 3);
        assert_eq!(buckets[0].as_arr().unwrap()[0].as_f64().unwrap(), 0.0);
        assert_eq!(buckets[2].as_arr().unwrap()[0].as_f64().unwrap(), 512.0);
    }

    #[test]
    fn detached_handle_is_noop_and_snapshotless() {
        let t = Telemetry::off();
        assert!(!t.is_attached());
        let mut ran = false;
        t.with(|_| ran = true);
        assert!(!ran);
        assert!(t.snapshot().is_none());
    }

    #[test]
    fn attached_handle_shares_one_sink_across_clones() {
        let t = Telemetry::attached();
        let t2 = t.clone();
        t.with(|m| m.stream.rows_journaled.add(3));
        t2.with(|m| m.stream.rows_aggregated.add(1));
        t.with(|m| assert_eq!(m.stream.journal_lag(), 2));
        let snap = t2.snapshot().unwrap();
        let stream = snap.get("stream").unwrap();
        assert_eq!(
            stream.get("journal_lag").unwrap().as_usize().unwrap(),
            2
        );
    }

    #[test]
    fn snapshot_schema_has_all_groups() {
        let t = Telemetry::attached();
        t.with(|m| {
            m.sched.runs.inc();
            m.serve.requests.inc();
            m.pool.jobs.inc();
        });
        let snap = t.snapshot().unwrap();
        for group in ["sched", "pool", "stream", "serve"] {
            assert!(snap.get(group).is_ok(), "missing group {group}");
        }
        // round-trips through our own JSON layer
        let text = snap.to_json_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn global_install_and_clear() {
        // Serialize against other tests touching the global via a local
        // lock on the install API itself: this test is the only one that
        // installs, and it restores the detached state before exiting.
        install(Telemetry::attached());
        let g = global();
        assert!(g.is_attached());
        g.with(|m| m.pool.jobs.add(7));
        let snap = global().snapshot().unwrap();
        assert_eq!(
            snap.get("pool").unwrap().get("jobs").unwrap().as_usize().unwrap(),
            7
        );
        install(Telemetry::off());
        assert!(!global().is_attached());
    }
}
