//! Minimal JSON reader/writer (the offline image has no serde).
//!
//! Used to parse `artifacts/manifest.json` (produced by `python -m
//! compile.aot`) and to emit machine-readable experiment outputs. Supports
//! the full JSON grammar except for exotic number forms; strings support
//! the standard escapes plus `\uXXXX` (BMP only — enough for manifests and
//! our own outputs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Fetch `key` from an object, erroring with the key name if missing.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => {
                m.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
            }
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    /// Optional object lookup.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_json_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_str(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build `Value::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: number value.
pub fn num(n: f64) -> Value {
    Value::Num(n)
}

/// Convenience: string value.
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

/// Convenience: numeric array value.
pub fn num_arr(v: &[f64]) -> Value {
    Value::Arr(v.iter().map(|&n| Value::Num(n)).collect())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!(
                "expected '{}' got '{}' at byte {}",
                b as char,
                got as char,
                self.pos - 1
            );
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(map)),
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(items)),
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    anyhow!("bad \\u escape")
                                })?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow!("bad codepoint"))?,
                        );
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => bail!("control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let start = self.pos - 1;
                        self.pos = start + len;
                        let slice = self
                            .bytes
                            .get(start..self.pos)
                            .ok_or_else(|| anyhow!("truncated utf8"))?;
                        out.push_str(std::str::from_utf8(slice)?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("bad number '{text}' at byte {start}"))?;
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap(),
            &Value::Bool(false)
        );
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = obj(vec![
            ("name", s("edgepipe")),
            ("nums", num_arr(&[1.0, 2.5, -3.0])),
            ("nested", obj(vec![("ok", Value::Bool(true))])),
        ]);
        for text in [v.to_json(), v.to_json_pretty()] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
          "format": 1,
          "constants": {"d": 8, "k_max": 512},
          "artifacts": {
            "sgd_block": {
              "file": "sgd_block.hlo.txt",
              "inputs": [{"name": "w", "shape": [1, 8], "dtype": "float32"}]
            }
          }
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(
            v.get("constants").unwrap().get("d").unwrap().as_usize().unwrap(),
            8
        );
        let inputs = v
            .get("artifacts").unwrap()
            .get("sgd_block").unwrap()
            .get("inputs").unwrap();
        assert_eq!(
            inputs.as_arr().unwrap()[0].get("shape").unwrap().as_arr().unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Value::Str("Aé".into()));
        let v = Value::Str("Aé\u{1F600}".into());
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }
}
