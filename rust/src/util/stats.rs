//! Streaming and batch statistics used by the bench harness and sweeps.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Batch summary of a sample vector.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; `samples` is copied and sorted internally.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty slice");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in samples {
            w.push(x);
        }
        Summary {
            n: samples.len(),
            mean: w.mean(),
            std: w.std(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[sorted.len() - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let m = xs.iter().sum::<f64>() / 5.0;
        let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / 4.0;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.variance() - v).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentiles() {
        let sorted: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 0.5), 50.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 100.0);
        assert!((percentile_sorted(&sorted, 0.905) - 90.5).abs() < 1e-9);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }
}
