//! `edgepipe serve`: a line-delimited JSON scenario service.
//!
//! One request per line, one JSON reply per line. A request names a
//! scenario by its axis strings (the same grammar as the `scenario`
//! command flags) plus a seed range, and gets back the Monte-Carlo
//! final-loss statistics:
//!
//! ```text
//! → {"id":1,"channel":"erasure:0.1","policy":"fixed","seeds":8}
//! ← {"id":1,"ok":true,"label":"erasure:0.1|fixed|k1","n_c":437,
//!    "seed0":0,"seeds":8,"mean":…,"std":…,"sem":…,"n":8,"cache":"miss"}
//! ```
//!
//! The service is a warm cache around the sweep machinery: each
//! distinct scenario label builds its [`ScenarioRunner`] (and memoized
//! `ControlPlan`) once per session, one [`BatchWorkspace`] persists
//! across a session's requests, and identical `(label, n_c, seed0,
//! seeds)` work is deduped to a cached [`McStats`] (`"cache":"hit"`).
//! Results are bit-identical to [`mc_scenario_loss_lanes`] at the same
//! lane width — the batched engine's 0-ULP contract carries over
//! unchanged.
//!
//! # Concurrency model
//!
//! [`serve_tcp`] used to serve ONE connection at a time: a second
//! client queued behind the first's entire session. It now spawns a
//! scoped thread per connection. The result cache is the only shared
//! mutable state ([`Mutex`]-guarded, held only for a get or an
//! insert — never across a run); each connection gets its own
//! [`ServeState::session`] with private runners and workspace, so no
//! run-time state crosses threads. Two clients racing the same
//! uncached key may both compute it — they compute THE SAME BITS
//! (the 0-ULP contract), so last-writer-wins insertion is benign and
//! replies stay bit-identical to the single-session service.
//! `{"cmd":"shutdown"}` flips a flag and self-connects to unblock the
//! accept loop, so shutdown still works mid-fleet.
//!
//! Every malformed or failing request produces an `{"ok":false,
//! "error":…}` reply on its line — never a panic, never a dropped
//! connection. This is why the satellite bugfixes (fallible
//! `run_group`/`grouped_losses`, `seeds == 0` rejected at the boundary)
//! had to land with this PR: a `.expect` three layers down would have
//! been a remote crash trigger.
//!
//! Control lines: `{"cmd":"ping"}` → `{"ok":true,"pong":true}`;
//! `{"cmd":"stats"}` → `{"ok":true,"stats":…}` with the service's
//! telemetry snapshot (connections, requests, cache hit/miss, reply-time
//! histogram — see `util::telemetry` for the schema and the write-only
//! contract that keeps every other reply bit-identical);
//! `{"cmd":"shutdown"}` replies and stops the accept loop.
//!
//! [`mc_scenario_loss_lanes`]: crate::sweep::runner::mc_scenario_loss_lanes

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream,
};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::des::DesConfig;
use crate::data::Dataset;
use crate::linalg::batch::snap_lanes;
use crate::sweep::batch::{
    batch_lanes, group_jobs_iter, run_group, BatchWorkspace,
};
use crate::sweep::runner::{sweep_cfg, McStats};
use crate::sweep::scenario::{ScenarioRunner, ScenarioSpec};
use crate::sweep::stream::loss_value;
use crate::util::json::{self, num, obj, s, Value};
use crate::util::stats::Welford;
use crate::util::telemetry::Telemetry;

/// What [`ServeState::handle_line`] wants done with its reply.
pub enum ServeReply {
    /// Write the line and keep reading.
    Response(String),
    /// Write the line, then stop serving.
    Shutdown(String),
}

/// `(label, n_c, seed0, seeds)` — everything a result depends on
/// besides the shared base config.
type CacheKey = (String, usize, u64, usize);

/// Warm service state: private runners + workspace for one session,
/// plus the result cache shared (via `Arc<Mutex<…>>`) with every
/// session cloned off it by [`ServeState::session`].
pub struct ServeState<'a> {
    ds: &'a Dataset,
    base: DesConfig,
    max_seeds: usize,
    lanes: usize,
    runners: HashMap<String, ScenarioRunner<'a>>,
    cache: Arc<Mutex<HashMap<CacheKey, McStats>>>,
    bw: BatchWorkspace,
    /// Always-attached telemetry sink, shared across every session
    /// (like the cache) so `{"cmd":"stats"}` reports service-wide
    /// totals. Counters never feed back into replies (write-only
    /// observation — see `util::telemetry`), so existing replies stay
    /// bit-identical to the pre-telemetry service.
    tel: Telemetry,
}

impl<'a> ServeState<'a> {
    /// `lanes` 0 = the `EDGEPIPE_LANES` default; otherwise snapped to a
    /// supported width.
    pub fn new(
        ds: &'a Dataset,
        base: DesConfig,
        max_seeds: usize,
        lanes: usize,
    ) -> ServeState<'a> {
        ServeState {
            ds,
            base,
            max_seeds: max_seeds.max(1),
            lanes: if lanes == 0 { batch_lanes() } else { snap_lanes(lanes) },
            runners: HashMap::new(),
            cache: Arc::new(Mutex::new(HashMap::new())),
            bw: BatchWorkspace::new(),
            // a private always-attached sink (NOT the process-global
            // one: sharing that would let unrelated work pollute
            // service-wide stats, and makes test counts racy) — so
            // `{"cmd":"stats"}` always has something to report
            tel: Telemetry::attached(),
        }
    }

    /// A fresh per-connection session: same config, SAME result cache
    /// (the `Arc` is cloned, not the map), private runners and
    /// workspace. Runners rebuild lazily per session — they memoize
    /// `ControlPlan`s mutably mid-run, so sharing them across
    /// connection threads would race; the deduped McStats results are
    /// what's worth sharing.
    pub fn session(&self) -> ServeState<'a> {
        ServeState {
            ds: self.ds,
            base: self.base.clone(),
            max_seeds: self.max_seeds,
            lanes: self.lanes,
            runners: HashMap::new(),
            cache: Arc::clone(&self.cache),
            bw: BatchWorkspace::new(),
            tel: self.tel.clone(),
        }
    }

    /// Cached results so far (for logging/tests).
    pub fn cached_results(&self) -> usize {
        lock_cache(&self.cache).len()
    }

    /// The state's private sink. `edgepipe serve` installs this as the
    /// process-global sink so the scheduler/pool counters of served
    /// runs land in the same `{"cmd":"stats"}` snapshot; tests that
    /// build several states in one process skip the install and stay
    /// isolated.
    pub fn telemetry(&self) -> Telemetry {
        self.tel.clone()
    }

    /// Handle one request line. Always yields a reply line; errors
    /// become `{"ok":false,"error":…}` responses, never panics or
    /// dropped lines.
    pub fn handle_line(&mut self, line: &str) -> ServeReply {
        self.tel.with(|m| m.serve.requests.inc());
        let parsed = match json::parse(line.trim()) {
            Ok(v) => v,
            Err(e) => {
                return self
                    .error(Value::Null, &format!("bad request: {e:#}"))
            }
        };
        let id = parsed.opt("id").cloned().unwrap_or(Value::Null);
        if let Some(cmd) = parsed.opt("cmd") {
            return match cmd.as_str() {
                Ok("ping") => ServeReply::Response(
                    obj(vec![
                        ("id", id),
                        ("ok", Value::Bool(true)),
                        ("pong", Value::Bool(true)),
                    ])
                    .to_json(),
                ),
                Ok("stats") => ServeReply::Response(
                    obj(vec![
                        ("id", id),
                        ("ok", Value::Bool(true)),
                        // always-attached sink ⇒ never Null in practice
                        ("stats", self.tel.snapshot().unwrap_or(Value::Null)),
                    ])
                    .to_json(),
                ),
                Ok("shutdown") => ServeReply::Shutdown(
                    obj(vec![
                        ("id", id),
                        ("ok", Value::Bool(true)),
                        ("shutdown", Value::Bool(true)),
                    ])
                    .to_json(),
                ),
                Ok(other) => {
                    self.error(id, &format!("unknown cmd '{other}'"))
                }
                Err(_) => self.error(id, "cmd must be a string"),
            };
        }
        match self.run_request(&parsed) {
            Ok(body) => ServeReply::Response(with_id(body, id).to_json()),
            Err(e) => self.error(id, &format!("{e:#}")),
        }
    }

    /// Count and format an error reply.
    fn error(&self, id: Value, message: &str) -> ServeReply {
        self.tel.with(|m| m.serve.errors.inc());
        ServeReply::Response(error_reply(id, message))
    }

    /// Parse, validate and run (or cache-hit) one scenario request.
    fn run_request(&mut self, v: &Value) -> Result<Value> {
        let spec = ScenarioSpec::parse(
            &str_field(v, "channel", "ideal")?,
            &str_field(v, "policy", "fixed")?,
            &str_field(v, "traffic", "1")?,
            &str_field(v, "workload", "ridge")?,
            usize_field(v, "store", 0)?,
        )?;
        let seeds = usize_field(v, "seeds", 10)?;
        if seeds == 0 {
            bail!("seeds must be >= 1 (a 0-seed estimate is undefined)");
        }
        if seeds > self.max_seeds {
            bail!("seeds {} exceeds --max-seeds {}", seeds, self.max_seeds);
        }
        let seed0 = usize_field(v, "seed0", 0)? as u64;
        let n_c = usize_field(v, "n_c", self.base.n_c)?;
        if n_c == 0 || n_c > self.ds.n {
            bail!("n_c {} out of range (must be 1..={})", n_c, self.ds.n);
        }

        let label = spec.label();
        let key = (label.clone(), n_c, seed0, seeds);
        // lock only for the lookup — a run under the lock would
        // serialize every concurrent session on the slowest request
        let cached = lock_cache(&self.cache).get(&key).copied();
        let hit = cached.is_some();
        self.tel.with(|m| {
            if hit {
                m.serve.cache_hits.inc();
            } else {
                m.serve.cache_misses.inc();
            }
        });
        let stats = match cached {
            Some(stats) => stats,
            None => {
                let base = DesConfig { n_c, ..self.base.clone() };
                let ds = self.ds;
                let runner = self
                    .runners
                    .entry(label.clone())
                    .or_insert_with(|| ScenarioRunner::new(spec, ds));
                let mut w = Welford::new();
                for job in group_jobs_iter(1, seeds, self.lanes) {
                    let outs =
                        run_group(runner, &mut self.bw, job.len, |l| {
                            sweep_cfg(&base, seed0 + job.seed0 + l as u64)
                        })
                        .with_context(|| {
                            format!(
                                "{label}: seed group {}..{}",
                                seed0 + job.seed0,
                                seed0 + job.seed0 + job.len as u64
                            )
                        })?;
                    for l in 0..job.len {
                        w.push(outs[l].final_loss);
                    }
                }
                let stats = McStats::from_welford(&w);
                // two sessions racing the same key insert identical
                // bits (0-ULP determinism): last-writer-wins is benign
                lock_cache(&self.cache).insert(key, stats);
                stats
            }
        };
        Ok(obj(vec![
            ("ok", Value::Bool(true)),
            ("label", s(&label)),
            ("n_c", num(n_c as f64)),
            ("seed0", num(seed0 as f64)),
            ("seeds", num(seeds as f64)),
            ("mean", loss_value(stats.mean)),
            ("std", loss_value(stats.std)),
            ("sem", loss_value(stats.sem)),
            ("n", num(stats.n as f64)),
            ("cache", s(if hit { "hit" } else { "miss" })),
        ]))
    }
}

/// Lock the shared result cache, shrugging off poisoning: the guarded
/// map holds `Copy` stats with no cross-key invariant, so a connection
/// thread that panicked mid-insert can't have left it inconsistent,
/// and one bad client must not wedge every other session's cache.
fn lock_cache<'m>(
    cache: &'m Arc<Mutex<HashMap<CacheKey, McStats>>>,
) -> std::sync::MutexGuard<'m, HashMap<CacheKey, McStats>> {
    cache.lock().unwrap_or_else(|e| e.into_inner())
}

fn with_id(mut v: Value, id: Value) -> Value {
    if let Value::Obj(m) = &mut v {
        m.insert("id".to_string(), id);
    }
    v
}

fn error_reply(id: Value, message: &str) -> String {
    obj(vec![
        ("id", id),
        ("ok", Value::Bool(false)),
        ("error", s(message)),
    ])
    .to_json()
}

fn str_field(v: &Value, key: &str, default: &str) -> Result<String> {
    match v.opt(key) {
        Some(val) => Ok(val
            .as_str()
            .with_context(|| format!("field '{key}'"))?
            .to_string()),
        None => Ok(default.to_string()),
    }
}

fn usize_field(v: &Value, key: &str, default: usize) -> Result<usize> {
    match v.opt(key) {
        Some(val) => {
            val.as_usize().with_context(|| format!("field '{key}'"))
        }
        None => Ok(default),
    }
}

/// Serve one connection (or stdin): read request lines, write reply
/// lines, flush each. Returns `Ok(true)` when a shutdown command asked
/// the caller to stop accepting.
pub fn serve_connection<R: BufRead, W: Write>(
    state: &mut ServeState<'_>,
    reader: R,
    mut writer: W,
) -> Result<bool> {
    for line in reader.lines() {
        let line = line.context("reading request line")?;
        if line.trim().is_empty() {
            continue;
        }
        // wall clock flows write-only into the reply-time histogram —
        // it never shapes a reply
        let t0 = std::time::Instant::now();
        let (reply, stop) = match state.handle_line(&line) {
            ServeReply::Response(reply) => (reply, false),
            ServeReply::Shutdown(reply) => (reply, true),
        };
        writeln!(writer, "{reply}")?;
        writer.flush()?;
        state.tel.with(|m| m.serve.reply_time.record(t0.elapsed()));
        if stop {
            return Ok(true);
        }
    }
    Ok(false)
}

/// Accept loop: one scoped thread per connection, each driving its own
/// [`ServeState::session`] (private runners/workspace, shared result
/// cache). A dropped or erroring connection logs and keeps serving;
/// `{"cmd":"shutdown"}` from ANY client flips the stop flag and
/// self-connects to unblock `accept`, so in-flight siblings finish
/// (the scope joins them) and the loop exits.
pub fn serve_listener(
    state: &ServeState<'_>,
    listener: TcpListener,
) -> Result<()> {
    let local = listener.local_addr().context("listener address")?;
    // `local` is the BOUND address: on `0.0.0.0:<port>` (or `[::]`)
    // connecting to the unspecified IP is non-portable — some stacks
    // refuse it, leaving `accept` blocked forever after a shutdown.
    // Wake via loopback on the bound port instead.
    let wake = if local.ip().is_unspecified() {
        let ip = match local.ip() {
            IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        };
        SocketAddr::new(ip, local.port())
    } else {
        local
    };
    let shutdown = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let shutdown = &shutdown;
        for stream in listener.incoming() {
            if shutdown.load(Ordering::SeqCst) {
                break; // possibly the wake connection itself
            }
            let stream = match stream {
                Ok(stream) => stream,
                Err(e) => {
                    eprintln!("serve: accept failed: {e}");
                    continue;
                }
            };
            state.tel.with(|m| m.serve.connections.inc());
            scope.spawn(move || {
                let reader = match stream.try_clone() {
                    Ok(clone) => BufReader::new(clone),
                    Err(e) => {
                        eprintln!("serve: cloning connection: {e}");
                        return;
                    }
                };
                let mut session = state.session();
                match serve_connection(&mut session, reader, stream) {
                    Ok(true) => {
                        shutdown.store(true, Ordering::SeqCst);
                        // unblock accept() so it observes the flag
                        let _ = TcpStream::connect(wake);
                    }
                    Ok(false) => {}
                    // a bad client must not take the service down
                    Err(e) => eprintln!("serve: connection error: {e:#}"),
                }
            });
        }
    });
    Ok(())
}

/// Bind `addr` and serve it with [`serve_listener`] until a client
/// sends `{"cmd":"shutdown"}`.
pub fn serve_tcp(state: &mut ServeState<'_>, addr: &str) -> Result<()> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!("edgepipe serve: listening on {}", listener.local_addr()?);
    serve_listener(state, listener)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    fn tiny_state(ds: &Dataset) -> ServeState<'_> {
        let base = DesConfig {
            loss_every: 0,
            record_blocks: false,
            collect_snapshots: false,
            event_capacity: 0,
            ..DesConfig::paper(16, 5.0, 120.0, 7)
        };
        ServeState::new(ds, base, 64, 4)
    }

    fn reply_of(r: ServeReply) -> (String, bool) {
        match r {
            ServeReply::Response(text) => (text, false),
            ServeReply::Shutdown(text) => (text, true),
        }
    }

    #[test]
    fn control_lines_and_malformed_requests_reply_in_place() {
        let ds = synth_calhousing(&SynthSpec { n: 96, ..Default::default() });
        let mut state = tiny_state(&ds);
        let (pong, stop) =
            reply_of(state.handle_line(r#"{"id":7,"cmd":"ping"}"#));
        assert!(!stop);
        let v = json::parse(&pong).unwrap();
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("pong").unwrap(), &Value::Bool(true));

        for (line, needle) in [
            ("this is not json", "bad request"),
            (r#"{"cmd":"reboot"}"#, "unknown cmd"),
            (r#"{"cmd":3}"#, "cmd must be a string"),
            (r#"{"policy":"warp-drive"}"#, "warp-drive"),
            (r#"{"seeds":0}"#, "seeds must be >= 1"),
            (r#"{"seeds":65}"#, "--max-seeds"),
            (r#"{"n_c":0}"#, "out of range"),
            (r#"{"seeds":"three"}"#, "field 'seeds'"),
        ] {
            let (text, stop) = reply_of(state.handle_line(line));
            assert!(!stop, "{line} must not stop the service");
            let v = json::parse(&text).expect("error replies are JSON");
            assert_eq!(v.get("ok").unwrap(), &Value::Bool(false), "{line}");
            assert!(
                v.get("error").unwrap().as_str().unwrap().contains(needle),
                "{line}: wanted '{needle}' in {text}"
            );
        }

        let (bye, stop) = reply_of(state.handle_line(r#"{"cmd":"shutdown"}"#));
        assert!(stop);
        assert!(json::parse(&bye).is_ok());
    }

    #[test]
    fn identical_requests_hit_the_cache_with_identical_bits() {
        let ds = synth_calhousing(&SynthSpec { n: 96, ..Default::default() });
        let mut state = tiny_state(&ds);
        let req = r#"{"channel":"erasure:0.2","seeds":3,"seed0":2}"#;
        let (a, _) = reply_of(state.handle_line(req));
        let (b, _) = reply_of(state.handle_line(req));
        let va = json::parse(&a).unwrap();
        let vb = json::parse(&b).unwrap();
        assert_eq!(va.get("cache").unwrap().as_str().unwrap(), "miss");
        assert_eq!(vb.get("cache").unwrap().as_str().unwrap(), "hit");
        assert_eq!(state.cached_results(), 1);
        for key in ["mean", "std", "sem", "n"] {
            assert_eq!(va.get(key).unwrap(), vb.get(key).unwrap(), "{key}");
        }
        assert_eq!(va.get("n").unwrap().as_usize().unwrap(), 3);
        // a different seed window is different work, not a stale hit
        let (c, _) = reply_of(
            state.handle_line(r#"{"channel":"erasure:0.2","seeds":3}"#),
        );
        let vc = json::parse(&c).unwrap();
        assert_eq!(vc.get("cache").unwrap().as_str().unwrap(), "miss");
    }

    #[test]
    fn sessions_share_the_result_cache_but_not_runners() {
        let ds = synth_calhousing(&SynthSpec { n: 96, ..Default::default() });
        let parent = tiny_state(&ds);
        let req = r#"{"channel":"erasure:0.2","seeds":3}"#;
        let mut a = parent.session();
        let mut b = parent.session();
        let (ra, _) = reply_of(a.handle_line(req));
        // session B never ran this: the hit comes through the shared
        // cache, with the exact bits session A computed
        let (rb, _) = reply_of(b.handle_line(req));
        let va = json::parse(&ra).unwrap();
        let vb = json::parse(&rb).unwrap();
        assert_eq!(va.get("cache").unwrap().as_str().unwrap(), "miss");
        assert_eq!(vb.get("cache").unwrap().as_str().unwrap(), "hit");
        for key in ["mean", "std", "sem", "n"] {
            assert_eq!(va.get(key).unwrap(), vb.get(key).unwrap(), "{key}");
        }
        assert_eq!(parent.cached_results(), 1);
    }

    #[test]
    fn stats_reply_reports_requests_and_cache_counters() {
        let ds = synth_calhousing(&SynthSpec { n: 96, ..Default::default() });
        let mut state = tiny_state(&ds);
        let req = r#"{"channel":"erasure:0.2","seeds":2}"#;
        let _ = reply_of(state.handle_line(req)); // miss
        let _ = reply_of(state.handle_line(req)); // hit
        let _ = reply_of(state.handle_line(r#"{"cmd":"nope"}"#)); // error
        let (text, stop) =
            reply_of(state.handle_line(r#"{"id":9,"cmd":"stats"}"#));
        assert!(!stop);
        let v = json::parse(&text).unwrap();
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 9);
        assert_eq!(v.get("ok").unwrap(), &Value::Bool(true));
        let stats = v.get("stats").unwrap();
        for group in ["sched", "pool", "stream", "serve"] {
            assert!(stats.get(group).is_ok(), "stats missing group {group}");
        }
        let serve = stats.get("serve").unwrap();
        // the stats request itself is the 4th
        assert_eq!(serve.get("requests").unwrap().as_usize().unwrap(), 4);
        assert_eq!(serve.get("cache_hits").unwrap().as_usize().unwrap(), 1);
        assert_eq!(serve.get("cache_misses").unwrap().as_usize().unwrap(), 1);
        assert_eq!(serve.get("errors").unwrap().as_usize().unwrap(), 1);
        // sessions share the sink, exactly like the result cache
        let mut session = state.session();
        let (text, _) = reply_of(session.handle_line(r#"{"cmd":"stats"}"#));
        let v = json::parse(&text).unwrap();
        let requests = v
            .get("stats").unwrap()
            .get("serve").unwrap()
            .get("requests").unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(requests, 5);
    }

    #[test]
    fn shutdown_unblocks_accept_on_unspecified_bind() {
        let ds = synth_calhousing(&SynthSpec { n: 96, ..Default::default() });
        let state = tiny_state(&ds);
        // the documented fleet case: bind the unspecified address
        let listener = TcpListener::bind("0.0.0.0:0").unwrap();
        let port = listener.local_addr().unwrap().port();
        std::thread::scope(|scope| {
            let state = &state;
            let server = scope.spawn(move || serve_listener(state, listener));
            let mut conn = TcpStream::connect(("127.0.0.1", port)).unwrap();
            writeln!(conn, "{}", r#"{"cmd":"shutdown"}"#).unwrap();
            conn.flush().unwrap();
            let mut reply = String::new();
            BufReader::new(conn).read_line(&mut reply).unwrap();
            let v = json::parse(&reply).unwrap();
            assert_eq!(v.get("shutdown").unwrap(), &Value::Bool(true));
            // the loopback wake (NOT a connect to 0.0.0.0) must unblock
            // accept(); this join hangs forever without the rewrite on
            // stacks that refuse unspecified-destination connects
            server.join().unwrap().unwrap();
        });
    }

    #[test]
    fn serve_connection_round_trips_lines_until_shutdown() {
        let ds = synth_calhousing(&SynthSpec { n: 96, ..Default::default() });
        let mut state = tiny_state(&ds);
        let input = "\n{\"id\":1,\"cmd\":\"ping\"}\n{\"id\":2,\"seeds\":2}\n\
                     {\"id\":3,\"cmd\":\"shutdown\"}\n{\"id\":4,\"cmd\":\"ping\"}\n";
        let mut out = Vec::new();
        let stopped = serve_connection(
            &mut state,
            std::io::Cursor::new(input),
            &mut out,
        )
        .unwrap();
        assert!(stopped, "shutdown must stop the loop");
        let text = String::from_utf8(out).unwrap();
        let ids: Vec<usize> = text
            .lines()
            .map(|l| json::parse(l).unwrap().get("id").unwrap().as_usize())
            .collect::<Result<_>>()
            .unwrap();
        // blank line skipped, everything after shutdown unread
        assert_eq!(ids, vec![1, 2, 3]);
    }
}
