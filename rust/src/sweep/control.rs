//! The closed-loop comparison sweep: fixed `ñ_c` vs open-loop warmup vs
//! channel-adaptive control, across fading severities — the fig-style
//! producer behind `edgepipe control`.
//!
//! For every channel in the severity grid, the base `n_c` is resolved to
//! the channel-aware Corollary-1 recommendation for THAT channel (the
//! same plan the control policy starts from, so every policy competes
//! from the same static optimum: `fixed` runs it unchanged, `warmup`
//! ramps up to it, `control` re-plans it online). Each (channel, policy)
//! cell reports the Monte-Carlo mean/std of the final loss, the
//! deadline-outage rate (fraction of seeds whose schedule missed `T` —
//! a late block or an undelivered dataset) and the mean delivered
//! sample count. All jobs fan out flat over the worker pool with
//! recycled [`RunWorkspace`]s, like every other sweep.

use crate::bound::replan::ControlPlan;
use crate::coordinator::des::DesConfig;
use crate::coordinator::scheduler::RunWorkspace;
use crate::data::Dataset;
use crate::sweep::runner::McStats;
use crate::sweep::scenario::{
    ChannelSpec, PolicySpec, ScenarioRunner, ScenarioSpec,
};
use crate::util::pool::{default_threads, parallel_map_with};
use crate::util::stats::Welford;

/// One (channel, policy) cell of the comparison.
#[derive(Clone, Debug)]
pub struct ControlCompareRow {
    /// Channel-axis label (the fading severity).
    pub channel: String,
    /// Policy-axis label.
    pub policy: String,
    /// The channel-aware recommended `ñ_c` the cell ran with.
    pub n_c: usize,
    /// Final-loss statistics over the seeds.
    pub loss: McStats,
    /// Fraction of seeds whose schedule missed the deadline.
    pub outage_rate: f64,
    /// Mean samples delivered by the deadline.
    pub mean_delivered: f64,
}

/// The default severity grid: the ideal link, then three Gilbert–Elliott
/// channels of increasing fade frequency/depth (the last one is the
/// `adaptive_fading` preset's channel).
pub fn fading_severities() -> Vec<ChannelSpec> {
    vec![
        ChannelSpec::Ideal,
        // shallow, quick fades: ~1 packet in 12, 0.7x rate
        ChannelSpec::Fading {
            p_gb: 0.05,
            p_bg: 0.5,
            p_good: 0.0,
            p_bad: 0.3,
            rate_good: 1.0,
            rate_bad: 0.7,
        },
        // the registry's bursty link
        ChannelSpec::Fading {
            p_gb: 0.05,
            p_bg: 0.25,
            p_good: 0.0,
            p_bad: 0.6,
            rate_good: 1.0,
            rate_bad: 0.5,
        },
        // severe slow-mixing fades (the adaptive_fading preset)
        ChannelSpec::Fading {
            p_gb: 0.1,
            p_bg: 0.15,
            p_good: 0.0,
            p_bad: 0.5,
            rate_good: 1.0,
            rate_bad: 0.3,
        },
    ]
}

/// Cross `channels × policies × seeds` in one flat parallel fan-out.
/// Rows come back in (channel-major, policy-minor) order.
pub fn control_comparison(
    ds: &Dataset,
    base: &DesConfig,
    channels: &[ChannelSpec],
    policies: &[PolicySpec],
    seeds: usize,
    threads: usize,
) -> Vec<ControlCompareRow> {
    assert!(seeds >= 1, "need at least one seed");
    let threads = if threads == 0 { default_threads() } else { threads };

    // one runner per (channel, policy); the per-channel recommended n_c
    // is the channel-aware control plan's n_c0 — computed once per
    // channel here, and (deterministically) recomputed to the identical
    // value inside each control-policy runner's own plan cache, so
    // every policy in a row competes from the same static optimum
    let mut runners: Vec<(usize, ScenarioRunner)> = Vec::new();
    for channel in channels {
        let row_spec = ScenarioSpec {
            channel: channel.clone(),
            ..ScenarioSpec::paper()
        };
        let n_rec =
            ControlPlan::compute(ds, base, row_spec.expected_slowdown()).n_c0;
        for policy in policies {
            let spec = ScenarioSpec {
                policy: policy.clone(),
                ..row_spec.clone()
            };
            runners.push((n_rec, ScenarioRunner::new(spec, ds)));
        }
    }

    let jobs: Vec<(usize, u64)> = (0..runners.len())
        .flat_map(|i| (0..seeds as u64).map(move |s| (i, s)))
        .collect();
    let outcomes = parallel_map_with(
        &jobs,
        threads,
        RunWorkspace::new,
        |ws, &(i, s)| {
            let (n_rec, runner) = &runners[i];
            let cfg = DesConfig {
                n_c: *n_rec,
                seed: base.seed.wrapping_add(s),
                loss_every: 0,
                record_blocks: false,
                collect_snapshots: false,
                event_capacity: 0,
                ..base.clone()
            };
            let stats =
                runner.run_with(ws, &cfg).expect("control sweep run failed");
            (
                stats.final_loss,
                stats.deadline_outage(),
                stats.samples_delivered,
            )
        },
    );

    runners
        .iter()
        .enumerate()
        .map(|(i, (n_rec, runner))| {
            let cell = &outcomes[i * seeds..(i + 1) * seeds];
            let mut w = Welford::new();
            let mut outages = 0usize;
            let mut delivered = 0usize;
            for (loss, outage, samples) in cell {
                w.push(*loss);
                outages += usize::from(*outage);
                delivered += *samples;
            }
            ControlCompareRow {
                channel: runner.spec().channel.label(),
                policy: runner.spec().policy.label(),
                n_c: *n_rec,
                loss: McStats {
                    mean: w.mean(),
                    std: w.std(),
                    sem: w.sem(),
                    n: cell.len(),
                },
                outage_rate: outages as f64 / cell.len() as f64,
                mean_delivered: delivered as f64 / cell.len() as f64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::sweep::scenario::EstimatorSpec;

    #[test]
    fn comparison_covers_the_grid_and_is_thread_stable() {
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(1, 8.0, 450.0, 41)
        };
        let channels = vec![
            ChannelSpec::Ideal,
            ChannelSpec::Fading {
                p_gb: 0.1,
                p_bg: 0.15,
                p_good: 0.0,
                p_bad: 0.5,
                rate_good: 1.0,
                rate_bad: 0.3,
            },
        ];
        let policies = vec![
            PolicySpec::Fixed { n_c: 0 },
            PolicySpec::Control {
                est: EstimatorSpec::Ge,
                replan_every: 1,
            },
        ];
        let a = control_comparison(&ds, &base, &channels, &policies, 3, 1);
        let b = control_comparison(&ds, &base, &channels, &policies, 3, 4);
        assert_eq!(a.len(), 4, "2 channels x 2 policies");
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.loss.mean, rb.loss.mean, "thread count changed results");
            assert_eq!(ra.outage_rate, rb.outage_rate);
            assert!(ra.loss.mean.is_finite());
            assert!((0.0..=1.0).contains(&ra.outage_rate));
            assert!(ra.n_c >= 1 && ra.n_c <= ds.n);
        }
        // on the ideal channel control == fixed (static no-op), so the
        // two ideal rows must agree exactly, seed for seed
        assert_eq!(a[0].loss.mean, a[1].loss.mean);
        assert_eq!(a[0].mean_delivered, a[1].mean_delivered);
        // both severities ran the same policy list in order
        assert_eq!(a[0].policy, "fixed");
        assert_eq!(a[1].policy, "control");
    }

    #[test]
    fn default_severity_grid_is_ordered_by_slowdown() {
        let grid = fading_severities();
        assert!(grid.len() >= 3);
        let slowdowns: Vec<f64> =
            grid.iter().map(|c| c.expected_slowdown()).collect();
        for w in slowdowns.windows(2) {
            assert!(w[1] > w[0], "severities must worsen monotonically");
        }
    }
}
