//! Parallel Monte-Carlo sweeps over the unified scheduler fast path.
//!
//! Every estimator here is scenario-generic: [`mc_scenario_loss`] runs
//! ANY registered [`ScenarioSpec`] (channel × policy × traffic), and
//! [`scenario_grid`] crosses a whole spec list in one parallel fan-out.
//! The historical [`mc_final_loss`] / [`grid_final_losses`] entry points
//! are the paper scenario special case and keep their exact seed
//! semantics.
//!
//! Hot-path shape: every sweep is ONE flat fan-out over the pool — no
//! pool per grid point — chunked into lane-sized seed-groups that the
//! batched-seed engine ([`crate::sweep::batch`]) traces once each and
//! replays through SoA SGD kernels, `EDGEPIPE_LANES` wide (default 8;
//! `1` recovers the scalar path run-for-run). Per-seed losses are
//! bit-identical either way. Every worker recycles one
//! [`BatchWorkspace`](crate::sweep::batch::BatchWorkspace) across its
//! groups, so steady state performs no heap allocation per run.
//! `rust/benches/bench_sweep.rs` tracks the resulting runs/sec against
//! both the pre-workspace baseline and the scalar engine.

use anyhow::{bail, Result};

use crate::coordinator::des::DesConfig;
use crate::data::Dataset;
use crate::sweep::batch::{batch_lanes, grouped_losses};
use crate::sweep::scenario::{ScenarioRunner, ScenarioSpec};
use crate::util::pool::default_threads;
use crate::util::stats::Welford;

/// Mean/std of a Monte-Carlo estimate.
#[derive(Clone, Copy, Debug)]
pub struct McStats {
    pub mean: f64,
    pub std: f64,
    pub sem: f64,
    pub n: usize,
}

impl McStats {
    /// Welford statistics over a loss slice. Well-defined on the empty
    /// slice: `n = 0` with NaN mean/std/sem (there is no estimate, and
    /// NaN says so louder than a fake 0.0) — though `seeds == 0` is
    /// rejected upstream at the config boundary, so an empty slice only
    /// reaches here through direct library use.
    pub fn of(losses: &[f64]) -> McStats {
        let mut w = Welford::new();
        for &l in losses {
            w.push(l);
        }
        McStats::from_welford(&w)
    }

    /// Finalize a [`Welford`] accumulator into MC statistics — the
    /// streaming aggregator's counterpart of [`McStats::of`], and
    /// bit-identical to it when fed the same values in the same order.
    pub fn from_welford(w: &Welford) -> McStats {
        let n = w.count() as usize;
        if n == 0 {
            return McStats {
                mean: f64::NAN,
                std: f64::NAN,
                sem: f64::NAN,
                n: 0,
            };
        }
        McStats { mean: w.mean(), std: w.std(), sem: w.sem(), n }
    }
}

/// Strip a base config down to sweep mode: per-seed reseed, no curve /
/// snapshot / event recording (the full-dataset evaluations would
/// otherwise dominate the sweep cost).
pub(crate) fn sweep_cfg(base: &DesConfig, seed_offset: u64) -> DesConfig {
    DesConfig {
        seed: base.seed.wrapping_add(seed_offset),
        loss_every: 0,
        record_blocks: false,
        collect_snapshots: false,
        event_capacity: 0,
        ..base.clone()
    }
}

/// Average final training loss of an arbitrary scenario over `seeds`
/// Monte-Carlo repetitions (parallel across a thread pool, seed-groups
/// lane-batched per `EDGEPIPE_LANES`).
pub fn mc_scenario_loss(
    ds: &Dataset,
    base: &DesConfig,
    spec: &ScenarioSpec,
    seeds: usize,
    threads: usize,
) -> Result<McStats> {
    mc_scenario_loss_lanes(ds, base, spec, seeds, threads, batch_lanes())
}

/// [`mc_scenario_loss`] with an explicit lane count (`1` = scalar
/// engine). Per-seed losses are bit-identical across lane counts, so
/// the stats are too; the explicit knob exists for the bench and for
/// tests that must not race on process-global env.
pub fn mc_scenario_loss_lanes(
    ds: &Dataset,
    base: &DesConfig,
    spec: &ScenarioSpec,
    seeds: usize,
    threads: usize,
    lanes: usize,
) -> Result<McStats> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let runner = ScenarioRunner::new(spec.clone(), ds);
    let losses = grouped_losses(&[&runner], seeds, threads, lanes, |_, s| {
        sweep_cfg(base, s)
    })?;
    Ok(McStats::of(&losses))
}

/// Average final training loss of the paper's protocol at one
/// configuration (ideal channel, fixed `n_c`, one device), over `seeds`
/// Monte-Carlo repetitions.
pub fn mc_final_loss(
    ds: &Dataset,
    base: &DesConfig,
    seeds: usize,
    threads: usize,
) -> Result<McStats> {
    mc_scenario_loss(ds, base, &ScenarioSpec::paper(), seeds, threads)
}

/// [`mc_final_loss`] with an explicit lane count (`1` = scalar engine).
pub fn mc_final_loss_lanes(
    ds: &Dataset,
    base: &DesConfig,
    seeds: usize,
    threads: usize,
    lanes: usize,
) -> Result<McStats> {
    mc_scenario_loss_lanes(
        ds,
        base,
        &ScenarioSpec::paper(),
        seeds,
        threads,
        lanes,
    )
}

/// Cross a list of scenarios in ONE parallel fan-out: every (spec, seed)
/// pair becomes an independent job, so uneven scenario costs still
/// balance across the pool. Returns `(label, stats)` rows in spec order.
pub fn scenario_grid(
    ds: &Dataset,
    base: &DesConfig,
    specs: &[ScenarioSpec],
    seeds: usize,
    threads: usize,
) -> Result<Vec<(String, McStats)>> {
    scenario_grid_lanes(ds, base, specs, seeds, threads, batch_lanes())
}

/// [`scenario_grid`] with an explicit lane count (`1` = scalar engine).
pub fn scenario_grid_lanes(
    ds: &Dataset,
    base: &DesConfig,
    specs: &[ScenarioSpec],
    seeds: usize,
    threads: usize,
    lanes: usize,
) -> Result<Vec<(String, McStats)>> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let runners: Vec<ScenarioRunner> = specs
        .iter()
        .map(|spec| ScenarioRunner::new(spec.clone(), ds))
        .collect();
    let refs: Vec<&ScenarioRunner> = runners.iter().collect();
    let losses = grouped_losses(&refs, seeds, threads, lanes, |_, s| {
        sweep_cfg(base, s)
    })?;
    Ok(specs
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            (spec.label(), McStats::of(&losses[i * seeds..(i + 1) * seeds]))
        })
        .collect())
}

/// Final-loss statistics for each block size in `n_cs` (the experimental
/// optimum finder behind Fig. 4).
///
/// One flat `(n_c, seed)` fan-out serves the whole grid — a single pool
/// spawn, workers' workspaces warm across grid points, and uneven
/// per-`n_c` costs balance. Per-seed configs are exactly the historical
/// per-point `mc_final_loss` ones, so results are unchanged.
pub fn grid_final_losses(
    ds: &Dataset,
    base: &DesConfig,
    n_cs: &[usize],
    seeds: usize,
    threads: usize,
) -> Result<Vec<(usize, McStats)>> {
    grid_final_losses_lanes(ds, base, n_cs, seeds, threads, batch_lanes())
}

/// [`grid_final_losses`] with an explicit lane count (`1` = scalar
/// engine).
pub fn grid_final_losses_lanes(
    ds: &Dataset,
    base: &DesConfig,
    n_cs: &[usize],
    seeds: usize,
    threads: usize,
    lanes: usize,
) -> Result<Vec<(usize, McStats)>> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let runner = ScenarioRunner::new(ScenarioSpec::paper(), ds);
    // one shared runner serves every grid point; configs differ per point
    let refs: Vec<&ScenarioRunner> = n_cs.iter().map(|_| &runner).collect();
    let losses = grouped_losses(&refs, seeds, threads, lanes, |point, s| {
        DesConfig { n_c: n_cs[point], ..sweep_cfg(base, s) }
    })?;
    Ok(n_cs
        .iter()
        .enumerate()
        .map(|(i, &n_c)| {
            (n_c, McStats::of(&losses[i * seeds..(i + 1) * seeds]))
        })
        .collect())
}

/// A log-spaced integer grid over `[1, n]` with at most `points` values
/// (log-rounding collisions are deduped, so small `n` can yield fewer).
/// Errors on a degenerate request (`n == 0` or `points < 2`) instead of
/// panicking — both are reachable from CLI flags.
pub fn log_grid(n: usize, points: usize) -> Result<Vec<usize>> {
    if n < 1 {
        bail!("log grid needs a non-empty dataset (n = {n})");
    }
    if points < 2 {
        bail!("log grid needs at least 2 points (got {points})");
    }
    let mut grid: Vec<usize> = (0..points)
        .map(|i| {
            let t = i as f64 / (points - 1) as f64;
            ((n as f64).powf(t)).round() as usize
        })
        .map(|v| v.clamp(1, n))
        .collect();
    grid.dedup();
    Ok(grid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::des::run_des;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;
    use crate::sweep::scenario::{PolicySpec, TrafficSpec};

    #[test]
    fn mc_stats_are_stable_across_thread_counts() {
        let ds = synth_calhousing(&SynthSpec { n: 400, ..Default::default() });
        let base = DesConfig::paper(40, 5.0, 800.0, 100);
        let a = mc_final_loss(&ds, &base, 6, 1).unwrap();
        let b = mc_final_loss(&ds, &base, 6, 4).unwrap();
        assert_eq!(a.mean, b.mean, "thread count must not change results");
        assert_eq!(a.n, 6);
        assert!(a.std >= 0.0);
    }

    #[test]
    fn mc_final_loss_matches_direct_des_runs() {
        // the scenario path must reproduce per-seed run_des exactly
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig::paper(30, 5.0, 600.0, 55);
        let stats = mc_final_loss(&ds, &base, 3, 2).unwrap();
        let mut manual = Vec::new();
        for s in 0..3u64 {
            let cfg = DesConfig {
                seed: base.seed.wrapping_add(s),
                record_blocks: false,
                ..base.clone()
            };
            let mut exec = NativeExecutor::new(
                RidgeModel::new(ds.d, cfg.lambda, ds.n),
                cfg.alpha,
            );
            manual.push(
                run_des(&ds, &cfg, &mut IdealChannel, &mut exec)
                    .unwrap()
                    .final_loss,
            );
        }
        // same Welford accumulation over the same per-seed losses
        let manual_stats = McStats::of(&manual);
        assert_eq!(
            stats.mean, manual_stats.mean,
            "scenario path diverged from run_des"
        );
        assert_eq!(stats.std, manual_stats.std);
    }

    #[test]
    fn grid_runs_every_point() {
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig::paper(1, 2.0, 500.0, 3);
        let rows = grid_final_losses(&ds, &base, &[10, 50, 150], 3, 2).unwrap();
        assert_eq!(rows.len(), 3);
        for (nc, stats) in rows {
            assert!(nc > 0);
            assert!(stats.mean.is_finite());
        }
    }

    #[test]
    fn scenario_grid_crosses_specs() {
        let ds = synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
        let base = DesConfig::paper(24, 4.0, 480.0, 17);
        let paper = ScenarioSpec::paper();
        let specs = vec![
            paper.clone(),
            ScenarioSpec {
                policy: PolicySpec::Sequential { n_c: 0 },
                ..paper.clone()
            },
            ScenarioSpec { traffic: TrafficSpec::Devices(3), ..paper },
        ];
        let rows = scenario_grid(&ds, &base, &specs, 4, 3).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].0, "ideal|fixed|k1");
        // pipelining must beat the sequential baseline on average
        assert!(rows[0].1.mean < rows[1].1.mean);
        for (_, stats) in &rows {
            assert!(stats.mean.is_finite() && stats.n == 4);
        }
    }

    #[test]
    fn lane_counts_do_not_change_results() {
        // the batched engine must be bit-identical to scalar per seed,
        // including ragged groups (6 seeds over width 4 → 4 + 2)
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig::paper(30, 5.0, 600.0, 9);
        let spec = ScenarioSpec::paper();
        let scalar = mc_scenario_loss_lanes(&ds, &base, &spec, 6, 2, 1).unwrap();
        for lanes in [4usize, 8, 16] {
            let batched =
                mc_scenario_loss_lanes(&ds, &base, &spec, 6, 2, lanes).unwrap();
            assert_eq!(
                scalar.mean.to_bits(),
                batched.mean.to_bits(),
                "lanes={lanes} mean"
            );
            assert_eq!(
                scalar.std.to_bits(),
                batched.std.to_bits(),
                "lanes={lanes} std"
            );
        }
        let g1 =
            grid_final_losses_lanes(&ds, &base, &[10, 40], 3, 2, 1).unwrap();
        let g8 =
            grid_final_losses_lanes(&ds, &base, &[10, 40], 3, 2, 8).unwrap();
        for (a, b) in g1.iter().zip(&g8) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.mean.to_bits(), b.1.mean.to_bits());
        }
    }

    #[test]
    fn log_grid_shape() {
        let g = log_grid(18576, 40).unwrap();
        assert_eq!(*g.first().unwrap(), 1);
        assert_eq!(*g.last().unwrap(), 18576);
        for w in g.windows(2) {
            assert!(w[1] > w[0], "grid must be strictly increasing");
        }
    }

    #[test]
    fn log_grid_degenerate_requests_are_errors() {
        assert!(log_grid(0, 10).is_err(), "n = 0 must not panic");
        assert!(log_grid(100, 0).is_err());
        assert!(log_grid(100, 1).is_err());
        // tiny n: rounding collisions dedup below `points`
        let g = log_grid(2, 24).unwrap();
        assert_eq!(g, vec![1, 2]);
    }

    #[test]
    fn empty_mc_stats_are_well_defined() {
        let s = McStats::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan() && s.std.is_nan() && s.sem.is_nan());
        // the accumulator route agrees with the slice route bit-for-bit
        let mut w = Welford::new();
        for &l in &[0.5f64, 1.25, -3.0] {
            w.push(l);
        }
        let a = McStats::of(&[0.5, 1.25, -3.0]);
        let b = McStats::from_welford(&w);
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.std.to_bits(), b.std.to_bits());
        assert_eq!(a.sem.to_bits(), b.sem.to_bits());
        assert_eq!(a.n, b.n);
    }
}
