//! Parallel Monte-Carlo sweeps over the unified scheduler fast path.
//!
//! Every estimator here is scenario-generic: [`mc_scenario_loss`] runs
//! ANY registered [`ScenarioSpec`] (channel × policy × traffic), and
//! [`scenario_grid`] crosses a whole spec list in one parallel fan-out.
//! The historical [`mc_final_loss`] / [`grid_final_losses`] entry points
//! are the paper scenario special case and keep their exact seed
//! semantics.
//!
//! Hot-path shape: every sweep is ONE flat fan-out over the pool — no
//! pool per grid point — chunked into lane-sized seed-groups that the
//! batched-seed engine ([`crate::sweep::batch`]) traces once each and
//! replays through SoA SGD kernels, `EDGEPIPE_LANES` wide (default 8;
//! `1` recovers the scalar path run-for-run). Per-seed losses are
//! bit-identical either way. Every worker recycles one
//! [`BatchWorkspace`](crate::sweep::batch::BatchWorkspace) across its
//! groups, so steady state performs no heap allocation per run.
//! `rust/benches/bench_sweep.rs` tracks the resulting runs/sec against
//! both the pre-workspace baseline and the scalar engine.

use crate::coordinator::des::DesConfig;
use crate::data::Dataset;
use crate::sweep::batch::{batch_lanes, grouped_losses};
use crate::sweep::scenario::{ScenarioRunner, ScenarioSpec};
use crate::util::pool::default_threads;
use crate::util::stats::Welford;

/// Mean/std of a Monte-Carlo estimate.
#[derive(Clone, Copy, Debug)]
pub struct McStats {
    pub mean: f64,
    pub std: f64,
    pub sem: f64,
    pub n: usize,
}

impl McStats {
    fn of(losses: &[f64]) -> McStats {
        let mut w = Welford::new();
        for &l in losses {
            w.push(l);
        }
        McStats { mean: w.mean(), std: w.std(), sem: w.sem(), n: losses.len() }
    }
}

/// Strip a base config down to sweep mode: per-seed reseed, no curve /
/// snapshot / event recording (the full-dataset evaluations would
/// otherwise dominate the sweep cost).
fn sweep_cfg(base: &DesConfig, seed_offset: u64) -> DesConfig {
    DesConfig {
        seed: base.seed.wrapping_add(seed_offset),
        loss_every: 0,
        record_blocks: false,
        collect_snapshots: false,
        event_capacity: 0,
        ..base.clone()
    }
}

/// Average final training loss of an arbitrary scenario over `seeds`
/// Monte-Carlo repetitions (parallel across a thread pool, seed-groups
/// lane-batched per `EDGEPIPE_LANES`).
pub fn mc_scenario_loss(
    ds: &Dataset,
    base: &DesConfig,
    spec: &ScenarioSpec,
    seeds: usize,
    threads: usize,
) -> McStats {
    mc_scenario_loss_lanes(ds, base, spec, seeds, threads, batch_lanes())
}

/// [`mc_scenario_loss`] with an explicit lane count (`1` = scalar
/// engine). Per-seed losses are bit-identical across lane counts, so
/// the stats are too; the explicit knob exists for the bench and for
/// tests that must not race on process-global env.
pub fn mc_scenario_loss_lanes(
    ds: &Dataset,
    base: &DesConfig,
    spec: &ScenarioSpec,
    seeds: usize,
    threads: usize,
    lanes: usize,
) -> McStats {
    let threads = if threads == 0 { default_threads() } else { threads };
    let runner = ScenarioRunner::new(spec.clone(), ds);
    let losses = grouped_losses(&[&runner], seeds, threads, lanes, |_, s| {
        sweep_cfg(base, s)
    });
    McStats::of(&losses)
}

/// Average final training loss of the paper's protocol at one
/// configuration (ideal channel, fixed `n_c`, one device), over `seeds`
/// Monte-Carlo repetitions.
pub fn mc_final_loss(
    ds: &Dataset,
    base: &DesConfig,
    seeds: usize,
    threads: usize,
) -> McStats {
    mc_scenario_loss(ds, base, &ScenarioSpec::paper(), seeds, threads)
}

/// [`mc_final_loss`] with an explicit lane count (`1` = scalar engine).
pub fn mc_final_loss_lanes(
    ds: &Dataset,
    base: &DesConfig,
    seeds: usize,
    threads: usize,
    lanes: usize,
) -> McStats {
    mc_scenario_loss_lanes(
        ds,
        base,
        &ScenarioSpec::paper(),
        seeds,
        threads,
        lanes,
    )
}

/// Cross a list of scenarios in ONE parallel fan-out: every (spec, seed)
/// pair becomes an independent job, so uneven scenario costs still
/// balance across the pool. Returns `(label, stats)` rows in spec order.
pub fn scenario_grid(
    ds: &Dataset,
    base: &DesConfig,
    specs: &[ScenarioSpec],
    seeds: usize,
    threads: usize,
) -> Vec<(String, McStats)> {
    scenario_grid_lanes(ds, base, specs, seeds, threads, batch_lanes())
}

/// [`scenario_grid`] with an explicit lane count (`1` = scalar engine).
pub fn scenario_grid_lanes(
    ds: &Dataset,
    base: &DesConfig,
    specs: &[ScenarioSpec],
    seeds: usize,
    threads: usize,
    lanes: usize,
) -> Vec<(String, McStats)> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let runners: Vec<ScenarioRunner> = specs
        .iter()
        .map(|spec| ScenarioRunner::new(spec.clone(), ds))
        .collect();
    let refs: Vec<&ScenarioRunner> = runners.iter().collect();
    let losses = grouped_losses(&refs, seeds, threads, lanes, |_, s| {
        sweep_cfg(base, s)
    });
    specs
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            (spec.label(), McStats::of(&losses[i * seeds..(i + 1) * seeds]))
        })
        .collect()
}

/// Final-loss statistics for each block size in `n_cs` (the experimental
/// optimum finder behind Fig. 4).
///
/// One flat `(n_c, seed)` fan-out serves the whole grid — a single pool
/// spawn, workers' workspaces warm across grid points, and uneven
/// per-`n_c` costs balance. Per-seed configs are exactly the historical
/// per-point `mc_final_loss` ones, so results are unchanged.
pub fn grid_final_losses(
    ds: &Dataset,
    base: &DesConfig,
    n_cs: &[usize],
    seeds: usize,
    threads: usize,
) -> Vec<(usize, McStats)> {
    grid_final_losses_lanes(ds, base, n_cs, seeds, threads, batch_lanes())
}

/// [`grid_final_losses`] with an explicit lane count (`1` = scalar
/// engine).
pub fn grid_final_losses_lanes(
    ds: &Dataset,
    base: &DesConfig,
    n_cs: &[usize],
    seeds: usize,
    threads: usize,
    lanes: usize,
) -> Vec<(usize, McStats)> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let runner = ScenarioRunner::new(ScenarioSpec::paper(), ds);
    // one shared runner serves every grid point; configs differ per point
    let refs: Vec<&ScenarioRunner> = n_cs.iter().map(|_| &runner).collect();
    let losses = grouped_losses(&refs, seeds, threads, lanes, |point, s| {
        DesConfig { n_c: n_cs[point], ..sweep_cfg(base, s) }
    });
    n_cs.iter()
        .enumerate()
        .map(|(i, &n_c)| {
            (n_c, McStats::of(&losses[i * seeds..(i + 1) * seeds]))
        })
        .collect()
}

/// A log-spaced integer grid over `[1, n]` with `points` unique values.
pub fn log_grid(n: usize, points: usize) -> Vec<usize> {
    assert!(n >= 1 && points >= 2);
    let mut grid: Vec<usize> = (0..points)
        .map(|i| {
            let t = i as f64 / (points - 1) as f64;
            ((n as f64).powf(t)).round() as usize
        })
        .map(|v| v.clamp(1, n))
        .collect();
    grid.dedup();
    grid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::des::run_des;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;
    use crate::sweep::scenario::{PolicySpec, TrafficSpec};

    #[test]
    fn mc_stats_are_stable_across_thread_counts() {
        let ds = synth_calhousing(&SynthSpec { n: 400, ..Default::default() });
        let base = DesConfig::paper(40, 5.0, 800.0, 100);
        let a = mc_final_loss(&ds, &base, 6, 1);
        let b = mc_final_loss(&ds, &base, 6, 4);
        assert_eq!(a.mean, b.mean, "thread count must not change results");
        assert_eq!(a.n, 6);
        assert!(a.std >= 0.0);
    }

    #[test]
    fn mc_final_loss_matches_direct_des_runs() {
        // the scenario path must reproduce per-seed run_des exactly
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig::paper(30, 5.0, 600.0, 55);
        let stats = mc_final_loss(&ds, &base, 3, 2);
        let mut manual = Vec::new();
        for s in 0..3u64 {
            let cfg = DesConfig {
                seed: base.seed.wrapping_add(s),
                record_blocks: false,
                ..base.clone()
            };
            let mut exec = NativeExecutor::new(
                RidgeModel::new(ds.d, cfg.lambda, ds.n),
                cfg.alpha,
            );
            manual.push(
                run_des(&ds, &cfg, &mut IdealChannel, &mut exec)
                    .unwrap()
                    .final_loss,
            );
        }
        // same Welford accumulation over the same per-seed losses
        let manual_stats = McStats::of(&manual);
        assert_eq!(
            stats.mean, manual_stats.mean,
            "scenario path diverged from run_des"
        );
        assert_eq!(stats.std, manual_stats.std);
    }

    #[test]
    fn grid_runs_every_point() {
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig::paper(1, 2.0, 500.0, 3);
        let rows = grid_final_losses(&ds, &base, &[10, 50, 150], 3, 2);
        assert_eq!(rows.len(), 3);
        for (nc, stats) in rows {
            assert!(nc > 0);
            assert!(stats.mean.is_finite());
        }
    }

    #[test]
    fn scenario_grid_crosses_specs() {
        let ds = synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
        let base = DesConfig::paper(24, 4.0, 480.0, 17);
        let paper = ScenarioSpec::paper();
        let specs = vec![
            paper.clone(),
            ScenarioSpec {
                policy: PolicySpec::Sequential { n_c: 0 },
                ..paper.clone()
            },
            ScenarioSpec { traffic: TrafficSpec::Devices(3), ..paper },
        ];
        let rows = scenario_grid(&ds, &base, &specs, 4, 3);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].0, "ideal|fixed|k1");
        // pipelining must beat the sequential baseline on average
        assert!(rows[0].1.mean < rows[1].1.mean);
        for (_, stats) in &rows {
            assert!(stats.mean.is_finite() && stats.n == 4);
        }
    }

    #[test]
    fn lane_counts_do_not_change_results() {
        // the batched engine must be bit-identical to scalar per seed,
        // including ragged groups (6 seeds over width 4 → 4 + 2)
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig::paper(30, 5.0, 600.0, 9);
        let spec = ScenarioSpec::paper();
        let scalar = mc_scenario_loss_lanes(&ds, &base, &spec, 6, 2, 1);
        for lanes in [4usize, 8, 16] {
            let batched =
                mc_scenario_loss_lanes(&ds, &base, &spec, 6, 2, lanes);
            assert_eq!(
                scalar.mean.to_bits(),
                batched.mean.to_bits(),
                "lanes={lanes} mean"
            );
            assert_eq!(
                scalar.std.to_bits(),
                batched.std.to_bits(),
                "lanes={lanes} std"
            );
        }
        let g1 = grid_final_losses_lanes(&ds, &base, &[10, 40], 3, 2, 1);
        let g8 = grid_final_losses_lanes(&ds, &base, &[10, 40], 3, 2, 8);
        for (a, b) in g1.iter().zip(&g8) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.mean.to_bits(), b.1.mean.to_bits());
        }
    }

    #[test]
    fn log_grid_shape() {
        let g = log_grid(18576, 40);
        assert_eq!(*g.first().unwrap(), 1);
        assert_eq!(*g.last().unwrap(), 18576);
        for w in g.windows(2) {
            assert!(w[1] > w[0], "grid must be strictly increasing");
        }
    }
}
