//! Parallel Monte-Carlo sweeps over the DES fast path.

use crate::channel::IdealChannel;
use crate::coordinator::des::{run_des, DesConfig};
use crate::coordinator::executor::NativeExecutor;
use crate::data::Dataset;
use crate::model::RidgeModel;
use crate::util::pool::{default_threads, parallel_tasks};
use crate::util::stats::Welford;

/// Mean/std of a Monte-Carlo estimate.
#[derive(Clone, Copy, Debug)]
pub struct McStats {
    pub mean: f64,
    pub std: f64,
    pub sem: f64,
    pub n: usize,
}

/// Average final training loss of the protocol at one configuration,
/// over `seeds` Monte-Carlo repetitions (parallel across a thread pool).
pub fn mc_final_loss(
    ds: &Dataset,
    base: &DesConfig,
    seeds: usize,
    threads: usize,
) -> McStats {
    let threads = if threads == 0 { default_threads() } else { threads };
    let losses = parallel_tasks(seeds, threads, |s| {
        let cfg = DesConfig {
            seed: base.seed.wrapping_add(s as u64),
            loss_every: 0,
            record_blocks: false,
            collect_snapshots: false,
            event_capacity: 0,
            ..base.clone()
        };
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        );
        run_des(ds, &cfg, &mut IdealChannel, &mut exec)
            .expect("DES run failed")
            .final_loss
    });
    let mut w = Welford::new();
    for &l in &losses {
        w.push(l);
    }
    McStats { mean: w.mean(), std: w.std(), sem: w.sem(), n: seeds }
}

/// Final-loss statistics for each block size in `n_cs` (the experimental
/// optimum finder behind Fig. 4).
pub fn grid_final_losses(
    ds: &Dataset,
    base: &DesConfig,
    n_cs: &[usize],
    seeds: usize,
    threads: usize,
) -> Vec<(usize, McStats)> {
    n_cs.iter()
        .map(|&n_c| {
            let cfg = DesConfig { n_c, ..base.clone() };
            (n_c, mc_final_loss(ds, &cfg, seeds, threads))
        })
        .collect()
}

/// A log-spaced integer grid over `[1, n]` with `points` unique values.
pub fn log_grid(n: usize, points: usize) -> Vec<usize> {
    assert!(n >= 1 && points >= 2);
    let mut grid: Vec<usize> = (0..points)
        .map(|i| {
            let t = i as f64 / (points - 1) as f64;
            ((n as f64).powf(t)).round() as usize
        })
        .map(|v| v.clamp(1, n))
        .collect();
    grid.dedup();
    grid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    #[test]
    fn mc_stats_are_stable_across_thread_counts() {
        let ds = synth_calhousing(&SynthSpec { n: 400, ..Default::default() });
        let base = DesConfig::paper(40, 5.0, 800.0, 100);
        let a = mc_final_loss(&ds, &base, 6, 1);
        let b = mc_final_loss(&ds, &base, 6, 4);
        assert_eq!(a.mean, b.mean, "thread count must not change results");
        assert_eq!(a.n, 6);
        assert!(a.std >= 0.0);
    }

    #[test]
    fn grid_runs_every_point() {
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig::paper(1, 2.0, 500.0, 3);
        let rows = grid_final_losses(&ds, &base, &[10, 50, 150], 3, 2);
        assert_eq!(rows.len(), 3);
        for (nc, stats) in rows {
            assert!(nc > 0);
            assert!(stats.mean.is_finite());
        }
    }

    #[test]
    fn log_grid_shape() {
        let g = log_grid(18576, 40);
        assert_eq!(*g.first().unwrap(), 1);
        assert_eq!(*g.last().unwrap(), 18576);
        for w in g.windows(2) {
            assert!(w[1] > w[0], "grid must be strictly increasing");
        }
    }
}
