//! Parallel Monte-Carlo sweeps over the unified scheduler fast path.
//!
//! Every estimator here is scenario-generic: [`mc_scenario_loss`] runs
//! ANY registered [`ScenarioSpec`] (channel × policy × traffic), and
//! [`scenario_grid`] crosses a whole spec list in one parallel fan-out.
//! The historical [`mc_final_loss`] / [`grid_final_losses`] entry points
//! are the paper scenario special case and keep their exact seed
//! semantics.
//!
//! Hot-path shape: every sweep is ONE flat `(point, seed)` fan-out over
//! the pool — no pool per grid point — and every worker drives its jobs
//! through a long-lived [`RunWorkspace`]
//! (`ScenarioRunner::run_with`), so steady state performs no heap
//! allocation per run. `rust/benches/bench_sweep.rs` tracks the
//! resulting runs/sec against the pre-workspace baseline.

use crate::coordinator::des::DesConfig;
use crate::coordinator::scheduler::RunWorkspace;
use crate::data::Dataset;
use crate::sweep::scenario::{ScenarioRunner, ScenarioSpec};
use crate::util::pool::{
    default_threads, parallel_map_with, parallel_tasks_with,
};
use crate::util::stats::Welford;

/// Mean/std of a Monte-Carlo estimate.
#[derive(Clone, Copy, Debug)]
pub struct McStats {
    pub mean: f64,
    pub std: f64,
    pub sem: f64,
    pub n: usize,
}

impl McStats {
    fn of(losses: &[f64]) -> McStats {
        let mut w = Welford::new();
        for &l in losses {
            w.push(l);
        }
        McStats { mean: w.mean(), std: w.std(), sem: w.sem(), n: losses.len() }
    }
}

/// Strip a base config down to sweep mode: per-seed reseed, no curve /
/// snapshot / event recording (the full-dataset evaluations would
/// otherwise dominate the sweep cost).
fn sweep_cfg(base: &DesConfig, seed_offset: u64) -> DesConfig {
    DesConfig {
        seed: base.seed.wrapping_add(seed_offset),
        loss_every: 0,
        record_blocks: false,
        collect_snapshots: false,
        event_capacity: 0,
        ..base.clone()
    }
}

/// Average final training loss of an arbitrary scenario over `seeds`
/// Monte-Carlo repetitions (parallel across a thread pool).
pub fn mc_scenario_loss(
    ds: &Dataset,
    base: &DesConfig,
    spec: &ScenarioSpec,
    seeds: usize,
    threads: usize,
) -> McStats {
    let threads = if threads == 0 { default_threads() } else { threads };
    let runner = ScenarioRunner::new(spec.clone(), ds);
    let losses =
        parallel_tasks_with(seeds, threads, RunWorkspace::new, |ws, s| {
            runner
                .run_with(ws, &sweep_cfg(base, s as u64))
                .expect("scenario run failed")
                .final_loss
        });
    McStats::of(&losses)
}

/// Average final training loss of the paper's protocol at one
/// configuration (ideal channel, fixed `n_c`, one device), over `seeds`
/// Monte-Carlo repetitions.
pub fn mc_final_loss(
    ds: &Dataset,
    base: &DesConfig,
    seeds: usize,
    threads: usize,
) -> McStats {
    mc_scenario_loss(ds, base, &ScenarioSpec::paper(), seeds, threads)
}

/// Cross a list of scenarios in ONE parallel fan-out: every (spec, seed)
/// pair becomes an independent job, so uneven scenario costs still
/// balance across the pool. Returns `(label, stats)` rows in spec order.
pub fn scenario_grid(
    ds: &Dataset,
    base: &DesConfig,
    specs: &[ScenarioSpec],
    seeds: usize,
    threads: usize,
) -> Vec<(String, McStats)> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let runners: Vec<ScenarioRunner> = specs
        .iter()
        .map(|spec| ScenarioRunner::new(spec.clone(), ds))
        .collect();
    let jobs: Vec<(usize, u64)> = (0..specs.len())
        .flat_map(|i| (0..seeds as u64).map(move |s| (i, s)))
        .collect();
    let losses =
        parallel_map_with(&jobs, threads, RunWorkspace::new, |ws, &(i, s)| {
            runners[i]
                .run_with(ws, &sweep_cfg(base, s))
                .expect("scenario run failed")
                .final_loss
        });
    specs
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            (spec.label(), McStats::of(&losses[i * seeds..(i + 1) * seeds]))
        })
        .collect()
}

/// Final-loss statistics for each block size in `n_cs` (the experimental
/// optimum finder behind Fig. 4).
///
/// One flat `(n_c, seed)` fan-out serves the whole grid — a single pool
/// spawn, workers' workspaces warm across grid points, and uneven
/// per-`n_c` costs balance. Per-seed configs are exactly the historical
/// per-point `mc_final_loss` ones, so results are unchanged.
pub fn grid_final_losses(
    ds: &Dataset,
    base: &DesConfig,
    n_cs: &[usize],
    seeds: usize,
    threads: usize,
) -> Vec<(usize, McStats)> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let runner = ScenarioRunner::new(ScenarioSpec::paper(), ds);
    let jobs: Vec<(usize, u64)> = n_cs
        .iter()
        .flat_map(|&n_c| (0..seeds as u64).map(move |s| (n_c, s)))
        .collect();
    let losses = parallel_map_with(
        &jobs,
        threads,
        RunWorkspace::new,
        |ws, &(n_c, s)| {
            let cfg = DesConfig { n_c, ..sweep_cfg(base, s) };
            runner
                .run_with(ws, &cfg)
                .expect("scenario run failed")
                .final_loss
        },
    );
    n_cs.iter()
        .enumerate()
        .map(|(i, &n_c)| {
            (n_c, McStats::of(&losses[i * seeds..(i + 1) * seeds]))
        })
        .collect()
}

/// A log-spaced integer grid over `[1, n]` with `points` unique values.
pub fn log_grid(n: usize, points: usize) -> Vec<usize> {
    assert!(n >= 1 && points >= 2);
    let mut grid: Vec<usize> = (0..points)
        .map(|i| {
            let t = i as f64 / (points - 1) as f64;
            ((n as f64).powf(t)).round() as usize
        })
        .map(|v| v.clamp(1, n))
        .collect();
    grid.dedup();
    grid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::des::run_des;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;
    use crate::sweep::scenario::{PolicySpec, TrafficSpec};

    #[test]
    fn mc_stats_are_stable_across_thread_counts() {
        let ds = synth_calhousing(&SynthSpec { n: 400, ..Default::default() });
        let base = DesConfig::paper(40, 5.0, 800.0, 100);
        let a = mc_final_loss(&ds, &base, 6, 1);
        let b = mc_final_loss(&ds, &base, 6, 4);
        assert_eq!(a.mean, b.mean, "thread count must not change results");
        assert_eq!(a.n, 6);
        assert!(a.std >= 0.0);
    }

    #[test]
    fn mc_final_loss_matches_direct_des_runs() {
        // the scenario path must reproduce per-seed run_des exactly
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig::paper(30, 5.0, 600.0, 55);
        let stats = mc_final_loss(&ds, &base, 3, 2);
        let mut manual = Vec::new();
        for s in 0..3u64 {
            let cfg = DesConfig {
                seed: base.seed.wrapping_add(s),
                record_blocks: false,
                ..base.clone()
            };
            let mut exec = NativeExecutor::new(
                RidgeModel::new(ds.d, cfg.lambda, ds.n),
                cfg.alpha,
            );
            manual.push(
                run_des(&ds, &cfg, &mut IdealChannel, &mut exec)
                    .unwrap()
                    .final_loss,
            );
        }
        // same Welford accumulation over the same per-seed losses
        let manual_stats = McStats::of(&manual);
        assert_eq!(
            stats.mean, manual_stats.mean,
            "scenario path diverged from run_des"
        );
        assert_eq!(stats.std, manual_stats.std);
    }

    #[test]
    fn grid_runs_every_point() {
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig::paper(1, 2.0, 500.0, 3);
        let rows = grid_final_losses(&ds, &base, &[10, 50, 150], 3, 2);
        assert_eq!(rows.len(), 3);
        for (nc, stats) in rows {
            assert!(nc > 0);
            assert!(stats.mean.is_finite());
        }
    }

    #[test]
    fn scenario_grid_crosses_specs() {
        let ds = synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
        let base = DesConfig::paper(24, 4.0, 480.0, 17);
        let paper = ScenarioSpec::paper();
        let specs = vec![
            paper.clone(),
            ScenarioSpec {
                policy: PolicySpec::Sequential { n_c: 0 },
                ..paper.clone()
            },
            ScenarioSpec { traffic: TrafficSpec::Devices(3), ..paper },
        ];
        let rows = scenario_grid(&ds, &base, &specs, 4, 3);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].0, "ideal|fixed|k1");
        // pipelining must beat the sequential baseline on average
        assert!(rows[0].1.mean < rows[1].1.mean);
        for (_, stats) in &rows {
            assert!(stats.mean.is_finite() && stats.n == 4);
        }
    }

    #[test]
    fn log_grid_shape() {
        let g = log_grid(18576, 40);
        assert_eq!(*g.first().unwrap(), 1);
        assert_eq!(*g.last().unwrap(), 18576);
        for w in g.windows(2) {
            assert!(w[1] > w[0], "grid must be strictly increasing");
        }
    }
}
