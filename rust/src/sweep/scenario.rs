//! Scenario specifications: a declarative (channel × policy × traffic)
//! registry over the generic scheduler, so Monte-Carlo sweeps and the
//! CLI can run ANY protocol variant — not just the paper's single-device
//! fixed-`n_c` setting — from one code path.
//!
//! A scenario is four orthogonal axes plus an optional store bound:
//!
//! * [`ChannelSpec`] — `ideal`, `erasure:<p>`, `rate:<r>[:<p>]`,
//!   `fading:<p_gb>:<p_bg>:<p_bad>[:<p_good>[:<r_bad>[:<r_good>]]]`
//!   (Gilbert–Elliott good/bad Markov states, clocked per packet); any
//!   channel takes an optional `:fault=<spec>` suffix wrapping it in a
//!   scripted [`FaultPlan`] (see [`FaultSpec`]) — `fault=off` (or no
//!   suffix) is the identity and parses back to the bare channel
//! * [`PolicySpec`] — `fixed[:n_c]`, `warmup:<start>:<growth>[:<cap>]`,
//!   `deadline:<frac>`, `sequential[:n_c]`, `allfirst`, or the
//!   closed-loop `control[:est=<ge|ema>][:replan=<k>]` (online channel
//!   estimation + Corollary-1 re-planning at block boundaries)
//! * [`TrafficSpec`] — `<k>` round-robin devices on ONE shared channel,
//!   `online:<rate>` streaming arrivals, or the heterogeneous multi-lane
//!   uplink `devices:<k>[:sched=<rr|greedy|pfair>][:skew=<f>]`
//!   `[:ch=<spec>,<spec>,…]` — per-device channels (one spec broadcast,
//!   or exactly `k`; omitted = the scenario's channel axis on every
//!   lane), a pluggable [`DeviceScheduler`] and non-IID label-skew
//!   sharding
//! * [`Workload`] — `ridge` regression (the paper) or `logistic`
//!   classification (labels derived by median-binarizing the dataset)
//!
//! Each axis parses from the compact string form above (used by
//! `scenario.*` config keys and the `edgepipe scenario` subcommand), and
//! [`ScenarioRunner`] executes a spec deterministically for a given
//! [`DesConfig`] — building a fresh channel/source/policy/executor per
//! run so seeds can fan out across threads.

use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::bound::replan::{ControlPlan, Replanner, PLAN_REL_TOL};
use crate::channel::estimator::{
    ControlEstimator, EmaRateEstimator, GeBeliefEstimator, GeParams,
    PacketObs,
};
use crate::channel::{
    Channel, Delivery, ErasureChannel, FaultPlan, FaultSpec,
    GilbertElliottChannel, IdealChannel, LinkState, MultiLaneChannel,
    RateLimitedChannel,
};
use crate::coordinator::des::DesConfig;
use crate::coordinator::run::RunResult;
use crate::coordinator::executor::{
    BlockExecutor, NativeExecutor, TraceExecutor,
};
use crate::coordinator::scheduler::{
    run_schedule_with_opts, BlockPolicy, ControlPolicy, DeviceScheduler,
    FaultObs, FixedPolicy, GreedyScheduler, LaneView, OnlineArrivalSource,
    OverlapMode, PropFairScheduler, RoundRobinScheduler, RoundRobinSource,
    RunStats, RunWorkspace, SingleDeviceSource,
};
use crate::coordinator::shard::{shard_count, ShardedSource};
use crate::data::classify::binarize_labels;
use crate::data::shard::{shard_label_skew, shard_round_robin};
use crate::data::Dataset;
use crate::extensions::adaptive::{DeadlineAwareSchedule, WarmupSchedule};
use crate::model::{LogisticModel, RidgeModel, Workload};
use crate::util::rng::Pcg32;

/// EMA step of the unknown-channel (`est=ema`) slowdown tracker.
const CONTROL_EMA_WEIGHT: f64 = 0.2;

/// Which channel carries the blocks.
#[derive(Clone, Debug, PartialEq)]
pub enum ChannelSpec {
    /// Error-free (the paper's main analysis).
    Ideal,
    /// Packet erasure with ARQ retransmission at probability `p`.
    Erasure { p: f64 },
    /// Relative rate `rate` over an erasure link with probability `p`.
    Rate { rate: f64, p: f64 },
    /// Gilbert–Elliott two-state fading: good/bad Markov states with
    /// per-state erasure probability and rate, transitions clocked per
    /// packet. `p_gb = 0` pins the chain to the good state, making it
    /// stream-identical to `Erasure { p: p_good }`.
    Fading {
        p_gb: f64,
        p_bg: f64,
        p_good: f64,
        p_bad: f64,
        rate_good: f64,
        rate_bad: f64,
    },
    /// Any of the above wrapped in a scripted [`FaultPlan`]
    /// (`<channel>:fault=<spec>`). A disabled spec never constructs
    /// this variant — `fault=off` parses back to the bare channel, so
    /// fault-free scenarios are structurally (and bit-) identical.
    Faulty { inner: Box<ChannelSpec>, fault: FaultSpec },
}

impl ChannelSpec {
    /// Parse `ideal` | `erasure:<p>` | `rate:<r>[:<p>]` |
    /// `fading:<p_gb>:<p_bg>:<p_bad>[:<p_good>[:<r_bad>[:<r_good>]]]`
    /// (defaults: `p_good = 0`, `r_bad = r_good = 1`), each with an
    /// optional `:fault=<spec>` suffix ([`FaultSpec::parse`]).
    pub fn parse(s: &str) -> Result<ChannelSpec> {
        // the fault suffix comes off first: clauses contain ':' and '+'
        // but never ":fault=", so the split is unambiguous
        if let Some(i) = s.find(":fault=") {
            let inner = ChannelSpec::parse(&s[..i])?;
            let fault = FaultSpec::parse(&s[i + 7..])?;
            return Ok(inner.with_fault(&fault));
        }
        let parts: Vec<&str> = s.split(':').collect();
        let f64_at = |i: usize| -> Result<f64> {
            parts[i]
                .parse::<f64>()
                .with_context(|| format!("bad number '{}' in '{s}'", parts[i]))
        };
        match parts[0] {
            "fading" if (4..=7).contains(&parts.len()) => {
                let p_gb = f64_at(1)?;
                let p_bg = f64_at(2)?;
                let p_bad = f64_at(3)?;
                let p_good =
                    if parts.len() > 4 { f64_at(4)? } else { 0.0 };
                let rate_bad =
                    if parts.len() > 5 { f64_at(5)? } else { 1.0 };
                let rate_good =
                    if parts.len() > 6 { f64_at(6)? } else { 1.0 };
                for (name, p) in [("p_gb", p_gb), ("p_bg", p_bg)] {
                    if !(0.0..=1.0).contains(&p) {
                        bail!("fading {name} must be in [0, 1], got {p}");
                    }
                }
                for (name, p) in [("p_bad", p_bad), ("p_good", p_good)] {
                    if !(0.0..1.0).contains(&p) {
                        bail!("fading {name} must be in [0, 1), got {p}");
                    }
                }
                for (name, r) in
                    [("rate_bad", rate_bad), ("rate_good", rate_good)]
                {
                    if r <= 0.0 {
                        bail!("fading {name} must be positive, got {r}");
                    }
                }
                return Ok(ChannelSpec::Fading {
                    p_gb,
                    p_bg,
                    p_good,
                    p_bad,
                    rate_good,
                    rate_bad,
                });
            }
            _ => {}
        }
        match parts[0] {
            "ideal" if parts.len() == 1 => Ok(ChannelSpec::Ideal),
            "erasure" if parts.len() == 2 => {
                let p: f64 = parts[1]
                    .parse()
                    .with_context(|| format!("bad erasure p '{}'", parts[1]))?;
                if !(0.0..1.0).contains(&p) {
                    bail!("erasure p must be in [0, 1), got {p}");
                }
                Ok(ChannelSpec::Erasure { p })
            }
            "rate" if parts.len() == 2 || parts.len() == 3 => {
                let rate: f64 = parts[1]
                    .parse()
                    .with_context(|| format!("bad rate '{}'", parts[1]))?;
                if rate <= 0.0 {
                    bail!("rate must be positive, got {rate}");
                }
                let p: f64 = match parts.get(2) {
                    Some(t) => t
                        .parse()
                        .with_context(|| format!("bad rate p '{t}'"))?,
                    None => 0.0,
                };
                if !(0.0..1.0).contains(&p) {
                    bail!("rate-channel p must be in [0, 1), got {p}");
                }
                Ok(ChannelSpec::Rate { rate, p })
            }
            other => bail!(
                "unknown or malformed channel '{other}' (expected ideal | \
                 erasure:<p> | rate:<r>[:<p>] | \
                 fading:<p_gb>:<p_bg>:<p_bad>[:<p_good>[:<r_bad>[:<r_good>]]])"
            ),
        }
    }

    /// Expected long-run slowdown factor of the channel relative to the
    /// ideal unit-rate link (≥ 1 for loss, ≤ 1 for a faster rate): the
    /// factor by which the effective transmission budget shrinks. Used
    /// by `bound::validate` to make the Corollary-1 recommendation
    /// channel-aware. For fading this is the stationary mixture of the
    /// per-state occupancies (exact in the stationary regime).
    pub fn expected_slowdown(&self) -> f64 {
        match *self {
            ChannelSpec::Ideal => 1.0,
            ChannelSpec::Erasure { p } => 1.0 / (1.0 - p),
            ChannelSpec::Rate { rate, p } => 1.0 / ((1.0 - p) * rate),
            ChannelSpec::Fading { .. } => match self.make() {
                ScenarioChannel::Fading(ge) => ge.expected_slowdown(),
                _ => unreachable!("fading spec builds a fading channel"),
            },
            // deliberately fault-blind: the a-priori Corollary-1
            // recommendation must not anticipate scripted faults (the
            // whole point of the graceful-degradation comparison)
            ChannelSpec::Faulty { ref inner, .. } => {
                inner.expected_slowdown()
            }
        }
    }

    /// Instantiate a fresh channel on the stack (stateless across runs;
    /// the sweep hot path builds one per run without a heap allocation —
    /// except [`Faulty`](Self::Faulty), which boxes its wrapper).
    pub fn make(&self) -> ScenarioChannel {
        match *self {
            ChannelSpec::Ideal => ScenarioChannel::Ideal(IdealChannel),
            ChannelSpec::Erasure { p } => {
                ScenarioChannel::Erasure(ErasureChannel::new(p))
            }
            ChannelSpec::Rate { rate, p } => ScenarioChannel::Rate(
                RateLimitedChannel::new(rate, ErasureChannel::new(p)),
            ),
            ChannelSpec::Fading {
                p_gb,
                p_bg,
                p_good,
                p_bad,
                rate_good,
                rate_bad,
            } => ScenarioChannel::Fading(GilbertElliottChannel::new(
                p_gb,
                p_bg,
                LinkState::new(rate_good, p_good),
                LinkState::new(rate_bad, p_bad),
            )),
            ChannelSpec::Faulty { ref inner, ref fault } => {
                ScenarioChannel::Faulty(Box::new(FaultPlan::new(
                    fault.clone(),
                    inner.make(),
                )))
            }
        }
    }

    /// [`make`](Self::make) with the fault plan (if any) pinned to
    /// device `lane` — required inside a
    /// [`MultiLaneChannel`](crate::channel::MultiLaneChannel), which
    /// routes packets to lane channels without forwarding
    /// [`Channel::select_lane`].
    pub fn make_for_lane(&self, lane: usize) -> ScenarioChannel {
        match self.make() {
            ScenarioChannel::Faulty(plan) => {
                ScenarioChannel::Faulty(Box::new(plan.for_lane(lane)))
            }
            other => other,
        }
    }

    /// Boxed convenience form of [`make`](Self::make).
    pub fn build(&self) -> Box<dyn Channel> {
        Box::new(self.make())
    }

    /// Wrap this channel in `fault` (replacing any existing plan); a
    /// disabled spec unwraps instead, so `with_fault(off)` is the bare
    /// channel — the parity invariant behind `fault=off` ≡ absent.
    pub fn with_fault(&self, fault: &FaultSpec) -> ChannelSpec {
        let inner = match self {
            ChannelSpec::Faulty { inner, .. } => inner.as_ref().clone(),
            other => other.clone(),
        };
        if fault.is_disabled() {
            inner
        } else {
            ChannelSpec::Faulty {
                inner: Box::new(inner),
                fault: fault.clone(),
            }
        }
    }

    /// The scripted fault plan, if one is attached.
    pub fn fault_spec(&self) -> Option<&FaultSpec> {
        match self {
            ChannelSpec::Faulty { fault, .. } => Some(fault),
            _ => None,
        }
    }

    /// The Gilbert–Elliott parameters the `est=ge` belief filter
    /// conditions on: exact for `fading`; the static channels are the
    /// degenerate pinned-good chain (`p_gb = 0`), under which the
    /// belief — and therefore the slowdown estimate — never moves, the
    /// invariant behind the ControlPolicy ≡ FixedPolicy parity.
    pub fn ge_params(&self) -> GeParams {
        match *self {
            ChannelSpec::Ideal => {
                let link = LinkState::new(1.0, 0.0);
                GeParams::new(0.0, 1.0, link, link)
            }
            ChannelSpec::Erasure { p } => {
                let link = LinkState::new(1.0, p);
                GeParams::new(0.0, 1.0, link, link)
            }
            ChannelSpec::Rate { rate, p } => {
                let link = LinkState::new(rate, p);
                GeParams::new(0.0, 1.0, link, link)
            }
            ChannelSpec::Fading {
                p_gb,
                p_bg,
                p_good,
                p_bad,
                rate_good,
                rate_bad,
            } => GeParams::new(
                p_gb,
                p_bg,
                LinkState::new(rate_good, p_good),
                LinkState::new(rate_bad, p_bad),
            ),
            // fault-blind, like expected_slowdown: the belief filter
            // conditions on the nominal channel only
            ChannelSpec::Faulty { ref inner, .. } => inner.ge_params(),
        }
    }

    pub fn label(&self) -> String {
        match *self {
            ChannelSpec::Ideal => "ideal".to_string(),
            ChannelSpec::Erasure { p } => format!("erasure:{p}"),
            ChannelSpec::Rate { rate, p } => format!("rate:{rate}:{p}"),
            ChannelSpec::Fading {
                p_gb,
                p_bg,
                p_good,
                p_bad,
                rate_good,
                rate_bad,
            } => {
                // print the shortest suffix-defaulted form that still
                // round-trips through parse()
                let mut label = format!("fading:{p_gb}:{p_bg}:{p_bad}");
                if p_good != 0.0 || rate_bad != 1.0 || rate_good != 1.0 {
                    label.push_str(&format!(":{p_good}"));
                }
                if rate_bad != 1.0 || rate_good != 1.0 {
                    label.push_str(&format!(":{rate_bad}"));
                }
                if rate_good != 1.0 {
                    label.push_str(&format!(":{rate_good}"));
                }
                label
            }
            ChannelSpec::Faulty { ref inner, ref fault } => {
                format!("{}:fault={}", inner.label(), fault.label())
            }
        }
    }
}

/// A [`ChannelSpec`]'s channel, built by value (no `Box`) so the sweep
/// hot path stays allocation-free.
pub enum ScenarioChannel {
    Ideal(IdealChannel),
    Erasure(ErasureChannel),
    Rate(RateLimitedChannel<ErasureChannel>),
    Fading(GilbertElliottChannel),
    /// Boxed to break the `FaultPlan<ScenarioChannel>` recursion — the
    /// one allocation is paid only by fault-injected runs.
    Faulty(Box<FaultPlan<ScenarioChannel>>),
}

impl Channel for ScenarioChannel {
    fn transmit(
        &mut self,
        sent_at: f64,
        duration: f64,
        rng: &mut Pcg32,
    ) -> Delivery {
        match self {
            ScenarioChannel::Ideal(c) => c.transmit(sent_at, duration, rng),
            ScenarioChannel::Erasure(c) => c.transmit(sent_at, duration, rng),
            ScenarioChannel::Rate(c) => c.transmit(sent_at, duration, rng),
            ScenarioChannel::Fading(c) => c.transmit(sent_at, duration, rng),
            ScenarioChannel::Faulty(c) => c.transmit(sent_at, duration, rng),
        }
    }

    fn describe(&self) -> String {
        match self {
            ScenarioChannel::Ideal(c) => c.describe(),
            ScenarioChannel::Erasure(c) => c.describe(),
            ScenarioChannel::Rate(c) => c.describe(),
            ScenarioChannel::Fading(c) => c.describe(),
            ScenarioChannel::Faulty(c) => c.describe(),
        }
    }

    fn select_lane(&mut self, lane: usize) {
        // only the fault plan keys off the active device; the nominal
        // channels keep the trait's no-op
        if let ScenarioChannel::Faulty(c) = self {
            c.select_lane(lane);
        }
    }
}

/// Which channel estimator a closed-loop `control` policy runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EstimatorSpec {
    /// Bayesian Gilbert–Elliott belief filter conditioned on the
    /// scenario's channel parameters (exact for `fading`, degenerate
    /// pinned-good for the static channels). On heterogeneous
    /// multi-lane traffic — whose aggregate has no single
    /// Gilbert–Elliott model — the runner falls back to [`Ema`](Self::Ema).
    Ge,
    /// Model-free exponentially weighted moving average of the measured
    /// per-packet slowdown (for unknown channels; also the right choice
    /// on the heterogeneous multi-lane uplink, whose aggregate has no
    /// single Gilbert–Elliott model).
    Ema,
}

impl EstimatorSpec {
    /// Parse `ge` | `ema`.
    pub fn parse(s: &str) -> Result<EstimatorSpec> {
        match s {
            "ge" => Ok(EstimatorSpec::Ge),
            "ema" => Ok(EstimatorSpec::Ema),
            other => bail!(
                "unknown channel estimator '{other}' (expected ge | ema)"
            ),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EstimatorSpec::Ge => "ge",
            EstimatorSpec::Ema => "ema",
        }
    }
}

/// How block sizes are chosen (and whether compute overlaps the link).
#[derive(Clone, Debug, PartialEq)]
pub enum PolicySpec {
    /// The paper's fixed `n_c` (0 = inherit the run config's `n_c`).
    Fixed { n_c: usize },
    /// Geometric warmup from `start`, ×`growth` per block, capped at
    /// `cap` (0 = inherit the run config's `n_c`).
    Warmup { start: usize, growth: f64, cap: usize },
    /// Deadline-aware greedy sizing at `frac` of the remaining budget.
    Deadline { frac: f64 },
    /// Non-pipelined baseline: fixed blocks, edge idles while sending.
    Sequential { n_c: usize },
    /// Transmit-all-first baseline: one block of every sample.
    AllFirst,
    /// Closed-loop channel-adaptive control: an online channel
    /// estimator + the Corollary-1 remaining-budget re-optimizer,
    /// re-planned every `replan_every` blocks (`bound::replan`,
    /// `channel::estimator`, `coordinator::scheduler::ControlPolicy`).
    Control { est: EstimatorSpec, replan_every: usize },
}

impl PolicySpec {
    /// Parse `fixed[:n_c]` | `warmup:<start>:<growth>[:<cap>]` |
    /// `deadline:<frac>` | `sequential[:n_c]` | `allfirst` |
    /// `control[:est=<ge|ema>][:replan=<k>]`.
    pub fn parse(s: &str) -> Result<PolicySpec> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts[0] == "control" {
            let mut est = EstimatorSpec::Ge;
            let mut replan_every = 1usize;
            for part in &parts[1..] {
                if let Some(v) = part.strip_prefix("est=") {
                    est = EstimatorSpec::parse(v)?;
                } else if let Some(v) = part.strip_prefix("replan=") {
                    replan_every = v.parse().with_context(|| {
                        format!("bad replan interval '{v}' in '{s}'")
                    })?;
                    if replan_every == 0 {
                        bail!("control replan interval must be >= 1");
                    }
                } else {
                    bail!(
                        "unknown control option '{part}' in '{s}' \
                         (expected est=<ge|ema>, replan=<k>)"
                    );
                }
            }
            return Ok(PolicySpec::Control { est, replan_every });
        }
        let usize_at = |i: usize| -> Result<usize> {
            parts[i]
                .parse::<usize>()
                .with_context(|| format!("bad integer '{}' in '{s}'", parts[i]))
        };
        match parts[0] {
            "fixed" if parts.len() == 1 => Ok(PolicySpec::Fixed { n_c: 0 }),
            "fixed" if parts.len() == 2 => {
                Ok(PolicySpec::Fixed { n_c: usize_at(1)? })
            }
            "warmup" if parts.len() == 3 || parts.len() == 4 => {
                let start = usize_at(1)?;
                if start == 0 {
                    bail!("warmup start must be >= 1");
                }
                let growth: f64 = parts[2].parse().with_context(|| {
                    format!("bad growth '{}' in '{s}'", parts[2])
                })?;
                if growth < 1.0 {
                    bail!("warmup growth must be >= 1.0, got {growth}");
                }
                let cap =
                    if parts.len() == 4 { usize_at(3)? } else { 0 };
                Ok(PolicySpec::Warmup { start, growth, cap })
            }
            "deadline" if parts.len() == 2 => {
                let frac: f64 = parts[1].parse().with_context(|| {
                    format!("bad fraction '{}' in '{s}'", parts[1])
                })?;
                if !(0.0..=1.0).contains(&frac) || frac == 0.0 {
                    bail!("deadline fraction must be in (0, 1], got {frac}");
                }
                Ok(PolicySpec::Deadline { frac })
            }
            "sequential" if parts.len() == 1 => {
                Ok(PolicySpec::Sequential { n_c: 0 })
            }
            "sequential" if parts.len() == 2 => {
                Ok(PolicySpec::Sequential { n_c: usize_at(1)? })
            }
            "allfirst" if parts.len() == 1 => Ok(PolicySpec::AllFirst),
            other => bail!(
                "unknown policy '{other}' (expected fixed[:n_c] | \
                 warmup:<start>:<growth>[:<cap>] | deadline:<frac> | \
                 sequential[:n_c] | allfirst | \
                 control[:est=<ge|ema>][:replan=<k>])"
            ),
        }
    }

    /// Whether the edge computes while the channel is busy.
    pub fn overlap(&self) -> OverlapMode {
        match self {
            PolicySpec::Sequential { .. } => OverlapMode::Sequential,
            _ => OverlapMode::Pipelined,
        }
    }

    /// Instantiate the block policy on the stack for a dataset of `n`
    /// samples (no `Box` — the sweep hot path builds one per run).
    ///
    /// `Control` cannot be built here: its plan needs the dataset and
    /// the scenario's channel prior, which only `ScenarioRunner` has —
    /// it builds the `ControlPolicy` itself (`run_with`); calling
    /// `make`/`build` on a `Control` spec panics.
    pub fn make(&self, cfg: &DesConfig, n: usize) -> ScenarioPolicy {
        let inherit = |v: usize| {
            let v = if v == 0 { cfg.n_c } else { v };
            v.clamp(1, n.max(1))
        };
        match *self {
            PolicySpec::Fixed { n_c } => {
                ScenarioPolicy::Fixed(FixedPolicy(inherit(n_c)))
            }
            PolicySpec::Warmup { start, growth, cap } => {
                let cap = inherit(cap).max(start);
                ScenarioPolicy::Warmup(WarmupSchedule::new(start, growth, cap))
            }
            PolicySpec::Deadline { frac } => {
                ScenarioPolicy::Deadline(DeadlineAwareSchedule {
                    t_budget: cfg.t_budget,
                    n_o: cfg.n_o,
                    aggressiveness: frac,
                })
            }
            PolicySpec::Sequential { n_c } => {
                ScenarioPolicy::Fixed(FixedPolicy(inherit(n_c)))
            }
            PolicySpec::AllFirst => {
                ScenarioPolicy::Fixed(FixedPolicy(n.max(1)))
            }
            PolicySpec::Control { .. } => panic!(
                "ControlPolicy needs dataset context; run control \
                 scenarios through ScenarioRunner"
            ),
        }
    }

    /// Boxed convenience form of [`make`](Self::make).
    pub fn build(&self, cfg: &DesConfig, n: usize) -> Box<dyn BlockPolicy> {
        Box::new(self.make(cfg, n))
    }

    pub fn label(&self) -> String {
        match *self {
            PolicySpec::Fixed { n_c: 0 } => "fixed".to_string(),
            PolicySpec::Fixed { n_c } => format!("fixed:{n_c}"),
            PolicySpec::Warmup { start, growth, cap: 0 } => {
                format!("warmup:{start}:{growth}")
            }
            PolicySpec::Warmup { start, growth, cap } => {
                format!("warmup:{start}:{growth}:{cap}")
            }
            PolicySpec::Deadline { frac } => format!("deadline:{frac}"),
            PolicySpec::Sequential { n_c: 0 } => "sequential".to_string(),
            PolicySpec::Sequential { n_c } => format!("sequential:{n_c}"),
            PolicySpec::AllFirst => "allfirst".to_string(),
            PolicySpec::Control { est, replan_every } => {
                // shortest suffix-defaulted form that round-trips
                let mut label = "control".to_string();
                if est != EstimatorSpec::Ge {
                    label.push_str(&format!(":est={}", est.label()));
                }
                if replan_every != 1 {
                    label.push_str(&format!(":replan={replan_every}"));
                }
                label
            }
        }
    }
}

/// A [`PolicySpec`]'s block policy, built by value (no `Box`) so the
/// sweep hot path stays allocation-free.
pub enum ScenarioPolicy {
    Fixed(FixedPolicy),
    Warmup(WarmupSchedule),
    Deadline(DeadlineAwareSchedule),
    Control(ControlPolicy),
}

impl BlockPolicy for ScenarioPolicy {
    fn next_n_c(&mut self, block: usize, remaining: usize, t_now: f64)
        -> usize {
        match self {
            ScenarioPolicy::Fixed(p) => p.next_n_c(block, remaining, t_now),
            ScenarioPolicy::Warmup(p) => p.next_n_c(block, remaining, t_now),
            ScenarioPolicy::Deadline(p) => {
                p.next_n_c(block, remaining, t_now)
            }
            ScenarioPolicy::Control(p) => {
                p.next_n_c(block, remaining, t_now)
            }
        }
    }

    fn observe(&mut self, obs: &PacketObs) {
        // only the closed-loop policy consumes observations; the
        // open-loop schedules keep the trait's no-op
        if let ScenarioPolicy::Control(p) = self {
            p.observe(obs);
        }
    }

    fn observe_fault(&mut self, obs: &FaultObs) {
        if let ScenarioPolicy::Control(p) = self {
            p.observe_fault(obs);
        }
    }

    fn name(&self) -> String {
        match self {
            ScenarioPolicy::Fixed(p) => p.name(),
            ScenarioPolicy::Warmup(p) => p.name(),
            ScenarioPolicy::Deadline(p) => p.name(),
            ScenarioPolicy::Control(p) => p.name(),
        }
    }
}

/// Which [`DeviceScheduler`] picks the transmitting device on a
/// heterogeneous multi-lane uplink.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerSpec {
    /// Strict rotation (the Sec. 6 baseline).
    RoundRobin,
    /// Fastest-expected-finish greedy via the lanes' expected slowdowns
    /// (ties rotate, so identical lanes reduce to round-robin).
    Greedy,
    /// Data-debt proportional-fair:
    /// `remaining / ((1 + sent) · slowdown)`.
    PropFair,
}

impl SchedulerSpec {
    /// Parse `rr` | `greedy` | `pfair`.
    pub fn parse(s: &str) -> Result<SchedulerSpec> {
        match s {
            "rr" | "round-robin" | "roundrobin" => {
                Ok(SchedulerSpec::RoundRobin)
            }
            "greedy" => Ok(SchedulerSpec::Greedy),
            "pfair" | "prop-fair" | "propfair" => Ok(SchedulerSpec::PropFair),
            other => bail!(
                "unknown device scheduler '{other}' \
                 (expected rr | greedy | pfair)"
            ),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchedulerSpec::RoundRobin => "rr",
            SchedulerSpec::Greedy => "greedy",
            SchedulerSpec::PropFair => "pfair",
        }
    }

    /// Instantiate the scheduler on the stack (fresh rotation state).
    pub fn make(&self) -> ScenarioScheduler {
        match self {
            SchedulerSpec::RoundRobin => {
                ScenarioScheduler::RoundRobin(RoundRobinScheduler::new())
            }
            SchedulerSpec::Greedy => {
                ScenarioScheduler::Greedy(GreedyScheduler::new())
            }
            SchedulerSpec::PropFair => {
                ScenarioScheduler::PropFair(PropFairScheduler::new())
            }
        }
    }
}

/// A [`SchedulerSpec`]'s scheduler, built by value (no `Box`) so the
/// sweep hot path stays allocation-free.
pub enum ScenarioScheduler {
    RoundRobin(RoundRobinScheduler),
    Greedy(GreedyScheduler),
    PropFair(PropFairScheduler),
}

impl DeviceScheduler for ScenarioScheduler {
    fn pick(&mut self, lanes: &[LaneView]) -> usize {
        match self {
            ScenarioScheduler::RoundRobin(s) => s.pick(lanes),
            ScenarioScheduler::Greedy(s) => s.pick(lanes),
            ScenarioScheduler::PropFair(s) => s.pick(lanes),
        }
    }

    fn name(&self) -> String {
        match self {
            ScenarioScheduler::RoundRobin(s) => s.name(),
            ScenarioScheduler::Greedy(s) => s.name(),
            ScenarioScheduler::PropFair(s) => s.name(),
        }
    }
}

/// The heterogeneous multi-lane uplink: `k` devices with their own
/// channels, a pluggable device scheduler and label-skew sharding.
#[derive(Clone, Debug, PartialEq)]
pub struct HeteroSpec {
    /// Device count (`k >= 1`).
    pub k: usize,
    /// Who transmits next.
    pub sched: SchedulerSpec,
    /// Label-skew of the shards (0 = IID round-robin sharding,
    /// 1 = fully label-sorted contiguous shards).
    pub skew: f64,
    /// Per-device channels: empty = every lane inherits the scenario's
    /// channel axis; one spec = broadcast to all lanes; else exactly
    /// `k` specs, lane `i` gets `channels[i]`.
    pub channels: Vec<ChannelSpec>,
}

impl HeteroSpec {
    /// Validated constructor (shared by the parser and the CLI).
    pub fn new(
        k: usize,
        sched: SchedulerSpec,
        skew: f64,
        channels: Vec<ChannelSpec>,
    ) -> Result<HeteroSpec> {
        if k == 0 {
            bail!("device count must be >= 1");
        }
        if !(0.0..=1.0).contains(&skew) {
            bail!("device skew must be in [0, 1], got {skew}");
        }
        if !(channels.is_empty()
            || channels.len() == 1
            || channels.len() == k)
        {
            bail!(
                "need 0, 1 or {k} device channels, got {}",
                channels.len()
            );
        }
        Ok(HeteroSpec { k, sched, skew, channels })
    }

    /// Lane `i`'s channel spec, with `default` (the scenario channel
    /// axis) filling in when no per-device channels were given.
    pub fn lane_channel(&self, i: usize, default: &ChannelSpec)
        -> ChannelSpec {
        match self.channels.len() {
            0 => default.clone(),
            1 => self.channels[0].clone(),
            _ => self.channels[i].clone(),
        }
    }
}

/// Who is transmitting.
#[derive(Clone, Debug, PartialEq)]
pub enum TrafficSpec {
    /// `k` devices with disjoint IID shards, round-robin on ONE shared
    /// uplink channel (`k = 1` is the paper's single device).
    Devices(usize),
    /// One device whose samples arrive over time at `rate` per unit.
    Online { rate: f64 },
    /// Heterogeneous multi-lane uplink: per-device channels + pluggable
    /// device scheduler + label-skew shards ([`HeteroSpec`]).
    Hetero(HeteroSpec),
}

impl TrafficSpec {
    /// Parse `<k>` | `online:<rate>` |
    /// `devices:<k>[:sched=<rr|greedy|pfair>][:skew=<f>]`
    /// `[:ch=<spec>,<spec>,…]` (the `ch=` option must come last — channel
    /// specs contain `:` and `,` themselves).
    pub fn parse(s: &str) -> Result<TrafficSpec> {
        if let Some(rest) = s.strip_prefix("online:") {
            let rate: f64 = rest
                .parse()
                .with_context(|| format!("bad arrival rate '{rest}'"))?;
            if rate <= 0.0 {
                bail!("arrival rate must be positive, got {rate}");
            }
            return Ok(TrafficSpec::Online { rate });
        }
        if let Some(rest) = s.strip_prefix("devices:") {
            // split the ch= tail off first: everything after ":ch=" is
            // the comma-separated per-device channel list
            let (head, ch_list) = match rest.find(":ch=") {
                Some(i) => (&rest[..i], Some(&rest[i + 4..])),
                None => (rest, None),
            };
            let mut parts = head.split(':');
            let k_part = parts.next().unwrap_or("");
            let k: usize = k_part.parse().with_context(|| {
                format!("bad device count '{k_part}' in '{s}'")
            })?;
            let mut sched = SchedulerSpec::RoundRobin;
            let mut skew = 0.0f64;
            for part in parts {
                if let Some(v) = part.strip_prefix("sched=") {
                    sched = SchedulerSpec::parse(v)?;
                } else if let Some(v) = part.strip_prefix("skew=") {
                    skew = v.parse().with_context(|| {
                        format!("bad skew '{v}' in '{s}'")
                    })?;
                } else {
                    bail!(
                        "unknown device option '{part}' in '{s}' \
                         (expected sched=<rr|greedy|pfair>, skew=<f>, \
                         or a trailing ch=<spec>,<spec>,…)"
                    );
                }
            }
            let channels = match ch_list {
                Some("") => bail!("empty ch= list in '{s}'"),
                Some(list) => list
                    .split(',')
                    .map(ChannelSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
                None => Vec::new(),
            };
            return Ok(TrafficSpec::Hetero(HeteroSpec::new(
                k, sched, skew, channels,
            )?));
        }
        let k: usize = s
            .parse()
            .with_context(|| format!("bad device count '{s}'"))?;
        if k == 0 {
            bail!("device count must be >= 1");
        }
        Ok(TrafficSpec::Devices(k))
    }

    pub fn label(&self) -> String {
        match self {
            TrafficSpec::Devices(k) => format!("k{k}"),
            TrafficSpec::Online { rate } => format!("online:{rate}"),
            TrafficSpec::Hetero(h) => {
                // shortest suffix-defaulted form that round-trips
                let mut label = format!("devices:{}", h.k);
                if h.sched != SchedulerSpec::RoundRobin {
                    label.push_str(&format!(":sched={}", h.sched.label()));
                }
                if h.skew != 0.0 {
                    label.push_str(&format!(":skew={}", h.skew));
                }
                if !h.channels.is_empty() {
                    let specs: Vec<String> =
                        h.channels.iter().map(|c| c.label()).collect();
                    label.push_str(&format!(":ch={}", specs.join(",")));
                }
                label
            }
        }
    }
}

/// One fully-specified protocol scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub channel: ChannelSpec,
    pub policy: PolicySpec,
    pub traffic: TrafficSpec,
    /// Which per-sample loss the edge trains (ridge = the paper).
    pub workload: Workload,
    /// Edge store capacity (None = unbounded).
    pub store_capacity: Option<usize>,
}

impl ScenarioSpec {
    /// The paper's reference scenario (ideal channel, fixed `n_c`, one
    /// device, ridge) —
    /// [`mc_final_loss`](crate::sweep::runner::mc_final_loss)
    /// runs exactly this.
    pub fn paper() -> ScenarioSpec {
        ScenarioSpec {
            channel: ChannelSpec::Ideal,
            policy: PolicySpec::Fixed { n_c: 0 },
            traffic: TrafficSpec::Devices(1),
            workload: Workload::Ridge,
            store_capacity: None,
        }
    }

    /// Parse the four axis strings (`store` 0 = unbounded).
    pub fn parse(
        channel: &str,
        policy: &str,
        traffic: &str,
        workload: &str,
        store: usize,
    ) -> Result<ScenarioSpec> {
        Ok(ScenarioSpec {
            channel: ChannelSpec::parse(channel)?,
            policy: PolicySpec::parse(policy)?,
            traffic: TrafficSpec::parse(traffic)?,
            workload: Workload::parse(workload)?,
            store_capacity: if store == 0 { None } else { Some(store) },
        })
    }

    /// Compact display label, e.g. `erasure:0.1|warmup:16:2|k4` (the
    /// default ridge workload is omitted for continuity with pre-axis
    /// labels).
    pub fn label(&self) -> String {
        let mut label = format!(
            "{}|{}|{}",
            self.channel.label(),
            self.policy.label(),
            self.traffic.label()
        );
        if self.workload != Workload::Ridge {
            label.push_str(&format!("|{}", self.workload.label()));
        }
        if let Some(cap) = self.store_capacity {
            label.push_str(&format!("|cap{cap}"));
        }
        label
    }

    /// Expected long-run slowdown of the scenario's whole uplink.
    ///
    /// For single-channel traffic this is the channel axis's
    /// [`ChannelSpec::expected_slowdown`]. For the heterogeneous
    /// multi-lane uplink it is the data-share-weighted aggregate of the
    /// per-lane slowdowns (`bound::validate::aggregate_slowdown` with
    /// equal shares — shards are near-equal by construction): every lane
    /// must push its shard through the shared serialized uplink, so the
    /// effective budget shrinks by the mean per-sample occupancy.
    pub fn expected_slowdown(&self) -> f64 {
        match &self.traffic {
            TrafficSpec::Hetero(h) => {
                (0..h.k)
                    .map(|i| {
                        h.lane_channel(i, &self.channel).expected_slowdown()
                    })
                    .sum::<f64>()
                    / h.k as f64
            }
            _ => self.channel.expected_slowdown(),
        }
    }
}

/// Named presets runnable as `edgepipe scenario --preset <name>`.
pub fn registry() -> Vec<(&'static str, ScenarioSpec)> {
    let base = ScenarioSpec::paper();
    vec![
        ("paper", base.clone()),
        (
            "sequential",
            ScenarioSpec {
                policy: PolicySpec::Sequential { n_c: 0 },
                ..base.clone()
            },
        ),
        (
            "all-first",
            ScenarioSpec { policy: PolicySpec::AllFirst, ..base.clone() },
        ),
        (
            "erasure",
            ScenarioSpec {
                channel: ChannelSpec::Erasure { p: 0.1 },
                ..base.clone()
            },
        ),
        (
            "warmup",
            ScenarioSpec {
                policy: PolicySpec::Warmup {
                    start: 16,
                    growth: 2.0,
                    cap: 0,
                },
                ..base.clone()
            },
        ),
        (
            "multi4",
            ScenarioSpec { traffic: TrafficSpec::Devices(4), ..base.clone() },
        ),
        (
            "online",
            ScenarioSpec {
                traffic: TrafficSpec::Online { rate: 1.0 },
                ..base.clone()
            },
        ),
        (
            "limited-memory",
            ScenarioSpec { store_capacity: Some(1000), ..base.clone() },
        ),
        (
            // bursty link: fades every ~20 packets, lasting ~4 packets,
            // losing 60% of attempts at half rate while faded
            "fading",
            ScenarioSpec {
                channel: ChannelSpec::Fading {
                    p_gb: 0.05,
                    p_bg: 0.25,
                    p_good: 0.0,
                    p_bad: 0.6,
                    rate_good: 1.0,
                    rate_bad: 0.5,
                },
                ..base.clone()
            },
        ),
        (
            "logistic",
            ScenarioSpec { workload: Workload::Logistic, ..base.clone() },
        ),
        (
            // heterogeneous fleet: a clean lane, a lossy lane and a
            // bursty fading lane, scheduled fastest-expected-finish with
            // moderately label-skewed shards
            "hetero3",
            ScenarioSpec {
                traffic: TrafficSpec::Hetero(HeteroSpec {
                    k: 3,
                    sched: SchedulerSpec::Greedy,
                    skew: 0.5,
                    channels: vec![
                        ChannelSpec::Ideal,
                        ChannelSpec::Erasure { p: 0.2 },
                        ChannelSpec::Fading {
                            p_gb: 0.05,
                            p_bg: 0.25,
                            p_good: 0.0,
                            p_bad: 0.6,
                            rate_good: 1.0,
                            rate_bad: 0.5,
                        },
                    ],
                }),
                ..base.clone()
            },
        ),
        (
            // proportional-fair service of four rate-diverse devices
            // holding strongly non-IID shards
            "pfair4",
            ScenarioSpec {
                traffic: TrafficSpec::Hetero(HeteroSpec {
                    k: 4,
                    sched: SchedulerSpec::PropFair,
                    skew: 0.8,
                    channels: vec![
                        ChannelSpec::Rate { rate: 2.0, p: 0.0 },
                        ChannelSpec::Rate { rate: 1.0, p: 0.1 },
                        ChannelSpec::Rate { rate: 0.5, p: 0.1 },
                        ChannelSpec::Erasure { p: 0.3 },
                    ],
                }),
                ..base.clone()
            },
        ),
        (
            "fading-logistic",
            ScenarioSpec {
                channel: ChannelSpec::Fading {
                    p_gb: 0.05,
                    p_bg: 0.25,
                    p_good: 0.0,
                    p_bad: 0.6,
                    rate_good: 1.0,
                    rate_bad: 0.5,
                },
                workload: Workload::Logistic,
                ..base.clone()
            },
        ),
        (
            // the hetero3 fleet under faults: the bursty lane's device
            // dies permanently at t = 150 and the protocol runs the
            // hardened ARQ (timeout 4x, budget 2, evict after 2
            // consecutive timeouts), so the closed-loop controller
            // re-plans around the shed shard instead of stalling on it
            "hetero3_dropout_control",
            ScenarioSpec {
                traffic: TrafficSpec::Hetero(HeteroSpec {
                    k: 3,
                    sched: SchedulerSpec::Greedy,
                    skew: 0.5,
                    channels: vec![
                        ChannelSpec::Ideal,
                        ChannelSpec::Erasure { p: 0.2 },
                        ChannelSpec::parse(
                            "fading:0.05:0.25:0.6:0:0.5\
                             :fault=drop:2:150+retry:4:2:2",
                        )
                        .expect("preset fault spec parses"),
                    ],
                }),
                policy: PolicySpec::Control {
                    est: EstimatorSpec::Ema,
                    replan_every: 1,
                },
                ..base.clone()
            },
        ),
        (
            // severe, slow-mixing fades (~6-7 packets each, 40% of the
            // time, 50% loss at 0.3x rate while faded): the regime
            // where a fixed a-priori n_c wastes budget and the
            // closed-loop controller (GE belief filter + Corollary-1
            // re-planning at every block boundary) earns its keep
            "adaptive_fading",
            ScenarioSpec {
                channel: ChannelSpec::Fading {
                    p_gb: 0.1,
                    p_bg: 0.15,
                    p_good: 0.0,
                    p_bad: 0.5,
                    rate_good: 1.0,
                    rate_bad: 0.3,
                },
                policy: PolicySpec::Control {
                    est: EstimatorSpec::Ge,
                    replan_every: 1,
                },
                ..base
            },
        ),
    ]
}

/// Look a preset up by name.
pub fn from_name(name: &str) -> Option<ScenarioSpec> {
    registry()
        .into_iter()
        .find(|(n, _)| *n == name)
        .map(|(_, spec)| spec)
}

/// Executes one [`ScenarioSpec`] deterministically per [`DesConfig`].
/// Shards (and, for the logistic workload, the median-binarized label
/// view) are built once at construction; every [`run`](Self::run) call
/// builds a fresh channel/source/policy/executor, so a single runner can
/// serve many seeds from many threads concurrently.
pub struct ScenarioRunner<'a> {
    ds: &'a Dataset,
    /// Classification view (labels binarized at the median) used when
    /// the workload is logistic; covariates are shared with `ds`.
    class_ds: Option<Dataset>,
    spec: ScenarioSpec,
    shards: Vec<Dataset>,
    /// Resolved per-lane channel specs (heterogeneous traffic only).
    lane_channels: Vec<ChannelSpec>,
    /// Per-lane expected slowdowns, the greedy/proportional-fair
    /// schedulers' ranking signal (heterogeneous traffic only).
    lane_slowdowns: Vec<f64>,
    /// Memoized control plan (Control policy only): the plan is a pure
    /// function of (dataset, λ, α, T, n_o, τ_p, workload, slowdown
    /// prior) — computed once, shared across all Monte-Carlo seeds and
    /// worker threads.
    control_cache: Mutex<Option<(PlanKey, ControlPlan)>>,
}

/// The run-config fields a [`ControlPlan`] depends on (f64s compared by
/// exact bit pattern: same inputs → same cached plan).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct PlanKey {
    lambda: u64,
    alpha: u64,
    t_budget: u64,
    n_o: u64,
    tau_p: u64,
    workload: Workload,
}

impl PlanKey {
    fn of(cfg: &DesConfig) -> PlanKey {
        PlanKey {
            lambda: cfg.lambda.to_bits(),
            alpha: cfg.alpha.to_bits(),
            t_budget: cfg.t_budget.to_bits(),
            n_o: cfg.n_o.to_bits(),
            tau_p: cfg.tau_p.to_bits(),
            workload: cfg.workload,
        }
    }
}

impl<'a> ScenarioRunner<'a> {
    pub fn new(spec: ScenarioSpec, ds: &'a Dataset) -> ScenarioRunner<'a> {
        let class_ds = match spec.workload {
            Workload::Ridge => None,
            Workload::Logistic => Some(binarize_labels(ds)),
        };
        let shards = {
            let eff = class_ds.as_ref().unwrap_or(ds);
            match &spec.traffic {
                TrafficSpec::Devices(k) if *k > 1 => {
                    shard_round_robin(eff, *k)
                }
                // skew = 0 keeps the exact IID round-robin layout, so a
                // zero-skew hetero scenario shards like Devices(k)
                TrafficSpec::Hetero(h) if h.skew == 0.0 => {
                    shard_round_robin(eff, h.k)
                }
                TrafficSpec::Hetero(h) => {
                    shard_label_skew(eff, h.k, h.skew)
                }
                _ => Vec::new(),
            }
        };
        let lane_channels: Vec<ChannelSpec> = match &spec.traffic {
            TrafficSpec::Hetero(h) => (0..h.k)
                .map(|i| h.lane_channel(i, &spec.channel))
                .collect(),
            _ => Vec::new(),
        };
        let lane_slowdowns: Vec<f64> =
            lane_channels.iter().map(|c| c.expected_slowdown()).collect();
        ScenarioRunner {
            ds,
            class_ds,
            spec,
            shards,
            lane_channels,
            lane_slowdowns,
            control_cache: Mutex::new(None),
        }
    }

    pub fn spec(&self) -> &ScenarioSpec {
        &self.spec
    }

    /// The control plan for `cfg` (Control policy only): computed on
    /// first use with the scenario's a-priori expected slowdown, then
    /// cached — every Monte-Carlo seed reuses the identical plan, so
    /// sweeps pay the constant estimation once.
    pub fn control_plan(&self, cfg: &DesConfig) -> ControlPlan {
        let key = PlanKey::of(cfg);
        let mut guard = self
            .control_cache
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        if let Some((k, plan)) = guard.as_ref() {
            if *k == key {
                return plan.clone();
            }
        }
        let plan = ControlPlan::compute(
            self.data(),
            cfg,
            self.spec.expected_slowdown(),
        );
        *guard = Some((key, plan.clone()));
        plan
    }

    /// Build the policy for one run: open-loop policies come straight
    /// from the spec; the closed-loop controller glues the channel
    /// estimator (conditioned on the channel axis, or EMA-primed at the
    /// scenario slowdown prior) to the remaining-budget re-planner.
    ///
    /// The GE belief filter models ONE link; a heterogeneous multi-lane
    /// uplink's aggregate has no single Gilbert–Elliott chain, so
    /// `est=ge` on hetero traffic falls back to the model-free EMA
    /// tracker (primed at the lane-aggregate prior) instead of silently
    /// conditioning on the channel-axis chain — the two estimator specs
    /// are bit-identical there (asserted in
    /// `rust/tests/scenario_parity.rs`).
    fn make_policy(&self, cfg: &DesConfig, n: usize) -> ScenarioPolicy {
        match self.spec.policy {
            PolicySpec::Control { est, replan_every } => {
                let plan = self.control_plan(cfg);
                let hetero =
                    matches!(self.spec.traffic, TrafficSpec::Hetero(_));
                let estimator = match est {
                    EstimatorSpec::Ge if !hetero => ControlEstimator::Ge(
                        GeBeliefEstimator::new(self.spec.channel.ge_params()),
                    ),
                    _ => ControlEstimator::Ema(EmaRateEstimator::new(
                        plan.slowdown0,
                        CONTROL_EMA_WEIGHT,
                    )),
                };
                ScenarioPolicy::Control(ControlPolicy::new(
                    estimator,
                    Replanner::new(plan, PLAN_REL_TOL),
                    replan_every,
                ))
            }
            _ => self.spec.policy.make(cfg, n),
        }
    }

    /// The dataset the scenario actually trains on (the workload's
    /// label view over the shared covariates).
    pub fn data(&self) -> &Dataset {
        self.class_ds.as_ref().unwrap_or(self.ds)
    }

    /// One deterministic run of the scenario on the native backend.
    /// Convenience wrapper over [`run_with`](Self::run_with) with a
    /// fresh [`RunWorkspace`].
    pub fn run(&self, cfg: &DesConfig) -> Result<RunResult> {
        let mut ws = RunWorkspace::new();
        let stats = self.run_with(&mut ws, cfg)?;
        Ok(ws.into_result(stats))
    }

    /// One deterministic run against a reusable [`RunWorkspace`] — the
    /// sweep hot path. Identical semantics and bit-identical outputs to
    /// [`run`](Self::run) (asserted in `rust/tests/scenario_parity.rs`).
    /// Channel, policy and executor are built on the stack and every
    /// buffer (frame, store, weights, index scratch, event log) is
    /// recycled through `ws`, so single-device and online-arrival runs
    /// perform zero heap allocations after warm-up; the multi-device
    /// paths (shared-channel round-robin AND the heterogeneous
    /// multi-lane uplink) still make O(k) small allocations per run for
    /// the lane/channel tables (the per-lane index buffers themselves
    /// are recycled through `ws`).
    pub fn run_with(
        &self,
        ws: &mut RunWorkspace,
        cfg: &DesConfig,
    ) -> Result<RunStats> {
        let ds = self.data();
        let cfg = self.effective_cfg(cfg);
        // both executors live on the stack; only the workload's one is
        // initialized and borrowed as the dyn seam
        let mut ridge_exec;
        let mut logit_exec;
        let exec: &mut dyn BlockExecutor = match self.spec.workload {
            Workload::Ridge => {
                ridge_exec = NativeExecutor::new(
                    RidgeModel::new(ds.d, cfg.lambda, ds.n),
                    cfg.alpha,
                );
                &mut ridge_exec
            }
            Workload::Logistic => {
                logit_exec = NativeExecutor::new(
                    LogisticModel::new(ds.d, cfg.lambda, ds.n),
                    cfg.alpha,
                );
                &mut logit_exec
            }
        };
        self.dispatch_run(ws, &cfg, exec, true)
    }

    /// The per-run config the scenario actually executes: the spec's
    /// store-capacity and workload overrides applied on top of `cfg`,
    /// plus any scheduler/trainer-side fault tolerance (retry/timeout,
    /// eviction, preemption windows) carried by the channel axis's
    /// `fault=` suffix — an explicit `cfg.faults` wins over the spec's.
    /// Public so callers (and `sweep::batch::batchable`) can reason
    /// about what a run will actually do.
    pub fn effective_cfg(&self, cfg: &DesConfig) -> DesConfig {
        let faults = if cfg.faults.is_trivial() {
            std::iter::once(&self.spec.channel)
                .chain(self.lane_channels.iter())
                .filter_map(|c| c.fault_spec())
                .map(|f| f.tolerance())
                .find(|t| !t.is_trivial())
                .unwrap_or_else(|| cfg.faults.clone())
        } else {
            cfg.faults.clone()
        };
        DesConfig {
            store_capacity: self.spec.store_capacity.or(cfg.store_capacity),
            workload: self.spec.workload,
            faults,
            ..cfg.clone()
        }
    }

    /// The batched-seed engine's trace pass: the full DES with a
    /// [`TraceExecutor`], recording the flushed SGD index stream into
    /// `tape` (cleared first) without executing it or evaluating any
    /// loss. After the call `ws` holds the run's `w_init` and its final
    /// store; the returned stats carry real protocol counters but a
    /// `NAN` final loss. Bit-identical traffic/channel/policy decisions
    /// to [`run_with`](Self::run_with) — the sweep-mode trajectory does
    /// not depend on `w` (asserted in `rust/tests/batch_parity.rs`).
    pub(crate) fn run_traced(
        &self,
        ws: &mut RunWorkspace,
        cfg: &DesConfig,
        tape: &mut Vec<u32>,
    ) -> Result<RunStats> {
        let cfg = self.effective_cfg(cfg);
        let mut exec = TraceExecutor::new(tape);
        self.dispatch_run(ws, &cfg, &mut exec, false)
    }

    /// The channel/policy/traffic dispatch shared by
    /// [`run_with`](Self::run_with) and [`run_traced`](Self::run_traced).
    /// `cfg` must already be the effective config
    /// ([`effective_cfg`](Self::effective_cfg)).
    fn dispatch_run(
        &self,
        ws: &mut RunWorkspace,
        cfg: &DesConfig,
        exec: &mut dyn BlockExecutor,
        eval_losses: bool,
    ) -> Result<RunStats> {
        let ds = self.data();
        // both channel shapes live on the stack; heterogeneous traffic
        // routes blocks through per-device lanes, everything else uses
        // the single channel axis
        let mut single_chan;
        let mut multi_chan;
        let channel: &mut dyn Channel = match &self.spec.traffic {
            TrafficSpec::Hetero(_) => {
                // per-lane fault plans must be pinned to their device:
                // MultiLaneChannel routes without forwarding select_lane
                multi_chan = MultiLaneChannel::new(
                    self.lane_channels
                        .iter()
                        .enumerate()
                        .map(|(i, c)| c.make_for_lane(i))
                        .collect(),
                );
                &mut multi_chan
            }
            _ => {
                single_chan = self.spec.channel.make();
                &mut single_chan
            }
        };
        let mut policy = self.make_policy(cfg, ds.n);
        let mode = self.spec.policy.overlap();
        match &self.spec.traffic {
            TrafficSpec::Devices(1) => {
                let mut source = SingleDeviceSource::with_buf(
                    ds,
                    cfg.seed,
                    std::mem::take(&mut ws.src_buf),
                );
                let stats = run_schedule_with_opts(
                    ws,
                    ds,
                    cfg,
                    &mut source,
                    &mut policy,
                    mode,
                    channel,
                    exec,
                    eval_losses,
                );
                ws.src_buf = source.into_buf();
                stats
            }
            TrafficSpec::Devices(_) => {
                let mut source = RoundRobinSource::with_bufs(
                    &self.shards,
                    cfg.seed,
                    std::mem::take(&mut ws.lane_bufs),
                );
                let stats = run_schedule_with_opts(
                    ws,
                    ds,
                    cfg,
                    &mut source,
                    &mut policy,
                    mode,
                    channel,
                    exec,
                    eval_losses,
                );
                ws.lane_bufs = source.into_bufs();
                stats
            }
            TrafficSpec::Hetero(h) => {
                // the sharded source is bit-identical to the legacy
                // `ScheduledSource` at every shard count (asserted in
                // `rust/tests/scenario_parity.rs`), so the env knob is
                // a pure execution-strategy choice
                let mut source = ShardedSource::with_bufs(
                    &self.shards,
                    cfg.seed,
                    std::mem::take(&mut ws.lane_bufs),
                    h.sched.make(),
                    &self.lane_slowdowns,
                    shard_count(),
                );
                let stats = run_schedule_with_opts(
                    ws,
                    ds,
                    cfg,
                    &mut source,
                    &mut policy,
                    mode,
                    channel,
                    exec,
                    eval_losses,
                );
                ws.lane_bufs = source.into_bufs();
                stats
            }
            TrafficSpec::Online { rate } => {
                let mut source = OnlineArrivalSource::with_buf(
                    ds,
                    *rate,
                    cfg.seed,
                    std::mem::take(&mut ws.src_buf),
                );
                let stats = run_schedule_with_opts(
                    ws,
                    ds,
                    cfg,
                    &mut source,
                    &mut policy,
                    mode,
                    channel,
                    exec,
                    eval_losses,
                );
                ws.src_buf = source.into_buf();
                stats
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_axis() {
        assert_eq!(ChannelSpec::parse("ideal").unwrap(), ChannelSpec::Ideal);
        assert_eq!(
            ChannelSpec::parse("erasure:0.25").unwrap(),
            ChannelSpec::Erasure { p: 0.25 }
        );
        assert_eq!(
            ChannelSpec::parse("rate:2.0:0.1").unwrap(),
            ChannelSpec::Rate { rate: 2.0, p: 0.1 }
        );
        assert_eq!(
            ChannelSpec::parse("fading:0.05:0.25:0.6").unwrap(),
            ChannelSpec::Fading {
                p_gb: 0.05,
                p_bg: 0.25,
                p_good: 0.0,
                p_bad: 0.6,
                rate_good: 1.0,
                rate_bad: 1.0,
            }
        );
        assert_eq!(
            ChannelSpec::parse("fading:0.1:0.3:0.5:0.05:0.5:2").unwrap(),
            ChannelSpec::Fading {
                p_gb: 0.1,
                p_bg: 0.3,
                p_good: 0.05,
                p_bad: 0.5,
                rate_good: 2.0,
                rate_bad: 0.5,
            }
        );
        assert_eq!(Workload::parse("ridge").unwrap(), Workload::Ridge);
        assert_eq!(
            Workload::parse("logistic").unwrap(),
            Workload::Logistic
        );
        assert_eq!(
            PolicySpec::parse("fixed:437").unwrap(),
            PolicySpec::Fixed { n_c: 437 }
        );
        assert_eq!(
            PolicySpec::parse("warmup:16:2.0").unwrap(),
            PolicySpec::Warmup { start: 16, growth: 2.0, cap: 0 }
        );
        assert_eq!(
            PolicySpec::parse("sequential").unwrap(),
            PolicySpec::Sequential { n_c: 0 }
        );
        assert_eq!(
            PolicySpec::parse("control").unwrap(),
            PolicySpec::Control { est: EstimatorSpec::Ge, replan_every: 1 }
        );
        assert_eq!(
            PolicySpec::parse("control:est=ema").unwrap(),
            PolicySpec::Control { est: EstimatorSpec::Ema, replan_every: 1 }
        );
        assert_eq!(
            PolicySpec::parse("control:replan=4:est=ge").unwrap(),
            PolicySpec::Control { est: EstimatorSpec::Ge, replan_every: 4 }
        );
        assert_eq!(
            TrafficSpec::parse("4").unwrap(),
            TrafficSpec::Devices(4)
        );
        assert_eq!(
            TrafficSpec::parse("online:0.5").unwrap(),
            TrafficSpec::Online { rate: 0.5 }
        );
        assert_eq!(
            TrafficSpec::parse("devices:3").unwrap(),
            TrafficSpec::Hetero(HeteroSpec {
                k: 3,
                sched: SchedulerSpec::RoundRobin,
                skew: 0.0,
                channels: Vec::new(),
            })
        );
        assert_eq!(
            TrafficSpec::parse(
                "devices:4:sched=greedy:skew=0.5:ch=fading:0.05:0.25:0.6,\
                 erasure:0.1,ideal,rate:2:0.1"
            )
            .unwrap(),
            TrafficSpec::Hetero(HeteroSpec {
                k: 4,
                sched: SchedulerSpec::Greedy,
                skew: 0.5,
                channels: vec![
                    ChannelSpec::Fading {
                        p_gb: 0.05,
                        p_bg: 0.25,
                        p_good: 0.0,
                        p_bad: 0.6,
                        rate_good: 1.0,
                        rate_bad: 1.0,
                    },
                    ChannelSpec::Erasure { p: 0.1 },
                    ChannelSpec::Ideal,
                    ChannelSpec::Rate { rate: 2.0, p: 0.1 },
                ],
            })
        );
        assert_eq!(
            TrafficSpec::parse("devices:2:sched=pfair:ch=erasure:0.3")
                .unwrap(),
            TrafficSpec::Hetero(HeteroSpec {
                k: 2,
                sched: SchedulerSpec::PropFair,
                skew: 0.0,
                channels: vec![ChannelSpec::Erasure { p: 0.3 }],
            })
        );
        assert_eq!(
            SchedulerSpec::parse("rr").unwrap(),
            SchedulerSpec::RoundRobin
        );
    }

    #[test]
    fn hetero_traffic_labels_round_trip() {
        for s in [
            "devices:1",
            "devices:3:sched=greedy",
            "devices:4:skew=0.8",
            "devices:2:sched=pfair:skew=0.25:ch=ideal,fading:0.05:0.25:0.6",
            "devices:3:ch=erasure:0.2",
        ] {
            let spec = TrafficSpec::parse(s).unwrap();
            assert_eq!(spec.label(), s, "canonical form of '{s}'");
            let re = TrafficSpec::parse(&spec.label()).unwrap();
            assert_eq!(spec, re, "round trip of '{s}'");
        }
    }

    #[test]
    fn rejects_malformed_device_specs() {
        assert!(TrafficSpec::parse("devices:0").is_err());
        assert!(TrafficSpec::parse("devices:x").is_err());
        assert!(TrafficSpec::parse("devices:2:sched=fifo").is_err());
        assert!(TrafficSpec::parse("devices:2:skew=1.5").is_err());
        assert!(TrafficSpec::parse("devices:2:turbo=1").is_err());
        assert!(TrafficSpec::parse("devices:2:ch=").is_err());
        // 3 channels for 2 devices: neither broadcast nor exact
        assert!(
            TrafficSpec::parse("devices:2:ch=ideal,ideal,ideal").is_err()
        );
    }

    #[test]
    fn hetero_slowdown_is_the_lane_mean() {
        let spec = ScenarioSpec {
            traffic: TrafficSpec::Hetero(HeteroSpec {
                k: 2,
                sched: SchedulerSpec::Greedy,
                skew: 0.0,
                channels: vec![
                    ChannelSpec::Ideal,
                    ChannelSpec::Erasure { p: 0.5 },
                ],
            }),
            ..ScenarioSpec::paper()
        };
        // (1 + 2) / 2
        assert!((spec.expected_slowdown() - 1.5).abs() < 1e-12);
        // empty lane list inherits the channel axis on every lane
        let inherit = ScenarioSpec {
            channel: ChannelSpec::Erasure { p: 0.5 },
            traffic: TrafficSpec::Hetero(HeteroSpec {
                k: 3,
                sched: SchedulerSpec::RoundRobin,
                skew: 0.0,
                channels: Vec::new(),
            }),
            ..ScenarioSpec::paper()
        };
        assert!((inherit.expected_slowdown() - 2.0).abs() < 1e-12);
        // non-hetero traffic: the channel axis as before
        assert_eq!(ScenarioSpec::paper().expected_slowdown(), 1.0);
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(ChannelSpec::parse("laser").is_err());
        assert!(ChannelSpec::parse("erasure").is_err());
        assert!(ChannelSpec::parse("erasure:1.5").is_err());
        assert!(ChannelSpec::parse("fading").is_err());
        assert!(ChannelSpec::parse("fading:0.1:0.2").is_err());
        assert!(ChannelSpec::parse("fading:1.5:0.2:0.5").is_err());
        assert!(ChannelSpec::parse("fading:0.1:0.2:1.0").is_err());
        assert!(ChannelSpec::parse("fading:0.1:0.2:0.5:0:0").is_err());
        assert!(PolicySpec::parse("warmup:0:2.0").is_err());
        assert!(PolicySpec::parse("deadline:0").is_err());
        assert!(PolicySpec::parse("bogus").is_err());
        assert!(PolicySpec::parse("control:est=kalman").is_err());
        assert!(PolicySpec::parse("control:replan=0").is_err());
        assert!(PolicySpec::parse("control:turbo=1").is_err());
        assert!(TrafficSpec::parse("0").is_err());
        assert!(TrafficSpec::parse("online:-1").is_err());
        assert!(Workload::parse("svm").is_err());
    }

    #[test]
    fn labels_round_trip() {
        let spec =
            ScenarioSpec::parse("erasure:0.1", "warmup:8:2", "4", "ridge", 500)
                .unwrap();
        assert_eq!(spec.label(), "erasure:0.1|warmup:8:2|k4|cap500");
        let re =
            ScenarioSpec::parse("erasure:0.1", "warmup:8:2", "4", "ridge", 500)
                .unwrap();
        assert_eq!(spec, re);
    }

    #[test]
    fn fading_and_workload_labels_round_trip() {
        for s in [
            "fading:0.05:0.25:0.6",
            "fading:0.05:0.25:0.6:0.01",
            "fading:0.05:0.25:0.6:0:0.5",
            "fading:0.05:0.25:0.6:0:0.5:2",
        ] {
            let spec = ChannelSpec::parse(s).unwrap();
            let re = ChannelSpec::parse(&spec.label()).unwrap();
            assert_eq!(spec, re, "label '{}' of '{s}'", spec.label());
        }
        let spec = ScenarioSpec::parse(
            "fading:0.05:0.25:0.6",
            "fixed",
            "1",
            "logistic",
            0,
        )
        .unwrap();
        assert_eq!(spec.label(), "fading:0.05:0.25:0.6|fixed|k1|logistic");
        assert_eq!(spec.workload, Workload::Logistic);
        // the ridge default keeps historical labels unchanged
        assert_eq!(ScenarioSpec::paper().label(), "ideal|fixed|k1");
    }

    #[test]
    fn expected_slowdown_per_channel() {
        assert_eq!(ChannelSpec::Ideal.expected_slowdown(), 1.0);
        let er = ChannelSpec::Erasure { p: 0.5 }.expected_slowdown();
        assert!((er - 2.0).abs() < 1e-12);
        let rt = ChannelSpec::Rate { rate: 2.0, p: 0.0 }.expected_slowdown();
        assert!((rt - 0.5).abs() < 1e-12);
        // π_bad = 0.05/(0.05+0.25) = 1/6; slowdown =
        // 5/6·1 + 1/6·(1/(0.4·0.5)) = 5/6 + 5/6 = 5/3
        let fd = ChannelSpec::Fading {
            p_gb: 0.05,
            p_bg: 0.25,
            p_good: 0.0,
            p_bad: 0.6,
            rate_good: 1.0,
            rate_bad: 0.5,
        }
        .expected_slowdown();
        assert!((fd - 5.0 / 3.0).abs() < 1e-12, "fading slowdown {fd}");
    }

    #[test]
    fn registry_names_resolve() {
        for (name, spec) in registry() {
            let found =
                from_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(found, spec);
        }
        assert!(from_name("no-such-scenario").is_none());
    }

    #[test]
    fn control_labels_round_trip() {
        for s in ["control", "control:est=ema", "control:replan=8",
            "control:est=ema:replan=3"]
        {
            let spec = PolicySpec::parse(s).unwrap();
            assert_eq!(spec.label(), s, "canonical form of '{s}'");
            assert_eq!(PolicySpec::parse(&spec.label()).unwrap(), spec);
        }
        // option order is free on input; the label is canonical
        let spec = PolicySpec::parse("control:replan=3:est=ema").unwrap();
        assert_eq!(spec.label(), "control:est=ema:replan=3");
        // the preset is registered and closed-loop
        let preset = from_name("adaptive_fading").expect("preset registered");
        assert!(matches!(preset.policy, PolicySpec::Control { .. }));
        assert_eq!(preset.policy.overlap(), OverlapMode::Pipelined);
    }

    #[test]
    fn ge_params_match_the_channel_closed_forms() {
        // static channels: pinned-good chain whose good state carries
        // the channel's own (rate, p) — the estimator's initial
        // slowdown equals the channel's expected slowdown EXACTLY
        for spec in [
            ChannelSpec::Ideal,
            ChannelSpec::Erasure { p: 0.25 },
            ChannelSpec::Rate { rate: 0.5, p: 0.1 },
        ] {
            let ge = spec.ge_params();
            assert_eq!(ge.p_gb, 0.0, "{}", spec.label());
            assert_eq!(
                ge.good.expected_slowdown(),
                spec.expected_slowdown(),
                "{}",
                spec.label()
            );
        }
        // fading: the filter conditions on the true chain
        let fading = ChannelSpec::Fading {
            p_gb: 0.05,
            p_bg: 0.25,
            p_good: 0.0,
            p_bad: 0.6,
            rate_good: 1.0,
            rate_bad: 0.5,
        };
        let ge = fading.ge_params();
        assert_eq!(ge.p_gb, 0.05);
        assert_eq!(ge.p_bg, 0.25);
        assert_eq!(ge.bad.rate, 0.5);
        assert_eq!(ge.bad.p_loss, 0.6);
    }

    #[test]
    fn fault_suffix_parses_and_round_trips() {
        for s in [
            "ideal:fault=outage:100:25",
            "erasure:0.2:fault=drop:0:150+retry:4:2:2",
            "rate:2:0.1:fault=ackloss:0.3",
            "fading:0.05:0.25:0.6:fault=outage:50:10:120+retry:3",
        ] {
            let spec = ChannelSpec::parse(s).unwrap();
            assert!(
                matches!(spec, ChannelSpec::Faulty { .. }),
                "'{s}' should wrap"
            );
            assert_eq!(spec.label(), s, "canonical form of '{s}'");
            assert_eq!(ChannelSpec::parse(&spec.label()).unwrap(), spec);
        }
    }

    #[test]
    fn fault_off_parses_to_the_bare_channel() {
        // `fault=off` (and an empty spec) is structurally the bare
        // channel — the fault-free parity invariant starts at parse time
        assert_eq!(
            ChannelSpec::parse("ideal:fault=off").unwrap(),
            ChannelSpec::Ideal
        );
        assert_eq!(
            ChannelSpec::parse("erasure:0.1:fault=off").unwrap(),
            ChannelSpec::Erasure { p: 0.1 }
        );
        assert_eq!(
            ChannelSpec::parse("erasure:0.1:fault=").unwrap(),
            ChannelSpec::Erasure { p: 0.1 }
        );
        assert!(ChannelSpec::parse("ideal:fault=bogus:1").is_err());
    }

    #[test]
    fn with_fault_wraps_replaces_and_unwraps() {
        let base = ChannelSpec::Erasure { p: 0.1 };
        let outage = FaultSpec::parse("outage:10:5").unwrap();
        let ack = FaultSpec::parse("ackloss:0.2").unwrap();
        let off = FaultSpec::parse("off").unwrap();
        let wrapped = base.with_fault(&outage);
        assert_eq!(wrapped.label(), "erasure:0.1:fault=outage:10:5");
        // replacing does not nest
        let replaced = wrapped.with_fault(&ack);
        assert_eq!(replaced.label(), "erasure:0.1:fault=ackloss:0.2");
        // off unwraps back to the bare channel
        assert_eq!(wrapped.with_fault(&off), base);
        assert_eq!(base.with_fault(&off), base);
    }

    #[test]
    fn faulty_channels_are_fault_blind_a_priori() {
        let inner = ChannelSpec::Erasure { p: 0.5 };
        let faulty =
            ChannelSpec::parse("erasure:0.5:fault=outage:10:5").unwrap();
        assert_eq!(
            faulty.expected_slowdown(),
            inner.expected_slowdown(),
            "the Corollary-1 prior must not anticipate scripted faults"
        );
        assert_eq!(
            faulty.ge_params().good.expected_slowdown(),
            inner.ge_params().good.expected_slowdown()
        );
    }

    #[test]
    fn effective_cfg_threads_the_spec_fault_tolerance() {
        use crate::data::synth::{synth_calhousing, SynthSpec};
        let ds = synth_calhousing(&SynthSpec { n: 32, ..Default::default() });
        let cfg = DesConfig::paper(8, 2.0, 100.0, 1);
        // channel-axis retry clause lands in cfg.faults
        let spec = ScenarioSpec {
            channel: ChannelSpec::parse("ideal:fault=retry:4:2:2").unwrap(),
            ..ScenarioSpec::paper()
        };
        let eff = ScenarioRunner::new(spec, &ds).effective_cfg(&cfg);
        assert_eq!(eff.faults.timeout_mult, 4.0);
        assert_eq!(eff.faults.retry_budget, 2);
        assert_eq!(eff.faults.evict_after, 2);
        // a per-lane clause on hetero traffic lands too
        let spec = from_name("hetero3_dropout_control").unwrap();
        let eff = ScenarioRunner::new(spec, &ds).effective_cfg(&cfg);
        assert_eq!(eff.faults.timeout_mult, 4.0);
        assert_eq!(eff.faults.evict_after, 2);
        // fault-free specs keep the config's (trivial) tolerance
        let eff = ScenarioRunner::new(ScenarioSpec::paper(), &ds)
            .effective_cfg(&cfg);
        assert!(eff.faults.is_trivial());
    }
}
