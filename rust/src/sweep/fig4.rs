//! Paper Fig. 4 producer: average training loss vs normalized time for
//! several block sizes, including the bound optimum ñ_c and the
//! experimentally optimal n_c* — plus the paper's headline comparison:
//! how much final loss is lost by trusting the bound instead of running
//! the (expensive) experimental sweep (paper: ≈ 3.8 %).

use anyhow::{bail, Context, Result};

use crate::bound::corollary1::BoundParams;
use crate::bound::optimizer::optimize_block_size;
use crate::coordinator::des::DesConfig;
use crate::coordinator::scheduler::RunWorkspace;
use crate::data::Dataset;
use crate::metrics::curve::mean_curve;
use crate::metrics::writer::CsvTable;
use crate::sweep::scenario::{ScenarioRunner, ScenarioSpec};
use crate::util::pool::{default_threads, try_parallel_map_with};

use super::runner::{grid_final_losses, log_grid, McStats};

/// Configuration for the Fig. 4 experiment.
#[derive(Clone, Debug)]
pub struct Fig4Config {
    /// Overhead n_o for every run.
    pub n_o: f64,
    /// τ_p.
    pub tau_p: f64,
    /// Deadline T.
    pub t_budget: f64,
    /// α, λ, init std, base seed (paper values by default).
    pub alpha: f64,
    pub lambda: f64,
    pub init_std: f64,
    pub seed: u64,
    /// Monte-Carlo repetitions per point.
    pub seeds: usize,
    /// Reference block sizes to plot alongside ñ_c and n_c* (dotted
    /// curves in the paper).
    pub reference_n_cs: Vec<usize>,
    /// Grid resolution for the experimental-optimum search.
    pub search_points: usize,
    /// Points on the output time grid.
    pub curve_points: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl Fig4Config {
    /// Paper-setup defaults for a given overhead.
    pub fn paper(n_o: f64, t_budget: f64) -> Fig4Config {
        Fig4Config {
            n_o,
            tau_p: 1.0,
            t_budget,
            alpha: 1e-4,
            lambda: 0.05,
            init_std: 1.0,
            seed: 1,
            seeds: 10,
            reference_n_cs: vec![10, 1000, 18576],
            search_points: 24,
            curve_points: 120,
            threads: 0,
        }
    }
}

/// One plotted curve.
#[derive(Clone, Debug)]
pub struct Fig4Curve {
    pub label: String,
    pub n_c: usize,
    /// Time grid and mean loss values.
    pub grid: Vec<f64>,
    pub mean_loss: Vec<f64>,
    /// Mean final loss across seeds.
    pub final_loss: f64,
}

/// The full figure data.
#[derive(Clone, Debug)]
pub struct Fig4Output {
    pub curves: Vec<Fig4Curve>,
    /// Bound optimum.
    pub bound_n_c: usize,
    /// Experimental optimum.
    pub exp_n_c: usize,
    /// Mean final losses at both.
    pub bound_final: f64,
    pub exp_final: f64,
    /// The search grid results (n_c -> final-loss stats).
    pub search: Vec<(usize, McStats)>,
    /// Relative penalty of using ñ_c instead of n_c*
    /// (paper reports ≈ 3.8 % in final training loss).
    pub bound_penalty: f64,
}

/// Per-seed loss curves for every plotted block size in ONE flat
/// `(curve, seed)` fan-out (single pool spawn; workers reuse their
/// [`RunWorkspace`] — the curve itself is the only per-run copy).
/// Returns, per plot entry, the mean curve's (grid, values, final).
fn mean_loss_curves(
    ds: &Dataset,
    base: &DesConfig,
    n_cs: &[usize],
    seeds: usize,
    threads: usize,
    points: usize,
) -> Result<Vec<(Vec<f64>, Vec<f64>, f64)>> {
    let runner = ScenarioRunner::new(ScenarioSpec::paper(), ds);
    let jobs: Vec<(usize, u64)> = n_cs
        .iter()
        .flat_map(|&n_c| (0..seeds as u64).map(move |s| (n_c, s)))
        .collect();
    let results = try_parallel_map_with(
        &jobs,
        threads,
        RunWorkspace::new,
        |ws, &(n_c, s)| {
            let cfg = DesConfig {
                n_c,
                seed: base.seed.wrapping_add(s),
                loss_every: (base.t_budget / base.tau_p / 400.0).max(1.0)
                    as usize,
                record_blocks: false,
                ..base.clone()
            };
            runner.run_with(ws, &cfg)?;
            Ok::<_, anyhow::Error>(ws.curve().to_vec())
        },
    );
    let mut curves = Vec::with_capacity(jobs.len());
    for (r, &(n_c, s)) in results.into_iter().zip(&jobs) {
        let curve = r.with_context(|| {
            format!("DES run failed: n_c {n_c} seed offset {s}")
        })?;
        // config-boundary check: a run whose loss_every schedule yields
        // no loss records cannot be averaged into a Fig. 4 curve —
        // surface the bad config here, with the run that produced it,
        // instead of a cryptic interpolation error (or the panic this
        // replaced) deeper down
        if curve.is_empty() {
            bail!(
                "n_c {n_c} seed offset {s}: run produced no loss records \
                 (loss_every too large for t_budget {}; lower loss_every \
                 or raise the budget)",
                base.t_budget
            );
        }
        curves.push(curve);
    }
    (0..n_cs.len())
        .map(|i| {
            let (grid, mean) = mean_curve(
                &curves[i * seeds..(i + 1) * seeds],
                base.t_budget,
                points,
            )
            .with_context(|| format!("averaging curves for n_c {}", n_cs[i]))?;
            let final_loss = *mean
                .last()
                .expect("mean_curve grids have >= 2 points");
            Ok((grid, mean, final_loss))
        })
        .collect()
}

/// Produce the full Fig. 4 dataset.
pub fn fig4_data(
    ds: &Dataset,
    params: &BoundParams,
    cfg: &Fig4Config,
) -> Result<Fig4Output> {
    let threads =
        if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let base = DesConfig {
        n_c: 1, // set per curve
        n_o: cfg.n_o,
        tau_p: cfg.tau_p,
        t_budget: cfg.t_budget,
        alpha: cfg.alpha,
        lambda: cfg.lambda,
        init_std: cfg.init_std,
        seed: cfg.seed,
        loss_every: 0,
        record_blocks: false,
        store_capacity: None,
        collect_snapshots: false,
        event_capacity: 0,
        workload: crate::model::Workload::Ridge,
        faults: Default::default(),
    };

    // 1. bound optimum ñ_c (cheap, closed form)
    let bound_n_c =
        optimize_block_size(params, ds.n, cfg.t_budget, cfg.n_o, cfg.tau_p)
            .n_c;

    // 2. experimental optimum n_c*: MC sweep over a log grid
    let grid = log_grid(ds.n, cfg.search_points)?;
    let search = grid_final_losses(ds, &base, &grid, cfg.seeds, threads)?;
    let exp_n_c = search
        .iter()
        .min_by(|a, b| a.1.mean.partial_cmp(&b.1.mean).unwrap())
        .map(|&(n_c, _)| n_c)
        .ok_or_else(|| anyhow::anyhow!("empty experimental search grid"))?;

    // 3. average loss curves for ñ_c, n_c* and the references
    let mut plot: Vec<(String, usize)> = vec![
        (format!("bound ñ_c={bound_n_c}"), bound_n_c),
        (format!("experimental n_c*={exp_n_c}"), exp_n_c),
    ];
    for &nc in &cfg.reference_n_cs {
        let nc = nc.min(ds.n);
        if nc != bound_n_c && nc != exp_n_c {
            plot.push((format!("n_c={nc}"), nc));
        }
    }
    let plot_n_cs: Vec<usize> = plot.iter().map(|&(_, nc)| nc).collect();
    let per_curve = mean_loss_curves(
        ds,
        &base,
        &plot_n_cs,
        cfg.seeds,
        threads,
        cfg.curve_points,
    )?;
    let mut curves = Vec::new();
    let mut bound_final = f64::NAN;
    let mut exp_final = f64::NAN;
    for ((label, nc), (grid, mean, final_loss)) in
        plot.into_iter().zip(per_curve)
    {
        if label.starts_with("bound") {
            bound_final = final_loss;
        }
        if label.starts_with("experimental") {
            exp_final = final_loss;
        }
        curves.push(Fig4Curve {
            label,
            n_c: nc,
            grid,
            mean_loss: mean,
            final_loss,
        });
    }
    let bound_penalty = (bound_final - exp_final) / exp_final;
    Ok(Fig4Output {
        curves,
        bound_n_c,
        exp_n_c,
        bound_final,
        exp_final,
        search,
        bound_penalty,
    })
}

impl Fig4Output {
    /// Long-form CSV: label, n_c, time, mean loss.
    pub fn curve_table(&self) -> CsvTable {
        let mut t = CsvTable::new(&["label", "n_c", "time", "mean_loss"]);
        for c in &self.curves {
            for (i, &time) in c.grid.iter().enumerate() {
                t.push_raw(vec![
                    c.label.clone(),
                    c.n_c.to_string(),
                    format!("{time}"),
                    format!("{}", c.mean_loss[i]),
                ]);
            }
        }
        t
    }

    /// The experimental-search CSV: n_c, mean final loss, std.
    pub fn search_table(&self) -> CsvTable {
        let mut t = CsvTable::new(&["n_c", "final_loss_mean", "final_loss_std"]);
        for (nc, s) in &self.search {
            t.push_nums(&[*nc as f64, s.mean, s.std]);
        }
        t
    }

    /// Render summary rows (bench/CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("Fig. 4 — average training loss vs time\n");
        for c in &self.curves {
            out.push_str(&format!(
                "  {:<28} final loss = {:.6}\n",
                c.label, c.final_loss
            ));
        }
        out.push_str(&format!(
            "  bound-vs-experimental penalty: {:+.2}% (paper: ≈ +3.8%)\n",
            100.0 * self.bound_penalty
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    #[test]
    fn small_scale_fig4_pipeline_works() {
        let ds = synth_calhousing(&SynthSpec { n: 600, ..Default::default() });
        let params = BoundParams {
            alpha: 1e-3,
            ..BoundParams::paper_fig3(3.0)
        };
        let cfg = Fig4Config {
            alpha: 1e-3,
            seeds: 3,
            search_points: 6,
            curve_points: 30,
            reference_n_cs: vec![600],
            ..Fig4Config::paper(10.0, 900.0)
        };
        let out = fig4_data(&ds, &params, &cfg).unwrap();
        assert!(out.curves.len() >= 2);
        for c in &out.curves {
            assert_eq!(c.grid.len(), 30);
            // loss must broadly decrease
            assert!(
                c.mean_loss.last().unwrap() < c.mean_loss.first().unwrap()
            );
        }
        assert!(out.bound_penalty.is_finite());
        assert!(out.exp_final <= out.bound_final + 1e-9);
        assert!(!out.search_table().is_empty());
        assert!(out.curve_table().len() >= 60);
    }

    #[test]
    fn budget_with_no_loss_records_errors_instead_of_panicking() {
        // t_budget smaller than one block's transmission time ⇒ zero
        // SGD updates ⇒ empty loss curves. This used to assert-panic
        // inside `interp`; it must surface as a config error naming the
        // knobs to fix.
        let ds = synth_calhousing(&SynthSpec { n: 200, ..Default::default() });
        let params =
            BoundParams { alpha: 1e-3, ..BoundParams::paper_fig3(3.0) };
        let cfg = Fig4Config {
            alpha: 1e-3,
            seeds: 2,
            search_points: 4,
            curve_points: 10,
            reference_n_cs: vec![],
            ..Fig4Config::paper(10.0, 0.5)
        };
        let err = fig4_data(&ds, &params, &cfg).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("no loss records"), "{text}");
    }
}
