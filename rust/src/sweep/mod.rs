//! Monte-Carlo sweep harness and figure-data producers.
//!
//! * [`scenario`] — declarative (channel × policy × traffic) scenario
//!   specs with a named registry, all runnable through one scheduler
//! * [`runner`] — parallel seed×parameter sweeps: the paper scenario
//!   fast path plus scenario-generic estimators and grid crossings
//! * [`batch`]  — the batched-seed engine: groups of seeds of one
//!   scenario point traced once each, then replayed lane-batched
//!   through SoA SGD kernels (`EDGEPIPE_LANES`), bit-identical to the
//!   scalar path per seed
//! * [`stream`] — the streaming sweep pipeline: gen → run → metrics →
//!   aggregate over bounded channels, JSONL journaling and resume,
//!   constant memory in the grid size, bit-identical to [`runner`]
//! * [`serve`]  — `edgepipe serve`: a line-delimited JSON scenario
//!   service reusing the warm runner/workspace machinery as a cache
//! * [`control`] — the closed-loop comparison sweep: fixed `ñ_c` vs
//!   open-loop warmup vs channel-adaptive control across fading
//!   severities, with deadline-outage rates
//! * [`fig3`]   — paper Fig. 3: Corollary-1 bound vs `n_c` per overhead
//! * [`fig4`]   — paper Fig. 4: average training-loss curves vs time for
//!   selected block sizes, the bound optimum ñ_c and the experimental
//!   optimum n_c*

pub mod batch;
pub mod control;
pub mod fig3;
pub mod fig4;
pub mod runner;
pub mod scenario;
pub mod serve;
pub mod stream;

pub use batch::{
    batch_lanes, batchable, group_jobs, group_jobs_iter, run_group,
    BatchWorkspace, GroupJob, LaneOutcome,
};
pub use control::{control_comparison, fading_severities, ControlCompareRow};
pub use fig3::{fig3_data, Fig3Output};
pub use fig4::{fig4_data, Fig4Config, Fig4Output};
pub use runner::{
    grid_final_losses, grid_final_losses_lanes, mc_final_loss,
    mc_final_loss_lanes, mc_scenario_loss, mc_scenario_loss_lanes,
    scenario_grid, scenario_grid_lanes, McStats,
};
pub use scenario::{
    from_name, registry, ChannelSpec, EstimatorSpec, HeteroSpec,
    PolicySpec, ScenarioRunner, ScenarioSpec, SchedulerSpec, TrafficSpec,
};
pub use serve::{
    serve_connection, serve_listener, serve_tcp, ServeReply, ServeState,
};
pub use stream::{
    compact_journal, stream_grid_with, stream_scenario_grid, StreamError,
    StreamOptions, StreamOutcome,
};
