//! Monte-Carlo sweep harness and figure-data producers.
//!
//! * [`scenario`] — declarative (channel × policy × traffic) scenario
//!   specs with a named registry, all runnable through one scheduler
//! * [`runner`] — parallel seed×parameter sweeps: the paper scenario
//!   fast path plus scenario-generic estimators and grid crossings
//! * [`control`] — the closed-loop comparison sweep: fixed `ñ_c` vs
//!   open-loop warmup vs channel-adaptive control across fading
//!   severities, with deadline-outage rates
//! * [`fig3`]   — paper Fig. 3: Corollary-1 bound vs `n_c` per overhead
//! * [`fig4`]   — paper Fig. 4: average training-loss curves vs time for
//!   selected block sizes, the bound optimum ñ_c and the experimental
//!   optimum n_c*

pub mod control;
pub mod fig3;
pub mod fig4;
pub mod runner;
pub mod scenario;

pub use control::{control_comparison, fading_severities, ControlCompareRow};
pub use fig3::{fig3_data, Fig3Output};
pub use fig4::{fig4_data, Fig4Config, Fig4Output};
pub use runner::{
    grid_final_losses, mc_final_loss, mc_scenario_loss, scenario_grid,
    McStats,
};
pub use scenario::{
    from_name, registry, ChannelSpec, EstimatorSpec, HeteroSpec,
    PolicySpec, ScenarioRunner, ScenarioSpec, SchedulerSpec, TrafficSpec,
};
