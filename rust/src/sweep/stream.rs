//! Streaming sweep pipeline: scenario-gen → run → metrics → aggregate
//! over bounded channels, in constant memory.
//!
//! The in-memory estimators ([`scenario_grid`] and friends) materialize
//! the full `points × seeds` loss vector before aggregating — fine for
//! figure-sized grids, fatal for the "10M-run sweep" workloads the
//! traffic axis targets. This module re-plumbs the SAME batched
//! seed-group fan-out as a four-stage pipeline over bounded `mpsc`
//! channels:
//!
//! ```text
//! gen ──(idx, GroupJob)──▶ run workers ──Row──▶ metrics ──Row──▶ aggregate
//!  lazy [`group_jobs_iter`]  BatchWorkspace      JSONL journal    per-point
//!  enumeration               per worker          (flushed/line)   Welford
//! ```
//!
//! Only O(workers + queue) rows are in flight at any moment; the
//! aggregator folds each completed group into a per-point [`Welford`]
//! accumulator in **job-index order** (a small reorder buffer absorbs
//! worker races), so the final `(label, McStats)` rows are bit-identical
//! to a fresh in-memory [`scenario_grid`] run over the same spec list.
//!
//! # The JSONL journal
//!
//! With a journal path set, every *executed* group appends one line:
//!
//! ```text
//! {"v":1,"kind":"header","labels":[...],"seeds":6,"lanes":4,"fingerprint":"..."}
//! {"v":1,"i":0,"point":0,"label":"ideal|fixed|k1","seed0":0,"len":4,"losses":[...]}
//! {"v":1,"i":1,"point":0,"label":"ideal|fixed|k1","seed0":4,"len":2,"error":"..."}
//! ```
//!
//! Lines are flushed individually, so a killed sweep leaves at most one
//! truncated trailing line. Loss values round-trip **exactly**: finite
//! numbers use Rust's shortest-exact `f64` formatting, and the
//! JSON-unrepresentable specials (NaN, ±inf, -0.0) are encoded as
//! strings that `str::parse::<f64>` restores bit-for-bit.
//!
//! `--resume <file>` replays the journal: completed `(point, seed0)`
//! groups are *reused* (their losses feed the aggregator without
//! re-running), error rows and the truncated tail re-run, and the final
//! aggregates are bit-identical to an uninterrupted run. The header
//! row pins `labels × seeds × lanes × config-fingerprint`; resuming
//! against a journal from a different sweep is an error, not a silent
//! wrong answer. Resume also rewrites the journal through
//! [`compact_journal`] — one header plus the latest row per
//! `(point, seed0)` — so error-heavy restart cycles don't accrete an
//! unbounded dead prefix of stale error rows and duplicate headers.
//!
//! # Error path
//!
//! A failed (or panicking) group run becomes an error *row* — the
//! journal stays valid, sibling groups complete, and the outcome lists
//! the failures per `(point, seed0)`. No panic ever reaches the pool;
//! `rust/tests/stream_parity.rs` asserts all of this.
//!
//! [`scenario_grid`]: crate::sweep::runner::scenario_grid
//! [`Welford`]: crate::util::stats::Welford

use std::collections::{BTreeMap, HashMap};
use std::io::BufRead;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::coordinator::des::DesConfig;
use crate::data::Dataset;
use crate::linalg::batch::{snap_lanes, MAX_LANES};
use crate::metrics::writer::JsonlWriter;
use crate::sweep::batch::{
    batch_lanes, group_jobs_iter, run_group, BatchWorkspace, GroupJob,
};
use crate::sweep::runner::{sweep_cfg, McStats};
use crate::sweep::scenario::{ScenarioRunner, ScenarioSpec};
use crate::util::json::{self, num, obj, s, Value};
use crate::util::pool::default_threads;
use crate::util::stats::Welford;
use crate::util::telemetry::Telemetry;

/// Journal format version this build writes and accepts.
const JOURNAL_VERSION: f64 = 1.0;

/// Knobs for a streamed sweep.
#[derive(Clone, Debug)]
pub struct StreamOptions {
    /// Monte-Carlo repetitions per point (must be >= 1).
    pub seeds: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Seed-group lane count (0 = `EDGEPIPE_LANES` default; otherwise
    /// snapped to a supported width like the in-memory path).
    pub lanes: usize,
    /// Bounded-channel capacity between stages (0 = auto:
    /// `max(4, 2 × threads)`).
    pub queue: usize,
    /// Append executed groups to this JSONL journal.
    pub journal: Option<PathBuf>,
    /// Replay this journal first, reusing its completed groups. When
    /// `journal` is unset, new groups are appended to the same file.
    pub resume: Option<PathBuf>,
    /// Config fingerprint pinned in the journal header (empty = filled
    /// in by [`stream_scenario_grid`] from the base `DesConfig`).
    pub fingerprint: String,
    /// Print a rate-limited progress ticker (groups done, groups/sec,
    /// per-stage queue depth, journal lag, error rows) to stderr.
    /// Display-only: stdout, journal bytes and losses are untouched.
    /// Implies an internal telemetry sink when none is attached.
    pub progress: bool,
    /// Telemetry sink for pipeline counters/gauges (detached = no-op;
    /// see `util::telemetry` for the write-only contract).
    pub telemetry: Telemetry,
}

impl Default for StreamOptions {
    fn default() -> StreamOptions {
        StreamOptions {
            seeds: 10,
            threads: 0,
            lanes: 0,
            queue: 0,
            journal: None,
            resume: None,
            fingerprint: String::new(),
            progress: false,
            telemetry: Telemetry::off(),
        }
    }
}

/// One failed group in a streamed sweep.
#[derive(Clone, Debug)]
pub struct StreamError {
    pub point: usize,
    pub label: String,
    pub seed0: u64,
    pub message: String,
}

/// Result of a streamed sweep.
#[derive(Clone, Debug)]
pub struct StreamOutcome {
    /// `(label, stats)` rows in spec order — bit-identical to the
    /// in-memory [`scenario_grid`](crate::sweep::runner::scenario_grid)
    /// when every group succeeds (errored groups simply drop their
    /// seeds from that point's accumulator, lowering its `n`).
    pub rows: Vec<(String, McStats)>,
    /// Failed groups, in job order.
    pub errors: Vec<StreamError>,
    /// Groups actually executed this run.
    pub groups_run: usize,
    /// Groups reused from the resume journal.
    pub groups_reused: usize,
}

/// A completed group traveling run → metrics → aggregate.
struct Row {
    index: usize,
    point: usize,
    seed0: u64,
    len: usize,
    reused: bool,
    result: Result<Vec<f64>, String>,
}

/// Encode one loss for the journal so it round-trips bit-exactly.
/// Finite values keep Rust's shortest-exact `Display` form (via
/// [`Value::Num`]); NaN, ±inf and -0.0 — which plain JSON numbers
/// cannot carry — become strings `str::parse::<f64>` restores exactly.
pub(crate) fn loss_value(x: f64) -> Value {
    if x.is_finite() && !(x == 0.0 && x.is_sign_negative()) {
        Value::Num(x)
    } else {
        Value::Str(format!("{x}"))
    }
}

/// Decode a journal loss written by [`loss_value`].
pub(crate) fn value_loss(v: &Value) -> Result<f64> {
    match v {
        Value::Num(n) => Ok(*n),
        Value::Str(text) => text
            .parse::<f64>()
            .with_context(|| format!("bad loss value '{text}'")),
        other => bail!("bad loss value {other:?}"),
    }
}

/// The config facts a journal is only valid for: everything that
/// changes per-seed losses besides the spec labels themselves.
pub fn base_fingerprint(base: &DesConfig) -> String {
    format!(
        "seed={};n_c={};n_o={};tau_p={};t={};alpha={};lambda={};init={};\
         workload={:?}",
        base.seed,
        base.n_c,
        base.n_o,
        base.tau_p,
        base.t_budget,
        base.alpha,
        base.lambda,
        base.init_std,
        base.workload,
    )
}

fn header_json(
    labels: &[String],
    seeds: usize,
    lanes: usize,
    fingerprint: &str,
) -> String {
    obj(vec![
        ("v", num(JOURNAL_VERSION)),
        ("kind", s("header")),
        ("labels", Value::Arr(labels.iter().map(|l| s(l)).collect())),
        ("seeds", num(seeds as f64)),
        ("lanes", num(lanes as f64)),
        ("fingerprint", s(fingerprint)),
    ])
    .to_json()
}

fn row_json(row: &Row, labels: &[String]) -> String {
    let mut pairs = vec![
        ("v", num(JOURNAL_VERSION)),
        ("i", num(row.index as f64)),
        ("point", num(row.point as f64)),
        ("label", s(&labels[row.point])),
        ("seed0", num(row.seed0 as f64)),
        ("len", num(row.len as f64)),
    ];
    match &row.result {
        Ok(losses) => pairs.push((
            "losses",
            Value::Arr(losses.iter().map(|&l| loss_value(l)).collect()),
        )),
        Err(message) => pairs.push(("error", s(message))),
    }
    obj(pairs).to_json()
}

/// Pull the next item off a `Mutex`-shared channel, recovering from a
/// poisoned lock. The guarded `Receiver` carries no invariant a
/// panicking holder could have broken halfway (mpsc channels are
/// themselves panic-safe), so a sibling worker that died between
/// `lock()` and consuming its `recv()` result — the only window outside
/// the per-row `catch_unwind` — must not take the whole pool down with
/// it: `unwrap()` here would convert one poisoned guard into `threads`
/// secondary panics and a hung pipeline. `None` means the channel is
/// closed (gen stage done and drained).
fn recv_shared<T>(rx: &Mutex<Receiver<T>>) -> Option<T> {
    rx.lock().unwrap_or_else(|e| e.into_inner()).recv().ok()
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|m| m.to_string()))
        .map(|m| format!("run panicked: {m}"))
        .unwrap_or_else(|| "run panicked (non-string payload)".to_string())
}

/// Replay a journal, returning completed `(point, seed0) → losses`
/// groups. Lenient per line — unparseable lines (e.g. the truncated
/// tail of a killed run), error rows, and rows that don't fit the
/// current grid are skipped and simply re-run — but strict about
/// headers: every header row must match the current sweep exactly, and
/// at least one must be present.
fn read_journal(
    path: &Path,
    labels: &[String],
    seeds: usize,
    lanes: usize,
    fingerprint: &str,
) -> Result<HashMap<(usize, u64), Vec<f64>>> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening resume journal {}", path.display()))?;
    let mut done = HashMap::new();
    let mut saw_header = false;
    for line in std::io::BufReader::new(file).lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = json::parse(line) else {
            continue; // truncated tail of a killed run
        };
        if v.opt("kind").and_then(|k| k.as_str().ok()) == Some("header") {
            verify_header(&v, labels, seeds, lanes, fingerprint)
                .with_context(|| {
                    format!("journal {} is for a different sweep", path.display())
                })?;
            saw_header = true;
            continue;
        }
        if v.opt("error").is_some() {
            continue; // failed group: re-run it
        }
        let Some(row) = parse_data_row(&v, labels, seeds, lanes) else {
            continue;
        };
        done.insert((row.0, row.1), row.2);
    }
    if !saw_header {
        bail!(
            "{} is not a sweep journal (no header row survived)",
            path.display()
        );
    }
    Ok(done)
}

fn verify_header(
    v: &Value,
    labels: &[String],
    seeds: usize,
    lanes: usize,
    fingerprint: &str,
) -> Result<()> {
    let jl = v.get("labels")?.as_arr()?;
    if jl.len() != labels.len()
        || jl
            .iter()
            .zip(labels)
            .any(|(a, b)| a.as_str().map(|a| a != b).unwrap_or(true))
    {
        bail!("scenario labels differ");
    }
    let js = v.get("seeds")?.as_usize()?;
    if js != seeds {
        bail!("seed count differs (journal {js}, requested {seeds})");
    }
    let jw = v.get("lanes")?.as_usize()?;
    if jw != lanes {
        bail!(
            "lane width differs (journal {jw}, requested {lanes}) — group \
             boundaries would not line up"
        );
    }
    let jf = v.get("fingerprint")?.as_str()?;
    if jf != fingerprint {
        bail!(
            "config fingerprint differs\n  journal:   {jf}\n  requested: \
             {fingerprint}"
        );
    }
    Ok(())
}

/// `(point, seed0)` of any journal row — data OR error — that belongs
/// to the current grid; `None` for foreign and garbage rows. Shared by
/// the resume reader (via [`parse_data_row`]) and [`compact_journal`],
/// which must group error rows by the same key so a later success
/// supersedes its own stale failures and nothing else's.
fn row_key(
    v: &Value,
    labels: &[String],
    seeds: usize,
    lanes: usize,
) -> Option<(usize, u64)> {
    let point = v.opt("point")?.as_usize().ok()?;
    let label = v.opt("label")?.as_str().ok()?;
    let seed0 = v.opt("seed0")?.as_usize().ok()?;
    let len = v.opt("len")?.as_usize().ok()?;
    if point >= labels.len() || labels[point] != label {
        return None;
    }
    // groups start at lane-width boundaries; anything else is foreign
    let expected = lanes.min(seeds.checked_sub(seed0)?);
    if seed0 % lanes != 0 || len != expected || len == 0 {
        return None;
    }
    Some((point, seed0 as u64))
}

/// Extract `(point, seed0, losses)` from a data row if it belongs to
/// the current grid; `None` skips (and re-runs) the row.
fn parse_data_row(
    v: &Value,
    labels: &[String],
    seeds: usize,
    lanes: usize,
) -> Option<(usize, u64, Vec<f64>)> {
    let (point, seed0) = row_key(v, labels, seeds, lanes)?;
    let len = v.opt("len")?.as_usize().ok()?;
    let losses = v.opt("losses")?.as_arr().ok()?;
    if losses.len() != len {
        return None;
    }
    let losses: Option<Vec<f64>> =
        losses.iter().map(|l| value_loss(l).ok()).collect();
    Some((point, seed0, losses?))
}

/// Rewrite a journal keeping one header plus only the LATEST row per
/// `(point, seed0)` group. Error-heavy resume cycles grow a journal
/// without bound: each resume appends another header line and a fresh
/// row for every re-run group while the stale error rows stay behind,
/// so a sweep limping through flaky groups re-parses an ever-longer
/// dead prefix on every restart. Compaction is pure bookkeeping — the
/// surviving data lines are byte-identical to what the pipeline wrote
/// (latest wins, matching [`read_journal`]'s insert-overwrite order),
/// so aggregates after a compacted resume are bit-identical to an
/// uncompacted one (`rust/tests/stream_parity.rs` pins this).
///
/// Headers are verified with the same strictness as the resume path;
/// garbage lines and the truncated tail of a killed run are dropped.
/// The rewrite goes through a `.tmp` sibling + atomic rename, so a
/// crash mid-compaction leaves the original journal untouched.
pub fn compact_journal(
    path: &Path,
    labels: &[String],
    seeds: usize,
    lanes: usize,
    fingerprint: &str,
) -> Result<()> {
    let file = std::fs::File::open(path).with_context(|| {
        format!("opening journal {} for compaction", path.display())
    })?;
    let mut latest: BTreeMap<(usize, u64), String> = BTreeMap::new();
    let mut saw_header = false;
    for line in std::io::BufReader::new(file).lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = json::parse(line) else {
            continue; // truncated tail of a killed run
        };
        if v.opt("kind").and_then(|k| k.as_str().ok()) == Some("header") {
            verify_header(&v, labels, seeds, lanes, fingerprint)
                .with_context(|| {
                    format!("journal {} is for a different sweep", path.display())
                })?;
            saw_header = true;
            continue;
        }
        let Some(key) = row_key(&v, labels, seeds, lanes) else {
            continue;
        };
        latest.insert(key, line.to_string());
    }
    if !saw_header {
        bail!(
            "{} is not a sweep journal (no header row survived)",
            path.display()
        );
    }
    // BTreeMap order == job order (group_jobs_iter is point-major,
    // seed0-minor), so the compacted journal reads like a clean run.
    let mut out = header_json(labels, seeds, lanes, fingerprint);
    out.push('\n');
    for row in latest.values() {
        out.push_str(row);
        out.push('\n');
    }
    let tmp = path.with_extension("compact.tmp");
    std::fs::write(&tmp, out)
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| {
        format!("replacing {} with its compaction", path.display())
    })
}

/// Rate-limited stderr progress line for [`StreamOptions::progress`],
/// driven by the stage-4 aggregator. Display-only: it reads the wall
/// clock and the telemetry sink but feeds neither back into the
/// pipeline — stdout, journal bytes and losses are untouched, so the
/// bit-identity contract is unaffected.
struct Ticker {
    total: usize,
    /// `None` = progress off (every call is one branch).
    start: Option<std::time::Instant>,
    last_print: Option<std::time::Instant>,
}

impl Ticker {
    const MIN_GAP: std::time::Duration = std::time::Duration::from_millis(500);

    fn new(on: bool, total: usize) -> Ticker {
        Ticker {
            total,
            start: on.then(std::time::Instant::now),
            last_print: None,
        }
    }

    fn tick(&mut self, tel: &Telemetry, done: usize) {
        let Some(start) = self.start else { return };
        let now = std::time::Instant::now();
        if let Some(last) = self.last_print {
            if now.duration_since(last) < Self::MIN_GAP {
                return;
            }
        }
        self.last_print = Some(now);
        self.print(tel, done, now.duration_since(start));
    }

    /// Unconditional final line (so short runs report at least once).
    fn finish(&mut self, tel: &Telemetry, done: usize) {
        if let Some(start) = self.start {
            self.print(tel, done, start.elapsed());
        }
    }

    fn print(
        &self,
        tel: &Telemetry,
        done: usize,
        elapsed: std::time::Duration,
    ) {
        let mut reused = 0u64;
        let mut errors = 0u64;
        let mut lag = 0u64;
        let (mut jq, mut rq, mut aq) = (0i64, 0i64, 0i64);
        tel.with(|m| {
            reused = m.stream.groups_reused.get();
            errors = m.stream.error_rows.get();
            lag = m.stream.journal_lag();
            jq = m.stream.job_queue.get();
            rq = m.stream.row_queue.get();
            aq = m.stream.agg_queue.get();
        });
        let rate = done as f64 / elapsed.as_secs_f64().max(1e-9);
        eprintln!(
            "stream: {done}/{} groups ({reused} reused, {errors} errors) \
             {rate:.1} groups/s  queues gen→run={jq} run→metrics={rq} \
             metrics→agg={aq}  journal_lag={lag}",
            self.total,
        );
    }
}

/// Run the four-stage streaming pipeline over an arbitrary group-run
/// stage. This seam is what `stream_parity.rs` injects failures and
/// panics through; production sweeps go through
/// [`stream_scenario_grid`], which plugs in the batched-seed engine.
///
/// `run` receives each [`GroupJob`] with a per-worker
/// [`BatchWorkspace`] and returns the group's per-lane final losses
/// (`[..job.len]` is read). It must be pure with respect to the
/// workspace, exactly like the in-memory pool contract.
pub fn stream_grid_with<F>(
    labels: &[String],
    opts: &StreamOptions,
    run: F,
) -> Result<StreamOutcome>
where
    F: Fn(&mut BatchWorkspace, &GroupJob) -> Result<[f64; MAX_LANES]> + Sync,
{
    if labels.is_empty() {
        bail!("streaming sweep needs at least one scenario");
    }
    if opts.seeds == 0 {
        bail!("streaming sweep needs seeds >= 1");
    }
    let points = labels.len();
    let seeds = opts.seeds;
    let lanes = if opts.lanes == 0 {
        batch_lanes()
    } else {
        snap_lanes(opts.lanes)
    };
    let threads =
        if opts.threads == 0 { default_threads() } else { opts.threads };
    let threads = threads.max(1);
    let queue = if opts.queue == 0 { (2 * threads).max(4) } else { opts.queue };
    let groups_per_point = seeds.div_ceil(lanes);
    let total = points * groups_per_point;

    let done = match &opts.resume {
        Some(path) => {
            let done =
                read_journal(path, labels, seeds, lanes, &opts.fingerprint)?;
            // error-heavy resume cycles otherwise accrete stale rows
            // and duplicate headers forever; reads what we just read,
            // so the reusable set is unchanged
            compact_journal(path, labels, seeds, lanes, &opts.fingerprint)?;
            done
        }
        None => HashMap::new(),
    };
    let journal_path = opts.journal.as_ref().or(opts.resume.as_ref());
    let mut journal = match journal_path {
        Some(path) => {
            let mut w = JsonlWriter::append(path)?;
            w.write_line(&header_json(labels, seeds, lanes, &opts.fingerprint))?;
            Some(w)
        }
        None => None,
    };

    let (job_tx, job_rx) = sync_channel::<(usize, GroupJob)>(queue);
    let (row_tx, row_rx) = sync_channel::<Row>(queue);
    let (agg_tx, agg_rx) = sync_channel::<Row>(queue);
    let job_rx = Mutex::new(job_rx);

    // The ticker reads queue depths and journal lag from a sink, so
    // progress without an external sink attaches a private one. All
    // instrumentation below is write-only observation (no RNG, no
    // control flow — see util::telemetry); `telemetry_parity.rs` pins
    // journal bytes and losses bit-identical attached vs detached.
    let tel = if opts.progress && !opts.telemetry.is_attached() {
        Telemetry::attached()
    } else {
        opts.telemetry.clone()
    };

    let mut welfords: Vec<Welford> = vec![Welford::new(); points];
    let mut errors: Vec<StreamError> = Vec::new();
    let mut groups_run = 0usize;
    let mut groups_reused = 0usize;

    std::thread::scope(|scope| -> Result<()> {
        // --- stage 1: scenario gen (lazy; never materializes the grid)
        let gen_tel = tel.clone();
        scope.spawn(move || {
            for item in group_jobs_iter(points, seeds, lanes).enumerate() {
                if job_tx.send(item).is_err() {
                    break; // downstream shut down (error path)
                }
                gen_tel.with(|m| m.stream.job_queue.add(1));
            }
        });

        // --- stage 2: run workers, one BatchWorkspace each
        let job_rx = &job_rx;
        let done = &done;
        let run = &run;
        for _ in 0..threads {
            let tx = row_tx.clone();
            let tel = tel.clone();
            scope.spawn(move || {
                let mut bw = BatchWorkspace::new();
                loop {
                    // recv_shared, not lock().unwrap(): a poisoned
                    // queue mutex must idle THIS worker's siblings,
                    // not unwind them (see its doc comment)
                    let Some((index, job)) = recv_shared(job_rx) else {
                        break;
                    };
                    tel.with(|m| m.stream.job_queue.sub(1));
                    let row = match done.get(&(job.point, job.seed0)) {
                        Some(losses) => Row {
                            index,
                            point: job.point,
                            seed0: job.seed0,
                            len: job.len,
                            reused: true,
                            result: Ok(losses.clone()),
                        },
                        None => {
                            // wall clock is read only when attached and
                            // flows write-only into the histogram
                            let t0 = tel
                                .is_attached()
                                .then(std::time::Instant::now);
                            // a panic must cost one row, not the pool
                            let result = match catch_unwind(
                                AssertUnwindSafe(|| run(&mut bw, &job)),
                            ) {
                                Ok(Ok(losses)) => {
                                    Ok(losses[..job.len].to_vec())
                                }
                                Ok(Err(e)) => Err(format!("{e:#}")),
                                Err(payload) => {
                                    // workspace state is suspect now
                                    bw = BatchWorkspace::new();
                                    Err(panic_message(payload))
                                }
                            };
                            if let Some(t0) = t0 {
                                tel.with(|m| {
                                    m.stream.group_time.record(t0.elapsed())
                                });
                            }
                            Row {
                                index,
                                point: job.point,
                                seed0: job.seed0,
                                len: job.len,
                                reused: false,
                                result,
                            }
                        }
                    };
                    if tx.send(row).is_err() {
                        break;
                    }
                    tel.with(|m| m.stream.row_queue.add(1));
                }
            });
        }
        drop(row_tx); // workers hold the only remaining clones

        // --- stage 3: metrics/journal (order as completed, not sorted —
        // resume tolerates any order, and sorting would buffer rows)
        let metrics_tel = tel.clone();
        let metrics = scope.spawn(move || -> Result<()> {
            for row in row_rx {
                metrics_tel.with(|m| m.stream.row_queue.sub(1));
                if !row.reused {
                    if let Some(w) = journal.as_mut() {
                        w.write_line(&row_json(&row, labels))?;
                    }
                }
                // journaled-or-reused and forwarded; the aggregator's
                // rows_aggregated chases this (journal lag → 0 on
                // completion)
                metrics_tel.with(|m| m.stream.rows_journaled.inc());
                if agg_tx.send(row).is_err() {
                    break;
                }
                metrics_tel.with(|m| m.stream.agg_queue.add(1));
            }
            Ok(())
        });

        // --- stage 4: aggregate on the calling thread, in job order
        let mut reorder: BTreeMap<usize, Row> = BTreeMap::new();
        let mut next = 0usize;
        let mut ticker = Ticker::new(opts.progress, total);
        for row in agg_rx {
            tel.with(|m| m.stream.agg_queue.sub(1));
            reorder.insert(row.index, row);
            while let Some(row) = reorder.remove(&next) {
                match row.result {
                    Ok(losses) => {
                        // same per-point push order as McStats::of over
                        // the in-memory flat vector → bit-identical
                        let w = &mut welfords[row.point];
                        for &l in &losses {
                            w.push(l);
                        }
                    }
                    Err(message) => {
                        tel.with(|m| m.stream.error_rows.inc());
                        errors.push(StreamError {
                            point: row.point,
                            label: labels[row.point].clone(),
                            seed0: row.seed0,
                            message,
                        })
                    }
                }
                tel.with(|m| {
                    if row.reused {
                        m.stream.groups_reused.inc();
                    } else {
                        m.stream.groups_run.inc();
                    }
                    m.stream.rows_aggregated.inc();
                });
                if row.reused {
                    groups_reused += 1;
                } else {
                    groups_run += 1;
                }
                next += 1;
            }
            ticker.tick(&tel, next);
        }
        ticker.finish(&tel, next);
        metrics.join().expect("metrics stage panicked")?;
        if next != total {
            bail!("stream pipeline ended early ({next}/{total} groups)");
        }
        Ok(())
    })?;

    Ok(StreamOutcome {
        rows: labels
            .iter()
            .zip(&welfords)
            .map(|(label, w)| (label.clone(), McStats::from_welford(w)))
            .collect(),
        errors,
        groups_run,
        groups_reused,
    })
}

/// Stream a scenario grid: the constant-memory, journaled, resumable
/// counterpart of [`scenario_grid`](crate::sweep::runner::scenario_grid),
/// bit-identical to it row-for-row. Runners (and their memoized
/// `ControlPlan`s) are built once and shared across every seed group of
/// their point.
pub fn stream_scenario_grid(
    ds: &Dataset,
    base: &DesConfig,
    specs: &[ScenarioSpec],
    opts: &StreamOptions,
) -> Result<StreamOutcome> {
    let runners: Vec<ScenarioRunner> = specs
        .iter()
        .map(|spec| ScenarioRunner::new(spec.clone(), ds))
        .collect();
    let labels: Vec<String> = specs.iter().map(|spec| spec.label()).collect();
    let mut opts = opts.clone();
    if opts.fingerprint.is_empty() {
        opts.fingerprint = base_fingerprint(base);
    }
    stream_grid_with(&labels, &opts, |bw, job| {
        let outs = run_group(&runners[job.point], bw, job.len, |l| {
            sweep_cfg(base, job.seed0 + l as u64)
        })
        .with_context(|| {
            format!(
                "point {} ({}) seed group {}..{}",
                job.point,
                labels[job.point],
                job.seed0,
                job.seed0 + job.len as u64
            )
        })?;
        let mut losses = [f64::NAN; MAX_LANES];
        for l in 0..job.len {
            losses[l] = outs[l].final_loss;
        }
        Ok(losses)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn losses_round_trip_exactly_including_specials() {
        let cases = [
            1.0,
            -1.5,
            0.1 + 0.2, // shortest-repr exercise
            1.0e-300,
            -0.0,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE / 2.0, // subnormal
        ];
        for &x in &cases {
            let row = Value::Arr(vec![loss_value(x)]).to_json();
            let parsed = json::parse(&row).unwrap();
            let back = value_loss(&parsed.as_arr().unwrap()[0]).unwrap();
            assert_eq!(
                x.to_bits(),
                back.to_bits(),
                "{x} did not round-trip ({row})"
            );
        }
    }

    #[test]
    fn header_and_row_lines_parse_back() {
        let labels = vec!["a|b|c".to_string(), "d|e|f".to_string()];
        let h = header_json(&labels, 6, 4, "fp");
        let v = json::parse(&h).unwrap();
        assert!(verify_header(&v, &labels, 6, 4, "fp").is_ok());
        assert!(verify_header(&v, &labels, 7, 4, "fp").is_err());
        assert!(verify_header(&v, &labels, 6, 8, "fp").is_err());
        assert!(verify_header(&v, &labels, 6, 4, "other").is_err());
        assert!(verify_header(&v, &labels[..1].to_vec(), 6, 4, "fp").is_err());

        let ok = Row {
            index: 3,
            point: 1,
            seed0: 4,
            len: 2,
            reused: false,
            result: Ok(vec![0.25, f64::NAN]),
        };
        let v = json::parse(&row_json(&ok, &labels)).unwrap();
        let (point, seed0, losses) =
            parse_data_row(&v, &labels, 6, 4).expect("valid row");
        assert_eq!((point, seed0), (1, 4));
        assert_eq!(losses[0], 0.25);
        assert!(losses[1].is_nan());
        // rows from a foreign grid are skipped, not trusted
        assert!(parse_data_row(&v, &labels, 12, 4).is_none(), "len mismatch");
        assert!(parse_data_row(&v, &labels[..1].to_vec(), 6, 4).is_none());

        let err = Row { result: Err("boom".into()), ..ok };
        let v = json::parse(&row_json(&err, &labels)).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "boom");
        assert!(v.opt("losses").is_none());
    }

    #[test]
    fn read_journal_is_lenient_per_line_and_strict_on_headers() {
        let dir = std::env::temp_dir().join("edgepipe_stream_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("j_{}.jsonl", std::process::id()));
        let labels = vec!["x".to_string()];
        let text = format!(
            "{}\n{}\nnot json at all\n{}\n{{\"i\":9,\"poin",
            header_json(&labels, 6, 4, "fp"),
            row_json(
                &Row {
                    index: 0,
                    point: 0,
                    seed0: 0,
                    len: 4,
                    reused: false,
                    result: Ok(vec![1.0, 2.0, 3.0, 4.0]),
                },
                &labels,
            ),
            row_json(
                &Row {
                    index: 1,
                    point: 0,
                    seed0: 4,
                    len: 2,
                    reused: false,
                    result: Err("boom".into()),
                },
                &labels,
            ),
        );
        std::fs::write(&p, text).unwrap();
        let done = read_journal(&p, &labels, 6, 4, "fp").unwrap();
        // the Ok row survives; garbage, the error row and the truncated
        // tail are skipped for re-running
        assert_eq!(done.len(), 1);
        assert_eq!(done[&(0, 0)], vec![1.0, 2.0, 3.0, 4.0]);
        // wrong fingerprint → hard error, not silent reuse
        assert!(read_journal(&p, &labels, 6, 4, "other").is_err());
        // a file with no header is not a journal
        std::fs::write(&p, "garbage\n").unwrap();
        assert!(read_journal(&p, &labels, 6, 4, "fp").is_err());
        std::fs::remove_file(&p).unwrap();
    }

    /// Satellite regression for the worker-pool poison bug: a thread
    /// that panics while holding the shared `job_rx` mutex used to take
    /// every sibling down via `lock().unwrap()`. `recv_shared` must
    /// keep draining a poisoned-but-intact channel.
    #[test]
    fn recv_shared_survives_a_poisoned_queue_mutex() {
        let (tx, rx) = sync_channel::<usize>(4);
        let rx = Mutex::new(rx);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        // poison the mutex exactly like a worker dying between lock()
        // and consuming its recv() result
        std::thread::scope(|scope| {
            let poisoner = scope.spawn(|| {
                let _guard = rx.lock().unwrap();
                panic!("worker died holding the queue lock");
            });
            assert!(poisoner.join().is_err());
        });
        assert!(rx.lock().is_err(), "mutex should be poisoned");
        // siblings still drain the queue...
        assert_eq!(recv_shared(&rx), Some(1));
        assert_eq!(recv_shared(&rx), Some(2));
        // ...and still see a clean shutdown when the sender hangs up
        drop(tx);
        assert_eq!(recv_shared(&rx), None);
    }

    #[test]
    fn compact_journal_keeps_one_header_and_latest_row_per_group() {
        let dir = std::env::temp_dir().join("edgepipe_stream_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("c_{}.jsonl", std::process::id()));
        let labels = vec!["x".to_string(), "y".to_string()];
        let header = header_json(&labels, 6, 4, "fp");
        let row = |index, point, seed0, len, result| {
            row_json(&Row { index, point, seed0, len, reused: false, result }, &labels)
        };
        // two resume cycles' worth of history: duplicate headers, a
        // stale error superseded by a success, a stale success
        // superseded by a rerun, garbage, and a truncated tail
        let text = format!(
            "{header}\n{}\n{}\n{}\nnot json\n{header}\n{}\n{}\n{{\"i\":9,\"poi",
            row(0, 0, 0, 4, Err("flaky".into())),
            row(1, 0, 4, 2, Ok(vec![9.0, 9.0])),
            row(2, 1, 0, 4, Ok(vec![5.0, 6.0, 7.0, 8.0])),
            row(0, 0, 0, 4, Ok(vec![1.0, 2.0, 3.0, 4.0])),
            row(1, 0, 4, 2, Ok(vec![0.5, 0.25])),
        );
        std::fs::write(&p, text).unwrap();
        compact_journal(&p, &labels, 6, 4, "fp").unwrap();
        let compacted = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> =
            compacted.lines().filter(|l| !l.trim().is_empty()).collect();
        // exactly one header + one row per surviving (point, seed0)
        assert_eq!(lines.len(), 4, "got:\n{compacted}");
        assert_eq!(lines[0], header);
        // ...and the reusable set is the latest rows, bit-for-bit
        let done = read_journal(&p, &labels, 6, 4, "fp").unwrap();
        assert_eq!(done.len(), 3);
        assert_eq!(done[&(0, 0)], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(done[&(0, 4)], vec![0.5, 0.25]);
        assert_eq!(done[&(1, 0)], vec![5.0, 6.0, 7.0, 8.0]);
        // idempotent: compacting a compacted journal is a no-op
        compact_journal(&p, &labels, 6, 4, "fp").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), compacted);
        // wrong fingerprint refuses to rewrite anything
        assert!(compact_journal(&p, &labels, 6, 4, "other").is_err());
        assert_eq!(std::fs::read_to_string(&p).unwrap(), compacted);
        std::fs::remove_file(&p).unwrap();
    }
}
