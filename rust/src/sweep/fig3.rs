//! Paper Fig. 3 producer: the Corollary-1 bound versus block size `n_c`
//! for several overhead values `n_o`, with the bound optimum ñ_c (the
//! crosses) and the full-delivery boundary `T = B_d(n_c+n_o)` (the dots).

use anyhow::Result;

use crate::bound::corollary1::{corollary1_bound, BoundParams};
use crate::bound::optimizer::optimize_block_size;
use crate::metrics::writer::CsvTable;
use crate::protocol::{Timeline, TimelineCase};

use super::runner::log_grid;

/// One overhead's curve and markers.
#[derive(Clone, Debug)]
pub struct Fig3Curve {
    pub n_o: f64,
    /// (n_c, bound value) samples along the curve.
    pub points: Vec<(usize, f64)>,
    /// The bound minimizer ñ_c (cross marker).
    pub opt_n_c: usize,
    pub opt_value: f64,
    /// Smallest n_c delivering the full dataset in time (dot marker).
    pub boundary_n_c: Option<usize>,
    /// Which Fig. 2 case the optimum falls in.
    pub opt_case: TimelineCase,
}

/// The full figure data.
#[derive(Clone, Debug)]
pub struct Fig3Output {
    pub curves: Vec<Fig3Curve>,
    pub params: BoundParams,
    pub n: usize,
    pub t_budget: f64,
    pub tau_p: f64,
}

/// Produce Fig. 3 for the paper's setup.
pub fn fig3_data(
    params: &BoundParams,
    n: usize,
    t_budget: f64,
    tau_p: f64,
    n_os: &[f64],
    grid_points: usize,
) -> Result<Fig3Output> {
    let grid = log_grid(n, grid_points)?;
    let curves = n_os
        .iter()
        .map(|&n_o| {
            let points: Vec<(usize, f64)> = grid
                .iter()
                .map(|&nc| {
                    (
                        nc,
                        corollary1_bound(
                            params, n, t_budget, nc as f64, n_o, tau_p, false,
                        ),
                    )
                })
                .collect();
            let opt = optimize_block_size(params, n, t_budget, n_o, tau_p);
            Fig3Curve {
                n_o,
                points,
                opt_n_c: opt.n_c,
                opt_value: opt.value,
                boundary_n_c: Timeline::full_delivery_boundary(
                    n, t_budget, n_o,
                ),
                opt_case: opt.case,
            }
        })
        .collect();
    Ok(Fig3Output {
        curves,
        params: *params,
        n,
        t_budget,
        tau_p,
    })
}

impl Fig3Output {
    /// Long-form CSV: n_o, n_c, bound.
    pub fn curve_table(&self) -> CsvTable {
        let mut t = CsvTable::new(&["n_o", "n_c", "bound"]);
        for c in &self.curves {
            for &(nc, v) in &c.points {
                t.push_nums(&[c.n_o, nc as f64, v]);
            }
        }
        t
    }

    /// Marker summary CSV: n_o, opt n_c, opt bound, boundary, case.
    pub fn marker_table(&self) -> CsvTable {
        let mut t = CsvTable::new(&[
            "n_o",
            "opt_n_c",
            "opt_bound",
            "boundary_n_c",
            "opt_case",
        ]);
        for c in &self.curves {
            t.push_raw(vec![
                format!("{}", c.n_o),
                format!("{}", c.opt_n_c),
                format!("{}", c.opt_value),
                c.boundary_n_c
                    .map(|b| b.to_string())
                    .unwrap_or_else(|| "none".into()),
                format!("{:?}", c.opt_case),
            ]);
        }
        t
    }

    /// Render the figure as aligned text rows (bench/CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Fig. 3 — Corollary-1 bound vs n_c  (N={}, T={}, τ_p={}, α={}, \
             L={:.3}, c={:.3}, D={:.3})\n",
            self.n,
            self.t_budget,
            self.tau_p,
            self.params.alpha,
            self.params.big_l,
            self.params.c,
            self.params.d_diam
        ));
        for c in &self.curves {
            out.push_str(&format!(
                "  n_o={:8}: ñ_c={:6} bound(ñ_c)={:.5} boundary={:>6} \
                 case={:?}\n",
                c.n_o,
                c.opt_n_c,
                c.opt_value,
                c.boundary_n_c
                    .map(|b| b.to_string())
                    .unwrap_or_else(|| "—".into()),
                c.opt_case
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_holds() {
        let p = BoundParams::paper_fig3(3.0);
        let out = fig3_data(
            &p,
            18576,
            1.5 * 18576.0,
            1.0,
            &[1.0, 10.0, 100.0, 1000.0],
            60,
        )
        .unwrap();
        assert_eq!(out.curves.len(), 4);
        // optima increase with overhead (paper Sec. 4 discussion)
        let opts: Vec<usize> = out.curves.iter().map(|c| c.opt_n_c).collect();
        for w in opts.windows(2) {
            assert!(w[1] > w[0], "ñ_c must grow with n_o: {opts:?}");
        }
        // every curve's optimum is interior and below the bound at n_c = N
        for c in &out.curves {
            assert!(c.opt_n_c > 1 && c.opt_n_c < 18576);
            let at_n = c.points.last().unwrap().1;
            assert!(c.opt_value < at_n);
        }
        // tables well-formed
        assert_eq!(out.marker_table().len(), 4);
        assert!(out.curve_table().len() >= 4 * 50);
        assert!(out.render().contains("ñ_c"));
    }
}
