//! The batched-seed Monte-Carlo engine: L seed-lanes of one scenario
//! point through one SoA weight state.
//!
//! # Why this is possible
//!
//! In sweep mode (`loss_every == 0`, no block-boundary curve, no
//! snapshots) the DES trajectory is independent of the weights: every
//! RNG stream (init, device, channel, edge sampling, eviction) is
//! seeded from the config alone, policies decide from channel outcomes
//! and time, and loss recording — the only consumer of `w` mid-run —
//! is pure. So the engine runs each lane's full DES once with a
//! [`TraceExecutor`](crate::coordinator::executor::TraceExecutor) that
//! records the flushed SGD index stream instead of executing it (the
//! *trace pass*), then replays all lanes' tapes lane-batched through a
//! [`LaneModel`] under an active-lane mask (the *replay pass*).
//! Timelines diverge per seed — lanes simply exhaust their tapes at
//! different steps — and when fewer than `max(2, width/4)` lanes remain
//! active the survivors *drain* through the scalar
//! [`SgdEngine`](crate::sgd::SgdEngine) with the real point model.
//!
//! # Bit-exactness
//!
//! Replay against the lane's **final** store is sound because the
//! unbounded store only appends (`X̃_{b+1} = X̃_b ∪ X_b`): row `i`'s
//! bytes never change after ingest, so an index drawn mid-run reads
//! identical bytes at replay time. A bounded (reservoir) store
//! overwrites rows, so those scenarios — and any config that records
//! curves or snapshots — take the scalar path ([`batchable`]). The
//! lane kernels preserve each lane's arithmetic order exactly
//! (`linalg/batch.rs`), the drain IS the scalar engine, and the
//! per-lane final loss is recomputed with the same
//! `Workload::full_loss` call the trainer uses — so every lane's final
//! loss is **bit-identical** to the scalar engine's (0 ULP; asserted
//! in `rust/tests/batch_parity.rs`).
//!
//! # Knob
//!
//! `EDGEPIPE_LANES` picks the lane count for MC fan-outs (default 8,
//! snapped to {1, 4, 8, 16}; `0`/`1` disable batching). The `_lanes`
//! function variants take the count explicitly so parallel tests never
//! race on process-global env.

use anyhow::{bail, Context, Result};

use crate::coordinator::des::DesConfig;
use crate::coordinator::scheduler::{RunStats, RunWorkspace};
use crate::linalg::batch::MAX_LANES;
use crate::model::{LaneModel, LogisticModel, RidgeModel, Workload};
use crate::sgd::SgdEngine;
use crate::sweep::scenario::ScenarioRunner;
use crate::util::pool::try_parallel_map_with;

/// Environment knob selecting the Monte-Carlo lane count.
pub const LANES_ENV: &str = "EDGEPIPE_LANES";

/// The lane count MC fan-outs use: `EDGEPIPE_LANES` snapped to a
/// supported width ({1, 4, 8, 16}), defaulting to 8 — batching is ON by
/// default for sweeps.
pub fn batch_lanes() -> usize {
    let requested = std::env::var(LANES_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(8);
    crate::linalg::batch::snap_lanes(requested)
}

/// Whether a run config (after the spec's overrides,
/// `ScenarioRunner::effective_cfg`) is eligible for traced replay:
/// sweep mode only — curves and snapshots need the scalar engine — and
/// an append-only store (a bounded reservoir overwrites rows, which
/// would break tape replay against the final store).
///
/// DES sharding (`EDGEPIPE_SHARDS`, `coordinator::shard`) does NOT
/// enter this predicate: the sharded source is bit-identical to the
/// single-threaded one at every shard count, so a sharded trace pass
/// records exactly the tape a scalar run would replay — threaded
/// hetero runs stay batchable with no explicit fallback.
pub fn batchable(cfg: &DesConfig) -> bool {
    cfg.loss_every == 0
        && !cfg.record_blocks
        && !cfg.collect_snapshots
        && cfg.store_capacity.is_none()
}

/// Per-lane result of a batched group: what the MC estimators and the
/// bench need from [`RunStats`], without the heap outputs.
#[derive(Clone, Copy, Debug)]
pub struct LaneOutcome {
    pub final_loss: f64,
    pub updates: usize,
}

impl LaneOutcome {
    const EMPTY: LaneOutcome = LaneOutcome { final_loss: f64::NAN, updates: 0 };
}

/// One lane's recyclable state: a full DES workspace plus its index
/// tape.
#[derive(Default)]
struct LaneSlot {
    ws: RunWorkspace,
    tape: Vec<u32>,
}

/// Every reusable buffer a batched seed-group needs — the batched
/// counterpart of [`RunWorkspace`], recycled per worker thread across
/// groups exactly like scalar sweeps recycle their workspaces.
#[derive(Default)]
pub struct BatchWorkspace {
    lanes: Vec<LaneSlot>,
    model: Option<LaneModel>,
    /// Gathered lane-striped sample block for one replay step.
    x_soa: Vec<f32>,
    /// Per-group staged configs (no heap inside `DesConfig`, so refills
    /// are allocation-free once capacity exists).
    cfgs: Vec<DesConfig>,
}

impl BatchWorkspace {
    pub fn new() -> BatchWorkspace {
        BatchWorkspace::default()
    }

    fn ensure_lanes(&mut self, count: usize) {
        while self.lanes.len() < count {
            self.lanes.push(LaneSlot::default());
        }
    }
}

/// Smallest supported lane width that fits `count` lanes.
fn width_for(count: usize) -> usize {
    match count {
        0..=4 => 4,
        5..=8 => 8,
        _ => 16,
    }
}

/// Replay drains to scalar when fewer lanes than this remain active.
fn drain_threshold(width: usize) -> usize {
    (width / 4).max(2)
}

/// Run one seed-group — `count ≤ 16` runs of the SAME scenario point
/// whose configs differ only in seed — lane-batched. Falls back to
/// scalar per-lane runs when `count == 1` or the config is not
/// [`batchable`]; either way the outcomes are bit-identical to
/// `count` scalar `run_with` calls.
pub fn run_group(
    runner: &ScenarioRunner<'_>,
    bw: &mut BatchWorkspace,
    count: usize,
    mut cfg_for: impl FnMut(usize) -> DesConfig,
) -> Result<[LaneOutcome; MAX_LANES]> {
    if !(1..=MAX_LANES).contains(&count) {
        bail!("group size {count} out of range (must be 1..={MAX_LANES})");
    }
    bw.ensure_lanes(count);
    bw.cfgs.clear();
    for l in 0..count {
        bw.cfgs.push(cfg_for(l));
    }
    let mut out = [LaneOutcome::EMPTY; MAX_LANES];

    let eff0 = runner.effective_cfg(&bw.cfgs[0]);
    if count == 1 || !batchable(&eff0) {
        for l in 0..count {
            let stats = runner.run_with(&mut bw.lanes[l].ws, &bw.cfgs[l])?;
            out[l] = LaneOutcome {
                final_loss: stats.final_loss,
                updates: stats.updates,
            };
        }
        return Ok(out);
    }

    // --- trace pass: full DES per lane, recording the index stream ---
    for l in 0..count {
        let lane = &mut bw.lanes[l];
        let stats: RunStats =
            runner.run_traced(&mut lane.ws, &bw.cfgs[l], &mut lane.tape)?;
        out[l].updates = stats.updates;
        debug_assert_eq!(
            stats.updates,
            lane.tape.len(),
            "tape must hold exactly the run's updates"
        );
    }

    // --- replay pass: lockstep lane-batched SGD over the tapes ---
    let ds = runner.data();
    let d = ds.d;
    let width = width_for(count);
    let workload = eff0.workload;
    let alpha = eff0.alpha;
    let lambda = eff0.lambda;
    let mut model = bw.model.take().unwrap_or_else(|| {
        LaneModel::new(workload, d, width, lambda, ds.n)
    });
    model.reset(workload, d, width, lambda, ds.n);
    for (l, lane) in bw.lanes[..count].iter().enumerate() {
        // the trace pass leaves w_init untouched in the workspace
        model.load_column(l, &lane.ws.train.w);
    }
    bw.x_soa.clear();
    bw.x_soa.resize(d * width, 0.0);
    let mut y = [0.0f64; MAX_LANES];
    let mut active = [false; MAX_LANES];
    let drain_below = drain_threshold(width);
    let mut t = 0usize;
    loop {
        let mut n_active = 0usize;
        for l in 0..count {
            let a = t < bw.lanes[l].tape.len();
            active[l] = a;
            if a {
                n_active += 1;
            }
        }
        if n_active < drain_below {
            break;
        }
        for l in 0..width {
            if l < count && active[l] {
                let lane = &bw.lanes[l];
                let view = lane.ws.train.store.view();
                let i = lane.tape[t] as usize;
                let row = view.row(i);
                for j in 0..d {
                    bw.x_soa[j * width + l] = row[j];
                }
                y[l] = view.y[i] as f64;
            } else {
                // neutral column: preserves the lane's weights exactly
                for j in 0..d {
                    bw.x_soa[j * width + l] = 0.0;
                }
                y[l] = 0.0;
            }
        }
        model.step(&bw.x_soa, &y, &active, alpha);
        t += 1;
    }
    // write every lane's column back, then drain stragglers scalar
    for (l, lane) in bw.lanes[..count].iter_mut().enumerate() {
        model.extract_column_into(l, &mut lane.ws.train.w);
    }
    bw.model = Some(model);
    let engine = SgdEngine::new(alpha);
    let ridge = RidgeModel::new(d, lambda, ds.n);
    let logit = LogisticModel::new(d, lambda, ds.n);
    for lane in bw.lanes[..count].iter_mut() {
        if t >= lane.tape.len() {
            continue;
        }
        let rest = &lane.tape[t..];
        let train = &mut lane.ws.train;
        match workload {
            Workload::Ridge => engine.run_indices(
                &ridge,
                &mut train.w,
                train.store.view(),
                rest,
            ),
            Workload::Logistic => engine.run_indices(
                &logit,
                &mut train.w,
                train.store.view(),
                rest,
            ),
        }
    }

    // --- final losses: the same evaluation the trainer performs ---
    let reg = lambda / ds.n as f64;
    for (l, lane) in bw.lanes[..count].iter().enumerate() {
        out[l].final_loss = workload.full_loss(ds, &lane.ws.train.w, reg);
    }
    Ok(out)
}

/// One batched fan-out job: a seed-group of one runner (scenario/grid
/// point).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupJob {
    /// Index into the caller's runner table.
    pub point: usize,
    /// First seed offset of the group.
    pub seed0: u64,
    /// Lanes in this group (`1..=MAX_LANES`).
    pub len: usize,
}

/// Lazy enumeration of the lane-sized groups covering `points × seeds`,
/// point-major in seed order (see [`group_jobs_iter`]). The streaming
/// pipeline drives this iterator directly so an arbitrarily large grid
/// never materializes its job list.
#[derive(Clone, Debug)]
pub struct GroupJobs {
    points: usize,
    seeds: usize,
    lanes: usize,
    point: usize,
    s: usize,
}

impl Iterator for GroupJobs {
    type Item = GroupJob;

    fn next(&mut self) -> Option<GroupJob> {
        while self.point < self.points {
            if self.s < self.seeds {
                let len = self.lanes.min(self.seeds - self.s);
                let job =
                    GroupJob { point: self.point, seed0: self.s as u64, len };
                self.s += len;
                return Some(job);
            }
            self.point += 1;
            self.s = 0;
        }
        None
    }
}

/// Chunk `points × seeds` into lane-sized groups, point-major in seed
/// order — flattening group results in job order reproduces the scalar
/// fan-out's `(point, seed)` order exactly. `lanes` is clamped to
/// `1..=MAX_LANES`.
pub fn group_jobs_iter(points: usize, seeds: usize, lanes: usize) -> GroupJobs {
    GroupJobs {
        points,
        seeds,
        lanes: lanes.clamp(1, MAX_LANES),
        point: 0,
        s: 0,
    }
}

/// Eager form of [`group_jobs_iter`] for fan-outs that want the whole
/// job list up front (the in-memory pool path).
pub fn group_jobs(points: usize, seeds: usize, lanes: usize) -> Vec<GroupJob> {
    group_jobs_iter(points, seeds, lanes).collect()
}

/// The grouped Monte-Carlo fan-out shared by every batched estimator:
/// runs every `(point, seed)` pair of `runners × seeds` through
/// lane-batched groups and returns final losses flattened point-major
/// in seed order — element-for-element (and bit-for-bit) what the
/// scalar fan-out returns.
///
/// A failed run no longer panics the pool: every group carries its own
/// `Result`, sibling groups complete, and the first error *in job
/// order* is returned with its `(point, seed range)` attached.
pub(crate) fn grouped_losses(
    runners: &[&ScenarioRunner<'_>],
    seeds: usize,
    threads: usize,
    lanes: usize,
    cfg_for: impl Fn(usize, u64) -> DesConfig + Sync,
) -> Result<Vec<f64>> {
    let jobs = group_jobs(runners.len(), seeds, lanes);
    let groups = try_parallel_map_with(
        &jobs,
        threads,
        BatchWorkspace::new,
        |bw, job| {
            let outs = run_group(runners[job.point], bw, job.len, |l| {
                cfg_for(job.point, job.seed0 + l as u64)
            })?;
            let mut losses = [f64::NAN; MAX_LANES];
            for l in 0..job.len {
                losses[l] = outs[l].final_loss;
            }
            Ok::<_, anyhow::Error>((losses, job.len))
        },
    );
    let mut flat = Vec::with_capacity(runners.len() * seeds);
    for (group, job) in groups.into_iter().zip(&jobs) {
        let (losses, len) = group.with_context(|| {
            format!(
                "scenario run failed: point {} seed group {}..{}",
                job.point,
                job.seed0,
                job.seed0 + job.len as u64
            )
        })?;
        flat.extend_from_slice(&losses[..len]);
    }
    Ok(flat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::sweep::scenario::ScenarioSpec;

    #[test]
    fn batchable_gate() {
        let sweep = DesConfig {
            loss_every: 0,
            record_blocks: false,
            collect_snapshots: false,
            ..DesConfig::paper(40, 5.0, 400.0, 7)
        };
        assert!(batchable(&sweep));
        assert!(!batchable(&DesConfig { loss_every: 10, ..sweep.clone() }));
        assert!(!batchable(&DesConfig { record_blocks: true, ..sweep.clone() }));
        assert!(!batchable(&DesConfig {
            collect_snapshots: true,
            ..sweep.clone()
        }));
        assert!(!batchable(&DesConfig {
            store_capacity: Some(64),
            ..sweep
        }));
    }

    #[test]
    fn group_jobs_cover_every_pair_in_order() {
        let jobs = group_jobs(2, 5, 4);
        let mut pairs = Vec::new();
        for j in &jobs {
            assert!(j.len >= 1 && j.len <= 4);
            for l in 0..j.len {
                pairs.push((j.point, j.seed0 + l as u64));
            }
        }
        let want: Vec<(usize, u64)> = (0..2)
            .flat_map(|p| (0..5u64).map(move |s| (p, s)))
            .collect();
        assert_eq!(pairs, want, "point-major seed order");
        // ragged tail: 5 seeds over width 4 → groups of 4 + 1
        assert_eq!(jobs[0].len, 4);
        assert_eq!(jobs[1].len, 1);
    }

    #[test]
    fn group_jobs_iter_matches_eager_and_is_resumable() {
        for (points, seeds, lanes) in
            [(2, 5, 4), (3, 1, 8), (1, 17, 4), (4, 8, 16), (0, 5, 4), (2, 0, 4)]
        {
            let lazy: Vec<GroupJob> =
                group_jobs_iter(points, seeds, lanes).collect();
            assert_eq!(
                lazy,
                group_jobs(points, seeds, lanes),
                "points={points} seeds={seeds} lanes={lanes}"
            );
        }
        // the iterator is cheap state, not a materialized list: cloning
        // mid-walk resumes from the same position
        let mut it = group_jobs_iter(3, 5, 4);
        it.next();
        let rest_a: Vec<GroupJob> = it.clone().collect();
        let rest_b: Vec<GroupJob> = it.collect();
        assert_eq!(rest_a, rest_b);
        assert_eq!(rest_a.len(), 3 * 2 - 1);
    }

    #[test]
    fn run_group_size_errors_are_results_not_panics() {
        let ds = synth_calhousing(&SynthSpec { n: 120, ..Default::default() });
        let runner = ScenarioRunner::new(ScenarioSpec::paper(), &ds);
        let base = DesConfig::paper(24, 5.0, 400.0, 7);
        let mut bw = BatchWorkspace::new();
        for count in [0usize, MAX_LANES + 1] {
            let err = run_group(&runner, &mut bw, count, |_| base.clone())
                .expect_err("out-of-range group size must be an Err");
            assert!(
                err.to_string().contains("out of range"),
                "unexpected error: {err:#}"
            );
        }
    }

    #[test]
    fn width_and_drain_rules() {
        assert_eq!(width_for(2), 4);
        assert_eq!(width_for(4), 4);
        assert_eq!(width_for(5), 8);
        assert_eq!(width_for(8), 8);
        assert_eq!(width_for(9), 16);
        assert_eq!(width_for(16), 16);
        assert_eq!(drain_threshold(4), 2);
        assert_eq!(drain_threshold(8), 2);
        assert_eq!(drain_threshold(16), 4);
    }

    /// End-to-end group parity on a small paper scenario: the batched
    /// group's outcomes must be bit-identical to scalar runs, including
    /// a ragged group and a reused workspace.
    #[test]
    fn run_group_matches_scalar_bitwise() {
        let ds = synth_calhousing(&SynthSpec { n: 300, ..Default::default() });
        let base = DesConfig {
            loss_every: 0,
            record_blocks: false,
            collect_snapshots: false,
            event_capacity: 0,
            ..DesConfig::paper(30, 5.0, 600.0, 55)
        };
        let runner = ScenarioRunner::new(ScenarioSpec::paper(), &ds);
        let cfg_for = |s: usize| DesConfig {
            seed: base.seed.wrapping_add(s as u64),
            ..base.clone()
        };
        let mut bw = BatchWorkspace::new();
        for count in [3usize, 6, 2] {
            // (6 exercises width 8; the loop reuses the workspace)
            let outs = run_group(&runner, &mut bw, count, cfg_for).unwrap();
            for l in 0..count {
                let mut ws = RunWorkspace::new();
                let stats = runner.run_with(&mut ws, &cfg_for(l)).unwrap();
                assert_eq!(
                    outs[l].final_loss.to_bits(),
                    stats.final_loss.to_bits(),
                    "count={count} lane {l} final loss"
                );
                assert_eq!(
                    outs[l].updates, stats.updates,
                    "count={count} lane {l} updates"
                );
            }
        }
    }
}
