//! Subcommand implementations for the `edgepipe` binary.

use std::path::Path;

use anyhow::{bail, Result};

use crate::bound::corollary1::BoundParams;
use crate::bound::{estimate_constants, optimize_block_size};
use crate::channel::IdealChannel;
use crate::config::ExperimentConfig;
use crate::coordinator::des::{run_des, DesConfig};
use crate::coordinator::executor::NativeExecutor;
use crate::coordinator::run::build_dataset;
use crate::metrics::writer::{write_csv, write_json, CsvTable};
use crate::model::{ridge_solution, RidgeModel};
use crate::sweep::fig3::fig3_data;
use crate::sweep::fig4::{fig4_data, Fig4Config};
use crate::sweep::runner::{grid_final_losses, log_grid};
use crate::util::telemetry::{self, Telemetry};
use crate::util::timefmt::fmt_count;

use super::args::{Args, HELP};

/// Dispatch a parsed command line. Returns the process exit code.
pub fn dispatch(args: &Args) -> Result<i32> {
    match args.command.as_str() {
        "help" => {
            println!("{HELP}");
            Ok(0)
        }
        "info" => cmd_info(args),
        "train" => cmd_train(args),
        "optimize" => cmd_optimize(args),
        "fig3" => cmd_fig3(args),
        "fig4" => cmd_fig4(args),
        "baselines" => cmd_baselines(args),
        "sweep" => cmd_sweep(args),
        "scenario" => cmd_scenario(args),
        "serve" => cmd_serve(args),
        "bench" => cmd_bench(args),
        "tightness" => cmd_tightness(args),
        "adaptive" => cmd_adaptive(args),
        "control" => cmd_control(args),
        other => {
            eprintln!("unknown command '{other}'\n\n{HELP}");
            Ok(2)
        }
    }
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    ExperimentConfig::load(
        args.config_path.as_deref().map(Path::new),
        &args.overrides,
    )
}

/// Split a comma-separated CLI list, trimming entries and dropping
/// empties (shared by the scenario and control sweep surfaces).
fn split_list(s: &str) -> Vec<String> {
    s.split(',')
        .map(|t| t.trim().to_string())
        .filter(|t| !t.is_empty())
        .collect()
}

/// The sweep-mode base [`DesConfig`] the Monte-Carlo surfaces share
/// (`scenario`, `control`, `optimize --mc`): protocol/train keys from
/// the experiment config, all recording off, ridge workload (callers
/// override fields like `workload` via struct update where needed).
fn sweep_base(cfg: &ExperimentConfig, t: f64, n_c: usize) -> DesConfig {
    DesConfig {
        n_c,
        n_o: cfg.protocol.n_o,
        tau_p: cfg.protocol.tau_p,
        t_budget: t,
        alpha: cfg.train.alpha,
        lambda: cfg.train.lambda,
        init_std: cfg.train.init_std,
        seed: cfg.train.seed,
        loss_every: 0,
        record_blocks: false,
        store_capacity: None,
        collect_snapshots: false,
        event_capacity: 0,
        workload: crate::model::Workload::Ridge,
        faults: Default::default(),
    }
}

/// Parse a `--<key> 0|1` flag (flags always consume a value, like
/// `--stdin 1`); absent counts as 0.
fn flag_01(args: &Args, key: &str) -> Result<bool> {
    match args.extra.get(key).map(String::as_str) {
        None | Some("0") => Ok(false),
        Some("1") => Ok(true),
        Some(other) => bail!("--{key} must be 0 or 1, got '{other}'"),
    }
}

/// `--progress 1` / `--metrics-json <file>` plumbing shared by
/// `scenario` and `bench`: when either flag is set, install a fresh
/// process-global telemetry sink (scheduler/pool/shard counters flow in
/// without further plumbing) and return the handle plus the dump path.
/// Telemetry is write-only observation — attaching it changes no
/// computed byte (pinned by `telemetry_parity.rs`).
fn telemetry_flags(
    args: &Args,
) -> Result<(bool, Option<std::path::PathBuf>, Telemetry)> {
    let progress = flag_01(args, "progress")?;
    let metrics_json =
        args.extra.get("metrics-json").map(std::path::PathBuf::from);
    let tel = if progress || metrics_json.is_some() {
        let tel = Telemetry::attached();
        telemetry::install(tel.clone());
        tel
    } else {
        Telemetry::off()
    };
    Ok((progress, metrics_json, tel))
}

/// Dump `--metrics-json` (if requested) and uninstall the global sink.
fn finish_telemetry(
    args: &Args,
    tel: &Telemetry,
    metrics_json: Option<&Path>,
) -> Result<()> {
    if !tel.is_attached() {
        return Ok(());
    }
    if let Some(path) = metrics_json {
        let snap = tel.snapshot().expect("attached handle has a snapshot");
        write_json(&snap, path)?;
        if !args.quiet {
            println!("wrote {}", path.display());
        }
    }
    telemetry::install(Telemetry::off());
    Ok(())
}

/// Resolve the bound parameters for a dataset (estimating constants).
fn bound_params(
    cfg: &ExperimentConfig,
    ds: &crate::data::Dataset,
) -> BoundParams {
    let k = estimate_constants(
        ds,
        cfg.train.lambda,
        cfg.train.alpha,
        2000,
        cfg.train.seed,
    );
    BoundParams::from_constants(cfg.train.alpha, &k)
}

fn cmd_info(args: &Args) -> Result<i32> {
    let cfg = load_config(args)?;
    println!("edgepipe {}", crate::VERSION);
    println!(
        "paper: Skatchkovsky & Simeone, 'Optimizing Pipelined Computation \
         and Communication for Latency-Constrained Edge Learning' (2019)"
    );
    match crate::runtime::find_artifact_dir() {
        Some(dir) => {
            let manifest = crate::runtime::Manifest::load(&dir)?;
            println!(
                "artifacts: {} ({} entry points, d={}, K_MAX={}, N_CAP={})",
                dir.display(),
                manifest.artifacts.len(),
                manifest.constants.d,
                manifest.constants.k_max,
                manifest.constants.n_cap
            );
        }
        None => println!("artifacts: NOT BUILT (run `make artifacts`)"),
    }
    let ds = build_dataset(&cfg)?;
    let k = estimate_constants(
        &ds,
        cfg.train.lambda,
        cfg.train.alpha,
        2000,
        cfg.train.seed,
    );
    println!(
        "dataset: N={} d={} (L={:.4}, c={:.4}, D={:.3}; paper: L=1.908, \
         c=0.061)",
        fmt_count(ds.n as u64),
        ds.d,
        k.big_l,
        k.c,
        k.d_diam
    );
    println!(
        "protocol: n_o={}, τ_p={}, T={}",
        cfg.protocol.n_o,
        cfg.protocol.tau_p,
        cfg.protocol.deadline(ds.n)
    );
    Ok(0)
}

fn cmd_optimize(args: &Args) -> Result<i32> {
    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let params = bound_params(&cfg, &ds);
    let opt = optimize_block_size(
        &params,
        ds.n,
        t,
        cfg.protocol.n_o,
        cfg.protocol.tau_p,
    );
    println!(
        "ñ_c = {} (bound {:.6}, case {:?}, full-delivery boundary {:?})",
        opt.n_c, opt.value, opt.case, opt.full_delivery_boundary
    );
    println!(
        "constants: L={:.4} c={:.4} D={:.3} α={} n_o={} T={}",
        params.big_l, params.c, params.d_diam, params.alpha, cfg.protocol.n_o, t
    );
    // --mc <seeds>: validate the (channel-aware) recommendation against
    // Monte-Carlo optimality gaps on the configured scenario axes
    if let Some(seeds) = args.extra.get("mc") {
        let seeds: usize = seeds
            .parse()
            .map_err(|_| anyhow::anyhow!("--mc must be an integer"))?;
        return validate_recommendation(&cfg, &ds, t, seeds, &params);
    }
    Ok(0)
}

/// The `optimize --mc` body: run the scenario configured by the
/// `scenario.*` keys at the channel-aware `ñ_c` and report whether the
/// Corollary-1 bound covers the measured gap at 99% bootstrap
/// confidence. `ridge_params` is the constant set `cmd_optimize`
/// already estimated (reused for the ridge workload; logistic
/// estimates its own conservative constants on its label view).
fn validate_recommendation(
    cfg: &ExperimentConfig,
    ds: &crate::data::Dataset,
    t: f64,
    seeds: usize,
    ridge_params: &BoundParams,
) -> Result<i32> {
    use crate::bound::{
        check_recommendation, estimate_logistic_constants, CheckConfig,
    };
    use crate::data::classify::binarize_labels;
    use crate::model::Workload;
    use crate::sweep::scenario::ScenarioSpec;

    let spec = ScenarioSpec::parse(
        &cfg.scenario.channel,
        &cfg.scenario.policy,
        &cfg.scenario.traffic,
        &cfg.scenario.workload,
        cfg.scenario.store,
    )?;
    // n_c = 1 is overridden by the recommendation
    let base = DesConfig { workload: spec.workload, ..sweep_base(cfg, t, 1) };
    // workload-matched constants and reference optimum, on the label
    // view the scenario actually trains (ridge trains on `ds` itself)
    let reg = cfg.train.lambda / ds.n as f64;
    let (params, loss_star) = match spec.workload {
        Workload::Ridge => {
            let w_star = ridge_solution(ds, cfg.train.lambda)?;
            (ridge_params.clone(), ds.ridge_loss(&w_star, reg))
        }
        Workload::Logistic => {
            let view = binarize_labels(ds);
            let k = estimate_logistic_constants(
                &view,
                cfg.train.lambda,
                cfg.train.alpha,
                4000,
                cfg.train.seed,
            );
            (
                BoundParams::from_constants(cfg.train.alpha, &k),
                crate::bound::logistic_reference_loss(
                    &view,
                    cfg.train.lambda,
                    cfg.train.alpha,
                    cfg.train.seed,
                ),
            )
        }
    };
    let check = CheckConfig {
        seeds,
        threads: cfg.sweep.threads,
        ..CheckConfig::default()
    };
    let out =
        check_recommendation(ds, &base, &spec, &params, loss_star, &check);
    println!(
        "validation [{}]: ñ_c={} (slowdown {:.3}), bound {:.6}",
        out.label, out.n_c, out.slowdown, out.bound
    );
    println!(
        "  measured gap {:.6} (99% bootstrap upper {:.6}, {} seeds) -> {}",
        out.mean_gap,
        out.gap_upper,
        seeds,
        if out.holds { "bound HOLDS" } else { "bound VIOLATED" }
    );
    Ok(if out.holds { 0 } else { 1 })
}

/// Resolve the block size for a run: the configured `n_c`, else the
/// bound optimizer's `ñ_c` (shared by `train` and `scenario`).
fn resolve_n_c(
    cfg: &ExperimentConfig,
    ds: &crate::data::Dataset,
    t: f64,
) -> usize {
    if cfg.protocol.n_c > 0 {
        cfg.protocol.n_c.min(ds.n)
    } else {
        let params = bound_params(cfg, ds);
        optimize_block_size(
            &params,
            ds.n,
            t,
            cfg.protocol.n_o,
            cfg.protocol.tau_p,
        )
        .n_c
    }
}

fn cmd_train(args: &Args) -> Result<i32> {
    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let n_c = resolve_n_c(&cfg, &ds, t);
    let des = DesConfig {
        n_c,
        n_o: cfg.protocol.n_o,
        tau_p: cfg.protocol.tau_p,
        t_budget: t,
        alpha: cfg.train.alpha,
        lambda: cfg.train.lambda,
        init_std: cfg.train.init_std,
        seed: cfg.train.seed,
        loss_every: 500,
        record_blocks: false,
        store_capacity: None,
        collect_snapshots: false,
        event_capacity: 64,
        workload: crate::model::Workload::Ridge,
        faults: Default::default(),
    };
    if !args.quiet {
        println!(
            "training: N={} n_c={} n_o={} T={} backend={}",
            ds.n, n_c, des.n_o, t, args.backend
        );
    }
    let result = match args.backend.as_str() {
        "native" => {
            let mut exec = NativeExecutor::new(
                RidgeModel::new(ds.d, des.lambda, ds.n),
                des.alpha,
            );
            run_des(&ds, &des, &mut IdealChannel, &mut exec)?
        }
        other => bail!("unknown backend {other}"),
    };
    let w_star = ridge_solution(&ds, cfg.train.lambda)?;
    let loss_star = ds.ridge_loss(&w_star, cfg.train.lambda / ds.n as f64);
    println!(
        "final loss {:.6} (gap to L(w*) {:.3e}); {} updates in {} blocks \
         ({} samples delivered, case {:?})",
        result.final_loss,
        result.final_gap(loss_star),
        fmt_count(result.updates as u64),
        result.blocks_sent,
        fmt_count(result.samples_delivered as u64),
        result.case
    );
    // emit the loss curve
    let mut table = CsvTable::new(&["time", "loss"]);
    for &(t, l) in &result.curve {
        table.push_nums(&[t, l]);
    }
    let out = Path::new(&args.out_dir).join("train_curve.csv");
    write_csv(&table, &out)?;
    if !args.quiet {
        println!("wrote {}", out.display());
    }
    Ok(0)
}

fn cmd_fig3(args: &Args) -> Result<i32> {
    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let params = bound_params(&cfg, &ds);
    let out = fig3_data(
        &params,
        ds.n,
        t,
        cfg.protocol.tau_p,
        &cfg.sweep.n_os,
        160,
    )?;
    print!("{}", out.render());
    let dir = Path::new(&args.out_dir);
    write_csv(&out.curve_table(), &dir.join("fig3_curves.csv"))?;
    write_csv(&out.marker_table(), &dir.join("fig3_markers.csv"))?;
    if !args.quiet {
        println!("wrote {}/fig3_curves.csv, fig3_markers.csv", dir.display());
    }
    Ok(0)
}

fn cmd_fig4(args: &Args) -> Result<i32> {
    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let params = bound_params(&cfg, &ds);
    let f4 = Fig4Config {
        n_o: cfg.protocol.n_o,
        tau_p: cfg.protocol.tau_p,
        t_budget: t,
        alpha: cfg.train.alpha,
        lambda: cfg.train.lambda,
        init_std: cfg.train.init_std,
        seed: cfg.train.seed,
        seeds: cfg.sweep.seeds,
        threads: cfg.sweep.threads,
        ..Fig4Config::paper(cfg.protocol.n_o, t)
    };
    let out = fig4_data(&ds, &params, &f4)?;
    print!("{}", out.render());
    let dir = Path::new(&args.out_dir);
    write_csv(&out.curve_table(), &dir.join("fig4_curves.csv"))?;
    write_csv(&out.search_table(), &dir.join("fig4_search.csv"))?;
    if !args.quiet {
        println!("wrote {}/fig4_curves.csv, fig4_search.csv", dir.display());
    }
    Ok(0)
}

fn cmd_baselines(args: &Args) -> Result<i32> {
    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let n_c = if cfg.protocol.n_c > 0 { cfg.protocol.n_c } else { 437 };
    let des = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(n_c.min(ds.n), cfg.protocol.n_o, t, cfg.train.seed)
    };
    let mk = || {
        NativeExecutor::new(
            RidgeModel::new(ds.d, des.lambda, ds.n),
            des.alpha,
        )
    };
    let pipe = run_des(&ds, &des, &mut IdealChannel, &mut mk())?;
    let seq = crate::baselines::sequential(
        &ds,
        &des,
        &mut IdealChannel,
        &mut mk(),
    )?;
    let all = crate::baselines::transmit_all_first(
        &ds,
        &des,
        &mut IdealChannel,
        &mut mk(),
    )?;
    println!("policy comparison (n_c={}, n_o={}, T={t}):", des.n_c, des.n_o);
    for (name, r) in [
        ("pipelined (paper)", &pipe),
        ("sequential (no overlap)", &seq),
        ("transmit-all-first", &all),
    ] {
        println!(
            "  {:<26} final loss {:.6}  updates {:>9}  delivered {:>6}",
            name,
            r.final_loss,
            fmt_count(r.updates as u64),
            r.samples_delivered
        );
    }
    Ok(0)
}

fn cmd_sweep(args: &Args) -> Result<i32> {
    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let grid = if cfg.sweep.n_cs.is_empty() {
        log_grid(ds.n, 24)?
    } else {
        cfg.sweep.n_cs.clone()
    };
    let des = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(1, cfg.protocol.n_o, t, cfg.train.seed)
    };
    let rows = grid_final_losses(
        &ds,
        &des,
        &grid,
        cfg.sweep.seeds,
        cfg.sweep.threads,
    )?;
    let mut table =
        CsvTable::new(&["n_c", "final_loss_mean", "final_loss_std"]);
    println!("final loss vs n_c (n_o={}, seeds={}):", des.n_o, cfg.sweep.seeds);
    for (nc, s) in &rows {
        println!("  n_c={:>6}  {:.6} ± {:.6}", nc, s.mean, s.std);
        table.push_nums(&[*nc as f64, s.mean, s.std]);
    }
    let best = rows
        .iter()
        .min_by(|a, b| a.1.mean.partial_cmp(&b.1.mean).unwrap())
        .unwrap();
    println!("experimental optimum n_c* = {} ({:.6})", best.0, best.1.mean);
    let out = Path::new(&args.out_dir).join("sweep_final_loss.csv");
    write_csv(&table, &out)?;
    Ok(0)
}

/// Monte-Carlo sweep over scenario specs (channel × policy × traffic).
fn cmd_scenario(args: &Args) -> Result<i32> {
    use crate::channel::FaultSpec;
    use crate::sweep::runner::scenario_grid;
    use crate::sweep::scenario::{
        from_name, registry, ChannelSpec, HeteroSpec, ScenarioSpec,
        SchedulerSpec, TrafficSpec,
    };

    let cfg = load_config(args)?;
    let preset = args.extra_or("preset", "");
    if preset == "list" {
        println!("registered scenarios:");
        for (name, spec) in registry() {
            println!("  {:<16} {}", name, spec.label());
        }
        return Ok(0);
    }

    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let n_c = resolve_n_c(&cfg, &ds, t);
    let base = sweep_base(&cfg, t, n_c);

    // heterogeneous-uplink options: when any is set, plain `<k>` traffic
    // specs in the sweep are upgraded to `devices:<k>` with these
    // per-device channels / scheduler / shard skew
    let dev_channels_str =
        args.extra_or("device-channels", &cfg.scenario.device_channels);
    let dev_sched_str =
        args.extra_or("device-sched", &cfg.scenario.device_sched);
    let dev_skew: f64 = args
        .extra_or("device-skew", &cfg.scenario.device_skew.to_string())
        .parse()
        .map_err(|_| anyhow::anyhow!("--device-skew must be a number"))?;
    let dev_sched = SchedulerSpec::parse(&dev_sched_str)?;
    let dev_channels: Vec<ChannelSpec> = split_list(&dev_channels_str)
        .iter()
        .map(|s| ChannelSpec::parse(s))
        .collect::<Result<_>>()?;
    let hetero_requested = !dev_channels.is_empty()
        || dev_sched != SchedulerSpec::RoundRobin
        || dev_skew != 0.0;
    let upgrade = |spec: ScenarioSpec| -> Result<ScenarioSpec> {
        match spec.traffic {
            TrafficSpec::Devices(k) if hetero_requested => {
                Ok(ScenarioSpec {
                    traffic: TrafficSpec::Hetero(HeteroSpec::new(
                        k,
                        dev_sched,
                        dev_skew,
                        dev_channels.clone(),
                    )?),
                    ..spec
                })
            }
            // an explicit devices: spec already fixes its options; the
            // flags cannot be merged in, and silently dropping them
            // would run a different uplink than the user asked for
            TrafficSpec::Hetero(_) if hetero_requested => bail!(
                "--device-channels/--device-sched/--device-skew cannot \
                 modify the explicit hetero traffic spec '{}': set the \
                 options inside the devices:… string, or use a plain \
                 <k> entry",
                spec.traffic.label()
            ),
            _ => Ok(spec),
        }
    };
    // presets get the same upgrade, so `--preset multi4 --device-sched
    // greedy` heterogenizes the preset's plain Devices(k) traffic
    // instead of silently ignoring the device flags (a count mismatch,
    // e.g. 4 per-device channels against the k=1 `paper` preset, is a
    // hard error)
    let specs: Vec<ScenarioSpec> = if preset == "all" {
        registry()
            .into_iter()
            .map(|(_, spec)| upgrade(spec))
            .collect::<Result<_>>()?
    } else if !preset.is_empty() {
        vec![upgrade(from_name(&preset).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario preset '{preset}' \
                 (try `edgepipe scenario --preset list`)"
            )
        })?)?]
    } else {
        let channels =
            split_list(&args.extra_or("channels", &cfg.scenario.channel));
        let policies =
            split_list(&args.extra_or("policies", &cfg.scenario.policy));
        let traffics =
            split_list(&args.extra_or("devices", &cfg.scenario.traffic));
        let workloads =
            split_list(&args.extra_or("workloads", &cfg.scenario.workload));
        let mut specs = Vec::new();
        for ch in &channels {
            for po in &policies {
                for tr in &traffics {
                    for wl in &workloads {
                        specs.push(upgrade(ScenarioSpec::parse(
                            ch,
                            po,
                            tr,
                            wl,
                            cfg.scenario.store,
                        )?)?);
                    }
                }
            }
        }
        specs
    };
    // --faults <spec>,<spec>,… crosses every selected scenario with each
    // fault plan on its channel axis (`off` = the unmodified scenario;
    // clauses join with '+', never ',', so the list split is safe).
    // Hetero lanes that inherit the channel axis inherit its plan too.
    let fault_list =
        split_list(&args.extra_or("faults", &cfg.scenario.fault));
    let faults: Vec<FaultSpec> = fault_list
        .iter()
        .map(|s| FaultSpec::parse(s))
        .collect::<Result<_>>()?;
    let specs: Vec<ScenarioSpec> = if faults.is_empty() {
        specs
    } else {
        specs
            .iter()
            .flat_map(|spec| {
                faults.iter().map(|f| ScenarioSpec {
                    channel: spec.channel.with_fault(f),
                    ..spec.clone()
                })
            })
            .collect()
    };
    if specs.is_empty() {
        bail!("no scenarios selected");
    }
    if !args.quiet {
        println!(
            "scenario sweep: N={} n_c={} n_o={} T={t} seeds={} ({} specs)",
            ds.n,
            base.n_c,
            base.n_o,
            cfg.sweep.seeds,
            specs.len()
        );
    }

    // --stream <file> journals every completed group as JSONL and
    // aggregates in constant memory; --resume <file> replays a journal
    // first (appending new groups to the same file unless --stream
    // names another). Both run the streaming pipeline, which is
    // bit-identical to the in-memory path row-for-row.
    let stream_path = args.extra.get("stream").map(std::path::PathBuf::from);
    let resume_path = args.extra.get("resume").map(std::path::PathBuf::from);
    let (progress, metrics_json, tel) = telemetry_flags(args)?;
    let (rows, failed) = if stream_path.is_some() || resume_path.is_some() {
        use crate::sweep::stream::{stream_scenario_grid, StreamOptions};
        let opts = StreamOptions {
            seeds: cfg.sweep.seeds,
            threads: cfg.sweep.threads,
            journal: stream_path,
            resume: resume_path,
            progress,
            telemetry: tel.clone(),
            ..StreamOptions::default()
        };
        let outcome = stream_scenario_grid(&ds, &base, &specs, &opts)?;
        if !args.quiet {
            println!(
                "streamed {} group(s) ({} reused from journal)",
                outcome.groups_run, outcome.groups_reused
            );
        }
        for e in &outcome.errors {
            eprintln!(
                "error: {} seeds {}..: {}",
                e.label,
                e.seed0,
                e.message
            );
        }
        (outcome.rows, !outcome.errors.is_empty())
    } else {
        let rows = scenario_grid(
            &ds,
            &base,
            &specs,
            cfg.sweep.seeds,
            cfg.sweep.threads,
        )?;
        (rows, false)
    };
    let mut table = CsvTable::new(&[
        "scenario",
        "final_loss_mean",
        "final_loss_std",
        "final_loss_sem",
        "seeds",
    ]);
    for (label, s) in &rows {
        println!(
            "  {:<40} {:.6} ± {:.6} (sem {:.2e})",
            label, s.mean, s.std, s.sem
        );
        table.push_raw(vec![
            label.clone(),
            format!("{}", s.mean),
            format!("{}", s.std),
            format!("{}", s.sem),
            format!("{}", s.n),
        ]);
    }
    // rows with no surviving seeds carry NaN stats; never let them
    // panic the ranking
    let best = rows
        .iter()
        .filter(|r| r.1.n > 0)
        .min_by(|a, b| {
            a.1.mean
                .partial_cmp(&b.1.mean)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
    if let Some(best) = best {
        println!("best scenario: {} ({:.6})", best.0, best.1.mean);
    }
    let out = Path::new(&args.out_dir).join("scenario_sweep.csv");
    write_csv(&table, &out)?;
    if !args.quiet {
        println!("wrote {}", out.display());
    }
    finish_telemetry(args, &tel, metrics_json.as_deref())?;
    Ok(if failed { 1 } else { 0 })
}

/// Long-running scenario service: line-delimited JSON requests over TCP
/// (or stdin/stdout with `--stdin 1`), reusing warm runners, a
/// persistent batch workspace and a result cache across requests.
fn cmd_serve(args: &Args) -> Result<i32> {
    use crate::sweep::serve::{serve_connection, serve_tcp, ServeState};

    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let n_c = resolve_n_c(&cfg, &ds, t);
    let base = sweep_base(&cfg, t, n_c);
    let max_seeds: usize =
        args.extra_or("max-seeds", "4096").parse().map_err(|_| {
            anyhow::anyhow!("--max-seeds must be a positive integer")
        })?;
    if !args.quiet {
        println!(
            "serve: N={} n_c={} n_o={} T={t} (max {} seeds/request)",
            ds.n, base.n_c, base.n_o, max_seeds
        );
    }
    let mut state = ServeState::new(&ds, base, max_seeds, 0);
    // route the scheduler/pool counters of served runs into the same
    // sink `{"cmd":"stats"}` reports from (write-only; replies other
    // than stats are unchanged)
    telemetry::install(state.telemetry());
    let served = if args.extra_or("stdin", "0") == "1" {
        serve_connection(
            &mut state,
            std::io::stdin().lock(),
            std::io::stdout().lock(),
        )
        .map(|_| ())
    } else {
        serve_tcp(&mut state, &args.extra_or("addr", "127.0.0.1:4088"))
    };
    telemetry::install(Telemetry::off());
    served?;
    Ok(0)
}

/// The tracked sweep-engine benchmark: baseline vs optimized engine
/// shapes on identical workloads; writes `BENCH_sweep.json` so future
/// changes regress against a recorded baseline.
fn cmd_bench(args: &Args) -> Result<i32> {
    use crate::bench::sweep::{env_flag, run_sweep_bench, SweepBenchConfig};

    let cfg = load_config(args)?;
    // an explicit --fast 0|1 wins over the EDGEPIPE_BENCH_FAST env var
    // (where "0"/"" count as unset); anything else is a usage error
    let fast = match args.extra.get("fast").map(String::as_str) {
        Some("1") => true,
        Some("0") => false,
        Some(other) => bail!("--fast must be 0 or 1, got '{other}'"),
        None => env_flag("EDGEPIPE_BENCH_FAST"),
    };
    let parse_points = |s: String| {
        s.parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--points must be an integer"))
    };
    // --fast selects the CI-scale preset for n/seeds/n_o (the usual
    // config keys are ignored and a note is printed); --points and
    // sweep.threads apply in both modes
    let bench_cfg = if fast {
        let preset = SweepBenchConfig::fast();
        if !args.quiet {
            println!(
                "fast mode: CI-scale preset (n={}, seeds={}, n_o={}); \
                 data.*/sweep.seeds/protocol.n_o config keys ignored",
                preset.n, preset.seeds, preset.n_o
            );
        }
        SweepBenchConfig {
            threads: cfg.sweep.threads,
            grid_points: match args.extra.get("points") {
                Some(p) => parse_points(p.clone())?,
                None => preset.grid_points,
            },
            ..preset
        }
    } else {
        SweepBenchConfig {
            n: cfg.data.n_raw,
            grid_points: parse_points(args.extra_or("points", "8"))?,
            seeds: cfg.sweep.seeds,
            threads: cfg.sweep.threads,
            n_o: cfg.protocol.n_o,
            // full-preset device counts; oversize ones are skipped
            // when the configured dataset can't populate them
            ..SweepBenchConfig::full()
        }
    };
    if !args.quiet {
        println!(
            "sweep bench: n_raw={} points={} seeds={} threads={} n_o={}",
            bench_cfg.n,
            bench_cfg.grid_points,
            bench_cfg.seeds,
            bench_cfg.threads,
            bench_cfg.n_o
        );
    }
    // `--progress 1` here only turns on the sink (bench prints its own
    // progress); `--metrics-json` captures the scheduler/pool counters
    // the benched sweeps accumulate through the process-global handle
    let (_progress, metrics_json, tel) = telemetry_flags(args)?;
    let report = run_sweep_bench(&bench_cfg);
    print!("{}", report.render());
    let json_path = args.extra_or("json", "BENCH_sweep.json");
    std::fs::write(&json_path, report.to_value().to_json_pretty())?;
    println!("wrote {json_path}");
    finish_telemetry(args, &tel, metrics_json.as_deref())?;
    Ok(0)
}

/// Theorem-1 vs Corollary-1 vs actual gap (the bound-tightness study).
fn cmd_tightness(args: &Args) -> Result<i32> {
    use crate::bound::corollary1::corollary1_bound;
    use crate::bound::theorem1::{theorem1_case_b, BlockGaps};
    use crate::protocol::TimelineCase;

    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let params = bound_params(&cfg, &ds);
    let w_star = ridge_solution(&ds, cfg.train.lambda)?;
    let loss_star = ds.ridge_loss(&w_star, cfg.train.lambda / ds.n as f64);
    let n_c = if cfg.protocol.n_c > 0 { cfg.protocol.n_c } else { 400 };

    let des = DesConfig {
        n_c,
        n_o: cfg.protocol.n_o,
        tau_p: cfg.protocol.tau_p,
        t_budget: t,
        alpha: cfg.train.alpha,
        lambda: cfg.train.lambda,
        init_std: cfg.train.init_std,
        seed: cfg.train.seed,
        loss_every: 0,
        record_blocks: false,
        store_capacity: None,
        collect_snapshots: true,
        event_capacity: 0,
        workload: crate::model::Workload::Ridge,
        faults: Default::default(),
    };
    let mut exec = NativeExecutor::new(
        RidgeModel::new(ds.d, des.lambda, ds.n),
        des.alpha,
    );
    let run = run_des(&ds, &des, &mut IdealChannel, &mut exec)?;
    if run.case != TimelineCase::Full {
        bail!("pick an n_c that delivers the dataset (case b) for tightness");
    }
    let reg = cfg.train.lambda / ds.n as f64;
    let gaps: Vec<f64> = run
        .snapshots
        .iter()
        .map(|s| {
            let block = crate::data::Dataset::new(
                s.x.clone(),
                s.y.clone(),
                s.y.len(),
                ds.d,
            );
            block.ridge_loss(&s.w_end, reg) - block.ridge_loss(&w_star, reg)
        })
        .collect();
    let b_d = run.snapshots.len();
    let block_len = n_c as f64 + cfg.protocol.n_o;
    let n_l = (t - b_d as f64 * block_len).max(0.0) / cfg.protocol.tau_p;
    let th1 = theorem1_case_b(
        &params,
        &BlockGaps { gaps, remainder_gap: 0.0 },
        b_d,
        block_len / cfg.protocol.tau_p,
        n_l,
    );
    let co1 = corollary1_bound(
        &params,
        ds.n,
        t,
        n_c as f64,
        cfg.protocol.n_o,
        cfg.protocol.tau_p,
        false,
    );
    println!("bound tightness at n_c={n_c}, n_o={}:", cfg.protocol.n_o);
    println!("  actual gap  : {:.6}", run.final_loss - loss_star);
    println!("  Theorem 1   : {th1:.6} (measured per-block gaps)");
    println!("  Corollary 1 : {co1:.6} (LD²/2 relaxation)");
    Ok(0)
}

/// Compare adaptive block schedules against the fixed bound optimum.
fn cmd_adaptive(args: &Args) -> Result<i32> {
    use crate::extensions::adaptive::{
        run_scheduled, BlockSchedule, FixedSchedule, WarmupSchedule,
    };

    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    let params = bound_params(&cfg, &ds);
    let nc_opt = optimize_block_size(
        &params,
        ds.n,
        t,
        cfg.protocol.n_o,
        cfg.protocol.tau_p,
    )
    .n_c;
    let des = DesConfig {
        record_blocks: false,
        ..DesConfig::paper(nc_opt, cfg.protocol.n_o, t, cfg.train.seed)
    };
    let mut schedules: Vec<Box<dyn BlockSchedule>> = vec![
        Box::new(FixedSchedule(nc_opt)),
        Box::new(WarmupSchedule::new(16, 2.0, nc_opt)),
        Box::new(WarmupSchedule::new(64, 4.0, 4 * nc_opt)),
    ];
    println!(
        "adaptive schedules (n_o={}, ñ_c={nc_opt}):",
        cfg.protocol.n_o
    );
    for sched in schedules.iter_mut() {
        let mut exec = NativeExecutor::new(
            RidgeModel::new(ds.d, des.lambda, ds.n),
            des.alpha,
        );
        let run = run_scheduled(
            &ds,
            &des,
            sched.as_mut(),
            &mut IdealChannel,
            &mut exec,
        )?;
        println!(
            "  {:<24} final loss {:.6} (delivered {})",
            sched.name(),
            run.final_loss,
            run.samples_delivered
        );
    }
    Ok(0)
}

/// The closed-loop comparison: fixed `ñ_c` vs open-loop warmup vs
/// channel-adaptive control across fading severities, reporting final
/// loss and deadline-outage rates (`sweep::control`).
fn cmd_control(args: &Args) -> Result<i32> {
    use crate::sweep::control::{control_comparison, fading_severities};
    use crate::sweep::scenario::{ChannelSpec, PolicySpec};

    let cfg = load_config(args)?;
    let ds = build_dataset(&cfg)?;
    let t = cfg.protocol.deadline(ds.n);
    // n_c = 1 is overridden per severity by the recommendation
    let base = sweep_base(&cfg, t, 1);
    let channels: Vec<ChannelSpec> =
        match args.extra.get("severities").map(String::as_str) {
            Some(list) => split_list(list)
                .iter()
                .map(|s| ChannelSpec::parse(s))
                .collect::<Result<_>>()?,
            None => fading_severities(),
        };
    let policies: Vec<PolicySpec> = split_list(&args.extra_or(
        "policies",
        "fixed,warmup:16:2,control,control:est=ema",
    ))
    .iter()
    .map(|s| PolicySpec::parse(s))
    .collect::<Result<_>>()?;
    if channels.is_empty() || policies.is_empty() {
        bail!("need at least one severity and one policy");
    }
    if !args.quiet {
        println!(
            "control sweep: N={} n_o={} T={t} seeds={} \
             ({} severities x {} policies)",
            ds.n,
            base.n_o,
            cfg.sweep.seeds,
            channels.len(),
            policies.len()
        );
    }
    let rows = control_comparison(
        &ds,
        &base,
        &channels,
        &policies,
        cfg.sweep.seeds,
        cfg.sweep.threads,
    );
    let mut table = CsvTable::new(&[
        "channel",
        "policy",
        "n_c",
        "final_loss_mean",
        "final_loss_std",
        "outage_rate",
        "mean_delivered",
        "seeds",
    ]);
    let mut last_channel = String::new();
    for row in &rows {
        if row.channel != last_channel {
            println!(
                "{} (slowdown-aware ñ_c = {}):",
                row.channel, row.n_c
            );
            last_channel = row.channel.clone();
        }
        println!(
            "  {:<24} loss {:.6} ± {:.6}  outage {:>5.1}%  delivered {:>8.1}",
            row.policy,
            row.loss.mean,
            row.loss.std,
            100.0 * row.outage_rate,
            row.mean_delivered
        );
        table.push_raw(vec![
            row.channel.clone(),
            row.policy.clone(),
            format!("{}", row.n_c),
            format!("{}", row.loss.mean),
            format!("{}", row.loss.std),
            format!("{}", row.outage_rate),
            format!("{}", row.mean_delivered),
            format!("{}", row.loss.n),
        ]);
    }
    let out = Path::new(&args.out_dir).join("control_sweep.csv");
    write_csv(&table, &out)?;
    if !args.quiet {
        println!("wrote {}", out.display());
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_help() {
        let args = Args { command: "help".into(), ..Default::default() };
        assert_eq!(dispatch(&args).unwrap(), 0);
    }

    #[test]
    fn dispatch_unknown_is_code_2() {
        let args = Args { command: "bogus".into(), ..Default::default() };
        assert_eq!(dispatch(&args).unwrap(), 2);
    }

    #[test]
    fn scenario_preset_list_runs() {
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("preset".to_string(), "list".to_string());
        let args = Args {
            command: "scenario".into(),
            backend: "native".into(),
            extra,
            ..Default::default()
        };
        assert_eq!(dispatch(&args).unwrap(), 0);
    }

    #[test]
    fn scenario_cross_sweep_on_small_config() {
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("channels".to_string(), "ideal".to_string());
        extra.insert("policies".to_string(), "fixed,sequential".to_string());
        extra.insert("devices".to_string(), "1,2".to_string());
        let args = Args {
            command: "scenario".into(),
            overrides: vec![
                ("data.n_raw".into(), "400".into()),
                ("protocol.n_c".into(), "40".into()),
                ("sweep.seeds".into(), "2".into()),
            ],
            out_dir: std::env::temp_dir()
                .join("edgepipe_scenario_test")
                .to_string_lossy()
                .into_owned(),
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert_eq!(dispatch(&args).unwrap(), 0);
    }

    #[test]
    fn scenario_stream_and_resume_match_in_memory_csv() {
        let base_dir = std::env::temp_dir().join("edgepipe_stream_cli_test");
        let journal =
            base_dir.join(format!("j_{}.jsonl", std::process::id()));
        let mk = |out: &str, flag: Option<(&str, &std::path::Path)>| {
            let mut extra = std::collections::BTreeMap::new();
            extra.insert("channels".to_string(), "ideal".to_string());
            extra
                .insert("policies".to_string(), "fixed,sequential".to_string());
            if let Some((k, p)) = flag {
                extra.insert(k.to_string(), p.to_string_lossy().into_owned());
            }
            Args {
                command: "scenario".into(),
                overrides: vec![
                    ("data.n_raw".into(), "400".into()),
                    ("protocol.n_c".into(), "40".into()),
                    ("sweep.seeds".into(), "3".into()),
                ],
                out_dir: base_dir.join(out).to_string_lossy().into_owned(),
                backend: "native".into(),
                quiet: true,
                extra,
                ..Default::default()
            }
        };
        let _ = std::fs::remove_file(&journal);
        assert_eq!(dispatch(&mk("mem", None)).unwrap(), 0);
        let streaming = mk("stream", Some(("stream", &journal)));
        assert_eq!(dispatch(&streaming).unwrap(), 0);
        let read = |out: &str| {
            std::fs::read_to_string(
                base_dir.join(out).join("scenario_sweep.csv"),
            )
            .unwrap()
        };
        let mem = read("mem");
        assert_eq!(mem, read("stream"), "streamed CSV must be byte-identical");
        // replaying the full journal reproduces the CSV without re-runs
        let resuming = mk("resumed", Some(("resume", &journal)));
        assert_eq!(dispatch(&resuming).unwrap(), 0);
        assert_eq!(mem, read("resumed"), "resumed CSV must be byte-identical");
        let _ = std::fs::remove_file(&journal);
    }

    #[test]
    fn scenario_stream_with_metrics_json_drains_to_zero_lag() {
        let base_dir = std::env::temp_dir().join("edgepipe_metrics_cli_test");
        let pid = std::process::id();
        let journal = base_dir.join(format!("j_{pid}.jsonl"));
        let metrics = base_dir.join(format!("m_{pid}.json"));
        let _ = std::fs::remove_file(&journal);
        let _ = std::fs::remove_file(&metrics);
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("channels".to_string(), "ideal".to_string());
        extra.insert("policies".to_string(), "fixed,sequential".to_string());
        extra.insert(
            "stream".to_string(),
            journal.to_string_lossy().into_owned(),
        );
        extra.insert(
            "metrics-json".to_string(),
            metrics.to_string_lossy().into_owned(),
        );
        let args = Args {
            command: "scenario".into(),
            overrides: vec![
                ("data.n_raw".into(), "400".into()),
                ("protocol.n_c".into(), "40".into()),
                ("sweep.seeds".into(), "2".into()),
            ],
            out_dir: base_dir.join("out").to_string_lossy().into_owned(),
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert_eq!(dispatch(&args).unwrap(), 0);
        let text = std::fs::read_to_string(&metrics).unwrap();
        let snap = crate::util::json::parse(&text).unwrap();
        let stream = snap.get("stream").unwrap();
        // every journaled row was aggregated: the pipeline drained
        assert_eq!(
            stream.get("journal_lag").unwrap().as_usize().unwrap(),
            0
        );
        // at least one seed-group per spec ran (exact count depends on
        // the EDGEPIPE_LANES chunking)
        assert!(
            stream.get("groups_run").unwrap().as_usize().unwrap() >= 2
        );
        // the benched sweep ran through the global sink too
        assert!(
            snap.get("sched")
                .unwrap()
                .get("runs")
                .unwrap()
                .as_usize()
                .unwrap()
                > 0
        );
        let _ = std::fs::remove_file(&journal);
        let _ = std::fs::remove_file(&metrics);
    }

    #[test]
    fn scenario_hetero_sweep_runs_end_to_end() {
        // the acceptance-criterion invocation: a 4-device heterogeneous
        // uplink with greedy scheduling and mixed per-device channels
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("devices".to_string(), "4".to_string());
        extra.insert("device-sched".to_string(), "greedy".to_string());
        extra.insert("device-skew".to_string(), "0.5".to_string());
        extra.insert(
            "device-channels".to_string(),
            "ideal,erasure:0.2,fading:0.05:0.25:0.6,rate:0.5:0.1"
                .to_string(),
        );
        let args = Args {
            command: "scenario".into(),
            overrides: vec![
                ("data.n_raw".into(), "400".into()),
                ("protocol.n_c".into(), "40".into()),
                ("sweep.seeds".into(), "2".into()),
            ],
            out_dir: std::env::temp_dir()
                .join("edgepipe_hetero_test")
                .to_string_lossy()
                .into_owned(),
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert_eq!(dispatch(&args).unwrap(), 0);
    }

    #[test]
    fn device_flags_upgrade_presets_too() {
        // --preset multi4 + --device-sched greedy must heterogenize the
        // preset's Devices(4) traffic, not silently ignore the flag
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("preset".to_string(), "multi4".to_string());
        extra.insert("device-sched".to_string(), "greedy".to_string());
        let args = Args {
            command: "scenario".into(),
            overrides: vec![
                ("data.n_raw".into(), "300".into()),
                ("protocol.n_c".into(), "30".into()),
                ("sweep.seeds".into(), "2".into()),
            ],
            out_dir: std::env::temp_dir()
                .join("edgepipe_hetero_preset_test")
                .to_string_lossy()
                .into_owned(),
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert_eq!(dispatch(&args).unwrap(), 0);
        // a channel-count mismatch against the preset's k errors out
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("preset".to_string(), "multi4".to_string());
        extra.insert(
            "device-channels".to_string(),
            "ideal,ideal".to_string(),
        );
        let args = Args {
            command: "scenario".into(),
            overrides: vec![
                ("data.n_raw".into(), "300".into()),
                ("protocol.n_c".into(), "30".into()),
            ],
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn device_flags_reject_explicit_hetero_specs() {
        // flags cannot silently merge into (or be dropped from) an
        // explicit devices:… traffic spec — hard error, both for a
        // sweep entry and for a hetero preset
        for (key, value) in [
            ("devices", "devices:2:sched=pfair"),
            ("preset", "hetero3"),
        ] {
            let mut extra = std::collections::BTreeMap::new();
            extra.insert(key.to_string(), value.to_string());
            extra.insert("device-skew".to_string(), "0.5".to_string());
            let args = Args {
                command: "scenario".into(),
                overrides: vec![
                    ("data.n_raw".into(), "200".into()),
                    ("protocol.n_c".into(), "20".into()),
                    ("sweep.seeds".into(), "1".into()),
                ],
                backend: "native".into(),
                quiet: true,
                extra,
                ..Default::default()
            };
            assert!(
                dispatch(&args).is_err(),
                "{key}={value} must reject device flags"
            );
        }
    }

    #[test]
    fn hetero_flags_reject_mismatched_channel_counts() {
        // 4 per-device channels cannot serve a k=3 sweep entry
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("devices".to_string(), "3".to_string());
        extra.insert(
            "device-channels".to_string(),
            "ideal,ideal,ideal,ideal".to_string(),
        );
        let args = Args {
            command: "scenario".into(),
            overrides: vec![
                ("data.n_raw".into(), "200".into()),
                ("protocol.n_c".into(), "20".into()),
                ("sweep.seeds".into(), "1".into()),
            ],
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn scenario_fault_sweep_runs_end_to_end() {
        // --faults crosses the grid: the same scenario fault-free (off)
        // and under a dropout with the hardened ARQ
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("channels".to_string(), "ideal".to_string());
        extra.insert("policies".to_string(), "fixed".to_string());
        extra.insert(
            "faults".to_string(),
            "off,drop:0:200+retry:3:1:2".to_string(),
        );
        let args = Args {
            command: "scenario".into(),
            overrides: vec![
                ("data.n_raw".into(), "400".into()),
                ("protocol.n_c".into(), "40".into()),
                ("sweep.seeds".into(), "2".into()),
            ],
            out_dir: std::env::temp_dir()
                .join("edgepipe_fault_test")
                .to_string_lossy()
                .into_owned(),
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert_eq!(dispatch(&args).unwrap(), 0);
        // a malformed fault list is a hard error, with the grammar named
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("faults".to_string(), "meteor:1".to_string());
        let args = Args {
            command: "scenario".into(),
            overrides: vec![
                ("data.n_raw".into(), "200".into()),
                ("protocol.n_c".into(), "20".into()),
            ],
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn control_sweep_runs_end_to_end() {
        let mut extra = std::collections::BTreeMap::new();
        extra.insert(
            "severities".to_string(),
            "ideal,fading:0.1:0.15:0.5:0:0.3".to_string(),
        );
        extra.insert("policies".to_string(), "fixed,control".to_string());
        let args = Args {
            command: "control".into(),
            overrides: vec![
                ("data.n_raw".into(), "300".into()),
                ("sweep.seeds".into(), "2".into()),
            ],
            out_dir: std::env::temp_dir()
                .join("edgepipe_control_test")
                .to_string_lossy()
                .into_owned(),
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert_eq!(dispatch(&args).unwrap(), 0);
        // malformed policy and severity lists are hard errors
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("policies".to_string(), "control:replan=0".to_string());
        let args = Args {
            command: "control".into(),
            overrides: vec![("data.n_raw".into(), "200".into())],
            backend: "native".into(),
            quiet: true,
            extra,
            ..Default::default()
        };
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn optimize_on_small_config() {
        let args = Args {
            command: "optimize".into(),
            overrides: vec![
                ("data.n_raw".into(), "600".into()),
                ("protocol.n_o".into(), "10".into()),
            ],
            out_dir: std::env::temp_dir()
                .join("edgepipe_cli_test")
                .to_string_lossy()
                .into_owned(),
            backend: "native".into(),
            ..Default::default()
        };
        assert_eq!(dispatch(&args).unwrap(), 0);
    }
}
