//! Minimal argv parser: `edgepipe <command> [--flag value]... [--set
//! section.key=value]...`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Top-level usage text.
pub const HELP: &str = "\
edgepipe — pipelined computation & communication for latency-constrained
edge learning (Skatchkovsky & Simeone, 2019; three-layer rust+JAX+Pallas)

USAGE:
    edgepipe <COMMAND> [OPTIONS]

COMMANDS:
    info        show version, artifact status and dataset constants
    train       run one pipelined training experiment
    optimize    pick the bound-optimal block size ñ_c
    fig3        regenerate paper Fig. 3 (bound vs n_c per overhead)
    fig4        regenerate paper Fig. 4 (loss curves; ñ_c vs n_c*)
    baselines   compare pipelined vs sequential vs transmit-all-first
    sweep       Monte-Carlo final-loss sweep over block sizes
    scenario    Monte-Carlo sweep over registered scenarios
                (channel × policy × device/traffic grids); --stream /
                --resume run it as a journaled constant-memory pipeline
    serve       long-running scenario service: line-delimited JSON
                requests over TCP (or stdin), warm runner/result cache
    bench       sweep-engine throughput benchmark (baseline vs optimized;
                runs/sec, SGD updates/sec, allocations/run)
    tightness   actual gap vs Theorem 1 vs Corollary 1
    adaptive    adaptive block-size schedules vs the fixed optimum ñ_c
    control     closed-loop control comparison: fixed ñ_c vs open-loop
                warmup vs channel-adaptive control across fading
                severities (final loss + deadline-outage rate)
    help        print this message

OPTIONS (all commands):
    --config <path>          TOML config file
    --set <section.key=val>  override any config key (repeatable)
    --out <dir>              output directory for CSV/JSON [default: out]
    --backend <native>       executor backend for `train` [default: native]
    --quiet                  suppress progress logging

SCENARIO OPTIONS (scenario command):
    --preset <name|all|list> run registry preset(s) / list their names
    --channels <a,b,..>      channel specs: ideal | erasure:<p> | rate:<r>[:<p>]
                             | fading:<p_gb>:<p_bg>:<p_bad>[:<p_good>
                               [:<r_bad>[:<r_good>]]]  (Gilbert–Elliott)
    --policies <a,b,..>      policy specs: fixed[:n_c] | warmup:<s>:<g>[:<cap>]
                             | deadline:<frac> | sequential[:n_c] | allfirst
                             | control[:est=<ge|ema>][:replan=<k>]
                             (closed-loop: ge = Gilbert-Elliott belief
                             filter on the channel axis params, ema =
                             model-free moving average; re-plans the
                             Corollary-1 ñ_c every k block boundaries)
    --devices <a,b,..>       traffic specs: <k> devices | online:<rate>
                             | devices:<k>[:sched=..][:skew=..]
    --workloads <a,b,..>     workload specs: ridge | logistic
    (the cross product of the four lists runs in one parallel sweep)
    --device-channels <a,b,..>  per-device channels for the heterogeneous
                             uplink (1 spec broadcast, or exactly k);
                             upgrades plain <k> traffic entries, incl.
                             inside --preset specs
    --device-sched <s>       device scheduler: rr | greedy (fastest
                             expected finish) | pfair (data-debt
                             proportional-fair)  [default: rr]
    --device-skew <f>        label skew of device shards in [0,1]
                             (0 = IID round-robin, 1 = label-sorted)
    --faults <a,b,..>        fault plans crossed with every selected
                             scenario on its channel axis. Each plan is
                             '+'-joined clauses: outage:<start>:<dur>
                             [:<period>] | ackloss:<p> | drop:<dev>:<t>
                             | preempt:<start>:<dur>[:<period>] |
                             retry:<timeout>[:<budget>[:<evict>]]; `off`
                             = the unmodified (bit-identical) scenario.
                             Any channel spec also takes the same plan
                             inline as a :fault=<spec> suffix.
    --stream <file>          run the sweep as a streaming pipeline,
                             appending one JSONL row per completed seed
                             group to <file>; constant memory in the
                             grid size, results bit-identical to the
                             in-memory sweep. Failed groups become
                             error rows (exit 1), never panics.
    --resume <file>          replay a --stream journal first: completed
                             groups are reused, error rows and the
                             truncated tail re-run, new groups append
                             to <file> (or to --stream if also given).
                             The journal header pins scenarios, seeds,
                             lanes and config; mismatches are errors.
    --progress 1             rate-limited stderr ticker for --stream /
                             --resume runs: groups done, groups/sec,
                             per-stage queue depths, journal lag.
                             Display-only — results stay bit-identical.
    --metrics-json <file>    dump a runtime-telemetry snapshot (event /
                             packet / retransmission counters, pool and
                             shard stats, streaming backpressure gauges)
                             as JSON after the sweep. Write-only
                             observation; never changes results.

SERVE OPTIONS (serve command):
    --addr <host:port>       TCP listen address [default: 127.0.0.1:4088]
    --stdin 1                serve one session on stdin/stdout instead
    --max-seeds <n>          per-request seed-count cap [default: 4096]
    (requests are one JSON object per line: axis strings as in the
     scenario flags — {\"channel\":\"erasure:0.1\",\"policy\":\"fixed\",
     \"traffic\":\"1\",\"workload\":\"ridge\",\"store\":0} — plus
     \"seeds\", \"seed0\", \"n_c\", optional \"id\" echoed back;
     {\"cmd\":\"ping\"}, {\"cmd\":\"stats\"} and {\"cmd\":\"shutdown\"}
     control the loop — stats returns a telemetry snapshot: requests,
     cache hits/misses, errors, reply-time histogram, plus the sched/
     pool counters accumulated by the served runs.
     Replies carry mean/std/sem/n and \"cache\":\"hit|miss\"; identical
     (scenario, n_c, seed0, seeds) requests are served from cache.)

CONTROL OPTIONS (control command):
    --severities <a,b,..>    channel specs to sweep (default: ideal +
                             three fading severities of increasing depth)
    --policies <a,b,..>      policies to compare at the per-channel
                             recommended ñ_c [default:
                             fixed,warmup:16:2,control,control:est=ema]

OPTIMIZE OPTIONS (optimize command):
    --mc <seeds>             validate the channel-aware recommendation by
                             Monte-Carlo: the measured optimality gap must
                             stay under the Corollary-1 bound at 99%
                             bootstrap confidence (axes come from the
                             scenario.* config keys; exit 1 on violation)

BENCH OPTIONS (bench command):
    --json <path>            write the machine-readable report
                             [default: BENCH_sweep.json]
    --fast <0|1>             CI-scale preset for n/seeds/n_o (also:
                             EDGEPIPE_BENCH_FAST=1; overrides those
                             config keys — --points/threads still apply)
    --points <k>             block-size grid resolution
    --metrics-json <file>    dump the telemetry snapshot the benched
                             sweeps accumulated (scheduler/pool
                             counters) as JSON after the run
    (at full scale, dataset size / seeds / threads come from the usual
     config keys, e.g. --set data.n_raw=2000 --set sweep.seeds=4
     --set sweep.threads=8)

ENVIRONMENT:
    EDGEPIPE_LANES=<n>       Monte-Carlo lane count for the batched-seed
                             sweep engine, snapped to 1|4|8|16
                             [default: 8]; 1 = scalar engine. Per-seed
                             results are bit-identical at every setting.
    EDGEPIPE_THREADS=<n>     sweep worker threads (0/unset = auto)
    EDGEPIPE_BENCH_FAST=1    CI-scale bench preset (see --fast)
    EDGEPIPE_BENCH_MIN_SPEEDUP=<x>  hard regression bar for
                             `cargo bench --bench bench_sweep`

EXAMPLES:
    edgepipe optimize --set protocol.n_o=100
    edgepipe train --set protocol.n_c=437 --set train.seed=3
    edgepipe fig3 --out out/fig3
    edgepipe fig4 --set protocol.n_o=100 --set sweep.seeds=10
    edgepipe scenario --preset all --set sweep.seeds=20
    edgepipe scenario --channels ideal,erasure:0.1,fading:0.05:0.25:0.6 \\
        --policies fixed,warmup:16:2 --devices 1,4 --workloads ridge,logistic
    edgepipe scenario --devices 4 --device-sched greedy \\
        --device-channels ideal,erasure:0.2,fading:0.05:0.25:0.6,rate:0.5 \\
        --device-skew 0.5
    edgepipe scenario --preset adaptive_fading --set sweep.seeds=24
    edgepipe scenario --channels erasure:0.1 --policies control:est=ema \\
        --faults off,outage:2000:500+retry:4:3,drop:0:5000+retry:4:2:2
    edgepipe scenario --preset hetero3_dropout_control --set sweep.seeds=24
    edgepipe scenario --preset all --set sweep.seeds=1000 \\
        --stream out/sweep.jsonl          # journaled, constant memory
    edgepipe scenario --preset all --set sweep.seeds=1000 \\
        --resume out/sweep.jsonl          # pick up where a kill stopped
    edgepipe scenario --preset all --stream out/sweep.jsonl \\
        --progress 1 --metrics-json out/metrics.json
    edgepipe serve --addr 127.0.0.1:4088 --set protocol.n_c=437
    edgepipe control --set sweep.seeds=24
    edgepipe bench --json BENCH_sweep.json
";

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub config_path: Option<String>,
    pub overrides: Vec<(String, String)>,
    pub out_dir: String,
    pub backend: String,
    pub quiet: bool,
    /// Any remaining --key value flags (command-specific).
    pub extra: BTreeMap<String, String>,
}

impl Args {
    /// Parse argv (excluding argv[0]).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args {
            out_dir: "out".to_string(),
            backend: "native".to_string(),
            ..Default::default()
        };
        let mut it = argv.iter().peekable();
        match it.next() {
            Some(cmd) if !cmd.starts_with('-') => {
                args.command = cmd.clone();
            }
            _ => {
                args.command = "help".to_string();
                return Ok(args);
            }
        }
        while let Some(flag) = it.next() {
            match flag.as_str() {
                "--config" => {
                    args.config_path = Some(expect_value(&mut it, flag)?)
                }
                "--set" => {
                    let kv = expect_value(&mut it, flag)?;
                    let (k, v) = kv.split_once('=').ok_or_else(|| {
                        anyhow::anyhow!("--set needs key=value, got '{kv}'")
                    })?;
                    args.overrides.push((k.to_string(), v.to_string()));
                }
                "--out" => args.out_dir = expect_value(&mut it, flag)?,
                "--backend" => args.backend = expect_value(&mut it, flag)?,
                "--quiet" => args.quiet = true,
                "--help" | "-h" => {
                    args.command = "help".to_string();
                }
                other if other.starts_with("--") => {
                    let key = other.trim_start_matches("--").to_string();
                    let value = expect_value(&mut it, flag)?;
                    args.extra.insert(key, value);
                }
                other => bail!("unexpected argument '{other}'"),
            }
        }
        if args.backend.as_str() != "native" {
            bail!("--backend must be 'native'");
        }
        Ok(args)
    }

    /// Command-specific flag with default.
    pub fn extra_or(&self, key: &str, default: &str) -> String {
        self.extra.get(key).cloned().unwrap_or_else(|| default.into())
    }
}

fn expect_value(
    it: &mut std::iter::Peekable<std::slice::Iter<String>>,
    flag: &str,
) -> Result<String> {
    it.next()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("flag {flag} needs a value"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Result<Args> {
        Args::parse(&tokens.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse(&[
            "train",
            "--set",
            "protocol.n_c=437",
            "--set",
            "train.seed=3",
            "--backend",
            "native",
            "--out",
            "results",
        ])
        .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.overrides.len(), 2);
        assert_eq!(a.overrides[0], ("protocol.n_c".into(), "437".into()));
        assert_eq!(a.backend, "native");
        assert_eq!(a.out_dir, "results");
    }

    #[test]
    fn missing_command_is_help() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn bad_backend_rejected() {
        assert!(parse(&["train", "--backend", "gpu"]).is_err());
    }

    #[test]
    fn bad_set_rejected() {
        assert!(parse(&["train", "--set", "novalue"]).is_err());
    }

    #[test]
    fn extra_flags_collected() {
        let a = parse(&["fig4", "--n-o", "100"]).unwrap();
        assert_eq!(a.extra_or("n-o", "10"), "100");
        assert_eq!(a.extra_or("missing", "42"), "42");
    }
}
