//! Command-line interface: argument parsing (no clap offline) and the
//! subcommand implementations behind the `edgepipe` binary.

pub mod args;
pub mod commands;

pub use args::{Args, HELP};
pub use commands::dispatch;
