//! Block-timing arithmetic for the protocol of Fig. 2.
//!
//! Given `(N, T, n_c, n_o, τ_p)` this module answers every scheduling
//! question the coordinator, the bound, and the benches ask: how many
//! blocks fit, how many samples each delivers, how many SGD updates run
//! during each block, and whether the run is in case (a) (`T ≤
//! B_d(n_c+n_o)`, dataset only partially delivered) or case (b) (full
//! dataset delivered, tail block `B_l` of pure computation).

/// Which side of the `T = B_d (n_c + n_o)` boundary a configuration is on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimelineCase {
    /// Paper Fig. 2(a): time runs out before the dataset is delivered.
    Partial,
    /// Paper Fig. 2(b): full dataset delivered; a tail block `B_l` of
    /// duration `τ_l = T − B_d(n_c+n_o)` remains for pure computation.
    Full,
}

/// Resolved timeline for one protocol configuration.
#[derive(Clone, Debug)]
pub struct Timeline {
    /// Training-set size N.
    pub n: usize,
    /// Deadline T in normalized units.
    pub t_budget: f64,
    /// Payload samples per block n_c.
    pub n_c: usize,
    /// Per-packet overhead n_o (normalized units).
    pub n_o: f64,
    /// Time per SGD update τ_p.
    pub tau_p: f64,
    /// Which case of Fig. 2 this configuration falls in.
    pub case: TimelineCase,
    /// Number of transmission blocks that BEGIN within T (capped at B_d).
    pub blocks: usize,
    /// B_d = ceil(N / n_c): blocks needed to deliver the whole dataset.
    pub b_d: usize,
    /// Duration of one full block, n_c + n_o.
    pub block_len: f64,
    /// SGD updates per full block, n_p = floor((n_c + n_o)/τ_p).
    pub n_p: usize,
    /// Tail-block updates n_l (case Full only; 0 otherwise).
    pub n_l: usize,
}

impl Timeline {
    /// Resolve the timeline for a configuration.
    ///
    /// Panics if any parameter is non-positive where positivity is
    /// required. `n_c` is clamped to `N` by the caller if needed.
    pub fn resolve(
        n: usize,
        t_budget: f64,
        n_c: usize,
        n_o: f64,
        tau_p: f64,
    ) -> Timeline {
        assert!(n > 0, "empty dataset");
        assert!(n_c > 0 && n_c <= n, "n_c must be in [1, N]");
        assert!(n_o >= 0.0, "negative overhead");
        assert!(tau_p > 0.0, "non-positive compute time");
        assert!(t_budget > 0.0, "non-positive deadline");

        let block_len = n_c as f64 + n_o;
        // B_d blocks suffice to deliver the dataset; the last block may
        // carry fewer than n_c samples when n_c does not divide N.
        let b_d = n.div_ceil(n_c);
        let full_delivery_time = b_d as f64 * block_len;
        let case = if t_budget > full_delivery_time {
            TimelineCase::Full
        } else {
            TimelineCase::Partial
        };
        let blocks = match case {
            TimelineCase::Full => b_d,
            // number of whole blocks that fit in T
            TimelineCase::Partial => (t_budget / block_len).floor() as usize,
        };
        let n_p = (block_len / tau_p).floor() as usize;
        let n_l = match case {
            TimelineCase::Full => {
                ((t_budget - full_delivery_time) / tau_p).floor() as usize
            }
            TimelineCase::Partial => 0,
        };
        Timeline {
            n,
            t_budget,
            n_c,
            n_o,
            tau_p,
            case,
            blocks,
            b_d,
            block_len,
            n_p,
            n_l,
        }
    }

    /// Samples delivered by the start of block `b` (1-indexed), i.e. the
    /// size of the store X̃_b the edge node trains on during block `b`.
    pub fn store_size_at_block(&self, b: usize) -> usize {
        assert!(b >= 1);
        ((b - 1) * self.n_c).min(self.n)
    }

    /// Number of samples the device puts in block `b` (1-indexed): `n_c`
    /// except possibly the final delivery block.
    pub fn payload_of_block(&self, b: usize) -> usize {
        assert!(b >= 1 && b <= self.b_d);
        let sent_before = (b - 1) * self.n_c;
        self.n_c.min(self.n - sent_before)
    }

    /// Fraction of the dataset delivered at the deadline (paper: `(B−1)/B_d`
    /// in case Partial — the block in flight at T does not count).
    pub fn delivered_fraction(&self) -> f64 {
        match self.case {
            TimelineCase::Full => 1.0,
            TimelineCase::Partial => {
                let usable = self.blocks.saturating_sub(1);
                (usable as f64 * self.n_c as f64 / self.n as f64).min(1.0)
            }
        }
    }

    /// Total SGD updates the edge node performs within T. Updates can only
    /// start once the first block has arrived (store is empty during block
    /// 1), so blocks 2..=blocks contribute n_p each, plus the tail n_l.
    pub fn total_updates(&self) -> usize {
        let training_blocks = self.blocks.saturating_sub(1);
        training_blocks * self.n_p + self.n_l
    }

    /// The boundary value of `n_c` at which `T = B_d(n_c + n_o)` for the
    /// given `(n, t, n_o)` — the smallest payload that still delivers the
    /// whole dataset in time (paper Fig. 3 dots). Returns None if even
    /// `n_c = N` cannot deliver in time.
    pub fn full_delivery_boundary(
        n: usize,
        t_budget: f64,
        n_o: f64,
    ) -> Option<usize> {
        (1..=n).find(|&nc| {
            let b_d = n.div_ceil(nc);
            b_d as f64 * (nc as f64 + n_o) < t_budget
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_split_matches_paper_inequality() {
        // N=100, n_c=10 -> B_d=10, block_len=12 (n_o=2), full delivery at 120
        let tl = Timeline::resolve(100, 119.0, 10, 2.0, 1.0);
        assert_eq!(tl.case, TimelineCase::Partial);
        let tl = Timeline::resolve(100, 121.0, 10, 2.0, 1.0);
        assert_eq!(tl.case, TimelineCase::Full);
        assert_eq!(tl.blocks, 10);
        assert_eq!(tl.n_l, 1); // (121-120)/1
    }

    #[test]
    fn partial_block_count() {
        let tl = Timeline::resolve(100, 50.0, 10, 2.0, 1.0);
        assert_eq!(tl.blocks, 4); // floor(50/12)
        assert_eq!(tl.n_p, 12);
        assert_eq!(tl.store_size_at_block(1), 0);
        assert_eq!(tl.store_size_at_block(4), 30);
        assert!((tl.delivered_fraction() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn ragged_final_block_payload() {
        // N=25, n_c=10 -> B_d=3, last block carries 5
        let tl = Timeline::resolve(25, 1000.0, 10, 0.0, 1.0);
        assert_eq!(tl.b_d, 3);
        assert_eq!(tl.payload_of_block(1), 10);
        assert_eq!(tl.payload_of_block(3), 5);
        assert_eq!(tl.store_size_at_block(4), 25);
    }

    #[test]
    fn updates_accounting() {
        let tl = Timeline::resolve(100, 121.0, 10, 2.0, 1.0);
        // 10 blocks, first has empty store: 9 * 12 + 1 tail
        assert_eq!(tl.total_updates(), 9 * 12 + 1);
    }

    #[test]
    fn tau_p_scales_updates() {
        let tl = Timeline::resolve(100, 50.0, 10, 2.0, 0.5);
        assert_eq!(tl.n_p, 24);
        let tl = Timeline::resolve(100, 50.0, 10, 2.0, 3.0);
        assert_eq!(tl.n_p, 4);
    }

    #[test]
    fn boundary_is_monotone_in_overhead() {
        let b1 = Timeline::full_delivery_boundary(18576, 27864.0, 10.0);
        let b2 = Timeline::full_delivery_boundary(18576, 27864.0, 100.0);
        let (b1, b2) = (b1.unwrap(), b2.unwrap());
        assert!(b2 > b1, "more overhead needs bigger blocks: {b1} vs {b2}");
        // and at the boundary the inequality actually flips
        let tl = Timeline::resolve(18576, 27864.0, b2, 100.0, 1.0);
        assert_eq!(tl.case, TimelineCase::Full);
        let tl = Timeline::resolve(18576, 27864.0, b2 - 1, 100.0, 1.0);
        assert_eq!(tl.case, TimelineCase::Partial);
    }

    #[test]
    fn n_c_equals_n_is_transmit_everything_first() {
        let tl = Timeline::resolve(1000, 2000.0, 1000, 50.0, 1.0);
        assert_eq!(tl.b_d, 1);
        assert_eq!(tl.case, TimelineCase::Full);
        // all updates happen in the tail
        assert_eq!(tl.total_updates(), tl.n_l);
        assert_eq!(tl.n_l, 950);
    }
}
