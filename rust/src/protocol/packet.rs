//! Packet framing for the device → edge link.
//!
//! A data packet carries `payload` fresh samples plus the fixed overhead
//! `n_o` (pilots / meta-data, paper Sec. 2). The coordinator's channel
//! moves `Packet`s; the erasure-channel extension re-transmits them.

/// What a packet contains.
#[derive(Clone, Debug, PartialEq)]
pub enum PacketKind {
    /// A data block: sample indices (into the device's dataset) plus the
    /// gathered rows and labels, ready for the edge store.
    Data {
        /// Indices of the transmitted samples in the device's dataset.
        indices: Vec<u32>,
        /// Row-major covariates, `indices.len() * d`.
        x: Vec<f32>,
        /// Labels.
        y: Vec<f32>,
    },
    /// End-of-stream marker: the device has nothing left to send.
    Fin,
}

/// A framed packet with its timing metadata (normalized units).
#[derive(Clone, Debug)]
pub struct Packet {
    /// 1-indexed block number.
    pub block: usize,
    /// Time the packet occupies the channel: payload + n_o.
    pub duration: f64,
    /// Transmission start time (normalized, from run start).
    pub sent_at: f64,
    /// Contents.
    pub kind: PacketKind,
}

impl Packet {
    /// Build a data packet for block `block` starting at `sent_at`.
    pub fn data(
        block: usize,
        sent_at: f64,
        n_o: f64,
        indices: Vec<u32>,
        x: Vec<f32>,
        y: Vec<f32>,
        d: usize,
    ) -> Packet {
        assert_eq!(x.len(), indices.len() * d, "packet payload shape");
        assert_eq!(y.len(), indices.len(), "packet label shape");
        Packet {
            block,
            duration: indices.len() as f64 + n_o,
            sent_at,
            kind: PacketKind::Data { indices, x, y },
        }
    }

    /// Build the end-of-stream marker (zero duration: nothing is sent).
    pub fn fin(block: usize, sent_at: f64) -> Packet {
        Packet { block, duration: 0.0, sent_at, kind: PacketKind::Fin }
    }

    /// Number of payload samples (0 for Fin).
    pub fn payload_len(&self) -> usize {
        match &self.kind {
            PacketKind::Data { indices, .. } => indices.len(),
            PacketKind::Fin => 0,
        }
    }

    /// Arrival time at the edge node (error-free channel).
    pub fn arrives_at(&self) -> f64 {
        self.sent_at + self.duration
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_packet_timing() {
        let p = Packet::data(
            3,
            10.0,
            2.5,
            vec![0, 5, 9],
            vec![0.0; 6],
            vec![0.0; 3],
            2,
        );
        assert_eq!(p.payload_len(), 3);
        assert!((p.duration - 5.5).abs() < 1e-12);
        assert!((p.arrives_at() - 15.5).abs() < 1e-12);
    }

    #[test]
    fn fin_packet() {
        let p = Packet::fin(7, 42.0);
        assert_eq!(p.payload_len(), 0);
        assert_eq!(p.arrives_at(), 42.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Packet::data(1, 0.0, 1.0, vec![0, 1], vec![0.0; 3], vec![0.0; 2], 2);
    }
}
