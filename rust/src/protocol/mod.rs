//! The paper's transmission/training protocol in normalized time units.
//!
//! All times are normalized to the transmission time of ONE sample
//! (paper Sec. 2). A block carries `n_c` fresh samples plus a fixed
//! overhead `n_o`, so it occupies the channel for `n_c + n_o` units; while
//! it is on the wire the edge node performs `n_p = (n_c + n_o)/τ_p` SGD
//! updates on previously received samples.

pub mod packet;
pub mod timeline;

pub use packet::{Packet, PacketKind};
pub use timeline::{Timeline, TimelineCase};
