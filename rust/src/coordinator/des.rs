//! The paper's reference protocol run (Fig. 2) — now a thin adapter over
//! the generic [`scheduler`](super::scheduler): one device, fixed `n_c`,
//! pipelined overlap.
//!
//! Time is normalized (1 unit = one sample's transmission). The device
//! serializes blocks on the channel; the edge trainer consumes compute
//! time in `τ_p` quanta whenever its store is non-empty. An update that
//! would finish after a block's arrival instant belongs to the next
//! window (the paper's `n_p = (n_c+n_o)/τ_p` per-block update count falls
//! out exactly for integer block lengths).
//!
//! This module also owns [`DesConfig`] (the run configuration every
//! variant shares), the fixed RNG stream ids, and the standalone
//! [`DeviceTransmitter`] used by the threaded pipeline's device thread
//! and the perf benches.

use anyhow::Result;

use crate::channel::Channel;
use crate::data::Dataset;
use crate::util::rng::Pcg32;

use super::executor::BlockExecutor;
use super::run::RunResult;
use super::scheduler::{
    run_schedule, FixedPolicy, OverlapMode, SingleDeviceSource,
};

/// Full configuration of one coordinator run.
#[derive(Clone, Debug)]
pub struct DesConfig {
    /// Block payload size n_c (samples per packet).
    pub n_c: usize,
    /// Per-packet overhead n_o.
    pub n_o: f64,
    /// Time per SGD update τ_p.
    pub tau_p: f64,
    /// Deadline T.
    pub t_budget: f64,
    /// Learning rate α.
    pub alpha: f64,
    /// Ridge regularization λ (coefficient λ/N applied internally).
    pub lambda: f64,
    /// Gaussian init std for w (paper: 1.0).
    pub init_std: f64,
    /// Master seed; all internal streams derive from it.
    pub seed: u64,
    /// Record the training loss every `loss_every` updates
    /// (0 = no intra-block records).
    pub loss_every: usize,
    /// Record the training loss at every block arrival (Fig. 4 curves).
    /// Disable for wide sweeps where only the final loss matters — the
    /// full-dataset evaluation at thousands of block boundaries would
    /// otherwise dominate the sweep cost.
    pub record_blocks: bool,
    /// Edge store capacity (None = unbounded, the paper's protocol).
    pub store_capacity: Option<usize>,
    /// Collect per-block snapshots for the Theorem-1 evaluation.
    pub collect_snapshots: bool,
    /// Max events to record (0 disables the event log).
    pub event_capacity: usize,
    /// Which per-sample loss the run trains/reports (the executor must
    /// match; `ScenarioRunner` keeps the two in sync).
    pub workload: crate::model::Workload,
    /// Protocol hardening + trainer preemption (timeout/retry/eviction
    /// knobs and compute-preemption windows). The all-default value is
    /// the paper's original protocol: unbounded ARQ, no timeouts, no
    /// eviction, never preempted — and keeps every fault-free path
    /// bit-identical. `ScenarioRunner` threads the knobs in from a
    /// channel spec's `fault=` suffix (`retry:`/`preempt:` clauses).
    pub faults: crate::channel::FaultTolerance,
}

impl DesConfig {
    /// Paper-experiment defaults for a given block size and overhead.
    pub fn paper(n_c: usize, n_o: f64, t_budget: f64, seed: u64) -> Self {
        DesConfig {
            n_c,
            n_o,
            tau_p: 1.0,
            t_budget,
            alpha: 1e-4,
            lambda: 0.05,
            init_std: 1.0,
            seed,
            loss_every: 0,
            record_blocks: true,
            store_capacity: None,
            collect_snapshots: false,
            event_capacity: 0,
            workload: crate::model::Workload::Ridge,
            faults: crate::channel::FaultTolerance::default(),
        }
    }
}

/// RNG stream ids (fixed so every coordinator path agrees).
pub(crate) const STREAM_INIT: u64 = 1;
pub(crate) const STREAM_DEVICE: u64 = 2;
pub(crate) const STREAM_EDGE: u64 = 3;
pub(crate) const STREAM_CHANNEL: u64 = 4;
pub(crate) const STREAM_EVICT: u64 = 5;

/// The device half: selects untransmitted samples uniformly without
/// replacement (paper Sec. 2) and frames them into blocks. Public so the
/// perf benches can measure it in isolation; the threaded pipeline's
/// device thread drives it directly. Its RNG stream matches
/// [`SingleDeviceSource`] draw-for-draw.
pub struct DeviceTransmitter<'a> {
    ds: &'a Dataset,
    remaining: Vec<u32>,
    rng: Pcg32,
    n_c: usize,
}

impl<'a> DeviceTransmitter<'a> {
    pub fn new(ds: &'a Dataset, n_c: usize, seed: u64) -> Self {
        DeviceTransmitter {
            ds,
            remaining: (0..ds.n as u32).collect(),
            rng: Pcg32::new(seed, STREAM_DEVICE),
            n_c: n_c.max(1).min(ds.n),
        }
    }

    pub fn exhausted(&self) -> bool {
        self.remaining.is_empty()
    }

    /// Draw the next block: uniform without replacement from the
    /// untransmitted set, gathered into contiguous payload buffers.
    /// Returns None when the dataset is fully transmitted.
    pub fn next_block(&mut self) -> Option<(Vec<u32>, Vec<f32>, Vec<f32>)> {
        if self.remaining.is_empty() {
            return None;
        }
        let k = self.n_c.min(self.remaining.len());
        let len = self.remaining.len();
        // partial Fisher–Yates into the tail: O(k) per block
        for i in 0..k {
            let j = self.rng.gen_range((len - i) as u64) as usize;
            self.remaining.swap(j, len - 1 - i);
        }
        let chosen: Vec<u32> = self.remaining.split_off(len - k);
        let d = self.ds.d;
        let mut x = Vec::with_capacity(k * d);
        let mut y = Vec::with_capacity(k);
        for &i in &chosen {
            x.extend_from_slice(self.ds.row(i as usize));
            y.push(self.ds.label(i as usize));
        }
        Some((chosen, x, y))
    }
}

/// Run the protocol as a discrete-event simulation — the reference
/// semantics and the Monte-Carlo fast path. Equivalent to
/// [`run_schedule`] under a single device, the fixed-`n_c` policy and
/// pipelined overlap (which is exactly how it is implemented).
pub fn run_des(
    ds: &Dataset,
    cfg: &DesConfig,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    assert!(cfg.n_c >= 1, "n_c must be >= 1");
    let mut source = SingleDeviceSource::new(ds, cfg.seed);
    let mut policy = FixedPolicy(cfg.n_c.min(ds.n));
    run_schedule(
        ds,
        cfg,
        &mut source,
        &mut policy,
        OverlapMode::Pipelined,
        channel,
        exec,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;
    use crate::protocol::{Timeline, TimelineCase};

    fn small_ds() -> Dataset {
        synth_calhousing(&SynthSpec { n: 1000, ..Default::default() })
    }

    fn native_exec(ds: &Dataset, alpha: f64, lambda: f64) -> NativeExecutor {
        NativeExecutor::new(RidgeModel::new(ds.d, lambda, ds.n), alpha)
    }

    #[test]
    fn update_count_matches_timeline_math() {
        let ds = small_ds();
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(100, 10.0, 2000.0, 7)
        };
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        let tl =
            Timeline::resolve(ds.n, cfg.t_budget, cfg.n_c, cfg.n_o, cfg.tau_p);
        assert_eq!(res.updates, tl.total_updates(), "DES vs closed form");
        assert_eq!(res.samples_delivered, ds.n);
        assert_eq!(res.case, TimelineCase::Full);
        assert_eq!(res.blocks_sent, tl.b_d);
    }

    #[test]
    fn partial_case_delivers_fraction() {
        let ds = small_ds();
        // block = 110, B_d = 10 -> full delivery at 1100 > T = 500
        let cfg = DesConfig::paper(100, 10.0, 500.0, 3);
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        assert_eq!(res.case, TimelineCase::Partial);
        // floor(500/110) = 4 blocks fully delivered
        assert_eq!(res.blocks_delivered, 4);
        assert_eq!(res.samples_delivered, 400);
        // a 5th block was sent but missed the deadline
        assert_eq!(res.blocks_sent, 5);
    }

    #[test]
    fn loss_decreases_substantially() {
        let ds = small_ds();
        let cfg = DesConfig {
            alpha: 2e-3,
            ..DesConfig::paper(50, 5.0, 3000.0, 11)
        };
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        let first = res.curve.first().unwrap().1;
        assert!(
            res.final_loss < 0.5 * first,
            "loss {first} -> {}",
            res.final_loss
        );
        // curve times are monotone
        for pair in res.curve.windows(2) {
            assert!(pair[1].0 >= pair[0].0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = small_ds();
        let cfg = DesConfig::paper(64, 8.0, 1500.0, 21);
        let mut e1 = native_exec(&ds, cfg.alpha, cfg.lambda);
        let mut e2 = native_exec(&ds, cfg.alpha, cfg.lambda);
        let r1 = run_des(&ds, &cfg, &mut IdealChannel, &mut e1).unwrap();
        let r2 = run_des(&ds, &cfg, &mut IdealChannel, &mut e2).unwrap();
        assert_eq!(r1.final_w, r2.final_w);
        assert_eq!(r1.curve, r2.curve);
        let cfg3 = DesConfig { seed: 22, ..cfg };
        let mut e3 = native_exec(&ds, cfg3.alpha, cfg3.lambda);
        let r3 = run_des(&ds, &cfg3, &mut IdealChannel, &mut e3).unwrap();
        assert_ne!(r1.final_w, r3.final_w);
    }

    #[test]
    fn no_sample_transmitted_twice() {
        let ds = small_ds();
        let mut device = DeviceTransmitter::new(&ds, 37, 5);
        let mut seen = vec![false; ds.n];
        while let Some((idx, _, _)) = device.next_block() {
            for i in idx {
                assert!(!seen[i as usize], "sample {i} sent twice");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "all samples eventually sent");
    }

    #[test]
    fn snapshots_collected_when_enabled() {
        let ds = small_ds();
        let cfg = DesConfig {
            collect_snapshots: true,
            ..DesConfig::paper(200, 10.0, 3000.0, 2)
        };
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        assert_eq!(res.snapshots.len(), res.blocks_delivered);
        for snap in &res.snapshots {
            assert_eq!(snap.w_end.len(), ds.d);
            assert_eq!(snap.x.len(), snap.y.len() * ds.d);
        }
    }

    #[test]
    fn loss_every_records_dense_curve() {
        let ds = small_ds();
        let cfg = DesConfig {
            loss_every: 100,
            ..DesConfig::paper(100, 10.0, 2000.0, 8)
        };
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        // ~ updates/100 interior points plus block boundaries
        assert!(
            res.curve.len() > res.updates / 100,
            "curve has {} points for {} updates",
            res.curve.len(),
            res.updates
        );
    }
}
