//! Discrete-event simulation of the pipelined protocol (paper Fig. 2) —
//! the coordinator's fast path, and the reference semantics the threaded
//! pipeline must match bit-for-bit.
//!
//! Time is normalized (1 unit = one sample's transmission). The device
//! serializes blocks on the channel; the edge trainer consumes compute
//! time in `τ_p` quanta whenever its store is non-empty. An update that
//! would finish after a block's arrival instant belongs to the next
//! window (the paper's `n_p = (n_c+n_o)/τ_p` per-block update count falls
//! out exactly for integer block lengths).

use anyhow::Result;

use crate::channel::Channel;
use crate::data::Dataset;
use crate::edge::SampleStore;
use crate::protocol::TimelineCase;
use crate::util::rng::Pcg32;

use super::events::{EventKind, EventLog};
use super::executor::BlockExecutor;
use super::run::{BlockSnapshot, RunResult};

/// Full configuration of one coordinator run.
#[derive(Clone, Debug)]
pub struct DesConfig {
    /// Block payload size n_c (samples per packet).
    pub n_c: usize,
    /// Per-packet overhead n_o.
    pub n_o: f64,
    /// Time per SGD update τ_p.
    pub tau_p: f64,
    /// Deadline T.
    pub t_budget: f64,
    /// Learning rate α.
    pub alpha: f64,
    /// Ridge regularization λ (coefficient λ/N applied internally).
    pub lambda: f64,
    /// Gaussian init std for w (paper: 1.0).
    pub init_std: f64,
    /// Master seed; all internal streams derive from it.
    pub seed: u64,
    /// Record the training loss every `loss_every` updates
    /// (0 = no intra-block records).
    pub loss_every: usize,
    /// Record the training loss at every block arrival (Fig. 4 curves).
    /// Disable for wide sweeps where only the final loss matters — the
    /// full-dataset evaluation at thousands of block boundaries would
    /// otherwise dominate the sweep cost.
    pub record_blocks: bool,
    /// Edge store capacity (None = unbounded, the paper's protocol).
    pub store_capacity: Option<usize>,
    /// Collect per-block snapshots for the Theorem-1 evaluation.
    pub collect_snapshots: bool,
    /// Max events to record (0 disables the event log).
    pub event_capacity: usize,
}

impl DesConfig {
    /// Paper-experiment defaults for a given block size and overhead.
    pub fn paper(n_c: usize, n_o: f64, t_budget: f64, seed: u64) -> Self {
        DesConfig {
            n_c,
            n_o,
            tau_p: 1.0,
            t_budget,
            alpha: 1e-4,
            lambda: 0.05,
            init_std: 1.0,
            seed,
            loss_every: 0,
            record_blocks: true,
            store_capacity: None,
            collect_snapshots: false,
            event_capacity: 0,
        }
    }
}

/// RNG stream ids (fixed so DES and threaded pipeline agree).
pub(crate) const STREAM_INIT: u64 = 1;
pub(crate) const STREAM_DEVICE: u64 = 2;
pub(crate) const STREAM_EDGE: u64 = 3;
pub(crate) const STREAM_CHANNEL: u64 = 4;
pub(crate) const STREAM_EVICT: u64 = 5;

/// The edge node's training half: owns `w`, the sample store, the compute
/// clock, loss recording and snapshot collection. Shared verbatim by the
/// DES and the threaded pipeline so their semantics cannot diverge.
pub(crate) struct EdgeTrainer<'a> {
    ds: &'a Dataset,
    pub w: Vec<f64>,
    pub store: SampleStore,
    /// Next update would start at this time.
    cursor: f64,
    tau_p: f64,
    t_budget: f64,
    reg: f64,
    rng: Pcg32,
    evict_rng: Pcg32,
    idx_buf: Vec<u32>,
    pub updates: usize,
    pub curve: Vec<(f64, f64)>,
    loss_every: usize,
    since_record: usize,
    pub snapshots: Vec<BlockSnapshot>,
    collect_snapshots: bool,
    record_blocks: bool,
}

impl<'a> EdgeTrainer<'a> {
    pub fn new(ds: &'a Dataset, cfg: &DesConfig) -> EdgeTrainer<'a> {
        let mut init_rng = Pcg32::new(cfg.seed, STREAM_INIT);
        let w: Vec<f64> = (0..ds.d)
            .map(|_| cfg.init_std * init_rng.next_gaussian())
            .collect();
        let store = match cfg.store_capacity {
            Some(cap) => SampleStore::with_capacity(ds.d, cap),
            None => SampleStore::new(ds.d),
        };
        let reg = cfg.lambda / ds.n as f64;
        let mut trainer = EdgeTrainer {
            ds,
            w,
            store,
            cursor: 0.0,
            tau_p: cfg.tau_p,
            t_budget: cfg.t_budget,
            reg,
            rng: Pcg32::new(cfg.seed, STREAM_EDGE),
            evict_rng: Pcg32::new(cfg.seed, STREAM_EVICT),
            idx_buf: Vec::with_capacity(4096),
            updates: 0,
            curve: Vec::new(),
            loss_every: cfg.loss_every,
            since_record: 0,
            snapshots: Vec::new(),
            collect_snapshots: cfg.collect_snapshots,
            record_blocks: cfg.record_blocks,
        };
        trainer.record_loss(0.0);
        trainer
    }

    /// Training loss over the FULL dataset (paper Fig. 4's y-axis).
    pub fn full_loss(&self) -> f64 {
        self.ds.ridge_loss(&self.w, self.reg)
    }

    fn record_loss(&mut self, t: f64) {
        let loss = self.full_loss();
        self.curve.push((t, loss));
        self.since_record = 0;
    }

    /// Advance the compute clock to `until`, running SGD updates while
    /// the store is non-empty (paper eq. (2)).
    pub fn advance_to(
        &mut self,
        until: f64,
        exec: &mut dyn BlockExecutor,
        events: &mut EventLog,
    ) -> Result<()> {
        let until = until.min(self.t_budget);
        if self.store.is_empty() {
            self.cursor = self.cursor.max(until);
            return Ok(());
        }
        let n = self.store.len() as u64;
        // updates that *finish* by `until` (tiny epsilon absorbs fp drift
        // in repeated cursor += tau_p)
        let eps = 1e-9 * self.tau_p;
        let mut ran = 0usize;
        while self.cursor + self.tau_p <= until + eps {
            self.idx_buf.push(self.rng.gen_range(n) as u32);
            self.cursor += self.tau_p;
            self.updates += 1;
            self.since_record += 1;
            ran += 1;
            let flush_for_record = self.loss_every > 0
                && self.since_record >= self.loss_every;
            if flush_for_record || self.idx_buf.len() >= 4096 {
                self.flush(exec)?;
                if flush_for_record {
                    self.record_loss(self.cursor);
                }
            }
        }
        self.flush(exec)?;
        if ran > 0 {
            events.push(self.cursor, EventKind::UpdatesRun { count: ran });
        }
        self.cursor = self.cursor.max(until);
        Ok(())
    }

    /// Let time pass WITHOUT computing (the sequential baseline's idle
    /// phase — the edge does nothing while the channel is busy).
    pub fn skip_to(&mut self, until: f64) {
        self.cursor = self.cursor.max(until.min(self.t_budget));
    }

    fn flush(&mut self, exec: &mut dyn BlockExecutor) -> Result<()> {
        if self.idx_buf.is_empty() {
            return Ok(());
        }
        exec.run_block(&mut self.w, self.store.view(), &self.idx_buf)?;
        self.idx_buf.clear();
        Ok(())
    }

    /// Ingest a delivered block at time `t` (records the boundary loss
    /// and, when enabled, the Theorem-1 snapshot of (w, X_b)).
    pub fn ingest_block(&mut self, block: usize, t: f64, x: &[f32], y: &[f32]) {
        if self.collect_snapshots {
            self.snapshots.push(BlockSnapshot {
                block,
                arrived_at: t,
                w_end: self.w.clone(),
                x: x.to_vec(),
                y: y.to_vec(),
            });
        }
        self.store.ingest(x, y, &mut self.evict_rng);
        if self.record_blocks {
            self.record_loss(t);
        }
    }

    /// Finish the run: flush pending updates and record the final loss.
    pub fn finish(
        &mut self,
        exec: &mut dyn BlockExecutor,
    ) -> Result<()> {
        self.flush(exec)?;
        self.record_loss(self.t_budget);
        Ok(())
    }
}

/// The device half: selects untransmitted samples uniformly without
/// replacement (paper Sec. 2) and frames them into blocks. Public so the
/// perf benches can measure it in isolation.
pub struct DeviceTransmitter<'a> {
    ds: &'a Dataset,
    remaining: Vec<u32>,
    rng: Pcg32,
    n_c: usize,
}

impl<'a> DeviceTransmitter<'a> {
    pub fn new(ds: &'a Dataset, n_c: usize, seed: u64) -> Self {
        DeviceTransmitter {
            ds,
            remaining: (0..ds.n as u32).collect(),
            rng: Pcg32::new(seed, STREAM_DEVICE),
            n_c: n_c.max(1).min(ds.n),
        }
    }

    pub fn exhausted(&self) -> bool {
        self.remaining.is_empty()
    }

    /// Draw the next block: uniform without replacement from the
    /// untransmitted set, gathered into contiguous payload buffers.
    /// Returns None when the dataset is fully transmitted.
    pub fn next_block(&mut self) -> Option<(Vec<u32>, Vec<f32>, Vec<f32>)> {
        if self.remaining.is_empty() {
            return None;
        }
        let k = self.n_c.min(self.remaining.len());
        let len = self.remaining.len();
        // partial Fisher–Yates into the tail: O(k) per block
        for i in 0..k {
            let j = self.rng.gen_range((len - i) as u64) as usize;
            self.remaining.swap(j, len - 1 - i);
        }
        let chosen: Vec<u32> = self.remaining.split_off(len - k);
        let d = self.ds.d;
        let mut x = Vec::with_capacity(k * d);
        let mut y = Vec::with_capacity(k);
        for &i in &chosen {
            x.extend_from_slice(self.ds.row(i as usize));
            y.push(self.ds.label(i as usize));
        }
        Some((chosen, x, y))
    }
}

/// Run the protocol as a discrete-event simulation.
pub fn run_des(
    ds: &Dataset,
    cfg: &DesConfig,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    assert!(cfg.n_c >= 1, "n_c must be >= 1");
    let mut events = EventLog::with_capacity(cfg.event_capacity);
    let mut trainer = EdgeTrainer::new(ds, cfg);
    let mut device = DeviceTransmitter::new(ds, cfg.n_c, cfg.seed);
    let mut chan_rng = Pcg32::new(cfg.seed, STREAM_CHANNEL);

    let mut t_send = 0.0f64;
    let mut block = 1usize;
    let mut blocks_sent = 0usize;
    let mut blocks_delivered = 0usize;
    let mut samples_delivered = 0usize;
    let mut retransmissions = 0u64;

    while t_send < cfg.t_budget && !device.exhausted() {
        let (_, x, y) = device.next_block().expect("non-exhausted device");
        let payload = y.len();
        let duration = payload as f64 + cfg.n_o;
        events.push(t_send, EventKind::BlockSent { block, payload });
        blocks_sent += 1;
        let delivery = channel.transmit(t_send, duration, &mut chan_rng);
        retransmissions += (delivery.attempts - 1) as u64;
        let arrival = delivery.arrival;
        if arrival < cfg.t_budget {
            // train through the block's transmission window, then ingest
            trainer.advance_to(arrival, exec, &mut events)?;
            trainer.ingest_block(block, arrival, &x, &y);
            blocks_delivered += 1;
            samples_delivered += payload;
            events.push(
                arrival,
                EventKind::BlockDelivered {
                    block,
                    payload,
                    attempts: delivery.attempts,
                },
            );
        } else {
            trainer.advance_to(cfg.t_budget, exec, &mut events)?;
            events.push(
                cfg.t_budget,
                EventKind::BlockMissedDeadline { block },
            );
        }
        t_send = arrival;
        block += 1;
    }
    // tail: no more transmissions; compute until the deadline (Fig. 2(b))
    trainer.advance_to(cfg.t_budget, exec, &mut events)?;
    trainer.finish(exec)?;

    let case = if samples_delivered >= ds.n {
        TimelineCase::Full
    } else {
        TimelineCase::Partial
    };
    events.push(
        cfg.t_budget,
        EventKind::Finished {
            updates: trainer.updates,
            delivered_samples: samples_delivered,
        },
    );

    let final_loss = trainer.full_loss();
    Ok(RunResult {
        curve: trainer.curve,
        final_loss,
        final_w: trainer.w,
        updates: trainer.updates,
        blocks_sent,
        blocks_delivered,
        samples_delivered,
        retransmissions,
        case,
        snapshots: trainer.snapshots,
        events: events.into_events(),
        backend: exec.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;
    use crate::protocol::Timeline;

    fn small_ds() -> Dataset {
        synth_calhousing(&SynthSpec { n: 1000, ..Default::default() })
    }

    fn native_exec(ds: &Dataset, alpha: f64, lambda: f64) -> NativeExecutor {
        NativeExecutor::new(RidgeModel::new(ds.d, lambda, ds.n), alpha)
    }

    #[test]
    fn update_count_matches_timeline_math() {
        let ds = small_ds();
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(100, 10.0, 2000.0, 7)
        };
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        let tl = Timeline::resolve(ds.n, cfg.t_budget, cfg.n_c, cfg.n_o, cfg.tau_p);
        assert_eq!(res.updates, tl.total_updates(), "DES vs closed form");
        assert_eq!(res.samples_delivered, ds.n);
        assert_eq!(res.case, TimelineCase::Full);
        assert_eq!(res.blocks_sent, tl.b_d);
    }

    #[test]
    fn partial_case_delivers_fraction() {
        let ds = small_ds();
        // block = 110, B_d = 10 -> full delivery at 1100 > T = 500
        let cfg = DesConfig::paper(100, 10.0, 500.0, 3);
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        assert_eq!(res.case, TimelineCase::Partial);
        // floor(500/110) = 4 blocks fully delivered
        assert_eq!(res.blocks_delivered, 4);
        assert_eq!(res.samples_delivered, 400);
        // a 5th block was sent but missed the deadline
        assert_eq!(res.blocks_sent, 5);
    }

    #[test]
    fn loss_decreases_substantially() {
        let ds = small_ds();
        let cfg = DesConfig {
            alpha: 2e-3,
            ..DesConfig::paper(50, 5.0, 3000.0, 11)
        };
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        let first = res.curve.first().unwrap().1;
        assert!(
            res.final_loss < 0.5 * first,
            "loss {first} -> {}",
            res.final_loss
        );
        // curve times are monotone
        for pair in res.curve.windows(2) {
            assert!(pair[1].0 >= pair[0].0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = small_ds();
        let cfg = DesConfig::paper(64, 8.0, 1500.0, 21);
        let mut e1 = native_exec(&ds, cfg.alpha, cfg.lambda);
        let mut e2 = native_exec(&ds, cfg.alpha, cfg.lambda);
        let r1 = run_des(&ds, &cfg, &mut IdealChannel, &mut e1).unwrap();
        let r2 = run_des(&ds, &cfg, &mut IdealChannel, &mut e2).unwrap();
        assert_eq!(r1.final_w, r2.final_w);
        assert_eq!(r1.curve, r2.curve);
        let cfg3 = DesConfig { seed: 22, ..cfg };
        let mut e3 = native_exec(&ds, cfg3.alpha, cfg3.lambda);
        let r3 = run_des(&ds, &cfg3, &mut IdealChannel, &mut e3).unwrap();
        assert_ne!(r1.final_w, r3.final_w);
    }

    #[test]
    fn no_sample_transmitted_twice() {
        let ds = small_ds();
        let mut device = DeviceTransmitter::new(&ds, 37, 5);
        let mut seen = vec![false; ds.n];
        while let Some((idx, _, _)) = device.next_block() {
            for i in idx {
                assert!(!seen[i as usize], "sample {i} sent twice");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "all samples eventually sent");
    }

    #[test]
    fn snapshots_collected_when_enabled() {
        let ds = small_ds();
        let cfg = DesConfig {
            collect_snapshots: true,
            ..DesConfig::paper(200, 10.0, 3000.0, 2)
        };
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        assert_eq!(res.snapshots.len(), res.blocks_delivered);
        for snap in &res.snapshots {
            assert_eq!(snap.w_end.len(), ds.d);
            assert_eq!(snap.x.len(), snap.y.len() * ds.d);
        }
    }

    #[test]
    fn loss_every_records_dense_curve() {
        let ds = small_ds();
        let cfg = DesConfig {
            loss_every: 100,
            ..DesConfig::paper(100, 10.0, 2000.0, 8)
        };
        let mut exec = native_exec(&ds, cfg.alpha, cfg.lambda);
        let res =
            run_des(&ds, &cfg, &mut IdealChannel, &mut exec).unwrap();
        // ~ updates/100 interior points plus block boundaries
        assert!(
            res.curve.len() > res.updates / 100,
            "curve has {} points for {} updates",
            res.curve.len(),
            res.updates
        );
    }
}
