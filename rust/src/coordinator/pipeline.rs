//! The threaded pipelined coordinator: a real device-transmitter thread
//! feeding an edge-trainer loop over a bounded packet channel.
//!
//! This is the systems realization of paper Fig. 2: transmission and
//! computation genuinely overlap (device thread selects + frames + pushes
//! packets while the edge thread trains), with backpressure from the
//! bounded channel. Timing stays in normalized units carried on the
//! packets, and all RNG streams match [`run_des`](super::des::run_des)
//! exactly, so the threaded run is bit-identical to the DES — asserted by
//! `rust/tests/pipeline_parity.rs`.

use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::channel::Channel;
use crate::data::Dataset;
use crate::protocol::TimelineCase;
use crate::util::rng::Pcg32;

use super::des::{DesConfig, DeviceTransmitter, STREAM_CHANNEL};
use super::events::{EventKind, EventLog};
use super::executor::BlockExecutor;
use super::run::RunResult;
use super::trainer::EdgeTrainer;

/// One framed block in flight from device to edge.
struct PipePacket {
    block: usize,
    arrival: f64,
    attempts: u32,
    x: Vec<f32>,
    y: Vec<f32>,
}

/// Device-side summary returned when the transmitter finishes.
struct DeviceSummary {
    blocks_sent: usize,
    retransmissions: u64,
}

/// Depth of the device → edge packet queue (bounded: backpressure).
const PIPE_DEPTH: usize = 4;

/// Run the protocol on the real two-thread pipeline.
pub fn run_pipelined(
    ds: &Dataset,
    cfg: &DesConfig,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    assert!(cfg.n_c >= 1, "n_c must be >= 1");
    let mut events = EventLog::with_capacity(cfg.event_capacity);
    let mut trainer = EdgeTrainer::new(ds, cfg);

    let (tx, rx) = mpsc::sync_channel::<PipePacket>(PIPE_DEPTH);
    let t_budget = cfg.t_budget;
    let n_c = cfg.n_c;
    let n_o = cfg.n_o;
    let seed = cfg.seed;

    let (summary, run) = std::thread::scope(
        |scope| -> (Result<DeviceSummary>, Result<(usize, usize)>) {
            // ---------------- device transmitter thread ----------------
            let device_handle = scope.spawn(move || -> Result<DeviceSummary> {
                let mut device = DeviceTransmitter::new(ds, n_c, seed);
                let mut chan_rng = Pcg32::new(seed, STREAM_CHANNEL);
                let mut t_send = 0.0f64;
                let mut block = 1usize;
                let mut blocks_sent = 0usize;
                let mut retransmissions = 0u64;
                while t_send < t_budget && !device.exhausted() {
                    let (_, x, y) =
                        device.next_block().expect("device not exhausted");
                    let duration = y.len() as f64 + n_o;
                    let delivery =
                        channel.transmit(t_send, duration, &mut chan_rng);
                    blocks_sent += 1;
                    retransmissions += (delivery.attempts - 1) as u64;
                    tx.send(PipePacket {
                        block,
                        arrival: delivery.arrival,
                        attempts: delivery.attempts,
                        x,
                        y,
                    })
                    .map_err(|_| anyhow!("edge hung up"))?;
                    t_send = delivery.arrival;
                    block += 1;
                }
                drop(tx); // FIN: closes the packet stream
                Ok(DeviceSummary { blocks_sent, retransmissions })
            });

            // ---------------- edge trainer (this thread) ----------------
            let edge = (|| -> Result<(usize, usize)> {
                let mut delivered = 0usize;
                let mut missed = 0usize;
                while let Ok(pkt) = rx.recv() {
                    if pkt.arrival < t_budget {
                        trainer.advance_to(pkt.arrival, exec, &mut events)?;
                        trainer.ingest_block(
                            pkt.block,
                            pkt.arrival,
                            &pkt.x,
                            &pkt.y,
                        );
                        delivered += 1;
                        events.push(
                            pkt.arrival,
                            EventKind::BlockDelivered {
                                block: pkt.block,
                                payload: pkt.y.len(),
                                attempts: pkt.attempts,
                            },
                        );
                    } else {
                        trainer.advance_to(t_budget, exec, &mut events)?;
                        missed += 1;
                        events.push(
                            t_budget,
                            EventKind::BlockMissedDeadline { block: pkt.block },
                        );
                    }
                }
                trainer.advance_to(t_budget, exec, &mut events)?;
                trainer.finish(exec)?;
                Ok((delivered, missed))
            })();

            let summary = device_handle
                .join()
                .unwrap_or_else(|_| Err(anyhow!("device thread panicked")));
            (summary, edge)
        },
    );
    let (blocks_delivered, blocks_missed) = run?;
    let summary = summary?;

    let samples_delivered = trainer.ingested();
    let case = if samples_delivered >= ds.n {
        TimelineCase::Full
    } else {
        TimelineCase::Partial
    };
    events.push(
        t_budget,
        EventKind::Finished {
            updates: trainer.updates,
            delivered_samples: samples_delivered,
        },
    );
    let final_loss = trainer.full_loss();
    let updates = trainer.updates;
    let space = trainer.into_space();
    Ok(RunResult {
        curve: space.curve,
        final_loss,
        final_w: space.w,
        updates,
        blocks_sent: summary.blocks_sent,
        blocks_delivered,
        samples_delivered,
        blocks_missed,
        retransmissions: summary.retransmissions,
        // the threaded pipeline is the paper's fault-free path: the ARQ
        // hardening lives in the generic scheduler only
        timeouts: 0,
        blocks_abandoned: 0,
        evictions: 0,
        samples_lost: 0,
        degraded_completion: false,
        case,
        snapshots: space.snapshots,
        events: events.into_events(),
        backend: exec.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::des::run_des;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;

    #[test]
    fn pipeline_is_bit_identical_to_des() {
        let ds = synth_calhousing(&SynthSpec { n: 600, ..Default::default() });
        let cfg = DesConfig {
            loss_every: 50,
            ..DesConfig::paper(64, 8.0, 1200.0, 17)
        };
        let mk =
            || NativeExecutor::new(RidgeModel::new(ds.d, 0.05, ds.n), 1e-4);
        let des =
            run_des(&ds, &cfg, &mut IdealChannel, &mut mk()).unwrap();
        let pipe =
            run_pipelined(&ds, &cfg, &mut IdealChannel, &mut mk()).unwrap();
        assert_eq!(des.final_w, pipe.final_w, "trajectory must match");
        assert_eq!(des.curve, pipe.curve, "loss curve must match");
        assert_eq!(des.updates, pipe.updates);
        assert_eq!(des.samples_delivered, pipe.samples_delivered);
        assert_eq!(des.blocks_sent, pipe.blocks_sent);
    }
}
