//! The per-block SGD executor abstraction.
//!
//! The coordinator samples the SGD indices ξ (so sampling is identical
//! across backends) and hands the executor a block of indices to apply.
//! Implementations: [`NativeExecutor`] (pure Rust, f64) here, and
//! `runtime::PjrtExecutor` (the AOT JAX/Pallas artifact, f32) — their
//! trajectories agree to f32 tolerance (integration-tested).

use anyhow::Result;

use crate::model::{PointModel, RidgeModel};
use crate::sgd::{SgdEngine, StoreView};

/// Applies one pipelined block of single-sample SGD updates (paper
/// eq. (2)) for a pre-sampled index sequence.
///
/// Not `Send`: the PJRT executor wraps non-Send PJRT handles. The
/// threaded pipeline keeps the executor on the edge (caller) thread.
pub trait BlockExecutor {
    /// Apply updates `w ← w − α∇ℓ(w, store[ξ])` for each ξ in `indices`.
    fn run_block(
        &mut self,
        w: &mut Vec<f64>,
        store: StoreView<'_>,
        indices: &[u32],
    ) -> Result<()>;

    /// Backend name for logs/metrics.
    fn name(&self) -> &'static str;
}

/// The native f64 executor (oracle + sweep fast path), generic over the
/// per-sample model so every [`PointModel`] workload (ridge, logistic)
/// runs through the same engine. Defaults to the paper's [`RidgeModel`]
/// so existing call sites and type annotations are unchanged.
pub struct NativeExecutor<M: PointModel = RidgeModel> {
    pub model: M,
    pub engine: SgdEngine,
}

impl<M: PointModel> NativeExecutor<M> {
    pub fn new(model: M, alpha: f64) -> NativeExecutor<M> {
        NativeExecutor { model, engine: SgdEngine::new(alpha) }
    }
}

impl<M: PointModel> BlockExecutor for NativeExecutor<M> {
    fn run_block(
        &mut self,
        w: &mut Vec<f64>,
        store: StoreView<'_>,
        indices: &[u32],
    ) -> Result<()> {
        self.engine.run_indices(&self.model, w, store, indices);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_executor_applies_updates() {
        let x = vec![1.0f32, 0.0, 0.0, 1.0];
        let y = vec![2.0f32, -2.0];
        let store = StoreView::new(&x, &y, 2);
        let model = RidgeModel::new(2, 0.0, 2);
        let mut exec = NativeExecutor::new(model, 0.1);
        let mut w = vec![0.0, 0.0];
        exec.run_block(&mut w, store, &[0, 1, 0, 1]).unwrap();
        assert!(w[0] > 0.0 && w[1] < 0.0, "moved toward labels: {w:?}");
        assert_eq!(exec.name(), "native");
    }

    #[test]
    fn native_executor_is_generic_over_the_workload() {
        use crate::model::LogisticModel;
        // classes on either axis; labels in {0, 1}
        let x = vec![1.0f32, 0.0, -1.0, 0.0];
        let y = vec![1.0f32, 0.0];
        let store = StoreView::new(&x, &y, 2);
        let model = LogisticModel::new(2, 0.0, 2);
        let mut exec = NativeExecutor::new(model, 0.5);
        let mut w = vec![0.0, 0.0];
        exec.run_block(&mut w, store, &[0, 1, 0, 1, 0, 1]).unwrap();
        assert!(w[0] > 0.0, "w must point toward the positive class: {w:?}");
        assert_eq!(exec.name(), "native");
    }
}
