//! The per-block SGD executor abstraction.
//!
//! The coordinator samples the SGD indices ξ (so sampling is identical
//! across executors) and hands the executor a block of indices to
//! apply. Implementations: [`NativeExecutor`] (pure Rust, f64 — the
//! oracle and the sweep fast path) and [`TraceExecutor`] (records the
//! index stream for the batched-seed engine's lane replay instead of
//! executing it).

use anyhow::Result;

use crate::model::{PointModel, RidgeModel};
use crate::sgd::{SgdEngine, StoreView};

/// Applies one pipelined block of single-sample SGD updates (paper
/// eq. (2)) for a pre-sampled index sequence.
///
/// Deliberately not required to be `Send`: the threaded pipeline keeps
/// the executor on the edge (caller) thread.
pub trait BlockExecutor {
    /// Apply updates `w ← w − α∇ℓ(w, store[ξ])` for each ξ in `indices`.
    fn run_block(
        &mut self,
        w: &mut Vec<f64>,
        store: StoreView<'_>,
        indices: &[u32],
    ) -> Result<()>;

    /// Backend name for logs/metrics.
    fn name(&self) -> &'static str;
}

/// The native f64 executor (oracle + sweep fast path), generic over the
/// per-sample model so every [`PointModel`] workload (ridge, logistic)
/// runs through the same engine. Defaults to the paper's [`RidgeModel`]
/// so existing call sites and type annotations are unchanged.
pub struct NativeExecutor<M: PointModel = RidgeModel> {
    pub model: M,
    pub engine: SgdEngine,
}

impl<M: PointModel> NativeExecutor<M> {
    pub fn new(model: M, alpha: f64) -> NativeExecutor<M> {
        NativeExecutor { model, engine: SgdEngine::new(alpha) }
    }
}

impl<M: PointModel> BlockExecutor for NativeExecutor<M> {
    fn run_block(
        &mut self,
        w: &mut Vec<f64>,
        store: StoreView<'_>,
        indices: &[u32],
    ) -> Result<()> {
        self.engine.run_indices(&self.model, w, store, indices);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Records the flushed SGD index stream instead of executing it — the
/// batched-seed engine's trace pass. Never touches `w`, so after a
/// traced run the workspace still holds the run's `w_init`. Indices
/// append in flush order, which IS the scalar engine's execution order;
/// against an append-only (unbounded) store the tape replays to a
/// bit-identical trajectory.
pub struct TraceExecutor<'a> {
    /// Flat index tape, appended in execution order.
    pub tape: &'a mut Vec<u32>,
}

impl<'a> TraceExecutor<'a> {
    pub fn new(tape: &'a mut Vec<u32>) -> TraceExecutor<'a> {
        tape.clear();
        TraceExecutor { tape }
    }
}

impl BlockExecutor for TraceExecutor<'_> {
    fn run_block(
        &mut self,
        _w: &mut Vec<f64>,
        _store: StoreView<'_>,
        indices: &[u32],
    ) -> Result<()> {
        self.tape.extend_from_slice(indices);
        Ok(())
    }

    fn name(&self) -> &'static str {
        // the replay applies the native engine's arithmetic, so runs
        // report the same backend label either way
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_executor_applies_updates() {
        let x = vec![1.0f32, 0.0, 0.0, 1.0];
        let y = vec![2.0f32, -2.0];
        let store = StoreView::new(&x, &y, 2);
        let model = RidgeModel::new(2, 0.0, 2);
        let mut exec = NativeExecutor::new(model, 0.1);
        let mut w = vec![0.0, 0.0];
        exec.run_block(&mut w, store, &[0, 1, 0, 1]).unwrap();
        assert!(w[0] > 0.0 && w[1] < 0.0, "moved toward labels: {w:?}");
        assert_eq!(exec.name(), "native");
    }

    #[test]
    fn native_executor_is_generic_over_the_workload() {
        use crate::model::LogisticModel;
        // classes on either axis; labels in {0, 1}
        let x = vec![1.0f32, 0.0, -1.0, 0.0];
        let y = vec![1.0f32, 0.0];
        let store = StoreView::new(&x, &y, 2);
        let model = LogisticModel::new(2, 0.0, 2);
        let mut exec = NativeExecutor::new(model, 0.5);
        let mut w = vec![0.0, 0.0];
        exec.run_block(&mut w, store, &[0, 1, 0, 1, 0, 1]).unwrap();
        assert!(w[0] > 0.0, "w must point toward the positive class: {w:?}");
        assert_eq!(exec.name(), "native");
    }

    #[test]
    fn trace_executor_records_without_touching_w() {
        let x = vec![1.0f32, 0.0, 0.0, 1.0];
        let y = vec![2.0f32, -2.0];
        let store = StoreView::new(&x, &y, 2);
        let mut tape = vec![9u32]; // stale content must be cleared
        let mut exec = TraceExecutor::new(&mut tape);
        let mut w = vec![0.5, -0.5];
        exec.run_block(&mut w, store, &[0, 1]).unwrap();
        exec.run_block(&mut w, store, &[1]).unwrap();
        assert_eq!(w, vec![0.5, -0.5], "trace pass must not touch w");
        assert_eq!(exec.name(), "native");
        drop(exec);
        assert_eq!(tape, vec![0, 1, 1], "flush-order index stream");
    }
}
