//! Event log for coordinator runs (debugging, tests, timeline plots).

/// One timestamped protocol event (times in normalized units).
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub t: f64,
    pub kind: EventKind,
}

/// Protocol event kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Device `device` started transmitting block `block` with `payload`
    /// samples (device 0 for single-device traffic).
    BlockSent { block: usize, payload: usize, device: usize },
    /// Block `block` fully received by the edge (after `attempts` tries).
    BlockDelivered { block: usize, payload: usize, attempts: u32 },
    /// Block arrived after the deadline and was discarded.
    BlockMissedDeadline { block: usize },
    /// Send attempt `resend` (0 = the initial send) of block `block` hit
    /// its per-packet ARQ timeout (fault-tolerance layer only).
    BlockTimedOut { block: usize, resend: u32 },
    /// Block `block` was given up on after exhausting its retry budget;
    /// its samples are shed (fault-tolerance layer only).
    BlockAbandoned { block: usize },
    /// Device `device` was evicted after consecutive timeouts; its
    /// undelivered shard of `lost_samples` is shed (fault-tolerance
    /// layer only).
    DeviceEvicted { device: usize, lost_samples: usize },
    /// The edge ran `count` SGD updates ending at time `t`.
    UpdatesRun { count: usize },
    /// Run finished (deadline reached or data exhausted + tail done).
    Finished { updates: usize, delivered_samples: usize },
}

/// A bounded event recorder (drops beyond `cap` to keep sweeps cheap).
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    events: Vec<Event>,
    cap: usize,
    dropped: usize,
}

impl EventLog {
    /// Recorder keeping at most `cap` events (0 disables recording).
    pub fn with_capacity(cap: usize) -> EventLog {
        EventLog { events: Vec::new(), cap, dropped: 0 }
    }

    /// Re-arm for a new run with capacity `cap`, keeping the backing
    /// buffer (workspace reuse: no allocation after warm-up).
    pub fn reset(&mut self, cap: usize) {
        self.events.clear();
        self.cap = cap;
        self.dropped = 0;
    }

    pub fn push(&mut self, t: f64, kind: EventKind) {
        if self.events.len() < self.cap {
            self.events.push(Event { t, kind });
        } else {
            self.dropped += 1;
        }
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn dropped(&self) -> usize {
        self.dropped
    }

    pub fn into_events(self) -> Vec<Event> {
        self.events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_is_respected() {
        let mut log = EventLog::with_capacity(2);
        for i in 0..5 {
            log.push(i as f64, EventKind::UpdatesRun { count: i });
        }
        assert_eq!(log.events().len(), 2);
        assert_eq!(log.dropped(), 3);
    }

    #[test]
    fn zero_capacity_disables() {
        let mut log = EventLog::with_capacity(0);
        log.push(
            0.0,
            EventKind::BlockSent { block: 1, payload: 5, device: 0 },
        );
        assert!(log.events().is_empty());
        assert_eq!(log.dropped(), 1);
    }
}
