//! The pipelined communication/computation coordinator — the paper's
//! system contribution (Sec. 2, Fig. 2).
//!
//! Two interchangeable implementations with bit-identical results:
//!
//! * [`des`] — a single-threaded discrete-event simulation, the fast path
//!   used by Monte-Carlo sweeps (millions of updates/s);
//! * [`pipeline`] — a real two-thread pipeline (device transmitter thread,
//!   edge trainer thread, mpsc packet channel) exercising the actual
//!   concurrent system structure.
//!
//! Both drive a [`BlockExecutor`](executor::BlockExecutor) — native Rust
//! SGD or the PJRT executor running the AOT JAX/Pallas artifacts — and
//! both consume identical RNG streams, so `des == pipeline` exactly
//! (asserted in `rust/tests/pipeline_parity.rs`).

pub mod des;
pub mod events;
pub mod executor;
pub mod pipeline;
pub mod run;

pub use des::{run_des, DesConfig, DeviceTransmitter};
pub use events::{Event, EventKind};
pub use executor::{BlockExecutor, NativeExecutor};
pub use pipeline::run_pipelined;
pub use run::{run_experiment, ExperimentOutput, RunResult};
