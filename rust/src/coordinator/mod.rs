//! The pipelined communication/computation coordinator — the paper's
//! system contribution (Sec. 2, Fig. 2).
//!
//! One generic engine, several faces:
//!
//! * [`scheduler`] — the event-driven core: [`run_schedule`] advances
//!   normalized time and dispatches to pluggable [`TrafficSource`] /
//!   [`BlockPolicy`] / [`OverlapMode`] policies over the existing
//!   [`Channel`](crate::channel::Channel) and [`BlockExecutor`] seams.
//!   Every protocol variant in the crate is a thin adapter over it.
//! * [`des`] — the reference configuration (single device, fixed `n_c`,
//!   pipelined): the fast path used by Monte-Carlo sweeps (millions of
//!   updates/s).
//! * [`pipeline`] — a real two-thread pipeline (device transmitter
//!   thread, edge trainer thread, mpsc packet channel) exercising the
//!   actual concurrent system structure.
//!
//! All paths drive a [`BlockExecutor`](executor::BlockExecutor) —
//! native Rust SGD, or the recording [`TraceExecutor`] behind the
//! batched-seed sweep engine — and consume identical RNG streams, so
//! `des == pipeline == run_schedule(single, fixed)` exactly (asserted
//! in `rust/tests/pipeline_parity.rs` and
//! `rust/tests/scenario_parity.rs`).

pub mod des;
pub mod events;
pub mod executor;
pub mod pipeline;
pub mod run;
pub mod scheduler;
pub mod shard;
mod trainer;

pub use des::{run_des, DesConfig, DeviceTransmitter};
pub use events::{Event, EventKind};
pub use executor::{BlockExecutor, NativeExecutor, TraceExecutor};
pub use pipeline::run_pipelined;
pub use run::{run_experiment, ExperimentOutput, RunResult};
pub use shard::{shard_count, ShardedSource, MAX_SHARDS, SHARDS_ENV};
pub use scheduler::{
    run_schedule, run_schedule_with, BlockFrame, BlockPolicy,
    ControlPolicy, DeviceScheduler, FixedPolicy, GreedyScheduler,
    LaneView, OnlineArrivalSource, OverlapMode, PropFairScheduler,
    RoundRobinScheduler, RoundRobinSource, RunStats, RunWorkspace,
    ScheduledSource, SingleDeviceSource, SourcePoll, TrafficSource,
};
