//! The edge node's training half, shared verbatim by every coordinator
//! path (DES adapter, generic scheduler, threaded pipeline) so their
//! semantics cannot diverge.
//!
//! The trainer's heap state lives in a detachable [`TrainSpace`] so
//! Monte-Carlo sweeps can reuse one set of buffers across thousands of
//! runs (`coordinator::scheduler::RunWorkspace`): a run takes the space
//! by value, mutates it, and hands it back — re-seeding every RNG and
//! clearing every buffer, so a reused space is bit-identical to a fresh
//! one (asserted in `rust/tests/scenario_parity.rs`).

use anyhow::Result;

use crate::channel::fault::{next_window, FaultWindow};
use crate::data::Dataset;
use crate::edge::SampleStore;
use crate::model::Workload;
use crate::util::rng::Pcg32;

use super::des::{DesConfig, STREAM_EDGE, STREAM_EVICT, STREAM_INIT};
use super::events::{EventKind, EventLog};
use super::executor::BlockExecutor;
use super::run::BlockSnapshot;

/// The trainer's reusable heap buffers: parameters, the sample store,
/// the SGD index batch, and the recorded outputs. One `TrainSpace`
/// serves arbitrarily many runs; every buffer is cleared (capacity kept)
/// when a run adopts it.
#[derive(Debug, Default)]
pub(crate) struct TrainSpace {
    pub w: Vec<f64>,
    pub store: SampleStore,
    pub idx_buf: Vec<u32>,
    pub curve: Vec<(f64, f64)>,
    pub snapshots: Vec<BlockSnapshot>,
}

/// The edge node's training half: owns `w`, the sample store, the compute
/// clock, loss recording and snapshot collection.
pub(crate) struct EdgeTrainer<'a> {
    ds: &'a Dataset,
    sp: TrainSpace,
    /// Next update would start at this time.
    cursor: f64,
    tau_p: f64,
    t_budget: f64,
    reg: f64,
    workload: Workload,
    rng: Pcg32,
    evict_rng: Pcg32,
    /// Scripted compute-preemption windows (`fault=preempt:...`): SGD is
    /// frozen while a window is active. Empty = never preempted, and the
    /// walker is bypassed entirely (the fault-free fast path).
    preempt: Vec<FaultWindow>,
    pub updates: usize,
    loss_every: usize,
    since_record: usize,
    collect_snapshots: bool,
    record_blocks: bool,
    /// When false, loss recording is skipped entirely (the batched-seed
    /// trace pass re-runs the DES for its index tape only; the losses
    /// are recomputed once per lane after replay).
    eval_losses: bool,
}

impl<'a> EdgeTrainer<'a> {
    /// Fresh trainer with its own (empty) buffers.
    pub fn new(ds: &'a Dataset, cfg: &DesConfig) -> EdgeTrainer<'a> {
        Self::from_space(TrainSpace::default(), ds, cfg)
    }

    /// Adopt an existing [`TrainSpace`]: clears every buffer (keeping
    /// capacity) and re-derives all per-run state from `cfg`, so the
    /// resulting trainer is indistinguishable from [`new`](Self::new).
    pub fn from_space(
        sp: TrainSpace,
        ds: &'a Dataset,
        cfg: &DesConfig,
    ) -> EdgeTrainer<'a> {
        Self::from_space_opts(sp, ds, cfg, true)
    }

    /// [`from_space`](Self::from_space) with loss evaluation optionally
    /// disabled (`eval_losses = false` is the trace-pass mode; the RNG
    /// draws, timelines, and index stream are unaffected since
    /// `record_loss` is pure).
    pub fn from_space_opts(
        mut sp: TrainSpace,
        ds: &'a Dataset,
        cfg: &DesConfig,
        eval_losses: bool,
    ) -> EdgeTrainer<'a> {
        let mut init_rng = Pcg32::new(cfg.seed, STREAM_INIT);
        sp.w.clear();
        sp.w.extend((0..ds.d).map(|_| cfg.init_std * init_rng.next_gaussian()));
        sp.store.reset(ds.d, cfg.store_capacity);
        sp.idx_buf.clear();
        sp.idx_buf.reserve(4096);
        sp.curve.clear();
        sp.snapshots.clear();
        let reg = cfg.lambda / ds.n as f64;
        let mut trainer = EdgeTrainer {
            ds,
            sp,
            cursor: 0.0,
            tau_p: cfg.tau_p,
            t_budget: cfg.t_budget,
            reg,
            workload: cfg.workload,
            rng: Pcg32::new(cfg.seed, STREAM_EDGE),
            evict_rng: Pcg32::new(cfg.seed, STREAM_EVICT),
            preempt: cfg.faults.preempt.clone(),
            updates: 0,
            loss_every: cfg.loss_every,
            since_record: 0,
            collect_snapshots: cfg.collect_snapshots,
            record_blocks: cfg.record_blocks,
            eval_losses,
        };
        trainer.record_loss(0.0);
        trainer
    }

    /// Release the buffers (with this run's outputs inside) for reuse or
    /// for assembling a `RunResult`.
    pub fn into_space(self) -> TrainSpace {
        self.sp
    }

    /// Total samples ever ingested into the store.
    pub fn ingested(&self) -> usize {
        self.sp.store.ingested()
    }

    /// Training loss over the FULL dataset (paper Fig. 4's y-axis),
    /// under the run's configured workload.
    pub fn full_loss(&self) -> f64 {
        self.workload.full_loss(self.ds, &self.sp.w, self.reg)
    }

    fn record_loss(&mut self, t: f64) {
        self.since_record = 0;
        if !self.eval_losses {
            return;
        }
        let loss = self.full_loss();
        self.sp.curve.push((t, loss));
    }

    /// Advance the compute clock to `until`, running SGD updates while
    /// the store is non-empty (paper eq. (2)) — except inside scripted
    /// preemption windows, where the clock passes but no update runs.
    pub fn advance_to(
        &mut self,
        until: f64,
        exec: &mut dyn BlockExecutor,
        events: &mut EventLog,
    ) -> Result<()> {
        if self.preempt.is_empty() {
            return self.advance_segment(until, exec, events);
        }
        let until = until.min(self.t_budget);
        loop {
            // bind before matching: both arms mutate self
            let win = next_window(&self.preempt, self.cursor);
            match win {
                Some((w_start, w_end)) if w_start < until => {
                    // compute up to the window, then freeze through it
                    self.advance_segment(
                        w_start.max(self.cursor),
                        exec,
                        events,
                    )?;
                    self.skip_to(w_end.min(until));
                    if w_end >= until {
                        return Ok(());
                    }
                }
                _ => return self.advance_segment(until, exec, events),
            }
        }
    }

    /// One preemption-free compute segment (the historical `advance_to`
    /// body — the whole story when no `preempt` windows are scripted).
    fn advance_segment(
        &mut self,
        until: f64,
        exec: &mut dyn BlockExecutor,
        events: &mut EventLog,
    ) -> Result<()> {
        let until = until.min(self.t_budget);
        if self.sp.store.is_empty() {
            self.cursor = self.cursor.max(until);
            return Ok(());
        }
        let n = self.sp.store.len() as u64;
        // updates that *finish* by `until` (tiny epsilon absorbs fp drift
        // in repeated cursor += tau_p)
        let eps = 1e-9 * self.tau_p;
        let mut ran = 0usize;
        while self.cursor + self.tau_p <= until + eps {
            self.sp.idx_buf.push(self.rng.gen_range(n) as u32);
            self.cursor += self.tau_p;
            self.updates += 1;
            self.since_record += 1;
            ran += 1;
            let flush_for_record = self.loss_every > 0
                && self.since_record >= self.loss_every;
            if flush_for_record || self.sp.idx_buf.len() >= 4096 {
                self.flush(exec)?;
                if flush_for_record {
                    self.record_loss(self.cursor);
                }
            }
        }
        self.flush(exec)?;
        if ran > 0 {
            events.push(self.cursor, EventKind::UpdatesRun { count: ran });
        }
        self.cursor = self.cursor.max(until);
        Ok(())
    }

    /// Let time pass WITHOUT computing (the sequential baseline's idle
    /// phase — the edge does nothing while the channel is busy).
    pub fn skip_to(&mut self, until: f64) {
        self.cursor = self.cursor.max(until.min(self.t_budget));
    }

    fn flush(&mut self, exec: &mut dyn BlockExecutor) -> Result<()> {
        if self.sp.idx_buf.is_empty() {
            return Ok(());
        }
        exec.run_block(&mut self.sp.w, self.sp.store.view(), &self.sp.idx_buf)?;
        self.sp.idx_buf.clear();
        Ok(())
    }

    /// Ingest a delivered block at time `t` (records the boundary loss
    /// and, when enabled, the Theorem-1 snapshot of (w, X_b)).
    pub fn ingest_block(&mut self, block: usize, t: f64, x: &[f32], y: &[f32]) {
        if self.collect_snapshots {
            self.sp.snapshots.push(BlockSnapshot {
                block,
                arrived_at: t,
                w_end: self.sp.w.clone(),
                x: x.to_vec(),
                y: y.to_vec(),
            });
        }
        self.sp.store.ingest(x, y, &mut self.evict_rng);
        if self.record_blocks {
            self.record_loss(t);
        }
    }

    /// Finish the run: flush pending updates and record the final loss.
    pub fn finish(&mut self, exec: &mut dyn BlockExecutor) -> Result<()> {
        self.flush(exec)?;
        self.record_loss(self.t_budget);
        Ok(())
    }
}
