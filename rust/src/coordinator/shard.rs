//! Sharded per-device event loops for the multi-device DES core — the
//! Brent-A/mcsim `PER_NODE_THREADING` plan applied to the scheduler's
//! heterogeneous traffic source.
//!
//! [`ShardedSource`] partitions the `k` device lanes into `shards`
//! contiguous shards, each owned by one long-lived worker thread
//! (`util::pool::ShardPool`). All *node-local* event handling — the
//! per-device untransmitted-index set, the per-device sample RNG
//! (stream `STREAM_DEVICE`, seed `+1000·i`), block draws and eviction
//! clears — is mutated only on the owning shard's thread. Only the
//! genuinely *cross-device* traffic stays on the caller's shared,
//! ordered event loop: the [`DeviceScheduler`] pick, `BlockPolicy`
//! sizing/observations, channel transmission (all lanes share the one
//! serialized uplink and the single stream-4 noise sequence) and the
//! trainer's SGD flushes.
//!
//! # Determinism — sharding is an execution strategy, not a semantics
//!
//! The shard count can NEVER change results. The scheduler pick runs on
//! the calling thread over [`LaneView`]s that are maintained
//! *incrementally* (only the picked or evicted lane's view changes per
//! event, so the views equal a per-poll rebuild by induction); the draw
//! for the picked lane is dispatched to its owning shard worker and the
//! caller blocks until it completes, so every event observes exactly
//! the state the single-threaded [`ScheduledSource`] would. Hence for
//! EVERY `shards`, `ShardedSource` is bit-identical — event stream,
//! weights, counters — to `ScheduledSource`, which stays in the tree as
//! the reference implementation (asserted in
//! `rust/tests/scenario_parity.rs`, including `shards ∈ {1,2,4}` forall
//! and fault-armed-but-dormant runs).
//!
//! What sharding buys instead:
//!
//! * **O(1) per-event bookkeeping.** `ScheduledSource` rebuilds all `k`
//!   lane views and scans all `k` lanes for exhaustion on every poll —
//!   O(k) per block. `ShardedSource` maintains the views and a running
//!   `total_remaining` incrementally, so a poll costs the scheduler's
//!   pick plus O(1), which is what makes 10k+ device scenarios feasible
//!   (`bench/sweep.rs` records the device-count scaling curve).
//! * **Parallel node setup.** Building/resetting `k` untransmitted
//!   index sets is O(total samples); shard workers do their own lanes
//!   concurrently.
//! * **Thread-affine node state.** Each lane's hot state is touched by
//!   one worker thread for the whole run — the structure the federated
//!   ("millions of users") scenarios need.
//!
//! `shards = 1` (the default) takes a fully inline path: no pool, no
//! threads, no unsafe — just the incremental-views win.
//!
//! # Knob
//!
//! `EDGEPIPE_SHARDS` picks the shard count for scenario runs (default
//! 1, snapped into `1..=MAX_SHARDS` and capped at the device count).
//! The explicit-count constructors exist so parallel tests never race
//! on process-global env.

use crate::data::Dataset;
use crate::util::pool::ShardPool;
use crate::util::rng::Pcg32;

use super::des::STREAM_DEVICE;
use super::scheduler::{
    draw_block, BlockFrame, DeviceLane, DeviceScheduler, LaneView,
    SourcePoll, TrafficSource,
};

/// Environment knob selecting the DES shard count.
pub const SHARDS_ENV: &str = "EDGEPIPE_SHARDS";

/// Most shard worker threads one source will spawn.
pub const MAX_SHARDS: usize = 16;

/// The shard count scenario runs use: `EDGEPIPE_SHARDS` clamped into
/// `1..=MAX_SHARDS`, defaulting to 1 (inline, thread-free). The
/// constructor additionally caps it at the device count.
pub fn shard_count() -> usize {
    std::env::var(SHARDS_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|n| n.clamp(1, MAX_SHARDS))
        .unwrap_or(1)
}

/// First device of shard `s` when `devices` lanes are split into
/// `shards` contiguous, balanced ranges. Shard `s` owns
/// `shard_start(s)..shard_start(s + 1)`; [`owner_of`] is the inverse.
fn shard_start(s: usize, devices: usize, shards: usize) -> usize {
    (s * devices).div_ceil(shards)
}

/// Shard owning device `device` (the inverse of [`shard_start`]).
fn owner_of(device: usize, devices: usize, shards: usize) -> usize {
    device * shards / devices
}

/// `k` heterogeneous devices with per-shard event-loop threads — the
/// scaling form of [`ScheduledSource`], to which it is bit-identical at
/// EVERY shard count (see the module docs). Device `i` draws on stream
/// `STREAM_DEVICE` seeded `seed + 1000·i` exactly as before; pair with
/// a [`MultiLaneChannel`](crate::channel::MultiLaneChannel) for
/// per-device links.
pub struct ShardedSource<'a, S: DeviceScheduler> {
    shards_ds: &'a [Dataset],
    lanes: Vec<DeviceLane>,
    /// Samples transmitted per lane (the scheduler's service counter).
    sent: Vec<usize>,
    slowdowns: &'a [f64],
    /// Incrementally maintained lane views (see module docs): equal to
    /// [`ScheduledSource`]'s per-poll rebuild at every pick.
    views: Vec<LaneView>,
    /// Running sum of every lane's `remaining` — O(1) exhaustion check.
    total_remaining: usize,
    sched: S,
    /// `None` when `n_shards == 1` (the inline, thread-free path).
    pool: Option<ShardPool>,
    n_shards: usize,
    /// Global telemetry handle, cloned once at construction so the
    /// per-event cost is one branch when detached (write-only
    /// observation — see `util::telemetry`).
    tel: crate::util::telemetry::Telemetry,
}

impl<'a, S: DeviceScheduler> ShardedSource<'a, S> {
    pub fn new(
        shards_ds: &'a [Dataset],
        seed: u64,
        sched: S,
        slowdowns: &'a [f64],
        n_shards: usize,
    ) -> ShardedSource<'a, S> {
        Self::with_bufs(shards_ds, seed, Vec::new(), sched, slowdowns, n_shards)
    }

    /// Build reusing `bufs` as the per-lane index scratch (the same
    /// recycling contract as [`ScheduledSource::with_bufs`]).
    /// `n_shards` is clamped to `1..=min(k, MAX_SHARDS)`.
    pub fn with_bufs(
        shards_ds: &'a [Dataset],
        seed: u64,
        mut bufs: Vec<Vec<u32>>,
        sched: S,
        slowdowns: &'a [f64],
        n_shards: usize,
    ) -> ShardedSource<'a, S> {
        assert!(!shards_ds.is_empty(), "need at least one device");
        assert_eq!(
            shards_ds.len(),
            slowdowns.len(),
            "one slowdown per device lane"
        );
        assert!(
            slowdowns.iter().all(|s| *s > 0.0),
            "lane slowdowns must be positive"
        );
        let k = shards_ds.len();
        let n_shards = n_shards.clamp(1, k.min(MAX_SHARDS));
        bufs.resize_with(k, Vec::new);
        // lane shells on the caller (seeding a PCG is a handful of u64
        // ops); the O(n) index refills run on the owning shard threads
        let mut lanes: Vec<DeviceLane> = bufs
            .into_iter()
            .enumerate()
            .map(|(i, mut buf)| {
                buf.clear();
                DeviceLane {
                    remaining: buf,
                    rng: Pcg32::new(
                        seed.wrapping_add(1000 * i as u64),
                        STREAM_DEVICE,
                    ),
                }
            })
            .collect();
        let pool = if n_shards > 1 {
            Some(ShardPool::new(n_shards))
        } else {
            None
        };
        match &pool {
            None => {
                for (lane, shard) in lanes.iter_mut().zip(shards_ds) {
                    lane.remaining.extend(0..shard.n as u32);
                }
            }
            Some(pool) => {
                // node-local init: split the lane table into the per-
                // shard ranges and let each worker fill its own
                let mut jobs: Vec<Option<Box<dyn FnOnce() + Send + '_>>> =
                    Vec::with_capacity(n_shards);
                let mut rest: &mut [DeviceLane] = &mut lanes;
                let mut offset = 0usize;
                for s in 0..n_shards {
                    let end = shard_start(s + 1, k, n_shards);
                    let (mine, tail) = rest.split_at_mut(end - offset);
                    rest = tail;
                    let my_ds = &shards_ds[offset..end];
                    jobs.push(Some(Box::new(move || {
                        for (lane, shard) in mine.iter_mut().zip(my_ds) {
                            lane.remaining.extend(0..shard.n as u32);
                        }
                    })));
                    offset = end;
                }
                pool.run_all(jobs);
            }
        }
        let total_remaining = shards_ds.iter().map(|s| s.n).sum();
        let views = shards_ds
            .iter()
            .zip(slowdowns)
            .map(|(shard, &slowdown)| LaneView {
                remaining: shard.n,
                sent: 0,
                slowdown,
            })
            .collect();
        ShardedSource {
            shards_ds,
            sent: vec![0; k],
            views,
            lanes,
            slowdowns,
            total_remaining,
            sched,
            pool,
            n_shards,
            tel: crate::util::telemetry::global(),
        }
    }

    /// Shard workers this source runs with (1 = inline).
    pub fn shard_workers(&self) -> usize {
        self.n_shards
    }

    /// Hand the per-lane index scratch back for reuse.
    pub fn into_bufs(self) -> Vec<Vec<u32>> {
        self.lanes.into_iter().map(|l| l.remaining).collect()
    }
}

impl<S: DeviceScheduler> TrafficSource for ShardedSource<'_, S> {
    fn remaining(&self) -> usize {
        self.total_remaining
    }

    fn poll(
        &mut self,
        n_c: usize,
        _t_now: f64,
        frame: &mut BlockFrame,
    ) -> SourcePoll {
        if self.total_remaining == 0 {
            return SourcePoll::Exhausted;
        }
        // cross-device decision on the shared, ordered loop
        let device = self.sched.pick(&self.views);
        let lane = &mut self.lanes[device];
        assert!(
            !lane.remaining.is_empty(),
            "{} picked empty lane {device}",
            self.sched.name()
        );
        let ds = &self.shards_ds[device];
        match &self.pool {
            // node-local draw, inline (shards = 1)
            None => {
                draw_block(ds, &mut lane.remaining, &mut lane.rng, n_c, frame)
            }
            // node-local draw on the owning shard's thread; the ack
            // barrier inside run_on keeps the event loop ordered
            Some(pool) => {
                let shard =
                    owner_of(device, self.shards_ds.len(), self.n_shards);
                let remaining = &mut lane.remaining;
                let rng = &mut lane.rng;
                let staged: &mut BlockFrame = &mut *frame;
                pool.run_on(
                    shard,
                    Box::new(move || {
                        draw_block(ds, remaining, rng, n_c, staged)
                    }),
                );
            }
        }
        self.tel.with(|m| m.pool.shard_draws.inc());
        let drawn = frame.len();
        self.sent[device] += drawn;
        self.total_remaining -= drawn;
        self.views[device].remaining = lane.remaining.len();
        self.views[device].sent = self.sent[device];
        SourcePoll::Block { device }
    }

    fn name(&self) -> String {
        format!(
            "sharded({}, {}, shards={})",
            self.lanes.len(),
            self.sched.name(),
            self.n_shards
        )
    }

    fn evict(&mut self, device: usize) -> usize {
        let k = self.shards_ds.len();
        let Some(lane) = self.lanes.get_mut(device) else { return 0 };
        let shed = lane.remaining.len();
        if shed > 0 {
            match &self.pool {
                None => lane.remaining.clear(),
                Some(pool) => {
                    let shard = owner_of(device, k, self.n_shards);
                    let remaining = &mut lane.remaining;
                    pool.run_on(shard, Box::new(move || remaining.clear()));
                }
            }
            self.tel.with(|m| m.pool.shard_evicts.inc());
        }
        self.total_remaining -= shed;
        self.views[device].remaining = 0;
        shed
    }
}

/// Drive `a` and `b` through identical poll/evict sequences and assert
/// identical observable behavior — the source-level form of the parity
/// contract (the run-level form lives in `rust/tests/scenario_parity.rs`).
#[cfg(test)]
fn assert_sources_agree(
    a: &mut dyn TrafficSource,
    b: &mut dyn TrafficSource,
    n_c: usize,
    evict_at: Option<(usize, usize)>,
) {
    let mut fa = BlockFrame::default();
    let mut fb = BlockFrame::default();
    let mut step = 0usize;
    loop {
        if let Some((at, device)) = evict_at {
            if step == at {
                assert_eq!(a.evict(device), b.evict(device), "evict shed");
            }
        }
        assert_eq!(a.remaining(), b.remaining(), "remaining at step {step}");
        let pa = a.poll(n_c, step as f64, &mut fa);
        let pb = b.poll(n_c, step as f64, &mut fb);
        match (pa, pb) {
            (
                SourcePoll::Block { device: da },
                SourcePoll::Block { device: db },
            ) => {
                assert_eq!(da, db, "picked device at step {step}");
                assert_eq!(fa.x, fb.x, "frame x at step {step}");
                assert_eq!(fa.y, fb.y, "frame y at step {step}");
            }
            (SourcePoll::Exhausted, SourcePoll::Exhausted) => break,
            (pa, pb) => panic!("poll divergence at step {step}: {pa:?} vs {pb:?}"),
        }
        step += 1;
        assert!(step < 100_000, "runaway poll loop");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::{
        GreedyScheduler, PropFairScheduler, RoundRobinScheduler,
        ScheduledSource,
    };
    use crate::data::shard::shard_round_robin;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    #[test]
    fn owner_and_range_math_partition_exactly() {
        for &(devices, shards) in
            &[(1usize, 1usize), (5, 2), (10, 3), (16, 16), (10_000, 7)]
        {
            assert_eq!(shard_start(0, devices, shards), 0);
            assert_eq!(shard_start(shards, devices, shards), devices);
            for s in 0..shards {
                let range = shard_start(s, devices, shards)
                    ..shard_start(s + 1, devices, shards);
                for i in range.clone() {
                    assert_eq!(
                        owner_of(i, devices, shards),
                        s,
                        "device {i} of {devices} over {shards} shards"
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_matches_scheduled_for_every_shard_count() {
        let ds = synth_calhousing(&SynthSpec { n: 240, ..Default::default() });
        let shards = shard_round_robin(&ds, 5);
        let slowdowns = [1.0, 2.0, 1.0, 3.0, 1.5];
        for n_shards in [1usize, 2, 4, 5] {
            let mut reference = ScheduledSource::new(
                &shards,
                9001,
                GreedyScheduler::new(),
                &slowdowns,
            );
            let mut sharded = ShardedSource::new(
                &shards,
                9001,
                GreedyScheduler::new(),
                &slowdowns,
                n_shards,
            );
            assert_eq!(sharded.shard_workers(), n_shards);
            assert_sources_agree(&mut reference, &mut sharded, 7, None);
        }
    }

    #[test]
    fn sharded_matches_scheduled_through_eviction() {
        let ds = synth_calhousing(&SynthSpec { n: 150, ..Default::default() });
        let shards = shard_round_robin(&ds, 3);
        let slowdowns = [1.0; 3];
        for n_shards in [1usize, 3] {
            let mut reference = ScheduledSource::new(
                &shards,
                42,
                RoundRobinScheduler::new(),
                &slowdowns,
            );
            let mut sharded = ShardedSource::new(
                &shards,
                42,
                RoundRobinScheduler::new(),
                &slowdowns,
                n_shards,
            );
            // evict device 1 mid-run; sheds must agree and the
            // remaining devices must inherit the schedule identically
            assert_sources_agree(
                &mut reference,
                &mut sharded,
                8,
                Some((4, 1)),
            );
        }
    }

    #[test]
    fn sharded_prop_fair_and_buf_recycling_agree() {
        let ds = synth_calhousing(&SynthSpec { n: 200, ..Default::default() });
        let shards = shard_round_robin(&ds, 4);
        let slowdowns = [1.0, 1.0, 2.0, 0.5];
        // recycled bufs (dirty from a previous, different run) must not
        // change anything
        let dirty: Vec<Vec<u32>> = vec![vec![7, 7, 7]; 4];
        let mut reference = ScheduledSource::new(
            &shards,
            5,
            PropFairScheduler::new(),
            &slowdowns,
        );
        let mut sharded = ShardedSource::with_bufs(
            &shards,
            5,
            dirty,
            PropFairScheduler::new(),
            &slowdowns,
            2,
        );
        assert_sources_agree(&mut reference, &mut sharded, 11, None);
        let bufs = sharded.into_bufs();
        assert_eq!(bufs.len(), 4);
        assert!(bufs.iter().all(|b| b.is_empty()), "drained run");
    }

    #[test]
    fn shard_count_env_contract() {
        // can't set process env in parallel tests; assert the clamp
        // logic through the constructor instead
        let ds = synth_calhousing(&SynthSpec { n: 60, ..Default::default() });
        let shards = shard_round_robin(&ds, 2);
        let slowdowns = [1.0, 1.0];
        let src = ShardedSource::new(
            &shards,
            1,
            RoundRobinScheduler::new(),
            &slowdowns,
            64,
        );
        assert_eq!(src.shard_workers(), 2, "capped at the device count");
        assert!(shard_count() >= 1 && shard_count() <= MAX_SHARDS);
    }
}
