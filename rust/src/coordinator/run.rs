//! Run results and the high-level experiment orchestration.

use anyhow::{bail, Result};

use crate::bound::{optimize_block_size, BoundParams};
use crate::channel::IdealChannel;
use crate::config::ExperimentConfig;
use crate::coordinator::des::{run_des, DesConfig};
use crate::coordinator::executor::NativeExecutor;
use crate::data::csv::load_csv;
use crate::data::split::train_split;
use crate::data::synth::{synth_calhousing, SynthSpec};
use crate::data::Dataset;
use crate::model::{ridge_solution, RidgeModel};
use crate::protocol::TimelineCase;

use super::events::Event;

/// Per-block snapshot for the Theorem-1 evaluation: the iterate at the
/// block's end and the block's own samples (paper eq. (7)'s `L_b`).
#[derive(Clone, Debug)]
pub struct BlockSnapshot {
    pub block: usize,
    pub arrived_at: f64,
    /// w at the end of the block's compute window (w_b^{n_p}).
    pub w_end: Vec<f64>,
    /// The block's transmitted covariates (row-major).
    pub x: Vec<f32>,
    /// The block's labels.
    pub y: Vec<f32>,
}

/// Everything a coordinator run produces.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// (time, full-dataset training loss) samples; first point is t=0.
    pub curve: Vec<(f64, f64)>,
    /// Training loss at the deadline (paper Fig. 4's endpoint).
    pub final_loss: f64,
    /// Final parameters.
    pub final_w: Vec<f64>,
    /// SGD updates performed.
    pub updates: usize,
    /// Blocks the device started transmitting.
    pub blocks_sent: usize,
    /// Blocks fully received before the deadline.
    pub blocks_delivered: usize,
    /// Samples available at the edge at the deadline.
    pub samples_delivered: usize,
    /// Blocks sent but arriving after the deadline (discarded).
    pub blocks_missed: usize,
    /// Total channel retransmissions (erasure channel; 0 when ideal).
    pub retransmissions: u64,
    /// Per-packet ARQ timeouts (0 unless the timeout machinery is armed
    /// via `DesConfig::faults`).
    pub timeouts: u64,
    /// Blocks given up on: retry budget exhausted, or dropped with an
    /// evicted device.
    pub blocks_abandoned: usize,
    /// Devices evicted after consecutive timeouts.
    pub evictions: usize,
    /// Samples deliberately dropped (abandoned blocks + evicted
    /// devices' undelivered shards) — the bias side of the
    /// bias/variance tradeoff under faults.
    pub samples_lost: usize,
    /// The run shed load instead of stalling: every sample was either
    /// delivered or deliberately dropped, and no sent block missed the
    /// deadline. A degraded completion is NOT a deadline outage.
    pub degraded_completion: bool,
    /// Whether the full dataset made it (Fig. 2 case).
    pub case: TimelineCase,
    /// Theorem-1 snapshots (when requested).
    pub snapshots: Vec<BlockSnapshot>,
    /// Event log (when requested).
    pub events: Vec<Event>,
    /// Executor backend name.
    pub backend: &'static str,
}

/// THE deadline-outage predicate: the schedule missed `T` — a sent
/// block arrived late, or the dataset was not fully delivered in time.
/// One definition shared by [`RunResult`] and
/// [`RunStats`](super::scheduler::RunStats) (and hence the run JSON and
/// the control sweeps), so the two surfaces cannot disagree on what an
/// outage is. Averaged over Monte-Carlo seeds this is the outage
/// probability (`sweep::control`).
///
/// A *degraded completion* — every undelivered sample was deliberately
/// shed (abandoned block / evicted device) and nothing arrived late —
/// is NOT an outage: the protocol traded bias for meeting `T`, which is
/// exactly the graceful-degradation contract. With
/// `degraded_completion = false` this reduces to the historical
/// two-argument predicate.
pub fn deadline_outage(
    blocks_missed: usize,
    case: TimelineCase,
    degraded_completion: bool,
) -> bool {
    blocks_missed > 0 || (case == TimelineCase::Partial && !degraded_completion)
}

impl RunResult {
    /// Optimality gap of the final iterate given the optimal loss.
    pub fn final_gap(&self, loss_star: f64) -> f64 {
        self.final_loss - loss_star
    }

    /// Deadline-outage indicator ([`deadline_outage`]).
    pub fn deadline_outage(&self) -> bool {
        deadline_outage(self.blocks_missed, self.case, self.degraded_completion)
    }
}

/// A fully-resolved experiment: dataset + run output + reference values.
pub struct ExperimentOutput {
    /// The training set actually used (after split).
    pub train: Dataset,
    /// The block size used (resolved from config or the bound optimizer).
    pub n_c: usize,
    /// The run itself.
    pub result: RunResult,
    /// Exact minimizer w* of the empirical risk.
    pub w_star: Vec<f64>,
    /// L(w*) — the optimal training loss.
    pub loss_star: f64,
}

/// Build the training set from a [`DataConfig`]-carrying experiment
/// config: CSV when provided, else the synthetic generator, then the
/// paper's train split.
pub fn build_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
    let raw = if cfg.data.csv_path.is_empty() {
        synth_calhousing(&SynthSpec {
            n: cfg.data.n_raw,
            d: cfg.data.d,
            hess_max: cfg.data.hess_max,
            hess_min: cfg.data.hess_min,
            noise_std: cfg.data.noise_std,
            seed: cfg.data.seed,
        })
    } else {
        load_csv(std::path::Path::new(&cfg.data.csv_path))?
    };
    let (train, _eval) = train_split(&raw, cfg.data.train_frac, cfg.data.seed);
    if train.n == 0 {
        bail!("empty training set after split");
    }
    Ok(train)
}

/// Run one experiment end-to-end on the native backend: build data,
/// resolve `n_c` (bound optimizer when `protocol.n_c == 0`), run the DES,
/// and compute the reference `w*`/`L(w*)`.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentOutput> {
    let train = build_dataset(cfg)?;
    let t_budget = cfg.protocol.deadline(train.n);

    let n_c = if cfg.protocol.n_c > 0 {
        cfg.protocol.n_c.min(train.n)
    } else {
        let constants = crate::bound::estimate_constants(
            &train,
            cfg.train.lambda,
            cfg.train.alpha,
            2000,
            cfg.train.seed,
        );
        let params = BoundParams {
            alpha: cfg.train.alpha,
            big_l: constants.big_l,
            c: constants.c,
            m: 1.0,
            m_g: 1.0,
            d_diam: constants.d_diam,
        };
        optimize_block_size(
            &params,
            train.n,
            t_budget,
            cfg.protocol.n_o,
            cfg.protocol.tau_p,
        )
        .n_c
    };

    let des_cfg = DesConfig {
        n_c,
        n_o: cfg.protocol.n_o,
        tau_p: cfg.protocol.tau_p,
        t_budget,
        alpha: cfg.train.alpha,
        lambda: cfg.train.lambda,
        init_std: cfg.train.init_std,
        seed: cfg.train.seed,
        loss_every: if cfg.train.loss_stride > 0.0 {
            (cfg.train.loss_stride / cfg.protocol.tau_p).max(1.0) as usize
        } else {
            0
        },
        record_blocks: true,
        store_capacity: None,
        collect_snapshots: false,
        event_capacity: 0,
        workload: crate::model::Workload::Ridge,
        faults: Default::default(),
    };
    let mut exec = NativeExecutor::new(
        RidgeModel::new(train.d, cfg.train.lambda, train.n),
        cfg.train.alpha,
    );
    let result = run_des(&train, &des_cfg, &mut IdealChannel, &mut exec)?;

    let w_star = ridge_solution(&train, cfg.train.lambda)?;
    let loss_star =
        train.ridge_loss(&w_star, cfg.train.lambda / train.n as f64);

    Ok(ExperimentOutput { train, n_c, result, w_star, loss_star })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.data.n_raw = 1000;
        cfg.protocol.n_c = 64;
        cfg.train.alpha = 1e-3;
        cfg
    }

    #[test]
    fn experiment_runs_and_improves_on_init() {
        let out = run_experiment(&tiny_cfg()).unwrap();
        assert_eq!(out.n_c, 64);
        assert!(out.result.final_loss < out.result.curve[0].1);
        assert!(out.loss_star <= out.result.final_loss + 1e-12);
        assert!(out.result.final_gap(out.loss_star) >= 0.0);
    }

    #[test]
    fn auto_nc_uses_bound_optimizer() {
        let mut cfg = tiny_cfg();
        cfg.protocol.n_c = 0; // auto
        let out = run_experiment(&cfg).unwrap();
        assert!(out.n_c >= 1 && out.n_c <= out.train.n);
    }

    #[test]
    fn split_respects_fraction() {
        let cfg = tiny_cfg();
        let ds = build_dataset(&cfg).unwrap();
        assert_eq!(ds.n, 900);
    }
}
