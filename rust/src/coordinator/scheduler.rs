//! The generic event-driven protocol core — ONE engine behind every
//! protocol variant.
//!
//! The paper's pipelined protocol used to be implemented four separate
//! times (DES fast path, adaptive schedules, multi-device round-robin,
//! sequential baseline), each duplicating the transmit/train/timeline
//! loop. [`run_schedule`] is the single remaining loop; everything else
//! is a policy plugged into it:
//!
//! * [`TrafficSource`] — *who sends which samples next*: one device
//!   ([`SingleDeviceSource`]), `k` devices sharing the uplink round-robin
//!   ([`RoundRobinSource`]), `k` heterogeneous devices picked by a
//!   pluggable [`DeviceScheduler`] ([`ScheduledSource`]), or a device
//!   whose samples arrive over time ([`OnlineArrivalSource`]).
//! * [`BlockPolicy`] — *how large the next block is*: the paper's fixed
//!   `n_c` ([`FixedPolicy`]) or any adaptive schedule
//!   (`extensions::adaptive`).
//! * [`OverlapMode`] — whether the edge trains during transmission
//!   (the paper's pipelining) or idles (the sequential baseline).
//! * [`Channel`] / [`BlockExecutor`] — the existing link and SGD-backend
//!   seams.
//!
//! RNG-stream discipline is identical to the seed DES (device selection
//! on `STREAM_DEVICE`, channel noise on `STREAM_CHANNEL`, SGD draws on
//! `STREAM_EDGE`), so `run_des == run_schedule(single device, fixed n_c,
//! pipelined)` bit-for-bit — asserted by `rust/tests/scenario_parity.rs`.
//! The hot loop stages each block in a reused [`BlockFrame`], so steady
//! state performs no per-block allocation; [`run_schedule_with`] goes
//! further and recycles EVERY per-run buffer through a [`RunWorkspace`],
//! so Monte-Carlo sweeps perform no per-run allocation after warm-up.

use anyhow::Result;

use crate::bound::replan::Replanner;
use crate::channel::estimator::{ControlEstimator, PacketObs};
use crate::channel::Channel;
use crate::data::Dataset;
use crate::protocol::TimelineCase;
use crate::util::rng::Pcg32;

use super::des::{DesConfig, STREAM_CHANNEL, STREAM_DEVICE};
use super::events::{EventKind, EventLog};
use super::executor::BlockExecutor;
use super::run::RunResult;
use super::trainer::{EdgeTrainer, TrainSpace};

/// Reused per-block staging buffers: one allocation per run, not per
/// block (frames are copied into the edge store on ingest, so reuse is
/// safe).
pub struct BlockFrame {
    /// Row-major covariates of the staged block.
    pub x: Vec<f32>,
    /// Labels of the staged block.
    pub y: Vec<f32>,
}

impl Default for BlockFrame {
    fn default() -> BlockFrame {
        BlockFrame { x: Vec::new(), y: Vec::new() }
    }
}

impl BlockFrame {
    /// Pre-size for blocks of `n_c` samples in `d` dimensions.
    pub fn with_capacity(n_c: usize, d: usize) -> BlockFrame {
        BlockFrame {
            x: Vec::with_capacity(n_c * d),
            y: Vec::with_capacity(n_c),
        }
    }

    /// Samples currently staged.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Drop staged samples, keeping the buffers.
    pub fn clear(&mut self) {
        self.x.clear();
        self.y.clear();
    }

    /// Re-arm for a run staging blocks of up to `n_c` samples in `d`
    /// dimensions (clears, then grows capacity only if needed).
    pub fn reset(&mut self, n_c: usize, d: usize) {
        self.clear();
        self.x.reserve(n_c * d);
        self.y.reserve(n_c);
    }
}

/// Every reusable buffer one protocol run needs: the staging frame, the
/// event log, the trainer's heap state (`TrainSpace`) and the traffic
/// sources' index scratch. Thread one workspace through
/// [`run_schedule_with`] (or `ScenarioRunner::run_with`) across many
/// seeds and a sweep-mode run (no snapshots; single-device or online
/// traffic) performs zero heap allocations after warm-up — the lever
/// behind the sweep engine's throughput
/// (`rust/benches/bench_sweep.rs`).
///
/// Reuse is pure: a run on a used workspace is bit-identical to a run
/// on a fresh one (every buffer is cleared, every RNG re-seeded;
/// asserted in `rust/tests/scenario_parity.rs`).
#[derive(Default)]
pub struct RunWorkspace {
    pub(crate) frame: BlockFrame,
    pub(crate) events: EventLog,
    pub(crate) train: TrainSpace,
    /// Index scratch for single-device / online-arrival sources.
    pub(crate) src_buf: Vec<u32>,
    /// Per-lane index scratch for the round-robin and scheduled
    /// multi-device sources.
    pub(crate) lane_bufs: Vec<Vec<u32>>,
}

impl RunWorkspace {
    pub fn new() -> RunWorkspace {
        RunWorkspace::default()
    }

    /// Final parameters of the last run.
    pub fn final_w(&self) -> &[f64] {
        &self.train.w
    }

    /// (time, loss) curve of the last run.
    pub fn curve(&self) -> &[(f64, f64)] {
        &self.train.curve
    }

    /// Theorem-1 snapshots of the last run (when collected).
    pub fn snapshots(&self) -> &[super::run::BlockSnapshot] {
        &self.train.snapshots
    }

    /// Event stream of the last run (when recorded).
    pub fn events(&self) -> &[super::events::Event] {
        self.events.events()
    }

    /// Assemble a full [`RunResult`] from the last run's buffers plus
    /// its [`RunStats`] (consumes the workspace).
    pub fn into_result(self, stats: RunStats) -> RunResult {
        RunResult {
            curve: self.train.curve,
            final_loss: stats.final_loss,
            final_w: self.train.w,
            updates: stats.updates,
            blocks_sent: stats.blocks_sent,
            blocks_delivered: stats.blocks_delivered,
            samples_delivered: stats.samples_delivered,
            blocks_missed: stats.blocks_missed,
            retransmissions: stats.retransmissions,
            timeouts: stats.timeouts,
            blocks_abandoned: stats.blocks_abandoned,
            evictions: stats.evictions,
            samples_lost: stats.samples_lost,
            degraded_completion: stats.degraded_completion,
            case: stats.case,
            snapshots: self.train.snapshots,
            events: self.events.into_events(),
            backend: stats.backend,
        }
    }
}

/// The allocation-free summary of one run: everything `RunResult`
/// carries except the heap-backed outputs (curve, weights, snapshots,
/// events), which stay in the [`RunWorkspace`] for reuse or inspection.
#[derive(Clone, Copy, Debug)]
pub struct RunStats {
    pub final_loss: f64,
    pub updates: usize,
    pub blocks_sent: usize,
    pub blocks_delivered: usize,
    pub samples_delivered: usize,
    /// Blocks sent but arriving after the deadline (discarded).
    pub blocks_missed: usize,
    pub retransmissions: u64,
    /// Per-packet ARQ timeouts (0 unless `DesConfig::faults` arms the
    /// timeout machinery).
    pub timeouts: u64,
    /// Blocks given up on (retry budget exhausted or device evicted).
    pub blocks_abandoned: usize,
    /// Devices evicted after consecutive timeouts.
    pub evictions: usize,
    /// Samples deliberately shed (abandoned blocks + evicted devices'
    /// undelivered shards).
    pub samples_lost: usize,
    /// Every sample was delivered or deliberately shed and nothing
    /// arrived late — the run degraded gracefully instead of stalling.
    pub degraded_completion: bool,
    pub case: TimelineCase,
    pub backend: &'static str,
}

impl RunStats {
    /// Deadline-outage indicator
    /// ([`deadline_outage`](super::run::deadline_outage) — one shared
    /// definition with `RunResult`).
    pub fn deadline_outage(&self) -> bool {
        super::run::deadline_outage(
            self.blocks_missed,
            self.case,
            self.degraded_completion,
        )
    }
}

/// What a [`TrafficSource`] produced for the current poll.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SourcePoll {
    /// The frame was filled by device `device`.
    Block { device: usize },
    /// Nothing is transmittable before `until` (online arrivals); the
    /// scheduler lets the edge compute through the gap.
    Idle { until: f64 },
    /// No device will ever have data again.
    Exhausted,
}

/// Which device sends which samples next. Implementations own the
/// without-replacement selection RNG (`STREAM_DEVICE` discipline) so the
/// scheduler core stays deterministic and backend-agnostic.
pub trait TrafficSource {
    /// Untransmitted samples remaining across all devices (a hint for
    /// [`BlockPolicy`] implementations).
    fn remaining(&self) -> usize;

    /// Stage the next block of up to `n_c` samples into `frame`.
    fn poll(
        &mut self,
        n_c: usize,
        t_now: f64,
        frame: &mut BlockFrame,
    ) -> SourcePoll;

    /// Name for logs.
    fn name(&self) -> String;

    /// Permanently remove device `device` from the schedule, dropping
    /// every sample it has not yet transmitted; returns how many
    /// samples were dropped. Called by the scheduler core when the
    /// fault-tolerance layer evicts a device after `evict_after`
    /// consecutive ARQ timeouts. Sources that cannot shed anything keep
    /// the default no-op (drop nothing, return 0). Must consume no RNG.
    fn evict(&mut self, _device: usize) -> usize {
        0
    }
}

/// A protocol-level fault observation fed to
/// [`BlockPolicy::observe_fault`] — what the graceful-degradation hook
/// sees when the ARQ machinery gives up on a packet or a device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultObs {
    /// A packet hit its per-packet timeout: the channel was occupied for
    /// AT LEAST `waited` (a censored observation — the true occupancy
    /// may be unbounded).
    Timeout {
        device: usize,
        /// Fault-free duration the packet would have taken.
        nominal: f64,
        /// How long the scheduler actually waited before giving up.
        waited: f64,
    },
    /// A device was evicted after consecutive timeouts; its undelivered
    /// shard (`lost_samples`, including the in-flight block) is gone.
    Eviction { device: usize, lost_samples: usize },
}

/// A per-block payload-size policy (the paper fixes one `n_c`; adaptive
/// schedules live in `extensions::adaptive`, the closed-loop
/// [`ControlPolicy`] below).
pub trait BlockPolicy {
    /// Payload for the `block`-th transmission (1-indexed), given how
    /// many samples remain untransmitted and the current time.
    fn next_n_c(&mut self, block: usize, remaining: usize, t_now: f64)
        -> usize;

    /// Observe one completed transmission (nominal duration, measured
    /// channel occupancy, ARQ attempts) — the scheduler core calls this
    /// once per sent block, right after the channel resolves it.
    /// Closed-loop policies feed their channel estimator here; open-loop
    /// policies keep the default no-op. Implementations must consume no
    /// RNG, so observing never perturbs the stream discipline.
    fn observe(&mut self, _obs: &PacketObs) {}

    /// Observe a protocol fault (packet timeout, device eviction) — the
    /// graceful-degradation hook. Closed-loop policies fold the
    /// censored occupancy into their channel belief and force a re-plan
    /// when capacity is lost, so the Corollary-1 argmin is re-solved
    /// over the residual problem; open-loop policies keep the default
    /// no-op. Must consume no RNG.
    fn observe_fault(&mut self, _obs: &FaultObs) {}

    /// Name for logs.
    fn name(&self) -> String;
}

/// The paper's fixed schedule.
pub struct FixedPolicy(pub usize);

impl BlockPolicy for FixedPolicy {
    fn next_n_c(&mut self, _b: usize, remaining: usize, _t: f64) -> usize {
        self.0.min(remaining).max(1)
    }

    fn name(&self) -> String {
        format!("fixed({})", self.0)
    }
}

/// The closed-loop channel-adaptive payload controller: an online
/// channel estimator ([`ControlEstimator`]) digests the per-packet
/// ACK/timing observations the scheduler feeds through
/// [`BlockPolicy::observe`], and a remaining-budget re-optimizer
/// ([`Replanner`]) re-solves the Corollary-1 argmin at block
/// boundaries with the elapsed time, untransmitted-sample count and
/// estimated channel slowdown substituted in.
///
/// Deterministic by construction: it consumes no RNG and reads only
/// observed events, so it preserves the scheduler's stream discipline.
/// On a static channel with exact estimator constants the slowdown
/// estimate never moves, re-planning is a no-op, and the controller is
/// bit-identical to `FixedPolicy(ñ_c)` at the channel-aware
/// recommendation (asserted in `rust/tests/scenario_parity.rs`).
pub struct ControlPolicy {
    est: ControlEstimator,
    replanner: Replanner,
    /// Re-plan every `replan_every`-th block boundary (1 = every block).
    replan_every: usize,
}

impl ControlPolicy {
    pub fn new(
        est: ControlEstimator,
        replanner: Replanner,
        replan_every: usize,
    ) -> ControlPolicy {
        assert!(replan_every >= 1, "replan interval must be >= 1");
        ControlPolicy { est, replanner, replan_every }
    }

    /// The currently planned payload size (test hook).
    pub fn planned_n_c(&self) -> usize {
        self.replanner.current()
    }
}

impl BlockPolicy for ControlPolicy {
    fn next_n_c(&mut self, block: usize, remaining: usize, t_now: f64)
        -> usize {
        if (block - 1) % self.replan_every == 0 {
            // expected remaining blocks under the current plan — the
            // estimator's mixing horizon
            let horizon = (remaining as f64
                / self.replanner.current().max(1) as f64)
                .ceil()
                .max(1.0);
            let slowdown = self.est.horizon_slowdown(horizon);
            self.replanner.replan(remaining, t_now, slowdown);
        }
        self.replanner.current().min(remaining).max(1)
    }

    fn observe(&mut self, obs: &PacketObs) {
        self.est.observe(obs);
    }

    fn observe_fault(&mut self, obs: &FaultObs) {
        match *obs {
            FaultObs::Timeout { nominal, waited, .. } => {
                // censored observation: the packet occupied the link for
                // at least `waited`. Feeding the finite censoring point
                // (not INFINITY, which would poison an EMA forever)
                // still drags the slowdown estimate up, shrinking the
                // re-planned payloads.
                self.est.observe(&PacketObs {
                    nominal,
                    occupancy: waited,
                    attempts: 1,
                });
            }
            FaultObs::Eviction { .. } => {
                // lost capacity changes the residual problem even when
                // the slowdown estimate has not moved: force the next
                // replan through the drift gate
                self.replanner.invalidate();
            }
        }
    }

    fn name(&self) -> String {
        format!(
            "control(est={}, replan={})",
            self.est.name(),
            self.replan_every
        )
    }
}

/// Does the edge node compute while the channel is busy?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapMode {
    /// The paper's protocol: transmission and SGD overlap.
    Pipelined,
    /// The non-pipelined baseline: the edge idles during every
    /// transmission and only computes afterwards.
    Sequential,
}

/// Draw up to `n_c` samples uniformly without replacement from
/// `remaining` (partial Fisher–Yates into the tail — O(k) per block, the
/// seed `DeviceTransmitter` discipline bit-for-bit) and gather them from
/// `ds` into `frame`.
pub(crate) fn draw_block(
    ds: &Dataset,
    remaining: &mut Vec<u32>,
    rng: &mut Pcg32,
    n_c: usize,
    frame: &mut BlockFrame,
) {
    let len = remaining.len();
    let k = n_c.min(len);
    for i in 0..k {
        let j = rng.gen_range((len - i) as u64) as usize;
        remaining.swap(j, len - 1 - i);
    }
    frame.clear();
    for &i in &remaining[len - k..] {
        frame.x.extend_from_slice(ds.row(i as usize));
        frame.y.push(ds.label(i as usize));
    }
    remaining.truncate(len - k);
}

/// The paper's setting: one device holding the whole dataset.
pub struct SingleDeviceSource<'a> {
    ds: &'a Dataset,
    remaining: Vec<u32>,
    rng: Pcg32,
}

impl<'a> SingleDeviceSource<'a> {
    pub fn new(ds: &'a Dataset, seed: u64) -> SingleDeviceSource<'a> {
        Self::with_buf(ds, seed, Vec::with_capacity(ds.n))
    }

    /// Build reusing `buf` as the untransmitted-index scratch (cleared
    /// and refilled; the workspace path — no allocation after warm-up).
    pub fn with_buf(
        ds: &'a Dataset,
        seed: u64,
        mut buf: Vec<u32>,
    ) -> SingleDeviceSource<'a> {
        buf.clear();
        buf.extend(0..ds.n as u32);
        SingleDeviceSource {
            ds,
            remaining: buf,
            rng: Pcg32::new(seed, STREAM_DEVICE),
        }
    }

    /// Hand the index scratch back for reuse.
    pub fn into_buf(self) -> Vec<u32> {
        self.remaining
    }
}

impl TrafficSource for SingleDeviceSource<'_> {
    fn remaining(&self) -> usize {
        self.remaining.len()
    }

    fn poll(
        &mut self,
        n_c: usize,
        _t_now: f64,
        frame: &mut BlockFrame,
    ) -> SourcePoll {
        if self.remaining.is_empty() {
            return SourcePoll::Exhausted;
        }
        draw_block(self.ds, &mut self.remaining, &mut self.rng, n_c, frame);
        SourcePoll::Block { device: 0 }
    }

    fn name(&self) -> String {
        "single-device".to_string()
    }

    fn evict(&mut self, device: usize) -> usize {
        if device != 0 {
            return 0;
        }
        let shed = self.remaining.len();
        self.remaining.clear();
        shed
    }
}

/// One device's transmit state in a multi-device schedule. Shared with
/// the sharded source (`coordinator::shard`), whose shard workers own
/// disjoint ranges of these lanes — a lane is only ever touched by its
/// owning shard thread there.
pub(crate) struct DeviceLane {
    pub(crate) remaining: Vec<u32>,
    pub(crate) rng: Pcg32,
}

/// `k` devices holding disjoint shards, taking turns on the shared
/// uplink (paper Sec. 6). Device `i` draws from stream `STREAM_DEVICE`
/// seeded `seed + 1000·i`, so `k = 1` is bit-identical to
/// [`SingleDeviceSource`] (asserted in `scenario_parity.rs`).
///
/// Kept as a dedicated source (rather than a wrapper over
/// [`ScheduledSource`] + [`RoundRobinScheduler`], to which it is
/// bit-identical on stateless channels — asserted in
/// `scenario_parity.rs`): it is the legacy zero-extra-state fast path
/// and needs no slowdown table. Behavioral changes to either poll loop
/// are policed by that parity test.
pub struct RoundRobinSource<'a> {
    shards: &'a [Dataset],
    lanes: Vec<DeviceLane>,
    turn: usize,
}

impl<'a> RoundRobinSource<'a> {
    pub fn new(shards: &'a [Dataset], seed: u64) -> RoundRobinSource<'a> {
        Self::with_bufs(shards, seed, Vec::new())
    }

    /// Build reusing `bufs` as the per-lane index scratch (resized to
    /// the shard count; each lane buffer is cleared and refilled).
    pub fn with_bufs(
        shards: &'a [Dataset],
        seed: u64,
        mut bufs: Vec<Vec<u32>>,
    ) -> RoundRobinSource<'a> {
        assert!(!shards.is_empty(), "need at least one device");
        bufs.resize_with(shards.len(), Vec::new);
        let lanes = shards
            .iter()
            .zip(bufs)
            .enumerate()
            .map(|(i, (shard, mut buf))| {
                buf.clear();
                buf.extend(0..shard.n as u32);
                DeviceLane {
                    remaining: buf,
                    rng: Pcg32::new(
                        seed.wrapping_add(1000 * i as u64),
                        STREAM_DEVICE,
                    ),
                }
            })
            .collect();
        RoundRobinSource { shards, lanes, turn: 0 }
    }

    /// Hand the per-lane index scratch back for reuse.
    pub fn into_bufs(self) -> Vec<Vec<u32>> {
        self.lanes.into_iter().map(|l| l.remaining).collect()
    }
}

/// One lane's observable state, handed to a [`DeviceScheduler`] pick.
#[derive(Clone, Copy, Debug)]
pub struct LaneView {
    /// Untransmitted samples still held by this device.
    pub remaining: usize,
    /// Samples this device has already transmitted.
    pub sent: usize,
    /// Expected slowdown of this device's uplink lane (strictly
    /// positive — [`ScheduledSource`] enforces `> 0`, and the
    /// proportional-fair debt divides by it; 1 = the ideal unit-rate
    /// link — see `ChannelSpec::expected_slowdown`).
    pub slowdown: f64,
}

/// Which device transmits next on a heterogeneous multi-lane uplink.
///
/// `pick` is called only when at least one lane has `remaining > 0` and
/// must return such a lane; it sees every lane's backlog, service count
/// and expected link slowdown, and may keep internal state (e.g. a
/// rotation cursor). Implementations must be deterministic — device
/// selection randomness lives in the per-lane sample draw
/// (`STREAM_DEVICE`), not in the scheduler.
pub trait DeviceScheduler {
    /// Index of the next transmitting lane.
    fn pick(&mut self, lanes: &[LaneView]) -> usize;

    /// Name for logs.
    fn name(&self) -> String;
}

/// Strict rotation over non-empty lanes — the Sec. 6 baseline. Exactly
/// reproduces [`RoundRobinSource`]'s turn order (asserted in
/// `rust/tests/scenario_parity.rs`).
#[derive(Clone, Debug, Default)]
pub struct RoundRobinScheduler {
    turn: usize,
}

impl RoundRobinScheduler {
    pub fn new() -> RoundRobinScheduler {
        RoundRobinScheduler { turn: 0 }
    }
}

impl DeviceScheduler for RoundRobinScheduler {
    fn pick(&mut self, lanes: &[LaneView]) -> usize {
        let k = lanes.len();
        for off in 0..k {
            let lane = (self.turn + off) % k;
            if lanes[lane].remaining > 0 {
                self.turn = lane + 1;
                return lane;
            }
        }
        panic!("pick() called with every lane empty");
    }

    fn name(&self) -> String {
        "round-robin".to_string()
    }
}

/// Fastest-expected-finish greedy: among lanes with data, pick the one
/// with the smallest expected slowdown (its block occupies the shared
/// uplink for the least expected time). Ties rotate round-robin from
/// the last pick, so identical lanes make this scheduler *exactly*
/// round-robin (asserted in `rust/tests/scenario_parity.rs`).
#[derive(Clone, Debug, Default)]
pub struct GreedyScheduler {
    turn: usize,
}

impl GreedyScheduler {
    pub fn new() -> GreedyScheduler {
        GreedyScheduler { turn: 0 }
    }
}

impl DeviceScheduler for GreedyScheduler {
    fn pick(&mut self, lanes: &[LaneView]) -> usize {
        let k = lanes.len();
        let mut best: Option<usize> = None;
        for off in 0..k {
            let lane = (self.turn + off) % k;
            if lanes[lane].remaining == 0 {
                continue;
            }
            // strict < keeps the first lane in rotation order among
            // ties — the round-robin pick when all lanes are identical
            if best
                .map_or(true, |b| lanes[lane].slowdown < lanes[b].slowdown)
            {
                best = Some(lane);
            }
        }
        let lane = best.expect("pick() called with every lane empty");
        self.turn = lane + 1;
        lane
    }

    fn name(&self) -> String {
        "greedy".to_string()
    }
}

/// Data-debt proportional-fair: pick the lane maximizing
/// `remaining / ((1 + sent) · slowdown)` — devices holding a large
/// untransmitted backlog relative to the service they have already
/// received go first, discounted by how slow their link is. Ties rotate
/// round-robin from the last pick.
#[derive(Clone, Debug, Default)]
pub struct PropFairScheduler {
    turn: usize,
}

impl PropFairScheduler {
    pub fn new() -> PropFairScheduler {
        PropFairScheduler { turn: 0 }
    }
}

impl DeviceScheduler for PropFairScheduler {
    fn pick(&mut self, lanes: &[LaneView]) -> usize {
        let k = lanes.len();
        let debt = |l: &LaneView| {
            l.remaining as f64 / ((1.0 + l.sent as f64) * l.slowdown)
        };
        let mut best: Option<usize> = None;
        for off in 0..k {
            let lane = (self.turn + off) % k;
            if lanes[lane].remaining == 0 {
                continue;
            }
            if best.map_or(true, |b| debt(&lanes[lane]) > debt(&lanes[b])) {
                best = Some(lane);
            }
        }
        let lane = best.expect("pick() called with every lane empty");
        self.turn = lane + 1;
        lane
    }

    fn name(&self) -> String {
        "proportional-fair".to_string()
    }
}

/// `k` heterogeneous devices holding disjoint shards: a
/// [`DeviceScheduler`] picks who transmits next, each device draws its
/// own samples on stream `STREAM_DEVICE` seeded `seed + 1000·i` (the
/// [`RoundRobinSource`] discipline, so `k = 1` is bit-identical to
/// [`SingleDeviceSource`] under EVERY scheduler — asserted in
/// `rust/tests/scenario_parity.rs`). Pair with a
/// [`MultiLaneChannel`](crate::channel::MultiLaneChannel) to give each
/// device its own link; the scheduler core routes each block to the
/// picked device's lane via `Channel::select_lane`.
pub struct ScheduledSource<'a, S: DeviceScheduler> {
    shards: &'a [Dataset],
    lanes: Vec<DeviceLane>,
    /// Samples transmitted per lane (the scheduler's service counter).
    sent: Vec<usize>,
    /// Per-lane expected link slowdowns (shared with the lane channels).
    slowdowns: &'a [f64],
    /// LaneView scratch, rebuilt per poll (no per-poll allocation).
    views: Vec<LaneView>,
    sched: S,
}

impl<'a, S: DeviceScheduler> ScheduledSource<'a, S> {
    pub fn new(
        shards: &'a [Dataset],
        seed: u64,
        sched: S,
        slowdowns: &'a [f64],
    ) -> ScheduledSource<'a, S> {
        Self::with_bufs(shards, seed, Vec::new(), sched, slowdowns)
    }

    /// Build reusing `bufs` as the per-lane index scratch (the same
    /// recycling contract as [`RoundRobinSource::with_bufs`]).
    pub fn with_bufs(
        shards: &'a [Dataset],
        seed: u64,
        mut bufs: Vec<Vec<u32>>,
        sched: S,
        slowdowns: &'a [f64],
    ) -> ScheduledSource<'a, S> {
        assert!(!shards.is_empty(), "need at least one device");
        assert_eq!(
            shards.len(),
            slowdowns.len(),
            "one slowdown per device lane"
        );
        assert!(
            slowdowns.iter().all(|s| *s > 0.0),
            "lane slowdowns must be positive"
        );
        bufs.resize_with(shards.len(), Vec::new);
        let lanes: Vec<DeviceLane> = shards
            .iter()
            .zip(bufs)
            .enumerate()
            .map(|(i, (shard, mut buf))| {
                buf.clear();
                buf.extend(0..shard.n as u32);
                DeviceLane {
                    remaining: buf,
                    rng: Pcg32::new(
                        seed.wrapping_add(1000 * i as u64),
                        STREAM_DEVICE,
                    ),
                }
            })
            .collect();
        ScheduledSource {
            shards,
            sent: vec![0; lanes.len()],
            views: Vec::with_capacity(lanes.len()),
            lanes,
            slowdowns,
            sched,
        }
    }

    /// Hand the per-lane index scratch back for reuse.
    pub fn into_bufs(self) -> Vec<Vec<u32>> {
        self.lanes.into_iter().map(|l| l.remaining).collect()
    }
}

impl<S: DeviceScheduler> TrafficSource for ScheduledSource<'_, S> {
    fn remaining(&self) -> usize {
        self.lanes.iter().map(|l| l.remaining.len()).sum()
    }

    fn poll(
        &mut self,
        n_c: usize,
        _t_now: f64,
        frame: &mut BlockFrame,
    ) -> SourcePoll {
        if self.lanes.iter().all(|l| l.remaining.is_empty()) {
            return SourcePoll::Exhausted;
        }
        self.views.clear();
        self.views.extend(self.lanes.iter().zip(self.sent.iter()).zip(
            self.slowdowns.iter(),
        ).map(
            |((lane, &sent), &slowdown)| LaneView {
                remaining: lane.remaining.len(),
                sent,
                slowdown,
            },
        ));
        let device = self.sched.pick(&self.views);
        let lane = &mut self.lanes[device];
        assert!(
            !lane.remaining.is_empty(),
            "{} picked empty lane {device}",
            self.sched.name()
        );
        draw_block(
            &self.shards[device],
            &mut lane.remaining,
            &mut lane.rng,
            n_c,
            frame,
        );
        self.sent[device] += frame.len();
        SourcePoll::Block { device }
    }

    fn name(&self) -> String {
        format!("scheduled({}, {})", self.lanes.len(), self.sched.name())
    }

    fn evict(&mut self, device: usize) -> usize {
        self.lanes.get_mut(device).map_or(0, |lane| {
            let shed = lane.remaining.len();
            lane.remaining.clear();
            shed
        })
    }
}

impl TrafficSource for RoundRobinSource<'_> {
    fn remaining(&self) -> usize {
        self.lanes.iter().map(|l| l.remaining.len()).sum()
    }

    fn poll(
        &mut self,
        n_c: usize,
        _t_now: f64,
        frame: &mut BlockFrame,
    ) -> SourcePoll {
        if self.lanes.iter().all(|l| l.remaining.is_empty()) {
            return SourcePoll::Exhausted;
        }
        while self.lanes[self.turn % self.lanes.len()].remaining.is_empty()
        {
            self.turn += 1;
        }
        let device = self.turn % self.lanes.len();
        self.turn += 1;
        let lane = &mut self.lanes[device];
        draw_block(
            &self.shards[device],
            &mut lane.remaining,
            &mut lane.rng,
            n_c,
            frame,
        );
        SourcePoll::Block { device }
    }

    fn name(&self) -> String {
        format!("round-robin({})", self.lanes.len())
    }

    fn evict(&mut self, device: usize) -> usize {
        self.lanes.get_mut(device).map_or(0, |lane| {
            let shed = lane.remaining.len();
            lane.remaining.clear();
            shed
        })
    }
}

/// A device whose samples only become available over time: sample `i`
/// (in dataset order) arrives at the device at `i / rate`. The device
/// greedily frames up to `n_c` of the arrived-but-unsent samples, chosen
/// uniformly without replacement; when none have arrived yet it reports
/// [`SourcePoll::Idle`] until the next arrival. As `rate → ∞` every
/// sample is available at `t = 0` and the source is bit-identical to
/// [`SingleDeviceSource`].
pub struct OnlineArrivalSource<'a> {
    ds: &'a Dataset,
    /// Arrived but not yet transmitted (dataset indices).
    pool: Vec<u32>,
    /// Samples arrived so far (prefix of dataset order).
    arrived: usize,
    rate: f64,
    rng: Pcg32,
}

impl<'a> OnlineArrivalSource<'a> {
    /// `rate` = samples arriving per normalized time unit (`> 0`;
    /// `f64::INFINITY` recovers the all-data-up-front setting).
    pub fn new(ds: &'a Dataset, rate: f64, seed: u64) -> Self {
        Self::with_buf(ds, rate, seed, Vec::with_capacity(ds.n))
    }

    /// Build reusing `buf` as the arrived-but-unsent scratch (cleared;
    /// the workspace path — no allocation after warm-up).
    pub fn with_buf(
        ds: &'a Dataset,
        rate: f64,
        seed: u64,
        mut buf: Vec<u32>,
    ) -> Self {
        assert!(rate > 0.0, "arrival rate must be positive");
        buf.clear();
        OnlineArrivalSource {
            ds,
            pool: buf,
            arrived: 0,
            rate,
            rng: Pcg32::new(seed, STREAM_DEVICE),
        }
    }

    /// Hand the index scratch back for reuse.
    pub fn into_buf(self) -> Vec<u32> {
        self.pool
    }

    fn arrival_time(&self, i: usize) -> f64 {
        i as f64 / self.rate
    }

    /// Move every sample with arrival time ≤ `t_now` into the pool.
    fn absorb(&mut self, t_now: f64) {
        while self.arrived < self.ds.n
            && self.arrival_time(self.arrived) <= t_now
        {
            self.pool.push(self.arrived as u32);
            self.arrived += 1;
        }
    }
}

impl TrafficSource for OnlineArrivalSource<'_> {
    fn remaining(&self) -> usize {
        // everything not yet transmitted, arrived or not
        self.pool.len() + (self.ds.n - self.arrived)
    }

    fn poll(
        &mut self,
        n_c: usize,
        t_now: f64,
        frame: &mut BlockFrame,
    ) -> SourcePoll {
        self.absorb(t_now);
        if self.pool.is_empty() {
            if self.arrived >= self.ds.n {
                return SourcePoll::Exhausted;
            }
            return SourcePoll::Idle {
                until: self.arrival_time(self.arrived),
            };
        }
        draw_block(self.ds, &mut self.pool, &mut self.rng, n_c, frame);
        SourcePoll::Block { device: 0 }
    }

    fn name(&self) -> String {
        format!("online-arrivals({})", self.rate)
    }

    fn evict(&mut self, device: usize) -> usize {
        if device != 0 {
            return 0;
        }
        // shed the arrived pool AND every future arrival: an evicted
        // device never transmits again
        let shed = self.pool.len() + (self.ds.n - self.arrived);
        self.pool.clear();
        self.arrived = self.ds.n;
        shed
    }
}

/// Run the pipelined protocol under pluggable traffic/block/overlap
/// policies — the one event loop every variant shares.
///
/// Timing, counters and the event stream reproduce the seed `run_des`
/// exactly when driven by `SingleDeviceSource` + `FixedPolicy` +
/// `Pipelined`. Convenience wrapper over [`run_schedule_with`] with a
/// fresh [`RunWorkspace`]; sweeps reuse one workspace instead.
pub fn run_schedule(
    ds: &Dataset,
    cfg: &DesConfig,
    source: &mut dyn TrafficSource,
    policy: &mut dyn BlockPolicy,
    mode: OverlapMode,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let mut ws = RunWorkspace::new();
    let stats =
        run_schedule_with(&mut ws, ds, cfg, source, policy, mode, channel, exec)?;
    Ok(ws.into_result(stats))
}

/// [`run_schedule`] against a reusable [`RunWorkspace`]: identical
/// semantics, but every buffer (frame, events, store, weights, SGD index
/// batch, curve) comes from — and returns to — `ws`, so a run allocates
/// nothing after the workspace has warmed up. Returns the stack-only
/// [`RunStats`]; heap outputs stay in `ws` (see its accessors).
#[allow(clippy::too_many_arguments)]
pub fn run_schedule_with(
    ws: &mut RunWorkspace,
    ds: &Dataset,
    cfg: &DesConfig,
    source: &mut dyn TrafficSource,
    policy: &mut dyn BlockPolicy,
    mode: OverlapMode,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunStats> {
    run_schedule_with_opts(
        ws, ds, cfg, source, policy, mode, channel, exec, true,
    )
}

/// [`run_schedule_with`] with loss evaluation optionally disabled.
/// `eval_losses = false` is the batched-seed trace pass: the DES
/// trajectory (RNG draws, timelines, index stream, counters) is
/// unchanged — loss recording is pure — but no full-dataset loss is
/// computed and `RunStats::final_loss` comes back `NAN`; the batch
/// runner recomputes per-lane losses once after replay.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_schedule_with_opts(
    ws: &mut RunWorkspace,
    ds: &Dataset,
    cfg: &DesConfig,
    source: &mut dyn TrafficSource,
    policy: &mut dyn BlockPolicy,
    mode: OverlapMode,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
    eval_losses: bool,
) -> Result<RunStats> {
    ws.events.reset(cfg.event_capacity);
    ws.frame.reset(cfg.n_c.max(1).min(ds.n), ds.d);
    let mut trainer = EdgeTrainer::from_space_opts(
        std::mem::take(&mut ws.train),
        ds,
        cfg,
        eval_losses,
    );
    let outcome = schedule_loop(
        &mut trainer,
        &mut ws.frame,
        &mut ws.events,
        ds,
        cfg,
        source,
        policy,
        mode,
        channel,
        exec,
    );
    let stats = outcome.map(|c| RunStats {
        final_loss: if eval_losses { trainer.full_loss() } else { f64::NAN },
        updates: trainer.updates,
        blocks_sent: c.blocks_sent,
        blocks_delivered: c.blocks_delivered,
        samples_delivered: c.samples_delivered,
        blocks_missed: c.blocks_missed,
        retransmissions: c.retransmissions,
        timeouts: c.timeouts,
        blocks_abandoned: c.blocks_abandoned,
        evictions: c.evictions,
        samples_lost: c.samples_lost,
        degraded_completion: c.degraded_completion,
        case: c.case,
        backend: exec.name(),
    });
    // the workspace gets its buffers back on success AND on error, so
    // an error mid-sweep doesn't silently degrade later runs to
    // fresh-allocation mode
    ws.train = trainer.into_space();
    // Fold the completed run's totals into the process-global telemetry
    // sink. This happens AFTER the loop from counters it already
    // produced — zero hot-loop instrumentation, so the write-only
    // contract (no RNG, no control flow; see util/telemetry.rs) holds
    // structurally.
    if let Ok(stats) = &stats {
        crate::util::telemetry::global().with(|m| {
            m.sched.runs.inc();
            m.sched
                .events
                .add((ws.events.events().len() + ws.events.dropped()) as u64);
            m.sched.packets_sent.add(stats.blocks_sent as u64);
            m.sched.packets_resent.add(stats.retransmissions);
            m.sched.timeouts.add(stats.timeouts);
            m.sched.evictions.add(stats.evictions as u64);
        });
    }
    stats
}

/// The fallible protocol loop's counters (everything `RunStats` needs
/// beyond what the trainer itself holds).
struct LoopCounters {
    blocks_sent: usize,
    blocks_delivered: usize,
    samples_delivered: usize,
    blocks_missed: usize,
    retransmissions: u64,
    timeouts: u64,
    blocks_abandoned: usize,
    evictions: usize,
    samples_lost: usize,
    degraded_completion: bool,
    case: TimelineCase,
}

#[allow(clippy::too_many_arguments)]
fn schedule_loop(
    trainer: &mut EdgeTrainer<'_>,
    frame: &mut BlockFrame,
    events: &mut EventLog,
    ds: &Dataset,
    cfg: &DesConfig,
    source: &mut dyn TrafficSource,
    policy: &mut dyn BlockPolicy,
    mode: OverlapMode,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<LoopCounters> {
    let mut chan_rng = Pcg32::new(cfg.seed, STREAM_CHANNEL);

    // protocol-hardening knobs (all-default = the paper's original
    // protocol: wait for every ACK however long it takes)
    let hard = &cfg.faults;
    let timeout_enabled = hard.enabled();
    // per-device consecutive-timeout counters, grown on demand so the
    // fault-free path allocates nothing extra
    let mut consec_timeouts: Vec<u32> = Vec::new();

    let mut t_send = 0.0f64;
    let mut block = 1usize;
    let mut blocks_sent = 0usize;
    let mut blocks_delivered = 0usize;
    let mut samples_delivered = 0usize;
    let mut blocks_missed = 0usize;
    let mut retransmissions = 0u64;
    let mut timeouts = 0u64;
    let mut blocks_abandoned = 0usize;
    let mut evictions = 0usize;
    let mut samples_lost = 0usize;

    while t_send < cfg.t_budget {
        let n_c = policy.next_n_c(block, source.remaining(), t_send);
        let device = match source.poll(n_c, t_send, frame) {
            SourcePoll::Exhausted => break,
            SourcePoll::Idle { until } => {
                // channel idle: the edge keeps computing (pipelined) or
                // keeps idling (sequential) until data shows up
                let until = until.max(t_send).min(cfg.t_budget);
                match mode {
                    OverlapMode::Pipelined => {
                        trainer.advance_to(until, exec, events)?
                    }
                    OverlapMode::Sequential => trainer.skip_to(until),
                }
                if until <= t_send {
                    // a source must make progress; treat as exhausted
                    break;
                }
                t_send = until;
                continue;
            }
            SourcePoll::Block { device } => device,
        };
        let payload = frame.len();
        let duration = payload as f64 + cfg.n_o;
        events.push(t_send, EventKind::BlockSent { block, payload, device });
        blocks_sent += 1;
        // route the block through the transmitting device's lane
        // (no-op for single-link channels; consumes no randomness)
        channel.select_lane(device);
        // ARQ retry loop: one iteration per send attempt of THIS block.
        // With the timeout machinery disarmed (the default) the first
        // iteration always breaks, so the fault-free path is the
        // historical single-shot transmit, bit for bit.
        let mut resend = 0u32;
        loop {
            let delivery = channel.transmit(t_send, duration, &mut chan_rng);
            // NaN/INFINITY-proof: a non-finite occupancy always times out
            let timed_out = timeout_enabled
                && !(delivery.arrival - t_send
                    <= hard.timeout_mult * duration);
            if !timed_out {
                retransmissions += (delivery.attempts - 1) as u64;
                // feed the delivery observation to the policy (no-op for
                // open-loop policies; closed-loop control updates its
                // channel belief — consumes no randomness either way)
                policy.observe(&PacketObs {
                    nominal: duration,
                    occupancy: delivery.arrival - t_send,
                    attempts: delivery.attempts,
                });
                if timeout_enabled {
                    if let Some(c) = consec_timeouts.get_mut(device) {
                        *c = 0;
                    }
                }
                if delivery.arrival < cfg.t_budget {
                    // train (or idle) through the transmission window,
                    // then ingest the delivered block
                    match mode {
                        OverlapMode::Pipelined => {
                            trainer.advance_to(delivery.arrival, exec, events)?
                        }
                        OverlapMode::Sequential => {
                            trainer.skip_to(delivery.arrival)
                        }
                    }
                    trainer.ingest_block(
                        block,
                        delivery.arrival,
                        &frame.x,
                        &frame.y,
                    );
                    blocks_delivered += 1;
                    samples_delivered += payload;
                    events.push(
                        delivery.arrival,
                        EventKind::BlockDelivered {
                            block,
                            payload,
                            attempts: delivery.attempts,
                        },
                    );
                } else {
                    match mode {
                        OverlapMode::Pipelined => {
                            trainer.advance_to(cfg.t_budget, exec, events)?
                        }
                        OverlapMode::Sequential => {
                            trainer.skip_to(cfg.t_budget)
                        }
                    }
                    blocks_missed += 1;
                    events.push(
                        cfg.t_budget,
                        EventKind::BlockMissedDeadline { block },
                    );
                }
                t_send = delivery.arrival;
                break;
            }
            // --- the attempt hit its per-packet timeout: give up on
            // the in-flight packet at t_out and decide what to do next
            timeouts += 1;
            let t_out = t_send + hard.timeout_mult * duration;
            events.push(
                t_out.min(cfg.t_budget),
                EventKind::BlockTimedOut { block, resend },
            );
            match mode {
                OverlapMode::Pipelined => {
                    trainer.advance_to(t_out.min(cfg.t_budget), exec, events)?
                }
                OverlapMode::Sequential => {
                    trainer.skip_to(t_out.min(cfg.t_budget))
                }
            }
            policy.observe_fault(&FaultObs::Timeout {
                device,
                nominal: duration,
                waited: hard.timeout_mult * duration,
            });
            t_send = t_out;
            if consec_timeouts.len() <= device {
                consec_timeouts.resize(device + 1, 0);
            }
            consec_timeouts[device] += 1;
            if hard.evict_after > 0
                && consec_timeouts[device] >= hard.evict_after
            {
                // evict the device: shed its undelivered shard (bias)
                // instead of letting it block the deadline (variance)
                let lost = payload + source.evict(device);
                evictions += 1;
                blocks_abandoned += 1;
                samples_lost += lost;
                events.push(
                    t_send.min(cfg.t_budget),
                    EventKind::DeviceEvicted { device, lost_samples: lost },
                );
                policy.observe_fault(&FaultObs::Eviction {
                    device,
                    lost_samples: lost,
                });
                break;
            }
            if resend >= hard.retry_budget {
                // retry budget exhausted: abandon the block, keep the
                // device
                blocks_abandoned += 1;
                samples_lost += payload;
                events.push(
                    t_send.min(cfg.t_budget),
                    EventKind::BlockAbandoned { block },
                );
                break;
            }
            resend += 1;
            // deterministic exponential backoff: duration · 2^(resend−1)
            let backoff = duration * (1u64 << (resend - 1).min(20)) as f64;
            let t_retry = t_send + backoff;
            match mode {
                OverlapMode::Pipelined => trainer.advance_to(
                    t_retry.min(cfg.t_budget),
                    exec,
                    events,
                )?,
                OverlapMode::Sequential => {
                    trainer.skip_to(t_retry.min(cfg.t_budget))
                }
            }
            t_send = t_retry;
            if t_send >= cfg.t_budget {
                // no time left to retry: the block misses the deadline
                blocks_missed += 1;
                events.push(
                    cfg.t_budget,
                    EventKind::BlockMissedDeadline { block },
                );
                break;
            }
            // retry the SAME frame (the samples were never delivered)
        }
        block += 1;
    }
    // tail: no more transmissions; compute until the deadline (Fig. 2(b))
    trainer.advance_to(cfg.t_budget, exec, events)?;
    trainer.finish(exec)?;

    let case = if samples_delivered >= ds.n {
        TimelineCase::Full
    } else {
        TimelineCase::Partial
    };
    // graceful degradation: every sample was either delivered or
    // deliberately shed, and nothing arrived late — the protocol traded
    // bias for the deadline instead of stalling
    let degraded_completion = blocks_missed == 0
        && samples_lost > 0
        && samples_delivered + samples_lost >= ds.n;
    events.push(
        cfg.t_budget,
        EventKind::Finished {
            updates: trainer.updates,
            delivered_samples: samples_delivered,
        },
    );

    Ok(LoopCounters {
        blocks_sent,
        blocks_delivered,
        samples_delivered,
        blocks_missed,
        retransmissions,
        timeouts,
        blocks_abandoned,
        evictions,
        samples_lost,
        degraded_completion,
        case,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::des::run_des;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;

    fn small_ds(n: usize) -> Dataset {
        synth_calhousing(&SynthSpec { n, ..Default::default() })
    }

    fn exec(ds: &Dataset, cfg: &DesConfig) -> NativeExecutor {
        NativeExecutor::new(
            RidgeModel::new(ds.d, cfg.lambda, ds.n),
            cfg.alpha,
        )
    }

    #[test]
    fn explicit_scheduler_matches_run_des() {
        let ds = small_ds(500);
        let cfg = DesConfig {
            event_capacity: 1 << 14,
            ..DesConfig::paper(64, 10.0, 900.0, 13)
        };
        let des = run_des(&ds, &cfg, &mut IdealChannel, &mut exec(&ds, &cfg))
            .unwrap();
        let mut source = SingleDeviceSource::new(&ds, cfg.seed);
        let mut policy = FixedPolicy(cfg.n_c);
        let uni = run_schedule(
            &ds,
            &cfg,
            &mut source,
            &mut policy,
            OverlapMode::Pipelined,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(des.final_w, uni.final_w);
        assert_eq!(des.curve, uni.curve);
        assert_eq!(des.events, uni.events);
        assert_eq!(des.updates, uni.updates);
        assert_eq!(des.blocks_sent, uni.blocks_sent);
    }

    #[test]
    fn infinite_arrival_rate_recovers_single_device() {
        let ds = small_ds(400);
        let cfg = DesConfig {
            record_blocks: false,
            ..DesConfig::paper(50, 5.0, 800.0, 4)
        };
        let des = run_des(&ds, &cfg, &mut IdealChannel, &mut exec(&ds, &cfg))
            .unwrap();
        let mut source =
            OnlineArrivalSource::new(&ds, f64::INFINITY, cfg.seed);
        let mut policy = FixedPolicy(cfg.n_c);
        let online = run_schedule(
            &ds,
            &cfg,
            &mut source,
            &mut policy,
            OverlapMode::Pipelined,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(des.final_w, online.final_w);
        assert_eq!(des.updates, online.updates);
        assert_eq!(des.samples_delivered, online.samples_delivered);
    }

    #[test]
    fn slow_arrivals_throttle_delivery_but_still_finish() {
        let ds = small_ds(300);
        // arrivals take n/rate = 600 time units; budget is generous
        let cfg = DesConfig {
            alpha: 1e-3,
            record_blocks: false,
            ..DesConfig::paper(30, 2.0, 2000.0, 8)
        };
        let mut source = OnlineArrivalSource::new(&ds, 0.5, cfg.seed);
        let mut policy = FixedPolicy(cfg.n_c);
        let run = run_schedule(
            &ds,
            &cfg,
            &mut source,
            &mut policy,
            OverlapMode::Pipelined,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(run.samples_delivered, ds.n);
        assert_eq!(run.case, TimelineCase::Full);
        assert!(run.final_loss.is_finite());
        // throttled arrivals force more, smaller blocks than n/n_c
        assert!(run.blocks_sent >= ds.n / cfg.n_c);
    }

    #[test]
    fn frame_reuse_keeps_capacity() {
        let ds = small_ds(200);
        let mut frame = BlockFrame::with_capacity(32, ds.d);
        let mut remaining: Vec<u32> = (0..ds.n as u32).collect();
        let mut rng = Pcg32::new(1, STREAM_DEVICE);
        draw_block(&ds, &mut remaining, &mut rng, 32, &mut frame);
        assert_eq!(frame.len(), 32);
        assert_eq!(frame.x.len(), 32 * ds.d);
        let cap_x = frame.x.capacity();
        draw_block(&ds, &mut remaining, &mut rng, 32, &mut frame);
        assert_eq!(frame.len(), 32);
        assert_eq!(frame.x.capacity(), cap_x, "no per-block reallocation");
        assert_eq!(remaining.len(), ds.n - 64);
    }

    fn views(lanes: &[(usize, usize, f64)]) -> Vec<LaneView> {
        lanes
            .iter()
            .map(|&(remaining, sent, slowdown)| LaneView {
                remaining,
                sent,
                slowdown,
            })
            .collect()
    }

    #[test]
    fn greedy_prefers_the_fastest_lane_and_rotates_ties() {
        let mut greedy = GreedyScheduler::new();
        // lane 1 is fastest while it has data
        let v = views(&[(10, 0, 2.0), (10, 0, 1.0), (10, 0, 1.5)]);
        assert_eq!(greedy.pick(&v), 1);
        // fastest lane empty -> next-fastest
        let v = views(&[(10, 0, 2.0), (0, 10, 1.0), (10, 0, 1.5)]);
        assert_eq!(greedy.pick(&v), 2);
        // identical lanes: ties rotate exactly like round-robin
        let mut greedy = GreedyScheduler::new();
        let mut rr = RoundRobinScheduler::new();
        let v = views(&[(5, 0, 1.0), (5, 0, 1.0), (5, 0, 1.0)]);
        for _ in 0..7 {
            assert_eq!(greedy.pick(&v), rr.pick(&v));
        }
    }

    #[test]
    fn proportional_fair_serves_the_largest_discounted_debt() {
        let mut pf = PropFairScheduler::new();
        // equal links: the big backlog goes first
        let v = views(&[(5, 0, 1.0), (50, 0, 1.0)]);
        assert_eq!(pf.pick(&v), 1);
        // service discounts debt: heavily-served lane 1 yields
        let v = views(&[(50, 0, 1.0), (50, 100, 1.0)]);
        assert_eq!(pf.pick(&v), 0);
        // a slow link discounts debt too
        let v = views(&[(50, 0, 10.0), (20, 0, 1.0)]);
        assert_eq!(pf.pick(&v), 1);
    }

    #[test]
    fn scheduled_source_k1_draws_like_single_device() {
        let ds = small_ds(150);
        let shards =
            crate::extensions::multi_device::shard_dataset(&ds, 1);
        let slowdowns = [1.0];
        let mut sched = ScheduledSource::new(
            &shards,
            42,
            PropFairScheduler::new(),
            &slowdowns,
        );
        let mut single = SingleDeviceSource::new(&ds, 42);
        let mut fa = BlockFrame::with_capacity(16, ds.d);
        let mut fb = BlockFrame::with_capacity(16, ds.d);
        loop {
            let a = sched.poll(16, 0.0, &mut fa);
            let b = single.poll(16, 0.0, &mut fb);
            match (a, b) {
                (SourcePoll::Exhausted, SourcePoll::Exhausted) => break,
                (
                    SourcePoll::Block { device: da },
                    SourcePoll::Block { device: db },
                ) => {
                    assert_eq!(da, db);
                    assert_eq!(fa.x, fb.x, "staged covariates diverged");
                    assert_eq!(fa.y, fb.y, "staged labels diverged");
                }
                _ => panic!("poll outcomes diverged"),
            }
        }
    }

    #[test]
    fn scheduled_source_tracks_service_counts() {
        let ds = small_ds(90);
        let shards =
            crate::extensions::multi_device::shard_dataset(&ds, 3);
        let slowdowns = [1.0, 1.0, 1.0];
        let mut source = ScheduledSource::new(
            &shards,
            7,
            GreedyScheduler::new(),
            &slowdowns,
        );
        let mut frame = BlockFrame::with_capacity(10, ds.d);
        let mut order = Vec::new();
        for _ in 0..9 {
            match source.poll(10, 0.0, &mut frame) {
                SourcePoll::Block { device } => order.push(device),
                _ => panic!("unexpected poll result"),
            }
        }
        // identical lanes -> greedy ties rotate round-robin
        assert_eq!(order, vec![0, 1, 2, 0, 1, 2, 0, 1, 2]);
        assert!(matches!(
            source.poll(10, 0.0, &mut frame),
            SourcePoll::Exhausted
        ));
    }

    #[test]
    fn missed_deadline_blocks_are_counted_and_flag_outage() {
        // block = 110 time units, B_d = 10 -> 4 delivered inside T=500,
        // a 5th sent block misses the deadline
        let ds = small_ds(1000);
        let cfg = DesConfig::paper(100, 10.0, 500.0, 3);
        let mut source = SingleDeviceSource::new(&ds, cfg.seed);
        let mut policy = FixedPolicy(cfg.n_c);
        let run = run_schedule(
            &ds,
            &cfg,
            &mut source,
            &mut policy,
            OverlapMode::Pipelined,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(run.blocks_sent, 5);
        assert_eq!(run.blocks_delivered, 4);
        assert_eq!(run.blocks_missed, 1);
        assert!(run.deadline_outage());
        // a generous budget delivers everything: no outage
        let cfg = DesConfig::paper(100, 10.0, 3000.0, 3);
        let mut source = SingleDeviceSource::new(&ds, cfg.seed);
        let mut policy = FixedPolicy(cfg.n_c);
        let run = run_schedule(
            &ds,
            &cfg,
            &mut source,
            &mut policy,
            OverlapMode::Pipelined,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(run.blocks_missed, 0);
        assert!(!run.deadline_outage());
    }

    #[test]
    fn control_policy_sizes_like_fixed_on_a_pinned_good_channel() {
        use crate::bound::replan::{ControlPlan, Replanner, PLAN_REL_TOL};
        use crate::bound::BoundParams;
        use crate::channel::estimator::{
            ControlEstimator, GeBeliefEstimator, GeParams,
        };
        use crate::channel::LinkState;

        // a plan whose channel never leaves the good state: the
        // estimate never moves, so every next_n_c call must size
        // exactly like FixedPolicy(n_c0) — even across observations
        let params = BoundParams::paper_fig3(3.0);
        let plan = ControlPlan {
            params,
            n: 2000,
            t_budget: 3000.0,
            n_o: 10.0,
            tau_p: 1.0,
            slowdown0: LinkState::new(1.0, 0.2).expected_slowdown(),
            n_c0: 64,
        };
        let ge = GeParams::new(
            0.0,
            1.0,
            LinkState::new(1.0, 0.2),
            LinkState::new(1.0, 0.2),
        );
        let mut control = ControlPolicy::new(
            ControlEstimator::Ge(GeBeliefEstimator::new(ge)),
            Replanner::new(plan, PLAN_REL_TOL),
            1,
        );
        let mut fixed = FixedPolicy(64);
        let mut remaining = 2000usize;
        let mut t = 0.0;
        let mut block = 1usize;
        while remaining > 0 {
            let a = control.next_n_c(block, remaining, t);
            let b = fixed.next_n_c(block, remaining, t);
            assert_eq!(a, b, "block {block} diverged");
            // noisy ARQ observations must not move the pinned belief
            control.observe(&PacketObs {
                nominal: a as f64 + 10.0,
                occupancy: (a as f64 + 10.0)
                    * (1.0 + (block % 3) as f64),
                attempts: 1 + (block % 3) as u32,
            });
            remaining -= a;
            t += (a as f64 + 10.0) * 1.25;
            block += 1;
        }
        assert_eq!(control.planned_n_c(), 64);
    }

    #[test]
    fn sources_shed_their_backlog_on_eviction() {
        let ds = small_ds(120);
        let mut frame = BlockFrame::with_capacity(10, ds.d);

        let mut single = SingleDeviceSource::new(&ds, 5);
        single.poll(10, 0.0, &mut frame);
        assert_eq!(single.evict(1), 0, "unknown device sheds nothing");
        assert_eq!(single.evict(0), 110);
        assert!(matches!(single.poll(10, 0.0, &mut frame), SourcePoll::Exhausted));
        assert_eq!(single.evict(0), 0, "second eviction is a no-op");

        let shards = crate::extensions::multi_device::shard_dataset(&ds, 3);
        let mut rr = RoundRobinSource::new(&shards, 5);
        rr.poll(10, 0.0, &mut frame); // device 0 sends 10
        assert_eq!(rr.evict(0), 30);
        assert_eq!(rr.remaining(), 80);
        // the evicted lane never transmits again
        for _ in 0..8 {
            match rr.poll(10, 0.0, &mut frame) {
                SourcePoll::Block { device } => assert_ne!(device, 0),
                _ => panic!("unexpected poll result"),
            }
        }
        assert!(matches!(rr.poll(10, 0.0, &mut frame), SourcePoll::Exhausted));

        let mut online = OnlineArrivalSource::new(&ds, 1.0, 5);
        online.poll(10, 30.0, &mut frame); // 31 arrived, 10 sent
        assert_eq!(online.evict(0), 110, "pool + future arrivals shed");
        assert!(matches!(
            online.poll(10, 500.0, &mut frame),
            SourcePoll::Exhausted
        ));
    }

    #[test]
    fn permanent_dropout_evicts_and_degrades_gracefully() {
        use crate::channel::{FaultPlan, FaultSpec, IdealChannel};

        // device 0's link dies at t = 0; with ARQ hardening the
        // scheduler times out, retries within budget, evicts, and sheds
        // the whole shard instead of stalling to the deadline
        let ds = small_ds(300);
        let spec = FaultSpec::parse("drop:0:0.0+retry:2:1:2").unwrap();
        let cfg = DesConfig {
            faults: spec.tolerance(),
            ..DesConfig::paper(50, 5.0, 2000.0, 9)
        };
        let mut source = SingleDeviceSource::new(&ds, cfg.seed);
        let mut policy = FixedPolicy(cfg.n_c);
        let run = run_schedule(
            &ds,
            &cfg,
            &mut source,
            &mut policy,
            OverlapMode::Pipelined,
            &mut FaultPlan::new(spec, IdealChannel),
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(run.blocks_delivered, 0);
        assert_eq!(run.timeouts, 2, "initial attempt + one retry");
        assert_eq!(run.evictions, 1);
        assert_eq!(run.blocks_abandoned, 1);
        assert_eq!(run.samples_lost, ds.n);
        assert_eq!(run.blocks_missed, 0);
        assert!(run.degraded_completion);
        assert_eq!(run.case, TimelineCase::Partial);
        assert!(
            !run.deadline_outage(),
            "a degraded completion is not an outage"
        );

        // the fault-blind baseline on the same dead link stalls forever
        // and flags an outage
        let spec = FaultSpec::parse("drop:0:0.0").unwrap();
        let cfg = DesConfig {
            faults: Default::default(),
            ..DesConfig::paper(50, 5.0, 2000.0, 9)
        };
        let mut source = SingleDeviceSource::new(&ds, cfg.seed);
        let mut policy = FixedPolicy(cfg.n_c);
        let run = run_schedule(
            &ds,
            &cfg,
            &mut source,
            &mut policy,
            OverlapMode::Pipelined,
            &mut FaultPlan::new(spec, IdealChannel),
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(run.blocks_missed, 1);
        assert_eq!(run.timeouts, 0);
        assert!(!run.degraded_completion);
        assert!(run.deadline_outage());
    }

    #[test]
    fn retry_budget_bounds_abandonment_without_eviction() {
        use crate::channel::{FaultPlan, FaultSpec, IdealChannel};

        // a long outage outlasts each block's whole retry ladder; the
        // retry budget (3) caps every abandoned block at 4 attempts and
        // the device survives (evict disabled), so the blocks sent
        // after the outage ends still deliver
        let ds = small_ds(200);
        let spec = FaultSpec::parse("outage:0:2000+retry:2:3").unwrap();
        let cfg = DesConfig {
            faults: spec.tolerance(),
            ..DesConfig::paper(40, 5.0, 3000.0, 11)
        };
        let mut source = SingleDeviceSource::new(&ds, cfg.seed);
        let mut policy = FixedPolicy(cfg.n_c);
        let run = run_schedule(
            &ds,
            &cfg,
            &mut source,
            &mut policy,
            OverlapMode::Pipelined,
            &mut FaultPlan::new(spec, IdealChannel),
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        assert_eq!(run.evictions, 0);
        assert!(run.blocks_abandoned >= 1);
        assert!(run.timeouts >= 4);
        // per abandoned block: exactly budget+1 = 4 attempts
        assert_eq!(run.timeouts % 4, 0);
        assert!(run.blocks_delivered > 0, "device recovers after outage");
        assert_eq!(
            run.samples_delivered + run.samples_lost,
            ds.n,
            "every sample is delivered or deliberately shed"
        );
        assert!(run.degraded_completion);
        assert!(!run.deadline_outage());
    }

    #[test]
    fn round_robin_alternates_devices() {
        let ds = small_ds(120);
        let shards =
            crate::extensions::multi_device::shard_dataset(&ds, 3);
        let mut source = RoundRobinSource::new(&shards, 9);
        let mut frame = BlockFrame::with_capacity(10, ds.d);
        let mut order = Vec::new();
        for _ in 0..6 {
            match source.poll(10, 0.0, &mut frame) {
                SourcePoll::Block { device } => order.push(device),
                _ => panic!("unexpected poll result"),
            }
        }
        assert_eq!(order, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(source.remaining(), 120 - 60);
    }
}
