//! Theorem 1 / Corollary 1: the convergence bound and the block-size
//! optimizer built on it (the paper's analytical contribution).

pub mod constants;
pub mod corollary1;
pub mod optimizer;
pub mod sensitivity;
pub mod theorem1;

pub use constants::{estimate_constants, BoundConstants};
pub use corollary1::{corollary1_bound, BoundParams};
pub use optimizer::{optimize_block_size, BoundOptimum};
pub use sensitivity::{max_regret, sensitivity_sweep, SensitivityRow};
