//! Theorem 1 / Corollary 1: the convergence bound, the block-size
//! optimizer built on it (the paper's analytical contribution), the
//! Monte-Carlo validation layer ([`validate`]) that checks the
//! recommendation against measured optimality gaps on non-ideal
//! channels and the logistic workload, and the mid-run re-optimizer
//! ([`replan`]) the closed-loop payload controller runs at block
//! boundaries.

pub mod constants;
pub mod corollary1;
pub mod optimizer;
pub mod replan;
pub mod sensitivity;
pub mod theorem1;
pub mod validate;

pub use constants::{
    estimate_constants, estimate_logistic_constants, BoundConstants,
};
pub use corollary1::{corollary1_bound, BoundParams};
pub use optimizer::{optimize_block_size, BoundOptimum};
pub use replan::{ControlPlan, Replanner, PLAN_REL_TOL};
pub use sensitivity::{max_regret, sensitivity_sweep, SensitivityRow};
pub use validate::{
    aggregate_slowdown, bootstrap_mean_upper, check_recommendation,
    logistic_reference_loss, recommend_block_size, split_budget,
    CheckConfig, RecommendationCheck,
};
