//! Corollary 1: the numerically evaluable upper bound on the expected
//! optimality gap `E[L(w_T) − L(w*)]` at the deadline.
//!
//! With `γ = α(1 − αLM_G/2)`, `A = α²LM/(2γc)`, `q = 1 − γc`,
//! `B = T/(n_c+n_o)`, `B_d = N/n_c`, `n_p = (n_c+n_o)/τ_p`:
//!
//! case (a), `T ≤ B_d(n_c+n_o)` (eq. 14):
//! ```text
//!   G = A·(B−1)/B_d + (1 − (B−1)/B_d)·LD²/2
//!       + (1/B_d) Σ_{l=1}^{⌊B⌋−1} q^{l·n_p} (LD²/2 − A)
//! ```
//! case (b), `T > B_d(n_c+n_o)` (eq. 15):
//! ```text
//!   G = A + (1/B_d)·q^{n_l} Σ_{l=0}^{⌈B_d⌉−1} q^{l·n_p} (LD²/2 − A)
//! ```
//!
//! The paper evaluates the bound with REAL-valued `B`, `B_d`, `n_p`
//! (Fig. 3's curves are smooth in `n_c`); we follow that convention,
//! flooring only the summation term counts. Geometric sums use the closed
//! form with an `r → 1` guard; `naive = true` switches to the explicit
//! sum (used by tests to validate the closed form).

/// SGD/loss constants entering the bound.
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// Learning rate α (paper Fig. 3: 1e-4). Must satisfy α ≤ 2/(L·M_G).
    pub alpha: f64,
    /// Smoothness constant L (paper: 1.908).
    pub big_l: f64,
    /// Polyak–Łojasiewicz constant c (paper: 0.061).
    pub c: f64,
    /// Additive gradient-variance constant M (paper: 1).
    pub m: f64,
    /// M_G = M_V + 1 multiplicative variance constant (paper: M_G = 1).
    pub m_g: f64,
    /// Diameter D of the iterate region W (assumption A1).
    pub d_diam: f64,
}

impl BoundParams {
    /// Paper Fig. 3 constants (D calibrated in EXPERIMENTS.md).
    pub fn paper_fig3(d_diam: f64) -> BoundParams {
        BoundParams {
            alpha: 1e-4,
            big_l: 1.908,
            c: 0.061,
            m: 1.0,
            m_g: 1.0,
            d_diam,
        }
    }

    /// Parameters from estimated data constants with the paper's
    /// variance model `M = M_G = 1` (the form every CLI/test consumer
    /// uses; see `bound::constants`).
    pub fn from_constants(
        alpha: f64,
        k: &super::constants::BoundConstants,
    ) -> BoundParams {
        BoundParams {
            alpha,
            big_l: k.big_l,
            c: k.c,
            m: 1.0,
            m_g: 1.0,
            d_diam: k.d_diam,
        }
    }

    /// γ = α(1 − ½αLM_G). Positive whenever α < 2/(L·M_G).
    pub fn gamma(&self) -> f64 {
        self.alpha * (1.0 - 0.5 * self.alpha * self.big_l * self.m_g)
    }

    /// The asymptotic bias floor A = α²LM/(2γc) (first term of eq. 15).
    pub fn bias_floor(&self) -> f64 {
        self.alpha * self.alpha * self.big_l * self.m
            / (2.0 * self.gamma() * self.c)
    }

    /// The per-update contraction factor q = 1 − γc.
    pub fn contraction(&self) -> f64 {
        1.0 - self.gamma() * self.c
    }

    /// LD²/2 — the A2+A1 initial-error cap used by Corollary 1.
    pub fn initial_error_cap(&self) -> f64 {
        0.5 * self.big_l * self.d_diam * self.d_diam
    }

    /// Check the stepsize condition (10): 0 < α ≤ 2/(L·M_G).
    pub fn stepsize_ok(&self) -> bool {
        self.alpha > 0.0 && self.alpha <= 2.0 / (self.big_l * self.m_g)
    }
}

/// Continuous geometric sum: `Σ_{l=start}^{...}` of `r^l` with a REAL
/// term count `k` — the `⌊k⌋` whole terms plus a `frac(k)`-weighted tail
/// term. Piecewise-linear interpolation in `k` keeps the bound free of
/// artificial cliffs when `B` or `B_d` is fractional (the paper treats
/// both as real-valued when plotting Fig. 3).
fn geom_sum_real(r: f64, start: u32, k: f64) -> f64 {
    if k <= 0.0 {
        return 0.0;
    }
    let whole = k.floor();
    let frac = k - whole;
    let whole_terms = whole as i32;
    let head = if (1.0 - r).abs() < 1e-12 {
        whole
    } else {
        r.powi(start as i32) * (1.0 - r.powi(whole_terms)) / (1.0 - r)
    };
    head + frac * r.powi(start as i32 + whole_terms)
}

/// Explicit-loop version of [`geom_sum_real`] (test oracle).
fn naive_sum_real(r: f64, start: u32, k: f64) -> f64 {
    if k <= 0.0 {
        return 0.0;
    }
    let whole = k.floor() as u32;
    let mut acc = 0.0;
    for l in 0..whole {
        acc += r.powi((start + l) as i32);
    }
    acc + (k - whole as f64) * r.powi((start + whole) as i32)
}

/// Evaluate the Corollary-1 bound for block size `n_c`.
///
/// * `n` — training-set size N
/// * `t_budget` — deadline T (normalized units)
/// * `n_c` — block payload (may be fractional when scanning; paper plots
///   the bound as a continuous function of n_c)
/// * `n_o` — per-packet overhead
/// * `tau_p` — time per SGD update
/// * `naive` — use the explicit geometric sum (for testing)
pub fn corollary1_bound(
    p: &BoundParams,
    n: usize,
    t_budget: f64,
    n_c: f64,
    n_o: f64,
    tau_p: f64,
    naive: bool,
) -> f64 {
    assert!(p.stepsize_ok(), "stepsize condition (10) violated");
    assert!(n_c >= 1.0 && n_c <= n as f64, "n_c out of range");
    let a = p.bias_floor();
    let cap = p.initial_error_cap();
    let q = p.contraction();

    let block_len = n_c + n_o;
    let b_d = n as f64 / n_c; // real-valued, paper convention
    let n_p = block_len / tau_p;
    let b = t_budget / block_len;
    let r = q.powf(n_p); // contraction over one block's updates

    if t_budget <= b_d * block_len {
        // ---- case (a), eq. (14): the series has B−1 (real) terms
        let frac = ((b - 1.0) / b_d).clamp(0.0, 1.0);
        let terms = (b - 1.0).max(0.0);
        let series = if naive {
            naive_sum_real(r, 1, terms)
        } else {
            geom_sum_real(r, 1, terms)
        };
        a * frac + (1.0 - frac) * cap + series * (cap - a) / b_d
    } else {
        // ---- case (b), eq. (15): the series has B_d (real) terms
        let tau_l = t_budget - b_d * block_len;
        let n_l = tau_l / tau_p;
        let series = if naive {
            naive_sum_real(r, 0, b_d)
        } else {
            geom_sum_real(r, 0, b_d)
        };
        a + q.powf(n_l) * series * (cap - a) / b_d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_params() -> BoundParams {
        BoundParams::paper_fig3(3.0)
    }

    /// Paper Fig. 3 setup: N = 18576, T = 1.5 N, τ_p = 1.
    const N: usize = 18576;
    const T: f64 = 1.5 * 18576.0;

    #[test]
    fn gamma_and_floor_formulas() {
        let p = paper_params();
        let gamma = 1e-4 * (1.0 - 0.5 * 1e-4 * 1.908);
        assert!((p.gamma() - gamma).abs() < 1e-18);
        let a = 1e-8 * 1.908 / (2.0 * gamma * 0.061);
        assert!((p.bias_floor() - a).abs() < 1e-12);
        assert!(p.stepsize_ok());
    }

    #[test]
    fn closed_form_matches_naive_sum() {
        let p = paper_params();
        for &n_o in &[1.0, 10.0, 100.0, 1000.0] {
            for &n_c in &[1.0, 7.0, 64.0, 500.0, 5000.0, 18576.0] {
                let fast = corollary1_bound(&p, N, T, n_c, n_o, 1.0, false);
                let slow = corollary1_bound(&p, N, T, n_c, n_o, 1.0, true);
                let rel = (fast - slow).abs() / slow.abs().max(1e-30);
                assert!(
                    rel < 1e-9,
                    "n_o={n_o} n_c={n_c}: {fast} vs {slow}"
                );
            }
        }
    }

    #[test]
    fn bound_is_positive_and_finite() {
        let p = paper_params();
        for nc in [1usize, 10, 100, 1000, 10000, N] {
            let g = corollary1_bound(&p, N, T, nc as f64, 10.0, 1.0, false);
            assert!(g.is_finite() && g > 0.0, "n_c={nc}: {g}");
        }
    }

    #[test]
    fn interior_minimum_exists() {
        // The paper's headline qualitative claim: the bound is minimized
        // at an interior block size, not at n_c = N (transmit-everything).
        let p = paper_params();
        let n_o = 10.0;
        let at = |nc: f64| corollary1_bound(&p, N, T, nc, n_o, 1.0, false);
        let best_interior = (1..=N)
            .step_by(16)
            .map(|nc| at(nc as f64))
            .fold(f64::INFINITY, f64::min);
        assert!(
            best_interior < at(N as f64),
            "pipelining should beat transmit-everything-first"
        );
        assert!(
            best_interior < at(1.0),
            "some batching should beat n_c = 1 under overhead"
        );
    }

    #[test]
    fn more_overhead_pushes_optimum_up() {
        // Paper Sec. 4: larger n_o must be amortized by larger blocks.
        let p = paper_params();
        let argmin = |n_o: f64| -> usize {
            (1..=N)
                .step_by(4)
                .min_by(|&a, &b| {
                    let ga = corollary1_bound(&p, N, T, a as f64, n_o, 1.0, false);
                    let gb = corollary1_bound(&p, N, T, b as f64, n_o, 1.0, false);
                    ga.partial_cmp(&gb).unwrap()
                })
                .unwrap()
        };
        let low = argmin(1.0);
        let high = argmin(1000.0);
        assert!(high > low, "ñ_c(n_o=1000)={high} <= ñ_c(n_o=1)={low}");
    }

    #[test]
    fn case_boundary_is_continuous() {
        // The two branches must agree (to first order) at the boundary
        // T = B_d(n_c + n_o): approach it from both sides.
        let p = paper_params();
        let n_o = 10.0;
        // pick n_c where boundary T equals our T: B_d(n_c+n_o) = T
        // with B_d = N/n_c -> n_c s.t. N(1 + n_o/n_c) = T
        let n_c = N as f64 * n_o / (T - N as f64);
        let below = corollary1_bound(&p, N, T * (1.0 + 1e-9), n_c, n_o, 1.0, false);
        let above = corollary1_bound(&p, N, T * (1.0 - 1e-9), n_c, n_o, 1.0, false);
        let rel = (below - above).abs() / above.abs();
        assert!(rel < 1e-2, "branch mismatch at boundary: {below} vs {above}");
    }

    #[test]
    fn much_longer_deadline_helps() {
        // Exact monotonicity in T does not hold pointwise (the two
        // branches discretize the series differently near the boundary),
        // but a well-separated deadline increase must strictly help.
        let p = paper_params();
        for nc in [50usize, 500, 5000] {
            let short = corollary1_bound(&p, N, 0.5 * T, nc as f64, 10.0, 1.0, false);
            let long = corollary1_bound(&p, N, 10.0 * T, nc as f64, 10.0, 1.0, false);
            assert!(long < short, "n_c={nc}: {long} >= {short}");
        }
    }

    #[test]
    #[should_panic]
    fn stepsize_violation_panics() {
        let p = BoundParams { alpha: 10.0, ..paper_params() };
        corollary1_bound(&p, N, T, 100.0, 10.0, 1.0, false);
    }
}
