//! Validation of the bound's payload-size recommendation against
//! Monte-Carlo reality, across channel and workload axes.
//!
//! The paper's optimizer picks `ñ_c = argmin` of the Corollary-1 bound
//! assuming the unit-rate error-free link. Real channels slow the link
//! down by an expected factor `s ≥ 1` (erasure ARQ, rate limits, fading
//! bursts); since the bound only sees the link through `T/(n_c + n_o)`
//! block counts, running it with the *effective* budget `T/s` makes the
//! recommendation channel-aware ([`recommend_block_size`]).
//!
//! [`check_recommendation`] then closes the loop empirically: it runs
//! the recommended `ñ_c` through the scenario Monte-Carlo engine,
//! measures per-seed optimality gaps `L(w_T) − L(w*)`, and checks — via
//! a seeded percentile bootstrap — that the mean gap stays below the
//! (channel-adjusted) Corollary-1 value at the requested confidence.
//! `rust/tests/golden_traces.rs` asserts this at 99% confidence over
//! the fading/logistic scenario grid; everything is seeded, so the
//! check is deterministic and CI-safe.

use crate::coordinator::des::DesConfig;
use crate::coordinator::scheduler::RunWorkspace;
use crate::data::Dataset;
use crate::model::{LogisticModel, Workload};
use crate::sgd::{SgdEngine, StoreView};
use crate::sweep::scenario::{PolicySpec, ScenarioRunner, ScenarioSpec};
use crate::util::pool::{default_threads, parallel_tasks_with};
use crate::util::rng::Pcg32;
use crate::util::stats::percentile_sorted;

use super::corollary1::{corollary1_bound, BoundParams};
use super::optimizer::{optimize_block_size, BoundOptimum};

/// Result of checking one scenario's recommendation.
#[derive(Clone, Debug)]
pub struct RecommendationCheck {
    /// The scenario the check ran.
    pub label: String,
    /// The channel-aware recommended payload size.
    pub n_c: usize,
    /// Expected channel slowdown used to adjust the budget.
    pub slowdown: f64,
    /// Corollary-1 bound value at the recommendation (adjusted budget).
    pub bound: f64,
    /// Mean measured optimality gap at the recommendation.
    pub mean_gap: f64,
    /// Bootstrap upper confidence bound on the mean gap.
    pub gap_upper: f64,
    /// Whether the bound holds at the requested confidence.
    pub holds: bool,
    /// Per-seed measured gaps (for diagnostics / re-testing).
    pub gaps: Vec<f64>,
}

/// Data-share-weighted aggregate slowdown of a heterogeneous multi-lane
/// uplink: `Σ share_i · s_i / Σ share_i`.
///
/// With the uplink serialized, every sample of lane `i`'s shard
/// occupies the channel for an expected `s_i` units per nominal unit,
/// so pushing the whole dataset through costs the share-weighted mean
/// of the per-lane slowdowns — this is the closed form
/// `ScenarioSpec::expected_slowdown` uses (equal shares) and the one
/// the seeded Monte-Carlo agreement test in
/// `rust/tests/channel_stats.rs` validates against measured channel
/// occupancy.
pub fn aggregate_slowdown(slowdowns: &[f64], shares: &[f64]) -> f64 {
    assert!(!slowdowns.is_empty(), "need at least one lane");
    assert_eq!(slowdowns.len(), shares.len(), "one share per lane");
    assert!(
        slowdowns.iter().all(|s| *s > 0.0),
        "lane slowdowns must be positive"
    );
    assert!(
        shares.iter().all(|w| *w >= 0.0),
        "lane shares must be non-negative"
    );
    let total: f64 = shares.iter().sum();
    assert!(total > 0.0, "lane shares must not all be zero");
    slowdowns
        .iter()
        .zip(shares)
        .map(|(s, w)| s * w)
        .sum::<f64>()
        / total
}

/// Split a transmission budget `t_budget` across heterogeneous lanes in
/// proportion to each lane's expected channel occupancy
/// (`share_i · s_i`): the wall-clock share lane `i` needs to push its
/// data share through the serialized uplink. Sums to `t_budget`
/// exactly up to rounding; a homogeneous uplink with equal shares
/// splits evenly.
pub fn split_budget(
    t_budget: f64,
    slowdowns: &[f64],
    shares: &[f64],
) -> Vec<f64> {
    assert!(t_budget >= 0.0, "budget must be non-negative");
    // reuse aggregate_slowdown's validation
    let mean = aggregate_slowdown(slowdowns, shares);
    let total_shares: f64 = shares.iter().sum();
    let denom = mean * total_shares;
    slowdowns
        .iter()
        .zip(shares)
        .map(|(s, w)| t_budget * (s * w) / denom)
        .collect()
}

/// Channel-aware `ñ_c`: the Corollary-1 argmin evaluated with the
/// budget shrunk by the channel's expected slowdown (`slowdown = 1`
/// recovers [`optimize_block_size`] exactly).
pub fn recommend_block_size(
    p: &BoundParams,
    n: usize,
    t_budget: f64,
    n_o: f64,
    tau_p: f64,
    slowdown: f64,
) -> BoundOptimum {
    assert!(slowdown > 0.0, "slowdown must be positive, got {slowdown}");
    optimize_block_size(p, n, t_budget / slowdown, n_o, tau_p)
}

/// Seeded percentile bootstrap of the sample mean: resample `gaps` with
/// replacement `resamples` times and return the `confidence` quantile
/// of the resampled means. Deterministic for a fixed `seed`.
pub fn bootstrap_mean_upper(
    gaps: &[f64],
    resamples: usize,
    confidence: f64,
    seed: u64,
) -> f64 {
    assert!(!gaps.is_empty(), "bootstrap on an empty sample");
    assert!((0.5..1.0).contains(&confidence), "confidence in [0.5, 1)");
    assert!(resamples >= 2, "need at least 2 resamples");
    let n = gaps.len() as u64;
    let mut rng = Pcg32::new(seed, 909);
    let mut means = Vec::with_capacity(resamples);
    for _ in 0..resamples {
        let mut acc = 0.0;
        for _ in 0..gaps.len() {
            acc += gaps[rng.gen_range(n) as usize];
        }
        means.push(acc / gaps.len() as f64);
    }
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&means, confidence)
}

/// Knobs for [`check_recommendation`].
#[derive(Clone, Copy, Debug)]
pub struct CheckConfig {
    /// Monte-Carlo repetitions at the recommended `ñ_c`.
    pub seeds: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Bootstrap resamples.
    pub resamples: usize,
    /// One-sided confidence level of the gap's upper bound (e.g. 0.99).
    pub confidence: f64,
    /// Seed of the bootstrap resampler (independent of run seeds).
    pub boot_seed: u64,
}

impl Default for CheckConfig {
    fn default() -> Self {
        CheckConfig {
            seeds: 24,
            threads: 0,
            resamples: 1000,
            confidence: 0.99,
            boot_seed: 1906,
        }
    }
}

/// Validate one scenario's recommendation end-to-end.
///
/// * `params` — bound constants matching the scenario's WORKLOAD
///   (`estimate_constants` for ridge, `estimate_logistic_constants`
///   for logistic);
/// * `loss_star` — the workload's optimal (or best-known reference)
///   full-dataset loss, on the same label view the scenario trains
///   (use [`ScenarioRunner::data`] to obtain it, or
///   [`logistic_reference_loss`]);
/// * `base` — the run configuration whose `n_c` is overridden by the
///   recommendation.
///
/// The recommendation IS a fixed pipelined schedule, so the scenario's
/// policy axis is forced to `fixed` (inheriting the recommended `n_c`)
/// before measuring — a warmup/deadline/allfirst policy would silently
/// reinterpret or discard the override and the check would compare the
/// bound against an unrelated schedule. Channel, traffic, workload and
/// store axes are honored as given.
///
/// Returns the measured gaps plus whether
/// `bootstrap_upper(mean gap) ≤ bound` at the requested confidence.
pub fn check_recommendation(
    ds: &Dataset,
    base: &DesConfig,
    spec: &ScenarioSpec,
    params: &BoundParams,
    loss_star: f64,
    check: &CheckConfig,
) -> RecommendationCheck {
    let spec = ScenarioSpec {
        policy: PolicySpec::Fixed { n_c: 0 },
        ..spec.clone()
    };
    // scenario-level slowdown: the channel axis for single-lane
    // traffic, the per-device aggregate for the heterogeneous uplink
    let slowdown = spec.expected_slowdown();
    let opt = recommend_block_size(
        params,
        ds.n,
        base.t_budget,
        base.n_o,
        base.tau_p,
        slowdown,
    );
    let bound = corollary1_bound(
        params,
        ds.n,
        base.t_budget / slowdown,
        opt.n_c as f64,
        base.n_o,
        base.tau_p,
        false,
    );
    let threads =
        if check.threads == 0 { default_threads() } else { check.threads };
    let runner = ScenarioRunner::new(spec.clone(), ds);
    let cfg = DesConfig {
        n_c: opt.n_c,
        loss_every: 0,
        record_blocks: false,
        collect_snapshots: false,
        event_capacity: 0,
        ..base.clone()
    };
    let gaps: Vec<f64> = parallel_tasks_with(
        check.seeds,
        threads,
        RunWorkspace::new,
        |ws, s| {
            let per_seed = DesConfig {
                seed: cfg.seed.wrapping_add(s as u64),
                ..cfg.clone()
            };
            let stats = runner
                .run_with(ws, &per_seed)
                .expect("scenario run failed");
            stats.final_loss - loss_star
        },
    );
    let mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
    let gap_upper = bootstrap_mean_upper(
        &gaps,
        check.resamples,
        check.confidence,
        check.boot_seed,
    );
    RecommendationCheck {
        label: spec.label(),
        n_c: opt.n_c,
        slowdown,
        bound,
        mean_gap,
        gap_upper,
        holds: gap_upper <= bound,
        gaps,
    }
}

/// Best-known reference loss for the logistic workload: a long seeded
/// full-data SGD run (20·n updates, zero init, RNG stream 305). The
/// logistic optimum has no closed form; any iterate's loss
/// upper-bounds `L(w*)`, so a gap measured against this reference
/// UNDERESTIMATES the true gap — [`check_recommendation`] on a
/// logistic scenario therefore validates the bound against the
/// measurable part of the gap (a weaker but still falsifiable check;
/// the ridge axes use the exact `ridge_solution` optimum). One
/// definition shared by the CLI (`edgepipe optimize --mc`) and the
/// statistical tests so the two cannot drift.
pub fn logistic_reference_loss(
    view: &Dataset,
    lambda: f64,
    alpha: f64,
    seed: u64,
) -> f64 {
    let model = LogisticModel::new(view.d, lambda, view.n);
    let engine = SgdEngine::new(alpha);
    let store = StoreView::new(&view.x, &view.y, view.d);
    let mut rng = Pcg32::new(seed, 305);
    let mut w = vec![0.0f64; view.d];
    engine.run_updates(&model, &mut w, store, 20 * view.n.max(1), &mut rng);
    let reg = lambda / view.n as f64;
    Workload::Logistic.full_loss(view, &w, reg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_slowdown_recovers_the_plain_optimizer() {
        let p = BoundParams::paper_fig3(3.0);
        let (n, t, n_o, tau) = (2000usize, 3000.0, 10.0, 1.0);
        let plain = optimize_block_size(&p, n, t, n_o, tau);
        let adj = recommend_block_size(&p, n, t, n_o, tau, 1.0);
        assert_eq!(plain.n_c, adj.n_c);
        assert_eq!(plain.value, adj.value);
    }

    #[test]
    fn slower_channels_never_increase_the_effective_budget() {
        // a slowdown of s is exactly the optimizer at T/s, so the
        // recommendation must match the direct call
        let p = BoundParams::paper_fig3(3.0);
        let adj = recommend_block_size(&p, 2000, 3000.0, 10.0, 1.0, 2.5);
        let direct = optimize_block_size(&p, 2000, 1200.0, 10.0, 1.0);
        assert_eq!(adj.n_c, direct.n_c);
    }

    #[test]
    fn aggregate_slowdown_closed_forms() {
        // equal shares -> arithmetic mean
        let agg = aggregate_slowdown(&[1.0, 2.0, 3.0], &[1.0, 1.0, 1.0]);
        assert!((agg - 2.0).abs() < 1e-12);
        // homogeneous lanes -> the common slowdown, any shares
        let agg = aggregate_slowdown(&[1.5, 1.5], &[0.9, 0.1]);
        assert!((agg - 1.5).abs() < 1e-12);
        // shares weight the mixture (and need not be normalized)
        let agg = aggregate_slowdown(&[1.0, 3.0], &[3.0, 1.0]);
        assert!((agg - 1.5).abs() < 1e-12);
    }

    #[test]
    fn split_budget_sums_and_orders() {
        let t = 1200.0;
        let slow = [1.0, 2.0, 4.0];
        let shares = [1.0, 1.0, 1.0];
        let split = split_budget(t, &slow, &shares);
        assert_eq!(split.len(), 3);
        let sum: f64 = split.iter().sum();
        assert!((sum - t).abs() < 1e-9, "split must cover the budget");
        // slower lanes need proportionally more wall-clock
        assert!(split[0] < split[1] && split[1] < split[2]);
        assert!((split[2] / split[0] - 4.0).abs() < 1e-9);
        // homogeneous uplink with equal shares splits evenly
        let even = split_budget(t, &[2.0, 2.0], &[0.5, 0.5]);
        assert!((even[0] - 600.0).abs() < 1e-9);
        assert!((even[1] - 600.0).abs() < 1e-9);
    }

    #[test]
    fn bootstrap_is_deterministic_and_ordered() {
        let gaps: Vec<f64> = (0..40).map(|i| (i % 7) as f64 * 0.1).collect();
        let a = bootstrap_mean_upper(&gaps, 500, 0.99, 42);
        let b = bootstrap_mean_upper(&gaps, 500, 0.99, 42);
        assert_eq!(a, b, "same seed must give the same quantile");
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!(a >= mean, "99% upper bound below the sample mean");
        let median = bootstrap_mean_upper(&gaps, 500, 0.5, 42);
        assert!(a >= median, "quantiles must be ordered");
    }

    #[test]
    fn degenerate_sample_collapses_the_interval() {
        let gaps = vec![0.25; 16];
        let u = bootstrap_mean_upper(&gaps, 200, 0.99, 7);
        assert!((u - 0.25).abs() < 1e-12);
    }
}
