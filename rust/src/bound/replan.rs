//! Mid-run budget re-optimization: re-solve the Corollary-1 block-size
//! problem at block boundaries with the *remaining* inputs substituted
//! in — the acting half of the closed-loop payload controller.
//!
//! The paper picks one `ñ_c` ahead of time from the bias–variance
//! bound. On a time-varying channel the inputs that optimization
//! depends on drift as the run unfolds: the remaining deadline budget
//! shrinks with every fade-stretched transmission, the untransmitted
//! sample count shrinks with every delivery, and the expected slowdown
//! of the link ahead is whatever the channel estimator
//! (`channel::estimator`) currently believes. [`Replanner`] re-runs the
//! exact integer argmin over that residual problem whenever the
//! slowdown estimate has actually moved; with an unchanged estimate
//! re-planning is a **no-op by construction** (the current plan is kept
//! without re-solving), which is what makes the closed-loop policy
//! bit-identical to the paper's fixed schedule on static channels
//! (`rust/tests/scenario_parity.rs`).
//!
//! [`ControlPlan`] is the deterministic pre-run plan both the
//! controller and its tests share: workload-matched bound constants
//! estimated with a FIXED pilot seed (the plan describes the scenario,
//! not one Monte-Carlo repetition — every sweep seed gets the same
//! plan), plus the channel-aware initial recommendation.

use crate::coordinator::des::DesConfig;
use crate::data::Dataset;
use crate::model::Workload;

use super::constants::{estimate_constants, estimate_logistic_constants};
use super::corollary1::BoundParams;
use super::optimizer::optimize_block_size;
use super::validate::recommend_block_size;

/// Pilot-run seed for the plan's constant estimation. Fixed (not the
/// run seed) so one scenario has ONE plan across all Monte-Carlo
/// repetitions and the `ScenarioRunner` can cache it.
pub const PLAN_PILOT_SEED: u64 = 1906;

/// Pilot-run SGD updates for the `D` estimate (matches the CLI's
/// `estimate_constants` call).
pub const PLAN_PILOT_UPDATES: usize = 2000;

/// Relative slowdown drift below which the controller keeps its current
/// plan instead of re-solving. Any exact no-change (the static-channel
/// case) is below every positive tolerance; fading estimates move by
/// whole state mixtures, far above it.
pub const PLAN_REL_TOL: f64 = 1e-9;

/// The deterministic pre-run control plan: bound constants matched to
/// the workload, the original problem size/budget, and the
/// channel-aware initial recommendation `ñ_c`.
#[derive(Clone, Debug)]
pub struct ControlPlan {
    /// Workload-matched Corollary-1 constants.
    pub params: BoundParams,
    /// Total training-set size N.
    pub n: usize,
    /// The full deadline T.
    pub t_budget: f64,
    /// Per-packet overhead n_o.
    pub n_o: f64,
    /// Time per SGD update τ_p.
    pub tau_p: f64,
    /// A-priori expected slowdown the initial recommendation used.
    pub slowdown0: f64,
    /// The channel-aware initial recommendation
    /// (`recommend_block_size` at `slowdown0`).
    pub n_c0: usize,
}

impl ControlPlan {
    /// Build the plan for a dataset and run configuration.
    ///
    /// `ds` must be the dataset the scenario actually trains (for the
    /// logistic workload: the binarized label view,
    /// `ScenarioRunner::data`). Constants are estimated with the fixed
    /// [`PLAN_PILOT_SEED`], so the plan is a pure function of
    /// (dataset, λ, α, T, n_o, τ_p, workload, slowdown prior) —
    /// identical across Monte-Carlo seeds.
    pub fn compute(ds: &Dataset, cfg: &DesConfig, slowdown0: f64) -> ControlPlan {
        let k = match cfg.workload {
            Workload::Ridge => estimate_constants(
                ds,
                cfg.lambda,
                cfg.alpha,
                PLAN_PILOT_UPDATES,
                PLAN_PILOT_SEED,
            ),
            Workload::Logistic => estimate_logistic_constants(
                ds,
                cfg.lambda,
                cfg.alpha,
                PLAN_PILOT_UPDATES,
                PLAN_PILOT_SEED,
            ),
        };
        let params = BoundParams::from_constants(cfg.alpha, &k);
        let n_c0 = recommend_block_size(
            &params,
            ds.n,
            cfg.t_budget,
            cfg.n_o,
            cfg.tau_p,
            slowdown0,
        )
        .n_c;
        ControlPlan {
            params,
            n: ds.n,
            t_budget: cfg.t_budget,
            n_o: cfg.n_o,
            tau_p: cfg.tau_p,
            slowdown0,
            n_c0,
        }
    }
}

/// The remaining-budget re-optimizer: keeps the currently planned
/// `n_c`, and re-solves the Corollary-1 argmin over the residual
/// problem (untransmitted samples, remaining wall-clock budget shrunk
/// by the estimated slowdown) whenever the slowdown estimate drifts.
///
/// Deterministic: consumes no RNG; its decisions are a pure function of
/// the plan and the `(remaining, t_now, slowdown)` inputs it is handed.
#[derive(Clone, Debug)]
pub struct Replanner {
    plan: ControlPlan,
    rel_tol: f64,
    /// The slowdown estimate the current `n_c` was solved under.
    last_slowdown: f64,
    n_c: usize,
    /// Force the next [`replan`](Self::replan) through the drift gate
    /// (set by [`invalidate`](Self::invalidate) when the residual
    /// problem changed without the slowdown moving — e.g. a device
    /// eviction shed part of the workload).
    force: bool,
}

impl Replanner {
    pub fn new(plan: ControlPlan, rel_tol: f64) -> Replanner {
        assert!(rel_tol >= 0.0, "tolerance must be non-negative");
        assert!(plan.slowdown0 > 0.0, "plan slowdown must be positive");
        Replanner {
            last_slowdown: plan.slowdown0,
            n_c: plan.n_c0,
            rel_tol,
            plan,
            force: false,
        }
    }

    /// Mark the current plan stale: the next [`replan`](Self::replan)
    /// re-solves even if the slowdown estimate has not drifted. Used by
    /// the graceful-degradation path when capacity is lost (device
    /// eviction) — the residual problem shrank while the channel belief
    /// stayed put.
    pub fn invalidate(&mut self) {
        self.force = true;
    }

    /// The currently planned payload size.
    pub fn current(&self) -> usize {
        self.n_c
    }

    /// The plan this re-planner executes.
    pub fn plan(&self) -> &ControlPlan {
        &self.plan
    }

    /// Re-plan at a block boundary: `remaining` untransmitted samples,
    /// current time `t_now`, estimated slowdown of the link ahead.
    /// Returns the (possibly updated) planned `n_c`.
    ///
    /// No-op cases, in order: an unchanged slowdown estimate (relative
    /// drift within tolerance — re-planning with unchanged inputs must
    /// not disturb the schedule), nothing left to send, or a residual
    /// budget of zero or less (nothing to optimize over).
    pub fn replan(
        &mut self,
        remaining: usize,
        t_now: f64,
        slowdown: f64,
    ) -> usize {
        assert!(slowdown > 0.0, "slowdown must be positive, got {slowdown}");
        let drift = (slowdown - self.last_slowdown).abs();
        if !self.force && drift <= self.rel_tol * self.last_slowdown {
            return self.n_c;
        }
        let residual_budget = (self.plan.t_budget - t_now) / slowdown;
        if remaining == 0 || residual_budget <= 0.0 {
            // nothing to optimize over — and the drifted estimate (or a
            // pending invalidation) is NOT absorbed, so a later call
            // with real inputs still re-solves
            return self.n_c;
        }
        self.force = false;
        self.last_slowdown = slowdown;
        self.n_c = optimize_block_size(
            &self.plan.params,
            remaining,
            residual_budget,
            self.plan.n_o,
            self.plan.tau_p,
        )
        .n_c;
        self.n_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    fn plan_fixture() -> ControlPlan {
        ControlPlan {
            params: BoundParams::paper_fig3(3.0),
            n: 2000,
            t_budget: 3000.0,
            n_o: 10.0,
            tau_p: 1.0,
            slowdown0: 1.25,
            n_c0: recommend_block_size(
                &BoundParams::paper_fig3(3.0),
                2000,
                3000.0,
                10.0,
                1.0,
                1.25,
            )
            .n_c,
        }
    }

    #[test]
    fn unchanged_slowdown_is_a_no_op() {
        let plan = plan_fixture();
        let n_c0 = plan.n_c0;
        let mut rp = Replanner::new(plan, PLAN_REL_TOL);
        // bitwise-equal estimate: no re-solve, regardless of elapsed
        // time or delivered count
        for t in [0.0, 500.0, 2900.0] {
            assert_eq!(rp.replan(1234, t, 1.25), n_c0);
        }
        // sub-tolerance drift is also a no-op
        assert_eq!(rp.replan(1234, 100.0, 1.25 * (1.0 + 1e-12)), n_c0);
    }

    #[test]
    fn drifted_slowdown_resolves_the_residual_problem() {
        let plan = plan_fixture();
        let params = plan.params.clone();
        let (n_o, tau_p, t_budget) = (plan.n_o, plan.tau_p, plan.t_budget);
        let mut rp = Replanner::new(plan, PLAN_REL_TOL);
        let (remaining, t_now, slowdown) = (900usize, 1200.0, 3.0);
        let got = rp.replan(remaining, t_now, slowdown);
        let want = optimize_block_size(
            &params,
            remaining,
            (t_budget - t_now) / slowdown,
            n_o,
            tau_p,
        )
        .n_c;
        assert_eq!(got, want, "replan must be the residual argmin");
        assert_eq!(rp.current(), want);
        // the new estimate becomes the reference: repeating it no-ops
        assert_eq!(rp.replan(remaining - 100, t_now + 50.0, 3.0), want);
    }

    #[test]
    fn exhausted_inputs_keep_the_current_plan_and_do_not_absorb_drift() {
        let plan = plan_fixture();
        let params = plan.params.clone();
        let (n_o, tau_p, t_budget) = (plan.n_o, plan.tau_p, plan.t_budget);
        let n_c0 = plan.n_c0;
        let mut rp = Replanner::new(plan, PLAN_REL_TOL);
        assert_eq!(rp.replan(0, 100.0, 2.0), n_c0, "nothing left to send");
        assert_eq!(
            rp.replan(500, 5000.0, 2.0),
            n_c0,
            "budget already spent"
        );
        // the drift seen on those no-op calls was NOT recorded: the
        // next real call at the same slowdown still re-solves
        let got = rp.replan(500, 1000.0, 2.0);
        let want = optimize_block_size(
            &params,
            500,
            (t_budget - 1000.0) / 2.0,
            n_o,
            tau_p,
        )
        .n_c;
        assert_eq!(got, want, "drift must survive exhausted-input calls");
    }

    #[test]
    fn invalidation_forces_a_resolve_without_slowdown_drift() {
        let plan = plan_fixture();
        let params = plan.params.clone();
        let (n_o, tau_p, t_budget) = (plan.n_o, plan.tau_p, plan.t_budget);
        let n_c0 = plan.n_c0;
        let mut rp = Replanner::new(plan, PLAN_REL_TOL);
        // unchanged slowdown: no-op...
        assert_eq!(rp.replan(1500, 200.0, 1.25), n_c0);
        // ...until invalidated: same slowdown, residual problem re-solved
        rp.invalidate();
        let got = rp.replan(400, 200.0, 1.25);
        let want = optimize_block_size(
            &params,
            400,
            (t_budget - 200.0) / 1.25,
            n_o,
            tau_p,
        )
        .n_c;
        assert_eq!(got, want, "invalidate must force the residual argmin");
        // the invalidation is one-shot: the next unchanged call no-ops
        assert_eq!(rp.replan(399, 210.0, 1.25), want);
    }

    #[test]
    fn invalidation_survives_exhausted_input_calls() {
        let plan = plan_fixture();
        let n_c0 = plan.n_c0;
        let mut rp = Replanner::new(plan, PLAN_REL_TOL);
        rp.invalidate();
        // nothing to optimize over: keep the plan, keep the pending flag
        assert_eq!(rp.replan(0, 100.0, 1.25), n_c0);
        // the next real call still re-solves
        let got = rp.replan(400, 200.0, 1.25);
        assert_eq!(rp.current(), got);
    }

    #[test]
    fn plan_is_seed_independent_and_matches_the_recommendation() {
        let ds = synth_calhousing(&SynthSpec { n: 800, ..Default::default() });
        let mk_cfg = |seed: u64| DesConfig {
            record_blocks: false,
            ..DesConfig::paper(1, 10.0, 1200.0, seed)
        };
        let a = ControlPlan::compute(&ds, &mk_cfg(1), 1.5);
        let b = ControlPlan::compute(&ds, &mk_cfg(999), 1.5);
        assert_eq!(a.n_c0, b.n_c0, "the plan must not depend on the run seed");
        assert_eq!(a.params.big_l, b.params.big_l);
        assert_eq!(a.params.d_diam, b.params.d_diam);
        // and n_c0 is exactly the channel-aware recommendation
        let want = recommend_block_size(&a.params, ds.n, 1200.0, 10.0, 1.0, 1.5);
        assert_eq!(a.n_c0, want.n_c);
        assert!(a.n_c0 >= 1 && a.n_c0 <= ds.n);
    }
}
