//! Estimation of the bound constants `(L, c, D)` from data.
//!
//! For the quadratic ridge loss the Hessian of the empirical risk is
//! `H = 2·(XᵀX/N) + (2λ/N)·I`, so the smoothness constant `L` is
//! `λ_max(H)` and the PL constant `c` is `λ_min(H)` (paper Sec. 5 uses
//! exactly these, reporting L = 1.908, c = 0.061). `D` (the diameter of
//! the iterate region, assumption A1) is estimated from a pilot SGD run.

use crate::data::Dataset;
use crate::linalg::{gram_matrix, jacobi_eigen};
use crate::model::{ridge_solution, LogisticModel, PointModel, RidgeModel};
use crate::sgd::{SgdEngine, StoreView};
use crate::util::rng::Pcg32;

/// Constants consumed by the Corollary-1 bound.
#[derive(Clone, Copy, Debug)]
pub struct BoundConstants {
    /// Smoothness constant L = λ_max(Hessian).
    pub big_l: f64,
    /// PL constant c = λ_min(Hessian).
    pub c: f64,
    /// Iterate-region diameter D.
    pub d_diam: f64,
}

/// Estimate `(L, c)` from the dataset's Gramian and `D` from a pilot run.
///
/// The pilot runs `pilot_updates` SGD steps over the full dataset from the
/// Gaussian init the experiments use, tracking `max ‖w − w*‖`; `D` is
/// twice that radius (a diameter).
pub fn estimate_constants(
    ds: &Dataset,
    lambda: f64,
    alpha: f64,
    pilot_updates: usize,
    seed: u64,
) -> BoundConstants {
    let g = gram_matrix(&ds.x, ds.n, ds.d);
    let eig = jacobi_eigen(&g);
    let reg2 = 2.0 * lambda / ds.n as f64;
    let big_l = 2.0 * eig.values[ds.d - 1] + reg2;
    let c = 2.0 * eig.values[0] + reg2;

    // pilot run for D
    let w_star = ridge_solution(ds, lambda).expect("ridge solve");
    let model = RidgeModel::new(ds.d, lambda, ds.n);
    let mut rng = Pcg32::new(seed, 303);
    let w: Vec<f64> = (0..ds.d).map(|_| rng.next_gaussian()).collect();
    let d_diam =
        pilot_diameter(&model, alpha, ds, &w_star, w, pilot_updates, &mut rng);
    BoundConstants { big_l, c, d_diam }
}

/// `D` estimate shared by the per-workload constant estimators: run
/// `pilot_updates` SGD steps from `w`, sampling `‖w − w_ref‖` every 256
/// updates, and return twice the largest radius seen (a diameter).
fn pilot_diameter<M: PointModel>(
    model: &M,
    alpha: f64,
    ds: &Dataset,
    w_ref: &[f64],
    mut w: Vec<f64>,
    pilot_updates: usize,
    rng: &mut Pcg32,
) -> f64 {
    let engine = SgdEngine::new(alpha);
    let store = StoreView::new(&ds.x, &ds.y, ds.d);
    let dist = |w: &[f64]| -> f64 {
        w.iter()
            .zip(w_ref)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    };
    let mut max_radius = dist(&w);
    let chunk = 256;
    let mut done = 0;
    while done < pilot_updates {
        let k = chunk.min(pilot_updates - done);
        engine.run_updates(model, &mut w, store, k, rng);
        max_radius = max_radius.max(dist(&w));
        done += k;
    }
    2.0 * max_radius
}

/// Conservative `(L, c, D)` for the logistic workload (labels in
/// `{0, 1}`).
///
/// The logistic empirical-risk Hessian is
/// `H(w) = (1/N) Σ σ'(wᵀx_i) x_i x_iᵀ + (2λ/N) I` with `σ' ≤ 1/4`, so
/// `L = λ_max(Gram)/4 + 2λ/N` is a uniform smoothness bound; the only
/// curvature guaranteed everywhere comes from the regularizer, so
/// `c = 2λ/N` (valid, very loose — the resulting Corollary-1 values are
/// upper bounds, not tight predictions). `D` comes from a pilot SGD run
/// against a longer reference run's final iterate, mirroring
/// [`estimate_constants`].
pub fn estimate_logistic_constants(
    ds: &Dataset,
    lambda: f64,
    alpha: f64,
    pilot_updates: usize,
    seed: u64,
) -> BoundConstants {
    let g = gram_matrix(&ds.x, ds.n, ds.d);
    let eig = jacobi_eigen(&g);
    let reg2 = 2.0 * lambda / ds.n as f64;
    let big_l = 0.25 * eig.values[ds.d - 1] + reg2;
    let c = reg2;

    let model = LogisticModel::new(ds.d, lambda, ds.n);
    let engine = SgdEngine::new(alpha);
    let store = StoreView::new(&ds.x, &ds.y, ds.d);

    // reference iterate: a longer run from the same init family
    let mut ref_rng = Pcg32::new(seed, 304);
    let mut w_ref: Vec<f64> =
        (0..ds.d).map(|_| ref_rng.next_gaussian()).collect();
    engine.run_updates(
        &model,
        &mut w_ref,
        store,
        4 * pilot_updates.max(1),
        &mut ref_rng,
    );

    // pilot trajectory radius around the reference
    let mut rng = Pcg32::new(seed, 303);
    let w: Vec<f64> = (0..ds.d).map(|_| rng.next_gaussian()).collect();
    let d_diam =
        pilot_diameter(&model, alpha, ds, &w_ref, w, pilot_updates, &mut rng);
    BoundConstants { big_l, c, d_diam }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    #[test]
    fn recovers_paper_constants_from_synth_data() {
        let ds = synth_calhousing(&SynthSpec { n: 4000, ..Default::default() });
        let k = estimate_constants(&ds, 0.05, 1e-4, 2000, 1);
        assert!((k.big_l - 1.908).abs() < 2e-3, "L = {}", k.big_l);
        assert!((k.c - 0.061).abs() < 2e-3, "c = {}", k.c);
        assert!(k.d_diam > 0.0 && k.d_diam.is_finite());
    }

    #[test]
    fn diameter_covers_init_distance() {
        // D must be at least twice the initial distance to w*.
        let ds = synth_calhousing(&SynthSpec { n: 1000, ..Default::default() });
        let lambda = 0.05;
        let w_star = ridge_solution(&ds, lambda).unwrap();
        let mut rng = Pcg32::new(9, 303);
        let w0: Vec<f64> = (0..ds.d).map(|_| rng.next_gaussian()).collect();
        let init_dist: f64 = w0
            .iter()
            .zip(&w_star)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let k = estimate_constants(&ds, lambda, 1e-4, 100, 9);
        assert!(k.d_diam >= 2.0 * init_dist - 1e-9);
    }

    #[test]
    fn logistic_constants_are_conservative() {
        use crate::data::classify::{synth_logistic, LogitSpec};
        let ds = synth_logistic(&LogitSpec { n: 800, ..Default::default() });
        let lambda = 0.05;
        let k = estimate_logistic_constants(&ds, lambda, 1e-2, 500, 3);
        let ridge_like = estimate_constants(&ds, lambda, 1e-2, 1, 3);
        // σ' ≤ 1/4 relates the two smoothness estimates:
        // L_logit = λ_max(G)/4 + 2λ/N vs L_ridge = 2·λ_max(G) + 2λ/N
        let reg2 = 2.0 * lambda / ds.n as f64;
        let expected = (ridge_like.big_l - reg2) / 8.0 + reg2;
        assert!((k.big_l - expected).abs() < 1e-9, "L = {}", k.big_l);
        assert!((k.c - reg2).abs() < 1e-15, "c = {}", k.c);
        assert!(k.d_diam > 0.0 && k.d_diam.is_finite());
        assert!(k.big_l > k.c);
    }
}
