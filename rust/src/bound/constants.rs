//! Estimation of the bound constants `(L, c, D)` from data.
//!
//! For the quadratic ridge loss the Hessian of the empirical risk is
//! `H = 2·(XᵀX/N) + (2λ/N)·I`, so the smoothness constant `L` is
//! `λ_max(H)` and the PL constant `c` is `λ_min(H)` (paper Sec. 5 uses
//! exactly these, reporting L = 1.908, c = 0.061). `D` (the diameter of
//! the iterate region, assumption A1) is estimated from a pilot SGD run.

use crate::data::Dataset;
use crate::linalg::{gram_matrix, jacobi_eigen};
use crate::model::{ridge_solution, RidgeModel};
use crate::sgd::{SgdEngine, StoreView};
use crate::util::rng::Pcg32;

/// Constants consumed by the Corollary-1 bound.
#[derive(Clone, Copy, Debug)]
pub struct BoundConstants {
    /// Smoothness constant L = λ_max(Hessian).
    pub big_l: f64,
    /// PL constant c = λ_min(Hessian).
    pub c: f64,
    /// Iterate-region diameter D.
    pub d_diam: f64,
}

/// Estimate `(L, c)` from the dataset's Gramian and `D` from a pilot run.
///
/// The pilot runs `pilot_updates` SGD steps over the full dataset from the
/// Gaussian init the experiments use, tracking `max ‖w − w*‖`; `D` is
/// twice that radius (a diameter).
pub fn estimate_constants(
    ds: &Dataset,
    lambda: f64,
    alpha: f64,
    pilot_updates: usize,
    seed: u64,
) -> BoundConstants {
    let g = gram_matrix(&ds.x, ds.n, ds.d);
    let eig = jacobi_eigen(&g);
    let reg2 = 2.0 * lambda / ds.n as f64;
    let big_l = 2.0 * eig.values[ds.d - 1] + reg2;
    let c = 2.0 * eig.values[0] + reg2;

    // pilot run for D
    let w_star = ridge_solution(ds, lambda).expect("ridge solve");
    let model = RidgeModel::new(ds.d, lambda, ds.n);
    let engine = SgdEngine::new(alpha);
    let mut rng = Pcg32::new(seed, 303);
    let mut w: Vec<f64> = (0..ds.d).map(|_| rng.next_gaussian()).collect();
    let store = StoreView::new(&ds.x, &ds.y, ds.d);

    let dist = |w: &[f64]| -> f64 {
        w.iter()
            .zip(&w_star)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    };
    let mut max_radius = dist(&w);
    let chunk = 256;
    let mut done = 0;
    while done < pilot_updates {
        let k = chunk.min(pilot_updates - done);
        engine.run_updates(&model, &mut w, store, k, &mut rng);
        max_radius = max_radius.max(dist(&w));
        done += k;
    }
    BoundConstants { big_l, c, d_diam: 2.0 * max_radius }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    #[test]
    fn recovers_paper_constants_from_synth_data() {
        let ds = synth_calhousing(&SynthSpec { n: 4000, ..Default::default() });
        let k = estimate_constants(&ds, 0.05, 1e-4, 2000, 1);
        assert!((k.big_l - 1.908).abs() < 2e-3, "L = {}", k.big_l);
        assert!((k.c - 0.061).abs() < 2e-3, "c = {}", k.c);
        assert!(k.d_diam > 0.0 && k.d_diam.is_finite());
    }

    #[test]
    fn diameter_covers_init_distance() {
        // D must be at least twice the initial distance to w*.
        let ds = synth_calhousing(&SynthSpec { n: 1000, ..Default::default() });
        let lambda = 0.05;
        let w_star = ridge_solution(&ds, lambda).unwrap();
        let mut rng = Pcg32::new(9, 303);
        let w0: Vec<f64> = (0..ds.d).map(|_| rng.next_gaussian()).collect();
        let init_dist: f64 = w0
            .iter()
            .zip(&w_star)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let k = estimate_constants(&ds, lambda, 1e-4, 100, 9);
        assert!(k.d_diam >= 2.0 * init_dist - 1e-9);
    }
}
