//! Sensitivity of the bound-optimal block size `ñ_c` to the constants.
//!
//! Practitioners must *estimate* `(L, c, D)` before they can evaluate
//! Corollary 1 (`estimate_constants` does it from the Gramian + a pilot
//! run). This module quantifies how much an estimation error moves the
//! recommendation: we perturb each constant by a multiplicative factor,
//! re-optimize, and report both the shifted `ñ_c` and — more importantly
//! — the *regret*: how much worse the perturbed recommendation scores
//! under the TRUE constants. Small regret ⇒ the paper's method is robust
//! to sloppy constant estimation (which is what makes it practical).

use super::corollary1::{corollary1_bound, BoundParams};
use super::optimizer::optimize_block_size;

/// One perturbation's outcome.
#[derive(Clone, Debug)]
pub struct SensitivityRow {
    /// Which constant was perturbed ("L", "c", "D", "alpha").
    pub constant: &'static str,
    /// Multiplicative perturbation applied.
    pub factor: f64,
    /// The block size recommended under the perturbed constants.
    pub n_c: usize,
    /// Bound value of that recommendation under the TRUE constants.
    pub true_bound_at_n_c: f64,
    /// Relative regret vs the true optimum: (above − opt) / opt.
    pub regret: f64,
}

/// Apply a multiplicative factor to one named constant.
fn perturb(p: &BoundParams, name: &str, factor: f64) -> BoundParams {
    let mut q = *p;
    match name {
        "L" => q.big_l *= factor,
        "c" => q.c *= factor,
        "D" => q.d_diam *= factor,
        "alpha" => q.alpha *= factor,
        other => panic!("unknown constant '{other}'"),
    }
    q
}

/// Sensitivity sweep: perturb each of `L, c, D, alpha` by each factor,
/// re-optimize, and score the recommendation under the true constants.
pub fn sensitivity_sweep(
    truth: &BoundParams,
    n: usize,
    t_budget: f64,
    n_o: f64,
    tau_p: f64,
    factors: &[f64],
) -> Vec<SensitivityRow> {
    let opt = optimize_block_size(truth, n, t_budget, n_o, tau_p);
    let mut rows = Vec::new();
    for &name in &["L", "c", "D", "alpha"] {
        for &factor in factors {
            let perturbed = perturb(truth, name, factor);
            if !perturbed.stepsize_ok() {
                continue; // an inflated L can violate condition (10)
            }
            let rec =
                optimize_block_size(&perturbed, n, t_budget, n_o, tau_p);
            let true_at = corollary1_bound(
                truth,
                n,
                t_budget,
                rec.n_c as f64,
                n_o,
                tau_p,
                false,
            );
            rows.push(SensitivityRow {
                constant: name,
                factor,
                n_c: rec.n_c,
                true_bound_at_n_c: true_at,
                regret: (true_at - opt.value) / opt.value,
            });
        }
    }
    rows
}

/// The worst regret across a sweep (headline robustness number).
pub fn max_regret(rows: &[SensitivityRow]) -> f64 {
    rows.iter().map(|r| r.regret).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn truth() -> BoundParams {
        BoundParams::paper_fig3(6.4)
    }

    const N: usize = 18576;
    const T: f64 = 1.5 * 18576.0;

    #[test]
    fn unperturbed_has_zero_regret() {
        let rows = sensitivity_sweep(&truth(), N, T, 100.0, 1.0, &[1.0]);
        for r in &rows {
            assert!(
                r.regret.abs() < 1e-12,
                "{} x1.0 regret {}",
                r.constant,
                r.regret
            );
        }
    }

    #[test]
    fn regret_is_nonnegative() {
        let rows = sensitivity_sweep(
            &truth(),
            N,
            T,
            100.0,
            1.0,
            &[0.5, 0.8, 1.25, 2.0],
        );
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.regret >= -1e-12, "{:?}", r);
            assert!(r.n_c >= 1 && r.n_c <= N);
        }
    }

    #[test]
    fn paper_method_is_robust_to_2x_estimation_error() {
        // The practical claim: being 2x off on any single constant costs
        // only a few percent of bound value — consistent with Fig. 4's
        // flat loss surface around the optimum.
        let rows = sensitivity_sweep(
            &truth(),
            N,
            T,
            100.0,
            1.0,
            &[0.5, 2.0],
        );
        let worst = max_regret(&rows);
        assert!(worst < 0.05, "max regret {worst} too large");
    }

    #[test]
    fn stepsize_violations_are_skipped() {
        // alpha x (huge) breaks condition (10); the sweep must skip it
        // rather than panic.
        let rows = sensitivity_sweep(
            &truth(),
            N,
            T,
            100.0,
            1.0,
            &[20000.0],
        );
        assert!(rows.iter().all(|r| r.constant != "alpha" || r.factor != 20000.0));
    }
}
