//! Theorem 1: the tighter, Monte-Carlo-estimable bound (eqs. 12–13).
//!
//! Unlike Corollary 1, Theorem 1 keeps the per-block initial-error terms
//! `E[L_{b−l}(w_{b−l}^{n_p}) − L_{b−l}(w*)]` instead of capping them by
//! `LD²/2`. The paper notes evaluating it requires Monte-Carlo over the
//! transmission sequence — which is exactly what this module does, by
//! replaying measured per-block losses from a coordinator run into the
//! bound's recursion. Used by `examples/bound_tightness.rs` and tests to
//! show Theorem 1 ≤ Corollary 1.

use super::corollary1::BoundParams;

/// Per-block measurements extracted from a (simulated) run: for each
/// transmission block `b`, the gap `L_b(w_b^{n_p}) − L_b(w*)` of the
/// block-local empirical loss (paper eq. (7)) at the block's end.
#[derive(Clone, Debug)]
pub struct BlockGaps {
    /// gaps[b-1] = measured E_b-style gap for block b (1-indexed blocks).
    pub gaps: Vec<f64>,
    /// Gap of the remainder loss ΔL_B (case (a) only; eq. (8)).
    pub remainder_gap: f64,
}

/// Evaluate the Theorem-1 bound (eq. 12) for case (a), `T ≤ B_d(n_c+n_o)`,
/// using measured per-block gaps.
///
/// * `b` — number of blocks B that fit in T
/// * `b_d` — B_d = N/n_c (real-valued, paper convention)
/// * `n_p` — SGD updates per block
pub fn theorem1_case_a(
    p: &BoundParams,
    gaps: &BlockGaps,
    b: usize,
    b_d: f64,
    n_p: f64,
) -> f64 {
    assert!(b >= 1 && gaps.gaps.len() >= b - 1, "need B-1 block gaps");
    let a = p.bias_floor();
    let q = p.contraction();
    let frac = ((b as f64 - 1.0) / b_d).clamp(0.0, 1.0);

    let mut acc = a * frac + (1.0 - frac) * gaps.remainder_gap;
    for l in 1..b {
        // block index B-l is 1-indexed -> gaps[B-l-1]
        let gap = gaps.gaps[b - l - 1];
        acc += q.powf(l as f64 * n_p) * (gap - a) / b_d;
    }
    acc
}

/// Evaluate the Theorem-1 bound (eq. 13) for case (b),
/// `T > B_d(n_c+n_o)`, with `n_l` tail updates.
pub fn theorem1_case_b(
    p: &BoundParams,
    gaps: &BlockGaps,
    b_d: usize,
    n_p: f64,
    n_l: f64,
) -> f64 {
    assert!(gaps.gaps.len() >= b_d, "need B_d block gaps");
    let a = p.bias_floor();
    let q = p.contraction();
    let mut acc = a;
    let tail = q.powf(n_l);
    for l in 0..b_d {
        let gap = gaps.gaps[b_d - l - 1];
        acc += tail * q.powf(l as f64 * n_p) * (gap - a) / b_d as f64;
    }
    acc
}

/// The Corollary-1 relaxation replaces every measured gap by `LD²/2`;
/// check: plugging the cap into the Theorem-1 evaluators must reproduce
/// the Corollary-1 value (used as a consistency test).
pub fn capped_gaps(p: &BoundParams, blocks: usize) -> BlockGaps {
    BlockGaps {
        gaps: vec![p.initial_error_cap(); blocks],
        remainder_gap: p.initial_error_cap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bound::corollary1::corollary1_bound;

    fn params() -> BoundParams {
        BoundParams::paper_fig3(3.0)
    }

    #[test]
    fn capped_theorem1_equals_corollary1_case_a() {
        let p = params();
        let (n, n_o, tau_p) = (18576usize, 10.0, 1.0);
        let n_c = 50.0;
        let t = 10_000.0; // well inside case (a)
        let block_len = n_c + n_o;
        let b = (t / block_len) as usize;
        let b_d = n as f64 / n_c;
        let n_p = block_len / tau_p;
        let gaps = capped_gaps(&p, b);
        let th = theorem1_case_a(&p, &gaps, b, b_d, n_p);
        let co = corollary1_bound(&p, n, t, n_c, n_o, tau_p, false);
        // Corollary uses floor(B)-1 sum terms and the real-valued (B-1)/B_d
        // fraction; with matching discretization the two must agree.
        let b_real = t / block_len;
        let frac_adjust = (b_real - b as f64) * (p.initial_error_cap() - p.bias_floor()) / b_d;
        assert!(
            (th - co).abs() <= frac_adjust.abs() + 1e-9,
            "theorem1 {th} vs corollary1 {co}"
        );
    }

    #[test]
    fn capped_theorem1_equals_corollary1_case_b() {
        let p = params();
        let (n, n_o, tau_p) = (1000usize, 5.0, 1.0);
        let n_c = 100.0;
        let block_len = n_c + n_o;
        let b_d = n as f64 / n_c; // exactly 10
        let t = b_d * block_len + 500.0;
        let n_l = 500.0;
        let gaps = capped_gaps(&p, b_d as usize);
        let th = theorem1_case_b(&p, &gaps, b_d as usize, block_len / tau_p, n_l);
        let co = corollary1_bound(&p, n, t, n_c, n_o, tau_p, false);
        assert!((th - co).abs() / co < 1e-9, "{th} vs {co}");
    }

    #[test]
    fn smaller_measured_gaps_tighten_the_bound() {
        let p = params();
        let b = 20usize;
        let (b_d, n_p) = (100.0, 60.0);
        let capped = capped_gaps(&p, b);
        let tighter = BlockGaps {
            gaps: vec![p.initial_error_cap() * 0.1; b],
            remainder_gap: p.initial_error_cap() * 0.1,
        };
        let loose = theorem1_case_a(&p, &capped, b, b_d, n_p);
        let tight = theorem1_case_a(&p, &tighter, b, b_d, n_p);
        assert!(tight < loose, "{tight} vs {loose}");
    }
}
