//! Block-size optimizer: pick `ñ_c = argmin` of the Corollary-1 bound.
//!
//! The bound evaluates in O(1) (closed-form geometric sums), so a full
//! integer scan over `n_c ∈ [1, N]` is exact and cheap (~20k evals for the
//! paper's N). The scan also records the full-delivery boundary (the dots
//! in paper Fig. 3) and whether the optimum sits in case (a) — the paper's
//! "forego some training points for more training time" regime.

use crate::protocol::{Timeline, TimelineCase};

use super::corollary1::{corollary1_bound, BoundParams};

/// Result of optimizing the block size.
#[derive(Clone, Debug)]
pub struct BoundOptimum {
    /// The bound-minimizing block size ñ_c.
    pub n_c: usize,
    /// Bound value at ñ_c.
    pub value: f64,
    /// Smallest n_c that still delivers the whole dataset within T
    /// (None if even n_c = N cannot).
    pub full_delivery_boundary: Option<usize>,
    /// Which Fig. 2 case the optimum falls in.
    pub case: TimelineCase,
}

/// Exact integer argmin of the Corollary-1 bound over `n_c ∈ [1, N]`.
pub fn optimize_block_size(
    p: &BoundParams,
    n: usize,
    t_budget: f64,
    n_o: f64,
    tau_p: f64,
) -> BoundOptimum {
    let mut best_nc = 1usize;
    let mut best = f64::INFINITY;
    for nc in 1..=n {
        let g = corollary1_bound(p, n, t_budget, nc as f64, n_o, tau_p, false);
        if g < best {
            best = g;
            best_nc = nc;
        }
    }
    let tl = Timeline::resolve(n, t_budget, best_nc, n_o, tau_p);
    BoundOptimum {
        n_c: best_nc,
        value: best,
        full_delivery_boundary: Timeline::full_delivery_boundary(
            n, t_budget, n_o,
        ),
        case: tl.case,
    }
}

/// Scan the bound over a set of block sizes (Fig. 3 curve producer).
pub fn scan_bound(
    p: &BoundParams,
    n: usize,
    t_budget: f64,
    n_o: f64,
    tau_p: f64,
    n_cs: &[usize],
) -> Vec<(usize, f64)> {
    n_cs.iter()
        .map(|&nc| {
            (nc, corollary1_bound(p, n, t_budget, nc as f64, n_o, tau_p, false))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 18576;
    const T: f64 = 1.5 * 18576.0;

    #[test]
    fn optimum_beats_grid() {
        let p = BoundParams::paper_fig3(3.0);
        let opt = optimize_block_size(&p, N, T, 10.0, 1.0);
        for nc in (1..=N).step_by(97) {
            let g = corollary1_bound(&p, N, T, nc as f64, 10.0, 1.0, false);
            assert!(opt.value <= g + 1e-15, "beaten at n_c={nc}");
        }
    }

    #[test]
    fn optimum_is_interior() {
        let p = BoundParams::paper_fig3(3.0);
        let opt = optimize_block_size(&p, N, T, 10.0, 1.0);
        assert!(opt.n_c > 1 && opt.n_c < N, "ñ_c = {}", opt.n_c);
    }

    #[test]
    fn paper_small_overhead_lands_in_case_b() {
        // Paper Sec. 4 (Fig. 3 discussion): for small n_o the minimizer
        // delivers the full dataset (case b); for large n_o it does not.
        let p = BoundParams::paper_fig3(3.0);
        let small = optimize_block_size(&p, N, T, 1.0, 1.0);
        assert_eq!(small.case, TimelineCase::Full, "n_o=1 -> case (b)");
        // with our calibrated constants the crossover sits near n_o ≈ 2e3
        let large = optimize_block_size(&p, N, T, 3000.0, 1.0);
        assert_eq!(large.case, TimelineCase::Partial, "n_o=3000 -> case (a)");
    }

    #[test]
    fn scan_matches_pointwise_eval() {
        let p = BoundParams::paper_fig3(3.0);
        let n_cs: Vec<usize> = vec![1, 10, 100, 1000];
        let rows = scan_bound(&p, N, T, 5.0, 1.0, &n_cs);
        assert_eq!(rows.len(), 4);
        for (nc, v) in rows {
            let direct =
                corollary1_bound(&p, N, T, nc as f64, 5.0, 1.0, false);
            assert_eq!(v, direct);
        }
    }
}
