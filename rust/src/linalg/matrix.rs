//! Row-major dense f64 matrix with just the operations the crate needs.

use std::ops::{Index, IndexMut};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major flat slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> Mat {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably (kernel accumulation into Gram rows).
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| {
                self.row(i).iter().zip(v).map(|(a, b)| a * b).sum::<f64>()
            })
            .collect()
    }

    /// Max absolute entry difference to another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Is this matrix symmetric to tolerance `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(2, 2, &[19.0, 22.0, 43.0, 50.0]));
    }

    #[test]
    fn identity_is_neutral() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(Mat::eye(2).matmul(&a), a);
        assert_eq!(a.matmul(&Mat::eye(3)), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn matvec_known() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn symmetry_check() {
        let s = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 5.0]);
        assert!(s.is_symmetric(1e-12));
        let ns = Mat::from_rows(2, 2, &[1.0, 2.0, 2.1, 5.0]);
        assert!(!ns.is_symmetric(1e-3));
    }
}
