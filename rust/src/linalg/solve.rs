//! Gauss–Jordan linear solver with partial pivoting (for the d×d normal
//! equations that give the exact ridge solution w*).

use anyhow::{bail, Result};

use super::matrix::Mat;

/// Solve `A x = b` for square `A` by Gauss–Jordan with partial pivoting.
pub fn solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    let n = a.rows();
    if a.cols() != n {
        bail!("solve requires a square matrix, got {}x{}", n, a.cols());
    }
    if b.len() != n {
        bail!("rhs length {} != {}", b.len(), n);
    }
    // augmented system in working copies
    let mut m = a.clone();
    let mut x = b.to_vec();

    for col in 0..n {
        // partial pivot
        let mut piv = col;
        let mut best = m[(col, col)].abs();
        for r in (col + 1)..n {
            if m[(r, col)].abs() > best {
                best = m[(r, col)].abs();
                piv = r;
            }
        }
        if best < 1e-300 {
            bail!("singular matrix (pivot {col})");
        }
        if piv != col {
            for j in 0..n {
                let tmp = m[(col, j)];
                m[(col, j)] = m[(piv, j)];
                m[(piv, j)] = tmp;
            }
            x.swap(col, piv);
        }
        // normalize pivot row
        let p = m[(col, col)];
        for j in 0..n {
            m[(col, j)] /= p;
        }
        x[col] /= p;
        // eliminate column everywhere else
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = m[(r, col)];
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                m[(r, j)] -= f * m[(col, j)];
            }
            x[r] -= f * x[col];
        }
    }
    Ok(x)
}

/// Invert a square matrix (column-by-column solve). Used in tests and for
/// small whitening transforms.
pub fn invert(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    let mut out = Mat::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        let col = solve(a, &e)?;
        for i in 0..n {
            out[(i, j)] = col[i];
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_known_system() {
        let a = Mat::from_rows(2, 2, &[2.0, 1.0, 1.0, 3.0]);
        let x = solve(&a, &[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = Mat::from_rows(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        let x = solve(&a, &[2.0, 3.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn singular_is_error() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        assert!(solve(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn residual_is_small() {
        let a = Mat::from_rows(
            3,
            3,
            &[4.0, -2.0, 1.0, -2.0, 4.0, -2.0, 1.0, -2.0, 4.0],
        );
        let b = [1.0, 2.0, 3.0];
        let x = solve(&a, &b).unwrap();
        let ax = a.matvec(&x);
        for i in 0..3 {
            assert!((ax[i] - b[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let a = Mat::from_rows(3, 3, &[2.0, 1.0, 0.0, 1.0, 3.0, 1.0, 0.0, 1.0, 2.0]);
        let inv = invert(&a).unwrap();
        assert!(inv.matmul(&a).max_abs_diff(&Mat::eye(3)) < 1e-12);
    }
}
