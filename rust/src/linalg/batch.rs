//! Lane-striped SoA kernels for the batched-seed Monte-Carlo engine.
//!
//! `L` seed-lanes of the *same scenario point* share one
//! structure-of-arrays weight state: element `j` of lane `l` lives at
//! `w[j * L + l]`, so a loop over lanes at fixed `j` is a contiguous
//! vector op the compiler autovectorizes on stable Rust (explicit
//! fixed-width accumulator arrays, no `std::simd`). Covariates are
//! gathered into the same layout per step (`x[j * L + l]`, f32) with
//! labels widened once into `y[l]`.
//!
//! **Bit-exactness contract.** Batching is *across* lanes only: each
//! lane's per-update arithmetic order is exactly the scalar model's, so
//! every lane's trajectory — and final loss — is bit-identical to a
//! scalar run by construction. Concretely, the per-lane reassociation
//! rule pinned here (and in ARCHITECTURE.md, "Batched-seed execution")
//! is:
//!
//! * general `d`: the lane dot uses [`dot_f32_f64`]'s association —
//!   four accumulators over chunks of 4, sequential tail, combined
//!   `(a0 + a1) + (a2 + a3) + tail` ([`lane_dot`]);
//! * ridge `d == 8`: a single sequential accumulator
//!   ([`lane_dot_seq`]), matching `RidgeModel`'s fixed-size fused step;
//! * the weight update `w[j] = w[j]·shrink − coeff·x[j]` is
//!   element-wise (no reassociation) in both engines.
//!
//! Because the rule is "same association per lane", the parity bound is
//! 0 ULP — the tests below assert bit equality, not closeness.
//!
//! **Inactive lanes** (timeline drained, or a ragged group smaller than
//! the lane width) are neutralized per update by `coeff = 0.0`,
//! `shrink = 1.0` *and* zero-filled covariate columns, which preserves
//! the lane's weights bit-for-bit — including `NaN`/`±Inf` columns,
//! since `w·1.0 − 0.0·0.0 = w` for every finite, infinite, or NaN `w`.
//! Lanes never share an accumulator, so a poisoned lane cannot
//! contaminate its neighbors.
//!
//! [`dot_f32_f64`]: crate::linalg::kernels::dot_f32_f64

use crate::linalg::kernels::sigmoid;

/// Widest supported lane count (SoA scratch is sized for this).
pub const MAX_LANES: usize = 16;

/// The lane widths the batched engine monomorphizes for.
pub const LANE_WIDTHS: [usize; 3] = [4, 8, 16];

/// Snap a requested lane count to a supported width: `0`/`1` mean
/// scalar, `2..=5 → 4`, `6..=11 → 8`, `≥ 12 → 16`.
pub fn snap_lanes(requested: usize) -> usize {
    match requested {
        0 | 1 => 1,
        2..=5 => 4,
        6..=11 => 8,
        _ => 16,
    }
}

/// Per-lane `z[l] = Σ_j w[j·L + l] · x[j·L + l]` with
/// [`dot_f32_f64`](crate::linalg::kernels::dot_f32_f64)'s pinned
/// association applied independently in every lane: four accumulator
/// arrays over chunks of 4 dimensions, a sequential tail, combined
/// `(a0 + a1) + (a2 + a3) + tail`.
#[inline]
pub fn lane_dot<const L: usize>(
    w: &[f64],
    x: &[f32],
    d: usize,
    out: &mut [f64; L],
) {
    debug_assert_eq!(w.len(), d * L, "lane dot shape mismatch");
    debug_assert_eq!(x.len(), d * L, "lane dot shape mismatch");
    let chunks = d / 4;
    let mut a0 = [0.0f64; L];
    let mut a1 = [0.0f64; L];
    let mut a2 = [0.0f64; L];
    let mut a3 = [0.0f64; L];
    for c in 0..chunks {
        let b = c * 4 * L;
        for l in 0..L {
            a0[l] += w[b + l] * x[b + l] as f64;
        }
        for l in 0..L {
            a1[l] += w[b + L + l] * x[b + L + l] as f64;
        }
        for l in 0..L {
            a2[l] += w[b + 2 * L + l] * x[b + 2 * L + l] as f64;
        }
        for l in 0..L {
            a3[l] += w[b + 3 * L + l] * x[b + 3 * L + l] as f64;
        }
    }
    let mut tail = [0.0f64; L];
    for j in chunks * 4..d {
        let b = j * L;
        for l in 0..L {
            tail[l] += w[b + l] * x[b + l] as f64;
        }
    }
    for l in 0..L {
        out[l] = (a0[l] + a1[l]) + (a2[l] + a3[l]) + tail[l];
    }
}

/// Per-lane dot with a *single sequential accumulator* — the
/// association of `RidgeModel`'s fixed `d == 8` fused step, applied
/// independently in every lane.
#[inline]
pub fn lane_dot_seq<const L: usize>(
    w: &[f64],
    x: &[f32],
    d: usize,
    out: &mut [f64; L],
) {
    debug_assert_eq!(w.len(), d * L, "lane dot shape mismatch");
    debug_assert_eq!(x.len(), d * L, "lane dot shape mismatch");
    let mut acc = [0.0f64; L];
    for j in 0..d {
        let b = j * L;
        for l in 0..L {
            acc[l] += w[b + l] * x[b + l] as f64;
        }
    }
    *out = acc;
}

/// Per-lane axpy `y[j·L + l] += a[l] · x[j·L + l]` — element-wise per
/// lane, so bit-identical to
/// [`axpy_f32_f64`](crate::linalg::kernels::axpy_f32_f64) per column.
#[inline]
pub fn lane_axpy<const L: usize>(
    a: &[f64; L],
    x: &[f32],
    y: &mut [f64],
    d: usize,
) {
    debug_assert_eq!(x.len(), d * L, "lane axpy shape mismatch");
    debug_assert_eq!(y.len(), d * L, "lane axpy shape mismatch");
    for j in 0..d {
        let b = j * L;
        for l in 0..L {
            y[b + l] += a[l] * x[b + l] as f64;
        }
    }
}

/// Dense lane-striped weight update
/// `w[j·L + l] = w[j·L + l] · shrink[l] − coeff[l] · x[j·L + l]` —
/// the element-wise second half of both models' fused SGD step.
/// Neutral lanes pass `coeff = 0.0`, `shrink = 1.0` (with zero-filled
/// `x` columns) and keep their weights bit-for-bit.
#[inline]
pub fn lane_update<const L: usize>(
    w: &mut [f64],
    x: &[f32],
    d: usize,
    coeff: &[f64; L],
    shrink: &[f64; L],
) {
    debug_assert_eq!(w.len(), d * L, "lane update shape mismatch");
    debug_assert_eq!(x.len(), d * L, "lane update shape mismatch");
    for j in 0..d {
        let b = j * L;
        for l in 0..L {
            w[b + l] = w[b + l] * shrink[l] - coeff[l] * x[b + l] as f64;
        }
    }
}

/// Fused lane-batched ridge SGD step, matching
/// `RidgeModel::sgd_step` per lane bit-for-bit: sequential dot on the
/// fixed `d == 8` path, [`lane_dot`] association otherwise, then
/// `w ← w·(1 − α·reg2) − 2α(z − y)·x` on active lanes.
pub fn lane_ridge_step<const L: usize>(
    w: &mut [f64],
    x: &[f32],
    y: &[f64; L],
    active: &[bool; L],
    d: usize,
    alpha: f64,
    reg2: f64,
) {
    let mut z = [0.0f64; L];
    if d == 8 {
        lane_dot_seq::<L>(w, x, d, &mut z);
    } else {
        lane_dot::<L>(w, x, d, &mut z);
    }
    let shrink_on = 1.0 - alpha * reg2;
    let mut coeff = [0.0f64; L];
    let mut shrink = [1.0f64; L];
    for l in 0..L {
        if active[l] {
            coeff[l] = 2.0 * alpha * (z[l] - y[l]);
            shrink[l] = shrink_on;
        }
    }
    lane_update::<L>(w, x, d, &coeff, &shrink);
}

/// Fused lane-batched logistic SGD step, matching
/// `LogisticModel::sgd_step` per lane bit-for-bit ([`lane_dot`]
/// association for every `d`, then
/// `w ← w·(1 − α·reg2) − α(σ(z) − y)·x` on active lanes).
pub fn lane_logistic_step<const L: usize>(
    w: &mut [f64],
    x: &[f32],
    y: &[f64; L],
    active: &[bool; L],
    d: usize,
    alpha: f64,
    reg2: f64,
) {
    let mut z = [0.0f64; L];
    lane_dot::<L>(w, x, d, &mut z);
    let shrink_on = 1.0 - alpha * reg2;
    let mut coeff = [0.0f64; L];
    let mut shrink = [1.0f64; L];
    for l in 0..L {
        if active[l] {
            coeff[l] = alpha * (sigmoid(z[l]) - y[l]);
            shrink[l] = shrink_on;
        }
    }
    lane_update::<L>(w, x, d, &coeff, &shrink);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernels::{axpy_f32_f64, dot_f32_f64};
    use crate::model::{LogisticModel, PointModel, RidgeModel};
    use crate::util::rng::Pcg32;

    const DIMS: &[usize] = &[1, 3, 7, 8, 9, 33];

    /// Pack per-lane AoS rows into the SoA layout (`soa[j·L + l]`).
    fn pack_f64<const L: usize>(cols: &[Vec<f64>], d: usize) -> Vec<f64> {
        let mut soa = vec![0.0f64; d * L];
        for (l, col) in cols.iter().enumerate() {
            for j in 0..d {
                soa[j * L + l] = col[j];
            }
        }
        soa
    }

    fn pack_f32<const L: usize>(cols: &[Vec<f32>], d: usize) -> Vec<f32> {
        let mut soa = vec![0.0f32; d * L];
        for (l, col) in cols.iter().enumerate() {
            for j in 0..d {
                soa[j * L + l] = col[j];
            }
        }
        soa
    }

    fn unpack_col<const L: usize>(soa: &[f64], d: usize, l: usize) -> Vec<f64> {
        (0..d).map(|j| soa[j * L + l]).collect()
    }

    fn lane_case<const L: usize>(
        d: usize,
        seed: u64,
    ) -> (Vec<Vec<f64>>, Vec<Vec<f32>>, Vec<f64>) {
        let mut rng = Pcg32::seeded(seed);
        let ws: Vec<Vec<f64>> = (0..L)
            .map(|_| (0..d).map(|_| rng.next_gaussian()).collect())
            .collect();
        let xs: Vec<Vec<f32>> = (0..L)
            .map(|_| (0..d).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let ys: Vec<f64> =
            (0..L).map(|_| rng.next_gaussian() as f32 as f64).collect();
        (ws, xs, ys)
    }

    /// Assert two f64 slices are bit-identical (NaN-safe).
    fn assert_bits(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (va, vb)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: bit mismatch at {i}: {va} vs {vb}"
            );
        }
    }

    fn dot_parity_case<const L: usize>(d: usize, seed: u64) {
        let (ws, xs, _) = lane_case::<L>(d, seed);
        let w_soa = pack_f64::<L>(&ws, d);
        let x_soa = pack_f32::<L>(&xs, d);
        let mut got = [0.0f64; L];
        lane_dot::<L>(&w_soa, &x_soa, d, &mut got);
        for l in 0..L {
            let want = dot_f32_f64(&ws[l], &xs[l]);
            assert_eq!(
                got[l].to_bits(),
                want.to_bits(),
                "lane_dot L={L} d={d} lane {l}: {} vs {want}",
                got[l]
            );
        }
        // sequential variant vs a plain sequential scalar loop
        let mut got_seq = [0.0f64; L];
        lane_dot_seq::<L>(&w_soa, &x_soa, d, &mut got_seq);
        for l in 0..L {
            let mut want = 0.0f64;
            for j in 0..d {
                want += ws[l][j] * xs[l][j] as f64;
            }
            assert_eq!(
                got_seq[l].to_bits(),
                want.to_bits(),
                "lane_dot_seq L={L} d={d} lane {l}"
            );
        }
    }

    #[test]
    fn lane_dot_matches_scalar_bitwise_on_all_dims_and_widths() {
        for &d in DIMS {
            dot_parity_case::<4>(d, 10 + d as u64);
            dot_parity_case::<8>(d, 20 + d as u64);
            dot_parity_case::<16>(d, 30 + d as u64);
        }
    }

    fn axpy_parity_case<const L: usize>(d: usize, seed: u64) {
        let (ws, xs, ys) = lane_case::<L>(d, seed);
        let mut soa = pack_f64::<L>(&ws, d);
        let x_soa = pack_f32::<L>(&xs, d);
        let mut a = [0.0f64; L];
        for l in 0..L {
            a[l] = ys[l];
        }
        lane_axpy::<L>(&a, &x_soa, &mut soa, d);
        for l in 0..L {
            let mut want = ws[l].clone();
            axpy_f32_f64(a[l], &xs[l], &mut want);
            assert_bits(
                &unpack_col::<L>(&soa, d, l),
                &want,
                &format!("lane_axpy L={L} d={d} lane {l}"),
            );
        }
    }

    #[test]
    fn lane_axpy_matches_scalar_bitwise() {
        for &d in DIMS {
            axpy_parity_case::<4>(d, 40 + d as u64);
            axpy_parity_case::<8>(d, 50 + d as u64);
            axpy_parity_case::<16>(d, 60 + d as u64);
        }
    }

    /// Run `steps` fused lane steps against the real scalar models with
    /// the given active mask; inactive lanes get zero-filled covariate
    /// columns (as the batch runner gathers them) and must keep their
    /// weights bit-for-bit.
    fn step_parity_case<const L: usize>(
        d: usize,
        steps: usize,
        active: [bool; L],
        logistic: bool,
        seed: u64,
    ) {
        let alpha = 1e-2;
        let lambda = 0.05;
        let n_full = 100;
        let ridge = RidgeModel::new(d, lambda, n_full);
        let logit = LogisticModel::new(d, lambda, n_full);
        let reg2 = 2.0 * lambda / n_full as f64;

        let (ws, _, _) = lane_case::<L>(d, seed);
        let mut soa = pack_f64::<L>(&ws, d);
        let mut scalar_w = ws.clone();
        let mut rng = Pcg32::seeded(seed ^ 0xbeef);
        for step in 0..steps {
            // fresh per-lane samples each step
            let mut xs: Vec<Vec<f32>> = Vec::new();
            let mut y = [0.0f64; L];
            let mut y32 = [0.0f32; L];
            for l in 0..L {
                let row: Vec<f32> = (0..d)
                    .map(|_| rng.next_gaussian() as f32)
                    .collect();
                y32[l] = if logistic {
                    ((l + step) % 2) as f32
                } else {
                    rng.next_gaussian() as f32
                };
                xs.push(row);
            }
            // inactive lanes gather zeros, like the batch runner
            for l in 0..L {
                if active[l] {
                    y[l] = y32[l] as f64;
                } else {
                    xs[l].iter_mut().for_each(|v| *v = 0.0);
                }
            }
            let x_soa = pack_f32::<L>(&xs, d);
            if logistic {
                lane_logistic_step::<L>(
                    &mut soa, &x_soa, &y, &active, d, alpha, reg2,
                );
            } else {
                lane_ridge_step::<L>(
                    &mut soa, &x_soa, &y, &active, d, alpha, reg2,
                );
            }
            for l in 0..L {
                if !active[l] {
                    continue;
                }
                if logistic {
                    logit.sgd_step(&mut scalar_w[l], &xs[l], y32[l], alpha);
                } else {
                    ridge.sgd_step(&mut scalar_w[l], &xs[l], y32[l], alpha);
                }
            }
        }
        let kind = if logistic { "logistic" } else { "ridge" };
        for l in 0..L {
            assert_bits(
                &unpack_col::<L>(&soa, d, l),
                &scalar_w[l],
                &format!("{kind} step L={L} d={d} lane {l} active={}", active[l]),
            );
        }
    }

    #[test]
    fn fused_steps_match_scalar_models_bitwise() {
        for &d in DIMS {
            for logistic in [false, true] {
                step_parity_case::<4>(d, 5, [true; 4], logistic, 70 + d as u64);
                step_parity_case::<8>(d, 5, [true; 8], logistic, 80 + d as u64);
                step_parity_case::<16>(
                    d,
                    3,
                    [true; 16],
                    logistic,
                    90 + d as u64,
                );
            }
        }
    }

    #[test]
    fn ragged_masks_with_holes_leave_inactive_lanes_untouched() {
        // masks with interior holes, a dead tail, and a single survivor
        let mut hole8 = [true; 8];
        hole8[1] = false;
        hole8[5] = false;
        let mut tail8 = [false; 8];
        tail8[..3].iter_mut().for_each(|v| *v = true);
        let mut solo8 = [false; 8];
        solo8[6] = true;
        for mask in [hole8, tail8, solo8] {
            for logistic in [false, true] {
                step_parity_case::<8>(8, 4, mask, logistic, 0xa11);
                step_parity_case::<8>(9, 4, mask, logistic, 0xa12);
            }
        }
        let mut hole4 = [true; 4];
        hole4[2] = false;
        step_parity_case::<4>(3, 4, hole4, false, 0xa13);
        let mut hole16 = [true; 16];
        hole16[0] = false;
        hole16[9] = false;
        hole16[15] = false;
        step_parity_case::<16>(7, 3, hole16, true, 0xa14);
    }

    #[test]
    fn all_inactive_step_is_a_bitwise_noop() {
        step_parity_case::<4>(8, 3, [false; 4], false, 0xb01);
        step_parity_case::<8>(5, 3, [false; 8], true, 0xb02);
    }

    #[test]
    fn empty_inputs_are_noops() {
        let mut out = [1.0f64; 4];
        lane_dot::<4>(&[], &[], 0, &mut out);
        assert_eq!(out, [0.0; 4]);
        lane_dot_seq::<4>(&[], &[], 0, &mut out);
        assert_eq!(out, [0.0; 4]);
        let mut w: Vec<f64> = vec![];
        lane_axpy::<4>(&[2.0; 4], &[], &mut w, 0);
        lane_update::<4>(&mut w, &[], 0, &[1.0; 4], &[0.5; 4]);
        assert!(w.is_empty());
    }

    #[test]
    fn poisoned_lane_does_not_contaminate_neighbors() {
        const L: usize = 8;
        let d = 8;
        let alpha = 1e-2;
        let reg2 = 0.01;
        let ridge = RidgeModel::new(d, 0.05, 10);
        let (ws, xs, ys) = lane_case::<L>(d, 0xc0de);
        let mut soa = pack_f64::<L>(&ws, d);
        // poison lane 3's weights with NaN and lane 5's sample with Inf
        for j in 0..d {
            soa[j * L + 3] = f64::NAN;
        }
        let mut xs = xs;
        xs[5][2] = f32::INFINITY;
        let x_soa = pack_f32::<L>(&xs, d);
        let mut y = [0.0f64; L];
        for l in 0..L {
            y[l] = ys[l];
        }
        lane_ridge_step::<L>(
            &mut soa, &x_soa, &y, &[true; L], d, alpha, reg2,
        );
        for l in 0..L {
            let col = unpack_col::<L>(&soa, d, l);
            match l {
                3 => assert!(
                    col.iter().all(|v| v.is_nan()),
                    "poisoned lane lost its NaN"
                ),
                5 => assert!(
                    col.iter().any(|v| !v.is_finite()),
                    "Inf sample must poison its own lane"
                ),
                _ => {
                    // healthy lanes: bit-exact vs the scalar model
                    // (RidgeModel::new(d, 0.05, 10) has reg2 = 0.01)
                    let mut want = ws[l].clone();
                    ridge.sgd_step(&mut want, &xs[l], ys[l] as f32, alpha);
                    assert_bits(&col, &want, &format!("healthy lane {l}"));
                }
            }
        }
        // an inactive NaN lane is preserved bit-for-bit too
        let mut soa2 = pack_f64::<L>(&ws, d);
        for j in 0..d {
            soa2[j * L] = f64::NAN;
        }
        let before = unpack_col::<L>(&soa2, d, 0);
        let mut mask = [true; L];
        mask[0] = false;
        let mut xs0 = xs.clone();
        xs0[0].iter_mut().for_each(|v| *v = 0.0);
        let x_soa0 = pack_f32::<L>(&xs0, d);
        lane_ridge_step::<L>(
            &mut soa2, &x_soa0, &y, &mask, d, alpha, reg2,
        );
        let after = unpack_col::<L>(&soa2, d, 0);
        for (a, b) in before.iter().zip(&after) {
            assert!(a.is_nan() && b.is_nan(), "inactive NaN lane changed");
        }
    }

    #[test]
    fn snap_lanes_covers_the_supported_widths() {
        assert_eq!(snap_lanes(0), 1);
        assert_eq!(snap_lanes(1), 1);
        assert_eq!(snap_lanes(2), 4);
        assert_eq!(snap_lanes(4), 4);
        assert_eq!(snap_lanes(5), 4);
        assert_eq!(snap_lanes(6), 8);
        assert_eq!(snap_lanes(8), 8);
        assert_eq!(snap_lanes(11), 8);
        assert_eq!(snap_lanes(12), 16);
        assert_eq!(snap_lanes(64), 16);
        for w in LANE_WIDTHS {
            assert_eq!(snap_lanes(w), w);
        }
    }
}
