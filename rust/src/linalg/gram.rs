//! Gram matrix of a dataset: `G = (1/n) Xᵀ X` over flat row-major samples.
//!
//! The Hessian of the paper's empirical ridge loss is `2G + (2λ/N) I`; its
//! extreme eigenvalues are the smoothness constant `L` and the PL constant
//! `c` used by the Corollary-1 bound (paper Sec. 4/5).

use super::matrix::Mat;

/// Compute `(1/n) Xᵀ X` from flat row-major `f32` data (n rows, d cols).
pub fn gram_matrix(x: &[f32], n: usize, d: usize) -> Mat {
    assert_eq!(x.len(), n * d, "data length mismatch");
    assert!(n > 0, "empty dataset");
    let mut g = Mat::zeros(d, d);
    for row in x.chunks_exact(d) {
        for i in 0..d {
            let xi = row[i] as f64;
            for j in i..d {
                g[(i, j)] += xi * row[j] as f64;
            }
        }
    }
    let inv_n = 1.0 / n as f64;
    for i in 0..d {
        for j in i..d {
            let v = g[(i, j)] * inv_n;
            g[(i, j)] = v;
            g[(j, i)] = v;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_small_case() {
        // X = [[1,0],[0,2],[1,1]]; XᵀX = [[2,1],[1,5]]; /3
        let x = [1.0f32, 0.0, 0.0, 2.0, 1.0, 1.0];
        let g = gram_matrix(&x, 3, 2);
        assert!((g[(0, 0)] - 2.0 / 3.0).abs() < 1e-12);
        assert!((g[(0, 1)] - 1.0 / 3.0).abs() < 1e-12);
        assert!((g[(1, 0)] - 1.0 / 3.0).abs() < 1e-12);
        assert!((g[(1, 1)] - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn symmetric_and_psd() {
        use crate::linalg::sym_eig::jacobi_eigen;
        use crate::util::rng::Pcg32;

        let mut rng = Pcg32::seeded(11);
        let (n, d) = (200, 5);
        let x: Vec<f32> =
            (0..n * d).map(|_| rng.next_gaussian() as f32).collect();
        let g = gram_matrix(&x, n, d);
        assert!(g.is_symmetric(1e-12));
        let e = jacobi_eigen(&g);
        assert!(e.values.iter().all(|&l| l > -1e-10), "{:?}", e.values);
    }
}
