//! Small dense linear algebra (d ≈ 8): matrices, Gram matrices, a Jacobi
//! symmetric eigensolver and a Gauss–Jordan solver.
//!
//! Used to (i) synthesize datasets whose Gramian spectrum matches the
//! paper's constants `L = 1.908`, `c = 0.061` exactly, (ii) estimate
//! `(L, c)` from arbitrary data, and (iii) compute the exact ridge
//! solution `w*` needed for optimality-gap curves.

pub mod gram;
pub mod matrix;
pub mod solve;
pub mod sym_eig;

pub use gram::gram_matrix;
pub use matrix::Mat;
pub use solve::solve;
pub use sym_eig::jacobi_eigen;
