//! Small dense linear algebra (d ≈ 8): matrices, Gram matrices, a Jacobi
//! symmetric eigensolver, a Gauss–Jordan solver, and the vectorized
//! f32→f64 compute kernels behind the sweep hot path.
//!
//! Used to (i) synthesize datasets whose Gramian spectrum matches the
//! paper's constants `L = 1.908`, `c = 0.061` exactly, (ii) estimate
//! `(L, c)` from arbitrary data, (iii) compute the exact ridge
//! solution `w*` needed for optimality-gap curves, (iv) evaluate
//! dot products / axpy updates / batched losses with multi-accumulator
//! instruction-level parallelism ([`kernels`]), and (v) run the
//! lane-striped SoA kernels behind the batched-seed Monte-Carlo engine
//! ([`batch`]).

pub mod batch;
pub mod gram;
pub mod kernels;
pub mod matrix;
pub mod solve;
pub mod sym_eig;

pub use batch::{
    lane_axpy, lane_dot, lane_dot_seq, lane_logistic_step, lane_ridge_step,
    lane_update, snap_lanes, LANE_WIDTHS, MAX_LANES,
};
pub use gram::gram_matrix;
pub use kernels::{
    axpy_f32_f64, batch_logistic_loss, batch_ridge_loss, batch_sq_err,
    dot_f32_f64, sigmoid, softplus,
};
pub use matrix::Mat;
pub use solve::solve;
pub use sym_eig::jacobi_eigen;
