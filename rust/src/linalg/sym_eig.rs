//! Cyclic Jacobi eigensolver for small symmetric matrices.
//!
//! Exact enough for d = 8 Gramians (convergence is quadratic; we sweep
//! until the off-diagonal Frobenius mass is < 1e-14 × scale). Returns
//! eigenvalues ascending with matching eigenvectors as matrix columns.

use super::matrix::Mat;

/// Result of a symmetric eigendecomposition `A = V diag(λ) Vᵀ`.
#[derive(Clone, Debug)]
pub struct EigenSym {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Column `k` of this matrix is the eigenvector for `values[k]`.
    pub vectors: Mat,
}

/// Jacobi eigendecomposition of a symmetric matrix.
///
/// Panics if `a` is not square; symmetry is asserted to 1e-9 × scale.
pub fn jacobi_eigen(a: &Mat) -> EigenSym {
    let n = a.rows();
    assert_eq!(n, a.cols(), "jacobi_eigen requires a square matrix");
    let scale = a.frobenius().max(1e-300);
    assert!(
        a.is_symmetric(1e-9 * scale),
        "jacobi_eigen requires a symmetric matrix"
    );

    let mut m = a.clone();
    let mut v = Mat::eye(n);

    for _sweep in 0..100 {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-14 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // tan of the rotation angle, the numerically stable form
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // apply the rotation G(p,q,θ): m = Gᵀ m G, v = v G
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract and sort ascending, permuting eigenvector columns to match.
    let mut pairs: Vec<(f64, usize)> =
        (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|&(l, _)| l).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            vectors[(r, new_col)] = v[(r, old_col)];
        }
    }
    EigenSym { values, vectors }
}

/// Symmetric square root `A^(1/2)` of an SPD matrix via Jacobi.
pub fn spd_sqrt(a: &Mat) -> Mat {
    let eig = jacobi_eigen(a);
    assert!(
        eig.values.iter().all(|&l| l > -1e-12),
        "spd_sqrt requires PSD input, got eigenvalues {:?}",
        eig.values
    );
    let sqrt_d: Vec<f64> =
        eig.values.iter().map(|&l| l.max(0.0).sqrt()).collect();
    let v = &eig.vectors;
    v.matmul(&Mat::diag(&sqrt_d)).matmul(&v.transpose())
}

/// Symmetric inverse square root `A^(-1/2)` of an SPD matrix.
pub fn spd_inv_sqrt(a: &Mat) -> Mat {
    let eig = jacobi_eigen(a);
    assert!(
        eig.values.iter().all(|&l| l > 1e-12),
        "spd_inv_sqrt requires SPD input, got eigenvalues {:?}",
        eig.values
    );
    let inv_sqrt_d: Vec<f64> =
        eig.values.iter().map(|&l| 1.0 / l.sqrt()).collect();
    let v = &eig.vectors;
    v.matmul(&Mat::diag(&inv_sqrt_d)).matmul(&v.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix() {
        let a = Mat::diag(&[3.0, 1.0, 2.0]);
        let e = jacobi_eigen(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Mat::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let e = jacobi_eigen(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthonormality() {
        // Random-ish symmetric 5x5 built from a fixed seed pattern.
        let n = 5;
        let mut a = Mat::zeros(n, n);
        let mut val = 0.37;
        for i in 0..n {
            for j in i..n {
                val = (val * 97.0 + 13.0) % 7.0 - 3.0;
                a[(i, j)] = val;
                a[(j, i)] = val;
            }
        }
        let e = jacobi_eigen(&a);
        // V diag(λ) Vᵀ == A
        let recon = e
            .vectors
            .matmul(&Mat::diag(&e.values))
            .matmul(&e.vectors.transpose());
        assert!(recon.max_abs_diff(&a) < 1e-10, "reconstruction failed");
        // VᵀV == I
        let vtv = e.vectors.transpose().matmul(&e.vectors);
        assert!(vtv.max_abs_diff(&Mat::eye(n)) < 1e-10);
        // ascending order
        for w in e.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn sqrt_squares_back() {
        let a = Mat::from_rows(3, 3, &[4.0, 1.0, 0.0, 1.0, 3.0, 0.5, 0.0, 0.5, 2.0]);
        let r = spd_sqrt(&a);
        assert!(r.matmul(&r).max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn inv_sqrt_whitens() {
        let a = Mat::from_rows(2, 2, &[5.0, 2.0, 2.0, 3.0]);
        let w = spd_inv_sqrt(&a);
        let eye = w.matmul(&a).matmul(&w);
        assert!(eye.max_abs_diff(&Mat::eye(2)) < 1e-10);
    }

    #[test]
    fn trace_is_preserved() {
        let a = Mat::from_rows(3, 3, &[2.0, 1.0, 0.3, 1.0, 4.0, 0.7, 0.3, 0.7, 6.0]);
        let e = jacobi_eigen(&a);
        let trace = a[(0, 0)] + a[(1, 1)] + a[(2, 2)];
        let sum: f64 = e.values.iter().sum();
        assert!((trace - sum).abs() < 1e-10);
    }
}
