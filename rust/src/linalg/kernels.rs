//! Vectorized f32→f64 compute kernels for the sweep hot path.
//!
//! The covariate data is f32 (matching the AOT artifact layout) while
//! all accumulation is f64; these kernels widen on the fly and use
//! multiple independent accumulators so the compiler can keep several
//! fused multiply-adds in flight instead of serializing on one
//! dependency chain. They back:
//!
//! * [`RidgeModel`](crate::model::RidgeModel)'s general-`d` loss /
//!   gradient / SGD-step path (the `d == 8` paper workload keeps its
//!   fixed-size specialization),
//! * the batched store-wide loss evaluator
//!   ([`batch_ridge_loss`]) used by `Dataset::ridge_loss` — i.e. every
//!   final-loss evaluation in every sweep,
//! * `ridge_solution`'s Gram-matrix accumulation ([`axpy_f32_f64`]),
//! * the lane-striped batched-seed kernels in [`crate::linalg::batch`],
//!   which reuse [`dot_f32_f64`]'s accumulator association per lane.
//!
//! Equivalence with the scalar reference on odd dimensions and empty
//! inputs is unit-tested below (multi-accumulator summation reorders
//! floating-point adds, so comparisons are to ~1e-12 relative, not
//! bit-exact; `axpy` is element-wise and exact).
//!
//! **The pinned dot association** (relied on by the batched-seed engine
//! for bit-identical scalar↔lane parity): four independent accumulators
//! over chunks of 4, a sequential tail, combined as
//! `(a0 + a1) + (a2 + a3) + tail`. Any change here must update
//! `linalg/batch.rs` and the ULP note in ARCHITECTURE.md in lockstep.

/// `Σ_j w[j] · x[j]` with the f32 row widened to f64.
///
/// Four independent accumulators (an explicit fixed-width array, so the
/// compiler sees one vector register) over the unrolled body; the tail
/// is sequential. The association `(a0 + a1) + (a2 + a3) + tail` is the
/// pinned rule mirrored per-lane by `linalg/batch.rs` — identical to
/// the named-variable form this replaced, bit for bit. `w` and `x` must
/// have equal length.
#[inline]
pub fn dot_f32_f64(w: &[f64], x: &[f32]) -> f64 {
    debug_assert_eq!(w.len(), x.len(), "dot length mismatch");
    let n = w.len();
    let chunks = n / 4;
    let mut acc = [0.0f64; 4];
    for c in 0..chunks {
        let b = c * 4;
        let w4: &[f64; 4] = w[b..b + 4].try_into().unwrap();
        let x4: &[f32; 4] = x[b..b + 4].try_into().unwrap();
        for k in 0..4 {
            acc[k] += w4[k] * x4[k] as f64;
        }
    }
    let mut tail = 0.0f64;
    for j in chunks * 4..n {
        tail += w[j] * x[j] as f64;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// `y[j] += a · x[j]` with the f32 `x` widened to f64.
///
/// Element-wise (no reassociation): results are bit-identical to the
/// scalar loop regardless of the 8-wide chunking, which only exists so
/// the body is a fixed-size loop the autovectorizer unrolls whole.
/// `x` and `y` must have equal length.
#[inline]
pub fn axpy_f32_f64(a: f64, x: &[f32], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len(), "axpy length mismatch");
    let n = y.len();
    let chunks = n / 8;
    for c in 0..chunks {
        let b = c * 8;
        let y8: &mut [f64; 8] = (&mut y[b..b + 8]).try_into().unwrap();
        let x8: &[f32; 8] = x[b..b + 8].try_into().unwrap();
        for k in 0..8 {
            y8[k] += a * x8[k] as f64;
        }
    }
    for j in chunks * 8..n {
        y[j] += a * x[j] as f64;
    }
}

/// Sum of squared prediction errors `Σ_i (w·x_i − y_i)²` over a flat
/// row-major batch (`x.len() == y.len() · d`).
///
/// Rows are processed in groups into independent accumulators — the
/// batched store-wide evaluator behind every final-loss computation.
/// The `d == 8` paper workload takes a fixed-size inner path with
/// eight rows in flight (an 8×8 tile the compiler fully vectorizes);
/// general `d` keeps four rows of [`dot_f32_f64`] chains in flight.
pub fn batch_sq_err(x: &[f32], y: &[f32], d: usize, w: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len() * d, "batch shape mismatch");
    debug_assert_eq!(w.len(), d, "weight dimension mismatch");
    let n = y.len();
    if n == 0 {
        return 0.0;
    }
    if d == 8 {
        let w8 = <&[f64; 8]>::try_from(w).unwrap();
        let mut acc = [0.0f64; 8];
        let mut rows = x.chunks_exact(8);
        let octs = n / 8;
        for q in 0..octs {
            let base = q * 8;
            for k in 0..8 {
                let r8 =
                    <&[f32; 8]>::try_from(rows.next().unwrap()).unwrap();
                let mut dot = 0.0f64;
                for j in 0..8 {
                    dot += w8[j] * r8[j] as f64;
                }
                let e = dot - y[base + k] as f64;
                acc[k] += e * e;
            }
        }
        let mut tail = 0.0f64;
        for (row, &yi) in rows.by_ref().zip(&y[octs * 8..]) {
            let r8 = <&[f32; 8]>::try_from(row).unwrap();
            let mut dot = 0.0f64;
            for j in 0..8 {
                dot += w8[j] * r8[j] as f64;
            }
            let e = dot - yi as f64;
            tail += e * e;
        }
        return ((acc[0] + acc[1]) + (acc[2] + acc[3]))
            + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
            + tail;
    }
    let mut acc = [0.0f64; 4];
    let quads = n / 4;
    for q in 0..quads {
        let base = q * 4;
        for k in 0..4 {
            let i = base + k;
            let e = dot_f32_f64(w, &x[i * d..(i + 1) * d]) - y[i] as f64;
            acc[k] += e * e;
        }
    }
    let mut tail = 0.0f64;
    for i in quads * 4..n {
        let e = dot_f32_f64(w, &x[i * d..(i + 1) * d]) - y[i] as f64;
        tail += e * e;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// Empirical ridge loss over a flat batch:
/// `(1/n) Σ (w·x_i − y_i)² + reg · ‖w‖²` (empty batch: just the
/// regularizer term).
pub fn batch_ridge_loss(
    x: &[f32],
    y: &[f32],
    d: usize,
    w: &[f64],
    reg: f64,
) -> f64 {
    let w2: f64 = w.iter().map(|v| v * v).sum();
    if y.is_empty() {
        return reg * w2;
    }
    batch_sq_err(x, y, d, w) / y.len() as f64 + reg * w2
}

/// Numerically stable `ln(1 + e^z)` (softplus): never overflows for
/// large `z`, never underflows to a spurious 0 for moderate negatives.
#[inline]
pub fn softplus(z: f64) -> f64 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

/// Numerically stable logistic sigmoid `1/(1 + e^{−z})`.
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Empirical logistic loss over a flat batch with labels `y ∈ {0, 1}`:
/// `(1/n) Σ [softplus(w·x_i) − y_i·(w·x_i)] + reg · ‖w‖²` (empty
/// batch: just the regularizer term). Four-row unroll with independent
/// accumulators, mirroring [`batch_sq_err`].
pub fn batch_logistic_loss(
    x: &[f32],
    y: &[f32],
    d: usize,
    w: &[f64],
    reg: f64,
) -> f64 {
    debug_assert_eq!(x.len(), y.len() * d, "batch shape mismatch");
    debug_assert_eq!(w.len(), d, "weight dimension mismatch");
    let w2: f64 = w.iter().map(|v| v * v).sum();
    let n = y.len();
    if n == 0 {
        return reg * w2;
    }
    let mut acc = [0.0f64; 4];
    let quads = n / 4;
    for q in 0..quads {
        let base = q * 4;
        for k in 0..4 {
            let i = base + k;
            let z = dot_f32_f64(w, &x[i * d..(i + 1) * d]);
            acc[k] += softplus(z) - y[i] as f64 * z;
        }
    }
    let mut tail = 0.0f64;
    for i in quads * 4..n {
        let z = dot_f32_f64(w, &x[i * d..(i + 1) * d]);
        tail += softplus(z) - y[i] as f64 * z;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3]) + tail) / n as f64 + reg * w2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// The dimensions the kernels must agree with the scalar reference
    /// on: odd, sub-unroll, the paper's d = 8, and past one unroll.
    const DIMS: &[usize] = &[1, 3, 7, 8, 9, 33];

    fn scalar_dot(w: &[f64], x: &[f32]) -> f64 {
        let mut acc = 0.0;
        for j in 0..w.len() {
            acc += w[j] * x[j] as f64;
        }
        acc
    }

    fn random_case(d: usize, n: usize, seed: u64) -> (Vec<f64>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::seeded(seed);
        let w: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let x: Vec<f32> =
            (0..n * d).map(|_| rng.next_gaussian() as f32).collect();
        let y: Vec<f32> =
            (0..n).map(|_| rng.next_gaussian() as f32).collect();
        (w, x, y)
    }

    fn assert_close(a: f64, b: f64, what: &str) {
        let scale = a.abs().max(b.abs()).max(1.0);
        assert!(
            (a - b).abs() <= 1e-12 * scale,
            "{what}: {a} vs {b}"
        );
    }

    #[test]
    fn dot_matches_scalar_on_odd_dims() {
        for &d in DIMS {
            let (w, x, _) = random_case(d, 1, 7 + d as u64);
            assert_close(
                dot_f32_f64(&w, &x),
                scalar_dot(&w, &x),
                &format!("dot d={d}"),
            );
        }
    }

    #[test]
    fn dot_empty_is_zero() {
        assert_eq!(dot_f32_f64(&[], &[]), 0.0);
    }

    #[test]
    fn axpy_matches_scalar_exactly() {
        for &d in DIMS {
            let (w, x, _) = random_case(d, 1, 100 + d as u64);
            let mut y1 = w.clone();
            let mut y2 = w.clone();
            axpy_f32_f64(0.37, &x, &mut y1);
            for j in 0..d {
                y2[j] += 0.37 * x[j] as f64;
            }
            assert_eq!(y1, y2, "axpy must be element-wise exact (d={d})");
        }
    }

    #[test]
    fn axpy_empty_is_noop() {
        let mut y: Vec<f64> = vec![];
        axpy_f32_f64(2.0, &[], &mut y);
        assert!(y.is_empty());
    }

    #[test]
    fn batch_loss_matches_scalar_on_odd_dims_and_row_counts() {
        // row counts straddle the 4-row unroll; dims straddle the
        // 4-lane dot unroll and the d == 8 specialization
        for &d in DIMS {
            for n in [1usize, 2, 3, 4, 5, 7, 8, 17] {
                let (w, x, y) = random_case(d, n, 1000 + (d * n) as u64);
                let reg = 0.05 / n as f64;
                let got = batch_ridge_loss(&x, &y, d, &w, reg);
                // scalar reference (seed ridge_loss shape)
                let mut acc = 0.0;
                for i in 0..n {
                    let e =
                        scalar_dot(&w, &x[i * d..(i + 1) * d]) - y[i] as f64;
                    acc += e * e;
                }
                let w2: f64 = w.iter().map(|v| v * v).sum();
                let want = acc / n as f64 + reg * w2;
                assert_close(got, want, &format!("batch loss d={d} n={n}"));
            }
        }
    }

    #[test]
    fn batch_loss_empty_inputs() {
        let w = [0.5, -0.5, 1.0];
        assert_eq!(batch_sq_err(&[], &[], 3, &w), 0.0);
        let w2: f64 = w.iter().map(|v| v * v).sum();
        assert_eq!(batch_ridge_loss(&[], &[], 3, &w, 0.25), 0.25 * w2);
        assert_eq!(batch_logistic_loss(&[], &[], 3, &w, 0.25), 0.25 * w2);
    }

    #[test]
    fn logistic_loss_matches_scalar_on_odd_dims_and_row_counts() {
        for &d in DIMS {
            for n in [1usize, 2, 3, 4, 5, 7, 8, 17] {
                let (w, x, _) = random_case(d, n, 4400 + (d * n) as u64);
                // {0, 1} labels derived deterministically from the case
                let y: Vec<f32> =
                    (0..n).map(|i| (i % 2) as f32).collect();
                let reg = 0.05 / n as f64;
                let got = batch_logistic_loss(&x, &y, d, &w, reg);
                let mut acc = 0.0;
                for i in 0..n {
                    let z = scalar_dot(&w, &x[i * d..(i + 1) * d]);
                    acc += softplus(z) - y[i] as f64 * z;
                }
                let w2: f64 = w.iter().map(|v| v * v).sum();
                let want = acc / n as f64 + reg * w2;
                assert_close(got, want, &format!("logit loss d={d} n={n}"));
            }
        }
    }

    #[test]
    fn softplus_and_sigmoid_are_stable_at_extremes() {
        assert_eq!(softplus(-1000.0), 0.0);
        assert!((softplus(1000.0) - 1000.0).abs() < 1e-12);
        assert!((softplus(0.0) - std::f64::consts::LN_2).abs() < 1e-15);
        assert_eq!(sigmoid(-1000.0), 0.0);
        assert_eq!(sigmoid(1000.0), 1.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        // complementary identity on moderate values
        for z in [-3.0, -0.5, 0.0, 0.5, 3.0] {
            assert!((sigmoid(z) + sigmoid(-z) - 1.0).abs() < 1e-15);
        }
    }

    /// Compare two results that may be non-finite: both NaN, or exactly
    /// equal (covers ±Inf sign agreement).
    fn assert_same_class(a: f64, b: f64, what: &str) {
        if a.is_nan() || b.is_nan() {
            assert!(
                a.is_nan() && b.is_nan(),
                "{what}: NaN mismatch ({a} vs {b})"
            );
        } else {
            assert_eq!(a, b, "{what}");
        }
    }

    #[test]
    fn nan_and_inf_propagation_matches_the_scalar_reference() {
        // The multi-accumulator lanes reassociate additions; NaN and
        // single-signed Inf must still land in the same class as the
        // sequential scalar loop, in every lane position.
        for &d in DIMS {
            for poison in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                for pos in [0, d / 2, d - 1] {
                    let (w, mut x, _) = random_case(d, 1, 9000 + d as u64);
                    x[pos] = poison;
                    assert_same_class(
                        dot_f32_f64(&w, &x),
                        scalar_dot(&w, &x),
                        &format!("dot d={d} poison={poison} pos={pos}"),
                    );
                }
            }
            // mixed ±Inf products collapse to NaN in both orders
            if d >= 2 {
                let mut w = vec![1.0f64; d];
                w[d - 1] = -1.0;
                let mut x = vec![0.0f32; d];
                x[0] = f32::INFINITY;
                x[d - 1] = f32::INFINITY; // w·x = +inf + (−inf)
                assert_same_class(
                    dot_f32_f64(&w, &x),
                    scalar_dot(&w, &x),
                    &format!("dot mixed inf d={d}"),
                );
                assert!(dot_f32_f64(&w, &x).is_nan());
            }
        }
        // batched evaluators: one poisoned row must poison the total
        // exactly like the scalar accumulation does
        for n in [1usize, 4, 5, 9] {
            for poison in [f32::NAN, f32::INFINITY] {
                let d = 8; // exercises the specialized d == 8 path
                let (w, mut x, y) = random_case(d, n, 9500 + n as u64);
                x[(n - 1) * d + 3] = poison;
                let got = batch_sq_err(&x, &y, d, &w);
                let mut want = 0.0;
                for i in 0..n {
                    let e = scalar_dot(&w, &x[i * d..(i + 1) * d])
                        - y[i] as f64;
                    want += e * e;
                }
                assert_same_class(
                    got,
                    want,
                    &format!("batch_sq_err n={n} poison={poison}"),
                );
                assert!(!got.is_finite(), "poison must not vanish");
                let logit = batch_logistic_loss(&x, &y, d, &w, 0.01);
                assert!(
                    !logit.is_finite() || logit.is_nan(),
                    "logistic loss swallowed a poisoned row: {logit}"
                );
            }
        }
    }

    // The length checks are debug_assert!s (the hot path cannot afford
    // them in release); assert the guard fires where tests run (debug).
    #[cfg(debug_assertions)]
    mod length_mismatch {
        use super::*;

        #[test]
        #[should_panic(expected = "dot length mismatch")]
        fn dot_rejects_mismatched_lengths() {
            dot_f32_f64(&[1.0, 2.0], &[1.0f32]);
        }

        #[test]
        #[should_panic(expected = "axpy length mismatch")]
        fn axpy_rejects_mismatched_lengths() {
            let mut y = vec![0.0f64; 3];
            axpy_f32_f64(1.0, &[1.0f32, 2.0], &mut y);
        }

        #[test]
        #[should_panic(expected = "batch shape mismatch")]
        fn batch_sq_err_rejects_bad_shapes() {
            batch_sq_err(&[1.0f32; 5], &[1.0f32; 2], 2, &[0.0, 0.0]);
        }

        #[test]
        #[should_panic(expected = "weight dimension mismatch")]
        fn batch_sq_err_rejects_bad_weight_dim() {
            batch_sq_err(&[1.0f32; 4], &[1.0f32; 2], 2, &[0.0; 3]);
        }

        #[test]
        #[should_panic(expected = "batch shape mismatch")]
        fn batch_logistic_loss_rejects_bad_shapes() {
            batch_logistic_loss(&[1.0f32; 5], &[1.0f32; 2], 2, &[0.0; 2], 0.0);
        }
    }
}
