//! [`LaneModel`]: SoA weight state for the batched-seed engine.
//!
//! Wraps the scalar point models ([`RidgeModel`](super::RidgeModel),
//! [`LogisticModel`](super::LogisticModel)) with a lane-striped weight
//! vector (`w[j * width + l]`, see `linalg/batch.rs` for the layout and
//! the bit-exactness contract) and dispatches each fused SGD step to
//! the monomorphized lane kernel for the configured width. Model
//! constants follow the scalar constructors exactly: `reg = λ/N` over
//! the FULL training-set size, `reg2 = 2·reg`.

use crate::linalg::batch::{
    lane_logistic_step, lane_ridge_step, LANE_WIDTHS, MAX_LANES,
};

use super::Workload;

/// SoA weights for up to [`MAX_LANES`] seed-lanes of one scenario point.
#[derive(Clone, Debug)]
pub struct LaneModel {
    workload: Workload,
    d: usize,
    width: usize,
    reg2: f64,
    /// Lane-striped weights, `d * width` long, `w[j * width + l]`.
    w: Vec<f64>,
}

impl LaneModel {
    /// Build for feature dimension `d`, lane width `width` (one of
    /// [`LANE_WIDTHS`]), regularization `lambda`, and full dataset size
    /// `n_full` — the same `(λ, N)` convention as the scalar models.
    pub fn new(
        workload: Workload,
        d: usize,
        width: usize,
        lambda: f64,
        n_full: usize,
    ) -> LaneModel {
        let mut m = LaneModel {
            workload,
            d,
            width,
            reg2: 0.0,
            w: Vec::new(),
        };
        m.reset(workload, d, width, lambda, n_full);
        m
    }

    /// Re-initialize in place (weights zeroed, buffer reused) — the
    /// workspace-recycling entry point.
    pub fn reset(
        &mut self,
        workload: Workload,
        d: usize,
        width: usize,
        lambda: f64,
        n_full: usize,
    ) {
        assert!(
            LANE_WIDTHS.contains(&width),
            "unsupported lane width {width} (expected one of {LANE_WIDTHS:?})"
        );
        self.workload = workload;
        self.d = d;
        self.width = width;
        self.reg2 = 2.0 * lambda / n_full as f64;
        self.w.clear();
        self.w.resize(d * width, 0.0);
    }

    /// Lane width this model was monomorphized for.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Copy a scalar weight vector into lane `l`'s column.
    pub fn load_column(&mut self, l: usize, w: &[f64]) {
        debug_assert!(l < self.width, "lane out of range");
        debug_assert_eq!(w.len(), self.d, "weight dimension mismatch");
        for j in 0..self.d {
            self.w[j * self.width + l] = w[j];
        }
    }

    /// Copy lane `l`'s column out into a scalar weight vector.
    pub fn extract_column_into(&self, l: usize, out: &mut [f64]) {
        debug_assert!(l < self.width, "lane out of range");
        debug_assert_eq!(out.len(), self.d, "weight dimension mismatch");
        for j in 0..self.d {
            out[j] = self.w[j * self.width + l];
        }
    }

    /// One fused SGD step over all lanes. `x_soa` is the gathered
    /// lane-striped sample block (`d * width`, zero-filled in inactive
    /// columns), `y`/`active` are indexed by lane (entries past
    /// `width` are ignored). Active lanes take exactly the scalar
    /// model's update; inactive lanes keep their weights bit-for-bit.
    pub fn step(
        &mut self,
        x_soa: &[f32],
        y: &[f64; MAX_LANES],
        active: &[bool; MAX_LANES],
        alpha: f64,
    ) {
        debug_assert_eq!(x_soa.len(), self.d * self.width);
        match self.width {
            4 => self.step_w::<4>(x_soa, y, active, alpha),
            8 => self.step_w::<8>(x_soa, y, active, alpha),
            16 => self.step_w::<16>(x_soa, y, active, alpha),
            w => unreachable!("unsupported lane width {w}"),
        }
    }

    fn step_w<const L: usize>(
        &mut self,
        x_soa: &[f32],
        y: &[f64; MAX_LANES],
        active: &[bool; MAX_LANES],
        alpha: f64,
    ) {
        let y_l: &[f64; L] = y[..L].try_into().unwrap();
        let active_l: &[bool; L] = active[..L].try_into().unwrap();
        match self.workload {
            Workload::Ridge => lane_ridge_step::<L>(
                &mut self.w,
                x_soa,
                y_l,
                active_l,
                self.d,
                alpha,
                self.reg2,
            ),
            Workload::Logistic => lane_logistic_step::<L>(
                &mut self.w,
                x_soa,
                y_l,
                active_l,
                self.d,
                alpha,
                self.reg2,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LogisticModel, PointModel, RidgeModel};
    use crate::util::rng::Pcg32;

    #[test]
    fn columns_round_trip() {
        let mut m = LaneModel::new(Workload::Ridge, 5, 4, 0.05, 10);
        let col = [1.0, -2.0, 3.0, -4.0, 5.0];
        m.load_column(2, &col);
        let mut out = [0.0; 5];
        m.extract_column_into(2, &mut out);
        assert_eq!(out, col);
        // neighbors untouched
        m.extract_column_into(1, &mut out);
        assert_eq!(out, [0.0; 5]);
    }

    /// The wrapper must route through the same specialization the
    /// scalar model picks (ridge d == 8 sequential dot vs general
    /// 4-chunk dot), so trajectories stay bit-identical per lane.
    #[test]
    fn lane_trajectories_match_scalar_models_bitwise() {
        for (workload, d) in [
            (Workload::Ridge, 8),
            (Workload::Ridge, 7),
            (Workload::Logistic, 8),
            (Workload::Logistic, 5),
        ] {
            let (lambda, n_full, alpha, width) = (0.05, 50, 1e-2, 8usize);
            let mut lane = LaneModel::new(workload, d, width, lambda, n_full);
            let ridge = RidgeModel::new(d, lambda, n_full);
            let logit = LogisticModel::new(d, lambda, n_full);
            let mut rng = Pcg32::seeded(42 + d as u64);
            let mut scalar_w: Vec<Vec<f64>> = (0..width)
                .map(|_| (0..d).map(|_| rng.next_gaussian()).collect())
                .collect();
            for (l, col) in scalar_w.iter().enumerate() {
                lane.load_column(l, col);
            }
            let mut y = [0.0f64; MAX_LANES];
            let mut active = [false; MAX_LANES];
            active[..width].iter_mut().for_each(|a| *a = true);
            let mut x_soa = vec![0.0f32; d * width];
            for step in 0..6 {
                let mut rows: Vec<Vec<f32>> = Vec::new();
                for l in 0..width {
                    let row: Vec<f32> = (0..d)
                        .map(|_| rng.next_gaussian() as f32)
                        .collect();
                    let label = match workload {
                        Workload::Ridge => rng.next_gaussian() as f32,
                        Workload::Logistic => ((l + step) % 2) as f32,
                    };
                    y[l] = label as f64;
                    for j in 0..d {
                        x_soa[j * width + l] = row[j];
                    }
                    rows.push(row);
                }
                lane.step(&x_soa, &y, &active, alpha);
                for l in 0..width {
                    let yl = y[l] as f32;
                    match workload {
                        Workload::Ridge => ridge.sgd_step(
                            &mut scalar_w[l],
                            &rows[l],
                            yl,
                            alpha,
                        ),
                        Workload::Logistic => logit.sgd_step(
                            &mut scalar_w[l],
                            &rows[l],
                            yl,
                            alpha,
                        ),
                    }
                }
            }
            let mut col = vec![0.0f64; d];
            for l in 0..width {
                lane.extract_column_into(l, &mut col);
                for j in 0..d {
                    assert_eq!(
                        col[j].to_bits(),
                        scalar_w[l][j].to_bits(),
                        "{workload:?} d={d} lane {l} coord {j}"
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "unsupported lane width")]
    fn rejects_unsupported_widths() {
        LaneModel::new(Workload::Ridge, 4, 5, 0.05, 10);
    }
}
