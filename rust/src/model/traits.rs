//! The per-sample model abstraction the SGD engine is generic over.

/// A model trained by single-sample SGD on `(x, y)` pairs (paper eq. (2)).
///
/// Implementations must be cheap: `grad_into` is the innermost loop of the
/// whole system (tens of millions of calls per sweep).
pub trait PointModel: Sync {
    /// Parameter dimension.
    fn dim(&self) -> usize;

    /// Per-sample loss `ℓ(w, (x, y))`.
    fn loss(&self, w: &[f64], x: &[f32], y: f32) -> f64;

    /// Per-sample gradient written into `out` (length `dim()`).
    fn grad_into(&self, w: &[f64], x: &[f32], y: f32, out: &mut [f64]);

    /// One in-place SGD step `w ← w − α ∇ℓ(w, (x,y))`. A default is
    /// provided via `grad_into`; implementations may fuse it.
    fn sgd_step(&self, w: &mut [f64], x: &[f32], y: f32, alpha: f64) {
        let mut g = vec![0.0; self.dim()];
        self.grad_into(w, x, y, &mut g);
        for (wi, gi) in w.iter_mut().zip(&g) {
            *wi -= alpha * gi;
        }
    }
}
