//! Model layer: the ridge-regression workload of the paper plus the trait
//! the SGD engine and coordinator are generic over.

pub mod ridge;
pub mod traits;

pub use ridge::{ridge_solution, RidgeModel};
pub use traits::PointModel;
