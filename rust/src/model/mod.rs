//! Model layer: the ridge-regression workload of the paper, the logistic
//! classification workload, the trait the SGD engine and coordinator are
//! generic over, and the [`Workload`] selector the scenario layer uses to
//! pick between them.

pub mod lane;
pub mod logistic;
pub mod ridge;
pub mod traits;

pub use lane::LaneModel;
pub use logistic::LogisticModel;
pub use ridge::{ridge_solution, RidgeModel};
pub use traits::PointModel;

use anyhow::{bail, Result};

use crate::data::Dataset;

/// Which supervised learning task the edge node trains (the paper's
/// abstract covers "regression or classification"; its experiments fix
/// ridge). Selectable per scenario (`scenario.workload`, `--workloads`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Workload {
    /// Ridge regression on real-valued labels (the paper's experiments).
    #[default]
    Ridge,
    /// Logistic regression on `{0, 1}` labels.
    Logistic,
}

impl Workload {
    /// Parse `ridge` | `logistic` (alias `logit`).
    pub fn parse(s: &str) -> Result<Workload> {
        match s {
            "ridge" => Ok(Workload::Ridge),
            "logistic" | "logit" => Ok(Workload::Logistic),
            other => bail!(
                "unknown workload '{other}' (expected ridge | logistic)"
            ),
        }
    }

    /// Compact display/config form (round-trips through [`parse`](Self::parse)).
    pub fn label(self) -> &'static str {
        match self {
            Workload::Ridge => "ridge",
            Workload::Logistic => "logistic",
        }
    }

    /// Full-dataset empirical risk of `w` under this workload's
    /// per-sample loss (`reg` = λ/N). This is the quantity every loss
    /// curve and final-loss sweep reports.
    pub fn full_loss(self, ds: &Dataset, w: &[f64], reg: f64) -> f64 {
        match self {
            Workload::Ridge => ds.ridge_loss(w, reg),
            Workload::Logistic => crate::linalg::kernels::batch_logistic_loss(
                &ds.x, &ds.y, ds.d, w, reg,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_labels_round_trip() {
        for w in [Workload::Ridge, Workload::Logistic] {
            assert_eq!(Workload::parse(w.label()).unwrap(), w);
        }
        assert_eq!(Workload::parse("logit").unwrap(), Workload::Logistic);
        assert!(Workload::parse("svm").is_err());
    }

    #[test]
    fn full_loss_dispatches_per_workload() {
        let ds = Dataset::new(
            vec![1.0, 0.0, 0.0, 1.0],
            vec![1.0, 0.0],
            2,
            2,
        );
        let w = [0.0, 0.0];
        let ridge = Workload::Ridge.full_loss(&ds, &w, 0.0);
        // errors 1, 0 -> mean 0.5
        assert!((ridge - 0.5).abs() < 1e-12);
        let logit = Workload::Logistic.full_loss(&ds, &w, 0.0);
        // zero margins -> ln 2 per sample
        assert!((logit - std::f64::consts::LN_2).abs() < 1e-12);
    }
}
