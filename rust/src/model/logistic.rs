//! Logistic-regression classification workload.
//!
//! The paper's abstract targets "a supervised learning task, e.g.
//! regression or classification"; its experiments only exercise ridge
//! regression. This module supplies the classification half with the
//! same conventions as [`RidgeModel`](super::RidgeModel):
//!
//! Loss per sample (labels `y ∈ {0, 1}`, margin `z = wᵀx`):
//! `ℓ(w, x) = softplus(z) − y·z + (λ/N)‖w‖²`
//! Gradient: `∇ℓ = x (σ(z) − y) + (2λ/N) w`
//!
//! `N` is the FULL training-set size, matching the ridge `λ/N`
//! convention, so per-sample losses average exactly to the empirical
//! risk. With the L2 term the loss is `2λ/N`-strongly convex, which is
//! what the bound layer's (conservative) logistic constants use.

use crate::linalg::kernels::{dot_f32_f64, sigmoid, softplus};

use super::traits::PointModel;

/// Logistic-regression point model.
#[derive(Clone, Debug)]
pub struct LogisticModel {
    d: usize,
    /// λ/N — the per-sample regularizer coefficient.
    pub reg: f64,
    /// 2λ/N — the gradient's regularizer coefficient.
    pub reg2: f64,
}

impl LogisticModel {
    /// Build for feature dimension `d`, regularization `lambda`, and
    /// full dataset size `n_full` (mirrors `RidgeModel::new`).
    pub fn new(d: usize, lambda: f64, n_full: usize) -> LogisticModel {
        let reg = lambda / n_full as f64;
        LogisticModel { d, reg, reg2: 2.0 * reg }
    }

    /// Fused SGD step (saves the temp gradient buffer, mirroring the
    /// ridge hot path): `w ← w(1 − α·2λ/N) − α(σ(wᵀx) − y)·x`.
    #[inline]
    pub fn sgd_step_fused(
        &self,
        w: &mut [f64],
        x: &[f32],
        y: f32,
        alpha: f64,
    ) {
        debug_assert_eq!(w.len(), x.len());
        let z = dot_f32_f64(w, x);
        let alpha_err = alpha * (sigmoid(z) - y as f64);
        let shrink = 1.0 - alpha * self.reg2;
        for j in 0..w.len() {
            w[j] = w[j] * shrink - alpha_err * x[j] as f64;
        }
    }
}

impl PointModel for LogisticModel {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss(&self, w: &[f64], x: &[f32], y: f32) -> f64 {
        let z = dot_f32_f64(w, x);
        let w2: f64 = w.iter().map(|v| v * v).sum();
        softplus(z) - y as f64 * z + self.reg * w2
    }

    fn grad_into(&self, w: &[f64], x: &[f32], y: f32, out: &mut [f64]) {
        let err = sigmoid(dot_f32_f64(w, x)) - y as f64;
        for j in 0..self.d {
            out[j] = self.reg2 * w[j] + err * x[j] as f64;
        }
    }

    fn sgd_step(&self, w: &mut [f64], x: &[f32], y: f32, alpha: f64) {
        self.sgd_step_fused(w, x, y, alpha);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> LogisticModel {
        LogisticModel::new(3, 0.05, 100)
    }

    #[test]
    fn loss_at_zero_margin_is_ln2() {
        let m = model();
        let w = [0.0, 0.0, 0.0];
        let x = [1.0f32, -2.0, 0.5];
        for y in [0.0f32, 1.0] {
            let got = m.loss(&w, &x, y);
            assert!(
                (got - std::f64::consts::LN_2).abs() < 1e-12,
                "y={y}: {got}"
            );
        }
    }

    #[test]
    fn loss_is_stable_at_extreme_margins() {
        let m = LogisticModel::new(1, 0.0, 1);
        // huge positive margin, label 1: loss ~ 0, never NaN/inf
        let l1 = m.loss(&[500.0], &[2.0], 1.0);
        assert!(l1.is_finite() && l1 < 1e-12, "l1={l1}");
        // huge positive margin, label 0: loss ~ z, linear not inf
        let l0 = m.loss(&[500.0], &[2.0], 0.0);
        assert!((l0 - 1000.0).abs() < 1e-9, "l0={l0}");
        // huge negative margin, label 0: ~ 0
        let l2 = m.loss(&[-500.0], &[2.0], 0.0);
        assert!(l2.is_finite() && l2 < 1e-12, "l2={l2}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = model();
        let w = [0.3, -0.7, 1.1];
        let x = [1.0f32, 0.5, -2.0];
        for y in [0.0f32, 1.0] {
            let mut g = [0.0; 3];
            m.grad_into(&w, &x, y, &mut g);
            let eps = 1e-6;
            for j in 0..3 {
                let mut wp = w;
                wp[j] += eps;
                let mut wm = w;
                wm[j] -= eps;
                let fd = (m.loss(&wp, &x, y) - m.loss(&wm, &x, y))
                    / (2.0 * eps);
                assert!(
                    (g[j] - fd).abs() < 1e-6,
                    "y={y} coord {j}: {} vs {fd}",
                    g[j]
                );
            }
        }
    }

    #[test]
    fn fused_step_equals_generic_step() {
        let m = model();
        let x = [1.0f32, -0.5, 0.25];
        let y = 1.0f32;
        let mut w1 = vec![0.2, 0.4, -0.6];
        let mut w2 = w1.clone();
        m.sgd_step_fused(&mut w1, &x, y, 1e-2);
        let mut g = vec![0.0; 3];
        m.grad_into(&w2.clone(), &x, y, &mut g);
        for j in 0..3 {
            w2[j] -= 1e-2 * g[j];
        }
        for j in 0..3 {
            assert!((w1[j] - w2[j]).abs() < 1e-14);
        }
    }

    #[test]
    fn sgd_separates_linearly_separable_points() {
        // two points on either side of the origin, labels by sign
        let m = LogisticModel::new(2, 0.0, 2);
        let mut w = vec![0.0, 0.0];
        for _ in 0..2000 {
            m.sgd_step(&mut w, &[1.0, 0.5], 1.0, 0.1);
            m.sgd_step(&mut w, &[-1.0, -0.5], 0.0, 0.1);
        }
        let z_pos = w[0] * 1.0 + w[1] * 0.5;
        assert!(z_pos > 1.0, "positive point must end deep on + side: {z_pos}");
    }
}
