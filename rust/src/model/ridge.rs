//! The paper's workload: ridge regression (Sec. 5).
//!
//! Loss per sample:  `ℓ(w, x) = (wᵀx − y)² + (λ/N) ‖w‖²`
//! Gradient:         `∇ℓ = 2 x (wᵀx − y) + (2λ/N) w`
//!
//! `N` is the FULL training-set size: the regularizer coefficient is fixed
//! at dataset scale, matching the paper's `λ/N` convention, so per-sample
//! losses average exactly to the empirical risk (1).

use anyhow::Result;

use crate::data::Dataset;
use crate::linalg::kernels::{axpy_f32_f64, dot_f32_f64};
use crate::linalg::{solve, Mat};

use super::traits::PointModel;

/// Ridge-regression point model.
#[derive(Clone, Debug)]
pub struct RidgeModel {
    d: usize,
    /// λ/N — the per-sample regularizer coefficient.
    pub reg: f64,
    /// 2λ/N — the gradient's regularizer coefficient.
    pub reg2: f64,
}

impl RidgeModel {
    /// Build for feature dimension `d`, regularization `lambda`, and full
    /// dataset size `n_full` (paper: λ = 0.05, N = 18 576).
    pub fn new(d: usize, lambda: f64, n_full: usize) -> RidgeModel {
        let reg = lambda / n_full as f64;
        RidgeModel { d, reg, reg2: 2.0 * reg }
    }

    /// Fused SGD step specialized for ridge (saves the temp gradient
    /// buffer; this is the native engine's hot path). The `d == 8` case
    /// (the paper's workload) takes a fixed-size-array path the compiler
    /// fully vectorizes.
    #[inline]
    pub fn sgd_step_fused(
        &self,
        w: &mut [f64],
        x: &[f32],
        y: f32,
        alpha: f64,
    ) {
        debug_assert_eq!(w.len(), x.len());
        if let (Ok(w8), Ok(x8)) = (
            <&mut [f64; 8]>::try_from(&mut *w),
            <&[f32; 8]>::try_from(x),
        ) {
            let mut xf = [0.0f64; 8];
            let mut dot = 0.0;
            for j in 0..8 {
                xf[j] = x8[j] as f64;
                dot += w8[j] * xf[j];
            }
            let two_alpha_err = 2.0 * alpha * (dot - y as f64);
            let shrink = 1.0 - alpha * self.reg2;
            for j in 0..8 {
                w8[j] = w8[j] * shrink - two_alpha_err * xf[j];
            }
            return;
        }
        // general-d path: multi-accumulator dot, then a fused
        // shrink-and-step sweep
        let dot = dot_f32_f64(w, x);
        let two_alpha_err = 2.0 * alpha * (dot - y as f64);
        let shrink = 1.0 - alpha * self.reg2;
        for j in 0..w.len() {
            w[j] = w[j] * shrink - two_alpha_err * x[j] as f64;
        }
    }
}

impl PointModel for RidgeModel {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss(&self, w: &[f64], x: &[f32], y: f32) -> f64 {
        let e = dot_f32_f64(w, x) - y as f64;
        let w2: f64 = w.iter().map(|v| v * v).sum();
        e * e + self.reg * w2
    }

    fn grad_into(&self, w: &[f64], x: &[f32], y: f32, out: &mut [f64]) {
        let e2 = 2.0 * (dot_f32_f64(w, x) - y as f64);
        for j in 0..self.d {
            out[j] = self.reg2 * w[j];
        }
        axpy_f32_f64(e2, x, out);
    }

    fn sgd_step(&self, w: &mut [f64], x: &[f32], y: f32, alpha: f64) {
        self.sgd_step_fused(w, x, y, alpha);
    }
}

/// Exact ridge minimizer `w* = argmin (1/N)Σ(wᵀx−y)² + (λ/N)‖w‖²`, i.e.
/// the solution of the normal equations `(XᵀX + λ I) w = Xᵀ y`.
pub fn ridge_solution(ds: &Dataset, lambda: f64) -> Result<Vec<f64>> {
    let d = ds.d;
    let mut xtx = Mat::zeros(d, d);
    let mut xty = vec![0.0; d];
    for i in 0..ds.n {
        let row = ds.row(i);
        let y = ds.y[i] as f64;
        for a in 0..d {
            let xa = row[a] as f64;
            xty[a] += xa * y;
            // upper triangle of the Gram row as one axpy kernel call
            axpy_f32_f64(xa, &row[a..], &mut xtx.row_mut(a)[a..]);
        }
    }
    for a in 0..d {
        for b in a..d {
            let v = xtx[(a, b)];
            xtx[(b, a)] = v;
        }
        xtx[(a, a)] += lambda;
    }
    solve(&xtx, &xty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synth_calhousing, SynthSpec};

    fn model() -> RidgeModel {
        RidgeModel::new(3, 0.05, 100)
    }

    #[test]
    fn loss_formula() {
        let m = model();
        let w = [1.0, 2.0, -1.0];
        let x = [0.5f32, 1.0, 2.0];
        // pred = 0.5 + 2 - 2 = 0.5; err vs y=1 -> 0.25
        let want = 0.25 + (0.05 / 100.0) * 6.0;
        assert!((m.loss(&w, &x, 1.0) - want).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = model();
        let w = [0.3, -0.7, 1.1];
        let x = [1.0f32, 0.5, -2.0];
        let y = 0.8f32;
        let mut g = [0.0; 3];
        m.grad_into(&w, &x, y, &mut g);
        let eps = 1e-6;
        for j in 0..3 {
            let mut wp = w;
            wp[j] += eps;
            let mut wm = w;
            wm[j] -= eps;
            let fd = (m.loss(&wp, &x, y) - m.loss(&wm, &x, y)) / (2.0 * eps);
            assert!((g[j] - fd).abs() < 1e-6, "coord {j}: {} vs {fd}", g[j]);
        }
    }

    #[test]
    fn fused_step_equals_generic_step() {
        let m = model();
        let x = [1.0f32, -0.5, 0.25];
        let y = 0.3f32;
        let mut w1 = vec![0.2, 0.4, -0.6];
        let mut w2 = w1.clone();
        m.sgd_step_fused(&mut w1, &x, y, 1e-2);
        // generic path via grad_into
        let mut g = vec![0.0; 3];
        m.grad_into(&w2.clone(), &x, y, &mut g);
        for j in 0..3 {
            w2[j] -= 1e-2 * g[j];
        }
        for j in 0..3 {
            assert!((w1[j] - w2[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn exact_solution_has_zero_gradient() {
        let ds = synth_calhousing(&SynthSpec { n: 2000, ..Default::default() });
        let lambda = 0.05;
        let w = ridge_solution(&ds, lambda).unwrap();
        let m = RidgeModel::new(ds.d, lambda, ds.n);
        // full empirical gradient at w* must vanish
        let mut g_total = vec![0.0; ds.d];
        let mut g = vec![0.0; ds.d];
        for i in 0..ds.n {
            m.grad_into(&w, ds.row(i), ds.y[i], &mut g);
            for j in 0..ds.d {
                g_total[j] += g[j];
            }
        }
        for j in 0..ds.d {
            assert!(
                (g_total[j] / ds.n as f64).abs() < 1e-9,
                "grad[{j}] = {}",
                g_total[j] / ds.n as f64
            );
        }
    }

    #[test]
    fn solution_recovers_ground_truth_at_low_noise() {
        let spec = SynthSpec { n: 5000, noise_std: 0.01, ..Default::default() };
        let ds = synth_calhousing(&spec);
        let w = ridge_solution(&ds, 1e-6).unwrap();
        let truth = crate::data::synth::ground_truth_w(ds.d);
        for j in 0..ds.d {
            assert!((w[j] - truth[j]).abs() < 0.05, "{} vs {}", w[j], truth[j]);
        }
    }
}
