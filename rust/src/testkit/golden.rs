//! Golden-trace snapshots: deterministic event-stream fixtures.
//!
//! A protocol run's [`Event`] stream is a complete, loss-value-free
//! record of what the scheduler did — when each block was sent, how
//! many ARQ attempts it took, how many SGD updates ran in each compute
//! window. Snapshotting it pins the *semantics* of every scenario axis:
//! any change to RNG stream consumption, channel timing, policy sizing
//! or trainer clocking shows up as a one-line diff.
//!
//! Format (`rust/tests/golden/<name>.trace`): a header line, then one
//! event per line as `<f64 bits of t as hex> t=<t:?> <kind:?>`. Times
//! are serialized through their exact bit pattern, so comparison is
//! bit-exact and platform-independent; the human-readable forms are for
//! diff readability only.
//!
//! Modes of [`assert_golden_trace`]:
//!
//! * fixture exists → compare, panic on the first diverging line;
//! * `EDGEPIPE_REGEN_GOLDEN=1` → rewrite the fixture and pass (use
//!   after an *intentional* semantic change, then commit the diff);
//! * fixture missing → write it and pass ("bootstrap": the first
//!   toolchain-bearing run materializes the fixtures; CI fails if the
//!   working tree is dirty under `rust/tests/golden/` afterwards, so a
//!   fixture can never silently regenerate on CI).

use std::fmt::Write as _;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::coordinator::events::Event;

/// Serializes fixture reads/writes: tests in one binary run on parallel
/// threads, and two tests may assert against the SAME fixture (the
/// fading ≡ erasure equivalence does); without the lock a bootstrap
/// write could race a concurrent read into a spurious mismatch.
static GOLDEN_LOCK: Mutex<()> = Mutex::new(());

/// Directory holding the committed fixtures.
pub fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("golden")
}

fn fixture_path(name: &str) -> PathBuf {
    assert!(
        name.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
        "fixture names are [A-Za-z0-9_-]: '{name}'"
    );
    fixture_dir().join(format!("{name}.trace"))
}

/// Serialize an event stream into the canonical golden-trace text.
pub fn render_trace(label: &str, events: &[Event]) -> String {
    let mut out = String::new();
    writeln!(out, "# edgepipe golden trace v1 · {label}").unwrap();
    writeln!(out, "# events: {}", events.len()).unwrap();
    for e in events {
        writeln!(out, "{:016x} t={:?} {:?}", e.t.to_bits(), e.t, e.kind)
            .unwrap();
    }
    out
}

/// Compare `rendered` against the committed fixture `name` (see the
/// module docs for the regen/bootstrap modes).
pub fn assert_golden_trace(name: &str, rendered: &str) {
    let _guard = GOLDEN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let path = fixture_path(name);
    let regen = std::env::var("EDGEPIPE_REGEN_GOLDEN")
        .map(|v| v == "1")
        .unwrap_or(false);
    if regen || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap())
            .unwrap_or_else(|e| panic!("mkdir {}: {e}", path.display()));
        std::fs::write(&path, rendered)
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
        eprintln!(
            "golden: {} fixture {}",
            if regen { "regenerated" } else { "bootstrapped" },
            path.display()
        );
        return;
    }
    let expected = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    if expected == rendered {
        return;
    }
    // locate the first diverging line for an actionable failure
    let mut line_no = 0usize;
    let mut want_line = "<missing>";
    let mut got_line = "<missing>";
    for (i, pair) in expected
        .lines()
        .map(Some)
        .chain(std::iter::repeat(None))
        .zip(rendered.lines().map(Some).chain(std::iter::repeat(None)))
        .enumerate()
    {
        match pair {
            (None, None) => break,
            (w, g) if w != g => {
                line_no = i + 1;
                want_line = w.unwrap_or("<missing>");
                got_line = g.unwrap_or("<missing>");
                break;
            }
            _ => {}
        }
    }
    panic!(
        "golden trace '{name}' diverged from {} at line {line_no}:\n  \
         fixture: {want_line}\n  actual : {got_line}\n\
         If this change is intentional, rerun with \
         EDGEPIPE_REGEN_GOLDEN=1 and commit the fixture diff.",
        path.display()
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::events::EventKind;

    fn sample_events() -> Vec<Event> {
        vec![
            Event {
                t: 0.0,
                kind: EventKind::BlockSent {
                    block: 1,
                    payload: 8,
                    device: 0,
                },
            },
            Event {
                t: 18.0,
                kind: EventKind::BlockDelivered {
                    block: 1,
                    payload: 8,
                    attempts: 2,
                },
            },
            Event { t: 18.0, kind: EventKind::UpdatesRun { count: 18 } },
            Event {
                t: 40.0,
                kind: EventKind::Finished { updates: 40, delivered_samples: 8 },
            },
        ]
    }

    #[test]
    fn render_is_deterministic_and_bit_exact() {
        let a = render_trace("unit", &sample_events());
        let b = render_trace("unit", &sample_events());
        assert_eq!(a, b);
        // the hex field is the exact f64 bit pattern
        assert!(a.contains(&format!("{:016x}", 18.0f64.to_bits())));
        assert_eq!(a.lines().count(), 2 + 4, "header + one line per event");
    }

    #[test]
    fn distinct_times_render_distinct_lines() {
        let mut evs = sample_events();
        let a = render_trace("unit", &evs);
        // perturb one time by 1 ulp — must change the rendering
        evs[1].t = f64::from_bits(evs[1].t.to_bits() + 1);
        let b = render_trace("unit", &evs);
        assert_ne!(a, b, "1-ulp time changes must be visible");
    }

    #[test]
    #[should_panic]
    fn bad_fixture_names_are_rejected() {
        assert_golden_trace("../escape", "x");
    }
}
