//! Property-testing mini-framework (the offline image has no proptest)
//! plus the golden-trace snapshot harness ([`golden`]).
//!
//! Provides seeded random generators and a `forall` runner that reports
//! the failing case's seed and a shrunk reproduction hint. Used by the
//! coordinator/protocol/bound property tests in `rust/tests/`.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the libxla_extension rpath)
//! use edgepipe::testkit::{forall, Gen};
//! forall("addition commutes", 200, |g| {
//!     let (a, b) = (g.u64_in(0..=1_000), g.u64_in(0..=1_000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

pub mod golden;

pub use golden::{assert_golden_trace, render_trace};

use crate::util::rng::Pcg32;

/// A seeded case generator handed to each property invocation.
pub struct Gen {
    rng: Pcg32,
    /// The case seed (printed on failure for reproduction).
    pub seed: u64,
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen { rng: Pcg32::new(seed, 777), seed, log: Vec::new() }
    }

    /// Record a generated value so failures print the full case.
    fn note(&mut self, name: &str, value: impl std::fmt::Display) {
        self.log.push(format!("{name}={value}"));
    }

    /// Uniform u64 in an inclusive range.
    pub fn u64_in(&mut self, range: std::ops::RangeInclusive<u64>) -> u64 {
        let (lo, hi) = (*range.start(), *range.end());
        let v = lo + self.rng.gen_range(hi - lo + 1);
        self.note("u64", v);
        v
    }

    /// Uniform usize in an inclusive range.
    pub fn usize_in(
        &mut self,
        range: std::ops::RangeInclusive<usize>,
    ) -> usize {
        self.u64_in(*range.start() as u64..=*range.end() as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + (hi - lo) * self.rng.next_f64();
        self.note("f64", v);
        v
    }

    /// Log-uniform f64 in [lo, hi) (both positive).
    pub fn f64_log(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        let v = (self.rng.next_f64() * (hi.ln() - lo.ln()) + lo.ln()).exp();
        self.note("f64log", v);
        v
    }

    /// Bernoulli(p).
    pub fn bool_with(&mut self, p: f64) -> bool {
        let v = self.rng.next_f64() < p;
        self.note("bool", v);
        v
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        let i = self.rng.gen_range(items.len() as u64) as usize;
        &items[i]
    }

    /// A fresh RNG derived from this case (for seeding subsystems).
    pub fn rng(&mut self) -> Pcg32 {
        let s = self.rng.next_u64();
        Pcg32::seeded(s)
    }
}

/// Run `cases` random cases of a property. Panics (failing the enclosing
/// test) on the first case whose closure panics, reporting the case seed
/// and every generated value.
pub fn forall<F: Fn(&mut Gen)>(name: &str, cases: u64, property: F) {
    // honor EDGEPIPE_PT_SEED to replay one failing case
    if let Ok(seed) = std::env::var("EDGEPIPE_PT_SEED") {
        let seed: u64 = seed.parse().expect("bad EDGEPIPE_PT_SEED");
        let mut g = Gen::new(seed);
        property(&mut g);
        return;
    }
    let base = fxhash(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut g = Gen::new(seed);
                property(&mut g);
                g
            }));
        if let Err(err) = result {
            // regenerate to recover the value log
            let mut g = Gen::new(seed);
            let _ = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| property(&mut g)),
            );
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    err.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} \
                 (EDGEPIPE_PT_SEED={seed}):\n  values: [{}]\n  panic: {msg}",
                g.log.join(", ")
            );
        }
    }
}

/// Deterministic 64-bit hash of the property name (FNV-1a).
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum symmetric", 50, |g| {
            let a = g.u64_in(0..=100);
            let b = g.u64_in(0..=100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            forall("always fails above 90", 200, |g| {
                let v = g.u64_in(0..=100);
                assert!(v <= 90, "got {v}");
            });
        });
        let err = result.expect_err("property must fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("EDGEPIPE_PT_SEED="), "msg: {msg}");
        assert!(msg.contains("values:"), "msg: {msg}");
    }

    #[test]
    fn generators_hit_ranges() {
        forall("ranges respected", 100, |g| {
            let u = g.usize_in(3..=7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let l = g.f64_log(0.1, 10.0);
            assert!((0.1..10.0).contains(&l));
            let c = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&c));
        });
    }

    #[test]
    fn deterministic_given_name() {
        use std::cell::RefCell;
        let a = RefCell::new(Vec::new());
        forall("det", 10, |g| a.borrow_mut().push(g.u64_in(0..=1000)));
        let b = RefCell::new(Vec::new());
        forall("det", 10, |g| b.borrow_mut().push(g.u64_in(0..=1000)));
        assert_eq!(a.into_inner(), b.into_inner());
    }
}
