//! Experiment output: loss curves, CSV/JSON emission, run summaries.

pub mod curve;
pub mod summary;
pub mod writer;

pub use curve::{align_curves, mean_curve};
pub use summary::{render_run, run_to_json};
pub use writer::{write_csv, write_json, CsvTable};
