//! Run summaries: human-readable reports and JSON export of a
//! [`RunResult`](crate::coordinator::RunResult) for downstream tooling.

use crate::coordinator::RunResult;
use crate::util::json::{num, obj, s, Value};

/// Render a one-paragraph human report of a run.
pub fn render_run(result: &RunResult, loss_star: Option<f64>) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "final loss {:.6}; {} updates over {} sent blocks \
         ({} delivered, {} samples, case {:?}, backend {})\n",
        result.final_loss,
        result.updates,
        result.blocks_sent,
        result.blocks_delivered,
        result.samples_delivered,
        result.case,
        result.backend
    ));
    if result.retransmissions > 0 {
        out.push_str(&format!(
            "channel retransmissions: {}\n",
            result.retransmissions
        ));
    }
    if result.timeouts > 0 || result.samples_lost > 0 {
        out.push_str(&format!(
            "faults: {} timeouts, {} blocks abandoned, {} evictions, \
             {} samples shed{}\n",
            result.timeouts,
            result.blocks_abandoned,
            result.evictions,
            result.samples_lost,
            if result.degraded_completion {
                " (degraded completion)"
            } else {
                ""
            }
        ));
    }
    if let Some(star) = loss_star {
        out.push_str(&format!(
            "optimality gap: {:.3e} (L(w*) = {star:.6})\n",
            result.final_loss - star
        ));
    }
    out
}

/// Export a run to a JSON value (curve + scalars).
pub fn run_to_json(result: &RunResult, loss_star: Option<f64>) -> Value {
    let curve = Value::Arr(
        result
            .curve
            .iter()
            .map(|&(t, l)| Value::Arr(vec![num(t), num(l)]))
            .collect(),
    );
    let mut fields = vec![
        ("final_loss", num(result.final_loss)),
        ("updates", num(result.updates as f64)),
        ("blocks_sent", num(result.blocks_sent as f64)),
        ("blocks_delivered", num(result.blocks_delivered as f64)),
        ("samples_delivered", num(result.samples_delivered as f64)),
        ("blocks_missed", num(result.blocks_missed as f64)),
        ("deadline_outage", num(result.deadline_outage() as u8 as f64)),
        ("retransmissions", num(result.retransmissions as f64)),
        ("timeouts", num(result.timeouts as f64)),
        ("blocks_abandoned", num(result.blocks_abandoned as f64)),
        ("evictions", num(result.evictions as f64)),
        ("samples_lost", num(result.samples_lost as f64)),
        (
            "degraded_completion",
            num(result.degraded_completion as u8 as f64),
        ),
        ("case", s(&format!("{:?}", result.case))),
        ("backend", s(result.backend)),
        ("final_w", crate::util::json::num_arr(&result.final_w)),
        ("curve", curve),
    ];
    if let Some(star) = loss_star {
        fields.push(("loss_star", num(star)));
        fields.push(("gap", num(result.final_loss - star)));
    }
    obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::TimelineCase;

    fn fake_run() -> RunResult {
        RunResult {
            curve: vec![(0.0, 2.0), (10.0, 1.0)],
            final_loss: 1.0,
            final_w: vec![0.5, -0.5],
            updates: 100,
            blocks_sent: 5,
            blocks_delivered: 4,
            samples_delivered: 400,
            blocks_missed: 1,
            retransmissions: 2,
            timeouts: 3,
            blocks_abandoned: 1,
            evictions: 1,
            samples_lost: 100,
            degraded_completion: false,
            case: TimelineCase::Partial,
            snapshots: vec![],
            events: vec![],
            backend: "native",
        }
    }

    #[test]
    fn render_contains_key_facts() {
        let r = render_run(&fake_run(), Some(0.4));
        assert!(r.contains("final loss 1.000000"));
        assert!(r.contains("retransmissions: 2"));
        assert!(r.contains("faults: 3 timeouts, 1 blocks abandoned"));
        assert!(r.contains("optimality gap"));
    }

    #[test]
    fn json_roundtrips() {
        let v = run_to_json(&fake_run(), Some(0.4));
        let text = v.to_json_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            back.get("final_loss").unwrap().as_f64().unwrap(),
            1.0
        );
        assert_eq!(
            back.get("curve").unwrap().as_arr().unwrap().len(),
            2
        );
        assert_eq!(back.get("gap").unwrap().as_f64().unwrap(), 0.6);
    }
}
