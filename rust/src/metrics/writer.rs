//! CSV / JSON experiment-output writers (figure data, bench rows).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Value;

/// A simple column-oriented CSV table.
#[derive(Clone, Debug, Default)]
pub struct CsvTable {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(headers: &[&str]) -> CsvTable {
        CsvTable {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of already-formatted cells.
    pub fn push_raw(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Append a row of f64 cells.
    pub fn push_nums(&mut self, cells: &[f64]) {
        self.push_raw(cells.iter().map(|v| format!("{v}")).collect());
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to CSV text.
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Write a CSV table to disk, creating parent dirs.
pub fn write_csv(table: &CsvTable, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(table.to_csv().as_bytes())?;
    Ok(())
}

/// Append-mode JSON-lines journal writer: one JSON value per line,
/// flushed per line, so a killed process leaves at most one truncated
/// trailing line (which the resume reader skips). Opening never
/// truncates — resuming a sweep appends below the existing rows.
pub struct JsonlWriter {
    file: std::io::BufWriter<std::fs::File>,
    path: std::path::PathBuf,
}

impl JsonlWriter {
    /// Open `path` for appending, creating it (and parent dirs) if
    /// missing.
    pub fn append(path: &Path) -> Result<JsonlWriter> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        Ok(JsonlWriter {
            file: std::io::BufWriter::new(file),
            path: path.to_path_buf(),
        })
    }

    /// Write one JSON line (the value must already be serialized,
    /// newline-free) and flush it to the OS so the row survives a kill.
    pub fn write_line(&mut self, line: &str) -> Result<()> {
        debug_assert!(!line.contains('\n'), "journal rows are single lines");
        self.file
            .write_all(line.as_bytes())
            .and_then(|_| self.file.write_all(b"\n"))
            .and_then(|_| self.file.flush())
            .with_context(|| format!("writing journal {}", self.path.display()))
    }
}

/// Write a JSON value (pretty) to disk, creating parent dirs.
pub fn write_json(value: &Value, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, value.to_json_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_rendering() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push_nums(&[1.0, 2.5]);
        t.push_raw(vec!["x".into(), "y".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2.5\nx,y\n");
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic]
    fn column_mismatch_panics() {
        let mut t = CsvTable::new(&["a"]);
        t.push_nums(&[1.0, 2.0]);
    }

    #[test]
    fn jsonl_appends_one_flushed_line_per_write() {
        let dir = std::env::temp_dir().join("edgepipe_writer_test");
        let p = dir.join(format!("j_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&p);
        {
            let mut w = JsonlWriter::append(&p).unwrap();
            w.write_line("{\"i\":0}").unwrap();
            w.write_line("{\"i\":1}").unwrap();
        }
        // a second open APPENDS — resume must not clobber history
        {
            let mut w = JsonlWriter::append(&p).unwrap();
            w.write_line("{\"i\":2}").unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "{\"i\":0}\n{\"i\":1}\n{\"i\":2}\n");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn files_roundtrip() {
        let dir = std::env::temp_dir().join("edgepipe_writer_test");
        let mut t = CsvTable::new(&["x"]);
        t.push_nums(&[42.0]);
        let p = dir.join("t.csv");
        write_csv(&t, &p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "x\n42\n");
        let j = dir.join("v.json");
        write_json(&crate::util::json::num(1.5), &j).unwrap();
        assert_eq!(std::fs::read_to_string(&j).unwrap(), "1.5");
    }
}
