//! Loss-curve utilities: resampling onto a common time grid and
//! Monte-Carlo averaging (paper Fig. 4 plots the AVERAGE training loss
//! over random seeds).

/// Linearly interpolate a (time, value) curve at `t` (clamped at ends).
pub fn interp(curve: &[(f64, f64)], t: f64) -> f64 {
    assert!(!curve.is_empty(), "empty curve");
    if t <= curve[0].0 {
        return curve[0].1;
    }
    if t >= curve[curve.len() - 1].0 {
        return curve[curve.len() - 1].1;
    }
    // binary search for the segment containing t
    let mut lo = 0usize;
    let mut hi = curve.len() - 1;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if curve[mid].0 <= t {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let (t0, v0) = curve[lo];
    let (t1, v1) = curve[hi];
    if t1 <= t0 {
        return v0;
    }
    v0 + (v1 - v0) * (t - t0) / (t1 - t0)
}

/// Resample several runs' curves onto a shared uniform grid of `points`
/// between 0 and `t_max`. Returns (grid, per-run values).
pub fn align_curves(
    curves: &[Vec<(f64, f64)>],
    t_max: f64,
    points: usize,
) -> (Vec<f64>, Vec<Vec<f64>>) {
    assert!(points >= 2);
    let grid: Vec<f64> = (0..points)
        .map(|i| t_max * i as f64 / (points - 1) as f64)
        .collect();
    let values = curves
        .iter()
        .map(|c| grid.iter().map(|&t| interp(c, t)).collect())
        .collect();
    (grid, values)
}

/// Pointwise mean curve over aligned runs: returns (grid, mean values).
pub fn mean_curve(
    curves: &[Vec<(f64, f64)>],
    t_max: f64,
    points: usize,
) -> (Vec<f64>, Vec<f64>) {
    let (grid, values) = align_curves(curves, t_max, points);
    let n = values.len().max(1) as f64;
    let mean = (0..grid.len())
        .map(|i| values.iter().map(|v| v[i]).sum::<f64>() / n)
        .collect();
    (grid, mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_endpoints_and_middle() {
        let c = vec![(0.0, 1.0), (10.0, 3.0)];
        assert_eq!(interp(&c, -5.0), 1.0);
        assert_eq!(interp(&c, 15.0), 3.0);
        assert_eq!(interp(&c, 5.0), 2.0);
    }

    #[test]
    fn interp_multi_segment() {
        let c = vec![(0.0, 0.0), (1.0, 10.0), (3.0, 30.0)];
        assert!((interp(&c, 0.5) - 5.0).abs() < 1e-12);
        assert!((interp(&c, 2.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn mean_of_two_constant_curves() {
        let curves = vec![
            vec![(0.0, 1.0), (10.0, 1.0)],
            vec![(0.0, 3.0), (10.0, 3.0)],
        ];
        let (grid, mean) = mean_curve(&curves, 10.0, 5);
        assert_eq!(grid.len(), 5);
        assert!(mean.iter().all(|&v| (v - 2.0).abs() < 1e-12));
    }

    #[test]
    fn duplicate_time_points_are_safe() {
        // block-boundary records can duplicate a timestamp
        let c = vec![(0.0, 5.0), (1.0, 4.0), (1.0, 3.0), (2.0, 2.0)];
        let v = interp(&c, 1.0);
        assert!((3.0..=4.0).contains(&v));
        assert!((interp(&c, 1.5) - 2.5).abs() < 1e-12);
    }
}
