//! Loss-curve utilities: resampling onto a common time grid and
//! Monte-Carlo averaging (paper Fig. 4 plots the AVERAGE training loss
//! over random seeds).
//!
//! Everything here is fallible rather than panicking (the panic-free
//! sweep convention): an empty curve or a degenerate grid is a config
//! problem — e.g. a `loss_every` schedule that yields no loss records —
//! and must surface as an `Err` at the `edgepipe fig4` boundary, not
//! take the process down.

use anyhow::{bail, Result};

/// Linearly interpolate a (time, value) curve at `t` (clamped at ends).
/// Errs on an empty curve (there is nothing to clamp to).
pub fn interp(curve: &[(f64, f64)], t: f64) -> Result<f64> {
    if curve.is_empty() {
        bail!("cannot interpolate an empty curve (no loss records)");
    }
    if t <= curve[0].0 {
        return Ok(curve[0].1);
    }
    if t >= curve[curve.len() - 1].0 {
        return Ok(curve[curve.len() - 1].1);
    }
    // binary search for the segment containing t
    let mut lo = 0usize;
    let mut hi = curve.len() - 1;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if curve[mid].0 <= t {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let (t0, v0) = curve[lo];
    let (t1, v1) = curve[hi];
    if t1 <= t0 {
        return Ok(v0);
    }
    Ok(v0 + (v1 - v0) * (t - t0) / (t1 - t0))
}

/// Resample several runs' curves onto a shared uniform grid of `points`
/// between 0 and `t_max`. Returns (grid, per-run values). Errs when the
/// grid is degenerate (`points < 2`) or any run's curve is empty.
pub fn align_curves(
    curves: &[Vec<(f64, f64)>],
    t_max: f64,
    points: usize,
) -> Result<(Vec<f64>, Vec<Vec<f64>>)> {
    if points < 2 {
        bail!("curve grid needs at least 2 points (got {points})");
    }
    let grid: Vec<f64> = (0..points)
        .map(|i| t_max * i as f64 / (points - 1) as f64)
        .collect();
    let values = curves
        .iter()
        .enumerate()
        .map(|(run, c)| {
            grid.iter()
                .map(|&t| interp(c, t))
                .collect::<Result<Vec<f64>>>()
                .map_err(|e| e.context(format!("aligning run {run}")))
        })
        .collect::<Result<Vec<Vec<f64>>>>()?;
    Ok((grid, values))
}

/// Pointwise mean curve over aligned runs: returns (grid, mean values).
pub fn mean_curve(
    curves: &[Vec<(f64, f64)>],
    t_max: f64,
    points: usize,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let (grid, values) = align_curves(curves, t_max, points)?;
    let n = values.len().max(1) as f64;
    let mean = (0..grid.len())
        .map(|i| values.iter().map(|v| v[i]).sum::<f64>() / n)
        .collect();
    Ok((grid, mean))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_endpoints_and_middle() {
        let c = vec![(0.0, 1.0), (10.0, 3.0)];
        assert_eq!(interp(&c, -5.0).unwrap(), 1.0);
        assert_eq!(interp(&c, 15.0).unwrap(), 3.0);
        assert_eq!(interp(&c, 5.0).unwrap(), 2.0);
    }

    #[test]
    fn interp_multi_segment() {
        let c = vec![(0.0, 0.0), (1.0, 10.0), (3.0, 30.0)];
        assert!((interp(&c, 0.5).unwrap() - 5.0).abs() < 1e-12);
        assert!((interp(&c, 2.0).unwrap() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn mean_of_two_constant_curves() {
        let curves = vec![
            vec![(0.0, 1.0), (10.0, 1.0)],
            vec![(0.0, 3.0), (10.0, 3.0)],
        ];
        let (grid, mean) = mean_curve(&curves, 10.0, 5).unwrap();
        assert_eq!(grid.len(), 5);
        assert!(mean.iter().all(|&v| (v - 2.0).abs() < 1e-12));
    }

    #[test]
    fn duplicate_time_points_are_safe() {
        // block-boundary records can duplicate a timestamp
        let c = vec![(0.0, 5.0), (1.0, 4.0), (1.0, 3.0), (2.0, 2.0)];
        let v = interp(&c, 1.0).unwrap();
        assert!((3.0..=4.0).contains(&v));
        assert!((interp(&c, 1.5).unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_curve_is_an_error_not_a_panic() {
        // reachable from `edgepipe fig4` when a run's loss_every
        // schedule yields no loss records
        let err = interp(&[], 1.0).unwrap_err();
        assert!(err.to_string().contains("empty curve"), "{err:#}");
        let curves = vec![vec![(0.0, 1.0), (10.0, 1.0)], vec![]];
        let err = mean_curve(&curves, 10.0, 5).unwrap_err();
        assert!(format!("{err:#}").contains("run 1"), "{err:#}");
    }

    #[test]
    fn one_point_curve_interpolates_as_a_constant() {
        // a single loss record clamps everywhere — never divides by the
        // zero-width segment
        let c = vec![(2.0, 7.0)];
        for t in [-1.0, 2.0, 5.0] {
            assert_eq!(interp(&c, t).unwrap(), 7.0);
        }
        let (grid, mean) = mean_curve(&[c], 10.0, 3).unwrap();
        assert_eq!(grid, vec![0.0, 5.0, 10.0]);
        assert!(mean.iter().all(|&v| v == 7.0));
    }

    #[test]
    fn degenerate_grid_is_an_error_not_an_assert() {
        let curves = vec![vec![(0.0, 1.0), (10.0, 1.0)]];
        for points in [0, 1] {
            let err = align_curves(&curves, 10.0, points).unwrap_err();
            assert!(
                err.to_string().contains("at least 2 points"),
                "{err:#}"
            );
        }
    }
}
