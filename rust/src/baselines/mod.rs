//! Baseline transmission/training policies the pipelined protocol is
//! compared against (Abl-1 in DESIGN.md):
//!
//! * [`transmit_all_first`] — `n_c = N`: ship the whole dataset in one
//!   block, then train on everything in the remaining time (the paper's
//!   "communicating the entire data set first reduces the bias ... but it
//!   may not leave sufficient time for learning").
//! * [`sequential`] — NO pipelining: the edge node idles during every
//!   transmission and only trains between blocks ... which for an
//!   always-busy channel means it only trains after the last delivered
//!   block. Isolates the gain from overlapping comm and compute.
//!
//! Both are thin adapters over the generic scheduler:
//! `transmit_all_first` is the fixed policy at `n_c = N`; `sequential` is
//! the same single-device traffic under [`OverlapMode::Sequential`].
//!
//! Note: the unified scheduler records the full event stream (BlockSent
//! / BlockDelivered / Finished) for every variant; the seed `sequential`
//! loop recorded only `UpdatesRun` events. Loss trajectories, counters
//! and RNG streams are unchanged — only the (previously sparse) event
//! log gained entries.

use anyhow::Result;

use crate::channel::Channel;
use crate::coordinator::des::{run_des, DesConfig};
use crate::coordinator::executor::BlockExecutor;
use crate::coordinator::run::RunResult;
use crate::coordinator::scheduler::{
    run_schedule, FixedPolicy, OverlapMode, SingleDeviceSource,
};
use crate::data::Dataset;

/// "Transmit everything first": a single block of all N samples.
pub fn transmit_all_first(
    ds: &Dataset,
    cfg: &DesConfig,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let cfg = DesConfig { n_c: ds.n, ..cfg.clone() };
    run_des(ds, &cfg, channel, exec)
}

/// Sequential (non-pipelined) policy: blocks of `n_c` are transmitted,
/// but the edge node performs NO updates while the channel is busy; all
/// computation happens after the final delivery (or never, if
/// transmission fills the whole budget).
pub fn sequential(
    ds: &Dataset,
    cfg: &DesConfig,
    channel: &mut dyn Channel,
    exec: &mut dyn BlockExecutor,
) -> Result<RunResult> {
    let mut source = SingleDeviceSource::new(ds, cfg.seed);
    let mut policy = FixedPolicy(cfg.n_c.max(1).min(ds.n));
    run_schedule(
        ds,
        cfg,
        &mut source,
        &mut policy,
        OverlapMode::Sequential,
        channel,
        exec,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::IdealChannel;
    use crate::coordinator::executor::NativeExecutor;
    use crate::data::synth::{synth_calhousing, SynthSpec};
    use crate::model::RidgeModel;

    fn setup() -> (Dataset, DesConfig) {
        let ds =
            synth_calhousing(&SynthSpec { n: 800, ..Default::default() });
        let cfg = DesConfig {
            alpha: 1e-3,
            ..DesConfig::paper(80, 20.0, 1200.0, 5)
        };
        (ds, cfg)
    }

    fn exec(ds: &Dataset, cfg: &DesConfig) -> NativeExecutor {
        NativeExecutor::new(RidgeModel::new(ds.d, cfg.lambda, ds.n), cfg.alpha)
    }

    #[test]
    fn pipelined_beats_sequential() {
        let (ds, cfg) = setup();
        let pipe = run_des(
            &ds,
            &cfg,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        let seq =
            sequential(&ds, &cfg, &mut IdealChannel, &mut exec(&ds, &cfg))
                .unwrap();
        // same delivery schedule...
        assert_eq!(pipe.samples_delivered, seq.samples_delivered);
        // ...but strictly more updates and a better loss when pipelined
        assert!(
            pipe.updates > seq.updates,
            "{} vs {}",
            pipe.updates,
            seq.updates
        );
        assert!(
            pipe.final_loss < seq.final_loss,
            "{} vs {}",
            pipe.final_loss,
            seq.final_loss
        );
    }

    #[test]
    fn transmit_all_first_matches_nc_equals_n() {
        let (ds, cfg) = setup();
        let a = transmit_all_first(
            &ds,
            &cfg,
            &mut IdealChannel,
            &mut exec(&ds, &cfg),
        )
        .unwrap();
        let direct_cfg = DesConfig { n_c: ds.n, ..cfg.clone() };
        let b = run_des(
            &ds,
            &direct_cfg,
            &mut IdealChannel,
            &mut exec(&ds, &direct_cfg),
        )
        .unwrap();
        assert_eq!(a.final_w, b.final_w);
        assert_eq!(a.blocks_sent, 1);
    }

    #[test]
    fn sequential_updates_only_after_delivery() {
        let (ds, cfg) = setup();
        let seq =
            sequential(&ds, &cfg, &mut IdealChannel, &mut exec(&ds, &cfg))
                .unwrap();
        // delivery ends at B_d * (n_c + n_o); compute-only tail remains
        let b_d = ds.n.div_ceil(cfg.n_c);
        let tail =
            cfg.t_budget - b_d as f64 * (cfg.n_c as f64 + cfg.n_o);
        assert_eq!(seq.updates, (tail / cfg.tau_p).floor() as usize);
    }
}
