//! TOML-subset parser: sections, dotted sections, scalars, and flat
//! arrays — the subset our config files use.
//!
//! ```toml
//! [protocol]
//! n_o = 10.0          # float
//! n_c = 437           # integer
//! pipelined = true    # bool
//! label = "fig3"      # string
//! n_os = [1, 10, 100] # array of scalars
//! ```

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed TOML scalar or array.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Int(i) => Ok(*i as f64),
            TomlValue::Float(f) => Ok(*f),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as u64),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64_arr(&self) -> Result<Vec<f64>> {
        match self {
            TomlValue::Arr(items) => {
                items.iter().map(|v| v.as_f64()).collect()
            }
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_usize_arr(&self) -> Result<Vec<usize>> {
        match self {
            TomlValue::Arr(items) => {
                items.iter().map(|v| v.as_usize()).collect()
            }
            _ => bail!("expected array, got {self:?}"),
        }
    }
}

/// Parsed document: `section.key -> value` (root keys have no prefix).
pub type TomlDoc = BTreeMap<String, TomlValue>;

/// Parse a TOML-subset document into a flat `section.key` map.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unclosed '['", lineno + 1))?
                .trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| {
            anyhow!("line {}: expected 'key = value'", lineno + 1)
        })?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let value = parse_value(value.trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        doc.insert(full_key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside a string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse one scalar or array value.
pub fn parse_value(text: &str) -> Result<TomlValue> {
    let text = text.trim();
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unclosed array"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(&part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unclosed string"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !text.contains(['.', 'e', 'E']) {
        if let Ok(i) = text.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    // bare-word fallback so axis specs like `erasure:0.1`, `fixed:437`
    // or `devices:4:sched=greedy:ch=ideal,erasure:0.1` can be written
    // unquoted in `--set` overrides and config files (',' and '=' cover
    // the device-spec grammar, '+' joins fault clauses; arrays were
    // already consumed above, so a bare comma cannot be confused with
    // an array separator)
    if text.chars().next().is_some_and(|c| c.is_ascii_alphabetic())
        && text.chars().all(|c| {
            c.is_ascii_alphanumeric()
                || matches!(c, ':' | '.' | '_' | '-' | ',' | '=' | '+')
        })
    {
        return Ok(TomlValue::Str(text.to_string()));
    }
    bail!("cannot parse value '{text}'")
}

fn split_top_level(text: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    let mut in_str = false;
    for c in text.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                parts.push(cur.trim().to_string());
                cur.clear();
            }
            c => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur.trim().to_string());
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_scalars() {
        let doc = parse_toml(
            "top = 1\n[protocol]\nn_o = 10.5\nn_c = 437 # comment\n\
             pipelined = true\nlabel = \"fig3\"\n",
        )
        .unwrap();
        assert_eq!(doc["top"], TomlValue::Int(1));
        assert_eq!(doc["protocol.n_o"], TomlValue::Float(10.5));
        assert_eq!(doc["protocol.n_c"], TomlValue::Int(437));
        assert_eq!(doc["protocol.pipelined"], TomlValue::Bool(true));
        assert_eq!(doc["protocol.label"], TomlValue::Str("fig3".into()));
    }

    #[test]
    fn arrays() {
        let doc = parse_toml("xs = [1, 2, 3]\nys = [1.5, \"a\", true]\n")
            .unwrap();
        assert_eq!(doc["xs"].as_usize_arr().unwrap(), vec![1, 2, 3]);
        let ys = match &doc["ys"] {
            TomlValue::Arr(v) => v,
            _ => panic!(),
        };
        assert_eq!(ys[1], TomlValue::Str("a".into()));
    }

    #[test]
    fn dotted_sections() {
        let doc = parse_toml("[a.b]\nc = 2\n").unwrap();
        assert_eq!(doc["a.b.c"], TomlValue::Int(2));
    }

    #[test]
    fn comments_and_underscores() {
        let doc =
            parse_toml("# full line\nn = 18_576\ns = \"has # inside\"\n")
                .unwrap();
        assert_eq!(doc["n"], TomlValue::Int(18576));
        assert_eq!(doc["s"], TomlValue::Str("has # inside".into()));
    }

    #[test]
    fn errors() {
        assert!(parse_toml("[unclosed\n").is_err());
        assert!(parse_toml("novalue\n").is_err());
        assert!(parse_toml("x = [1, 2\n").is_err());
        assert!(parse_toml("x = @@\n").is_err());
    }

    #[test]
    fn bare_words_parse_as_strings() {
        let doc = parse_toml("[scenario]\nchannel = erasure:0.1\n").unwrap();
        assert_eq!(
            doc["scenario.channel"],
            TomlValue::Str("erasure:0.1".into())
        );
        // numbers still win over the bare-word fallback
        assert_eq!(parse_value("437").unwrap(), TomlValue::Int(437));
        assert_eq!(parse_value("1e-4").unwrap(), TomlValue::Float(1e-4));
    }

    #[test]
    fn device_spec_bare_words_parse_as_strings() {
        // the hetero device grammar uses '=' and ','
        let doc = parse_toml(
            "[scenario]\ntraffic = devices:4:sched=greedy:ch=ideal,erasure:0.1\n\
             device_channels = ideal,fading:0.05:0.25:0.6\n",
        )
        .unwrap();
        assert_eq!(
            doc["scenario.traffic"],
            TomlValue::Str("devices:4:sched=greedy:ch=ideal,erasure:0.1".into())
        );
        assert_eq!(
            doc["scenario.device_channels"],
            TomlValue::Str("ideal,fading:0.05:0.25:0.6".into())
        );
        // leading-alphabetic rule still rejects junk
        assert!(parse_value("=x").is_err());
    }

    #[test]
    fn fault_spec_bare_words_parse_as_strings() {
        // fault clauses join with '+'
        let doc = parse_toml(
            "[scenario]\nfault = outage:100:25+retry:4:2:2\n",
        )
        .unwrap();
        assert_eq!(
            doc["scenario.fault"],
            TomlValue::Str("outage:100:25+retry:4:2:2".into())
        );
        // a leading '+' is still junk, not a bare word
        assert!(parse_value("+retry:4").is_err());
    }

    #[test]
    fn scientific_notation() {
        let doc = parse_toml("alpha = 1e-4\nbeta = 2.5E3\n").unwrap();
        assert_eq!(doc["alpha"].as_f64().unwrap(), 1e-4);
        assert_eq!(doc["beta"].as_f64().unwrap(), 2500.0);
    }
}
