//! Configuration system: a TOML-subset parser (offline image has no serde)
//! plus the typed configs every subsystem consumes and `key=value` CLI
//! overrides, mirroring how MaxText/Megatron launchers merge config files
//! with command-line flags.

pub mod parser;
pub mod types;

pub use parser::{parse_toml, TomlValue};
pub use types::{
    DataConfig, ExperimentConfig, ProtocolConfig, ScenarioConfig,
    SweepConfig, TrainConfig,
};
